//! LASSO regularization path — sweep λ from dense to empty solutions on
//! the E2006-tfidf analog, comparing cyclic CD (Friedman et al.) against
//! ACF-CD at every point of the path (the paper's Table 3 workload as a
//! user-facing workflow).
//!
//!     cargo run --release --example lasso_path

use acf_cd::data::{registry, Scale};
use acf_cd::sched::Policy;
use acf_cd::acf::AcfParams;
use acf_cd::solvers::{lasso, SolverConfig};
use acf_cd::util::rng::Rng;
use acf_cd::util::timer::fmt_count;

fn main() {
    let (ds, w_true) =
        registry::regression("e2006-like", Scale(0.4), 7).expect("registry dataset");
    let truth_nnz = w_true.iter().filter(|&&v| v != 0.0).count();
    println!(
        "dataset: {} × {} ({} nnz); planted signal has {truth_nnz} non-zeros\n",
        ds.n_instances(),
        ds.n_features(),
        ds.nnz()
    );
    println!(
        "{:<10} {:>8} {:>14} {:>14} {:>9}  {:>9}",
        "lambda", "nnz(w)", "cyclic iters", "acf iters", "speedup", "objective"
    );
    let prob = lasso::LassoProblem::new(&ds);
    for lambda in [1e-3, 3e-4, 1e-4, 3e-5, 1e-5, 3e-6] {
        let cfg = SolverConfig::with_eps(2e-6);
        let mut cyc = Policy::Cyclic.build(ds.n_features(), AcfParams::default(), Rng::new(1));
        let (_m1, r1) = lasso::solve_prepared(&prob, lambda, cyc.as_mut(), cfg.clone());
        let mut acf = Policy::Acf.build(ds.n_features(), AcfParams::default(), Rng::new(2));
        let (m2, r2) = lasso::solve_prepared(&prob, lambda, acf.as_mut(), cfg);
        println!(
            "{:<10} {:>8} {:>14} {:>14} {:>8.1}x  {:>9.4}",
            lambda,
            lasso::nnz_coefficients(&m2),
            fmt_count(r1.iterations as f64),
            fmt_count(r2.iterations as f64),
            r1.iterations as f64 / r2.iterations.max(1) as f64,
            r2.objective,
        );
        // sanity: both solvers agree on the optimum
        // ε-stationarity bounds the objective gap only loosely at the
        // smallest λ (tiny objective scale) — 1% agreement is the check
        let rel = (r1.objective - r2.objective).abs() / r1.objective.abs().max(1e-6);
        assert!(rel < 1e-2, "objectives diverged at λ = {lambda}: {rel}");
    }
    println!("\n(path computed with a shared pre-transposed design matrix)");
}
