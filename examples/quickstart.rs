//! Quickstart — train a linear SVM with ACF and compare against uniform
//! CD on a synthetic text-classification dataset.
//!
//!     cargo run --release --example quickstart

use acf_cd::acf::AcfParams;
use acf_cd::data::{binary_accuracy, synth};
use acf_cd::sched::{AcfSchedulerPolicy, PermutationScheduler};
use acf_cd::solvers::{svm, SolverConfig};
use acf_cd::util::rng::Rng;

fn main() {
    // 1. A sparse dataset with heterogeneous coordinate importance —
    //    the regime the ACF paper targets.
    let ds = synth::sparse_text(
        &synth::SparseTextSpec {
            name: "quickstart",
            n: 1500,
            d: 6000,
            nnz_per_row: 40,
            zipf_s: 1.0,
            concept_k: 80,
            noise: 0.03,
        },
        &mut Rng::new(42),
    );
    println!(
        "dataset: {} instances × {} features ({} non-zeros)",
        ds.n_instances(),
        ds.n_features(),
        ds.nnz()
    );

    // hard regime: large C means the conflict-pair outliers need their
    // dual variables driven all the way to the bound — the setting where
    // adaptive coordinate frequencies pay off (paper §3.2)
    let c = 1000.0;
    let cfg = SolverConfig::with_eps(0.001);

    // 2. Baseline: liblinear-style random-permutation CD.
    let mut perm = PermutationScheduler::new(ds.n_instances(), Rng::new(1));
    let (model_u, res_u) = svm::solve(&ds, c, &mut perm, cfg.clone());
    println!("\nuniform : {}", res_u.summary());

    // 3. The paper's contribution: ACF scheduling (Algorithms 2 + 3).
    let mut acf = AcfSchedulerPolicy::new(ds.n_instances(), AcfParams::default(), Rng::new(2));
    let (model_a, res_a) = svm::solve(&ds, c, &mut acf, cfg);
    println!("acf     : {}", res_a.summary());

    // 4. Same solution quality, fewer iterations/operations.
    println!(
        "\ntrain accuracy — uniform {:.2}%, acf {:.2}%",
        100.0 * binary_accuracy(&ds, &model_u.w),
        100.0 * binary_accuracy(&ds, &model_a.w),
    );
    println!(
        "speed-up — iterations {:.1}×, operations {:.1}×, wall-clock {:.1}×",
        res_u.iterations as f64 / res_a.iterations as f64,
        res_u.ops as f64 / res_a.ops as f64,
        res_u.seconds / res_a.seconds.max(1e-9),
    );
}
