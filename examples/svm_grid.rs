//! Model-selection workflow — C-grid search with 3-fold cross-validation
//! for a linear SVM, the exact scenario where the paper argues ACF's
//! savings compound ("the computational cost of finding a good value can
//! easily exceed that of training the final model", §7).
//!
//!     cargo run --release --example svm_grid

use acf_cd::coordinator::{cross_validate, run_sweep, JobSpec, Problem, SweepSpec};
use acf_cd::data::Scale;
use acf_cd::sched::Policy;
use acf_cd::util::threadpool::default_workers;

fn main() {
    let dataset = "rcv1-like";
    let grid = vec![0.01, 0.1, 1.0, 10.0, 100.0];
    let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, dataset, Policy::Acf);
    base.scale = Scale(0.4);
    base.eps = 0.01;

    // full grid with both policies + the shrinking baseline
    let outcomes = run_sweep(&SweepSpec {
        base: base.clone(),
        grid: grid.clone(),
        policies: vec![Policy::Acf, Policy::Permutation],
        include_shrinking: true,
        workers: default_workers(),
    })
    .expect("sweep");

    let table = acf_cd::coordinator::comparison_table(
        &format!("SVM grid search on {dataset} (ε = 0.01)"),
        &outcomes,
        "svm-shrinking",
        "C",
    );
    table.print();

    // CV model selection
    println!("\n3-fold cross-validation (ACF policy):");
    let mut best = (grid[0], 0.0);
    for &c in &grid {
        let acc = cross_validate(
            Problem::Svm { c },
            dataset,
            Policy::Acf,
            0.01,
            base.scale,
            3,
            base.seed,
            default_workers(),
        )
        .expect("cv");
        println!("  C = {c:<8} accuracy {:.2}%", 100.0 * acc);
        if acc > best.1 {
            best = (c, acc);
        }
    }
    println!("\nselected C = {} ({:.2}% CV accuracy)", best.0, 100.0 * best.1);

    // total work comparison across the whole grid — the quantity that
    // matters for model selection
    if let Some((it, ops, time)) =
        acf_cd::coordinator::geomean_speedups(&outcomes, "svm-shrinking")
    {
        println!(
            "grid-wide geomean speed-up of ACF over liblinear-shrinking: \
             iterations {it:.2}×, operations {ops:.2}×, time {time:.2}×"
        );
    }
}
