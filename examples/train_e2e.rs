//! End-to-end driver — exercises every layer of the stack on a real
//! small workload and proves they compose (the repository's E2E
//! validation; its output is recorded in EXPERIMENTS.md):
//!
//!   L3  Rust: generate an rcv1-scale dataset, train linear SVMs with
//!       ACF / uniform / shrinking policies, logging the convergence
//!       trace (objective + KKT violation vs iterations);
//!   L2+L1  PJRT: load the AOT JAX/Pallas artifacts and audit the
//!       trained model's primal loss + accuracy through the tiled
//!       validator — a separately-compiled stack must agree with the
//!       Rust-native numbers;
//!   §6  Markov: run the balance + perturbation-curve experiment through
//!       both the native chain and the Pallas cd_sweep kernel.
//!
//!     make artifacts && cargo run --release --example train_e2e

use acf_cd::acf::AcfParams;
use acf_cd::coordinator::{run_job_on, JobSpec, Problem};
use acf_cd::data::{self, Scale};
use acf_cd::markov;
use acf_cd::runtime::{validator, Runtime, MARKOV_M, MARKOV_N};
use acf_cd::sched::Policy;
use acf_cd::solvers::{svm, SolverConfig};
use acf_cd::util::json::{arr_f64, Json};
use acf_cd::util::rng::Rng;

fn main() -> acf_cd::Result<()> {
    let mut evidence = Json::obj();

    // ------------------------------------------------ L3: train + trace
    println!("=== L3: training (rcv1-like, C = 10, ε = 0.01) ===");
    let mut spec = JobSpec::new(Problem::Svm { c: 10.0 }, "rcv1-like", Policy::Acf);
    spec.scale = Scale(0.6);
    let ds = spec.load_dataset()?;
    let split = data::train_test_split(ds.n_instances(), 0.25, &mut Rng::new(3));
    let (train, test) = data::apply(&ds, &split);
    println!(
        "dataset: {} train / {} test instances, {} features",
        train.n_instances(),
        test.n_instances(),
        train.n_features()
    );

    let mut cfg = SolverConfig::with_eps(0.01);
    cfg.trace_every = 2_000;
    let mut acf =
        Policy::Acf.build(train.n_instances(), AcfParams::default(), Rng::new(11));
    let (model, res_acf) = svm::solve(&train, 10.0, acf.as_mut(), cfg.clone());
    println!("acf     : {}", res_acf.summary());
    println!("convergence trace (iteration → objective, violation):");
    for p in res_acf
        .trace
        .points
        .iter()
        .step_by((res_acf.trace.points.len() / 8).max(1))
    {
        println!("  {:>9} → {:>14.4}  viol {:.4}", p.iteration, p.objective, p.violation);
    }
    res_acf.trace.check_monotone(1e-9).expect("objective must be monotone");

    let mut perm =
        Policy::Permutation.build(train.n_instances(), AcfParams::default(), Rng::new(12));
    let (_m2, res_uni) = svm::solve(&train, 10.0, perm.as_mut(), cfg);
    println!("uniform : {}", res_uni.summary());
    let mut shr_spec = spec.clone();
    shr_spec.problem = Problem::SvmShrinking { c: 10.0 };
    let res_shr = run_job_on(&shr_spec, &train).expect("shrinking job failed");
    println!("shrink  : {}", res_shr.result.summary());

    let acc_train = data::binary_accuracy(&train, &model.w);
    let acc_test = data::binary_accuracy(&test, &model.w);
    println!("accuracy: train {:.2}%, test {:.2}%", 100.0 * acc_train, 100.0 * acc_test);
    evidence.set("svm", {
        let mut o = Json::obj();
        o.set("acf_iters", Json::Num(res_acf.iterations as f64))
            .set("uniform_iters", Json::Num(res_uni.iterations as f64))
            .set("shrinking_iters", Json::Num(res_shr.result.iterations as f64))
            .set("speedup_iters_vs_uniform", Json::Num(res_uni.iterations as f64 / res_acf.iterations as f64))
            .set("test_accuracy", Json::Num(acc_test))
            .set("trace_len", Json::Num(res_acf.trace.points.len() as f64));
        o
    });

    // --------------------------------- L2+L1: cross-stack validation
    println!("\n=== L2+L1: PJRT validator audit (AOT JAX/Pallas artifacts) ===");
    let rt = Runtime::load_default()?;
    println!("PJRT platform: {}", rt.platform());
    let rep = validator::validate(&rt, &test, &model.w)?;
    let native_primal = svm::primal_objective(&test, &model.w, 10.0);
    let xla_primal = rep.svm_primal(&model.w, 10.0);
    println!(
        "validator accuracy {:.2}% (native {:.2}%)",
        100.0 * rep.accuracy,
        100.0 * acc_test
    );
    println!("primal objective — native {native_primal:.4}, xla {xla_primal:.4}");
    let rel = (native_primal - xla_primal).abs() / native_primal.abs().max(1.0);
    assert!(rel < 1e-2, "cross-stack primal mismatch: {rel}");
    assert!((rep.accuracy - acc_test).abs() < 1e-9, "accuracy mismatch");
    evidence.set("validator", {
        let mut o = Json::obj();
        o.set("platform", Json::Str(rt.platform()))
            .set("primal_rel_err", Json::Num(rel))
            .set("accuracy", Json::Num(rep.accuracy));
        o
    });

    // ------------------------------------------------ §6: Markov chain
    println!("\n=== §6: Markov-chain experiment (n = 5) ===");
    let mut rng = Rng::new(21);
    let q = markov::Quadratic::rbf_gram(5, 1.0, &mut rng);
    let bal = markov::balance(
        &q,
        &markov::BalanceConfig { steps_per_round: 30_000, ..Default::default() },
        &mut rng,
    );
    let uni = markov::progress_rate(&q, &[0.2; 5], 2_000, 100_000, &mut rng);
    println!(
        "balanced π̄ = {:?}\nρ(π̄) = {:.6} vs ρ(uniform) = {:.6} (gain {:.3}×)",
        bal.pi.iter().map(|p| (p * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
        bal.rho,
        uni.rho,
        bal.rho / uni.rho
    );
    // cross-stack sweep through the Pallas kernel
    let mut qpad = vec![0.0f32; MARKOV_N * MARKOV_N];
    for i in 0..MARKOV_N {
        for j in 0..MARKOV_N {
            qpad[i * MARKOV_N + j] = if i < 5 && j < 5 {
                q.entry(i, j) as f32
            } else if i == j {
                1.0
            } else {
                0.0
            };
        }
    }
    let w0: Vec<f64> = (0..5).map(|_| rng.gaussian()).collect();
    let mut wpad = vec![0.0f32; MARKOV_N];
    for i in 0..5 {
        wpad[i] = w0[i] as f32;
    }
    let seq: Vec<i32> = (0..MARKOV_M).map(|k| (k % 5) as i32).collect();
    let (_w, t_pallas) = rt.cd_sweep_block(&qpad, &wpad, &seq)?;
    let mut chain = markov::Chain { q: &q, w: w0 };
    let t_rust = chain.apply_sequence(&seq.iter().map(|&i| i as u32).collect::<Vec<_>>());
    let rel = (t_pallas as f64 - t_rust).abs() / t_rust.abs().max(1.0);
    println!("cd_sweep log-progress: pallas {t_pallas:.4} vs rust {t_rust:.4} (rel {rel:.4})");
    assert!(rel < 0.05);
    evidence.set("markov", {
        let mut o = Json::obj();
        o.set("pi_bar", arr_f64(&bal.pi))
            .set("rho_balanced", Json::Num(bal.rho))
            .set("rho_uniform", Json::Num(uni.rho))
            .set("cd_sweep_rel_err", Json::Num(rel));
        o
    });

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/train_e2e.json", evidence.to_string_pretty())?;
    println!("\nall layers compose ✓ — evidence written to results/train_e2e.json");
    Ok(())
}
