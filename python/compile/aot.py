"""AOT lowering: L2 graphs → HLO *text* artifacts for the Rust runtime.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the `xla`
crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    args = model.example_args()
    manifest = {
        "tile": {"bl": model.BL, "bd": model.BD},
        "markov": {"n": model.MARKOV_N, "m": model.MARKOV_M},
        "graphs": {},
    }
    for name, fn in model.GRAPHS.items():
        lowered = jax.jit(fn).lower(*args[name])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["graphs"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(a.shape), "dtype": a.dtype.name} for a in args[name]
            ],
            "bytes": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    lower_all(args.out_dir)


if __name__ == "__main__":
    main()
