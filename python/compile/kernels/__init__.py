"""L1 Pallas kernels (build-time only; lowered to HLO via ../aot.py).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO ops
that the Rust runtime's PJRT CPU client executes directly. Real-TPU
performance is estimated structurally (VMEM footprint / MXU utilization)
in DESIGN.md §Hardware-Adaptation.
"""
