"""Sequential CD sweep on a dense quadratic — the §6 Markov-chain
compute hot-spot as an L1 Pallas kernel.

For f(w) = ½ wᵀQw and a block of coordinate indices `seq`, performs the
Newton-projection steps

    g     = Q[i] · w
    gain  = g² / (2·Q[i,i])
    w[i] -= g / Q[i,i]
    total += −log(1 − gain/f);  f −= gain

entirely inside one kernel invocation with Q resident in VMEM — the
HBM↔VMEM traffic is amortized over the whole index block, mirroring how
Algorithm 3 amortizes sampling cost over Θ(n) CD iterations.

The CD recurrence is inherently sequential (each step reads the previous
w), so this kernel exercises Pallas' `fori_loop` control path rather
than the MXU; n ≤ 8 for the paper's Figure-1 instances, so the whole
state (Q: n², w: n) is a few hundred bytes of VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep_kernel(q_ref, w_ref, seq_ref, wout_ref, total_ref):
    q = q_ref[...]
    seq = seq_ref[...]
    w0 = w_ref[...]

    def obj(w):
        return 0.5 * jnp.dot(w, jnp.dot(q, w, preferred_element_type=jnp.float32))

    def body(t, carry):
        w, total = carry
        i = seq[t]
        qi = q[i]
        f_before = obj(w)
        g = jnp.dot(qi, w, preferred_element_type=jnp.float32)
        qii = q[i, i]
        w = w.at[i].add(-g / qii)
        f_after = jnp.maximum(obj(w), 1e-30)
        total = total + (jnp.log(f_before) - jnp.log(f_after))
        # scale invariance (Lemma 1): renormalize every step so f stays
        # O(1) in float32 over arbitrarily long sweeps
        norm = jnp.sqrt(jnp.sum(w * w))
        w = w / jnp.maximum(norm, 1e-30)
        return w, total

    m = seq.shape[0]
    w, total = jax.lax.fori_loop(
        0, m, body, (w0, jnp.array(0.0, dtype=jnp.float32))
    )
    wout_ref[...] = w
    total_ref[...] = total.reshape(total_ref.shape)


@jax.jit
def sweep(q, w, seq):
    """Run the CD sweep. q: (N,N) f32, w: (N,) f32, seq: (M,) int32.

    Returns (w_out (N,), total_log_progress (1,)).
    """
    n = q.shape[0]
    assert q.shape == (n, n) and w.shape == (n,)
    (m,) = seq.shape
    return pl.pallas_call(
        _sweep_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(q, w.astype(jnp.float32), seq.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("reps",))
def sweep_repeated(q, w, seq, *, reps: int):
    """Apply the same index block `reps` times (long-chain simulation),
    renormalizing w between blocks for scale invariance. Returns
    (w_out, total_log_progress (1,))."""

    def body(_, carry):
        w, total = carry
        w2, t = sweep(q, w, seq)
        norm = jnp.sqrt(jnp.sum(w2 * w2))
        return w2 / jnp.maximum(norm, 1e-30), total + t

    w_out, total = jax.lax.fori_loop(
        0, reps, body, (w.astype(jnp.float32), jnp.zeros((1,), jnp.float32))
    )
    return w_out, total
