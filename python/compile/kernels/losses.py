"""Fused masked loss reductions over margins — L1 Pallas kernel.

Given margins m (L,), labels y (L,) and a validity mask (L,) (padding
rows carry mask 0), one pass computes the four reductions the Rust
validator consumes:

    hinge_sum    Σ mask·max(0, 1 − y·m)        (SVM primal loss)
    logistic_sum Σ mask·softplus(−y·m)         (logreg primal loss)
    correct      Σ mask·[y·m > 0]              (accuracy numerator)
    sq_err_sum   Σ mask·(m − y)²               (LASSO residual term)

Fusing margin→elementwise→reduce keeps the elementwise intermediates in
VMEM — they never round-trip to HBM (the analog of what a CUDA kernel
would keep in registers/shared memory). The within-block partial sums
are accumulated across the grid axis in the (4,)-vector output block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BL = 256


def _losses_kernel(m_ref, y_ref, mask_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = m_ref[...]
    y = y_ref[...]
    mask = mask_ref[...]
    ym = y * m
    hinge = jnp.sum(mask * jnp.maximum(0.0, 1.0 - ym))
    # numerically stable softplus(−ym)
    logistic = jnp.sum(
        mask * (jnp.maximum(-ym, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(ym))))
    )
    correct = jnp.sum(mask * (ym > 0.0).astype(m.dtype))
    sq_err = jnp.sum(mask * (m - y) ** 2)
    o_ref[...] += jnp.stack([hinge, logistic, correct, sq_err])


@functools.partial(jax.jit, static_argnames=("bl",))
def binary_eval(m, y, mask, *, bl: int = DEFAULT_BL):
    """Fused reductions; all inputs (L,) with L a multiple of bl.

    Returns a (4,) vector [hinge_sum, logistic_sum, correct, sq_err_sum].
    """
    (l,) = m.shape
    assert l % bl == 0, (l, bl)
    grid = (l // bl,)
    return pl.pallas_call(
        _losses_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec((bl,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((4,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
        interpret=True,
    )(m, y, mask)


def binary_eval_padded(m, y, mask, *, bl: int = DEFAULT_BL):
    """binary_eval() for arbitrary L via zero-padding (mask handles it)."""
    (l,) = m.shape
    lp = -(-l // bl) * bl
    pad = (0, lp - l)
    return binary_eval(jnp.pad(m, pad), jnp.pad(y, pad), jnp.pad(mask, pad), bl=bl)
