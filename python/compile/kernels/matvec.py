"""Tiled dense margins matvec — the validator's L1 hot-spot.

Computes ``m = X @ w`` for a dense data tile X of shape (L, D) by
gridding over (L/BL, D/BD) VMEM blocks: each program multiplies an
(BL, BD) block of X against a (BD,) slice of w on the MXU and
accumulates into the (BL,) output block.

TPU design notes (DESIGN.md §Hardware-Adaptation):
  * BL×BD f32 block at the default (256, 256) = 256 KiB of VMEM for X
    plus 1 KiB for w and 1 KiB for the accumulator — comfortably within
    a TensorCore's ~16 MiB VMEM, leaving room for double-buffering the
    HBM→VMEM stream along the D grid axis.
  * The inner product maps to the MXU as a (BL, BD) × (BD, 1) matmul;
    f32 accumulation avoids bf16 drift across D tiles.
  * Grid order (row-major over (i, j)) makes the j axis innermost so the
    partial-sum accumulator for a row block stays resident.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BL = 256
DEFAULT_BD = 256


def _matvec_kernel(x_ref, w_ref, o_ref):
    """One (i, j) grid cell: o[i] += X[i,j] @ w[j]."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BL, BD) @ (BD,) on the MXU, f32 accumulation
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bl", "bd"))
def margins(x, w, *, bl: int = DEFAULT_BL, bd: int = DEFAULT_BD):
    """m = X @ w with Pallas tiling. Shapes must divide (bl, bd)."""
    l, d = x.shape
    assert l % bl == 0 and d % bd == 0, (l, d, bl, bd)
    grid = (l // bl, d // bd)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bl,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), jnp.float32),
        interpret=True,
    )(x, w)


def margins_padded(x, w, *, bl: int = DEFAULT_BL, bd: int = DEFAULT_BD):
    """margins() for arbitrary shapes via zero-padding to tile multiples."""
    l, d = x.shape
    lp = -(-l // bl) * bl
    dp = -(-d // bd) * bd
    xp = jnp.pad(x, ((0, lp - l), (0, dp - d)))
    wp = jnp.pad(w, (0, dp - d))
    return margins(xp, wp, bl=bl, bd=bd)[:l]
