"""Pure-jnp oracles for every Pallas kernel — the correctness baseline.

Each function here is the mathematically obvious implementation; the
pytest suite asserts the Pallas kernels match these within float32
tolerance across hypothesis-generated shapes.
"""

import jax.numpy as jnp


def margins(x, w):
    """Row margins of a dense data tile: m = X·w. x: (L, D), w: (D,)."""
    return x @ w


def binary_eval(m, y, mask):
    """Masked binary-classification reductions over margins.

    Returns (hinge_sum, logistic_sum, correct_count, sq_err_sum):
      hinge    Σ mask·max(0, 1 − y·m)
      logistic Σ mask·log(1 + exp(−y·m))   (numerically stable)
      correct  Σ mask·[y·m > 0]
      sq_err   Σ mask·(m − y)²             (regression reuse)
    """
    ym = y * m
    hinge = jnp.sum(mask * jnp.maximum(0.0, 1.0 - ym))
    # stable softplus(−ym)
    logistic = jnp.sum(mask * (jnp.maximum(-ym, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(ym)))))
    correct = jnp.sum(mask * (ym > 0.0).astype(m.dtype))
    sq_err = jnp.sum(mask * (m - y) ** 2)
    return hinge, logistic, correct, sq_err


def cd_sweep(q, w, seq):
    """Sequential CD Newton-projection sweep on f(w) = ½ wᵀQw.

    For each index i in seq: w_i ← w_i − (Q_i·w)/Q_ii, accumulating the
    log-progress Σ log f_before − log f_after, renormalizing w after each
    step (the chain is scale invariant; this keeps f representable in
    float32 over long sweeps). Returns (w_out, total).
    Reference implementation with a python loop (small n only).
    """
    total = jnp.array(0.0, dtype=w.dtype)
    for i in list(seq):
        i = int(i)
        f_before = 0.5 * w @ (q @ w)
        g = q[i] @ w
        w = w.at[i].add(-g / q[i, i])
        f_after = jnp.maximum(0.5 * w @ (q @ w), 1e-30)
        total = total + (jnp.log(f_before) - jnp.log(f_after))
        w = w / jnp.maximum(jnp.sqrt(jnp.sum(w * w)), 1e-30)
    return w, total
