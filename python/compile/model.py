"""L2 — the JAX compute graphs the Rust runtime executes (via AOT HLO).

Three graph families, all calling the L1 Pallas kernels:

* ``margins_block``   — one (BL, BD) tile's contribution to m = X·w;
  the Rust validator streams dense tiles of the sparse design matrix
  through this graph and accumulates partial margins.
* ``binary_eval_block`` — fused masked loss/accuracy reductions over a
  margins block (hinge, logistic, correct count, squared error).
* ``cd_sweep_block``  — the §6 Markov-chain CD sweep on a dense Q.

Fixed shapes (AOT contract, mirrored by rust/src/runtime/):
  BL = 256 rows per tile, BD = 256 features per tile,
  MARKOV_N = 8 coordinates, MARKOV_M = 256 steps per sweep block.
"""

import jax.numpy as jnp

from .kernels import cd_sweep as _cd_sweep
from .kernels import losses as _losses
from .kernels import matvec as _matvec

# AOT tile contract — keep in sync with rust/src/runtime/mod.rs.
BL = 256
BD = 256
MARKOV_N = 8
MARKOV_M = 256


def margins_block(x_tile, w_tile):
    """Partial margins of one dense tile: (BL, BD) × (BD,) → (BL,)."""
    return (_matvec.margins(x_tile, w_tile, bl=BL, bd=BD),)


def binary_eval_block(m, y, mask):
    """Fused reductions over a margins block of BL entries.

    Returns a (4,) vector [hinge_sum, logistic_sum, correct, sq_err_sum].
    """
    return (_losses.binary_eval(m, y, mask, bl=BL),)


def cd_sweep_block(q, w, seq):
    """One CD sweep block on the MARKOV_N-dim quadratic."""
    w_out, total = _cd_sweep.sweep(q, w, seq)
    return (w_out, total)


def example_args():
    """ShapeDtypeStructs for AOT lowering of each graph."""
    import jax

    f32 = jnp.float32
    i32 = jnp.int32
    return {
        "margins": (
            jax.ShapeDtypeStruct((BL, BD), f32),
            jax.ShapeDtypeStruct((BD,), f32),
        ),
        "binary_eval": (
            jax.ShapeDtypeStruct((BL,), f32),
            jax.ShapeDtypeStruct((BL,), f32),
            jax.ShapeDtypeStruct((BL,), f32),
        ),
        "cd_sweep": (
            jax.ShapeDtypeStruct((MARKOV_N, MARKOV_N), f32),
            jax.ShapeDtypeStruct((MARKOV_N,), f32),
            jax.ShapeDtypeStruct((MARKOV_M,), i32),
        ),
    }


GRAPHS = {
    "margins": margins_block,
    "binary_eval": binary_eval_block,
    "cd_sweep": cd_sweep_block,
}
