"""AOT pipeline tests: every L2 graph lowers to parseable HLO text, the
manifest is consistent, and the lowered computations still produce
correct numbers when executed through the XLA client from the text —
i.e. exactly what the Rust runtime will do.
"""

import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref


def test_lower_all_writes_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as td:
        manifest = aot.lower_all(td)
        assert set(manifest["graphs"].keys()) == set(model.GRAPHS.keys())
        for name, info in manifest["graphs"].items():
            path = os.path.join(td, info["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert text.startswith("HloModule"), f"{name} not HLO text"
            assert info["bytes"] == len(text)
        # manifest round-trips as JSON
        with open(os.path.join(td, "manifest.json")) as f:
            loaded = json.load(f)
        assert loaded["tile"]["bl"] == model.BL


def _compile_from_text(text):
    """Parse HLO text and compile on the CPU client — the Rust runtime's
    exact path, via the python xla_client for test purposes."""
    from jax._src.lib import xla_client as xc

    comp = xc._xla.hlo_module_from_text(text)
    return comp


def test_margins_graph_numerics_via_text():
    with tempfile.TemporaryDirectory() as td:
        aot.lower_all(td)
        text = open(os.path.join(td, "margins.hlo.txt")).read()
        # Text must parse back into an HLO module (id-reassignment path).
        mod = _compile_from_text(text)
        assert mod is not None
    # numerics: execute the jitted graph directly and compare to oracle
    rng = np.random.default_rng(0)
    x = rng.normal(size=(model.BL, model.BD)).astype(np.float32)
    w = rng.normal(size=(model.BD,)).astype(np.float32)
    (got,) = model.margins_block(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(got), ref.margins(x, w), rtol=2e-5, atol=2e-5)


def test_binary_eval_graph_numerics():
    rng = np.random.default_rng(1)
    m = rng.normal(size=(model.BL,)).astype(np.float32)
    y = np.where(rng.uniform(size=model.BL) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = np.ones(model.BL, np.float32)
    (got,) = model.binary_eval_block(jnp.asarray(m), jnp.asarray(y), jnp.asarray(mask))
    want = jnp.stack(ref.binary_eval(jnp.asarray(m), jnp.asarray(y), jnp.asarray(mask)))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_cd_sweep_graph_numerics():
    rng = np.random.default_rng(2)
    n, m = model.MARKOV_N, model.MARKOV_M
    a = rng.normal(size=(2 * n, n)).astype(np.float32)
    q = a.T @ a / (2 * n) + 0.1 * np.eye(n, dtype=np.float32)
    w = rng.normal(size=(n,)).astype(np.float32)
    seq = rng.integers(0, n, size=m).astype(np.int32)
    w_out, total = model.cd_sweep_block(jnp.asarray(q), jnp.asarray(w), jnp.asarray(seq))
    w_want, t_want = ref.cd_sweep(jnp.asarray(q), jnp.asarray(w), seq)
    assert_allclose(np.asarray(w_out), np.asarray(w_want), rtol=1e-3, atol=1e-3)
    assert_allclose(float(total[0]), float(t_want), rtol=1e-2, atol=1e-2)


def test_graph_shapes_match_manifest_contract():
    args = model.example_args()
    assert args["margins"][0].shape == (model.BL, model.BD)
    assert args["binary_eval"][0].shape == (model.BL,)
    assert args["cd_sweep"][2].shape == (model.MARKOV_M,)
