"""Pallas kernel correctness vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value ranges; every kernel must match its
oracle within float32 tolerance. This is the CORE correctness signal of
the L1 layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import cd_sweep, losses, matvec, ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape, lo=-2.0, hi=2.0):
    return (rng.uniform(lo, hi, size=shape)).astype(np.float32)


# ---------------------------------------------------------------- matvec

@settings(**SETTINGS)
@given(
    li=st.integers(1, 3),
    dj=st.integers(1, 3),
    bl=st.sampled_from([8, 16]),
    bd=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_margins_matches_ref_tiled(li, dj, bl, bd, seed):
    rng = np.random.default_rng(seed)
    l, d = li * bl, dj * bd
    x = rand(rng, l, d)
    w = rand(rng, d)
    got = matvec.margins(jnp.asarray(x), jnp.asarray(w), bl=bl, bd=bd)
    want = ref.margins(x, w)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    l=st.integers(1, 70),
    d=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_margins_padded_arbitrary_shapes(l, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, l, d)
    w = rand(rng, d)
    got = matvec.margins_padded(jnp.asarray(x), jnp.asarray(w), bl=16, bd=16)
    want = ref.margins(x, w)
    assert got.shape == (l,)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_margins_rejects_non_multiple():
    with pytest.raises(AssertionError):
        matvec.margins(jnp.zeros((10, 16)), jnp.zeros((16,)), bl=16, bd=16)


def test_margins_zero_weight_gives_zero():
    x = jnp.ones((16, 16), jnp.float32)
    w = jnp.zeros((16,), jnp.float32)
    out = matvec.margins(x, w, bl=16, bd=16)
    assert_allclose(np.asarray(out), np.zeros(16), atol=0)


# ---------------------------------------------------------------- losses

@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 4),
    bl=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_eval_matches_ref(blocks, bl, seed):
    rng = np.random.default_rng(seed)
    l = blocks * bl
    m = rand(rng, l, lo=-4.0, hi=4.0)
    y = np.where(rng.uniform(size=l) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = (rng.uniform(size=l) < 0.8).astype(np.float32)
    got = losses.binary_eval(jnp.asarray(m), jnp.asarray(y), jnp.asarray(mask), bl=bl)
    want = jnp.stack(ref.binary_eval(jnp.asarray(m), jnp.asarray(y), jnp.asarray(mask)))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(l=st.integers(1, 100), seed=st.integers(0, 2**31 - 1))
def test_binary_eval_padded(l, seed):
    rng = np.random.default_rng(seed)
    m = rand(rng, l, lo=-3.0, hi=3.0)
    y = np.where(rng.uniform(size=l) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = np.ones(l, np.float32)
    got = losses.binary_eval_padded(
        jnp.asarray(m), jnp.asarray(y), jnp.asarray(mask), bl=16
    )
    want = jnp.stack(ref.binary_eval(jnp.asarray(m), jnp.asarray(y), jnp.asarray(mask)))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_binary_eval_mask_zeroes_padding():
    m = jnp.asarray(np.array([10.0] * 8 + [99.0] * 8, np.float32))
    y = jnp.ones((16,), jnp.float32)
    mask = jnp.asarray(np.array([1.0] * 8 + [0.0] * 8, np.float32))
    got = losses.binary_eval(m, y, mask, bl=8)
    # correct-count = 8 (only masked-in rows count)
    assert float(got[2]) == 8.0


def test_binary_eval_known_values():
    m = jnp.asarray(np.array([0.5, -0.5, 2.0, -2.0], np.float32))
    y = jnp.asarray(np.array([1.0, 1.0, -1.0, -1.0], np.float32))
    mask = jnp.ones((4,), jnp.float32)
    got = np.asarray(losses.binary_eval(m, y, mask, bl=4))
    # ym = [0.5, −0.5, −2, 2]; hinge = 0.5+1.5+3+0 = 5
    assert_allclose(got[0], 5.0, rtol=1e-6)
    # correct = 2
    assert got[2] == 2.0
    # sq_err = (0.5−1)²+(−0.5−1)²+(2+1)²+(−2+1)² = .25+2.25+9+1 = 12.5
    assert_allclose(got[3], 12.5, rtol=1e-6)


# -------------------------------------------------------------- cd_sweep

def spd_matrix(rng, n):
    a = rng.normal(size=(2 * n, n)).astype(np.float32)
    q = a.T @ a / (2 * n) + 0.1 * np.eye(n, dtype=np.float32)
    return q


@settings(**SETTINGS)
@given(
    n=st.integers(2, 8),
    m=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_cd_sweep_matches_ref(n, m, seed):
    rng = np.random.default_rng(seed)
    q = spd_matrix(rng, n)
    w = rand(rng, n)
    seq = rng.integers(0, n, size=m).astype(np.int32)
    w_got, tot_got = cd_sweep.sweep(jnp.asarray(q), jnp.asarray(w), jnp.asarray(seq))
    w_want, tot_want = ref.cd_sweep(jnp.asarray(q), jnp.asarray(w), seq)
    assert_allclose(np.asarray(w_got), np.asarray(w_want), rtol=2e-4, atol=2e-4)
    assert_allclose(float(tot_got[0]), float(tot_want), rtol=2e-3, atol=2e-3)


def test_cd_sweep_progress_is_positive_and_unit_norm():
    rng = np.random.default_rng(0)
    n = 6
    q = spd_matrix(rng, n)
    w = rand(rng, n)
    seq = np.arange(n, dtype=np.int32)
    w_out, total = cd_sweep.sweep(jnp.asarray(q), jnp.asarray(w), jnp.asarray(seq))
    w_out = np.asarray(w_out)
    # positive accumulated log-progress and renormalized output state
    assert float(total[0]) > 0.0
    assert_allclose(np.linalg.norm(w_out), 1.0, rtol=1e-5)


def test_cd_sweep_total_is_scale_invariant():
    # Lemma 1: scaling the start point must not change the log-progress.
    rng = np.random.default_rng(3)
    n = 5
    q = spd_matrix(rng, n)
    w = rand(rng, n)
    seq = rng.integers(0, n, size=32).astype(np.int32)
    _, t1 = cd_sweep.sweep(jnp.asarray(q), jnp.asarray(w), jnp.asarray(seq))
    _, t2 = cd_sweep.sweep(jnp.asarray(q), jnp.asarray(w * 7.5), jnp.asarray(seq))
    assert_allclose(float(t1[0]), float(t2[0]), rtol=1e-3, atol=1e-3)


def test_cd_sweep_repeated_accumulates():
    rng = np.random.default_rng(1)
    n = 4
    q = spd_matrix(rng, n)
    w = rand(rng, n)
    seq = np.arange(n, dtype=np.int32)
    _, t1 = cd_sweep.sweep_repeated(jnp.asarray(q), jnp.asarray(w), jnp.asarray(seq), reps=1)
    _, t3 = cd_sweep.sweep_repeated(jnp.asarray(q), jnp.asarray(w), jnp.asarray(seq), reps=3)
    assert float(t3[0]) > float(t1[0]) > 0.0
