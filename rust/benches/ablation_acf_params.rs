//! Ablation — the paper's Table 1 claim: "the algorithm was found to be
//! rather insensitive to these settings". We sweep each ACF parameter
//! (c, p_min/p_max range, η) around the defaults on a linear SVM problem
//! and report the iteration counts; the spread across reasonable
//! settings should stay within a small factor, and every setting should
//! beat the uniform baseline on this ACF-friendly workload.
//!
//! Run: `cargo bench --bench ablation_acf_params [-- --quick]`

use acf_cd::acf::AcfParams;
use acf_cd::bench_util::{BenchConfig, Table};
use acf_cd::coordinator::{run_job_on, JobSpec, Problem};
use acf_cd::data::Scale;
use acf_cd::sched::Policy;
use acf_cd::util::json::Json;
use acf_cd::util::timer::fmt_count;

fn main() {
    let cfg = BenchConfig::from_env();
    let scale = if cfg.quick { Scale(0.12) } else { Scale(0.5) };
    let c_svm = 100.0; // hard problem where adaptation matters
    let mut base = JobSpec::new(Problem::Svm { c: c_svm }, "rcv1-like", Policy::Acf);
    base.scale = scale;
    base.seed = cfg.seed;
    base.eps = 0.01;
    let ds = base.load_dataset().expect("dataset");

    // the ablation grid: one axis at a time around Table 1 defaults
    let variants: Vec<(String, AcfParams)> = vec![
        ("defaults (c=0.2, [1/20,20], η=1/n)".into(), AcfParams::default()),
        ("c = 0.05".into(), AcfParams { c: 0.05, ..Default::default() }),
        ("c = 0.1".into(), AcfParams { c: 0.1, ..Default::default() }),
        ("c = 0.5".into(), AcfParams { c: 0.5, ..Default::default() }),
        ("c = 1.0".into(), AcfParams { c: 1.0, ..Default::default() }),
        (
            "range [1/5, 5]".into(),
            AcfParams { p_min: 0.2, p_max: 5.0, ..Default::default() },
        ),
        (
            "range [1/100, 100]".into(),
            AcfParams { p_min: 0.01, p_max: 100.0, ..Default::default() },
        ),
        ("η = 10/n".into(), AcfParams { eta: None, ..Default::default() }), // patched below
        ("η = 0.1/n".into(), AcfParams { eta: None, ..Default::default() }),
    ];
    let n = ds.n_instances() as f64;
    let mut variants = variants;
    variants[7].1.eta = Some(10.0 / n);
    variants[8].1.eta = Some(0.1 / n);

    // uniform baseline for reference
    let mut uni_spec = base.clone();
    uni_spec.policy = Policy::Permutation;
    let uni = run_job_on(&uni_spec, &ds).expect("job failed");

    let mut t = Table::new(
        &format!("ACF parameter ablation — linear SVM, rcv1-like, C = {c_svm}"),
        &["variant", "iters", "ops", "sec", "vs defaults", "vs uniform"],
    );
    let mut results = Json::obj();
    results.set("uniform_iters", Json::Num(uni.result.iterations as f64));
    let outcomes: Vec<_> = acf_cd::util::threadpool::parallel_map(
        variants.len(),
        cfg.workers,
        |k| {
            let mut spec = base.clone();
            spec.acf_params = variants[k].1;
            run_job_on(&spec, &ds).expect("job failed")
        },
    );
    let default_iters = outcomes[0].result.iterations as f64;
    let mut arr = Vec::new();
    for ((label, _), out) in variants.iter().zip(outcomes.iter()) {
        let it = out.result.iterations as f64;
        t.row(vec![
            label.clone(),
            fmt_count(it),
            fmt_count(out.result.ops as f64),
            format!("{:.3}", out.result.seconds),
            format!("{:.2}", it / default_iters),
            format!("{:.2}", it / uni.result.iterations as f64),
        ]);
        let mut o = out.to_json();
        o.set("variant", Json::Str(label.clone()));
        arr.push(o);
    }
    t.row(vec![
        "uniform (reference)".into(),
        fmt_count(uni.result.iterations as f64),
        fmt_count(uni.result.ops as f64),
        format!("{:.3}", uni.result.seconds),
        format!("{:.2}", uni.result.iterations as f64 / default_iters),
        "1.00".into(),
    ]);
    t.print();
    results.set("variants", Json::Arr(arr));

    // insensitivity audit: all ACF variants within a modest factor of the
    // defaults (the paper's Table 1 claim)
    let max_ratio = outcomes
        .iter()
        .map(|o| o.result.iterations as f64 / default_iters)
        .fold(0.0f64, f64::max);
    println!("\nmax iteration ratio across ACF variants: {max_ratio:.2}");
    results.set("max_ratio_vs_defaults", Json::Num(max_ratio));
    cfg.finish(results);
}
