//! Figure 1 — the §6 Markov-chain experiment: for random RBF-Gram
//! quadratics in dimensions n ∈ {4, 5, 6, 7}, balance π with the Rprop
//! procedure to get π̄ ≈ π*, then sweep the perturbation curves
//! γ_{π̄,i}(t) for t ∈ {−1, −½, −¼, −⅒, 0, ⅒, ¼, ½, 1} and report
//! ρ(γ)/ρ(π̄) per coordinate. Conjecture 1 predicts every curve is
//! uni-modal with its maximum at t = 0.
//!
//! The same sweep mechanics run through the AOT `cd_sweep` Pallas kernel
//! (L1) via the PJRT runtime as a cross-stack consistency check.
//!
//! Run: `cargo bench --bench figure1_markov [-- --quick]`

use acf_cd::bench_util::{BenchConfig, Table};
use acf_cd::markov::{self, BalanceConfig, Quadratic, T_GRID};
use acf_cd::runtime::Runtime;
use acf_cd::util::json::{arr_f64, Json};
use acf_cd::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let dims: Vec<usize> = if cfg.quick { vec![4, 5] } else { vec![4, 5, 6, 7] };
    let steps: u64 = if cfg.quick { 500_000 } else { 4_000_000 };
    let mut results = Json::obj();
    let mut peak_count = 0usize;
    let mut curve_count = 0usize;
    for &n in &dims {
        let mut rng = Rng::new(cfg.seed ^ n as u64);
        let q = Quadratic::rbf_gram(n, 3.0, &mut rng);
        let bal = markov::balance(
            &q,
            &BalanceConfig {
                steps_per_round: steps / 4,
                max_rounds: 80,
                tol: 0.02,
                ..Default::default()
            },
            &mut rng,
        );
        eprintln!(
            "n = {n}: balanced after {} rounds, imbalance {:.3}, ρ(π̄) = {:.6}",
            bal.rounds, bal.imbalance, bal.rho
        );
        let curves = markov::curves_around(&q, &bal.pi, 4_000, steps, &mut rng);
        let mut headers = vec!["coord".to_string()];
        headers.extend(T_GRID.iter().map(|t| format!("t={t}")));
        headers.push("max@0".into());
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Figure 1 (analog) — ρ(γ_π̄,i(t))/ρ(π̄), n = {n}"),
            &header_refs,
        );
        let mut jn = Json::obj();
        jn.set("pi_bar", arr_f64(&bal.pi)).set("rho", Json::Num(bal.rho));
        let mut jcurves = Vec::new();
        for c in &curves {
            curve_count += 1;
            let peaked = c.max_at_zero(0.02);
            if peaked {
                peak_count += 1;
            }
            let mut row = vec![format!("{}", c.coordinate)];
            row.extend(c.relative_rho.iter().map(|r| format!("{r:.4}")));
            row.push(if peaked { "yes".into() } else { "NO".into() });
            t.row(row);
            jcurves.push(arr_f64(&c.relative_rho));
        }
        jn.set("curves", Json::Arr(jcurves));
        t.print();
        results.set(&format!("n{n}"), jn);
    }
    println!(
        "\n{peak_count}/{curve_count} curves peak at t = 0 (Conjecture 1 signature)"
    );
    results.set("curves_peaked", Json::Num(peak_count as f64));
    results.set("curves_total", Json::Num(curve_count as f64));

    // Cross-stack check: run a fixed coordinate sequence through the AOT
    // Pallas cd_sweep kernel and the native Rust chain; log-progress must
    // agree (documents that L1 composes with L3 on this experiment).
    match Runtime::load_default() {
        Ok(rt) => {
            use acf_cd::runtime::{MARKOV_M, MARKOV_N};
            let n = 6usize;
            let mut rng = Rng::new(cfg.seed ^ 0xCD);
            let quad = Quadratic::rbf_gram(n, 1.0, &mut rng);
            let mut q = vec![0.0f32; MARKOV_N * MARKOV_N];
            for i in 0..MARKOV_N {
                for j in 0..MARKOV_N {
                    q[i * MARKOV_N + j] = if i < n && j < n {
                        quad.entry(i, j) as f32
                    } else if i == j {
                        1.0
                    } else {
                        0.0
                    };
                }
            }
            let w0: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let mut w_pad = vec![0.0f32; MARKOV_N];
            for i in 0..n {
                w_pad[i] = w0[i] as f32;
            }
            let seq: Vec<i32> = (0..MARKOV_M).map(|k| ((k * 5 + 1) % n) as i32).collect();
            let (_w, total_pallas) = rt.cd_sweep_block(&q, &w_pad, &seq).expect("cd_sweep");
            let mut chain = markov::Chain { q: &quad, w: w0 };
            let sequ: Vec<u32> = seq.iter().map(|&i| i as u32).collect();
            let total_rust = chain.apply_sequence(&sequ);
            let rel = (total_pallas as f64 - total_rust).abs() / total_rust.abs().max(1.0);
            println!(
                "cross-stack cd_sweep: pallas {total_pallas:.4} vs rust {total_rust:.4} (rel {rel:.4})"
            );
            results.set("cross_stack_rel_err", Json::Num(rel));
            assert!(rel < 0.05, "Pallas/Rust sweep mismatch");
        }
        Err(e) => eprintln!("skipping cross-stack check (artifacts not built): {e}"),
    }
    cfg.finish(results);
}
