//! Figure 2 — linear SVM training-time curves as a function of C, for
//! liblinear (shrinking) and ACF-CD at ε = 0.01 (solid) and ε = 0.001
//! (dashed), with 3-fold cross-validation accuracy plotted alongside.
//! This bench emits the same data series as the figure: per dataset, a
//! (C, time_liblinear, time_acf) series per ε plus a (C, cv_accuracy)
//! series.
//!
//! Run: `cargo bench --bench figure2_svm_curves [-- --quick]`

use acf_cd::bench_util::{BenchConfig, Table};
use acf_cd::coordinator::{cross_validate, run_sweep, JobSpec, Problem, SweepSpec};
use acf_cd::data::Scale;
use acf_cd::sched::Policy;
use acf_cd::util::json::{arr_f64, Json};

fn main() {
    let cfg = BenchConfig::from_env();
    let (scale, datasets, grid): (Scale, Vec<&str>, Vec<f64>) = if cfg.quick {
        (Scale(0.12), vec!["rcv1-like"], vec![0.1, 1.0, 10.0])
    } else {
        (
            Scale(0.6),
            vec!["news20-like", "rcv1-like", "url-like", "covtype-like"],
            vec![0.01, 0.1, 1.0, 10.0, 100.0, 1000.0],
        )
    };
    let mut results = Json::obj();
    for name in &datasets {
        let mut series = Json::obj();
        series.set("c_grid", arr_f64(&grid));
        let mut t = Table::new(
            &format!("Figure 2 (analog) — training time vs C on {name}"),
            &["C", "lib ε=.01", "acf ε=.01", "lib ε=.001", "acf ε=.001", "3-fold CV"],
        );
        let mut rows: Vec<Vec<String>> = grid.iter().map(|c| vec![format!("{c}")]).collect();
        for &eps in &[0.01, 0.001] {
            let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, name, Policy::Acf);
            base.scale = scale;
            base.seed = cfg.seed;
            base.eps = eps;
            base.max_iterations = if cfg.quick { 5_000_000 } else { 60_000_000 };
            let outcomes = run_sweep(&SweepSpec {
                base,
                grid: grid.clone(),
                policies: vec![Policy::Acf],
                selectors: vec![],
                include_shrinking: true,
                workers: cfg.workers,
            })
            .expect("sweep");
            let mut lib_times = Vec::new();
            let mut acf_times = Vec::new();
            for (gi, &c) in grid.iter().enumerate() {
                let lib = outcomes
                    .iter()
                    .find(|o| {
                        o.spec.problem.parameter() == c
                            && o.spec.problem.family() == "svm-shrinking"
                    })
                    .unwrap();
                let acf = outcomes
                    .iter()
                    .find(|o| o.spec.problem.parameter() == c && o.spec.policy == Policy::Acf)
                    .unwrap();
                let fmt = |o: &acf_cd::coordinator::JobOutcome| {
                    if o.result.status.converged() {
                        format!("{:.3}", o.result.seconds)
                    } else {
                        "—".to_string()
                    }
                };
                rows[gi].push(fmt(lib));
                rows[gi].push(fmt(acf));
                lib_times.push(lib.result.seconds);
                acf_times.push(acf.result.seconds);
            }
            series.set(&format!("liblinear_sec_eps{eps}"), arr_f64(&lib_times));
            series.set(&format!("acf_sec_eps{eps}"), arr_f64(&acf_times));
        }
        // CV accuracy series (green curve in the paper's figure)
        let mut cvs = Vec::new();
        for (gi, &c) in grid.iter().enumerate() {
            let acc = cross_validate(
                Problem::Svm { c },
                name,
                Policy::Acf,
                0.01,
                scale,
                3,
                cfg.seed,
                cfg.workers,
            )
            .unwrap_or(f64::NAN);
            rows[gi].push(format!("{:.1}%", 100.0 * acc));
            cvs.push(acc);
        }
        series.set("cv_accuracy", arr_f64(&cvs));
        for r in rows {
            t.row(r);
        }
        t.print();
        // figure-shape audit: best CV accuracy should be interior
        let best = cvs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!(
            "best CV C = {} ({}) — interior of tested range: {}",
            grid[best],
            name,
            best > 0 && best + 1 < grid.len()
        );
        results.set(name, series);
    }
    cfg.finish(results);
}
