//! Sparse-kernel micro-benchmark — ns per CD-step primitive, across row
//! densities nnz ∈ {4, 32, 256, 4096}:
//!
//!   * gather dot: sequential bounds-checked reference
//!     (`kernels::dot_dense_scalar`, `#[inline(never)]` so the baseline
//!     stays a real call) vs the always-compiled 4-way scalar unroll
//!     (`kernels::scalar::dot`) vs the runtime-dispatched SIMD tier
//!     behind `RowView::dot_dense`,
//!   * scatter axpy: the same three levels (`kernels::axpy_scalar`,
//!     `kernels::scalar::axpy`, `RowView::axpy_into`),
//!   * one full CD step: split `dot_dense` + `axpy_into` vs the fused
//!     `RowView::step` (same slices, one bounds gate), plus the fused
//!     step pinned to the scalar-unroll tier,
//!   * the software-pipelined batched dot (`kernels::dot_many_unchecked`).
//!
//! The resolved dispatch tier (`avx2+fma` / `sse2` / `neon` / `scalar`)
//! is recorded in the JSON (`kernel_tier`, plus `arch`), so numbers from
//! different hosts are comparable. Rows share one index pattern so the
//! numbers isolate kernel instruction overhead (bounds checks,
//! dependency chains) rather than cache-miss behavior — the end-to-end
//! story lives in `scaling_shards` / `microbench_hotpath`.
//!
//! Run: `cargo bench --bench kernel_microbench [-- --quick]`
//! Writes `BENCH_kernel_microbench.json`; the CI `bench-smoke` job fails
//! if the fused step is slower than the split dot+axpy reference, or if
//! the SIMD tier falls below 0.95× the scalar unroll at nnz ≥ 32.

use acf_cd::bench_util::{bench_fn, write_bench_summary, BenchConfig, BenchReport};
use acf_cd::sparse::{kernels, RowView};
use acf_cd::util::json::Json;
use acf_cd::util::rng::Rng;

const NNZ_SIZES: [usize; 4] = [4, 32, 256, 4096];

/// Per-step scatter scale: tiny so thousands of repeated sweeps cannot
/// drift `w` out of its magnitude range, non-zero so the scatter always
/// executes.
const SCALE: f64 = 1e-12;

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = if cfg.quick { 25 } else { 80 };
    let warmup = 3;
    let sweep_elems = if cfg.quick { 1usize << 16 } else { 1 << 18 };
    let mut rng = Rng::new(cfg.seed);
    let tier = kernels::active_tier_name();
    // available_tiers() lists the always-compiled scalar unroll first
    let scalar_tier = kernels::available_tiers()[0];
    assert_eq!(scalar_tier.name(), "scalar");
    let mut out = Json::obj();
    out.set("bench", Json::Str("kernel_microbench".into()));
    out.set("quick", Json::Bool(cfg.quick));
    out.set("kernel_tier", Json::Str(tier.into()));
    out.set("arch", Json::Str(std::env::consts::ARCH.into()));
    println!("sparse-kernel microbench — ns per primitive, {iters} samples per point, dispatch tier {tier}");

    for &nnz in &NNZ_SIZES {
        let d = 4 * nnz;
        let rows = (sweep_elems / nnz).max(8);
        // strided, strictly increasing, duplicate-free — the CSR row
        // shape the kernels are specified for
        let indices: Vec<u32> = (0..nnz as u32).map(|k| 4 * k).collect();
        let values: Vec<Vec<f64>> =
            (0..rows).map(|_| (0..nnz).map(|_| rng.uniform_range(-1.0, 1.0)).collect()).collect();
        let w0: Vec<f64> = (0..d).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        // validated once, outside the timed region (RowView::new checks
        // the strictly-increasing invariant the unchecked kernels need)
        let views: Vec<RowView> = values.iter().map(|v| RowView::new(&indices, v)).collect();
        let row = |r: usize| views[r];

        // ---- gather dot ----------------------------------------------
        let dot_scalar = bench_fn(&format!("dot/scalar nnz={nnz}"), warmup, iters, || {
            let mut acc = 0.0;
            for r in 0..rows {
                acc += kernels::dot_dense_scalar(&indices, &values[r], &w0);
            }
            acc
        });
        let dot_unrolled = bench_fn(&format!("dot/unrolled nnz={nnz}"), warmup, iters, || {
            let mut acc = 0.0;
            for r in 0..rows {
                // SAFETY: indices are 4k < d = 4·nnz, validated above.
                acc += unsafe { kernels::scalar::dot(&indices, &values[r], &w0) };
            }
            acc
        });
        let dot_simd = bench_fn(&format!("dot/{tier} nnz={nnz}"), warmup, iters, || {
            let mut acc = 0.0;
            for r in 0..rows {
                acc += row(r).dot_dense(&w0);
            }
            acc
        });
        let pairs: Vec<(&[u32], &[f64])> = values.iter().map(|v| (indices.as_slice(), v.as_slice())).collect();
        let mut dots = vec![0.0; rows];
        let dot_many = bench_fn(&format!("dot_many/{tier} nnz={nnz}"), warmup, iters, || {
            // SAFETY: every pair shares the validated strided indices.
            unsafe { kernels::dot_many_unchecked(&pairs, &w0, &mut dots) };
            dots[0]
        });

        // ---- scatter axpy --------------------------------------------
        let mut w = w0.clone();
        let axpy_scalar = bench_fn(&format!("axpy/scalar nnz={nnz}"), warmup, iters, || {
            for r in 0..rows {
                kernels::axpy_scalar(SCALE, &indices, &values[r], &mut w);
            }
            w[0]
        });
        let axpy_unrolled = bench_fn(&format!("axpy/unrolled nnz={nnz}"), warmup, iters, || {
            for r in 0..rows {
                // SAFETY: indices are 4k < d = 4·nnz, validated above.
                unsafe { kernels::scalar::axpy(SCALE, &indices, &values[r], &mut w) };
            }
            w[0]
        });
        let axpy_simd = bench_fn(&format!("axpy/{tier} nnz={nnz}"), warmup, iters, || {
            for r in 0..rows {
                row(r).axpy_into(SCALE, &mut w);
            }
            w[0]
        });

        // ---- one full CD step: split vs fused ------------------------
        let split = bench_fn(&format!("step/split dot+axpy nnz={nnz}"), warmup, iters, || {
            let mut acc = 0.0;
            for r in 0..rows {
                let rv = row(r);
                let dot = rv.dot_dense(&w);
                rv.axpy_into(SCALE * dot, &mut w);
                acc += dot;
            }
            acc
        });
        let fused = bench_fn(&format!("step/fused {tier} nnz={nnz}"), warmup, iters, || {
            let mut acc = 0.0;
            for r in 0..rows {
                let (dot, _) = row(r).step(&mut w, |dot| SCALE * dot);
                acc += dot;
            }
            acc
        });
        let fused_unrolled = bench_fn(&format!("step/fused unrolled nnz={nnz}"), warmup, iters, || {
            let mut acc = 0.0;
            for r in 0..rows {
                // SAFETY: indices are 4k < d = 4·nnz, validated above.
                let (dot, _) = unsafe { scalar_tier.step(&indices, &values[r], &mut w, |dot| SCALE * dot) };
                acc += dot;
            }
            acc
        });

        for r in [
            &dot_scalar,
            &dot_unrolled,
            &dot_simd,
            &dot_many,
            &axpy_scalar,
            &axpy_unrolled,
            &axpy_simd,
            &split,
            &fused,
            &fused_unrolled,
        ] {
            r.print();
        }
        let ns = |rep: &BenchReport| rep.median() / rows as f64 * 1e9;
        let mut e = Json::obj();
        e.set("rows_per_sweep", Json::Num(rows as f64))
            .set("dot_scalar_ns", Json::Num(ns(&dot_scalar)))
            .set("dot_unrolled_ns", Json::Num(ns(&dot_unrolled)))
            .set("dot_simd_ns", Json::Num(ns(&dot_simd)))
            .set("dot_many_ns", Json::Num(ns(&dot_many)))
            .set("axpy_scalar_ns", Json::Num(ns(&axpy_scalar)))
            .set("axpy_unrolled_ns", Json::Num(ns(&axpy_unrolled)))
            .set("axpy_simd_ns", Json::Num(ns(&axpy_simd)))
            .set("split_dot_axpy_ns", Json::Num(ns(&split)))
            .set("fused_step_ns", Json::Num(ns(&fused)))
            .set("fused_unrolled_ns", Json::Num(ns(&fused_unrolled)))
            .set("dot_unrolled_speedup", Json::Num(ns(&dot_scalar) / ns(&dot_unrolled)))
            .set("axpy_unrolled_speedup", Json::Num(ns(&axpy_scalar) / ns(&axpy_unrolled)))
            .set("dot_simd_over_unrolled", Json::Num(ns(&dot_unrolled) / ns(&dot_simd)))
            .set("axpy_simd_over_unrolled", Json::Num(ns(&axpy_unrolled) / ns(&axpy_simd)))
            .set("fused_simd_over_unrolled", Json::Num(ns(&fused_unrolled) / ns(&fused)))
            .set("fused_over_split", Json::Num(ns(&split) / ns(&fused)));
        out.set(&format!("nnz_{nnz}"), e);
        println!(
            "nnz={nnz}: dot {:.2}x, axpy {:.2}x, fused/split {:.2}x, {tier}/unrolled dot {:.2}x axpy {:.2}x",
            ns(&dot_scalar) / ns(&dot_unrolled),
            ns(&axpy_scalar) / ns(&axpy_unrolled),
            ns(&split) / ns(&fused),
            ns(&dot_unrolled) / ns(&dot_simd),
            ns(&axpy_unrolled) / ns(&axpy_simd)
        );
    }

    write_bench_summary("kernel_microbench", &out);
    cfg.finish(out);
}
