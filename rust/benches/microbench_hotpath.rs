//! Hot-path micro-benchmarks — the L3 profiling substrate for the perf
//! pass (EXPERIMENTS.md §Perf). Times the primitives the CD inner loop
//! is built from:
//!
//!   * scheduler next()+report() per policy (ACF overhead vs baselines),
//!   * Algorithm 3 block generation,
//!   * sparse dot / axpy at text-dataset sparsity,
//!   * one full SVM CD step,
//!   * PJRT margins-tile dispatch (validator path).
//!
//! Run: `cargo bench --bench microbench_hotpath [-- --quick]`

use acf_cd::acf::{AcfParams, Preferences, SequenceGenerator};
use acf_cd::bench_util::{bench_fn, black_box, BenchConfig};
use acf_cd::data::synth;
use acf_cd::sched::{
    AcfSchedulerPolicy, CyclicScheduler, PermutationScheduler, Scheduler, UniformScheduler,
};
use acf_cd::util::json::Json;
use acf_cd::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let iters = if cfg.quick { 20 } else { 60 };
    let n = 4096usize;
    let mut reports = Vec::new();

    // ---- scheduler overhead: 10k next()+report() cycles ---------------
    let cycles = 10_000usize;
    {
        let mut s = CyclicScheduler::new(n);
        reports.push(bench_fn("sched/cyclic 10k next+report", 3, iters, || {
            let mut acc = 0usize;
            for _ in 0..cycles {
                let i = s.next();
                s.report(i, 1.0);
                acc += i;
            }
            acc
        }));
    }
    {
        let mut s = PermutationScheduler::new(n, Rng::new(1));
        reports.push(bench_fn("sched/permutation 10k next+report", 3, iters, || {
            let mut acc = 0usize;
            for _ in 0..cycles {
                let i = s.next();
                s.report(i, 1.0);
                acc += i;
            }
            acc
        }));
    }
    {
        let mut s = UniformScheduler::new(n, Rng::new(2));
        reports.push(bench_fn("sched/uniform 10k next+report", 3, iters, || {
            let mut acc = 0usize;
            for _ in 0..cycles {
                let i = s.next();
                s.report(i, 1.0);
                acc += i;
            }
            acc
        }));
    }
    {
        let mut s = AcfSchedulerPolicy::new(n, AcfParams::default(), Rng::new(3));
        let mut g = 0.5f64;
        reports.push(bench_fn("sched/acf 10k next+report", 3, iters, || {
            let mut acc = 0usize;
            for _ in 0..cycles {
                let i = s.next();
                g = (g * 1.1) % 2.0;
                s.report(i, g);
                acc += i;
            }
            acc
        }));
    }

    // ---- Algorithm 3 block generation ---------------------------------
    {
        let mut prefs = Preferences::new(n, AcfParams::default());
        for i in 0..n {
            prefs.update(i, 1.0);
        }
        let mut gen = SequenceGenerator::new(n);
        let mut rng = Rng::new(4);
        let mut buf = Vec::with_capacity(2 * n);
        reports.push(bench_fn("acf/block generation (n=4096)", 3, iters, || {
            gen.next_block(&prefs, &mut rng, &mut buf);
            buf.len()
        }));
    }

    // ---- sparse kernel ops at text sparsity ----------------------------
    let ds = synth::sparse_text(
        &synth::SparseTextSpec {
            name: "bench",
            n: 2000,
            d: 8000,
            nnz_per_row: 50,
            zipf_s: 1.0,
            concept_k: 60,
            noise: 0.03,
        },
        &mut Rng::new(5),
    );
    let w = vec![0.1f64; ds.n_features()];
    {
        let x = &ds.x;
        reports.push(bench_fn("sparse/2000 row dots (50 nnz)", 3, iters, || {
            let mut acc = 0.0;
            for i in 0..x.rows() {
                acc += x.row(i).dot_dense(&w);
            }
            acc
        }));
    }
    {
        let x = &ds.x;
        let mut wmut = w.clone();
        reports.push(bench_fn("sparse/2000 row axpy (50 nnz)", 3, iters, || {
            for i in 0..x.rows() {
                x.row(i).axpy_into(1e-9, &mut wmut);
            }
            wmut[0]
        }));
    }

    // ---- one SVM CD epoch ----------------------------------------------
    {
        let q_diag = ds.x.row_norms_sq();
        let mut alpha = vec![0.0f64; ds.n_instances()];
        let mut wv = vec![0.0f64; ds.n_features()];
        let c = 1.0;
        reports.push(bench_fn("svm/one epoch of CD steps (2000)", 1, iters, || {
            let mut progress = 0.0;
            for i in 0..ds.n_instances() {
                let row = ds.x.row(i);
                let g = ds.y[i] * row.dot_dense(&wv) - 1.0;
                let qii = q_diag[i];
                if qii > 0.0 {
                    let old = alpha[i];
                    let new = (old - g / qii).clamp(0.0, c);
                    let d = new - old;
                    if d != 0.0 {
                        alpha[i] = new;
                        row.axpy_into(d * ds.y[i], &mut wv);
                        progress += -(g * d + 0.5 * qii * d * d);
                    }
                }
            }
            progress
        }));
    }

    // ---- PJRT validator dispatch ----------------------------------------
    match acf_cd::runtime::Runtime::load_default() {
        Ok(rt) => {
            use acf_cd::runtime::{BD, BL};
            let mut rng = Rng::new(6);
            let x: Vec<f32> = (0..BL * BD).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let wt: Vec<f32> = (0..BD).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            reports.push(bench_fn("pjrt/margins tile (256×256)", 2, iters.min(30), || {
                black_box(rt.margins_tile(&x, &wt).unwrap())
            }));
            let m: Vec<f32> = (0..BL).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let y: Vec<f32> = (0..BL).map(|_| 1.0).collect();
            let mask = vec![1.0f32; BL];
            reports.push(bench_fn("pjrt/binary_eval block", 2, iters.min(30), || {
                black_box(rt.binary_eval_block(&m, &y, &mask).unwrap())
            }));
        }
        Err(e) => eprintln!("skipping PJRT microbench: {e}"),
    }

    println!();
    for r in &reports {
        r.print();
    }
    let mut results = Json::obj();
    results.set("reports", Json::Arr(reports.iter().map(|r| r.to_json()).collect()));
    // machine-readable medians for the cross-PR perf trajectory
    let mut medians = Json::obj();
    for r in &reports {
        medians.set(&r.name, Json::Num(r.median()));
    }
    let mut summary = Json::obj();
    summary.set("bench", Json::Str("microbench_hotpath".into())).set("median_s", medians);
    acf_cd::bench_util::write_bench_summary("microbench_hotpath", &summary);
    cfg.finish(results);
}
