//! Policy face-off — apples-to-apples comparison of every coordinate
//! selector in the [`acf_cd::select`] subsystem on three tasks
//! (svm / lasso / logreg).
//!
//! Protocol: every selector solves the same problem instance to the
//! same KKT ε; afterwards the *target objective* is derived from the
//! better of the ACF and uniform final objectives
//! (`f* + rel_tol·|f*|` — those two are the gated contenders, so the
//! target is always reachable by at least one of them) and each run's
//! convergence trace (one point per epoch) is scanned for the first
//! epoch/wall-clock time at which the target was reached. This makes
//! "epochs-to-target" comparable even though the selectors stop at
//! different iteration counts.
//!
//! Emits `BENCH_policy_faceoff.json` with, per task and per selector:
//! `epochs_to_target`, `seconds_to_target`, totals and the final
//! objective — plus the headline booleans the CI `bench-smoke` job
//! gates on (`all_converge_same_objective`,
//! `tasks_where_acf_beats_uniform`).
//!
//! Run: `cargo bench --bench policy_faceoff [-- --quick]`

use acf_cd::acf::AcfParams;
use acf_cd::bench_util::{write_bench_summary, BenchConfig, Table};
use acf_cd::data::{registry, Scale};
use acf_cd::select::SelectorKind;
use acf_cd::solvers::{lasso, logreg, svm, SolveResult};
use acf_cd::sparse::Dataset;
use acf_cd::util::json::Json;
use acf_cd::util::rng::Rng;

/// Relative tolerance defining the target objective above the best
/// final objective observed across selectors.
const REL_TARGET_TOL: f64 = 1e-3;

/// Tolerance for the "all selectors converge to the same objective"
/// check (relative spread of final objectives).
const SAME_OBJECTIVE_TOL: f64 = 5e-3;

/// Noise margin for the "ACF beats uniform" count: epoch counts are
/// deterministic given the seed, so the margin only absorbs
/// trace-granularity effects (one point per epoch).
const BEAT_MARGIN: f64 = 1.10;

/// One benchmark task: a problem family at one hyper-parameter point.
struct TaskSpec {
    key: &'static str,
    dataset: &'static str,
    param: f64,
}

/// Per-selector outcome with the to-target scan applied.
struct RunReport {
    kind: SelectorKind,
    result: SolveResult,
    /// (epochs, seconds) of the first trace point at/below the target;
    /// `None` when the target was never reached
    to_target: Option<(f64, f64)>,
}

fn run_one(
    task: &TaskSpec,
    ds: &Dataset,
    kind: SelectorKind,
    cfg: &BenchConfig,
    eps: f64,
) -> SolveResult {
    let n = match task.key {
        "lasso" => ds.n_features(),
        _ => ds.n_instances(),
    };
    let mut sel = kind.build(n, AcfParams::default(), Rng::new(cfg.seed ^ 0x5E1E_C704));
    let mut sc = cfg.solver_config(eps);
    sc.trace_every = n as u64; // ~one objective sample per epoch
    match task.key {
        "svm" => svm::solve(ds, task.param, sel.as_mut(), sc).1,
        "lasso" => lasso::solve(ds, task.param, sel.as_mut(), sc).1,
        "logreg" => logreg::solve(ds, task.param, sel.as_mut(), sc).1,
        other => unreachable!("unknown task {other}"),
    }
}

/// Scan a run's trace for the first epoch reaching `target`.
fn scan_to_target(result: &SolveResult, n: usize, target: f64) -> Option<(f64, f64)> {
    for p in &result.trace.points {
        if p.objective <= target {
            return Some((p.iteration as f64 / n as f64, p.seconds));
        }
    }
    // the final state may beat the target after the last sampled point
    if result.objective <= target {
        return Some((result.iterations as f64 / n as f64, result.seconds));
    }
    None
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.2}"),
        None => "—".to_string(),
    }
}

fn json_opt(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let (scale, eps) = if cfg.quick { (Scale(0.12), 1e-3) } else { (Scale(0.6), 1e-4) };
    // Hyper-parameters in each family's adaptive regime (paper §3:
    // speedups grow with C; small λ keeps the LASSO solution dense
    // enough that selection order matters).
    let tasks = [
        TaskSpec { key: "svm", dataset: "rcv1-like", param: 10.0 },
        TaskSpec { key: "lasso", dataset: "rcv1-like", param: 0.001 },
        TaskSpec { key: "logreg", dataset: "rcv1-like", param: 10.0 },
    ];

    let mut summary = Json::obj();
    summary
        .set("bench", Json::Str("policy_faceoff".into()))
        .set("quick", Json::Bool(cfg.quick))
        .set("eps", Json::Num(eps))
        .set("rel_target_tol", Json::Num(REL_TARGET_TOL))
        .set("beat_margin", Json::Num(BEAT_MARGIN));

    let mut beats = 0usize;
    let mut all_same = true;

    for task in &tasks {
        let ds = match task.key {
            "lasso" => registry::regression(task.dataset, scale, cfg.seed).map(|(ds, _)| ds),
            _ => registry::binary(task.dataset, scale, cfg.seed),
        }
        .expect("registry dataset");
        let n = match task.key {
            "lasso" => ds.n_features(),
            _ => ds.n_instances(),
        };
        eprintln!("[{}] {} — {} coordinates, param {}", task.key, ds.name, n, task.param);

        let runs: Vec<RunReport> = SelectorKind::all()
            .into_iter()
            .map(|kind| {
                let result = run_one(task, &ds, kind, &cfg, eps);
                RunReport { kind, result, to_target: None }
            })
            .collect();

        // Objective spread across all five (the same-objective check)...
        let f_best = runs.iter().map(|r| r.result.objective).fold(f64::INFINITY, f64::min);
        let f_worst = runs.iter().map(|r| r.result.objective).fold(f64::NEG_INFINITY, f64::max);
        let spread = (f_worst - f_best) / f_best.abs().max(1e-9);
        // ...but the to-target race is gated on ACF vs uniform, so the
        // target derives from the better of *those two* finals: a third
        // selector finding a slightly lower optimum must not push the
        // target below what both contenders reached (which would turn a
        // tie into a spurious double-DNF and fail the CI gate for a
        // reason unrelated to the ACF-beats-uniform claim).
        let pair_best = runs
            .iter()
            .filter(|r| matches!(r.kind, SelectorKind::Acf | SelectorKind::Uniform))
            .map(|r| r.result.objective)
            .fold(f64::INFINITY, f64::min);
        let target = pair_best + REL_TARGET_TOL * pair_best.abs().max(1e-9);
        let runs: Vec<RunReport> = runs
            .into_iter()
            .map(|mut r| {
                r.to_target = scan_to_target(&r.result, n, target);
                r
            })
            .collect();

        let mut t = Table::new(
            &format!("policy face-off — {} on {} (ε = {eps})", task.key, ds.name),
            &[
                "selector",
                "converged",
                "epochs→target",
                "secs→target",
                "total epochs",
                "final objective",
            ],
        );
        let mut task_json = Json::obj();
        task_json
            .set("n_coords", Json::Num(n as f64))
            .set("parameter", Json::Num(task.param))
            .set("target_objective", Json::Num(target))
            .set("objective_spread_rel", Json::Num(spread));
        for r in &runs {
            let epochs_total = r.result.iterations as f64 / n as f64;
            t.row(vec![
                r.kind.name().to_string(),
                format!("{}", r.result.status.converged()),
                fmt_opt(r.to_target.map(|x| x.0)),
                fmt_opt(r.to_target.map(|x| x.1)),
                format!("{epochs_total:.2}"),
                format!("{:.6e}", r.result.objective),
            ]);
            let mut o = Json::obj();
            o.set("converged", Json::Bool(r.result.status.converged()))
                .set("final_objective", Json::Num(r.result.objective))
                .set("iterations", Json::Num(r.result.iterations as f64))
                .set("epochs_total", Json::Num(epochs_total))
                .set("seconds_total", Json::Num(r.result.seconds))
                .set("epochs_to_target", json_opt(r.to_target.map(|x| x.0)))
                .set("seconds_to_target", json_opt(r.to_target.map(|x| x.1)));
            task_json.set(r.kind.name(), o);
        }
        // "same objective" is the spread criterion (a selector that hit
        // an iteration cap epsilon-close to the others still counts;
        // per-selector `converged` flags are reported above)
        all_same = all_same && spread < SAME_OBJECTIVE_TOL;
        t.print();

        let get = |kind: SelectorKind| runs.iter().find(|r| r.kind == kind).unwrap();
        let acf_e = get(SelectorKind::Acf).to_target.map(|x| x.0);
        let uni_e = get(SelectorKind::Uniform).to_target.map(|x| x.0);
        let beat = match (acf_e, uni_e) {
            (Some(a), Some(u)) => a <= u * BEAT_MARGIN,
            (Some(_), None) => true, // uniform never reached the target
            // vacuous tie — defensive: the pair-derived target above
            // guarantees at least one of the two reaches it
            (None, None) => true,
            (None, Some(_)) => false,
        };
        if beat {
            beats += 1;
        }
        let speedup = match (acf_e, uni_e) {
            (Some(a), Some(u)) if a > 0.0 => Some(u / a),
            _ => None,
        };
        task_json
            .set("acf_beats_uniform", Json::Bool(beat))
            .set("acf_vs_uniform_epoch_speedup", json_opt(speedup));
        summary.set(task.key, task_json);
        eprintln!(
            "[{}] ACF epochs→target {} vs uniform {} — {}",
            task.key,
            fmt_opt(acf_e),
            fmt_opt(uni_e),
            if beat { "ACF beats uniform" } else { "no win" }
        );
    }

    summary
        .set("tasks_where_acf_beats_uniform", Json::Num(beats as f64))
        .set("all_converge_same_objective", Json::Bool(all_same));
    write_bench_summary("policy_faceoff", &summary);
    cfg.finish(summary); // honors --out
    println!(
        "\nface-off: ACF beats uniform on {beats}/3 tasks; all selectors \
         reach the same objective: {all_same}"
    );
}
