//! Shard-scaling benchmark — wall-clock speedup and solution-quality
//! parity of the sharded parallel CD engine (`acf_cd::shard`) vs. the
//! serial ACF path, across S ∈ {1, 2, 4, 8} on large synthetic datasets
//! (LASSO: features sharded; SVM dual: instances sharded).
//!
//! Reported per S:
//!   * time-to-convergence wall clock + speedup over the serial solver,
//!   * relative final-objective difference vs. serial (parity target:
//!     ≤ 1e-4),
//!   * epochs and total CD steps,
//!   * determinism audit: S = 4 is run twice and must agree exactly.
//!
//! Run: `cargo bench --bench scaling_shards [-- --quick]`
//! Writes `BENCH_scaling_shards.json` next to the report.

use acf_cd::bench_util::{summary_entry, write_bench_summary, BenchConfig, Table};
use acf_cd::data::synth;
use acf_cd::sched::{AcfSchedulerPolicy, Scheduler};
use acf_cd::shard::{lasso as shard_lasso, svm as shard_svm, ShardSpec};
use acf_cd::solvers::{lasso, svm, SolveResult, SolverConfig};
use acf_cd::util::json::Json;
use acf_cd::util::rng::Rng;
use acf_cd::util::timer::fmt_secs;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn shard_spec(shards: usize, eps: f64, seed: u64) -> ShardSpec {
    ShardSpec::new(shards).with_seed(seed).with_config(SolverConfig::with_eps(eps))
}

struct Row {
    shards: usize,
    seconds: f64,
    result: SolveResult,
    rel_obj: f64,
}

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(1e-12)
}

#[allow(clippy::too_many_arguments)]
fn report_family(
    family: &str,
    serial_secs: f64,
    serial: &SolveResult,
    rows: &[Row],
    deterministic: bool,
    out: &mut Json,
) {
    let mut table = Table::new(
        &format!("{family}: sharded engine vs serial ACF (time to convergence)"),
        &["S", "seconds", "speedup", "rel Δobj vs serial", "epochs", "steps"],
    );
    table.row(vec![
        "serial".into(),
        fmt_secs(serial_secs),
        "1.0".into(),
        "—".into(),
        serial.epochs.to_string(),
        serial.iterations.to_string(),
    ]);
    for r in rows {
        table.row(vec![
            r.shards.to_string(),
            fmt_secs(r.seconds),
            format!("{:.2}", serial_secs / r.seconds.max(1e-12)),
            format!("{:.2e}", r.rel_obj),
            r.result.epochs.to_string(),
            r.result.iterations.to_string(),
        ]);
    }
    table.print();
    println!("determinism (S = 4, two runs identical): {deterministic}");

    let mut fam = Json::obj();
    let mut serial_entry = summary_entry(serial_secs, serial.epochs, serial.objective);
    serial_entry.set("steps", Json::Num(serial.iterations as f64));
    fam.set("serial", serial_entry);
    for r in rows {
        let mut e = summary_entry(r.seconds, r.result.epochs, r.result.objective);
        e.set("speedup", Json::Num(serial_secs / r.seconds.max(1e-12)))
            .set("rel_obj_vs_serial", Json::Num(r.rel_obj))
            .set("steps", Json::Num(r.result.iterations as f64))
            .set("converged", Json::Bool(r.result.status.converged()));
        fam.set(&format!("shards_{}", r.shards), e);
    }
    fam.set("deterministic", Json::Bool(deterministic));
    out.set(family, fam);
}

fn main() {
    let cfg = BenchConfig::from_env();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("shard scaling bench — {cores} hardware threads available");
    if cores < 4 {
        println!("note: fewer than 4 cores; speedups at S ≥ 4 will be machine-bound");
    }
    let mut out = Json::obj();
    out.set("cores", Json::Num(cores as f64));

    // ---------------- LASSO (features sharded) ------------------------
    {
        let (n, d, nnz) = if cfg.quick { (1_500, 4_000, 30) } else { (8_000, 30_000, 80) };
        let (ds, _) = synth::regression_sparse("scale-reg", n, d, nnz, 60, 0.05, &mut Rng::new(cfg.seed));
        let lambda = 0.002;
        let eps = 1e-5;
        println!(
            "\nLASSO dataset: {} instances × {} features, {} nnz",
            ds.n_instances(),
            ds.n_features(),
            ds.nnz()
        );

        // serial baseline: flat ACF (prepared problem, transpose excluded
        // from all timings on both paths)
        let prob = lasso::LassoProblem::new(&ds);
        let t = acf_cd::util::timer::Timer::start();
        let mut sched = AcfSchedulerPolicy::new(ds.n_features(), Default::default(), Rng::new(cfg.seed));
        let (_, serial) = lasso::solve_prepared(&prob, lambda, &mut sched as &mut dyn Scheduler, SolverConfig::with_eps(eps));
        let serial_secs = t.secs();
        println!("serial: {}", serial.summary());

        let sharded_prob = shard_lasso::ShardedLasso::new(&ds, lambda);
        let rows: Vec<Row> = SHARD_COUNTS
            .iter()
            .map(|&s| {
                let t = acf_cd::util::timer::Timer::start();
                let o = shard_lasso::run_prepared(&sharded_prob, shard_spec(s, eps, cfg.seed));
                let seconds = t.secs();
                println!("S = {s}: {}", o.result.summary());
                Row { shards: s, seconds, rel_obj: rel_diff(serial.objective, o.result.objective), result: o.result }
            })
            .collect();
        let a = shard_lasso::run_prepared(&sharded_prob, shard_spec(4, eps, cfg.seed));
        let b = shard_lasso::run_prepared(&sharded_prob, shard_spec(4, eps, cfg.seed));
        let deterministic = a.result.iterations == b.result.iterations
            && a.result.objective == b.result.objective
            && a.values == b.values;
        report_family("lasso", serial_secs, &serial, &rows, deterministic, &mut out);
    }

    // ---------------- SVM dual (instances sharded) ---------------------
    {
        let (n, d, nnz) = if cfg.quick { (2_000, 6_000, 30) } else { (12_000, 40_000, 80) };
        let ds = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "scale-svm",
                n,
                d,
                nnz_per_row: nnz,
                zipf_s: 1.0,
                concept_k: 200,
                noise: 0.03,
            },
            &mut Rng::new(cfg.seed ^ 1),
        );
        let c = 1.0;
        let eps = 1e-3;
        println!(
            "\nSVM dataset: {} instances × {} features, {} nnz",
            ds.n_instances(),
            ds.n_features(),
            ds.nnz()
        );

        let t = acf_cd::util::timer::Timer::start();
        let mut sched = AcfSchedulerPolicy::new(ds.n_instances(), Default::default(), Rng::new(cfg.seed));
        let (_, serial) = svm::solve(&ds, c, &mut sched as &mut dyn Scheduler, SolverConfig::with_eps(eps));
        let serial_secs = t.secs();
        println!("serial: {}", serial.summary());

        // ShardedSvm::new computes q_diag (row_norms_sq), which the serial
        // svm::solve also does inside its timed region — construct inside
        // the timer so both paths pay the same prep cost.
        let rows: Vec<Row> = SHARD_COUNTS
            .iter()
            .map(|&s| {
                let t = acf_cd::util::timer::Timer::start();
                let sharded_prob = shard_svm::ShardedSvm::new(&ds, c);
                let o = shard_svm::run_prepared(&sharded_prob, shard_spec(s, eps, cfg.seed));
                let seconds = t.secs();
                println!("S = {s}: {}", o.result.summary());
                Row { shards: s, seconds, rel_obj: rel_diff(serial.objective, o.result.objective), result: o.result }
            })
            .collect();
        let sharded_prob = shard_svm::ShardedSvm::new(&ds, c);
        let a = shard_svm::run_prepared(&sharded_prob, shard_spec(4, eps, cfg.seed));
        let b = shard_svm::run_prepared(&sharded_prob, shard_spec(4, eps, cfg.seed));
        let deterministic = a.result.iterations == b.result.iterations
            && a.result.objective == b.result.objective
            && a.values == b.values;
        report_family("svm", serial_secs, &serial, &rows, deterministic, &mut out);
    }

    write_bench_summary("scaling_shards", &out);
    cfg.finish(out);
}
