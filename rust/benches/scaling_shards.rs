//! Shard-scaling benchmark — wall-clock speedup and solution-quality
//! parity of the sharded parallel CD engine (`acf_cd::shard`) vs. the
//! serial ACF path, across S ∈ {1, 2, 4, 8} on large synthetic datasets
//! for all four paper families (LASSO: features sharded; SVM dual /
//! dual logreg / WW multi-class SVM: instances sharded — mcsvm with its
//! K per-class weight buffers merged as one versioned unit), for
//! **both** merge protocols: the epoch-synchronized barrier (`shards_S`
//! entries) and the asynchronous bounded-staleness merge
//! (`async_shards_S`).
//!
//! Reported per (S, merge mode):
//!   * time-to-convergence wall clock + speedup over the serial solver,
//!   * relative final-objective difference vs. serial (parity target:
//!     ≤ 1e-4 sync, ≤ 1e-3 async),
//!   * epochs (sync) / published versions (async) and total CD steps,
//!   * determinism audit: sync S = 4 is run twice and must agree
//!     exactly; async S = 4 is instead audited for a monotone published
//!     objective (async runs are not bit-reproducible by design).
//!
//! Every family trains on the **mapped** data backend (the synthetic
//! matrix is round-tripped through an `.acfbin` file and served from a
//! read-only mapping, `"data_backend": "mmap"` in the JSON), so the
//! CI speedup gates also cover the out-of-core data plane; an
//! `ingest_throughput` entry times the streaming libsvm → `.acfbin`
//! converter and checks its output against the in-memory parser.
//!
//! Run: `cargo bench --bench scaling_shards [-- --quick] [-- --max-iters N]`
//! (env mirrors for CI: `ACF_BENCH_QUICK=1`, `ACF_BENCH_MAX_ITERS=N`).
//! Writes `BENCH_scaling_shards.json` next to the report; the CI
//! `bench-smoke` job gates on the S = 4 speedups recorded there.

use acf_cd::bench_util::{summary_entry, write_bench_summary, BenchConfig, Table};
use acf_cd::data::synth;
use acf_cd::obs::{self, Obs, StageBreakdown, TraceLevel};
use acf_cd::sched::{AcfSchedulerPolicy, Scheduler};
use acf_cd::shard::{
    lasso as shard_lasso, logreg as shard_logreg, mcsvm as shard_mcsvm, svm as shard_svm,
    ShardSpec, ShardedOutcome, DEFAULT_STALENESS_BOUND,
};
use acf_cd::solvers::{lasso, logreg, mcsvm, svm, SolveResult};
use acf_cd::sparse::{ingest, storage, to_libsvm_string};
use acf_cd::util::json::Json;
use acf_cd::util::rng::Rng;
use acf_cd::util::timer::{fmt_secs, Timer};

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn shard_spec(shards: usize, cfg: &BenchConfig, eps: f64, asynchronous: bool) -> ShardSpec {
    let spec = ShardSpec::new(shards).with_seed(cfg.seed).with_config(cfg.solver_config(eps));
    if asynchronous {
        spec.with_async(DEFAULT_STALENESS_BOUND)
    } else {
        spec
    }
}

struct Row {
    label: String,
    json_key: String,
    seconds: f64,
    result: SolveResult,
    rel_obj: f64,
    /// async rows: staleness-bound discards (τ-tuning diagnostic)
    stale_drops: Option<u64>,
    /// async rows: merge-layer accounting (objective evals, batching)
    merge_stats: Option<acf_cd::shard::MergeStats>,
}

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(1e-12)
}

fn make_row(
    label: &str,
    key: &str,
    seconds: f64,
    serial_obj: f64,
    result: SolveResult,
    stale_drops: Option<u64>,
    merge_stats: Option<acf_cd::shard::MergeStats>,
) -> Row {
    Row {
        label: label.to_string(),
        json_key: key.to_string(),
        seconds,
        rel_obj: rel_diff(serial_obj, result.objective),
        result,
        stale_drops,
        merge_stats,
    }
}

/// Run one problem family across both merge modes and all shard counts,
/// plus the sync determinism and async monotonicity audits. `run` maps a
/// spec to a sharded outcome; one-time prep (the LASSO transpose, the
/// SVM norm cache) is warmed by the caller OUTSIDE every timed region so
/// serial and sharded timings measure identical work. The single code
/// path keeps the JSON schema identical for every family, which the CI
/// bench-smoke gate depends on.
fn run_family(
    family: &str,
    serial_secs: f64,
    serial: &SolveResult,
    cfg: &BenchConfig,
    eps: f64,
    run: impl Fn(ShardSpec) -> acf_cd::Result<ShardedOutcome>,
    out: &mut Json,
) {
    let mut rows: Vec<Row> = Vec::new();
    for asynchronous in [false, true] {
        for &s in &SHARD_COUNTS {
            let t = Timer::start();
            let o = run(shard_spec(s, cfg, eps, asynchronous)).expect("sharded run failed");
            let seconds = t.secs();
            let (label, key) = if asynchronous {
                (format!("{s} async"), format!("async_shards_{s}"))
            } else {
                (s.to_string(), format!("shards_{s}"))
            };
            println!("S = {label}: {}", o.result.summary());
            let drops = if asynchronous { Some(o.stale_drops) } else { None };
            let stats = if asynchronous { Some(o.merge_stats) } else { None };
            rows.push(make_row(&label, &key, seconds, serial.objective, o.result, drops, stats));
        }
    }
    let a = run(shard_spec(4, cfg, eps, false)).expect("determinism run failed");
    let b = run(shard_spec(4, cfg, eps, false)).expect("determinism run failed");
    let deterministic = a.result.iterations == b.result.iterations
        && a.result.objective == b.result.objective
        && a.values == b.values;
    let mut mono_spec = shard_spec(4, cfg, eps, true);
    mono_spec.config.trace_every = 1;
    let mono = run(mono_spec).expect("monotone audit run failed");
    let async_monotone = mono.result.trace.check_monotone(1e-9).is_ok();

    // Observability audit at the CI-gated S = 4 point: rerun with a
    // spans-level collector attached (4 shard rings + the driver ring),
    // fold the event stream into the stage-time split, and compare the
    // traced wall clock against the untraced shards_4 row — the
    // acceptance target for span recording is ≤ 5% overhead. The gate
    // rows above stay untraced so the speedup numbers are unaffected.
    let collector = std::sync::Arc::new(Obs::new(TraceLevel::Spans, 4 + 1, obs::DEFAULT_RING_CAP));
    let t = Timer::start();
    let traced =
        run(shard_spec(4, cfg, eps, false).with_obs(collector.clone())).expect("traced run failed");
    let traced_secs = t.secs();
    let untraced_secs =
        rows.iter().find(|r| r.json_key == "shards_4").map(|r| r.seconds).unwrap_or(traced_secs);
    let data = collector.drain();
    let breakdown = StageBreakdown::from_events(&data.events);
    let overhead = traced_secs / untraced_secs.max(1e-12);
    println!(
        "spans-level trace (sync S = 4): {} vs {} untraced ({:+.1}% overhead), {} events recorded, {} dropped",
        fmt_secs(traced_secs),
        fmt_secs(untraced_secs),
        (overhead - 1.0) * 100.0,
        data.total,
        data.dropped
    );
    let mut trace_audit = Json::obj();
    trace_audit
        .set("seconds", Json::Num(traced_secs))
        .set("spans_overhead_vs_untraced", Json::Num(overhead))
        .set("events_recorded", Json::Num(data.total as f64))
        .set("dropped_events", Json::Num(data.dropped as f64))
        .set("objective_matches_untraced", Json::Bool(traced.result.objective == a.result.objective))
        .set("stage_breakdown", breakdown.to_json());
    report_family(family, serial_secs, serial, &rows, deterministic, async_monotone, trace_audit, out);
}

fn report_family(
    family: &str,
    serial_secs: f64,
    serial: &SolveResult,
    rows: &[Row],
    deterministic: bool,
    async_monotone: bool,
    trace_audit: Json,
    out: &mut Json,
) {
    let mut table = Table::new(
        &format!("{family}: sharded engine vs serial ACF (time to convergence)"),
        &["S", "seconds", "speedup", "rel Δobj vs serial", "epochs", "steps"],
    );
    table.row(vec![
        "serial".into(),
        fmt_secs(serial_secs),
        "1.0".into(),
        "—".into(),
        serial.epochs.to_string(),
        serial.iterations.to_string(),
    ]);
    for r in rows {
        table.row(vec![
            r.label.clone(),
            fmt_secs(r.seconds),
            format!("{:.2}", serial_secs / r.seconds.max(1e-12)),
            format!("{:.2e}", r.rel_obj),
            r.result.epochs.to_string(),
            r.result.iterations.to_string(),
        ]);
    }
    table.print();
    println!("determinism (sync S = 4, two runs identical): {deterministic}");
    println!("async published objective monotone (S = 4): {async_monotone}");

    let mut fam = Json::obj();
    let mut serial_entry = summary_entry(serial_secs, serial.epochs, serial.objective);
    serial_entry.set("steps", Json::Num(serial.iterations as f64));
    fam.set("serial", serial_entry);
    for r in rows {
        let mut e = summary_entry(r.seconds, r.result.epochs, r.result.objective);
        e.set("speedup", Json::Num(serial_secs / r.seconds.max(1e-12)))
            .set("rel_obj_vs_serial", Json::Num(r.rel_obj))
            .set("steps", Json::Num(r.result.iterations as f64))
            .set("converged", Json::Bool(r.result.status.converged()));
        if let Some(drops) = r.stale_drops {
            e.set("stale_drops", Json::Num(drops as f64));
        }
        if let Some(ms) = r.merge_stats {
            // batching headline: evals per accepted submission < 1 means
            // the folded candidates amortized objective evaluations
            e.set("objective_evals", Json::Num(ms.objective_evals as f64))
                .set("accepted_submissions", Json::Num(ms.accepted_submissions as f64))
                .set("rejected_submissions", Json::Num(ms.rejected_submissions as f64))
                .set("batched_merges", Json::Num(ms.batched_merges as f64))
                .set("tau_final", Json::Num(ms.staleness_bound_final as f64))
                .set(
                    "objective_evals_per_accepted",
                    Json::Num(ms.objective_evals as f64 / ms.accepted_submissions.max(1) as f64),
                );
        }
        fam.set(&r.json_key, e);
    }
    // the ISSUE's headline sync↔async delta at the ROADMAP's S = 4 point
    let sync4 = rows.iter().find(|r| r.json_key == "shards_4");
    let async4 = rows.iter().find(|r| r.json_key == "async_shards_4");
    if let (Some(s4), Some(a4)) = (sync4, async4) {
        fam.set("s4_async_over_sync_speedup", Json::Num(s4.seconds / a4.seconds.max(1e-12)));
    }
    fam.set("deterministic", Json::Bool(deterministic));
    fam.set("async_monotone", Json::Bool(async_monotone));
    // spans-level rerun at S = 4: stage-time split + overhead ratio
    fam.set("s4_trace", trace_audit);
    out.set(family, fam);
}

fn main() {
    let cfg = BenchConfig::from_env();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("shard scaling bench — {cores} hardware threads available");
    if cores < 4 {
        println!("note: fewer than 4 cores; speedups at S ≥ 4 will be machine-bound");
    }
    let mut out = Json::obj();
    out.set("cores", Json::Num(cores as f64));
    out.set("quick", Json::Bool(cfg.quick));
    if let Some(m) = cfg.max_iterations {
        out.set("max_iterations_cap", Json::Num(m as f64));
    }
    out.set("staleness_bound", Json::Num(DEFAULT_STALENESS_BOUND as f64));
    // every family below trains on the mapped (.acfbin) backend
    out.set("data_backend", Json::Str("mmap".into()));

    // ---------------- LASSO (features sharded) ------------------------
    {
        let (n, d, nnz) = if cfg.quick { (1_500, 4_000, 30) } else { (8_000, 30_000, 80) };
        let (ds, _) =
            synth::regression_sparse("scale-reg", n, d, nnz, 60, 0.05, &mut Rng::new(cfg.seed));
        // mapped data backend: identical rows served from the page cache
        let ds = storage::remap_dataset(&ds).expect("remap to the mapped backend");
        let lambda = 0.002;
        let eps = 1e-5;
        println!(
            "\nLASSO dataset: {} instances × {} features, {} nnz",
            ds.n_instances(),
            ds.n_features(),
            ds.nnz()
        );

        // serial baseline: flat ACF (prepared problem, transpose excluded
        // from all timings on both paths)
        let prob = lasso::LassoProblem::new(&ds);
        let t = acf_cd::util::timer::Timer::start();
        let mut sched =
            AcfSchedulerPolicy::new(ds.n_features(), Default::default(), Rng::new(cfg.seed));
        let (_, serial) = lasso::solve_prepared(
            &prob,
            lambda,
            &mut sched as &mut dyn Scheduler,
            cfg.solver_config(eps),
        );
        let serial_secs = t.secs();
        println!("serial: {}", serial.summary());

        // prepared problem reused across runs (transpose excluded from
        // timings on both the serial and sharded paths)
        let sharded_prob = shard_lasso::ShardedLasso::new(&ds, lambda);
        run_family(
            "lasso",
            serial_secs,
            &serial,
            &cfg,
            eps,
            |spec| shard_lasso::run_prepared(&sharded_prob, spec),
            &mut out,
        );
    }

    // ---------------- SVM dual (instances sharded) ---------------------
    {
        let (n, d, nnz) = if cfg.quick { (2_000, 6_000, 30) } else { (12_000, 40_000, 80) };
        let ds = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "scale-svm",
                n,
                d,
                nnz_per_row: nnz,
                zipf_s: 1.0,
                concept_k: 200,
                noise: 0.03,
            },
            &mut Rng::new(cfg.seed ^ 1),
        );
        let ds = storage::remap_dataset(&ds).expect("remap to the mapped backend");
        let c = 1.0;
        let eps = 1e-3;
        println!(
            "\nSVM dataset: {} instances × {} features, {} nnz",
            ds.n_instances(),
            ds.n_features(),
            ds.nnz()
        );

        // warm the matrix-level norm cache OUTSIDE every timed region so
        // the serial baseline and the sharded runs (which all borrow it)
        // measure identical work — one-time prep must not bias the
        // CI-gated speedup
        let _ = ds.x.row_norms_sq();
        let t = acf_cd::util::timer::Timer::start();
        let mut sched =
            AcfSchedulerPolicy::new(ds.n_instances(), Default::default(), Rng::new(cfg.seed));
        let (_, serial) =
            svm::solve(&ds, c, &mut sched as &mut dyn Scheduler, cfg.solver_config(eps));
        let serial_secs = t.secs();
        println!("serial: {}", serial.summary());
        run_family(
            "svm",
            serial_secs,
            &serial,
            &cfg,
            eps,
            |spec| {
                let sharded_prob = shard_svm::ShardedSvm::new(&ds, c);
                shard_svm::run_prepared(&sharded_prob, spec)
            },
            &mut out,
        );
    }

    // ---------------- dual logreg (instances sharded) -------------------
    {
        let (n, d, nnz) = if cfg.quick { (2_000, 6_000, 30) } else { (12_000, 40_000, 80) };
        let ds = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "scale-logreg",
                n,
                d,
                nnz_per_row: nnz,
                zipf_s: 1.0,
                concept_k: 200,
                noise: 0.03,
            },
            &mut Rng::new(cfg.seed ^ 2),
        );
        let ds = storage::remap_dataset(&ds).expect("remap to the mapped backend");
        let c = 1.0;
        let eps = 1e-3;
        println!(
            "\nlogreg dataset: {} instances × {} features, {} nnz",
            ds.n_instances(),
            ds.n_features(),
            ds.nnz()
        );

        // warm the norm cache outside every timed region (both paths
        // borrow it), as for the SVM family
        let _ = ds.x.row_norms_sq();
        let t = acf_cd::util::timer::Timer::start();
        let mut sched =
            AcfSchedulerPolicy::new(ds.n_instances(), Default::default(), Rng::new(cfg.seed));
        let (_, serial) =
            logreg::solve(&ds, c, &mut sched as &mut dyn Scheduler, cfg.solver_config(eps));
        let serial_secs = t.secs();
        println!("serial: {}", serial.summary());
        run_family(
            "logreg",
            serial_secs,
            &serial,
            &cfg,
            eps,
            |spec| {
                let sharded_prob = shard_logreg::ShardedLogReg::new(&ds, c);
                shard_logreg::run_prepared(&sharded_prob, spec)
            },
            &mut out,
        );
    }

    // ---------------- WW multi-class SVM (instances sharded, K-wide
    // per-class shared state merged as one versioned unit). NB: the
    // serial "steps" count inner SMO steps (paper convention), sharded
    // rows count subspace solves — compare the ops/seconds columns, not
    // steps (see shard::mcsvm module docs). --------------------------
    {
        let (n, d, k, nnz) =
            if cfg.quick { (1_500, 4_000, 6, 20) } else { (8_000, 20_000, 10, 50) };
        let ds = synth::multiclass_text("scale-mcsvm", n, d, k, nnz, 0.02, &mut Rng::new(cfg.seed ^ 3));
        let ds = storage::remap_dataset(&ds).expect("remap to the mapped backend");
        let c = 1.0;
        let eps = 1e-2;
        println!(
            "\nmcsvm dataset: {} instances × {} features, {} classes, {} nnz",
            ds.n_instances(),
            ds.n_features(),
            k,
            ds.nnz()
        );

        let _ = ds.x.row_norms_sq();
        let t = acf_cd::util::timer::Timer::start();
        let mut sched =
            AcfSchedulerPolicy::new(ds.n_instances(), Default::default(), Rng::new(cfg.seed));
        let (_, serial) =
            mcsvm::solve(&ds, c, &mut sched as &mut dyn Scheduler, cfg.solver_config(eps))
                .expect("synthetic labels are 0..K-1");
        let serial_secs = t.secs();
        println!("serial: {}", serial.summary());
        // label validation + norm cache amortized across every run
        let sharded_prob =
            shard_mcsvm::ShardedMcSvm::new(&ds, c, eps).expect("synthetic labels are 0..K-1");
        run_family(
            "mcsvm",
            serial_secs,
            &serial,
            &cfg,
            eps,
            |spec| shard_mcsvm::run_prepared(&sharded_prob, spec),
            &mut out,
        );
    }

    // ---------------- ingest throughput (libsvm → .acfbin) --------------
    {
        let (n, d, nnz) = if cfg.quick { (1_500, 5_000, 30) } else { (8_000, 25_000, 60) };
        let ds = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "scale-ingest",
                n,
                d,
                nnz_per_row: nnz,
                zipf_s: 1.0,
                concept_k: 150,
                noise: 0.03,
            },
            &mut Rng::new(cfg.seed ^ 4),
        );
        let text = to_libsvm_string(&ds);
        let dir = std::env::temp_dir();
        let src = dir.join(format!("acf_bench_ingest_{}.libsvm", std::process::id()));
        let dst = dir.join(format!("acf_bench_ingest_{}.acfbin", std::process::id()));
        std::fs::write(&src, &text).expect("write libsvm text");
        let rep = ingest::ingest_libsvm(&src, &dst, ds.n_features(), 0).expect("streaming ingest");
        // the streamed chunked path must agree with the in-memory parser
        let mapped = storage::open_dataset(&dst).expect("open ingested file");
        assert_eq!(mapped.x, ds.x, "ingested matrix differs from the in-memory parse");
        assert_eq!(mapped.y, ds.y, "ingested labels differ from the in-memory parse");
        let _ = std::fs::remove_file(&src);
        let _ = std::fs::remove_file(&dst);
        println!(
            "\ningest throughput: {} rows ({} nnz), {:.1} MB in {} → {:.1} MB/s",
            rep.rows,
            rep.nnz,
            rep.input_bytes as f64 / 1e6,
            fmt_secs(rep.seconds),
            rep.mb_per_s
        );
        let mut ing = Json::obj();
        ing.set("rows", Json::Num(rep.rows as f64))
            .set("cols", Json::Num(rep.cols as f64))
            .set("nnz", Json::Num(rep.nnz as f64))
            .set("input_mb", Json::Num(rep.input_bytes as f64 / 1e6))
            .set("output_bytes", Json::Num(rep.output_bytes as f64))
            .set("seconds", Json::Num(rep.seconds))
            .set("mb_per_s", Json::Num(rep.mb_per_s))
            .set("round_trip_bit_identical", Json::Bool(true));
        out.set("ingest_throughput", ing);
    }

    write_bench_summary("scaling_shards", &out);
    cfg.finish(out);
}
