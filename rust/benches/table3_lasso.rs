//! Table 3 — LASSO: uniform (cyclic, Friedman et al.) vs ACF-CD.
//!
//! Paper protocol: three datasets (news20, rcv1, E2006-tfidf analogs),
//! λ varied so the solution sparsity spans <10 … >10⁴ non-zeros; report
//! iterations, operations, and the speed-up factors. Shape expectation:
//! ACF never much slower, up to 1–2 orders of magnitude faster at small
//! λ (hard problems), ~parity at large λ (trivially sparse problems).
//!
//! Run: `cargo bench --bench table3_lasso [-- --quick] [-- --out t3.json]`

use acf_cd::bench_util::{BenchConfig, Table};
use acf_cd::coordinator::{run_sweep, JobSpec, Problem, SweepSpec};
use acf_cd::data::Scale;
use acf_cd::sched::Policy;
use acf_cd::util::json::Json;
use acf_cd::util::timer::fmt_count;

fn main() {
    let cfg = BenchConfig::from_env();
    let scale = if cfg.quick { Scale(0.15) } else { Scale(1.0) };
    // per-dataset λ grids spanning very sparse → rich models (paper's
    // protocol); values tuned to the analogs' correlation scales —
    // smallest λ = richest model = hardest problem = ACF's regime
    let datasets: Vec<(&str, Vec<f64>)> = vec![
        ("rcv1-like", vec![0.002, 0.0005, 0.0001, 0.00002]),
        ("news20-like", vec![0.002, 0.0005, 0.0001, 0.00002]),
        ("e2006-like", vec![0.001, 0.00025, 0.00005, 0.00001]),
    ];
    let mut results = Json::obj();
    let mut all_tables = Vec::new();
    for (name, grid) in &datasets {
        let mut base = JobSpec::new(Problem::Lasso { lambda: grid[0] }, name, Policy::Acf);
        base.scale = scale;
        base.seed = cfg.seed;
        // tight tolerance — the paper's LASSO runs are long (1e7–1e9
        // iterations); at our reduced scale only a tight ε reaches the
        // multi-hundred-epoch regime where frequency adaptation pays
        base.eps = 2e-5;
        base.max_iterations = if cfg.quick { 20_000_000 } else { 100_000_000 };
        let sweep = SweepSpec {
            base,
            grid: grid.clone(),
            policies: vec![Policy::Cyclic, Policy::Acf],
            selectors: vec![],
            include_shrinking: false,
            workers: cfg.workers,
        };
        let outcomes = run_sweep(&sweep).expect("sweep");
        let mut t = Table::new(
            &format!("Table 3 (analog) — LASSO on {name}"),
            &[
                "lambda", "nnz(w)", "uniform iters", "uniform ops", "acf iters", "acf ops",
                "speedup iter", "speedup ops",
            ],
        );
        for &lambda in grid {
            let cyc = outcomes
                .iter()
                .find(|o| o.spec.problem.parameter() == lambda && o.spec.policy == Policy::Cyclic)
                .unwrap();
            let acf = outcomes
                .iter()
                .find(|o| o.spec.problem.parameter() == lambda && o.spec.policy == Policy::Acf)
                .unwrap();
            let sp_it = cyc.result.iterations as f64 / acf.result.iterations.max(1) as f64;
            let sp_op = cyc.result.ops as f64 / acf.result.ops.max(1) as f64;
            t.row(vec![
                format!("{lambda}"),
                format!("{}", acf.nnz_coeffs.unwrap_or(0)),
                fmt_count(cyc.result.iterations as f64),
                fmt_count(cyc.result.ops as f64),
                fmt_count(acf.result.iterations as f64),
                fmt_count(acf.result.ops as f64),
                format!("{sp_it:.1}"),
                format!("{sp_op:.1}"),
            ]);
        }
        t.print();
        results.set(name, acf_cd::coordinator::outcomes_json(&outcomes));
        all_tables.push(t.to_json());
    }
    results.set("tables", Json::Arr(all_tables));
    cfg.finish(results);
}
