//! Tables 5 & 6 — linear SVM training: liblinear (random permutation +
//! shrinking) vs ACF-CD, at ε = 0.01 (Table 5) and ε = 0.001 (Table 6),
//! C ∈ {0.01, 0.1, 1, 10, 100, 1000}, six dataset analogs.
//!
//! Shape expectations from the paper: ACF wins on the sparse
//! high-dimensional text datasets with the margin growing with C (up to
//! >10× at C ≥ 100); the dense low-dimensional cover-type analog is the
//! known regression (ACF overhead loses); capped runs print "—" like the
//! paper's multi-week DNFs.
//!
//! Run: `cargo bench --bench table5_6_svm [-- --quick]`

use acf_cd::bench_util::{BenchConfig, Table};
use acf_cd::coordinator::{run_sweep, JobSpec, Problem, SweepSpec};
use acf_cd::data::Scale;
use acf_cd::sched::Policy;
use acf_cd::util::json::Json;
use acf_cd::util::timer::fmt_count;

fn main() {
    let cfg = BenchConfig::from_env();
    let (scale, datasets, grid): (Scale, Vec<&str>, Vec<f64>) = if cfg.quick {
        (Scale(0.12), vec!["rcv1-like", "covtype-like"], vec![0.1, 1.0, 10.0])
    } else {
        (
            Scale(1.0),
            vec![
                "covtype-like",
                "kdda-like",
                "kddb-like",
                "news20-like",
                "rcv1-like",
                "url-like",
            ],
            vec![0.01, 0.1, 1.0, 10.0, 100.0, 1000.0],
        )
    };
    let mut results = Json::obj();
    for &eps in &[0.01, 0.001] {
        let table_no = if eps == 0.01 { 5 } else { 6 };
        let mut per_eps = Json::obj();
        for name in &datasets {
            let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, name, Policy::Acf);
            base.scale = scale;
            base.seed = cfg.seed;
            base.eps = eps;
            // DNF cap — mirrors the paper's aborted multi-week runs
            base.max_iterations = if cfg.quick { 5_000_000 } else { 60_000_000 };
            let sweep = SweepSpec {
                base,
                grid: grid.clone(),
                policies: vec![Policy::Acf],
            selectors: vec![],
                include_shrinking: true, // the liblinear baseline
                workers: cfg.workers,
            };
            let outcomes = run_sweep(&sweep).expect("sweep");
            let mut t = Table::new(
                &format!("Table {table_no} (analog) — linear SVM on {name}, ε = {eps}"),
                &[
                    "C", "liblinear sec", "liblinear iters", "acf sec", "acf iters",
                    "speedup time", "speedup iters",
                ],
            );
            for &c in &grid {
                let lib = outcomes
                    .iter()
                    .find(|o| {
                        o.spec.problem.parameter() == c
                            && o.spec.problem.family() == "svm-shrinking"
                    })
                    .unwrap();
                let acf = outcomes
                    .iter()
                    .find(|o| o.spec.problem.parameter() == c && o.spec.policy == Policy::Acf)
                    .unwrap();
                let dnf_l = !lib.result.status.converged();
                let dnf_a = !acf.result.status.converged();
                let cell =
                    |x: f64, dnf: bool| if dnf { "—".into() } else { fmt_count(x) };
                let secf = |o: &acf_cd::coordinator::JobOutcome, dnf: bool| {
                    if dnf {
                        "—".to_string()
                    } else {
                        format!("{:.3}", o.result.seconds)
                    }
                };
                let ratio = |a: f64, b: f64| {
                    if dnf_l || dnf_a || b <= 0.0 {
                        "—".to_string()
                    } else {
                        format!("{:.1}", a / b)
                    }
                };
                t.row(vec![
                    format!("{c}"),
                    secf(lib, dnf_l),
                    cell(lib.result.iterations as f64, dnf_l),
                    secf(acf, dnf_a),
                    cell(acf.result.iterations as f64, dnf_a),
                    ratio(lib.result.seconds, acf.result.seconds),
                    ratio(lib.result.iterations as f64, acf.result.iterations as f64),
                ]);
            }
            t.print();
            per_eps.set(name, acf_cd::coordinator::outcomes_json(&outcomes));
        }
        results.set(&format!("eps_{eps}"), per_eps);
    }
    cfg.finish(results);
}
