//! Table 8 — Weston–Watkins multi-class SVM with subspace descent:
//! uniform coordinate selection vs ACF.
//!
//! Paper protocol: iris / soybean / news20 / rcv1 (multi-class) analogs,
//! C on a 10^k grid of size 5 around the best value, reporting test
//! accuracy, iterations, seconds and speed-ups. Shape expectation: ACF
//! wins nearly everywhere and scales more gracefully with C.
//!
//! Run: `cargo bench --bench table8_mcsvm [-- --quick]`

use acf_cd::bench_util::{BenchConfig, Table};
use acf_cd::coordinator::{JobSpec, Problem};
use acf_cd::data::{self, Scale};
use acf_cd::sched::Policy;
use acf_cd::util::json::Json;
use acf_cd::util::rng::Rng;
use acf_cd::util::timer::fmt_count;

fn main() {
    let cfg = BenchConfig::from_env();
    let (scale, datasets): (Scale, Vec<(&str, Vec<f64>)>) = if cfg.quick {
        (
            Scale(0.1),
            vec![
                ("iris-like", vec![0.1, 1.0, 10.0]),
                ("soybean-like", vec![0.1, 1.0, 10.0]),
            ],
        )
    } else {
        (
            Scale(1.0),
            vec![
                ("iris-like", vec![0.01, 0.1, 1.0, 10.0, 100.0]),
                ("soybean-like", vec![0.01, 0.1, 1.0, 10.0, 100.0]),
                ("news20mc-like", vec![0.0001, 0.001, 0.01, 0.1, 1.0]),
                ("rcv1mc-like", vec![0.01, 0.1, 1.0, 10.0, 100.0]),
            ],
        )
    };
    let mut results = Json::obj();
    for (name, grid) in &datasets {
        let mut base = JobSpec::new(Problem::McSvm { c: 1.0 }, name, Policy::Acf);
        base.scale = scale;
        base.seed = cfg.seed;
        base.eps = 0.01;
        base.max_iterations = if cfg.quick { 5_000_000 } else { 50_000_000 };
        // hold out a test set for the accuracy column
        let full = base.load_dataset().expect("dataset");
        let mut rng = Rng::new(cfg.seed ^ 0x7E57);
        let split = data::train_test_split(full.n_instances(), 0.3, &mut rng);
        let (train, test) = data::apply(&full, &split);

        let mut jobs = Vec::new();
        for &c in grid {
            for policy in [Policy::Uniform, Policy::Acf] {
                let mut j = base.clone();
                j.problem = Problem::McSvm { c };
                j.policy = policy;
                jobs.push(j);
            }
        }
        let outcomes = acf_cd::util::threadpool::parallel_map(jobs.len(), cfg.workers, |k| {
            acf_cd::coordinator::run_job_on(&jobs[k], &train).expect("job failed")
        });
        let mut t = Table::new(
            &format!("Table 8 (analog) — WW multi-class SVM on {name}"),
            &[
                "C", "test acc", "uniform iters", "uniform sec", "acf iters", "acf sec",
                "speedup iter", "speedup time",
            ],
        );
        for &c in grid {
            let uni = outcomes
                .iter()
                .find(|o| o.spec.problem.parameter() == c && o.spec.policy == Policy::Uniform)
                .unwrap();
            let acf = outcomes
                .iter()
                .find(|o| o.spec.problem.parameter() == c && o.spec.policy == Policy::Acf)
                .unwrap();
            let acc = acf
                .w_multi
                .as_ref()
                .map(|wm| data::multiclass_accuracy(&test, wm))
                .unwrap_or(0.0);
            let dnf = !uni.result.status.converged() || !acf.result.status.converged();
            let ratio = |a: f64, b: f64| {
                if dnf || b <= 0.0 {
                    "—".to_string()
                } else {
                    format!("{:.1}", a / b)
                }
            };
            t.row(vec![
                format!("{c}"),
                format!("{:.1}%", 100.0 * acc),
                fmt_count(uni.result.iterations as f64),
                format!("{:.3}", uni.result.seconds),
                fmt_count(acf.result.iterations as f64),
                format!("{:.3}", acf.result.seconds),
                ratio(uni.result.iterations as f64, acf.result.iterations as f64),
                ratio(uni.result.seconds, acf.result.seconds),
            ]);
        }
        t.print();
        results.set(name, acf_cd::coordinator::outcomes_json(&outcomes));
    }
    cfg.finish(results);
}
