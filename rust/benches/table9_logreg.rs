//! Table 9 — dual logistic regression: liblinear (uniform sweeps in
//! random order; no shrinking — the dual solution is dense) vs ACF-CD.
//!
//! Paper protocol: news20 / rcv1 / url analogs, C on a 10^k grid of 5
//! values centered on the best 3-fold CV score, reporting CV accuracy,
//! iterations, seconds and speed-ups. Shape expectation: near-parity or
//! small losses at heavy regularization, speed-ups growing to 1–2 orders
//! of magnitude at large C; baseline runs that exceed the budget are
//! "—" (the paper's five-day DNFs).
//!
//! Run: `cargo bench --bench table9_logreg [-- --quick]`

use acf_cd::bench_util::{BenchConfig, Table};
use acf_cd::coordinator::{cross_validate, run_sweep, JobSpec, Problem, SweepSpec};
use acf_cd::data::Scale;
use acf_cd::sched::Policy;
use acf_cd::util::json::Json;
use acf_cd::util::timer::fmt_count;

fn main() {
    let cfg = BenchConfig::from_env();
    let (scale, datasets): (Scale, Vec<(&str, Vec<f64>)>) = if cfg.quick {
        (Scale(0.12), vec![("rcv1-like", vec![1.0, 10.0, 100.0])])
    } else {
        (
            Scale(1.0),
            vec![
                ("news20-like", vec![1.0, 10.0, 100.0, 1000.0, 10000.0]),
                ("rcv1-like", vec![1.0, 10.0, 100.0, 1000.0, 10000.0]),
                ("url-like", vec![0.1, 1.0, 10.0, 100.0, 1000.0]),
            ],
        )
    };
    let mut results = Json::obj();
    for (name, grid) in &datasets {
        let mut base = JobSpec::new(Problem::LogReg { c: 1.0 }, name, Policy::Acf);
        base.scale = scale;
        base.seed = cfg.seed;
        base.eps = 0.01;
        base.max_iterations = if cfg.quick { 5_000_000 } else { 60_000_000 };
        let sweep = SweepSpec {
            base: base.clone(),
            grid: grid.clone(),
            policies: vec![Policy::Permutation, Policy::Acf],
            selectors: vec![],
            include_shrinking: false,
            workers: cfg.workers,
        };
        let outcomes = run_sweep(&sweep).expect("sweep");
        let mut t = Table::new(
            &format!("Table 9 (analog) — dual logistic regression on {name}"),
            &[
                "C", "3-fold CV", "liblinear iters", "liblinear sec", "acf iters", "acf sec",
                "speedup iter", "speedup time",
            ],
        );
        for &c in grid {
            let lib = outcomes
                .iter()
                .find(|o| {
                    o.spec.problem.parameter() == c && o.spec.policy == Policy::Permutation
                })
                .unwrap();
            let acf = outcomes
                .iter()
                .find(|o| o.spec.problem.parameter() == c && o.spec.policy == Policy::Acf)
                .unwrap();
            let cv = cross_validate(
                Problem::LogReg { c },
                name,
                Policy::Acf,
                base.eps,
                scale,
                3,
                cfg.seed,
                cfg.workers,
            )
            .unwrap_or(f64::NAN);
            let dnf_l = !lib.result.status.converged();
            let dnf_a = !acf.result.status.converged();
            let cell = |x: f64, dnf: bool| if dnf { "—".into() } else { fmt_count(x) };
            let secf = |s: f64, dnf: bool| {
                if dnf {
                    "—".to_string()
                } else {
                    format!("{s:.3}")
                }
            };
            let ratio = |a: f64, b: f64| {
                if dnf_l || dnf_a || b <= 0.0 {
                    "—".to_string()
                } else {
                    format!("{:.1}", a / b)
                }
            };
            t.row(vec![
                format!("{c}"),
                format!("{:.1}%", 100.0 * cv),
                cell(lib.result.iterations as f64, dnf_l),
                secf(lib.result.seconds, dnf_l),
                cell(acf.result.iterations as f64, dnf_a),
                secf(acf.result.seconds, dnf_a),
                ratio(lib.result.iterations as f64, acf.result.iterations as f64),
                ratio(lib.result.seconds, acf.result.seconds),
            ]);
        }
        t.print();
        results.set(name, acf_cd::coordinator::outcomes_json(&outcomes));
    }
    cfg.finish(results);
}
