//! The paper's contribution: **Adaptive Coordinate Frequencies** (ACF).
//!
//! * [`preferences`] — Algorithm 2, the online preference update.
//! * [`sequence`] — Algorithm 3, amortized-O(1) block sampling from π.
//! * [`AcfScheduler`] — the two combined; solvers consume it through
//!   the [`crate::select::Selector`] interface (the
//!   [`crate::select::AcfSelector`] adapter delegates 1:1).

pub mod preferences;
pub mod sequence;

pub use preferences::{AcfParams, Preferences};
pub use sequence::SequenceGenerator;

use crate::util::rng::Rng;

/// The full ACF scheduler: preference adaptation + block sequencing.
#[derive(Clone, Debug)]
pub struct AcfScheduler {
    prefs: Preferences,
    gen: SequenceGenerator,
    block: Vec<u32>,
    cursor: usize,
    rng: Rng,
    blocks_emitted: u64,
}

impl AcfScheduler {
    pub fn new(n: usize, params: AcfParams, rng: Rng) -> Self {
        Self {
            prefs: Preferences::new(n, params),
            gen: SequenceGenerator::new(n),
            block: Vec::with_capacity(2 * n),
            cursor: 0,
            rng,
            blocks_emitted: 0,
        }
    }

    pub fn n(&self) -> usize {
        self.prefs.len()
    }

    pub fn preferences(&self) -> &Preferences {
        &self.prefs
    }

    /// Next coordinate to optimize (amortized O(1): regenerates a block
    /// of Θ(n) indices when the current one is exhausted).
    #[inline]
    pub fn next(&mut self) -> usize {
        while self.cursor >= self.block.len() {
            self.gen.next_block(&self.prefs, &mut self.rng, &mut self.block);
            self.cursor = 0;
            self.blocks_emitted += 1;
            // periodic drift correction: cheap (O(n)) relative to the
            // block we just built
            if self.blocks_emitted % 64 == 0 {
                self.prefs.refresh_sum();
            }
            // Degenerate guard: with extreme preference skew a block can
            // be empty only if all ⌊a_i⌋ = 0; the accumulators then grow
            // so the next call must emit. Loop rather than recurse.
        }
        let i = self.block[self.cursor];
        self.cursor += 1;
        i as usize
    }

    /// Report the observed progress `Δf` of the step on coordinate `i`
    /// (Algorithm 2 update).
    #[inline]
    pub fn report(&mut self, i: usize, delta_f: f64) {
        self.prefs.update(i, delta_f);
    }

    pub fn blocks_emitted(&self) -> u64 {
        self.blocks_emitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_cycles_through_all_coordinates() {
        let mut s = AcfScheduler::new(8, AcfParams::default(), Rng::new(1));
        let mut seen = vec![false; 8];
        for _ in 0..8 {
            seen[s.next()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn adaptation_shifts_frequencies() {
        // Reward coordinate 0 heavily; after adaptation it should appear
        // ~p_max/p_min more often than a starved coordinate.
        let n = 10;
        let mut s = AcfScheduler::new(n, AcfParams::default(), Rng::new(2));
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            let i = s.next();
            counts[i] += 1;
            let gain = if i == 0 { 10.0 } else { 0.01 };
            s.report(i, gain);
        }
        s.preferences().check_invariants().unwrap();
        // coordinate 0 should dominate
        let others_max = counts[1..].iter().copied().max().unwrap();
        assert!(
            counts[0] > 3 * others_max,
            "counts[0] = {}, max other = {}",
            counts[0],
            others_max
        );
        // ratio bounded by p_max/p_min = 400
        assert!(counts[0] < 400 * (others_max + 1));
    }

    #[test]
    fn equal_progress_keeps_near_uniform() {
        let n = 6;
        let mut s = AcfScheduler::new(n, AcfParams::default(), Rng::new(3));
        let mut counts = vec![0usize; n];
        for _ in 0..12_000 {
            let i = s.next();
            counts[i] += 1;
            s.report(i, 1.0);
        }
        let min = *counts.iter().min().unwrap() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max / min < 1.35, "min {min} max {max}");
    }

    #[test]
    fn next_terminates_and_covers_with_saturated_preferences() {
        // Companion to `sequence::tests::no_livelock_under_extreme_preference_skew`:
        // drive the preferences to the p_min/p_max clip bounds through
        // reports, then check the degenerate-block loop in `next` keeps
        // emitting and the waiting-time bound still covers every
        // coordinate.
        let n = 12;
        let mut s = AcfScheduler::new(n, AcfParams::default(), Rng::new(11));
        for _ in 0..20_000 {
            let i = s.next();
            s.report(i, if i == 0 { 100.0 } else { 0.0 });
        }
        let p = s.preferences();
        assert!(p.preference(0) >= p.params().p_max - 1e-9, "skew not saturated");
        let mut seen = vec![false; n];
        for _ in 0..n * 500 {
            seen[s.next()] = true;
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let mut s = AcfScheduler::new(5, AcfParams::default(), Rng::new(seed));
            (0..100)
                .map(|k| {
                    let i = s.next();
                    s.report(i, (k % 3) as f64);
                    i
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
