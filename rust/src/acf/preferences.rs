//! Algorithm 2 — the **Adaptive Coordinate Frequencies update**.
//!
//! Maintains unnormalized preferences `p_i` with `π_i = p_i / p_sum` and
//! an exponentially fading record `r̄` of average single-step progress.
//! After a CD step on coordinate `i` with observed gain `Δf`:
//!
//! ```text
//! p_new ← clip( exp(c · (Δf/r̄ − 1)) · p_i , p_min, p_max )
//! p_sum ← p_sum + p_new − p_i
//! p_i   ← p_new
//! r̄     ← (1 − η)·r̄ + η·Δf
//! ```
//!
//! Paper defaults (Table 1): `c = 1/5`, `p_min = 1/20`, `p_max = 20`,
//! `η = 1/n`. The paper notes the algorithm is rather insensitive to
//! these values.

/// Tunable ACF parameters (paper Table 1 defaults).
#[derive(Clone, Copy, Debug)]
pub struct AcfParams {
    /// learning rate of the preference update
    pub c: f64,
    /// lower clip bound for preferences
    pub p_min: f64,
    /// upper clip bound for preferences
    pub p_max: f64,
    /// fading rate of the average-progress record; `None` = 1/n
    pub eta: Option<f64>,
}

impl Default for AcfParams {
    fn default() -> Self {
        Self { c: 0.2, p_min: 1.0 / 20.0, p_max: 20.0, eta: None }
    }
}

/// Preference state of the ACF scheduler.
#[derive(Clone, Debug)]
pub struct Preferences {
    params: AcfParams,
    eta: f64,
    p: Vec<f64>,
    p_sum: f64,
    /// fading average progress r̄; `None` until warm-up completes
    r_bar: Option<f64>,
    /// accumulated progress during warm-up (first sweep, no adaptation)
    warmup_sum: f64,
    warmup_count: usize,
    warmup_target: usize,
}

impl Preferences {
    /// Uniform initialization over `n` coordinates. Warm-up lasts one
    /// sweep (`n` steps, paper §5): during warm-up, progress samples only
    /// feed the initial estimate of r̄ and preferences stay uniform.
    pub fn new(n: usize, params: AcfParams) -> Self {
        assert!(n > 0);
        assert!(params.p_min > 0.0 && params.p_min <= 1.0);
        assert!(params.p_max >= 1.0);
        assert!(params.c > 0.0);
        let eta = params.eta.unwrap_or(1.0 / n as f64);
        Self {
            params,
            eta,
            p: vec![1.0; n],
            p_sum: n as f64,
            r_bar: None,
            warmup_sum: 0.0,
            warmup_count: 0,
            warmup_target: n,
        }
    }

    /// Initialize with an informed (non-uniform) preference vector.
    pub fn with_initial(p: Vec<f64>, params: AcfParams) -> Self {
        let n = p.len();
        let mut s = Self::new(n, params);
        s.p_sum = p.iter().sum();
        assert!(s.p_sum > 0.0);
        s.p = p;
        for v in &s.p {
            assert!(*v >= s.params.p_min && *v <= s.params.p_max);
        }
        s
    }

    pub fn len(&self) -> usize {
        self.p.len()
    }

    pub fn is_empty(&self) -> bool {
        self.p.is_empty()
    }

    pub fn params(&self) -> &AcfParams {
        self.params_ref()
    }

    fn params_ref(&self) -> &AcfParams {
        &self.params
    }

    /// Raw preference of coordinate i.
    #[inline]
    pub fn preference(&self, i: usize) -> f64 {
        self.p[i]
    }

    /// Selection probability π_i = p_i / p_sum.
    #[inline]
    pub fn probability(&self, i: usize) -> f64 {
        self.p[i] / self.p_sum
    }

    pub fn p_sum(&self) -> f64 {
        self.p_sum
    }

    /// Non-allocating probability snapshot: clears `out` and refills it
    /// with π (capacity is reused across calls — the hot-path form for
    /// selectors and sequence diagnostics sampled once per block).
    pub fn probabilities_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.p.iter().map(|&v| v / self.p_sum));
    }

    /// Allocating convenience wrapper around
    /// [`probabilities_into`](Preferences::probabilities_into).
    pub fn probabilities(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.p.len());
        self.probabilities_into(&mut out);
        out
    }

    pub fn r_bar(&self) -> Option<f64> {
        self.r_bar
    }

    pub fn in_warmup(&self) -> bool {
        self.r_bar.is_none()
    }

    /// Algorithm 2: record progress `delta_f` of a step on coordinate `i`
    /// and adapt the preference. `delta_f` must be the *decrease* of the
    /// objective (non-negative for an exact one-dimensional solve; tiny
    /// negatives from floating-point noise are clamped to 0).
    #[inline]
    pub fn update(&mut self, i: usize, delta_f: f64) {
        let delta_f = delta_f.max(0.0);
        match self.r_bar {
            None => {
                // Warm-up: collect average progress over ~one sweep.
                self.warmup_sum += delta_f;
                self.warmup_count += 1;
                if self.warmup_count >= self.warmup_target {
                    let mean = self.warmup_sum / self.warmup_count as f64;
                    // Guard: an all-zero warm-up (already optimal) leaves
                    // r̄ unset; adaptation stays off until progress shows.
                    if mean > 0.0 {
                        self.r_bar = Some(mean);
                    } else {
                        self.warmup_sum = 0.0;
                        self.warmup_count = 0;
                    }
                }
            }
            Some(r_bar) => {
                debug_assert!(r_bar > 0.0);
                // Hot-path shortcuts (exact, by monotonicity of the
                // update): a preference already pinned at a bound only
                // moves if the multiplier points inward, so the common
                // converged cases (Δf below average at p_min, above
                // average at p_max) skip the exp() entirely.
                let p_i = self.p[i];
                let up = delta_f > r_bar;
                if !((p_i <= self.params.p_min && !up) || (p_i >= self.params.p_max && up)) {
                    // exp-argument clamped for numerical safety on wildly
                    // non-stationary progress (e.g. the first step after
                    // a constraint activates); bounds chosen so exp()
                    // cannot overflow and a single sample cannot blow
                    // past the clip range by more than e^±8.
                    let arg = (self.params.c * (delta_f / r_bar - 1.0)).clamp(-8.0, 8.0);
                    let p_new =
                        (arg.exp() * p_i).clamp(self.params.p_min, self.params.p_max);
                    self.p_sum += p_new - p_i;
                    self.p[i] = p_new;
                }
                let r_new = (1.0 - self.eta) * r_bar + self.eta * delta_f;
                // r̄ must stay strictly positive for the ratio to exist;
                // freeze at a tiny floor when converged.
                self.r_bar = Some(r_new.max(f64::MIN_POSITIVE * 1e16));
            }
        }
    }

    /// Re-normalize the stored sum (guards against floating-point drift
    /// across billions of incremental updates; called once per epoch by
    /// the scheduler).
    pub fn refresh_sum(&mut self) {
        self.p_sum = self.p.iter().sum();
    }

    /// Reset coordinate i's preference (used when a coordinate re-enters
    /// the active set after unshrinking).
    pub fn reset(&mut self, i: usize, value: f64) {
        let v = value.clamp(self.params.p_min, self.params.p_max);
        self.p_sum += v - self.p[i];
        self.p[i] = v;
    }

    /// Invariant check for property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, &v) in self.p.iter().enumerate() {
            if !(self.params.p_min..=self.params.p_max).contains(&v) {
                return Err(format!("p[{i}] = {v} out of bounds"));
            }
        }
        let direct: f64 = self.p.iter().sum();
        if (direct - self.p_sum).abs() > 1e-6 * direct.max(1.0) {
            return Err(format!("p_sum drift: stored {} vs direct {direct}", self.p_sum));
        }
        if let Some(r) = self.r_bar {
            if !(r > 0.0) {
                return Err(format!("r_bar not positive: {r}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn warmed(n: usize) -> Preferences {
        let mut p = Preferences::new(n, AcfParams::default());
        for i in 0..n {
            p.update(i, 1.0);
        }
        assert!(!p.in_warmup());
        p
    }

    #[test]
    fn warmup_initializes_r_bar_to_mean() {
        let mut p = Preferences::new(4, AcfParams::default());
        for (i, g) in [1.0, 2.0, 3.0, 4.0].iter().enumerate() {
            assert!(p.in_warmup());
            p.update(i, *g);
        }
        assert!((p.r_bar().unwrap() - 2.5).abs() < 1e-12);
        // preferences untouched during warmup
        assert!(p.probabilities().iter().all(|&x| (x - 0.25).abs() < 1e-12));
    }

    #[test]
    fn zero_warmup_defers_adaptation() {
        let mut p = Preferences::new(3, AcfParams::default());
        for i in 0..3 {
            p.update(i, 0.0);
        }
        assert!(p.in_warmup());
        // progress appears later
        for i in 0..3 {
            p.update(i, 0.5);
        }
        assert!(!p.in_warmup());
    }

    #[test]
    fn above_average_progress_raises_preference() {
        let mut p = warmed(4);
        let before = p.preference(2);
        p.update(2, 10.0); // way above r̄ ≈ 1
        assert!(p.preference(2) > before);
        p.check_invariants().unwrap();
    }

    #[test]
    fn below_average_progress_lowers_preference() {
        let mut p = warmed(4);
        let before = p.preference(1);
        p.update(1, 0.0);
        assert!(p.preference(1) < before);
        p.check_invariants().unwrap();
    }

    #[test]
    fn average_progress_is_neutral() {
        let mut p = warmed(4);
        let r = p.r_bar().unwrap();
        let before = p.preference(0);
        p.update(0, r); // Δf = r̄ ⇒ exp(0) = 1
        assert!((p.preference(0) - before).abs() < 1e-12);
    }

    #[test]
    fn clipping_holds_under_extreme_updates() {
        let mut p = warmed(4);
        for _ in 0..200 {
            p.update(0, 100.0);
        }
        assert!(p.preference(0) <= AcfParams::default().p_max + 1e-12);
        for _ in 0..500 {
            p.update(1, 0.0);
        }
        assert!(p.preference(1) >= AcfParams::default().p_min - 1e-12);
        p.check_invariants().unwrap();
    }

    #[test]
    fn probabilities_sum_to_one() {
        let mut p = warmed(8);
        let mut g = 0.3;
        for step in 0..1000 {
            p.update(step % 8, g);
            g = (g * 1.37) % 3.0;
        }
        let s: f64 = p.probabilities().iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        p.check_invariants().unwrap();
    }

    #[test]
    fn r_bar_tracks_fading_average() {
        let params = AcfParams { eta: Some(0.5), ..Default::default() };
        let mut p = Preferences::new(2, params);
        p.update(0, 1.0);
        p.update(1, 1.0); // warmup done, r̄ = 1
        p.update(0, 3.0); // r̄ ← 0.5·1 + 0.5·3 = 2
        assert!((p.r_bar().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn property_invariants_hold_under_random_updates() {
        prop::check(50, |gen| {
            let n = gen.usize_in(1, 40);
            let mut p = Preferences::new(n, AcfParams::default());
            let steps = gen.usize_in(n, 500);
            for _ in 0..steps {
                let i = gen.usize_in(0, n - 1);
                let g = if gen.bool() { gen.f64_in(0.0, 5.0) } else { 0.0 };
                p.update(i, g);
            }
            p.check_invariants().map_err(|e| e)
        });
    }

    #[test]
    fn negative_progress_is_clamped() {
        let mut p = warmed(3);
        let before = p.preference(0);
        p.update(0, -1e-9); // fp noise: treated as 0 ⇒ preference drops
        assert!(p.preference(0) <= before);
        p.check_invariants().unwrap();
    }

    #[test]
    fn reset_and_refresh() {
        let mut p = warmed(5);
        p.update(3, 9.0);
        p.reset(3, 1.0);
        assert_eq!(p.preference(3), 1.0);
        p.refresh_sum();
        p.check_invariants().unwrap();
    }

    #[test]
    fn informed_initialization() {
        let p = Preferences::with_initial(vec![0.5, 2.0, 1.0], AcfParams::default());
        assert!((p.probability(1) - 2.0 / 3.5).abs() < 1e-12);
        p.check_invariants().unwrap();
    }

    #[test]
    fn probabilities_into_matches_allocating_path_and_reuses_buffer() {
        let mut p = warmed(6);
        for step in 0..300 {
            p.update(step % 6, (step % 4) as f64);
        }
        let mut buf = vec![9.0; 40]; // stale, oversized: must be cleared
        p.probabilities_into(&mut buf);
        assert_eq!(buf, p.probabilities());
        assert_eq!(buf.len(), 6);
        assert!((buf.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // repeated calls reuse the buffer without growing it
        let cap = buf.capacity();
        p.probabilities_into(&mut buf);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn invariants_hold_under_long_randomized_update_reset_streams() {
        // Satellite coverage: interleave update/reset/refresh_sum for
        // many steps and re-check the full invariant set (clamping,
        // stored-sum drift, r̄ positivity) at adversarial points.
        prop::check(30, |gen| {
            let n = gen.usize_in(1, 32);
            let mut p = Preferences::new(n, AcfParams::default());
            let steps = gen.usize_in(2 * n, 3_000);
            for _ in 0..steps {
                let i = gen.usize_in(0, n - 1);
                match gen.usize_in(0, 9) {
                    // mostly updates, with occasional extreme magnitudes
                    0..=6 => {
                        let g =
                            if gen.bool() { gen.f64_in(0.0, 1e6) } else { gen.f64_in(0.0, 1.0) };
                        p.update(i, g);
                    }
                    // resets with out-of-range requests (must clamp)
                    7 => p.reset(i, gen.f64_in(-5.0, 50.0)),
                    // fp-noise negatives (must be treated as 0)
                    8 => p.update(i, -1e-12),
                    _ => p.refresh_sum(),
                }
            }
            p.check_invariants().map_err(|e| e)
        });
    }

    #[test]
    fn preferences_stay_clamped_after_reset_streams() {
        let mut p = warmed(5);
        let params = *p.params();
        for k in 0..200 {
            p.reset(k % 5, if k % 2 == 0 { 1e9 } else { -1e9 });
            p.update(k % 5, (k % 7) as f64);
        }
        for i in 0..5 {
            let v = p.preference(i);
            assert!((params.p_min..=params.p_max).contains(&v), "p[{i}] = {v}");
        }
        p.check_invariants().unwrap();
    }

    #[test]
    fn refresh_sum_drift_stays_within_tolerance() {
        // Drive many incremental updates, measure the stored-sum drift
        // against a direct summation, then confirm refresh_sum zeroes it.
        let mut p = warmed(16);
        let mut g = 0.1;
        for step in 0..50_000 {
            p.update(step % 16, g);
            g = (g * 1.618 + 0.01) % 7.0;
        }
        let direct: f64 = (0..16).map(|i| p.preference(i)).sum();
        let drift = (direct - p.p_sum()).abs();
        assert!(drift <= 1e-6 * direct.max(1.0), "pre-refresh drift {drift}");
        p.refresh_sum();
        let direct2: f64 = (0..16).map(|i| p.preference(i)).sum();
        assert_eq!(p.p_sum(), direct2, "refresh_sum must make the stored sum exact");
        p.check_invariants().unwrap();
    }
}
