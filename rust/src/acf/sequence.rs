//! Algorithm 3 — deterministic block sampling from the coordinate
//! distribution π in amortized O(1) per CD iteration.
//!
//! Per block: for every coordinate, `a_i ← a_i + n·p_i/p_sum`; append
//! `⌊a_i⌋` copies of `i`; keep the fractional part; shuffle the block.
//! The produced sequence respects π exactly over time, emits on average
//! `n` (at most `2n`) indices per block at Θ(n) cost, and guarantees a
//! waiting time of at most `⌈1/(n·π_min)⌉ ≤ ⌈p_sum/(n·p_min)⌉` blocks for
//! every coordinate — the "essentially cyclic" property that carries the
//! CD convergence guarantees over to ACF (paper §5).

use super::preferences::Preferences;
use crate::util::rng::Rng;

/// Block sequence generator (accumulator state of Algorithm 3).
#[derive(Clone, Debug)]
pub struct SequenceGenerator {
    accumulators: Vec<f64>,
}

impl SequenceGenerator {
    pub fn new(n: usize) -> Self {
        Self { accumulators: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.accumulators.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accumulators.is_empty()
    }

    /// Generate the next block of coordinate indices according to the
    /// current preferences. Reuses `out` to avoid per-block allocation in
    /// the hot loop.
    pub fn next_block(&mut self, prefs: &Preferences, rng: &mut Rng, out: &mut Vec<u32>) {
        debug_assert_eq!(self.accumulators.len(), prefs.len());
        let scale = self.accumulators.len() as f64 / prefs.p_sum();
        self.next_block_weighted(|i| prefs.preference(i) * scale, rng, out);
    }

    /// The Algorithm 3 core over an arbitrary weight function:
    /// `weight(i)` must equal `n·π_i` for the block to average `n`
    /// indices (and never exceed `2n`). Shared with
    /// [`crate::select::BlockSampler`], which drives it from a plain
    /// normalized probability slice — one copy of the
    /// waiting-time-bound-critical accumulator logic.
    pub fn next_block_weighted(
        &mut self,
        weight: impl Fn(usize) -> f64,
        rng: &mut Rng,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        for i in 0..self.accumulators.len() {
            let a = self.accumulators[i] + weight(i);
            let k = a as usize; // ⌊a⌋ (a ≥ 0 always)
            for _ in 0..k {
                out.push(i as u32);
            }
            self.accumulators[i] = a - k as f64;
        }
        rng.shuffle(out);
    }

    /// Like [`Self::next_block`] but allocates the output.
    pub fn block(&mut self, prefs: &Preferences, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(2 * self.accumulators.len());
        self.next_block(prefs, rng, &mut out);
        out
    }

    /// Reset accumulator state (used after shrinking re-indexes
    /// coordinates).
    pub fn reset(&mut self, n: usize) {
        self.accumulators.clear();
        self.accumulators.resize(n, 0.0);
    }

    pub fn accumulator(&self, i: usize) -> f64 {
        self.accumulators[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acf::preferences::AcfParams;
    use crate::util::prop;

    fn prefs_with(p: Vec<f64>) -> Preferences {
        Preferences::with_initial(p, AcfParams::default())
    }

    #[test]
    fn uniform_prefs_emit_each_coordinate_once() {
        let prefs = prefs_with(vec![1.0; 10]);
        let mut gen = SequenceGenerator::new(10);
        let mut rng = Rng::new(1);
        let block = gen.block(&prefs, &mut rng);
        let mut sorted = block.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0u32..10).collect::<Vec<_>>());
    }

    #[test]
    fn block_size_bounds() {
        // average n, at most 2n per block
        prop::check(40, |g| {
            let n = g.usize_in(1, 64);
            let p: Vec<f64> = (0..n).map(|_| g.f64_in(0.05, 20.0)).collect();
            let prefs = prefs_with(p);
            let mut gen = SequenceGenerator::new(n);
            let mut rng = Rng::new(g.seed);
            let mut total = 0usize;
            let blocks = 50;
            for _ in 0..blocks {
                let b = gen.block(&prefs, &mut rng);
                prop::assert_holds(b.len() <= 2 * n, "block ≤ 2n")?;
                total += b.len();
            }
            // average exactly n up to the accumulated fractional parts
            let avg = total as f64 / blocks as f64;
            prop::assert_holds(
                (avg - n as f64).abs() <= 1.0 + n as f64 / blocks as f64,
                "average block size ≈ n",
            )
        });
    }

    #[test]
    fn empirical_frequency_matches_pi() {
        // Over many blocks the emitted counts converge to π exactly
        // (deterministic accumulators ⇒ error ≤ 1 per coordinate).
        let p = vec![0.05, 1.0, 3.0, 20.0, 0.5];
        let prefs = prefs_with(p.clone());
        let n = p.len();
        let p_sum: f64 = p.iter().sum();
        let mut gen = SequenceGenerator::new(n);
        let mut rng = Rng::new(2);
        let mut counts = vec![0usize; n];
        let blocks = 400;
        let mut total = 0usize;
        for _ in 0..blocks {
            let b = gen.block(&prefs, &mut rng);
            total += b.len();
            for &i in &b {
                counts[i as usize] += 1;
            }
        }
        for i in 0..n {
            let expect = p[i] / p_sum;
            let got = counts[i] as f64 / total as f64;
            assert!(
                (got - expect).abs() < 2.0 / blocks as f64 + 1e-3,
                "coord {i}: got {got}, expect {expect}"
            );
        }
    }

    #[test]
    fn waiting_time_bound_holds() {
        // Every coordinate appears at least once every ⌈1/(n·π_min)⌉
        // blocks.
        prop::check(25, |g| {
            let n = g.usize_in(2, 32);
            let p: Vec<f64> = (0..n).map(|_| g.f64_in(0.05, 20.0)).collect();
            let p_sum: f64 = p.iter().sum();
            let pi_min = p.iter().cloned().fold(f64::INFINITY, f64::min) / p_sum;
            let tau = (1.0 / (n as f64 * pi_min)).ceil() as usize;
            let prefs = prefs_with(p);
            let mut gen = SequenceGenerator::new(n);
            let mut rng = Rng::new(g.seed);
            let mut last_seen = vec![0usize; n];
            let blocks = 30 * (tau + 1);
            for b in 1..=blocks {
                let blk = gen.block(&prefs, &mut rng);
                for &i in &blk {
                    let gap = b - last_seen[i as usize];
                    prop::assert_holds(
                        gap <= tau + 1,
                        &format!("coord {i} waited {gap} blocks (τ = {tau})"),
                    )?;
                    last_seen[i as usize] = b;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_livelock_under_extreme_preference_skew() {
        // Guards the degenerate-block loop in `acf/mod.rs` (AcfScheduler::next):
        // that loop terminates iff blocks cannot stay empty forever. The
        // accumulator increments of one block sum to exactly n, so every
        // block must emit ≥ 1 index — even with every preference pinned
        // at the p_min/p_max clip bounds — and the Algorithm-3 waiting
        // -time bound τ = ⌈1/(n·π_min)⌉ guarantees every coordinate is
        // eventually emitted. Checked here as a property over adversarial
        // bound-saturated preference vectors.
        let params = AcfParams::default();
        prop::check(40, |g| {
            let n = g.usize_in(1, 48);
            // adversarial skew: each preference at one of the clip
            // bounds (with a few mid-range values mixed in)
            let p: Vec<f64> = (0..n)
                .map(|_| *g.choose(&[params.p_min, params.p_min, params.p_max, 1.0]))
                .collect();
            let p_sum: f64 = p.iter().sum();
            let pi_min = p.iter().cloned().fold(f64::INFINITY, f64::min) / p_sum;
            let tau = (1.0 / (n as f64 * pi_min)).ceil() as usize;
            let prefs = prefs_with(p);
            let mut gen = SequenceGenerator::new(n);
            let mut rng = Rng::new(g.seed);
            let mut last_seen = vec![0usize; n];
            let blocks = 5 * (tau + 1);
            for b in 1..=blocks {
                let blk = gen.block(&prefs, &mut rng);
                prop::assert_holds(!blk.is_empty(), "a block can never be empty")?;
                for &i in &blk {
                    last_seen[i as usize] = b;
                }
                for (i, &seen) in last_seen.iter().enumerate() {
                    prop::assert_holds(
                        b - seen <= tau + 1,
                        &format!("coord {i} starved for {} blocks (τ = {tau})", b - seen),
                    )?;
                }
            }
            prop::assert_holds(
                last_seen.iter().all(|&s| s > 0),
                "every coordinate eventually emitted",
            )
        });
    }

    #[test]
    fn accumulators_stay_in_unit_interval() {
        let prefs = prefs_with(vec![0.07, 2.3, 11.0]);
        let mut gen = SequenceGenerator::new(3);
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let _ = gen.block(&prefs, &mut rng);
            for i in 0..3 {
                let a = gen.accumulator(i);
                assert!((0.0..1.0).contains(&a), "a[{i}] = {a}");
            }
        }
    }

    #[test]
    fn weighted_core_matches_preference_path_bit_for_bit() {
        // next_block delegates to next_block_weighted; the refactor must
        // be invisible — same blocks, same accumulator trajectories.
        let prefs = prefs_with(vec![0.05, 1.0, 3.0, 20.0, 0.5]);
        let n = 5;
        let mut g1 = SequenceGenerator::new(n);
        let mut g2 = SequenceGenerator::new(n);
        let mut r1 = Rng::new(13);
        let mut r2 = Rng::new(13);
        let scale = n as f64 / prefs.p_sum();
        let mut b1 = Vec::new();
        let mut b2 = Vec::new();
        for _ in 0..50 {
            g1.next_block(&prefs, &mut r1, &mut b1);
            g2.next_block_weighted(|i| prefs.preference(i) * scale, &mut r2, &mut b2);
            assert_eq!(b1, b2);
        }
        for i in 0..n {
            assert_eq!(g1.accumulator(i).to_bits(), g2.accumulator(i).to_bits());
        }
    }

    #[test]
    fn reuse_avoids_allocation_and_matches() {
        let prefs = prefs_with(vec![1.0; 6]);
        let mut gen1 = SequenceGenerator::new(6);
        let mut gen2 = SequenceGenerator::new(6);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let mut buf = Vec::new();
        for _ in 0..10 {
            gen1.next_block(&prefs, &mut r1, &mut buf);
            let fresh = gen2.block(&prefs, &mut r2);
            assert_eq!(buf, fresh);
        }
    }

    #[test]
    fn reset_clears_state() {
        let prefs = prefs_with(vec![1.5, 0.5]);
        let mut gen = SequenceGenerator::new(2);
        let mut rng = Rng::new(4);
        let _ = gen.block(&prefs, &mut rng);
        gen.reset(5);
        assert_eq!(gen.len(), 5);
        assert!((0..5).all(|i| gen.accumulator(i) == 0.0));
    }
}
