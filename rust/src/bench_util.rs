//! Benchmark harness (no `criterion` in the offline build).
//!
//! Two kinds of benchmarks coexist in `rust/benches/`:
//!
//! 1. **Micro-benchmarks** — timed closures with warmup and repeated
//!    samples, reporting mean/median/p10/p90 ([`bench_fn`]).
//! 2. **Experiment regenerators** — each paper table/figure is a bench
//!    binary that runs the relevant solvers and prints the same rows the
//!    paper reports ([`Table`] pretty-printer + JSON dump).
//!
//! All benches accept `--quick` (reduced sizes for CI smoke) and
//! `--out <path.json>` via [`BenchConfig`].

use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats;
use crate::util::timer::Timer;

/// Shared bench CLI configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Reduced problem sizes (used by `cargo bench` CI smoke runs).
    pub quick: bool,
    /// Where to write the JSON results (optional).
    pub out: Option<String>,
    /// Random seed for dataset generation.
    pub seed: u64,
    /// Worker threads for grid sweeps.
    pub workers: usize,
    /// Hard cap on solver iterations per run (CI smoke guard; `None` =
    /// each bench's own default budget).
    pub max_iterations: Option<u64>,
}

impl BenchConfig {
    /// Parse from CLI flags and environment variables. `cargo bench`
    /// cannot always forward flags (e.g. in CI wrappers), so the env
    /// vars `ACF_BENCH_QUICK=1` and `ACF_BENCH_MAX_ITERS=<n>` mirror
    /// `--quick` and `--max-iters`.
    pub fn from_env() -> Self {
        let args = Args::from_env();
        let env_quick = std::env::var("ACF_BENCH_QUICK")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        // A malformed cap must not silently run unbounded — the CI smoke
        // job relies on it to stay within the runner's time budget.
        let parse_cap = |source: &str, v: &str| -> Option<u64> {
            match v.parse() {
                Ok(n) => Some(n),
                Err(_) => {
                    eprintln!("warning: {source}='{v}' is not an integer; iteration cap IGNORED");
                    None
                }
            }
        };
        let env_iters: Option<u64> = std::env::var("ACF_BENCH_MAX_ITERS")
            .ok()
            .and_then(|v| parse_cap("ACF_BENCH_MAX_ITERS", &v));
        // `cargo bench` passes `--bench`; ignore it gracefully.
        BenchConfig {
            quick: args.has("quick") || env_quick,
            out: args.get("out").map(|s| s.to_string()),
            seed: args.u64_or("seed", 20140103).unwrap_or(20140103),
            workers: args
                .usize_or("workers", crate::util::threadpool::default_workers())
                .unwrap_or(4),
            max_iterations: args.get("max-iters").and_then(|v| parse_cap("--max-iters", v)).or(env_iters),
        }
    }

    /// A [`crate::solvers::SolverConfig`] at `eps` honoring the bench's
    /// iteration cap.
    pub fn solver_config(&self, eps: f64) -> crate::solvers::SolverConfig {
        let mut c = crate::solvers::SolverConfig::with_eps(eps);
        if let Some(m) = self.max_iterations {
            c.max_iterations = m;
        }
        c
    }

    /// Write results JSON if `--out` was given; always returns the value.
    pub fn finish(&self, results: Json) -> Json {
        if let Some(path) = &self.out {
            if let Err(e) = std::fs::write(path, results.to_string_pretty()) {
                eprintln!("warning: could not write {path}: {e}");
            } else {
                eprintln!("results written to {path}");
            }
        }
        results
    }
}

/// Write the machine-readable `BENCH_<name>.json` summary next to the
/// human-readable report (working directory). Benches call this with
/// their headline numbers — median wall-clock, epochs, final objective —
/// so the repository accumulates a perf trajectory across PRs that tools
/// can diff without parsing stdout. Returns the path on success.
pub fn write_bench_summary(name: &str, summary: &Json) -> Option<String> {
    write_bench_summary_to(std::path::Path::new("."), name, summary)
}

/// [`write_bench_summary`] with an explicit output directory.
pub fn write_bench_summary_to(dir: &std::path::Path, name: &str, summary: &Json) -> Option<String> {
    let path = dir.join(format!("BENCH_{name}.json"));
    match std::fs::write(&path, summary.to_string_pretty()) {
        Ok(()) => {
            eprintln!("bench summary written to {}", path.display());
            Some(path.display().to_string())
        }
        Err(e) => {
            eprintln!("warning: could not write {}: {e}", path.display());
            None
        }
    }
}

/// Conventional summary entry for one measured configuration: the three
/// headline metrics every bench reports, plus free-form extras.
pub fn summary_entry(median_wall_clock_s: f64, epochs: u64, final_objective: f64) -> Json {
    let mut o = Json::obj();
    o.set("median_wall_clock_s", Json::Num(median_wall_clock_s))
        .set("epochs", Json::Num(epochs as f64))
        .set("final_objective", Json::Num(final_objective));
    o
}

/// Timing report of a micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchReport {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn p10(&self) -> f64 {
        stats::percentile(&self.samples, 0.10)
    }

    pub fn p90(&self) -> f64 {
        stats::percentile(&self.samples, 0.90)
    }

    pub fn print(&self) {
        println!(
            "{:<44} mean {:>10}  median {:>10}  p10 {:>10}  p90 {:>10}  ({} samples)",
            self.name,
            crate::util::timer::fmt_secs(self.mean()),
            crate::util::timer::fmt_secs(self.median()),
            crate::util::timer::fmt_secs(self.p10()),
            crate::util::timer::fmt_secs(self.p90()),
            self.samples.len()
        );
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()))
            .set("mean_s", Json::Num(self.mean()))
            .set("median_s", Json::Num(self.median()))
            .set("p10_s", Json::Num(self.p10()))
            .set("p90_s", Json::Num(self.p90()))
            .set("samples", Json::Num(self.samples.len() as f64));
        o
    }
}

/// Time `f` with `warmup` unrecorded runs followed by `iters` samples.
/// `f` returns a value that is black-boxed to prevent dead-code
/// elimination.
pub fn bench_fn<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchReport {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        black_box(f());
        samples.push(t.secs());
    }
    BenchReport { name: name.to_string(), samples }
}

/// Opaque value sink (std::hint::black_box wrapper, kept here so bench
/// code has a single import point).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A plain-text table mirroring the paper's layout. Columns are
/// left-aligned strings; numeric formatting is the caller's concern so
/// each bench can match the paper's notation (e.g. `7.06e8`).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len();
        println!("\n=== {} ===", self.title);
        let mut line = String::new();
        for (h, w) in self.headers.iter().zip(&widths) {
            line.push_str(&format!("{:<w$}   ", h, w = w));
        }
        println!("{}", line.trim_end());
        println!("{}", "-".repeat(total.min(160)));
        for row in &self.rows {
            let mut line = String::new();
            for (c, w) in row.iter().zip(&widths) {
                line.push_str(&format!("{:<w$}   ", c, w = w));
            }
            println!("{}", line.trim_end());
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("title", Json::Str(self.title.clone()))
            .set(
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            )
            .set(
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            );
        o
    }
}

/// Format a speed-up ratio the way the paper does (one decimal).
pub fn fmt_speedup(baseline: f64, ours: f64) -> String {
    if ours <= 0.0 || baseline <= 0.0 {
        "—".to_string()
    } else {
        format!("{:.1}", baseline / ours)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_collects_samples() {
        let r = bench_fn("noop", 2, 10, || 42u64);
        assert_eq!(r.samples.len(), 10);
        assert!(r.mean() >= 0.0);
        assert!(r.p10() <= r.p90());
    }

    #[test]
    fn table_round_trip() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["2".into(), "yy".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("Demo"));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
        t.print(); // smoke: must not panic
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(10.0, 2.0), "5.0");
        assert_eq!(fmt_speedup(10.0, 0.0), "—");
    }

    #[test]
    fn solver_config_honors_iteration_cap() {
        let mut cfg = BenchConfig {
            quick: true,
            out: None,
            seed: 1,
            workers: 1,
            max_iterations: Some(1234),
        };
        assert_eq!(cfg.solver_config(0.01).max_iterations, 1234);
        assert_eq!(cfg.solver_config(0.01).eps, 0.01);
        cfg.max_iterations = None;
        let default = crate::solvers::SolverConfig::default().max_iterations;
        assert_eq!(cfg.solver_config(0.01).max_iterations, default);
    }

    #[test]
    fn summary_entry_has_conventional_fields() {
        let e = summary_entry(1.25, 7, -3.5);
        assert_eq!(e.get("median_wall_clock_s").unwrap().as_f64(), Some(1.25));
        assert_eq!(e.get("epochs").unwrap().as_usize(), Some(7));
        assert_eq!(e.get("final_objective").unwrap().as_f64(), Some(-3.5));
    }

    #[test]
    fn bench_summary_round_trips_through_disk() {
        let dir = std::env::temp_dir().join("acf_cd_bench_summary_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = Json::obj();
        s.set("bench", Json::Str("demo".into())).set("entry", summary_entry(0.5, 3, 1.0));
        let path = write_bench_summary_to(&dir, "demo", &s).expect("writable temp dir");
        assert!(path.ends_with("BENCH_demo.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("demo"));
        assert_eq!(parsed.get("entry").unwrap().get("epochs").unwrap().as_usize(), Some(3));
    }
}
