//! Grid sweeps: the paper's evaluation protocol is "vary C (or λ) over a
//! grid, compare policies at each point, optionally with k-fold CV" —
//! this module runs those sweeps in parallel over a shared dataset.

use super::jobs::{run_job_on, JobOutcome, JobSpec, Problem};
use crate::data::{self, Scale};
use crate::sched::Policy;
use crate::select::SelectorKind;
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;
use crate::util::error::Result;

/// A (policy × parameter-grid) sweep on one dataset.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// template whose `problem` parameter is replaced per grid point
    pub base: JobSpec,
    /// parameter grid (C or λ values)
    pub grid: Vec<f64>,
    /// policies to compare at each grid point
    pub policies: Vec<Policy>,
    /// non-empty switches the sweep's comparison axis from policies to
    /// coordinate-selection rules (`sweep --selector a,b,...`): every
    /// job runs the ACF policy with the row's explicit selector, and
    /// `policies`/`include_shrinking` are ignored
    pub selectors: Vec<SelectorKind>,
    /// include the liblinear shrinking baseline (SVM only)
    pub include_shrinking: bool,
    /// worker threads
    pub workers: usize,
}

/// Build the concrete problem for a grid value, preserving the family.
fn with_parameter(p: Problem, v: f64) -> Problem {
    match p {
        Problem::Svm { .. } => Problem::Svm { c: v },
        Problem::SvmShrinking { .. } => Problem::SvmShrinking { c: v },
        Problem::Lasso { .. } => Problem::Lasso { lambda: v },
        Problem::LogReg { .. } => Problem::LogReg { c: v },
        Problem::McSvm { .. } => Problem::McSvm { c: v },
    }
}

/// Run the sweep; outcomes are ordered grid-major. On the policy axis
/// the minor order is `policies` (with the shrinking baseline appended
/// per grid point when requested); with `selectors` non-empty it is the
/// selector list, every job on the ACF policy.
pub fn run_sweep(spec: &SweepSpec) -> Result<Vec<JobOutcome>> {
    let base = spec.base.clone();
    let ds = base.load_dataset()?;
    let mut jobs: Vec<JobSpec> = Vec::new();
    for &v in &spec.grid {
        if spec.selectors.is_empty() {
            for &policy in &spec.policies {
                let mut j = base.clone();
                j.problem = with_parameter(base.problem, v);
                j.policy = policy;
                // A policy sweep compares the named policies, so a
                // selector override must not leak into the rows.
                j.selector = None;
                jobs.push(j);
            }
            if spec.include_shrinking {
                let mut j = base.clone();
                j.problem = Problem::SvmShrinking { c: v };
                j.policy = Policy::Permutation;
                j.selector = None;
                jobs.push(j);
            }
        } else {
            // selector axis: identical solver/policy configuration per
            // row, only the coordinate-selection rule varies
            for &kind in &spec.selectors {
                let mut j = base.clone();
                j.problem = with_parameter(base.problem, v);
                j.policy = Policy::Acf;
                j.selector = Some(kind);
                jobs.push(j);
            }
        }
    }
    // A sweep runs its jobs concurrently, so a shared `--trace-out`
    // path would be clobbered; each grid cell writes its own file
    // instead: `<stem>.<row>.jsonl`, row = grid-major outcome index.
    if let Some(base_path) = &base.trace_out {
        for (row, j) in jobs.iter_mut().enumerate() {
            j.trace_out = Some(per_row_trace_path(base_path, row));
        }
    }
    // Concurrent rows cannot share one listening socket either: every
    // row gets its own ephemeral-port server (port forced to 0, address
    // printed per row) and a `row` label so scrapes stay attributable
    // to a grid cell.
    if let Some(base_addr) = &base.metrics_addr {
        let addr = per_row_metrics_addr(base_addr);
        for (row, j) in jobs.iter_mut().enumerate() {
            j.metrics_addr = Some(addr.clone());
            j.metrics_labels.push(("row".to_string(), row.to_string()));
        }
    }
    parallel_map(jobs.len(), spec.workers, |k| run_job_on(&jobs[k], &ds))
        .into_iter()
        .collect()
}

/// Per-row trace destination: `<stem>.<row>.jsonl`, where `<stem>` is
/// the sweep's `--trace-out` value with one trailing `.jsonl` stripped
/// (`sweep.jsonl` → `sweep.0.jsonl`, `sweep.1.jsonl`, …).
fn per_row_trace_path(base: &str, row: usize) -> String {
    let stem = base.strip_suffix(".jsonl").unwrap_or(base);
    format!("{stem}.{row}.jsonl")
}

/// Per-row metrics address: the sweep's `--metrics-addr` host with the
/// port replaced by 0, so every row binds its own ephemeral port
/// (`127.0.0.1:9090` → `127.0.0.1:0`).
fn per_row_metrics_addr(base: &str) -> String {
    match base.rfind(':') {
        Some(i) => format!("{}:0", &base[..i]),
        None => format!("{base}:0"),
    }
}

/// k-fold cross-validation accuracy of a problem family at one parameter
/// point (used by Figure 2 / Table 9 to report CV performance next to
/// training times). Returns mean test accuracy across folds.
pub fn cross_validate(
    problem: Problem,
    dataset: &str,
    policy: Policy,
    eps: f64,
    scale: Scale,
    k: usize,
    seed: u64,
    workers: usize,
) -> Result<f64> {
    let template = {
        let mut t = JobSpec::new(problem, dataset, policy);
        t.eps = eps;
        t.scale = scale;
        t.seed = seed;
        t
    };
    let ds = template.load_dataset()?;
    let mut rng = Rng::new(seed ^ 0xF01D);
    let folds = data::k_fold(ds.n_instances(), k, &mut rng);
    let accs: Vec<f64> = parallel_map(folds.len(), workers, |fi| -> Result<f64> {
        let (train, test) = data::apply(&ds, &folds[fi]);
        let out = run_job_on(&template, &train)?;
        Ok(match (&out.w, &out.w_multi) {
            (Some(w), _) => data::binary_accuracy(&test, w),
            (_, Some(wm)) => data::multiclass_accuracy(&test, wm),
            _ => 0.0,
        })
    })
    .into_iter()
    .collect::<Result<_>>()?;
    Ok(accs.iter().sum::<f64>() / accs.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_produces_grid_times_policies() {
        let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        base.scale = Scale(0.04);
        let spec = SweepSpec {
            base,
            grid: vec![0.1, 1.0],
            policies: vec![Policy::Acf, Policy::Permutation],
            selectors: vec![],
            include_shrinking: true,
            workers: 4,
        };
        let out = run_sweep(&spec).unwrap();
        assert_eq!(out.len(), 2 * 3);
        // ordering: first grid point first
        assert_eq!(out[0].spec.problem.parameter(), 0.1);
        assert_eq!(out[2].spec.problem.family(), "svm-shrinking");
        assert!(out.iter().all(|o| o.result.status.converged()));
    }

    #[test]
    fn sweep_selector_axis_produces_grid_times_selectors() {
        let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        base.scale = Scale(0.04);
        let spec = SweepSpec {
            base,
            grid: vec![0.1, 1.0],
            // policies are ignored on the selector axis
            policies: vec![Policy::Permutation],
            selectors: vec![SelectorKind::Acf, SelectorKind::Uniform, SelectorKind::Cyclic],
            include_shrinking: false,
            workers: 4,
        };
        let out = run_sweep(&spec).unwrap();
        assert_eq!(out.len(), 2 * 3);
        // grid-major, selector-minor ordering; every row is ACF policy
        assert_eq!(out[0].spec.problem.parameter(), 0.1);
        assert_eq!(out[3].spec.problem.parameter(), 1.0);
        for (i, o) in out.iter().enumerate() {
            assert_eq!(o.spec.policy, Policy::Acf, "row {i}");
            assert!(o.result.status.converged(), "row {i}: {}", o.result.summary());
        }
        assert_eq!(out[1].spec.selector, Some(SelectorKind::Uniform));
        assert_eq!(out[5].spec.selector, Some(SelectorKind::Cyclic));
    }

    #[test]
    fn per_row_trace_paths_strip_one_jsonl_suffix() {
        assert_eq!(per_row_trace_path("sweep.jsonl", 0), "sweep.0.jsonl");
        assert_eq!(per_row_trace_path("sweep.jsonl", 12), "sweep.12.jsonl");
        assert_eq!(per_row_trace_path("runs/sweep", 3), "runs/sweep.3.jsonl");
    }

    #[test]
    fn per_row_metrics_addrs_force_an_ephemeral_port() {
        assert_eq!(per_row_metrics_addr("127.0.0.1:9090"), "127.0.0.1:0");
        assert_eq!(per_row_metrics_addr("0.0.0.0:0"), "0.0.0.0:0");
        assert_eq!(per_row_metrics_addr("localhost"), "localhost:0");
    }

    #[test]
    fn sweep_rows_get_labelled_ephemeral_metrics_servers() {
        let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        base.scale = Scale(0.04);
        base.metrics_addr = Some("127.0.0.1:9090".into());
        let spec = SweepSpec {
            base,
            grid: vec![1.0],
            policies: vec![Policy::Acf, Policy::Permutation],
            selectors: vec![],
            include_shrinking: false,
            workers: 2,
        };
        let out = run_sweep(&spec).unwrap();
        assert_eq!(out.len(), 2);
        for (row, o) in out.iter().enumerate() {
            assert_eq!(o.spec.metrics_addr.as_deref(), Some("127.0.0.1:0"), "row {row}");
            let label = ("row".to_string(), row.to_string());
            let labels = &o.spec.metrics_labels;
            assert!(labels.contains(&label), "row {row}: {labels:?}");
            assert!(o.result.status.converged(), "row {row}");
        }
    }

    #[test]
    fn sweep_writes_one_trace_file_per_grid_row() {
        use crate::obs::TraceLevel;
        use crate::util::json::{self, Json};
        let stem = std::env::temp_dir()
            .join(format!("acf_sweep_trace_{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        base.scale = Scale(0.04);
        base.trace_level = TraceLevel::Spans;
        base.trace_out = Some(format!("{stem}.jsonl"));
        let spec = SweepSpec {
            base,
            grid: vec![0.1, 1.0],
            policies: vec![Policy::Acf, Policy::Permutation],
            selectors: vec![],
            include_shrinking: false,
            workers: 2,
        };
        let out = run_sweep(&spec).unwrap();
        assert_eq!(out.len(), 4);
        for (row, o) in out.iter().enumerate() {
            // grid-major outcome index = trace-file index
            let path = format!("{stem}.{row}.jsonl");
            assert_eq!(o.spec.trace_out.as_deref(), Some(path.as_str()), "row {row}");
            let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
            let _ = std::fs::remove_file(&path);
            let head = json::parse(text.lines().next().expect("non-empty trace")).unwrap();
            assert_eq!(head.get("kind").and_then(Json::as_str), Some("meta"), "row {row}");
        }
        // the bare base path is never written — only the per-row files
        assert!(!std::path::Path::new(&format!("{stem}.jsonl")).exists());
    }

    #[test]
    fn cv_returns_sane_accuracy() {
        let acc = cross_validate(
            Problem::Svm { c: 1.0 },
            "rcv1-like",
            Policy::Acf,
            0.01,
            Scale(0.06),
            3,
            42,
            3,
        )
        .unwrap();
        assert!(acc > 0.55 && acc <= 1.0, "accuracy {acc}");
    }
}
