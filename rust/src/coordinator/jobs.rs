//! Job specifications — one job = one solver run on one dataset at one
//! hyper-parameter point with one scheduling policy.

use crate::acf::AcfParams;
use crate::anyhow;
use crate::data::{registry, DataBackend, Scale};
use crate::obs::live::LiveMetrics;
use crate::obs::server::MetricsServer;
use crate::obs::{self, Obs, TraceLevel};
use crate::sched::Policy;
use crate::select::{Selector, SelectorKind};
use crate::shard::{self, MergeMode, Partitioner, ShardSpec};
use crate::solvers::{self, SolveResult, SolverConfig};
use crate::sparse::{storage, Dataset};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Which of the paper's four problem families to solve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Problem {
    /// linear SVM dual; parameter = C
    Svm { c: f64 },
    /// liblinear baseline (permutation + shrinking); parameter = C
    SvmShrinking { c: f64 },
    /// LASSO; parameter = λ
    Lasso { lambda: f64 },
    /// dual logistic regression; parameter = C
    LogReg { c: f64 },
    /// Weston–Watkins multi-class SVM; parameter = C
    McSvm { c: f64 },
}

impl Problem {
    pub fn family(&self) -> &'static str {
        match self {
            Problem::Svm { .. } => "svm",
            Problem::SvmShrinking { .. } => "svm-shrinking",
            Problem::Lasso { .. } => "lasso",
            Problem::LogReg { .. } => "logreg",
            Problem::McSvm { .. } => "mcsvm",
        }
    }

    pub fn parameter(&self) -> f64 {
        match *self {
            Problem::Svm { c }
            | Problem::SvmShrinking { c }
            | Problem::LogReg { c }
            | Problem::McSvm { c } => c,
            Problem::Lasso { lambda } => lambda,
        }
    }
}

/// A fully-specified solver run.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub problem: Problem,
    pub dataset: String,
    pub policy: Policy,
    /// explicit coordinate selector (`--selector`): overrides `policy`
    /// for serial solver runs and picks the sharded engine's inner-loop
    /// policy; `None` keeps the policy-driven behavior (ACF jobs then
    /// run the ACF selector, bit-identical to the pre-subsystem path)
    pub selector: Option<SelectorKind>,
    pub eps: f64,
    pub seed: u64,
    pub scale: Scale,
    /// storage backend the training matrix is resolved into
    /// (`--data-backend`): heap-resident CSR (the default) or a
    /// read-only `.acfbin` mapping with bit-identical rows
    pub data_backend: DataBackend,
    pub max_iterations: u64,
    pub max_seconds: Option<f64>,
    pub acf_params: AcfParams,
    /// > 1 routes ACF-policy jobs of any of the four paper families
    /// through the sharded parallel engine ([`crate::shard`]); 0/1
    /// keeps the serial path.
    pub shards: usize,
    /// coordinate→shard assignment strategy for sharded runs
    pub partitioner: Partitioner,
    /// worker-thread cap for the sharded engine (0 = bounded by shard
    /// count and hardware parallelism)
    pub shard_workers: usize,
    /// use the asynchronous bounded-staleness merge instead of the
    /// epoch-synchronized (bit-deterministic) default
    pub async_merge: bool,
    /// staleness bound τ of the async merge: submissions (and their Δf
    /// reports to the outer ACF) lagging the published version by more
    /// than τ flips are discarded
    pub staleness_bound: u64,
    /// `--staleness-bound auto`: tune τ online from the observed
    /// stale-drop/reject rate, starting from `staleness_bound`
    pub staleness_auto: bool,
    /// observability verbosity (`--trace-level`); [`TraceLevel::Off`]
    /// (the default) keeps the run bit-identical to an uninstrumented
    /// build — no collector is even constructed
    pub trace_level: TraceLevel,
    /// JSONL trace destination (`--trace-out`); consumed by the `trace`
    /// subcommand. `None` discards the recorded stream after the run
    pub trace_out: Option<String>,
    /// `--metrics-addr <ip:port>`: serve live telemetry over HTTP for
    /// the duration of the run (`/metrics`, `/snapshot`, `/healthz` —
    /// see [`crate::obs::server`]). Port 0 binds an ephemeral port; the
    /// resolved address is printed to stderr. `None` (the default)
    /// constructs neither the registry nor the server, keeping the run
    /// bit-identical to an uninstrumented build.
    pub metrics_addr: Option<String>,
    /// extra `name=value` labels stamped on every exported series
    /// (sweeps use this to tag per-row servers with the grid row)
    pub metrics_labels: Vec<(String, String)>,
}

impl JobSpec {
    pub fn new(problem: Problem, dataset: &str, policy: Policy) -> Self {
        Self {
            problem,
            dataset: dataset.to_string(),
            policy,
            selector: None,
            eps: 0.01,
            seed: 20140103,
            scale: Scale::default(),
            data_backend: DataBackend::default(),
            max_iterations: 200_000_000,
            max_seconds: None,
            acf_params: AcfParams::default(),
            shards: 0,
            partitioner: Partitioner::Contiguous,
            shard_workers: 0,
            async_merge: false,
            staleness_bound: shard::DEFAULT_STALENESS_BOUND,
            staleness_auto: false,
            trace_level: TraceLevel::Off,
            trace_out: None,
            metrics_addr: None,
            metrics_labels: Vec::new(),
        }
    }

    /// The coordinate selector driving a serial solver run: the
    /// explicit `--selector` choice when present, the named policy
    /// otherwise. With an events-level collector the policy is wrapped
    /// in [`obs::ObservedSelector`], which forwards every call
    /// unchanged while recording periodic distribution probes.
    fn build_selector(&self, n: usize, rng: Rng, obs: Option<&Arc<Obs>>) -> Box<dyn Selector> {
        let inner = match self.selector {
            Some(kind) => kind.build(n, self.acf_params, rng),
            None => self.policy.build(n, self.acf_params, rng),
        };
        match obs {
            Some(o) if o.level() >= TraceLevel::Events => Box::new(obs::ObservedSelector::new(
                inner,
                Arc::clone(o),
                0,
                obs::NO_SHARD,
            )),
            _ => inner,
        }
    }

    /// Sharded-engine configuration derived from this job.
    fn shard_spec(&self, obs: Option<&Arc<Obs>>, live: Option<&Arc<LiveMetrics>>) -> ShardSpec {
        let mut spec = ShardSpec::new(self.shards);
        spec.partitioner = self.partitioner;
        spec.inner_selector = self.selector.unwrap_or(SelectorKind::Acf);
        spec.seed = self.seed ^ 0x5EED;
        spec.inner_params = self.acf_params;
        spec.outer_params = self.acf_params;
        spec.workers = self.shard_workers;
        if self.async_merge {
            spec.merge =
                MergeMode::Async { staleness_bound: self.staleness_bound, adaptive: self.staleness_auto };
        }
        spec.config = self.solver_config();
        if let Some(o) = obs {
            spec = spec.with_obs(Arc::clone(o));
        }
        if let Some(l) = live {
            spec = spec.with_live(Arc::clone(l));
        }
        spec
    }

    /// The observability collector for this job, sized to the execution
    /// path: `shards + 1` rings for the parallel engine (ring *k* per
    /// shard plus the driver ring), a single ring for serial runs.
    /// `None` at `--trace-level off` — the solvers then run with the
    /// zero-cost disabled emitters.
    fn build_obs(&self) -> Option<Arc<Obs>> {
        if self.trace_level == TraceLevel::Off {
            return None;
        }
        let rings = if self.uses_sharded_engine() { self.shards + 1 } else { 1 };
        Some(Arc::new(Obs::new(self.trace_level, rings, obs::DEFAULT_RING_CAP)))
    }

    /// The live telemetry registry for this job, labelled with the job
    /// identity plus any [`JobSpec::metrics_labels`]. `None` when no
    /// `--metrics-addr` is configured — the solvers and engine then
    /// skip every recording branch (no registry is even allocated).
    fn build_live(&self) -> Option<Arc<LiveMetrics>> {
        self.metrics_addr.as_ref()?;
        let mut labels = vec![
            ("problem".to_string(), self.problem.family().to_string()),
            ("dataset".to_string(), self.dataset.clone()),
            ("policy".to_string(), self.policy.name().to_string()),
        ];
        labels.extend(self.metrics_labels.iter().cloned());
        Some(Arc::new(LiveMetrics::new(labels)))
    }

    /// Whether this job routes through the sharded parallel engine.
    ///
    /// Only the ACF policy has a sharded execution (the engine *is*
    /// hierarchical ACF); every other policy keeps its serial semantics
    /// so policy-comparison sweeps stay meaningful with `--shards` set,
    /// and `Policy::Hierarchical` keeps the serial two-level scheduler
    /// it names. All four paper families have shard-aware train loops
    /// (SVM/LASSO/logreg/mcsvm); the shrinking baseline stays serial —
    /// its active-set heuristic owns the iteration order.
    pub fn uses_sharded_engine(&self) -> bool {
        self.shards > 1
            && self.policy == Policy::Acf
            && !matches!(self.problem, Problem::SvmShrinking { .. })
    }

    pub fn solver_config(&self) -> SolverConfig {
        SolverConfig {
            eps: self.eps,
            max_iterations: self.max_iterations,
            max_seconds: self.max_seconds,
            trace_every: 0,
            ..SolverConfig::default()
        }
    }

    /// Resolve the dataset for this job. A name ending in `.acfbin` is
    /// opened as a file produced by `acf-cd ingest` (already mapped —
    /// the backend flag is moot); anything else hits the synthetic
    /// registry. With [`DataBackend::Mmap`] a registry dataset is
    /// round-tripped through a temporary `.acfbin` file and served
    /// from a read-only mapping ([`storage::remap_dataset`]): the rows
    /// are bit-identical, but the matrix lives in the page cache
    /// instead of the heap.
    pub fn load_dataset(&self) -> Result<Dataset> {
        if self.dataset.ends_with(".acfbin") {
            return storage::open_dataset(std::path::Path::new(&self.dataset));
        }
        let ds = match self.problem {
            Problem::Lasso { .. } => {
                registry::regression(&self.dataset, self.scale, self.seed).map(|(ds, _)| ds)
            }
            Problem::McSvm { .. } => registry::multiclass(&self.dataset, self.scale, self.seed),
            _ => registry::binary(&self.dataset, self.scale, self.seed),
        };
        let ds = ds.ok_or_else(|| {
            anyhow!("unknown dataset '{}' for problem family {}", self.dataset, self.problem.family())
        })?;
        match self.data_backend {
            DataBackend::Owned => Ok(ds),
            DataBackend::Mmap => storage::remap_dataset(&ds),
        }
    }
}

/// Bounded summary of a selector's final adaptive state, reduced from
/// [`Selector::snapshot`] at capture time so job outcomes never retain
/// the O(n) probability vector (sweeps hold every outcome until the
/// report is written).
#[derive(Clone, Copy, Debug)]
pub struct SelectorStateSummary {
    pub name: &'static str,
    pub n: usize,
    /// smallest / largest selection probability (floor vs concentration)
    pub p_min: f64,
    pub p_max: f64,
    /// Shannon entropy of the distribution (nats; ln n = uniform)
    pub entropy: f64,
    /// coordinate holding `p_max`
    pub top_coordinate: usize,
}

impl SelectorStateSummary {
    fn from_selector(sel: &dyn Selector) -> SelectorStateSummary {
        let snap = sel.snapshot();
        let p = &snap.probabilities;
        let p_min = p.iter().cloned().fold(f64::INFINITY, f64::min);
        let (top_coordinate, p_max) = p
            .iter()
            .cloned()
            .enumerate()
            .fold((0usize, 0.0f64), |acc, (i, x)| if x > acc.1 { (i, x) } else { acc });
        let entropy: f64 = -p.iter().filter(|&&x| x > 0.0).map(|&x| x * x.ln()).sum::<f64>();
        SelectorStateSummary { name: snap.name, n: snap.n, p_min, p_max, entropy, top_coordinate }
    }
}

/// Outcome of a job, with the trained model's primal weights when the
/// problem has a single weight vector (binary problems / LASSO).
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub spec: JobSpec,
    pub result: SolveResult,
    /// primal weights (binary/lasso) — used for accuracy evaluation
    pub w: Option<Vec<f64>>,
    /// per-class weights (multi-class)
    pub w_multi: Option<Vec<Vec<f64>>>,
    /// non-zero coefficient count (LASSO sparsity report)
    pub nnz_coeffs: Option<usize>,
    /// sharded runs: merge-layer accounting, incl. where an adaptive τ
    /// landed (`staleness_bound_final`)
    pub merge_stats: Option<shard::MergeStats>,
    /// sharded async runs: staleness-bound discards
    pub stale_drops: Option<u64>,
    /// serial runs: the coordinate selector's final state, summarized
    /// (sharded runs report the outer shard distribution instead)
    pub selector_state: Option<SelectorStateSummary>,
}

impl JobOutcome {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("problem", Json::Str(self.spec.problem.family().into()))
            .set("parameter", Json::Num(self.spec.problem.parameter()))
            .set("dataset", Json::Str(self.spec.dataset.clone()))
            .set("policy", Json::Str(self.spec.policy.name().into()))
            .set("eps", Json::Num(self.spec.eps))
            .set(
                "selector",
                match self.spec.selector {
                    Some(k) => Json::Str(k.name().into()),
                    None => Json::Null,
                },
            )
            .set("data_backend", Json::Str(self.spec.data_backend.name().into()))
            .set("converged", Json::Bool(self.result.status.converged()))
            .set("iterations", Json::Num(self.result.iterations as f64))
            .set("ops", Json::Num(self.result.ops as f64))
            .set("seconds", Json::Num(self.result.seconds))
            .set("objective", Json::Num(self.result.objective))
            .set("violation", Json::Num(self.result.final_violation));
        if let Some(k) = self.nnz_coeffs {
            o.set("nnz_coeffs", Json::Num(k as f64));
        }
        if let Some(ss) = &self.selector_state {
            // already reduced at capture time — reports stay bounded
            let mut sel = Json::obj();
            sel.set("name", Json::Str(ss.name.into()))
                .set("n", Json::Num(ss.n as f64))
                .set("p_min", Json::Num(ss.p_min))
                .set("p_max", Json::Num(ss.p_max))
                .set("entropy", Json::Num(ss.entropy))
                .set("top_coordinate", Json::Num(ss.top_coordinate as f64));
            o.set("selector_state", sel);
        }
        if self.spec.uses_sharded_engine() {
            o.set("shards", Json::Num(self.spec.shards as f64))
                .set("partitioner", Json::Str(self.spec.partitioner.name().into()))
                .set(
                    "merge",
                    Json::Str(if self.spec.async_merge { "async" } else { "sync" }.into()),
                );
            if self.spec.async_merge {
                o.set("staleness_bound", Json::Num(self.spec.staleness_bound as f64))
                    .set("staleness_auto", Json::Bool(self.spec.staleness_auto));
            }
            if let Some(ms) = self.merge_stats {
                o.set("objective_evals", Json::Num(ms.objective_evals as f64))
                    .set("accepted_submissions", Json::Num(ms.accepted_submissions as f64))
                    .set("rejected_submissions", Json::Num(ms.rejected_submissions as f64))
                    .set("batched_merges", Json::Num(ms.batched_merges as f64));
                if self.spec.async_merge {
                    // where the (possibly adaptive) τ ended up
                    o.set("staleness_bound_final", Json::Num(ms.staleness_bound_final as f64));
                }
                // nested mirror of the flat keys above (those stay for
                // downstream compat) plus derived rates
                let decided = ms.accepted_submissions + ms.rejected_submissions;
                let acceptance_rate =
                    if decided == 0 { 1.0 } else { ms.accepted_submissions as f64 / decided as f64 };
                let evals_per_accepted = if ms.accepted_submissions == 0 {
                    0.0
                } else {
                    ms.objective_evals as f64 / ms.accepted_submissions as f64
                };
                let mut m = Json::obj();
                m.set("objective_evals", Json::Num(ms.objective_evals as f64))
                    .set("accepted_submissions", Json::Num(ms.accepted_submissions as f64))
                    .set("rejected_submissions", Json::Num(ms.rejected_submissions as f64))
                    .set("batched_merges", Json::Num(ms.batched_merges as f64))
                    .set("acceptance_rate", Json::Num(acceptance_rate))
                    .set("objective_evals_per_accepted", Json::Num(evals_per_accepted));
                if self.spec.async_merge {
                    m.set("staleness_bound_final", Json::Num(ms.staleness_bound_final as f64));
                    if let Some(d) = self.stale_drops {
                        m.set("stale_drops", Json::Num(d as f64));
                    }
                }
                o.set("merge_stats", m);
            }
            if let Some(d) = self.stale_drops {
                o.set("stale_drops", Json::Num(d as f64));
            }
        }
        if self.spec.trace_level != TraceLevel::Off {
            o.set("trace_level", Json::Str(self.spec.trace_level.name().into()));
            if let Some(p) = &self.spec.trace_out {
                o.set("trace_out", Json::Str(p.clone()));
            }
        }
        if let Some(addr) = &self.spec.metrics_addr {
            o.set("metrics_addr", Json::Str(addr.clone()));
        }
        o
    }
}

/// Execute a job on an already-loaded dataset (lets sweeps share the
/// dataset across grid points). Fallible since the sharded engine
/// surfaces worker failures as
/// [`crate::util::error::ErrorKind::ShardWorker`] errors.
///
/// When the spec asks for tracing (`trace_level` above `off`) a
/// collector is attached to the run — sharded engine rings or the
/// serial [`obs::ObservedSelector`] wrapper — and drained into the
/// `trace_out` JSONL file afterwards. Recording never perturbs
/// results (see [`crate::obs`]); `off` skips the collector entirely.
pub fn run_job_on(spec: &JobSpec, ds: &Dataset) -> Result<JobOutcome> {
    let live = spec.build_live();
    let mut server = match (&spec.metrics_addr, &live) {
        (Some(addr), Some(l)) => {
            let srv = MetricsServer::start(addr, Arc::clone(l))?;
            eprintln!("metrics: listening on http://{}", srv.local_addr());
            Some(srv)
        }
        _ => None,
    };
    let outcome = run_job_with_live(spec, ds, live);
    if let Some(srv) = server.as_mut() {
        srv.stop();
    }
    outcome
}

/// [`run_job_on`] with a caller-supplied live registry — lets embedders
/// (and the telemetry tests) scrape a run they drive themselves without
/// going through the `--metrics-addr` server lifecycle. `None` behaves
/// exactly like a run without telemetry attached.
pub fn run_job_with_live(
    spec: &JobSpec,
    ds: &Dataset,
    live: Option<Arc<LiveMetrics>>,
) -> Result<JobOutcome> {
    let obs = spec.build_obs();
    let outcome = run_job_inner(spec, ds, obs.as_ref(), live.as_ref())?;
    if let Some(o) = &obs {
        write_job_trace(spec, &outcome, o)?;
    }
    Ok(outcome)
}

fn run_job_inner(
    spec: &JobSpec,
    ds: &Dataset,
    obs: Option<&Arc<Obs>>,
    live: Option<&Arc<LiveMetrics>>,
) -> Result<JobOutcome> {
    let mut cfg = spec.solver_config();
    cfg.obs = obs.cloned();
    cfg.live = live.cloned();
    let rng = Rng::new(spec.seed ^ 0x5EED);
    // Sharded engine path (ACF policy on any of the four paper families
    // — see `JobSpec::uses_sharded_engine`); everything else falls
    // through to the serial solvers.
    if spec.uses_sharded_engine() {
        // run through the prepared-problem entry points so the full
        // ShardedOutcome (merge stats, stale drops, adapted τ) reaches
        // the job report instead of being dropped by the model wrappers
        match spec.problem {
            Problem::Svm { c } => {
                let problem = shard::svm::ShardedSvm::new(ds, c);
                let out = shard::svm::run_prepared(&problem, spec.shard_spec(obs, live))?;
                return Ok(JobOutcome {
                    spec: spec.clone(),
                    result: out.result,
                    w: Some(out.shared),
                    w_multi: None,
                    nnz_coeffs: None,
                    merge_stats: Some(out.merge_stats),
                    stale_drops: Some(out.stale_drops),
                    selector_state: None,
                });
            }
            Problem::Lasso { lambda } => {
                let problem = shard::lasso::ShardedLasso::new(ds, lambda);
                let out = shard::lasso::run_prepared(&problem, spec.shard_spec(obs, live))?;
                let model = solvers::lasso::LassoModel { w: out.values, lambda };
                let k = solvers::lasso::nnz_coefficients(&model);
                return Ok(JobOutcome {
                    spec: spec.clone(),
                    result: out.result,
                    w: Some(model.w),
                    w_multi: None,
                    nnz_coeffs: Some(k),
                    merge_stats: Some(out.merge_stats),
                    stale_drops: Some(out.stale_drops),
                    selector_state: None,
                });
            }
            Problem::LogReg { c } => {
                let problem = shard::logreg::ShardedLogReg::new(ds, c);
                let out = shard::logreg::run_prepared(&problem, spec.shard_spec(obs, live))?;
                return Ok(JobOutcome {
                    spec: spec.clone(),
                    result: out.result,
                    w: Some(out.shared),
                    w_multi: None,
                    nnz_coeffs: None,
                    merge_stats: Some(out.merge_stats),
                    stale_drops: Some(out.stale_drops),
                    selector_state: None,
                });
            }
            Problem::McSvm { c } => {
                let problem = shard::mcsvm::ShardedMcSvm::new(ds, c, spec.eps)?;
                let out = shard::mcsvm::run_prepared(&problem, spec.shard_spec(obs, live))?;
                let w_multi = problem.unflatten_weights(&out.shared);
                return Ok(JobOutcome {
                    spec: spec.clone(),
                    result: out.result,
                    w: None,
                    w_multi: Some(w_multi),
                    nnz_coeffs: None,
                    merge_stats: Some(out.merge_stats),
                    stale_drops: Some(out.stale_drops),
                    selector_state: None,
                });
            }
            Problem::SvmShrinking { .. } => {
                unreachable!("uses_sharded_engine excludes the shrinking baseline")
            }
        }
    } else if spec.shards > 1 && !matches!(spec.policy, Policy::Hierarchical { .. }) {
        // (Policy::Hierarchical consumes --shards itself, serially.)
        eprintln!(
            "note: --shards engages the parallel engine only with --policy acf; \
             running {} with the serial {} policy",
            spec.problem.family(),
            spec.policy.name()
        );
    }
    // Reaching here means the sharded branch above did not engage (it
    // returns early), so an async-merge request is necessarily inert.
    if spec.async_merge {
        eprintln!(
            "note: --async-merge applies only to the sharded engine (--shards > 1 with \
             --policy acf); this run is serial, the flag has no effect"
        );
    }
    Ok(match spec.problem {
        Problem::Svm { c } => {
            let mut sched = spec.build_selector(ds.n_instances(), rng, obs);
            let (model, result) = solvers::svm::solve(ds, c, sched.as_mut(), cfg);
            JobOutcome {
                spec: spec.clone(),
                result,
                w: Some(model.w),
                w_multi: None,
                nnz_coeffs: None,
                merge_stats: None,
                stale_drops: None,
                selector_state: Some(SelectorStateSummary::from_selector(sched.as_ref())),
            }
        }
        Problem::SvmShrinking { c } => {
            // the shrinking baseline never consults a selector; normalize
            // the reported spec so the JSON cannot claim one was used
            // (the CLI rejects the combination outright — this guards
            // programmatic callers)
            let mut spec_out = spec.clone();
            if spec_out.selector.take().is_some() {
                eprintln!(
                    "note: selector ignored for svm-shrinking (the shrinking heuristic \
                     owns its permutation order)"
                );
            }
            let mut rng = rng;
            let (model, result) = solvers::svm::solve_liblinear_shrinking(ds, c, &mut rng, cfg);
            JobOutcome {
                spec: spec_out,
                result,
                w: Some(model.w),
                w_multi: None,
                nnz_coeffs: None,
                merge_stats: None,
                stale_drops: None,
                selector_state: None,
            }
        }
        Problem::Lasso { lambda } => {
            let mut sched = spec.build_selector(ds.n_features(), rng, obs);
            let (model, result) = solvers::lasso::solve(ds, lambda, sched.as_mut(), cfg);
            let k = solvers::lasso::nnz_coefficients(&model);
            JobOutcome {
                spec: spec.clone(),
                result,
                w: Some(model.w),
                w_multi: None,
                nnz_coeffs: Some(k),
                merge_stats: None,
                stale_drops: None,
                selector_state: Some(SelectorStateSummary::from_selector(sched.as_ref())),
            }
        }
        Problem::LogReg { c } => {
            let mut sched = spec.build_selector(ds.n_instances(), rng, obs);
            let (model, result) = solvers::logreg::solve(ds, c, sched.as_mut(), cfg);
            JobOutcome {
                spec: spec.clone(),
                result,
                w: Some(model.w),
                w_multi: None,
                nnz_coeffs: None,
                merge_stats: None,
                stale_drops: None,
                selector_state: Some(SelectorStateSummary::from_selector(sched.as_ref())),
            }
        }
        Problem::McSvm { c } => {
            let mut sched = spec.build_selector(ds.n_instances(), rng, obs);
            let (model, result) = solvers::mcsvm::solve(ds, c, sched.as_mut(), cfg)?;
            JobOutcome {
                spec: spec.clone(),
                result,
                w: None,
                w_multi: Some(model.w),
                nnz_coeffs: None,
                merge_stats: None,
                stale_drops: None,
                selector_state: Some(SelectorStateSummary::from_selector(sched.as_ref())),
            }
        }
    })
}

/// Drain the job's collector into the `--trace-out` JSONL file: a meta
/// line (run identity + stream accounting), the raw event lines at
/// `spans`/`events` level, 1-second [`obs::MetricsSnapshot`] windows,
/// and a summary line mirroring the headline result fields. Without
/// `trace_out` the recorded stream is simply discarded.
fn write_job_trace(spec: &JobSpec, outcome: &JobOutcome, obs: &Obs) -> Result<()> {
    let Some(path) = &spec.trace_out else { return Ok(()) };
    let data = obs.drain();
    let n_shards = if spec.uses_sharded_engine() { spec.shards } else { 0 };
    let snapshots = obs::window_snapshots(&data.events, n_shards, 1.0);
    let mut meta = Json::obj();
    meta.set("problem", Json::Str(spec.problem.family().into()))
        .set("parameter", Json::Num(spec.problem.parameter()))
        .set("dataset", Json::Str(spec.dataset.clone()))
        .set("policy", Json::Str(spec.policy.name().into()))
        .set("shards", Json::Num(n_shards as f64))
        .set("merge", Json::Str(if spec.async_merge { "async" } else { "sync" }.into()));
    let mut summary = Json::obj();
    summary
        .set("converged", Json::Bool(outcome.result.status.converged()))
        .set("iterations", Json::Num(outcome.result.iterations as f64))
        .set("ops", Json::Num(outcome.result.ops as f64))
        .set("seconds", Json::Num(outcome.result.seconds))
        .set("objective", Json::Num(outcome.result.objective));
    if let Some(ms) = outcome.merge_stats {
        summary
            .set("accepted_submissions", Json::Num(ms.accepted_submissions as f64))
            .set("rejected_submissions", Json::Num(ms.rejected_submissions as f64))
            .set("objective_evals", Json::Num(ms.objective_evals as f64));
    }
    let text = obs::sink::render_trace(spec.trace_level, &meta, &data, &snapshots, &summary);
    obs::sink::write_trace(path, &text)
}

/// Load the dataset and execute.
pub fn run_job(spec: &JobSpec) -> Result<JobOutcome> {
    let ds = spec.load_dataset()?;
    run_job_on(spec, &ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec(problem: Problem, dataset: &str, policy: Policy) -> JobSpec {
        let mut s = JobSpec::new(problem, dataset, policy);
        s.scale = Scale(0.05);
        s.eps = 0.01;
        s
    }

    #[test]
    fn svm_job_runs() {
        let spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged());
        assert!(out.w.is_some());
        let j = out.to_json();
        assert_eq!(j.get("policy").unwrap().as_str(), Some("acf"));
    }

    #[test]
    fn lasso_job_reports_sparsity() {
        let spec = quick_spec(Problem::Lasso { lambda: 0.01 }, "rcv1-like", Policy::Cyclic);
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged());
        assert!(out.nnz_coeffs.is_some());
    }

    #[test]
    fn shrinking_job_runs() {
        let spec =
            quick_spec(Problem::SvmShrinking { c: 1.0 }, "rcv1-like", Policy::Permutation);
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged());
    }

    #[test]
    fn mcsvm_job_runs() {
        let spec = quick_spec(Problem::McSvm { c: 1.0 }, "iris-like", Policy::Acf);
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged());
        assert!(out.w_multi.is_some());
    }

    #[test]
    fn mmap_backend_is_bit_identical_to_owned() {
        // serial and sharded-sync: the mapped matrix must reproduce the
        // owned run bit-for-bit (same rows ⇒ same arithmetic ⇒ same
        // trajectory)
        for shards in [0usize, 4] {
            let mut owned = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
            owned.shards = shards;
            let mut mapped = owned.clone();
            mapped.data_backend = DataBackend::Mmap;
            let a = run_job(&owned).unwrap();
            let b = run_job(&mapped).unwrap();
            assert_eq!(a.result.iterations, b.result.iterations, "shards={shards}");
            assert_eq!(a.result.objective.to_bits(), b.result.objective.to_bits(), "shards={shards}");
            assert_eq!(a.w, b.w, "shards={shards}");
            assert_eq!(b.to_json().get("data_backend").unwrap().as_str(), Some("mmap"));
            assert_eq!(a.to_json().get("data_backend").unwrap().as_str(), Some("owned"));
        }
    }

    #[test]
    fn acfbin_path_dataset_trains() {
        // the output of `acf-cd ingest` is directly trainable: a dataset
        // name ending in .acfbin bypasses the registry
        let ds = crate::data::binary("rcv1-like", Scale(0.05), 20140103).unwrap();
        let path = std::env::temp_dir().join(format!("acf_job_ds_{}.acfbin", std::process::id()));
        storage::write_dataset(&ds, &path).unwrap();
        let spec = quick_spec(Problem::Svm { c: 1.0 }, path.to_str().unwrap(), Policy::Acf);
        let out = run_job(&spec);
        let _ = std::fs::remove_file(&path);
        let out = out.unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
    }

    #[test]
    fn unknown_dataset_errors() {
        let spec = quick_spec(Problem::Svm { c: 1.0 }, "nonexistent", Policy::Acf);
        assert!(run_job(&spec).is_err());
    }

    #[test]
    fn sharded_svm_job_matches_serial() {
        let serial = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        let mut sharded = serial.clone();
        sharded.shards = 4;
        let a = run_job(&serial).unwrap();
        let b = run_job(&sharded).unwrap();
        assert!(a.result.status.converged() && b.result.status.converged());
        let rel = (a.result.objective - b.result.objective).abs() / a.result.objective.abs().max(1.0);
        assert!(rel < 1e-2, "{} vs {}", a.result.objective, b.result.objective);
        let j = b.to_json();
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("partitioner").unwrap().as_str(), Some("contiguous"));
        assert_eq!(j.get("merge").unwrap().as_str(), Some("sync"));
    }

    #[test]
    fn async_sharded_job_runs_and_reports_merge_mode() {
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        spec.shards = 4;
        spec.async_merge = true;
        spec.staleness_bound = 3;
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let j = out.to_json();
        assert_eq!(j.get("merge").unwrap().as_str(), Some("async"));
        assert_eq!(j.get("staleness_bound").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("staleness_auto").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn async_sharded_job_with_adaptive_tau_runs() {
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        spec.shards = 4;
        spec.async_merge = true;
        spec.staleness_auto = true;
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let j = out.to_json();
        assert_eq!(j.get("staleness_auto").unwrap().as_bool(), Some(true));
        // the adapted τ is observable from the job report
        let tau = j.get("staleness_bound_final").unwrap().as_usize().unwrap();
        assert!(tau >= 1, "adapted τ must stay positive, got {tau}");
        assert!(j.get("objective_evals").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn explicit_acf_selector_is_bit_identical_to_policy_path() {
        // The adapter contract at the job level: `--selector acf` must
        // reproduce the policy-driven (pre-subsystem) run bit-for-bit.
        let base = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        let mut explicit = base.clone();
        explicit.selector = Some(SelectorKind::Acf);
        let a = run_job(&base).unwrap();
        let b = run_job(&explicit).unwrap();
        assert_eq!(a.result.iterations, b.result.iterations);
        assert_eq!(a.result.ops, b.result.ops);
        assert_eq!(a.result.objective, b.result.objective);
        assert_eq!(a.w, b.w);
        let j = b.to_json();
        assert_eq!(j.get("selector").unwrap().as_str(), Some("acf"));
    }

    #[test]
    fn every_selector_kind_runs_each_serial_family() {
        for kind in SelectorKind::all() {
            for (problem, ds) in [
                (Problem::Svm { c: 1.0 }, "rcv1-like"),
                (Problem::Lasso { lambda: 0.01 }, "rcv1-like"),
                (Problem::LogReg { c: 1.0 }, "rcv1-like"),
                (Problem::McSvm { c: 1.0 }, "iris-like"),
            ] {
                let mut spec = quick_spec(problem, ds, Policy::Acf);
                spec.selector = Some(kind);
                let out = run_job(&spec).unwrap();
                assert!(
                    out.result.status.converged(),
                    "{} with selector {}: {}",
                    problem.family(),
                    kind.name(),
                    out.result.summary()
                );
            }
        }
    }

    #[test]
    fn shrinking_job_normalizes_an_inapplicable_selector() {
        // the shrinking baseline cannot honor a selector; the reported
        // spec must not claim one was used
        let mut spec =
            quick_spec(Problem::SvmShrinking { c: 1.0 }, "rcv1-like", Policy::Permutation);
        spec.selector = Some(SelectorKind::Bandit);
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged());
        assert!(out.spec.selector.is_none());
        assert!(out.to_json().get("selector").unwrap().as_str().is_none());
    }

    #[test]
    fn sharded_logreg_job_matches_serial() {
        let serial = quick_spec(Problem::LogReg { c: 1.0 }, "rcv1-like", Policy::Acf);
        let mut sharded = serial.clone();
        sharded.shards = 4;
        assert!(sharded.uses_sharded_engine());
        let a = run_job(&serial).unwrap();
        let b = run_job(&sharded).unwrap();
        assert!(a.result.status.converged() && b.result.status.converged());
        let rel = (a.result.objective - b.result.objective).abs() / a.result.objective.abs().max(1.0);
        assert!(rel < 1e-2, "{} vs {}", a.result.objective, b.result.objective);
        let j = b.to_json();
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("merge").unwrap().as_str(), Some("sync"));
    }

    #[test]
    fn sharded_mcsvm_job_matches_serial() {
        let serial = quick_spec(Problem::McSvm { c: 1.0 }, "iris-like", Policy::Acf);
        let mut sharded = serial.clone();
        sharded.shards = 2;
        assert!(sharded.uses_sharded_engine());
        let a = run_job(&serial).unwrap();
        let b = run_job(&sharded).unwrap();
        assert!(a.result.status.converged() && b.result.status.converged());
        let rel = (a.result.objective - b.result.objective).abs() / a.result.objective.abs().max(1.0);
        assert!(rel < 1e-2, "{} vs {}", a.result.objective, b.result.objective);
        // per-class weights reach the report for accuracy evaluation
        assert!(b.w_multi.is_some());
        assert_eq!(b.to_json().get("merge").unwrap().as_str(), Some("sync"));
    }

    #[test]
    fn serial_jobs_report_selector_state() {
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        spec.selector = Some(SelectorKind::Importance);
        let out = run_job(&spec).unwrap();
        let ss = out.selector_state.as_ref().expect("serial runs snapshot their selector");
        assert_eq!(ss.name, "importance");
        // a valid distribution: floor ≤ peak, entropy within [0, ln n]
        assert!(ss.p_min > 0.0 && ss.p_min <= ss.p_max && ss.p_max <= 1.0, "{ss:?}");
        assert!(ss.entropy >= 0.0 && ss.entropy <= (ss.n as f64).ln() + 1e-9, "{ss:?}");
        assert!(ss.top_coordinate < ss.n);
        let j = out.to_json();
        let sel = j.get("selector_state").expect("selector_state in JSON");
        assert_eq!(sel.get("name").unwrap().as_str(), Some("importance"));
        assert_eq!(sel.get("n").unwrap().as_usize(), Some(ss.n));
        assert!(sel.get("entropy").unwrap().as_f64().unwrap() >= 0.0);
        assert!(sel.get("p_max").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn selector_threads_into_sharded_inner_loops() {
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        spec.shards = 4;
        spec.selector = Some(SelectorKind::Cyclic);
        assert!(spec.uses_sharded_engine());
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        assert_eq!(out.to_json().get("selector").unwrap().as_str(), Some("cyclic"));
    }

    #[test]
    fn hierarchical_policy_job_runs() {
        let policy = Policy::parse("hier").unwrap();
        let spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", policy);
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
    }

    #[test]
    fn sharded_job_json_nests_merge_stats_with_derived_rates() {
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        spec.shards = 4;
        spec.async_merge = true;
        spec.staleness_bound = 3;
        let out = run_job(&spec).unwrap();
        let j = out.to_json();
        let m = j.get("merge_stats").expect("nested merge_stats object");
        // nested keys mirror the flat ones bit-for-bit
        for key in ["objective_evals", "accepted_submissions", "rejected_submissions", "batched_merges"] {
            assert_eq!(
                m.get(key).unwrap().as_f64(),
                j.get(key).unwrap().as_f64(),
                "flat/nested mismatch for {key}"
            );
        }
        let accepted = m.get("accepted_submissions").unwrap().as_f64().unwrap();
        let rejected = m.get("rejected_submissions").unwrap().as_f64().unwrap();
        let rate = m.get("acceptance_rate").unwrap().as_f64().unwrap();
        if accepted + rejected > 0.0 {
            assert!((rate - accepted / (accepted + rejected)).abs() < 1e-12, "rate {rate}");
        } else {
            assert_eq!(rate, 1.0);
        }
        let epa = m.get("objective_evals_per_accepted").unwrap().as_f64().unwrap();
        assert!(epa >= 0.0 && epa.is_finite());
        // async runs fold the staleness accounting into the object too
        assert!(m.get("staleness_bound_final").is_some());
        assert!(m.get("stale_drops").is_some());
        // untraced specs must not claim a trace in the report
        assert!(j.get("trace_level").is_none());
    }

    #[test]
    fn traced_job_is_bit_identical_to_untraced() {
        let plain = {
            let mut s = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
            s.shards = 4;
            s
        };
        let mut traced = plain.clone();
        traced.trace_level = TraceLevel::Events;
        let a = run_job(&plain).unwrap();
        let b = run_job(&traced).unwrap();
        assert_eq!(a.result.iterations, b.result.iterations);
        assert_eq!(a.result.ops, b.result.ops);
        assert_eq!(a.result.objective.to_bits(), b.result.objective.to_bits());
        assert_eq!(a.w, b.w);
        let j = b.to_json();
        assert_eq!(j.get("trace_level").unwrap().as_str(), Some("events"));
        // live-telemetry leg: attaching a registry (the `--metrics-addr`
        // data path, minus the HTTP server) must not perturb the
        // trajectory either, and the registry's final point must agree
        // with the run's own accounting
        let ds = plain.load_dataset().unwrap();
        let live = Arc::new(LiveMetrics::new(Vec::new()));
        let c = run_job_with_live(&plain, &ds, Some(Arc::clone(&live))).unwrap();
        assert_eq!(a.result.iterations, c.result.iterations);
        assert_eq!(a.result.ops, c.result.ops);
        assert_eq!(a.result.objective.to_bits(), c.result.objective.to_bits());
        assert_eq!(a.w, c.w);
        let point = live.latest();
        assert_eq!(point.snapshot.last_objective, Some(c.result.objective));
        let steps: u64 = point.snapshot.per_shard.iter().map(|s| s.steps).sum();
        assert_eq!(steps, c.result.iterations);
        assert_eq!(point.merge_stats, c.merge_stats.unwrap());
    }

    #[test]
    fn live_registry_on_a_serial_job_tracks_the_objective() {
        let spec = quick_spec(Problem::Lasso { lambda: 0.01 }, "rcv1-like", Policy::Cyclic);
        let ds = spec.load_dataset().unwrap();
        let plain = run_job_on(&spec, &ds).unwrap();
        let live = Arc::new(LiveMetrics::new(Vec::new()));
        let out = run_job_with_live(&spec, &ds, Some(Arc::clone(&live))).unwrap();
        assert_eq!(plain.result.objective.to_bits(), out.result.objective.to_bits());
        assert_eq!(plain.result.iterations, out.result.iterations);
        let point = live.latest();
        // serial solvers publish at epoch boundaries; the last published
        // objective tracks the trajectory (the final result value comes
        // from the verification pass after the last full epoch)
        let published = point.snapshot.last_objective.expect("serial run published an objective");
        let rel = (published - out.result.objective).abs() / out.result.objective.abs().max(1.0);
        assert!(rel < 1e-6, "published {published} vs final {}", out.result.objective);
    }

    #[test]
    fn metrics_addr_spec_runs_the_full_server_lifecycle() {
        // `--metrics-addr` end to end: run_job_on binds the server,
        // the run publishes, the server is torn down on completion, and
        // the JSON report records the flag (scrape-while-training is
        // covered by tests/telemetry.rs)
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        spec.shards = 2;
        spec.metrics_addr = Some("127.0.0.1:0".into());
        spec.metrics_labels = vec![("row".into(), "7".into())];
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let j = out.to_json();
        assert_eq!(j.get("metrics_addr").unwrap().as_str(), Some("127.0.0.1:0"));
    }

    #[test]
    fn traced_sharded_job_writes_a_readable_jsonl_trace() {
        use crate::util::json;
        let path = std::env::temp_dir()
            .join(format!("acf_job_trace_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        spec.shards = 4;
        spec.trace_level = TraceLevel::Events;
        spec.trace_out = Some(path.clone());
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let text = std::fs::read_to_string(&path).expect("trace file written");
        let _ = std::fs::remove_file(&path);
        let mut kinds = std::collections::BTreeSet::new();
        for (lineno, line) in text.lines().enumerate() {
            let j = json::parse(line).unwrap_or_else(|e| panic!("line {} not JSON: {e}", lineno + 1));
            kinds.insert(j.get("kind").and_then(Json::as_str).expect("kind field").to_string());
        }
        // meta header first, event lines in between, summary tail
        let first = json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("kind").unwrap().as_str(), Some("meta"));
        assert_eq!(first.get("dropped_events").unwrap().as_f64(), Some(0.0));
        assert_eq!(first.get("shards").unwrap().as_usize(), Some(4));
        for expected in ["meta", "epoch", "merge", "publish", "summary"] {
            assert!(kinds.contains(expected), "missing '{expected}' lines; got {kinds:?}");
        }
        // and the offline reporter accepts the file end-to-end
        let report = crate::obs::report::summarize(&text).expect("summarize");
        for section in ["stage time", "per shard", "merge outcomes"] {
            assert!(report.contains(section), "report missing '{section}':\n{report}");
        }
    }

    #[test]
    fn traced_serial_job_records_selector_probes() {
        use crate::util::json;
        let path = std::env::temp_dir()
            .join(format!("acf_serial_trace_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let mut spec = quick_spec(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        // tight eps so the run comfortably exceeds the ~1024-call probe
        // period of the selector decorator on this tiny dataset
        spec.eps = 0.001;
        spec.trace_level = TraceLevel::Events;
        spec.trace_out = Some(path.clone());
        let out = run_job(&spec).unwrap();
        assert!(out.result.status.converged());
        let text = std::fs::read_to_string(&path).expect("trace file written");
        let _ = std::fs::remove_file(&path);
        let probes = text
            .lines()
            .filter_map(|l| json::parse(l).ok())
            .filter(|j| j.get("kind").and_then(Json::as_str) == Some("selector"))
            .count();
        assert!(probes > 0, "serial events-level run should emit selector probes");
    }
}
