//! Experiment coordinator — the L3 launcher around the solvers: job
//! specs, parallel grid sweeps, cross-validation, and report generation.
//! The `acf-cd` CLI (rust/src/main.rs) and every bench binary drive the
//! system through this module.

pub mod grid;
pub mod jobs;
pub mod report;

pub use grid::{cross_validate, run_sweep, SweepSpec};
pub use jobs::{run_job, run_job_on, run_job_with_live, JobOutcome, JobSpec, Problem};
pub use report::{comparison_table, geomean_speedups, outcomes_json, selector_table};
