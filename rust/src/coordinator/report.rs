//! Turning sweep outcomes into the paper's table layouts and JSON dumps.

use super::jobs::JobOutcome;
use crate::bench_util::Table;
use crate::util::json::Json;
use crate::util::timer::fmt_count;

/// Group outcomes of one sweep into per-grid-point rows comparing a
/// baseline policy against ACF — the paper's table shape (baseline
/// iterations/ops/seconds, ACF ditto, speed-up columns).
pub fn comparison_table(
    title: &str,
    outcomes: &[JobOutcome],
    baseline_name: &str,
    param_label: &str,
) -> Table {
    let mut t = Table::new(
        title,
        &[
            param_label,
            "baseline iters",
            "baseline ops",
            "baseline sec",
            "acf iters",
            "acf ops",
            "acf sec",
            "speedup iters",
            "speedup ops",
            "speedup time",
        ],
    );
    // collect grid values in order of first appearance
    let mut grid: Vec<f64> = Vec::new();
    for o in outcomes {
        let v = o.spec.problem.parameter();
        if !grid.iter().any(|&g| g == v) {
            grid.push(v);
        }
    }
    for &v in &grid {
        let base = outcomes.iter().find(|o| {
            o.spec.problem.parameter() == v
                && (o.spec.policy.name() == baseline_name
                    || o.spec.problem.family() == baseline_name)
        });
        let acf = outcomes
            .iter()
            .find(|o| o.spec.problem.parameter() == v && o.spec.policy.name() == "acf");
        let (Some(b), Some(a)) = (base, acf) else { continue };
        let dnf = |o: &JobOutcome| !o.result.status.converged();
        let cell = |x: f64, is_dnf: bool| if is_dnf { "—".to_string() } else { fmt_count(x) };
        let sec = |o: &JobOutcome| {
            if dnf(o) {
                "—".to_string()
            } else {
                format!("{:.3}", o.result.seconds)
            }
        };
        let ratio = |num: f64, den: f64, any_dnf: bool| {
            if any_dnf || den <= 0.0 {
                "—".to_string()
            } else {
                format!("{:.1}", num / den)
            }
        };
        let any_dnf = dnf(b) || dnf(a);
        t.row(vec![
            format!("{v}"),
            cell(b.result.iterations as f64, dnf(b)),
            cell(b.result.ops as f64, dnf(b)),
            sec(b),
            cell(a.result.iterations as f64, dnf(a)),
            cell(a.result.ops as f64, dnf(a)),
            sec(a),
            ratio(b.result.iterations as f64, a.result.iterations as f64, any_dnf),
            ratio(b.result.ops as f64, a.result.ops as f64, any_dnf),
            ratio(b.result.seconds, a.result.seconds, any_dnf),
        ]);
    }
    t
}

/// Rows of a selector-axis sweep (`sweep --selector a,b,...`): one row
/// per outcome, grid-major like [`crate::coordinator::run_sweep`]'s
/// ordering, with each row's time relative to the `acf` selector at the
/// same grid point (1.00 = parity, above = slower than ACF).
pub fn selector_table(title: &str, outcomes: &[JobOutcome], param_label: &str) -> Table {
    let mut t = Table::new(
        title,
        &[param_label, "selector", "iters", "ops", "sec", "objective", "time vs acf"],
    );
    for o in outcomes {
        let name = o.spec.selector.map(|k| k.name()).unwrap_or_else(|| o.spec.policy.name());
        let acf = outcomes.iter().find(|b| {
            b.spec.problem.parameter() == o.spec.problem.parameter()
                && b.spec.selector.map(|k| k.name()) == Some("acf")
        });
        let dnf = !o.result.status.converged();
        let rel = match acf {
            Some(a)
                if !dnf && a.result.status.converged() && a.result.seconds > 0.0 =>
            {
                format!("{:.2}", o.result.seconds / a.result.seconds)
            }
            _ => "—".to_string(),
        };
        t.row(vec![
            format!("{}", o.spec.problem.parameter()),
            name.to_string(),
            if dnf { "—".into() } else { fmt_count(o.result.iterations as f64) },
            if dnf { "—".into() } else { fmt_count(o.result.ops as f64) },
            if dnf { "—".into() } else { format!("{:.3}", o.result.seconds) },
            format!("{:.6}", o.result.objective),
            rel,
        ]);
    }
    t
}

/// JSON array of all outcomes (for EXPERIMENTS.md evidence files).
pub fn outcomes_json(outcomes: &[JobOutcome]) -> Json {
    Json::Arr(outcomes.iter().map(|o| o.to_json()).collect())
}

/// Geometric-mean speedups (iters, ops, time) of ACF over a baseline
/// across all shared grid points where both converged.
pub fn geomean_speedups(outcomes: &[JobOutcome], baseline_name: &str) -> Option<(f64, f64, f64)> {
    let mut it = Vec::new();
    let mut ops = Vec::new();
    let mut secs = Vec::new();
    let mut grid: Vec<f64> = Vec::new();
    for o in outcomes {
        let v = o.spec.problem.parameter();
        if !grid.iter().any(|&g| g == v) {
            grid.push(v);
        }
    }
    for &v in &grid {
        let base = outcomes.iter().find(|o| {
            o.spec.problem.parameter() == v
                && (o.spec.policy.name() == baseline_name
                    || o.spec.problem.family() == baseline_name)
        })?;
        let acf = outcomes
            .iter()
            .find(|o| o.spec.problem.parameter() == v && o.spec.policy.name() == "acf")?;
        if base.result.status.converged() && acf.result.status.converged() {
            it.push(base.result.iterations as f64 / acf.result.iterations.max(1) as f64);
            ops.push(base.result.ops as f64 / acf.result.ops.max(1) as f64);
            if acf.result.seconds > 0.0 {
                secs.push(base.result.seconds / acf.result.seconds);
            }
        }
    }
    if it.is_empty() {
        return None;
    }
    use crate::util::stats::geomean;
    Some((geomean(&it), geomean(&ops), geomean(&secs)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::jobs::{JobSpec, Problem};
    use crate::coordinator::SweepSpec;
    use crate::data::Scale;
    use crate::sched::Policy;

    fn small_sweep() -> Vec<JobOutcome> {
        let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        base.scale = Scale(0.04);
        crate::coordinator::run_sweep(&SweepSpec {
            base,
            grid: vec![0.1, 1.0],
            policies: vec![Policy::Acf, Policy::Permutation],
            selectors: vec![],
            include_shrinking: false,
            workers: 4,
        })
        .unwrap()
    }

    #[test]
    fn table_has_one_row_per_grid_point() {
        let out = small_sweep();
        let t = comparison_table("demo", &out, "random-permutation", "C");
        assert_eq!(t.rows.len(), 2);
        t.print();
    }

    #[test]
    fn json_dump_covers_all() {
        let out = small_sweep();
        let j = outcomes_json(&out);
        assert_eq!(j.as_arr().unwrap().len(), out.len());
    }

    #[test]
    fn geomean_speedups_present() {
        let out = small_sweep();
        let s = geomean_speedups(&out, "random-permutation");
        assert!(s.is_some());
        let (it, ops, _) = s.unwrap();
        assert!(it > 0.0 && ops > 0.0);
    }

    #[test]
    fn selector_table_has_one_row_per_outcome() {
        use crate::select::SelectorKind;
        let mut base = JobSpec::new(Problem::Svm { c: 1.0 }, "rcv1-like", Policy::Acf);
        base.scale = Scale(0.04);
        let out = crate::coordinator::run_sweep(&SweepSpec {
            base,
            grid: vec![0.1, 1.0],
            policies: vec![],
            selectors: vec![SelectorKind::Acf, SelectorKind::Uniform],
            include_shrinking: false,
            workers: 4,
        })
        .unwrap();
        let t = selector_table("selectors", &out, "C");
        assert_eq!(t.rows.len(), 4);
        // the acf row is its own reference point: ratio exactly 1.00
        assert_eq!(t.rows[0][1], "acf");
        assert_eq!(t.rows[0][6], "1.00");
        t.print();
    }
}
