//! Dataset substrate: synthetic paper-analog generators, the named
//! registry used by benches, and split/CV helpers.

pub mod registry;
pub mod split;
pub mod synth;

pub use registry::{binary, multiclass, regression, DataBackend, Scale};
pub use split::{apply, binary_accuracy, k_fold, multiclass_accuracy, train_test_split, Split};
