//! Named registry of the paper-dataset analogs at laptop scale.
//!
//! Each entry mirrors one of the paper's benchmark datasets (Tables 2, 4,
//! 7) with the same *structure* (sparsity profile, feature/instance
//! ratio, class count) at roughly 100–1000× reduced scale. The mapping is
//! documented in DESIGN.md §6. A `--scale` factor lets benches trade time
//! for fidelity.

use super::synth;
use crate::sparse::Dataset;
use crate::util::rng::Rng;

/// Which storage backend a job resolves its training matrix into
/// (CLI `--data-backend`). `Owned` is the in-memory default; `Mmap`
/// round-trips the dataset through an `.acfbin` file and maps it
/// read-only ([`crate::sparse::storage::remap_dataset`]), exercising
/// the out-of-core path with bit-identical rows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DataBackend {
    /// Heap-resident CSR vectors (the classic path).
    #[default]
    Owned,
    /// Read-only file mapping of the `.acfbin` serialization.
    Mmap,
}

impl DataBackend {
    /// Accepted `--data-backend` spellings.
    pub const NAMES: [&'static str; 2] = ["owned", "mmap"];

    /// Parse a CLI spelling (case-insensitive).
    pub fn parse(text: &str) -> Option<DataBackend> {
        match text.to_ascii_lowercase().as_str() {
            "owned" => Some(DataBackend::Owned),
            "mmap" => Some(DataBackend::Mmap),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn name(self) -> &'static str {
        match self {
            DataBackend::Owned => "owned",
            DataBackend::Mmap => "mmap",
        }
    }
}

/// Scale multiplier applied to instance counts (1.0 = the default laptop
/// scale, which is already reduced vs the paper).
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Default for Scale {
    fn default() -> Self {
        Scale(1.0)
    }
}

fn scaled(n: usize, s: Scale) -> usize {
    ((n as f64 * s.0) as usize).max(16)
}

/// Binary-classification analogs (paper Table 4).
pub fn binary(name: &str, scale: Scale, seed: u64) -> Option<Dataset> {
    let mut rng = Rng::new(seed);
    let ds = match name {
        // news20: ℓ≈20k, d≈1.36M, very sparse, high-dim ≫ instances
        "news20-like" => synth::sparse_text(
            &synth::SparseTextSpec {
                name: "news20-like",
                n: scaled(2000, scale),
                d: 40_000,
                nnz_per_row: 60,
                zipf_s: 1.05,
                concept_k: 200,
                noise: 0.02,
            },
            &mut rng,
        ),
        // rcv1: ℓ≈20k, d≈47k, ~74 nnz/row
        "rcv1-like" => synth::sparse_text(
            &synth::SparseTextSpec {
                name: "rcv1-like",
                n: scaled(2500, scale),
                d: 8_000,
                nnz_per_row: 50,
                zipf_s: 1.3,
                concept_k: 120,
                noise: 0.03,
            },
            &mut rng,
        ),
        // url: ℓ≈2.4M, d≈3.2M; instances ≫ typical, mixed dense+sparse
        "url-like" => synth::sparse_text(
            &synth::SparseTextSpec {
                name: "url-like",
                n: scaled(8000, scale),
                d: 12_000,
                nnz_per_row: 30,
                zipf_s: 0.9,
                concept_k: 80,
                noise: 0.05,
            },
            &mut rng,
        ),
        // kdd-a: ℓ≈8.4M, d≈20M — extreme scale; we keep the shape
        // (instances ≈ features, very sparse) at reduced size
        "kdda-like" => synth::sparse_text(
            &synth::SparseTextSpec {
                name: "kdda-like",
                n: scaled(6000, scale),
                d: 15_000,
                nnz_per_row: 25,
                zipf_s: 1.1,
                concept_k: 100,
                noise: 0.08,
            },
            &mut rng,
        ),
        // kdd-b: like kdd-a, bigger
        "kddb-like" => synth::sparse_text(
            &synth::SparseTextSpec {
                name: "kddb-like",
                n: scaled(9000, scale),
                d: 22_000,
                nnz_per_row: 25,
                zipf_s: 1.1,
                concept_k: 120,
                noise: 0.08,
            },
            &mut rng,
        ),
        // cover type: ℓ≈581k, d=54 dense — the paper's negative case
        "covtype-like" => synth::dense_lowdim("covtype-like", scaled(8000, scale), 54, &mut rng),
        _ => return None,
    };
    Some(ds)
}

/// LASSO regression analogs (paper Table 2).
pub fn regression(name: &str, scale: Scale, seed: u64) -> Option<(Dataset, Vec<f64>)> {
    let mut rng = Rng::new(seed);
    let out = match name {
        // news20 (as regression design): d ≫ ℓ
        "news20-like" => synth::regression_sparse(
            "news20-like",
            scaled(1500, scale),
            30_000,
            50,
            40,
            0.5,
            &mut rng,
        ),
        // rcv1
        "rcv1-like" => synth::regression_sparse(
            "rcv1-like",
            scaled(2000, scale),
            6_000,
            45,
            60,
            0.5,
            &mut rng,
        ),
        // E2006-tfidf: ℓ≈16k, d≈150k, long documents (heavy rows)
        "e2006-like" => synth::regression_sparse(
            "e2006-like",
            scaled(1200, scale),
            20_000,
            150,
            50,
            0.3,
            &mut rng,
        ),
        _ => return None,
    };
    Some(out)
}

/// Multi-class analogs (paper Table 7).
pub fn multiclass(name: &str, scale: Scale, seed: u64) -> Option<Dataset> {
    let mut rng = Rng::new(seed);
    let ds = match name {
        // iris: 105 train, 4 features, 3 classes
        "iris-like" => synth::multiclass_blobs("iris-like", 105, 4, 3, 0.6, &mut rng),
        // soybean: 214 train, 35 features, 19 classes
        "soybean-like" => synth::multiclass_blobs("soybean-like", 214, 35, 19, 0.5, &mut rng),
        // news20 multi-class: ~16k × 62k, 20 classes
        "news20mc-like" => synth::multiclass_text(
            "news20mc-like",
            scaled(2000, scale),
            10_000,
            20,
            50,
            0.03,
            &mut rng,
        ),
        // rcv1 multi-class: ~15.5k × 47k, 53 classes
        "rcv1mc-like" => synth::multiclass_text(
            "rcv1mc-like",
            scaled(2120, scale),
            8_000,
            53,
            45,
            0.03,
            &mut rng,
        ),
        _ => return None,
    };
    Some(ds)
}

/// All names understood by [`binary`].
pub const BINARY_NAMES: &[&str] =
    &["covtype-like", "kdda-like", "kddb-like", "news20-like", "rcv1-like", "url-like"];

/// All names understood by [`regression`].
pub const REGRESSION_NAMES: &[&str] = &["news20-like", "rcv1-like", "e2006-like"];

/// All names understood by [`multiclass`].
pub const MULTICLASS_NAMES: &[&str] =
    &["iris-like", "soybean-like", "news20mc-like", "rcv1mc-like"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_binary_names_resolve() {
        for name in BINARY_NAMES {
            let ds = binary(name, Scale(0.05), 1).unwrap_or_else(|| panic!("{name}"));
            assert!(ds.n_instances() >= 16, "{name}");
            ds.x.check_invariants().unwrap();
        }
    }

    #[test]
    fn all_regression_names_resolve() {
        for name in REGRESSION_NAMES {
            let (ds, w) = regression(name, Scale(0.05), 1).unwrap();
            assert!(ds.n_instances() >= 16);
            assert_eq!(w.len(), ds.n_features());
        }
    }

    #[test]
    fn all_multiclass_names_resolve() {
        for name in MULTICLASS_NAMES {
            let ds = multiclass(name, Scale(0.05), 1).unwrap();
            assert!(ds.classes().len() >= 3, "{name}");
        }
    }

    #[test]
    fn unknown_names_are_none() {
        assert!(binary("nope", Scale(1.0), 1).is_none());
        assert!(regression("nope", Scale(1.0), 1).is_none());
        assert!(multiclass("nope", Scale(1.0), 1).is_none());
    }

    #[test]
    fn data_backend_spellings_round_trip() {
        for name in DataBackend::NAMES {
            assert_eq!(DataBackend::parse(name).unwrap().name(), name);
        }
        assert_eq!(DataBackend::parse("MMAP"), Some(DataBackend::Mmap));
        assert_eq!(DataBackend::default(), DataBackend::Owned);
        assert!(DataBackend::parse("disk").is_none());
    }

    #[test]
    fn seed_determinism() {
        let a = binary("rcv1-like", Scale(0.05), 9).unwrap();
        let b = binary("rcv1-like", Scale(0.05), 9).unwrap();
        assert_eq!(a.x, b.x);
    }
}
