//! Train/test splitting and k-fold cross-validation (the paper reports
//! 3-fold CV accuracy alongside training times in Figure 2 / Table 9).

use crate::sparse::Dataset;
use crate::util::rng::Rng;

/// A train/test split by instance indices.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<usize>,
    pub test: Vec<usize>,
}

/// Random split with `test_frac` of instances held out.
pub fn train_test_split(n: usize, test_frac: f64, rng: &mut Rng) -> Split {
    assert!((0.0..1.0).contains(&test_frac));
    let perm = rng.permutation(n);
    let n_test = ((n as f64) * test_frac).round() as usize;
    Split { test: perm[..n_test].to_vec(), train: perm[n_test..].to_vec() }
}

/// k-fold partition: returns `k` splits, each using one fold as test.
pub fn k_fold(n: usize, k: usize, rng: &mut Rng) -> Vec<Split> {
    assert!(k >= 2 && k <= n);
    let perm = rng.permutation(n);
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &idx) in perm.iter().enumerate() {
        folds[i % k].push(idx);
    }
    (0..k)
        .map(|t| {
            let test = folds[t].clone();
            let train =
                folds.iter().enumerate().filter(|&(i, _)| i != t).flat_map(|(_, f)| f.iter().copied()).collect();
            Split { train, test }
        })
        .collect()
}

/// Materialize (train, test) datasets from a split.
pub fn apply(ds: &Dataset, split: &Split) -> (Dataset, Dataset) {
    (ds.select(&split.train), ds.select(&split.test))
}

/// Binary classification accuracy of a linear model `w` on a dataset
/// (labels ±1).
pub fn binary_accuracy(ds: &Dataset, w: &[f64]) -> f64 {
    let mut correct = 0usize;
    for i in 0..ds.n_instances() {
        let m = ds.x.row(i).dot_dense(w);
        if m * ds.y[i] > 0.0 {
            correct += 1;
        }
    }
    correct as f64 / ds.n_instances().max(1) as f64
}

/// Multi-class accuracy with per-class weight vectors `w[k]`.
pub fn multiclass_accuracy(ds: &Dataset, w: &[Vec<f64>]) -> f64 {
    let mut correct = 0usize;
    for i in 0..ds.n_instances() {
        let row = ds.x.row(i);
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for (k, wk) in w.iter().enumerate() {
            let s = row.dot_dense(wk);
            if s > best_score {
                best_score = s;
                best = k;
            }
        }
        if best == ds.y[i] as usize {
            correct += 1;
        }
    }
    correct as f64 / ds.n_instances().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;
    use crate::util::prop;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            x: Csr::from_rows(
                2,
                vec![
                    vec![(0, 1.0)],
                    vec![(0, -1.0)],
                    vec![(1, 1.0)],
                    vec![(1, -1.0)],
                    vec![(0, 2.0)],
                    vec![(0, -2.0)],
                ],
            ),
            y: vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0],
        }
    }

    #[test]
    fn split_partitions() {
        let mut rng = Rng::new(1);
        let s = train_test_split(100, 0.25, &mut rng);
        assert_eq!(s.test.len(), 25);
        assert_eq!(s.train.len(), 75);
        let mut all: Vec<usize> = s.train.iter().chain(s.test.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn k_fold_covers_everything() {
        prop::check(20, |g| {
            let n = g.usize_in(6, 80);
            let k = g.usize_in(2, 5.min(n));
            let mut rng = Rng::new(g.seed);
            let folds = k_fold(n, k, &mut rng);
            prop::assert_holds(folds.len() == k, "k folds")?;
            // test folds partition 0..n
            let mut all: Vec<usize> = folds.iter().flat_map(|f| f.test.iter().copied()).collect();
            all.sort_unstable();
            prop::assert_holds(all == (0..n).collect::<Vec<_>>(), "partition")?;
            // each split's train+test = 0..n
            for f in &folds {
                let mut u: Vec<usize> = f.train.iter().chain(f.test.iter()).copied().collect();
                u.sort_unstable();
                prop::assert_holds(u == (0..n).collect::<Vec<_>>(), "train+test")?;
            }
            Ok(())
        });
    }

    #[test]
    fn accuracy_perfect_and_chance() {
        let ds = tiny();
        let w = vec![1.0, 1.0];
        assert_eq!(binary_accuracy(&ds, &w), 1.0);
        let w_bad = vec![-1.0, -1.0];
        assert_eq!(binary_accuracy(&ds, &w_bad), 0.0);
    }

    #[test]
    fn multiclass_accuracy_works() {
        let ds = Dataset {
            name: "mc".into(),
            x: Csr::from_rows(2, vec![vec![(0, 1.0)], vec![(1, 1.0)]]),
            y: vec![0.0, 1.0],
        };
        let w = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        assert_eq!(multiclass_accuracy(&ds, &w), 1.0);
    }

    #[test]
    fn apply_materializes() {
        let ds = tiny();
        let mut rng = Rng::new(2);
        let s = train_test_split(ds.n_instances(), 0.5, &mut rng);
        let (tr, te) = apply(&ds, &s);
        assert_eq!(tr.n_instances() + te.n_instances(), ds.n_instances());
        assert_eq!(tr.n_features(), ds.n_features());
    }
}
