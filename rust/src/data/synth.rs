//! Synthetic dataset generators — laptop-scale analogs of the paper's
//! libsvm-site benchmark datasets (no network access in this environment;
//! see DESIGN.md §6 for the substitution argument).
//!
//! The generators plant exactly the structure that drives the paper's
//! results:
//!
//! * **Sparse text-like data** ([`SparseTextSpec`]): feature ids drawn
//!   from a Zipf distribution (power-law document frequencies, as in
//!   news20/rcv1/url), a planted sparse linear concept, label noise and a
//!   controllable fraction of outliers. Heterogeneous coordinate
//!   importance — the regime where ACF wins.
//! * **Dense low-dimensional data** ([`dense_lowdim`]): the cover-type
//!   analog (many instances, few dense features) where dual variables are
//!   highly redundant and ACF's overhead is expected to *lose* — the
//!   paper's own negative case.
//! * **Regression data** ([`regression_sparse`]): sparse design with a
//!   planted sparse ground-truth weight vector for the LASSO experiments
//!   (E2006-tfidf analog: heavy-tailed column scales).
//! * **Multi-class data** ([`multiclass_blobs`] / text analog): K planted
//!   class prototypes (iris/soybean/news20/rcv1 analogs).

use crate::sparse::{Csr, Dataset};
use crate::util::rng::{Rng, Zipf};

/// Specification of a sparse "text-like" binary classification dataset.
#[derive(Clone, Debug)]
pub struct SparseTextSpec {
    pub name: &'static str,
    /// number of instances ℓ
    pub n: usize,
    /// feature-space dimension d
    pub d: usize,
    /// mean non-zeros per instance
    pub nnz_per_row: usize,
    /// Zipf exponent for feature frequencies (≈1 for natural text)
    pub zipf_s: f64,
    /// number of features carrying the planted concept
    pub concept_k: usize,
    /// label flip probability (creates outliers / bounded SVs)
    pub noise: f64,
}

/// Generate a binary classification dataset from the spec. Labels are
/// ±1. Feature values are tf-idf-like positives; rows are L2-normalized
/// (as is standard for the paper's text datasets).
pub fn sparse_text(spec: &SparseTextSpec, rng: &mut Rng) -> Dataset {
    let zipf = Zipf::new(spec.d, spec.zipf_s);
    // Proper idf: down-weight frequent terms. Document frequency of rank
    // f is P(f ∈ doc) ≈ 1 − (1 − pmf_f)^len; idf = −ln(df) (+ floor).
    let mean_len = spec.nnz_per_row as f64;
    let idf: Vec<f64> = (0..spec.d)
        .map(|f| {
            let df = 1.0 - (1.0 - zipf.pmf(f)).powf(mean_len);
            (-(df.max(1e-12)).ln()).max(0.05)
        })
        .collect();
    // Planted concept on mid-frequency features with alternating signs —
    // informative terms in real text are neither stop-words (tiny idf)
    // nor hapaxes (never observed); weights decay slowly with rank.
    let mut concept = vec![0.0f64; spec.d];
    // band [d/200, d/20]: each doc of ~nnz_per_row tokens hits a few of
    // these ranks, so the concept is observable in most documents
    let lo = (spec.d / 200).max(1);
    let hi = (spec.d / 20).max(lo + spec.concept_k + 1);
    for k in 0..spec.concept_k.min(spec.d) {
        let sign = if k % 2 == 0 { 1.0 } else { -1.0 };
        let feat = (lo + k * (hi - lo) / spec.concept_k.max(1)).min(spec.d - 1);
        concept[feat] = sign * (1.0 + 1.0 / (1.0 + k as f64).sqrt());
    }
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(spec.n);
    let mut margins = Vec::with_capacity(spec.n);
    for _ in 0..spec.n {
        // document length varies (Poisson-ish via geometric mixture)
        let len = 1 + ((spec.nnz_per_row as f64) * (0.5 + rng.uniform())) as usize;
        let mut row: Vec<(usize, f64)> = Vec::with_capacity(len);
        let mut seen = std::collections::HashSet::with_capacity(len * 2);
        for _ in 0..len {
            let f = zipf.sample(rng);
            if seen.insert(f) {
                // tf-idf: rarer features carry larger weight
                let tf = 1.0 + rng.exponential(2.0);
                row.push((f, tf * idf[f]));
            }
        }
        // L2 normalize
        let norm = row.iter().map(|(_, v)| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for (_, v) in row.iter_mut() {
                *v /= norm;
            }
        }
        let margin: f64 = row.iter().map(|&(j, v)| concept[j] * v).sum();
        margins.push(margin);
        rows.push(row);
    }
    // Second pass: label by the *median* margin so classes come out
    // balanced regardless of the concept/frequency interaction (all
    // feature values are positive, which would otherwise bias labels).
    let threshold = crate::util::stats::median(&margins);
    let mut y: Vec<f64> =
        margins.iter().map(|&m| if m >= threshold { 1.0 } else { -1.0 }).collect();
    // Noise as *conflict pairs*: duplicate a document's features with the
    // opposite label. No linear model can fit both copies, so their dual
    // variables saturate at the bound — exactly the "outlier with α at C"
    // regime the paper's §3.2 argues makes online adaptation of π
    // valuable (a label flip on a unique sparse doc would instead be
    // absorbed by its rare features in the d ≫ ℓ setting).
    for i in 1..spec.n {
        if rng.bernoulli(spec.noise) {
            rows[i] = rows[i - 1].clone();
            y[i] = -y[i - 1];
        }
    }
    Dataset { name: spec.name.to_string(), x: Csr::from_rows(spec.d, rows), y }
}

/// Dense low-dimensional classification data (cover-type analog): all
/// features present, moderate class overlap, many redundant instances.
pub fn dense_lowdim(name: &str, n: usize, d: usize, rng: &mut Rng) -> Dataset {
    // Two Gaussian clusters with significant overlap plus feature scaling
    // heterogeneity (covtype mixes binary and continuous features).
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    let mut dir = vec![0.0; d];
    for (j, w) in dir.iter_mut().enumerate() {
        *w = if j % 3 == 0 { 1.0 } else { 0.3 };
    }
    let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt();
    for v in dir.iter_mut() {
        *v /= norm;
    }
    for _ in 0..n {
        let label = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        let shift = 0.9 * label;
        let mut row = Vec::with_capacity(d);
        for (j, &dj) in dir.iter().enumerate() {
            let scale = if j % 5 == 0 { 2.0 } else { 1.0 };
            let v = rng.gaussian() * scale + shift * dj;
            row.push((j, v));
        }
        rows.push(row);
        y.push(label);
    }
    Dataset { name: name.to_string(), x: Csr::from_rows(d, rows), y }
}

/// Sparse regression dataset with planted sparse ground truth (LASSO
/// experiments). Returns (dataset, true weights).
pub fn regression_sparse(
    name: &str,
    n: usize,
    d: usize,
    nnz_per_row: usize,
    k_true: usize,
    noise_std: f64,
    rng: &mut Rng,
) -> (Dataset, Vec<f64>) {
    let zipf = Zipf::new(d, 1.05);
    // idf-style column scaling: frequent columns down-weighted so no
    // single head column dominates the design (as in real tf-idf data)
    let mean_len = nnz_per_row as f64;
    let idf: Vec<f64> = (0..d)
        .map(|f| {
            let df = 1.0 - (1.0 - zipf.pmf(f)).powf(mean_len);
            (-(df.max(1e-12)).ln()).max(0.05)
        })
        .collect();
    // true weights on mid-frequency features (as in real text, where the
    // informative terms are neither stop-words nor hapaxes)
    let mut w_true = vec![0.0; d];
    let lo = d / 50;
    let hi = d / 2;
    for k in 0..k_true.min(d) {
        let feat = lo + (k * (hi - lo)) / k_true.max(1);
        w_true[feat.min(d - 1)] = rng.normal(0.0, 2.0);
    }
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let len = 1 + ((nnz_per_row as f64) * (0.5 + rng.uniform())) as usize;
        let mut row: Vec<(usize, f64)> = Vec::with_capacity(len);
        let mut seen = std::collections::HashSet::with_capacity(len * 2);
        let mut last_f = zipf.sample(rng);
        for _ in 0..len {
            // topic bursts: with prob 0.5 pick a feature near the
            // previous one (co-occurrence clusters → correlated columns,
            // the regime where CD needs many sweeps), else a fresh draw
            let f = if rng.bernoulli(0.5) {
                (last_f + 1 + rng.below(8)).min(d - 1)
            } else {
                zipf.sample(rng)
            };
            last_f = f;
            if seen.insert(f) {
                // tf-idf-scaled magnitude (positive, as in tf-idf data)
                let tf = 1.0 + rng.exponential(2.0);
                row.push((f, tf * idf[f]));
            }
        }
        // L2-normalize rows (standard for the paper's tf-idf datasets)
        let norm = row.iter().map(|(_, v)| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for (_, v) in row.iter_mut() {
                *v /= norm;
            }
        }
        let target: f64 =
            row.iter().map(|&(j, v)| w_true[j] * v).sum::<f64>() + rng.normal(0.0, noise_std);
        rows.push(row);
        y.push(target);
    }
    (Dataset { name: name.to_string(), x: Csr::from_rows(d, rows), y }, w_true)
}

/// Multi-class dataset: K class prototypes in a sparse text-like space
/// (news20/rcv1 multi-class analogs) or dense blobs for the small UCI
/// analogs (iris/soybean).
pub fn multiclass_text(
    name: &str,
    n: usize,
    d: usize,
    k_classes: usize,
    nnz_per_row: usize,
    noise: f64,
    rng: &mut Rng,
) -> Dataset {
    let zipf = Zipf::new(d, 1.0);
    // Each class owns a random set of "topic" features.
    let topic_size = (d / (2 * k_classes)).max(2);
    let mut topics: Vec<Vec<usize>> = Vec::with_capacity(k_classes);
    for _ in 0..k_classes {
        topics.push(rng.sample_indices(d, topic_size));
    }
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % k_classes; // balanced
        let len = 1 + ((nnz_per_row as f64) * (0.5 + rng.uniform())) as usize;
        let mut row: Vec<(usize, f64)> = Vec::with_capacity(len);
        let mut seen = std::collections::HashSet::with_capacity(len * 2);
        for _ in 0..len {
            // mix: 60% topic features, 40% background Zipf
            let f = if rng.bernoulli(0.6) {
                topics[class][rng.below(topic_size)]
            } else {
                zipf.sample(rng)
            };
            if seen.insert(f) {
                row.push((f, 1.0 + rng.exponential(2.0)));
            }
        }
        let norm = row.iter().map(|(_, v)| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for (_, v) in row.iter_mut() {
                *v /= norm;
            }
        }
        let label = if rng.bernoulli(noise) { rng.below(k_classes) } else { class };
        rows.push(row);
        y.push(label as f64);
    }
    Dataset { name: name.to_string(), x: Csr::from_rows(d, rows), y }
}

/// Dense Gaussian blobs with K classes (iris/soybean analogs).
pub fn multiclass_blobs(
    name: &str,
    n: usize,
    d: usize,
    k_classes: usize,
    spread: f64,
    rng: &mut Rng,
) -> Dataset {
    let mut centers = Vec::with_capacity(k_classes);
    for _ in 0..k_classes {
        centers.push((0..d).map(|_| rng.normal(0.0, 2.0)).collect::<Vec<f64>>());
    }
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % k_classes;
        let row: Vec<(usize, f64)> = (0..d)
            .map(|j| (j, centers[class][j] + rng.gaussian() * spread))
            .collect();
        rows.push(row);
        y.push(class as f64);
    }
    Dataset { name: name.to_string(), x: Csr::from_rows(d, rows), y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_text_shapes() {
        let mut rng = Rng::new(1);
        let spec = SparseTextSpec {
            name: "t",
            n: 200,
            d: 500,
            nnz_per_row: 10,
            zipf_s: 1.0,
            concept_k: 20,
            noise: 0.02,
        };
        let ds = sparse_text(&spec, &mut rng);
        assert_eq!(ds.n_instances(), 200);
        assert_eq!(ds.n_features(), 500);
        ds.x.check_invariants().unwrap();
        // labels are ±1
        assert!(ds.y.iter().all(|&l| l == 1.0 || l == -1.0));
        // both classes present
        assert!(ds.y.iter().any(|&l| l == 1.0) && ds.y.iter().any(|&l| l == -1.0));
        // rows are L2 normalized
        for i in 0..ds.n_instances() {
            let n2 = ds.x.row(i).norm_sq();
            assert!((n2 - 1.0).abs() < 1e-9, "row {i} norm {n2}");
        }
    }

    #[test]
    fn sparse_text_is_deterministic() {
        let spec = SparseTextSpec {
            name: "t",
            n: 50,
            d: 100,
            nnz_per_row: 5,
            zipf_s: 1.0,
            concept_k: 6,
            noise: 0.0,
        };
        let a = sparse_text(&spec, &mut Rng::new(7));
        let b = sparse_text(&spec, &mut Rng::new(7));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn zipf_feature_skew_present() {
        let mut rng = Rng::new(2);
        let spec = SparseTextSpec {
            name: "t",
            n: 500,
            d: 1000,
            nnz_per_row: 20,
            zipf_s: 1.0,
            concept_k: 10,
            noise: 0.0,
        };
        let ds = sparse_text(&spec, &mut rng);
        let t = ds.x.transpose();
        let head: usize = (0..10).map(|c| t.row_nnz(c)).sum();
        let tail: usize = (900..910).map(|c| t.row_nnz(c)).sum();
        assert!(head > 5 * tail.max(1), "head {head} tail {tail}");
    }

    #[test]
    fn dense_lowdim_fully_dense() {
        let mut rng = Rng::new(3);
        let ds = dense_lowdim("cov", 100, 12, &mut rng);
        assert_eq!(ds.nnz(), 100 * 12);
        assert!(ds.y.iter().any(|&l| l == 1.0) && ds.y.iter().any(|&l| l == -1.0));
    }

    #[test]
    fn regression_has_signal() {
        let mut rng = Rng::new(4);
        let (ds, w_true) = regression_sparse("reg", 300, 200, 10, 12, 0.1, &mut rng);
        assert_eq!(ds.n_instances(), 300);
        let k = w_true.iter().filter(|&&w| w != 0.0).count();
        assert!(k > 0 && k <= 12);
        // predictions from w_true correlate strongly with y
        let pred = ds.x.matvec(&w_true);
        let my = crate::util::stats::mean(&ds.y);
        let mp = crate::util::stats::mean(&pred);
        let mut num = 0.0;
        let mut dy = 0.0;
        let mut dp = 0.0;
        for i in 0..ds.n_instances() {
            num += (ds.y[i] - my) * (pred[i] - mp);
            dy += (ds.y[i] - my).powi(2);
            dp += (pred[i] - mp).powi(2);
        }
        let corr = num / (dy.sqrt() * dp.sqrt());
        assert!(corr > 0.9, "corr {corr}");
    }

    #[test]
    fn multiclass_balanced() {
        let mut rng = Rng::new(5);
        let ds = multiclass_text("mc", 300, 400, 5, 12, 0.0, &mut rng);
        let classes = ds.classes();
        assert_eq!(classes, vec![0, 1, 2, 3, 4]);
        for c in classes {
            let count = ds.y.iter().filter(|&&l| l as i64 == c).count();
            assert_eq!(count, 60);
        }
    }

    #[test]
    fn blobs_separable_at_low_spread() {
        let mut rng = Rng::new(6);
        let ds = multiclass_blobs("blob", 90, 4, 3, 0.1, &mut rng);
        assert_eq!(ds.classes().len(), 3);
        assert_eq!(ds.nnz(), 90 * 4);
    }
}
