//! # acf-cd
//!
//! Full-system reproduction of **"Coordinate Descent with Online
//! Adaptation of Coordinate Frequencies"** (Glasmachers & Dogan, 2014).
//!
//! The crate is a coordinate-descent optimization framework in which the
//! paper's contribution — the **Adaptive Coordinate Frequencies (ACF)**
//! scheduler — is one policy inside the pluggable coordinate-selection
//! subsystem [`select`] (the [`select::Selector`] trait), evaluated
//! against uniform / permuted-cyclic / shrinking baselines *and* the
//! competing online schemes from the surrounding literature (EXP3
//! bandit sampling, adaptive importance sampling; `--selector
//! acf|uniform|cyclic|bandit|importance`, `cargo bench --bench
//! policy_faceoff`) on the paper's four problem families:
//!
//! * LASSO regression (§3.1, Table 3),
//! * linear SVM dual (§3.2, Tables 5–6, Figure 2),
//! * Weston–Watkins multi-class SVM via subspace descent (§3.3, Table 8),
//! * dual logistic regression (§3.4, Table 9),
//!
//! plus the §6 Markov-chain experiment (Figure 1).
//!
//! Architecture (three layers, Python never on the hot path):
//!
//! * **L3** — this crate: schedulers, solvers, the [`shard`] scaling
//!   subsystem, data substrates, experiment coordinator, benchmark
//!   harness.
//! * **L2** — `python/compile/model.py`: JAX evaluation graphs (margins,
//!   losses, dense-Q CD sweeps), AOT-lowered once to HLO text in
//!   `artifacts/`.
//! * **L1** — `python/compile/kernels/`: Pallas kernels called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate, behind the `pjrt` cargo feature) and exposes them to the
//! coordinator's *validation* path (objective audits, accuracy); the CD
//! iteration hot loop is pure Rust.
//!
//! Hot path: every CD step runs on the [`sparse::kernels`] layer —
//! `get_unchecked` gather/scatter with a fused dot+update+scatter
//! `step` (safety restored by an O(1) bound check on the
//! strictly-increasing CSR row indices), dispatched at runtime across
//! SIMD tiers (AVX2+FMA / SSE2 on x86_64, NEON on aarch64, with the
//! 4-way scalar unroll as the always-compiled fallback and oracle).
//! Every tier keeps the scalar unroll's exact 4-accumulator reduction
//! tree, so results are **bit-identical** across tiers and the sync
//! engine's determinism survives heterogeneous hardware; verify loops
//! software-pipeline the sweep by prefetching the next row while the
//! current reduction drains. Per-row norms are computed once and
//! cached on the matrix ([`sparse::Csr::row_norms_sq`]).
//!
//! Scaling axis: [`shard`] partitions the coordinate set into S shards,
//! runs an inner ACF scheduler per shard on a persistent worker pool,
//! and adapts shard visit frequencies with an *outer* ACF instance —
//! hierarchical ACF, the paper's Algorithms 2+3 applied at two levels.
//! Shared state merges either at an epoch barrier (default,
//! bit-deterministic) or asynchronously against versioned published
//! buffers with a bounded staleness τ (`--async-merge
//! --staleness-bound t`, Wright's async-CD regime). Serial solvers get
//! the same idea through [`sched::Policy::Hierarchical`]; the CLI
//! exposes it as `--policy hier --shards S --partitioner
//! contiguous|hash`.
//!
//! Observability: [`obs`] is the first-party tracing/metrics plane —
//! lock-free per-worker event rings capture engine spans (epochs,
//! merges, publishes, τ moves, parks) and adaptation probes, folded
//! into JSONL traces (`--trace-out`, `--trace-level`) that the `trace`
//! subcommand renders as a stage-time breakdown and adaptation
//! timeline, and gates against a baseline (`trace diff`). The same
//! plane serves live: `--metrics-addr` publishes epoch/merge-boundary
//! snapshots through [`obs::live`] and an in-process HTTP server
//! ([`obs::server`]) as Prometheus text ([`obs::export`]), JSON, and a
//! health probe — non-perturbing, and absent entirely when unset.
//!
//! Data plane: [`sparse`] serves the training matrix from three
//! interchangeable storage backends ([`sparse::CsrStorage`]) — owned
//! heap vectors, a read-only mapping of an `.acfbin` file
//! ([`sparse::storage`]; `--data-backend mmap`, datasets ≫ RAM), or
//! bounded chunks streamed by the libsvm ingest ([`sparse::ingest`],
//! `acf-cd ingest`) — all bit-identical behind the same
//! [`sparse::Csr`]/[`sparse::RowView`] API.
//!
//! The module map, the end-to-end data-flow walkthrough, and the
//! `.acfbin` format specification live in [`architecture`]
//! (`docs/ARCHITECTURE.md` in the repository).

/// Rendered copy of `docs/ARCHITECTURE.md`: module map, end-to-end
/// data-flow walkthrough, and the `.acfbin` on-disk format spec.
/// (Doc-only module — it exists so the architecture document ships with
/// `cargo doc` and its description stays next to the code it maps.)
#[cfg(doc)]
pub mod architecture {
    #![doc = include_str!("../../docs/ARCHITECTURE.md")]
}

pub mod acf;
pub mod bench_util;
pub mod coordinator;
pub mod data;
pub mod markov;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod select;
pub mod shard;
pub mod solvers;
pub mod sparse;
pub mod util;

/// Crate-wide result and error types (first-party `anyhow` analog —
/// the offline build carries no external dependencies).
pub use util::error::{Error, Result};
