//! `acf-cd` — launcher for the ACF coordinate-descent framework.
//!
//! Subcommands:
//!   train     one solver run (problem × dataset × policy × parameter)
//!   sweep     parameter-grid comparison (ACF vs baselines), paper-style table
//!   cv        k-fold cross-validation accuracy at one parameter point
//!   ingest    stream a libsvm text file into the mappable .acfbin format
//!   markov    §6 Markov-chain experiment (balance π, Figure-1 curves)
//!   trace     summarize a --trace-out JSONL file (stage times, adaptation)
//!             or gate two traces against each other (`trace diff`)
//!   datasets  list the paper-analog dataset registry
//!   info      artifacts/runtime status (PJRT platform, manifest)
//!
//! Examples:
//!   acf-cd train --problem svm --dataset rcv1-like --policy acf --c 1.0
//!   acf-cd sweep --problem svm --dataset news20-like --grid 0.01,0.1,1,10 \
//!                --policies acf,perm --shrinking --eps 0.01
//!   acf-cd sweep --problem svm --grid 0.1,1 --selector acf,uniform,bandit
//!   acf-cd train --shards 4 --trace-out run.jsonl --trace-level events
//!   acf-cd ingest data.libsvm data.acfbin
//!   acf-cd train --dataset data.acfbin --shards 4 --data-backend mmap
//!   acf-cd trace run.jsonl
//!   acf-cd trace diff baseline.jsonl candidate.jsonl --tolerance 0.2
//!   acf-cd train --shards 4 --metrics-addr 127.0.0.1:9090
//!   acf-cd markov --n 5 --seed 7 --curves

use acf_cd::coordinator::{self, JobSpec, Problem, SweepSpec};
use acf_cd::data::{registry, DataBackend, Scale};
use acf_cd::markov;
use acf_cd::obs::TraceLevel;
use acf_cd::runtime::Runtime;
use acf_cd::sched::Policy;
use acf_cd::select::SelectorKind;
use acf_cd::shard::Partitioner;
use acf_cd::sparse::{ingest, storage};
use acf_cd::util::cli::Args;
use acf_cd::util::rng::Rng;
use acf_cd::{anyhow, Result};
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("train") => cmd_train(args),
        Some("sweep") => cmd_sweep(args),
        Some("cv") => cmd_cv(args),
        Some("ingest") => cmd_ingest(args),
        Some("markov") => cmd_markov(args),
        Some("trace") => cmd_trace(args),
        Some("datasets") => cmd_datasets(),
        Some("info") => cmd_info(),
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (run without args for help)")),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "acf-cd — Adaptive Coordinate Frequencies CD framework\n\
         \n\
         subcommands: train | sweep | cv | ingest | markov | trace | datasets | info\n\
         common flags: --problem svm|lasso|logreg|mcsvm  --dataset <name>\n\
         \u{20}             --policy acf|perm|cyclic|uniform|hier  --c/--lambda <v>\n\
         \u{20}             --eps <v>  --scale <f>  --seed <n>  --workers <n>\n\
         selection:    --selector acf|uniform|cyclic|bandit|importance picks\n\
         \u{20}             the coordinate-selection rule explicitly (the\n\
         \u{20}             select/ subsystem: ACF, i.i.d. uniform, permuted\n\
         \u{20}             cyclic, EXP3 bandit, adaptive importance sampling);\n\
         \u{20}             overrides --policy for serial train runs and picks\n\
         \u{20}             the sharded engine's inner-loop rule; compare them\n\
         \u{20}             with `cargo bench --bench policy_faceoff`. NB:\n\
         \u{20}             --selector cyclic re-permutes each sweep, while\n\
         \u{20}             --policy cyclic is fixed index order\n\
         sharding:     --shards <S>  runs any of the four families\n\
         \u{20}             (svm/lasso/logreg/mcsvm) on the parallel sharded\n\
         \u{20}             engine (per-shard ACF + outer ACF over shards;\n\
         \u{20}             engages with --policy acf, the default — other\n\
         \u{20}             policies keep their serial semantics for fair\n\
         \u{20}             comparisons; mcsvm merges its K per-class weight\n\
         \u{20}             buffers atomically as one versioned unit);\n\
         \u{20}             --partitioner contiguous|hash picks\n\
         \u{20}             the coordinate split; --shard-workers <n> caps the\n\
         \u{20}             engine's threads; `--policy hier` is the serial\n\
         \u{20}             two-level ACF (shard count from --shards, 0 = √n)\n\
         async merge:  --async-merge drops the per-epoch barrier: workers\n\
         \u{20}             snapshot versioned shared-state buffers and a\n\
         \u{20}             merger publishes monotone flips (fast, but not\n\
         \u{20}             bit-deterministic); --staleness-bound <t|auto> caps\n\
         \u{20}             how many versions a merge/Δf report may lag\n\
         \u{20}             (default 2; 'auto' tunes τ online from the observed\n\
         \u{20}             stale-drop/reject rate)\n\
         data plane:   --data-backend owned|mmap picks the training-matrix\n\
         \u{20}             storage: owned = heap CSR (default); mmap round-\n\
         \u{20}             trips through a read-only .acfbin mapping with\n\
         \u{20}             bit-identical rows (page cache instead of heap).\n\
         \u{20}             `acf-cd ingest <in.libsvm> <out.acfbin>` streams a\n\
         \u{20}             libsvm file into that format in bounded row chunks\n\
         \u{20}             (--chunk-rows <n>, --min-features <d>); with\n\
         \u{20}             --dataset <name> it serializes a registry dataset\n\
         \u{20}             instead. A --dataset ending in .acfbin trains\n\
         \u{20}             straight from the file\n\
         observability: --trace-out <path> records the run as first-party\n\
         \u{20}             JSONL (meta line, span/event lines, 1 s metrics\n\
         \u{20}             windows, summary); --trace-level off|summary|spans|\n\
         \u{20}             events picks the verbosity (spans = epoch/merge/\n\
         \u{20}             publish timings; events adds snapshot/submit/\n\
         \u{20}             selector probes; a --trace-out without a level\n\
         \u{20}             implies spans). `acf-cd trace <file>` prints the\n\
         \u{20}             stage-time breakdown, per-shard throughput, merge\n\
         \u{20}             outcomes and the τ/objective adaptation timeline.\n\
         \u{20}             Recording never changes results: off is the\n\
         \u{20}             pre-instrumentation hot path, and every level\n\
         \u{20}             only reads solver state.\n\
         \u{20}             `acf-cd trace diff <a> <b> [--tolerance <t>]`\n\
         \u{20}             compares two traces (stage times, throughput,\n\
         \u{20}             acceptance, objective) and exits non-zero when a\n\
         \u{20}             watched ratio regresses beyond <t> (default 0.2)\n\
         live metrics: --metrics-addr <ip:port> serves the run over HTTP\n\
         \u{20}             while it trains: /metrics (Prometheus text\n\
         \u{20}             format), /snapshot (JSON), /healthz. Port 0 binds\n\
         \u{20}             an ephemeral port; the resolved address is printed\n\
         \u{20}             to stderr. Reads the same non-perturbing plane as\n\
         \u{20}             tracing; unset = no server, no registry. A sweep\n\
         \u{20}             gives every row its own ephemeral-port server\n\
         \u{20}             labelled row=<grid-major index>\n\
         selector sweeps: `sweep --selector a,b,...` compares coordinate-\n\
         \u{20}             selection rules (grid × selectors, all on the ACF\n\
         \u{20}             policy) instead of --policies; `sweep --trace-out\n\
         \u{20}             <p>` writes one file per grid cell, <stem>.<row>\n\
         \u{20}             .jsonl (row = grid-major index, stem = <p> minus a\n\
         \u{20}             trailing .jsonl)\n\
         run `cargo bench` for the paper's tables/figures and\n\
         `cargo bench --bench scaling_shards` for the shard-scaling curve."
    );
}

fn parse_problem(args: &Args) -> Result<Problem> {
    let fam = args.str_or("problem", "svm");
    let c = args.f64_or("c", 1.0)?;
    let lambda = args.f64_or("lambda", 0.01)?;
    Ok(match fam {
        "svm" => Problem::Svm { c },
        "svm-shrinking" => Problem::SvmShrinking { c },
        "lasso" => Problem::Lasso { lambda },
        "logreg" => Problem::LogReg { c },
        "mcsvm" => Problem::McSvm { c },
        other => return Err(anyhow!("unknown problem family '{other}'")),
    })
}

fn parse_spec(args: &Args) -> Result<JobSpec> {
    parse_spec_inner(args, true)
}

/// `parse_selector = false` leaves `--selector` untouched for callers
/// that give the flag a different meaning (`sweep` reads it as a
/// comma-separated comparison axis rather than a single override).
fn parse_spec_inner(args: &Args, parse_selector: bool) -> Result<JobSpec> {
    let problem = parse_problem(args)?;
    let default_ds = match problem {
        Problem::McSvm { .. } => "iris-like",
        _ => "rcv1-like",
    };
    let dataset = args.str_or("dataset", default_ds).to_string();
    let shards = args.usize_or("shards", 0)?;
    let partitioner = Partitioner::parse(args.str_or("partitioner", "contiguous"))
        .map_err(|e| anyhow!("{e}"))?;
    let policy = Policy::parse(args.str_or("policy", "acf"))
        .map_err(|e| anyhow!("{e}"))?
        .with_shards(shards)
        .with_partitioner(partitioner);
    let mut spec = JobSpec::new(problem, &dataset, policy);
    // --selector: explicit coordinate-selection rule (select/ subsystem)
    if let Some(s) = args.get("selector").filter(|_| parse_selector) {
        spec.selector = Some(SelectorKind::parse(s).map_err(|e| anyhow!("{e}"))?);
        // the shrinking baseline owns its permutation order — a selector
        // cannot be honored there, so reject instead of silently ignoring
        if matches!(spec.problem, Problem::SvmShrinking { .. }) {
            return Err(anyhow!(
                "--selector does not apply to --problem svm-shrinking (the shrinking \
                 heuristic is an active-set transformation with its own permutation order)"
            ));
        }
    }
    spec.eps = args.f64_or("eps", 0.01)?;
    spec.seed = args.u64_or("seed", 20140103)?;
    spec.scale = Scale(args.f64_or("scale", 1.0)?);
    // --data-backend: how the training matrix is stored (sparse/ data
    // plane) — heap CSR, or a read-only .acfbin mapping
    if let Some(v) = args.get("data-backend") {
        spec.data_backend = DataBackend::parse(v).ok_or_else(|| {
            anyhow!("--data-backend: expected one of {}", DataBackend::NAMES.join("|"))
        })?;
    }
    spec.max_iterations = args.u64_or("max-iterations", 200_000_000)?;
    if let Some(s) = args.get("max-seconds") {
        spec.max_seconds = Some(s.parse()?);
    }
    spec.shards = shards;
    spec.partitioner = partitioner;
    // deliberately a separate flag from --workers (the sweep job pool):
    // a sharded sweep would otherwise square the thread count
    spec.shard_workers = args.usize_or("shard-workers", 0)?;
    spec.async_merge = args.bool_or("async-merge", false)?;
    // --staleness-bound <n|auto>: a number fixes τ, "auto" tunes it
    // online from the observed stale-drop/reject rate
    match args.get("staleness-bound") {
        Some(v) if v.eq_ignore_ascii_case("auto") => spec.staleness_auto = true,
        Some(v) => {
            spec.staleness_bound =
                v.parse().map_err(|_| anyhow!("--staleness-bound: expected an integer or 'auto'"))?;
        }
        None => {}
    }
    if !spec.async_merge && args.has("staleness-bound") {
        eprintln!("note: --staleness-bound applies only with --async-merge; the flag is inert here");
    }
    // --trace-level / --trace-out: the first-party observability plane
    // (crate obs/). A destination without a level implies `spans`.
    if let Some(v) = args.get("trace-level") {
        spec.trace_level = TraceLevel::parse(v).ok_or_else(|| {
            anyhow!("--trace-level: expected one of {}", TraceLevel::NAMES.join("|"))
        })?;
    }
    if let Some(p) = args.get("trace-out") {
        spec.trace_out = Some(p.to_string());
        if spec.trace_level == TraceLevel::Off {
            spec.trace_level = TraceLevel::Spans;
        }
    } else if spec.trace_level != TraceLevel::Off {
        eprintln!(
            "note: --trace-level {} without --trace-out records in memory and then \
             discards the stream; add --trace-out <path> to keep it",
            spec.trace_level.name()
        );
    }
    // --metrics-addr: live telemetry HTTP endpoint (obs/server). The
    // resolved address (relevant with port 0) is printed at bind time.
    if let Some(a) = args.get("metrics-addr") {
        spec.metrics_addr = Some(a.to_string());
    }
    Ok(spec)
}

fn cmd_train(args: &Args) -> Result<()> {
    let spec = parse_spec(args)?;
    let ds = spec.load_dataset()?;
    eprintln!(
        "dataset {}: {} instances × {} features, {} nnz ({} storage)",
        ds.name,
        ds.n_instances(),
        ds.n_features(),
        ds.nnz(),
        ds.x.storage_kind()
    );
    if spec.uses_sharded_engine() {
        eprintln!(
            "sharded engine: {} shards, {} partition, {} merge",
            spec.shards,
            spec.partitioner.name(),
            if spec.async_merge && spec.staleness_auto {
                format!("async (staleness bound auto, from {})", spec.staleness_bound)
            } else if spec.async_merge {
                format!("async (staleness bound {})", spec.staleness_bound)
            } else {
                "synchronized".to_string()
            }
        );
    }
    let out = coordinator::run_job_on(&spec, &ds)?;
    if let Some(p) = &spec.trace_out {
        eprintln!("trace written to {p} (summarize with `acf-cd trace {p}`)");
    }
    println!("{}", out.result.summary());
    if let Some(w) = &out.w {
        if !matches!(spec.problem, Problem::Lasso { .. }) {
            let acc = acf_cd::data::binary_accuracy(&ds, w);
            println!("train accuracy: {:.2}%", 100.0 * acc);
        }
    }
    if let Some(wm) = &out.w_multi {
        let acc = acf_cd::data::multiclass_accuracy(&ds, wm);
        println!("train accuracy: {:.2}%", 100.0 * acc);
    }
    if let Some(k) = out.nnz_coeffs {
        println!("non-zero coefficients: {k}");
    }
    if let Some(ms) = &out.merge_stats {
        let tau = if spec.async_merge {
            format!(", final staleness bound {}", ms.staleness_bound_final)
        } else {
            String::new()
        };
        println!(
            "merge stats: {} objective evals, {} accepted / {} rejected submissions, {} batched folds{tau}",
            ms.objective_evals, ms.accepted_submissions, ms.rejected_submissions, ms.batched_merges
        );
    }
    // Optional cross-stack audit through the AOT/PJRT validator.
    if args.has("validate") {
        let rt = Runtime::load_default()?;
        if let Some(w) = &out.w {
            let rep = acf_cd::runtime::validator::validate(&rt, &ds, w)?;
            println!(
                "validator [{}]: accuracy {:.2}%, hinge {:.4}, logistic {:.4}",
                rt.platform(),
                100.0 * rep.accuracy,
                rep.hinge_sum,
                rep.logistic_sum
            );
        }
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, out.to_json().to_string_pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    // `sweep --selector a,b,...` switches the comparison axis from
    // policies to coordinate-selection rules, so the single-override
    // parsing in parse_spec is skipped here.
    let base = parse_spec_inner(args, false)?;
    let selectors: Vec<SelectorKind> = args
        .str_list("selector")
        .unwrap_or_default()
        .iter()
        .map(|s| SelectorKind::parse(s).map_err(|e| anyhow!("{e}")))
        .collect::<Result<_>>()?;
    if !selectors.is_empty() && (args.has("policies") || args.has("shrinking")) {
        return Err(anyhow!(
            "--selector picks the sweep's comparison axis (selection rules on the ACF \
             policy) and cannot be combined with --policies/--shrinking"
        ));
    }
    if !selectors.is_empty() && matches!(base.problem, Problem::SvmShrinking { .. }) {
        return Err(anyhow!(
            "--selector does not apply to --problem svm-shrinking (the shrinking \
             heuristic owns its permutation order)"
        ));
    }
    if let Some(p) = &base.trace_out {
        let stem = p.strip_suffix(".jsonl").unwrap_or(p);
        eprintln!(
            "note: a sweep runs its jobs concurrently, so each grid cell writes its own \
             trace file: {stem}.<row>.jsonl (row = grid-major outcome index)"
        );
    }
    let grid = args.f64_list("grid")?.unwrap_or_else(|| vec![0.01, 0.1, 1.0, 10.0]);
    let policies: Vec<Policy> = args
        .str_list("policies")
        .unwrap_or_else(|| vec!["acf".into(), "perm".into()])
        .iter()
        .map(|s| Policy::parse(s).map_err(|e| anyhow!("{e}")))
        .collect::<Result<_>>()?;
    let spec = SweepSpec {
        base,
        grid,
        policies,
        selectors,
        include_shrinking: args.has("shrinking"),
        workers: args.usize_or("workers", acf_cd::util::threadpool::default_workers())?,
    };
    let outcomes = coordinator::run_sweep(&spec)?;
    let title = format!(
        "{} on {} (ε = {})",
        spec.base.problem.family(),
        spec.base.dataset,
        spec.base.eps
    );
    if !spec.selectors.is_empty() {
        coordinator::selector_table(&title, &outcomes, "param").print();
    } else {
        let baseline = if spec.include_shrinking { "svm-shrinking" } else { "random-permutation" };
        coordinator::comparison_table(&title, &outcomes, baseline, "param").print();
        if let Some((it, ops, secs)) = coordinator::geomean_speedups(&outcomes, baseline) {
            println!("\ngeomean speedups — iters {it:.2}×, ops {ops:.2}×, time {secs:.2}×");
        }
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, coordinator::outcomes_json(&outcomes).to_string_pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `acf-cd trace <file.jsonl>` — offline summary of a recorded trace:
/// stage-time breakdown, per-shard throughput, epoch-time histogram,
/// merge outcomes, and the τ/objective adaptation timeline.
/// `acf-cd trace diff <a> <b>` compares two traces instead.
fn cmd_trace(args: &Args) -> Result<()> {
    if args.positional.first().map(|s| s.as_str()) == Some("diff") {
        return cmd_trace_diff(args);
    }
    let path = match args.get("file").or_else(|| args.positional.first().map(|s| s.as_str())) {
        Some(p) => p,
        None => return Err(anyhow!("usage: acf-cd trace <file.jsonl>  (or --file <path>)")),
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("cannot read trace file '{path}': {e}"))?;
    println!("{}", acf_cd::obs::report::summarize(&text)?.trim_end());
    Ok(())
}

/// `acf-cd trace diff <a.jsonl> <b.jsonl> [--tolerance <t>]` — the
/// regression gate: compare stage times, per-shard throughput, merge
/// acceptance and the final objective of two traces, print the table,
/// and exit non-zero when any watched ratio drifts beyond the
/// tolerance (default ±20%).
fn cmd_trace_diff(args: &Args) -> Result<()> {
    let (a, b) = match (args.positional.get(1), args.positional.get(2)) {
        (Some(a), Some(b)) => (a, b),
        _ => {
            return Err(anyhow!(
                "usage: acf-cd trace diff <baseline.jsonl> <candidate.jsonl> [--tolerance <t>]"
            ))
        }
    };
    let tolerance = args.f64_or("tolerance", 0.2)?;
    if !(0.0..=10.0).contains(&tolerance) {
        return Err(anyhow!("--tolerance: expected a fraction like 0.2, got {tolerance}"));
    }
    let ta = std::fs::read_to_string(a).map_err(|e| anyhow!("cannot read '{a}': {e}"))?;
    let tb = std::fs::read_to_string(b).map_err(|e| anyhow!("cannot read '{b}': {e}"))?;
    let report = acf_cd::obs::report::diff(&ta, &tb, tolerance)?;
    println!("{}", report.render().trim_end());
    let n = report.regressions();
    if n > 0 {
        return Err(anyhow!("{n} watched metric(s) regressed beyond ±{:.0}%", tolerance * 100.0));
    }
    Ok(())
}

fn cmd_cv(args: &Args) -> Result<()> {
    let spec = parse_spec(args)?;
    let k = args.usize_or("folds", 3)?;
    let acc = coordinator::cross_validate(
        spec.problem,
        &spec.dataset,
        spec.policy,
        spec.eps,
        spec.scale,
        k,
        spec.seed,
        args.usize_or("workers", acf_cd::util::threadpool::default_workers())?,
    )?;
    println!("{k}-fold CV accuracy: {:.2}%", 100.0 * acc);
    Ok(())
}

/// `acf-cd ingest <input.libsvm> <output.acfbin>` — stream a libsvm
/// text file into the mappable on-disk format in bounded row chunks
/// (the matrix is never fully materialized in memory). With
/// `--dataset <name>` a synthetic registry dataset is serialized
/// instead, resolved like `train` (--problem/--scale/--seed).
fn cmd_ingest(args: &Args) -> Result<()> {
    if args.has("dataset") {
        let out = match args.positional.first() {
            Some(p) => p,
            None => return Err(anyhow!("usage: acf-cd ingest --dataset <name> <out.acfbin>")),
        };
        let spec = parse_spec(args)?;
        let ds = spec.load_dataset()?;
        let sum = storage::write_dataset(&ds, Path::new(out))?;
        println!(
            "wrote {out}: {} rows × {} cols, {} nnz, {} bytes",
            sum.rows, sum.cols, sum.nnz, sum.bytes
        );
        return Ok(());
    }
    let (src, dst) = match (args.positional.first(), args.positional.get(1)) {
        (Some(s), Some(d)) => (s, d),
        _ => return Err(anyhow!("usage: acf-cd ingest <input.libsvm> <output.acfbin>")),
    };
    let min_features = args.usize_or("min-features", 0)?;
    let chunk_rows = args.usize_or("chunk-rows", 0)?;
    let rep = ingest::ingest_libsvm(Path::new(src), Path::new(dst), min_features, chunk_rows)?;
    println!("ingested {src}: {} rows × {} cols, {} nnz", rep.rows, rep.cols, rep.nnz);
    println!(
        "{:.1} MB read in {:.2} s ({:.1} MB/s); wrote {} bytes to {dst}",
        rep.input_bytes as f64 / 1e6,
        rep.seconds,
        rep.mb_per_s,
        rep.output_bytes
    );
    Ok(())
}

fn cmd_markov(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 5)?;
    let seed = args.u64_or("seed", 1)?;
    let steps = args.u64_or("steps", 200_000)?;
    let mut rng = Rng::new(seed);
    let q = markov::Quadratic::rbf_gram(n, 3.0, &mut rng);
    println!("balancing π on a random RBF-Gram instance, n = {n} …");
    let cfg = markov::BalanceConfig { steps_per_round: steps / 4, ..Default::default() };
    let res = markov::balance(&q, &cfg, &mut rng);
    println!(
        "π̄ = {:?}\nρ(π̄) = {:.6}, imbalance {:.3} ({} rounds)",
        res.pi.iter().map(|p| (p * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
        res.rho,
        res.imbalance,
        res.rounds
    );
    let uniform = markov::progress_rate(&q, &vec![1.0 / n as f64; n], 2_000, steps, &mut rng);
    println!(
        "ρ(uniform) = {:.6}  →  balanced/uniform = {:.3}",
        uniform.rho,
        res.rho / uniform.rho
    );
    if args.has("curves") {
        let curves = markov::curves_around(&q, &res.pi, 2_000, steps, &mut rng);
        for c in &curves {
            println!(
                "coord {}: {:?} (max at t=0: {})",
                c.coordinate,
                c.relative_rho.iter().map(|r| (r * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
                c.max_at_zero(0.02)
            );
        }
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("binary (svm / logreg):");
    for n in registry::BINARY_NAMES {
        println!("  {n}");
    }
    println!("regression (lasso):");
    for n in registry::REGRESSION_NAMES {
        println!("  {n}");
    }
    println!("multiclass (mcsvm):");
    for n in registry::MULTICLASS_NAMES {
        println!("  {n}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    match Runtime::load_default() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("manifest: {}", rt.manifest.to_string_pretty());
        }
        Err(e) => {
            println!("artifacts not loadable: {e:#}");
            println!("run `make artifacts` first");
        }
    }
    Ok(())
}
