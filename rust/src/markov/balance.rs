//! Rprop-style balancing of the coordinate distribution π — the procedure
//! the paper uses to find π̄ ≈ π* for Figure 1: "adaptively increasing
//! π_i if ρ_i > ρ and decreasing π_i if ρ_i < ρ with an Rprop-style
//! algorithm" (§6.2).

use super::chain::progress_rate;
use super::quadratic::Quadratic;
use crate::util::rng::Rng;

/// Configuration of the balancer.
#[derive(Clone, Debug)]
pub struct BalanceConfig {
    /// steps per ρ/ρ_i estimation round
    pub steps_per_round: u64,
    /// burn-in steps before each estimation
    pub burn_in: u64,
    /// maximum balancing rounds
    pub max_rounds: usize,
    /// stop when max_i |ρ_i − ρ|/ρ falls below this
    pub tol: f64,
    /// Rprop step-size growth / shrink factors
    pub eta_plus: f64,
    pub eta_minus: f64,
    /// initial / min / max multiplicative step sizes
    pub gamma0: f64,
    pub gamma_min: f64,
    pub gamma_max: f64,
    /// floor for π entries
    pub pi_min: f64,
}

impl Default for BalanceConfig {
    fn default() -> Self {
        Self {
            steps_per_round: 40_000,
            burn_in: 2_000,
            max_rounds: 60,
            tol: 0.02,
            eta_plus: 1.2,
            eta_minus: 0.5,
            gamma0: 0.10,
            gamma_min: 1e-4,
            gamma_max: 0.5,
            pi_min: 1e-4,
        }
    }
}

/// Result of balancing.
#[derive(Clone, Debug)]
pub struct BalanceResult {
    /// the balanced distribution π̄
    pub pi: Vec<f64>,
    /// final progress rate ρ(π̄)
    pub rho: f64,
    /// final imbalance max|ρ_i − ρ|/ρ
    pub imbalance: f64,
    pub rounds: usize,
}

/// Balance π so that all per-coordinate rates ρ_i agree with ρ.
pub fn balance(q: &Quadratic, cfg: &BalanceConfig, rng: &mut Rng) -> BalanceResult {
    let n = q.n();
    let mut pi = vec![1.0 / n as f64; n];
    let mut gamma = vec![cfg.gamma0; n];
    let mut last_sign = vec![0i8; n];
    let mut rho = 0.0;
    let mut imbalance = f64::INFINITY;
    let mut rounds = 0;
    for round in 0..cfg.max_rounds {
        rounds = round + 1;
        let est = progress_rate(q, &pi, cfg.burn_in, cfg.steps_per_round, rng);
        rho = est.rho;
        imbalance = est.imbalance();
        if imbalance < cfg.tol {
            break;
        }
        for i in 0..n {
            let diff = est.rho_i[i] - est.rho;
            let sign: i8 = if diff > 0.0 {
                1
            } else if diff < 0.0 {
                -1
            } else {
                0
            };
            // Rprop: accelerate on agreement, back off on sign flip
            if sign != 0 && last_sign[i] != 0 {
                if sign == last_sign[i] {
                    gamma[i] = (gamma[i] * cfg.eta_plus).min(cfg.gamma_max);
                } else {
                    gamma[i] = (gamma[i] * cfg.eta_minus).max(cfg.gamma_min);
                }
            }
            last_sign[i] = sign;
            // ρ_i above average ⇒ coordinate is under-visited ⇒ raise π_i
            match sign {
                1 => pi[i] *= 1.0 + gamma[i],
                -1 => pi[i] /= 1.0 + gamma[i],
                _ => {}
            }
            pi[i] = pi[i].max(cfg.pi_min);
        }
        let sum: f64 = pi.iter().sum();
        for p in pi.iter_mut() {
            *p /= sum;
        }
    }
    BalanceResult { pi, rho, imbalance, rounds }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 2-coordinate quadratic where the optimal π is analytically
    /// non-uniform: heavily different diagonal scales.
    fn skewed_quadratic() -> Quadratic {
        // strong coupling and asymmetric diagonals
        Quadratic::from_matrix(2, vec![4.0, 1.2, 1.2, 0.5])
    }

    #[test]
    fn balancing_reduces_imbalance() {
        let q = skewed_quadratic();
        let mut rng = Rng::new(1);
        let cfg = BalanceConfig {
            steps_per_round: 20_000,
            max_rounds: 40,
            tol: 0.03,
            ..Default::default()
        };
        let initial = progress_rate(&q, &[0.5, 0.5], 1_000, 20_000, &mut rng);
        let res = balance(&q, &cfg, &mut rng);
        assert!(
            res.imbalance < initial.imbalance().max(0.05),
            "imbalance {} not reduced from {}",
            res.imbalance,
            initial.imbalance()
        );
        let s: f64 = res.pi.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(res.pi.iter().all(|&p| p > 0.0));
    }

    #[test]
    fn balanced_pi_not_worse_than_uniform() {
        let q = skewed_quadratic();
        let mut rng = Rng::new(2);
        let res = balance(
            &q,
            &BalanceConfig { steps_per_round: 30_000, max_rounds: 40, ..Default::default() },
            &mut rng,
        );
        let uni = progress_rate(&q, &[0.5, 0.5], 2_000, 60_000, &mut rng);
        let bal = progress_rate(&q, &res.pi, 2_000, 60_000, &mut rng);
        // allow small estimation noise
        assert!(
            bal.rho >= uni.rho * 0.97,
            "balanced rho {} worse than uniform {}",
            bal.rho,
            uni.rho
        );
    }

    #[test]
    fn symmetric_problem_stays_near_uniform() {
        // Exchangeable coordinates: π* = uniform.
        let q = Quadratic::from_matrix(3, vec![1.0, 0.4, 0.4, 0.4, 1.0, 0.4, 0.4, 0.4, 1.0]);
        let mut rng = Rng::new(3);
        let res = balance(
            &q,
            &BalanceConfig { steps_per_round: 30_000, max_rounds: 30, ..Default::default() },
            &mut rng,
        );
        for &p in &res.pi {
            assert!((p - 1.0 / 3.0).abs() < 0.08, "pi {:?}", res.pi);
        }
    }
}
