//! The §6 CD Markov chain: iterates `w ← T_i w`, `i ∼ π`, on a quadratic
//! `f = ½wᵀQw`, with estimation of the asymptotic progress rate
//!
//! ```text
//! ρ   = lim (1/t)·[log f(w⁰) − log f(wᵗ)]
//! ρ_i = E[ log f(w) − log f(T_i w) ]   (steps with coordinate i)
//! ```
//!
//! The chain is scale invariant (Lemma 1), so the state is renormalized
//! periodically — the projective chain `z = κ(w)` is what is actually
//! simulated, avoiding floating-point underflow as f → 0.

use super::quadratic::Quadratic;
use crate::util::rng::{sample_weighted, Rng};
use crate::util::stats::Online;

/// Progress-rate estimates from a simulation run.
#[derive(Clone, Debug)]
pub struct ProgressEstimate {
    /// overall rate ρ (mean log-progress per step)
    pub rho: f64,
    /// standard error of ρ
    pub rho_sem: f64,
    /// per-coordinate rates ρ_i
    pub rho_i: Vec<f64>,
    /// per-coordinate sample counts
    pub counts: Vec<u64>,
    /// total steps simulated
    pub steps: u64,
}

impl ProgressEstimate {
    /// Max relative imbalance `max_i |ρ_i − ρ| / ρ` — the quantity the
    /// balancer drives to zero (Conjecture 1's equilibrium condition).
    pub fn imbalance(&self) -> f64 {
        self.rho_i
            .iter()
            .map(|&r| (r - self.rho).abs())
            .fold(0.0f64, f64::max)
            / self.rho.max(f64::MIN_POSITIVE)
    }
}

/// Simulator for the CD Markov chain under a fixed distribution π.
pub struct Chain<'a> {
    pub q: &'a Quadratic,
    pub w: Vec<f64>,
}

impl<'a> Chain<'a> {
    /// Start from a random Gaussian point (a.s. non-zero).
    pub fn new(q: &'a Quadratic, rng: &mut Rng) -> Self {
        let w = (0..q.n()).map(|_| rng.gaussian()).collect();
        Self { q, w }
    }

    /// Renormalize the state (projective-space representative).
    pub fn renormalize(&mut self) {
        let norm = self.w.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for v in self.w.iter_mut() {
                *v /= norm;
            }
        }
    }

    /// Run `burn_in` steps to let the projective chain approach its
    /// stationary distribution.
    pub fn burn_in(&mut self, pi: &[f64], steps: u64, rng: &mut Rng) {
        for s in 0..steps {
            let i = sample_weighted(rng, pi);
            self.q.project(&mut self.w, i);
            if s % 64 == 0 {
                self.renormalize();
            }
        }
        self.renormalize();
    }

    /// Estimate ρ and ρ_i over `steps` steps. The per-step log-progress
    /// `log f(w) − log f(T_i w)` is computed from the exact gain:
    /// `−log(1 − Δf/f)` with both terms O(n).
    pub fn estimate(&mut self, pi: &[f64], steps: u64, rng: &mut Rng) -> ProgressEstimate {
        let n = self.q.n();
        let mut per_coord: Vec<Online> = (0..n).map(|_| Online::new()).collect();
        let mut overall = Online::new();
        let mut f = self.q.objective(&self.w);
        for s in 0..steps {
            let i = sample_weighted(rng, pi);
            let gain = self.q.step_gain(&self.w, i);
            self.q.project(&mut self.w, i);
            // log f − log f' = −log(1 − gain/f); guard the fully-solved
            // coordinate case (gain == f up to fp error)
            let ratio = (gain / f).min(1.0 - 1e-16);
            let logp = -(1.0 - ratio).ln();
            per_coord[i].push(logp);
            overall.push(logp);
            f -= gain;
            if s % 64 == 63 {
                self.renormalize();
                f = self.q.objective(&self.w);
            } else if f <= 0.0 || !f.is_finite() {
                self.renormalize();
                f = self.q.objective(&self.w);
            }
        }
        ProgressEstimate {
            rho: overall.mean(),
            rho_sem: overall.sem(),
            rho_i: per_coord.iter().map(|o| o.mean()).collect(),
            counts: per_coord.iter().map(|o| o.count()).collect(),
            steps,
        }
    }

    /// Apply a fixed coordinate sequence, returning the summed
    /// log-progress `Σ log f_before − log f_after`, renormalizing the
    /// state after every step (scale invariance). Deterministic — the
    /// Pallas `cd_sweep` kernel implements exactly this loop, and the
    /// runtime integration tests cross-check the two.
    pub fn apply_sequence(&mut self, seq: &[u32]) -> f64 {
        let mut total = 0.0;
        for &i in seq {
            let f_before = self.q.objective(&self.w);
            self.q.project(&mut self.w, i as usize);
            let f_after = self.q.objective(&self.w).max(1e-300);
            total += f_before.ln() - f_after.ln();
            self.renormalize();
        }
        total
    }
}

/// Convenience: estimate ρ(π) for a fixed distribution with burn-in.
pub fn progress_rate(
    q: &Quadratic,
    pi: &[f64],
    burn_in: u64,
    steps: u64,
    rng: &mut Rng,
) -> ProgressEstimate {
    let mut chain = Chain::new(q, rng);
    chain.burn_in(pi, burn_in, rng);
    chain.estimate(pi, steps, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rbf(n: usize, seed: u64) -> Quadratic {
        Quadratic::rbf_gram(n, 3.0, &mut Rng::new(seed))
    }

    #[test]
    fn chain_makes_positive_progress() {
        let q = rbf(5, 1);
        let pi = vec![0.2; 5];
        let mut rng = Rng::new(2);
        let est = progress_rate(&q, &pi, 500, 20_000, &mut rng);
        assert!(est.rho > 0.0, "rho {}", est.rho);
        assert!(est.rho_i.iter().all(|&r| r >= 0.0));
        assert_eq!(est.steps, 20_000);
    }

    #[test]
    fn diagonal_q_solves_in_one_sweep() {
        // For diagonal Q each projection zeroes its coordinate exactly.
        let n = 4;
        let mut q = vec![0.0; n * n];
        for i in 0..n {
            q[i * n + i] = 1.0 + i as f64;
        }
        let q = Quadratic::from_matrix(n, q);
        let mut chain = Chain { q: &q, w: vec![1.0, -2.0, 0.5, 3.0] };
        for i in 0..n {
            q.project(&mut chain.w, i);
        }
        assert!(chain.w.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn estimates_are_deterministic_given_seed() {
        let q = rbf(4, 3);
        let pi = vec![0.25; 4];
        let a = progress_rate(&q, &pi, 100, 5_000, &mut Rng::new(7));
        let b = progress_rate(&q, &pi, 100, 5_000, &mut Rng::new(7));
        assert_eq!(a.rho, b.rho);
        assert_eq!(a.rho_i, b.rho_i);
    }

    #[test]
    fn skewed_pi_changes_rho() {
        let q = rbf(5, 4);
        let mut rng = Rng::new(5);
        let uniform = progress_rate(&q, &[0.2; 5], 500, 30_000, &mut rng);
        // near-degenerate distribution: starving coordinates hurts ρ
        let skewed = [0.96, 0.01, 0.01, 0.01, 0.01];
        let skew_est = progress_rate(&q, &skewed, 500, 30_000, &mut rng);
        assert!(
            skew_est.rho < uniform.rho,
            "skewed {} should be worse than uniform {}",
            skew_est.rho,
            uniform.rho
        );
    }

    #[test]
    fn apply_sequence_matches_unnormalized_run() {
        // For a short sequence (no underflow) the renormalized
        // log-progress must equal the raw chain's log f(w0) − log f(w_t)
        // — renormalization is a no-op on progress by scale invariance.
        let q = rbf(4, 6);
        let mut rng = Rng::new(8);
        let mut c1 = Chain::new(&q, &mut rng);
        let w0 = c1.w.clone();
        let seq: Vec<u32> = (0..40).map(|k| (k % 4) as u32).collect();
        let total = c1.apply_sequence(&seq);
        // raw replay without renormalization
        let mut w = w0;
        let f0 = q.objective(&w);
        for &i in &seq {
            q.project(&mut w, i as usize);
        }
        let f_end = q.objective(&w);
        let direct = f0.ln() - f_end.ln();
        assert!(
            (total - direct).abs() < 1e-6 * total.abs().max(1.0),
            "sum {total} vs direct {direct}"
        );
    }

    #[test]
    fn apply_sequence_is_scale_invariant() {
        let q = rbf(5, 10);
        let mut rng = Rng::new(11);
        let mut c1 = Chain::new(&q, &mut rng);
        let mut c2 = Chain { q: &q, w: c1.w.iter().map(|v| v * 123.0).collect() };
        let seq: Vec<u32> = (0..100).map(|k| (k * 3 % 5) as u32).collect();
        let t1 = c1.apply_sequence(&seq);
        let t2 = c2.apply_sequence(&seq);
        assert!((t1 - t2).abs() < 1e-9 * t1.abs().max(1.0), "{t1} vs {t2}");
    }

    #[test]
    fn renormalization_preserves_direction() {
        let q = rbf(3, 9);
        let mut rng = Rng::new(9);
        let mut chain = Chain::new(&q, &mut rng);
        let before = chain.w.clone();
        chain.renormalize();
        // proportional
        let ratio = before[0] / chain.w[0];
        for j in 1..3 {
            assert!((before[j] / chain.w[j] - ratio).abs() < 1e-9);
        }
    }
}
