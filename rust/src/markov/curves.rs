//! Figure 1's perturbation curves: starting from the balanced
//! distribution π̄, vary it along the paper's curves
//!
//! ```text
//! γ̃_{π,i}(t) = π + (2ᵗ − 1)·π_i·e_i
//! γ_{π,i}(t) = γ̃ / ‖γ̃‖₁            (re-normalized to the simplex)
//! ```
//!
//! and plot `ρ(γ_{π̄,i}(t)) / ρ(π̄)` over
//! `t ∈ {−1, −½, −¼, −⅒, 0, ⅒, ¼, ½, 1}`. Conjecture 1 predicts all
//! curves are uni-modal with the maximum at t = 0.

use super::chain::progress_rate;
use super::quadratic::Quadratic;
use crate::util::rng::Rng;

/// The paper's evaluation grid for t.
pub const T_GRID: [f64; 9] = [-1.0, -0.5, -0.25, -0.1, 0.0, 0.1, 0.25, 0.5, 1.0];

/// γ_{π,i}(t): scale coordinate i's probability by 2ᵗ, renormalize.
pub fn gamma_curve(pi: &[f64], i: usize, t: f64) -> Vec<f64> {
    let mut out = pi.to_vec();
    out[i] += (2f64.powf(t) - 1.0) * pi[i];
    let s: f64 = out.iter().sum();
    for v in out.iter_mut() {
        *v /= s;
    }
    out
}

/// One curve of Figure 1: relative rates ρ(γ(t))/ρ(π̄) over [`T_GRID`].
#[derive(Clone, Debug)]
pub struct Curve {
    pub coordinate: usize,
    pub t: Vec<f64>,
    /// ρ(γ(t)) / ρ(π̄)
    pub relative_rho: Vec<f64>,
}

impl Curve {
    /// Uni-modality with maximum at t = 0, up to estimation noise `tol`
    /// (relative). The conjecture's signature in the data.
    pub fn max_at_zero(&self, tol: f64) -> bool {
        // INFALLIBLE: the constructor builds `t` as a symmetric grid
        // around (and including) 0.
        let zero_idx = self.t.iter().position(|&t| t == 0.0).expect("grid contains 0");
        let at_zero = self.relative_rho[zero_idx];
        self.relative_rho.iter().all(|&r| r <= at_zero + tol)
    }
}

/// Estimate all n curves around a distribution.
pub fn curves_around(
    q: &Quadratic,
    pi: &[f64],
    burn_in: u64,
    steps: u64,
    rng: &mut Rng,
) -> Vec<Curve> {
    let base = progress_rate(q, pi, burn_in, steps, rng).rho;
    (0..q.n())
        .map(|i| {
            let mut rel = Vec::with_capacity(T_GRID.len());
            for &t in &T_GRID {
                if t == 0.0 {
                    rel.push(1.0);
                    continue;
                }
                let gamma = gamma_curve(pi, i, t);
                let est = progress_rate(q, &gamma, burn_in, steps, rng);
                rel.push(est.rho / base);
            }
            Curve { coordinate: i, t: T_GRID.to_vec(), relative_rho: rel }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_curve_is_distribution() {
        let pi = vec![0.1, 0.2, 0.3, 0.4];
        for i in 0..4 {
            for &t in &T_GRID {
                let g = gamma_curve(&pi, i, t);
                let s: f64 = g.iter().sum();
                assert!((s - 1.0).abs() < 1e-12);
                assert!(g.iter().all(|&v| v > 0.0));
            }
        }
    }

    #[test]
    fn gamma_at_zero_is_identity() {
        let pi = vec![0.25, 0.25, 0.5];
        let g = gamma_curve(&pi, 1, 0.0);
        for (a, b) in g.iter().zip(pi.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn gamma_doubles_and_halves_mass() {
        let pi = vec![0.5, 0.5];
        let g_up = gamma_curve(&pi, 0, 1.0); // 2× mass on coord 0 before renorm
        assert!(g_up[0] > g_up[1]);
        let g_dn = gamma_curve(&pi, 0, -1.0); // ½× mass
        assert!(g_dn[0] < g_dn[1]);
        // exact values: up = (1.0, 0.5)/1.5, down = (0.25,0.5)/0.75
        assert!((g_up[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!((g_dn[0] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn curve_max_detection() {
        let c = Curve {
            coordinate: 0,
            t: T_GRID.to_vec(),
            relative_rho: vec![0.8, 0.9, 0.95, 0.99, 1.0, 0.99, 0.97, 0.9, 0.85],
        };
        assert!(c.max_at_zero(0.0));
        let bad = Curve {
            coordinate: 0,
            t: T_GRID.to_vec(),
            relative_rho: vec![0.8, 0.9, 0.95, 0.99, 1.0, 1.05, 0.97, 0.9, 0.85],
        };
        assert!(!bad.max_at_zero(0.01));
        assert!(bad.max_at_zero(0.06));
    }

    #[test]
    fn small_instance_curves_peak_at_balanced_pi() {
        // End-to-end miniature of Figure 1 on a 3-coordinate instance:
        // balance, then verify all curves peak at t = 0 within noise.
        let q = Quadratic::rbf_gram(3, 3.0, &mut Rng::new(11));
        let mut rng = Rng::new(12);
        let res = crate::markov::balance::balance(
            &q,
            &crate::markov::balance::BalanceConfig {
                steps_per_round: 30_000,
                max_rounds: 40,
                tol: 0.02,
                ..Default::default()
            },
            &mut rng,
        );
        let curves = curves_around(&q, &res.pi, 2_000, 40_000, &mut rng);
        for c in &curves {
            assert!(
                c.max_at_zero(0.02),
                "coordinate {} curve not peaked at 0: {:?}",
                c.coordinate,
                c.relative_rho
            );
        }
    }
}
