//! §6 Markov-chain analysis of randomized CD: quadratic model problems,
//! the projective-chain simulator with ρ / ρ_i estimation, the Rprop
//! π-balancer, and Figure 1's perturbation curves.

pub mod balance;
pub mod chain;
pub mod curves;
pub mod quadratic;

pub use balance::{balance, BalanceConfig, BalanceResult};
pub use chain::{progress_rate, Chain, ProgressEstimate};
pub use curves::{curves_around, gamma_curve, Curve, T_GRID};
pub use quadratic::Quadratic;
