//! Unconstrained quadratic model problems `f(w) = ½ wᵀQw` for the §6
//! Markov-chain analysis, with the paper's two instance generators:
//!
//! * RBF Gram matrices of random 2-D point sets (the kernel-learning
//!   analog used for Figure 1), `Q_ij = exp(−‖x_i−x_j‖²/(2σ²))`, σ = 3;
//! * `Q = AᵀA` with standard-normal `A` (mentioned as giving similar
//!   results).

use crate::util::rng::Rng;

/// Dense symmetric positive-definite quadratic problem.
#[derive(Clone, Debug)]
pub struct Quadratic {
    n: usize,
    /// row-major n×n
    q: Vec<f64>,
}

impl Quadratic {
    pub fn from_matrix(n: usize, q: Vec<f64>) -> Self {
        assert_eq!(q.len(), n * n);
        Self { n, q }
    }

    /// RBF Gram matrix of `n` i.i.d. standard-normal points in R², with
    /// kernel width σ (paper: σ = 3). A tiny ridge keeps the matrix
    /// strictly positive definite for degenerate draws.
    pub fn rbf_gram(n: usize, sigma: f64, rng: &mut Rng) -> Self {
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.gaussian(), rng.gaussian())).collect();
        let mut q = vec![0.0; n * n];
        let denom = 2.0 * sigma * sigma;
        for i in 0..n {
            for j in 0..n {
                let dx = pts[i].0 - pts[j].0;
                let dy = pts[i].1 - pts[j].1;
                q[i * n + j] = (-(dx * dx + dy * dy) / denom).exp();
            }
            q[i * n + i] += 1e-10;
        }
        Self { n, q }
    }

    /// `Q = AᵀA + εI` with `A` standard normal `m×n` (m = 2n for good
    /// conditioning without degeneracy).
    pub fn gram_normal(n: usize, rng: &mut Rng) -> Self {
        let m = 2 * n;
        let a: Vec<f64> = (0..m * n).map(|_| rng.gaussian()).collect();
        let mut q = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for r in 0..m {
                    s += a[r * n + i] * a[r * n + j];
                }
                q[i * n + j] = s / m as f64;
            }
            q[i * n + i] += 1e-10;
        }
        Self { n, q }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        self.q[i * self.n + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.q[i * self.n..(i + 1) * self.n]
    }

    /// f(w) = ½ wᵀQw.
    pub fn objective(&self, w: &[f64]) -> f64 {
        debug_assert_eq!(w.len(), self.n);
        let mut total = 0.0;
        for i in 0..self.n {
            let qi = self.row(i);
            let mut s = 0.0;
            for j in 0..self.n {
                s += qi[j] * w[j];
            }
            total += w[i] * s;
        }
        0.5 * total
    }

    /// One CD projection step `w ← T_i w` (exact 1-D Newton step):
    /// `w_i ← w_i − (Q_i·w)/Q_ii`. Returns the step Δw_i.
    #[inline]
    pub fn project(&self, w: &mut [f64], i: usize) -> f64 {
        let qi = self.row(i);
        let mut g = 0.0;
        for j in 0..self.n {
            g += qi[j] * w[j];
        }
        let d = -g / qi[i];
        w[i] += d;
        d
    }

    /// Exact single-step decrease of f for a step on coordinate i at w
    /// (before the step): Δf = g²/(2Q_ii).
    #[inline]
    pub fn step_gain(&self, w: &[f64], i: usize) -> f64 {
        let qi = self.row(i);
        let mut g = 0.0;
        for j in 0..self.n {
            g += qi[j] * w[j];
        }
        g * g / (2.0 * qi[i])
    }

    /// Smallest/largest diagonal entries (sanity checks).
    pub fn diag_range(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..self.n {
            let d = self.entry(i, i);
            lo = lo.min(d);
            hi = hi.max(d);
        }
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_gram_is_symmetric_unit_diagonal() {
        let mut rng = Rng::new(1);
        let q = Quadratic::rbf_gram(6, 3.0, &mut rng);
        for i in 0..6 {
            assert!((q.entry(i, i) - 1.0).abs() < 1e-9);
            for j in 0..6 {
                assert!((q.entry(i, j) - q.entry(j, i)).abs() < 1e-12);
                assert!(q.entry(i, j) > 0.0 && q.entry(i, j) <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn objective_positive_definite() {
        let mut rng = Rng::new(2);
        for gen in 0..2 {
            let q = if gen == 0 {
                Quadratic::rbf_gram(5, 3.0, &mut rng)
            } else {
                Quadratic::gram_normal(5, &mut rng)
            };
            for _ in 0..50 {
                let w: Vec<f64> = (0..5).map(|_| rng.gaussian()).collect();
                let f = q.objective(&w);
                assert!(f > 0.0, "non-PD objective {f}");
            }
        }
    }

    #[test]
    fn projection_lands_on_hyperplane_and_descends() {
        let mut rng = Rng::new(3);
        let q = Quadratic::rbf_gram(7, 3.0, &mut rng);
        let mut w: Vec<f64> = (0..7).map(|_| rng.gaussian()).collect();
        for step in 0..100 {
            let i = step % 7;
            let before = q.objective(&w);
            let gain = q.step_gain(&w, i);
            q.project(&mut w, i);
            let after = q.objective(&w);
            // gradient along i vanishes after the step
            let g: f64 = (0..7).map(|j| q.entry(i, j) * w[j]).sum();
            assert!(g.abs() < 1e-9, "residual gradient {g}");
            // descent and exact gain match
            assert!(after <= before + 1e-12);
            assert!((before - after - gain).abs() < 1e-9 * before.max(1.0));
        }
    }

    #[test]
    fn projection_is_idempotent() {
        let mut rng = Rng::new(4);
        let q = Quadratic::rbf_gram(5, 3.0, &mut rng);
        let mut w: Vec<f64> = (0..5).map(|_| rng.gaussian()).collect();
        q.project(&mut w, 2);
        let w1 = w.clone();
        let d = q.project(&mut w, 2);
        assert!(d.abs() < 1e-12);
        assert_eq!(w, w1);
    }
}
