//! Measurement substrate: the paper's *operations* metric (multiply-adds
//! in derivative computations — its implementation-independent cost
//! model, §7), plus convergence-trace recording.

pub mod recorder;

pub use recorder::{Trace, TracePoint};

/// Counter for the paper's "number of operations" metric: multiplications
/// and additions needed to compute derivatives. Solvers add `nnz(x_i)`
/// per sparse dot / axpy touching instance (or feature) `i`.
#[derive(Clone, Debug, Default)]
pub struct OpCounter {
    ops: u64,
    iterations: u64,
}

impl OpCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one CD iteration costing `ops` multiply-adds.
    #[inline]
    pub fn step(&mut self, ops: usize) {
        self.ops += ops as u64;
        self.iterations += 1;
    }

    /// Record extra operations that are not an iteration (e.g. a
    /// stopping-criterion sweep or shrinking bookkeeping).
    #[inline]
    pub fn extra(&mut self, ops: usize) {
        self.ops += ops as u64;
    }

    pub fn ops(&self) -> u64 {
        self.ops
    }

    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    pub fn merge(&mut self, other: &OpCounter) {
        self.ops += other.ops;
        self.iterations += other.iterations;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let mut c = OpCounter::new();
        c.step(10);
        c.step(5);
        c.extra(3);
        assert_eq!(c.ops(), 18);
        assert_eq!(c.iterations(), 2);
        let mut d = OpCounter::new();
        d.step(2);
        c.merge(&d);
        assert_eq!(c.ops(), 20);
        assert_eq!(c.iterations(), 3);
    }
}
