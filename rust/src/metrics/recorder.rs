//! Convergence-trace recording: (iteration, operations, wall-clock,
//! objective / KKT violation) samples along a solver run, for the
//! figure-style outputs and EXPERIMENTS.md evidence.

use crate::util::json::Json;

/// One sample along an optimization run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    pub iteration: u64,
    pub ops: u64,
    pub seconds: f64,
    pub objective: f64,
    /// maximum KKT violation (or gradient-infinity-norm for unconstrained
    /// problems) at this point — the stopping-criterion quantity
    pub violation: f64,
}

/// A recorded convergence trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, p: TracePoint) {
        self.points.push(p);
    }

    pub fn last(&self) -> Option<&TracePoint> {
        self.points.last()
    }

    /// Objective values are non-increasing along a CD run (descent
    /// method); returns the first violating pair if any. Tolerance covers
    /// floating-point noise on plateaus.
    pub fn check_monotone(&self, tol: f64) -> Result<(), (usize, f64, f64)> {
        for (i, w) in self.points.windows(2).enumerate() {
            let scale = 1.0_f64.max(w[0].objective.abs());
            if w[1].objective > w[0].objective + tol * scale {
                return Err((i, w[0].objective, w[1].objective));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|p| {
                    let mut o = Json::obj();
                    o.set("iter", Json::Num(p.iteration as f64))
                        .set("ops", Json::Num(p.ops as f64))
                        .set("sec", Json::Num(p.seconds))
                        .set("obj", Json::Num(p.objective))
                        .set("viol", Json::Num(p.violation));
                    o
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(it: u64, obj: f64) -> TracePoint {
        TracePoint { iteration: it, ops: it * 10, seconds: it as f64, objective: obj, violation: 0.1 }
    }

    #[test]
    fn monotone_check() {
        let mut t = Trace::new();
        t.push(p(1, 10.0));
        t.push(p(2, 5.0));
        t.push(p(3, 5.0));
        assert!(t.check_monotone(1e-12).is_ok());
        t.push(p(4, 6.0));
        assert!(t.check_monotone(1e-12).is_err());
    }

    #[test]
    fn json_shape() {
        let mut t = Trace::new();
        t.push(p(1, 2.0));
        let j = t.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("obj").unwrap().as_f64(), Some(2.0));
    }
}
