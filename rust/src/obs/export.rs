//! First-party Prometheus text-exposition renderer for the live
//! registry (`GET /metrics` on [`crate::obs::server`]).
//!
//! Implements the exposition format (version 0.0.4) directly — `# HELP`
//! / `# TYPE` headers, label-value escaping (`\\`, `\"`, `\n`), and the
//! cumulative `_bucket`/`_sum`/`_count` encoding of the log₂ epoch-time
//! histogram — with zero dependencies. Every series carries the
//! registry's constant label set (job identity; `("row", i)` under
//! `sweep`), so multiple jobs scraped through one gateway stay
//! distinguishable.
//!
//! Metric names are prefixed `acf_`; the full catalog is documented in
//! `docs/ARCHITECTURE.md` ("Live telemetry").

use super::live::LiveMetrics;
use super::HIST_BUCKETS;

/// Escape a label value: backslash, double quote and newline, per the
/// exposition format.
fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape `# HELP` text: backslash and newline only (quotes are legal).
fn escape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a sample value: integers without a decimal point, floats via
/// the shortest round-tripping form, infinities as `+Inf`/`-Inf`.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 {
            "+Inf".to_string()
        } else {
            "-Inf".to_string()
        }
    } else if v == v.trunc() && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Incremental writer for one exposition document.
struct Prom<'a> {
    out: String,
    base: &'a [(String, String)],
}

impl Prom<'_> {
    fn family(&mut self, name: &str, help: &str, typ: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(&escape_help(help));
        self.out.push('\n');
        self.out.push_str("# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(typ);
        self.out.push('\n');
    }

    /// One sample line; `extra` labels follow the registry's base set.
    fn sample(&mut self, name: &str, extra: &[(&str, String)], value: f64) {
        self.out.push_str(name);
        if !self.base.is_empty() || !extra.is_empty() {
            self.out.push('{');
            let mut first = true;
            for (k, v) in self.base.iter() {
                if !first {
                    self.out.push(',');
                }
                first = false;
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label(v));
                self.out.push('"');
            }
            for (k, v) in extra {
                if !first {
                    self.out.push(',');
                }
                first = false;
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }
}

/// Render the registry's latest published point as one Prometheus
/// text-exposition document.
pub fn render_prometheus(live: &LiveMetrics) -> String {
    let point = live.latest();
    let snap = &point.snapshot;
    let ms = &point.merge_stats;
    let mut w = Prom { out: String::with_capacity(4096), base: live.labels() };

    w.family(
        "acf_uptime_seconds",
        "Seconds since the job started publishing live metrics.",
        "gauge",
    );
    w.sample("acf_uptime_seconds", &[], snap.t1);
    w.family("acf_scrapes_total", "Scrapes served by the /metrics endpoint.", "counter");
    w.sample("acf_scrapes_total", &[], live.scrapes() as f64);

    w.family("acf_shard_epochs_total", "Local epochs completed, per shard.", "counter");
    for (k, sw) in snap.per_shard.iter().enumerate() {
        w.sample("acf_shard_epochs_total", &[("shard", k.to_string())], sw.epochs as f64);
    }
    w.family("acf_shard_steps_total", "Coordinate steps taken, per shard.", "counter");
    for (k, sw) in snap.per_shard.iter().enumerate() {
        w.sample("acf_shard_steps_total", &[("shard", k.to_string())], sw.steps as f64);
    }
    w.family("acf_shard_ops_total", "Multiply-add operations spent, per shard.", "counter");
    for (k, sw) in snap.per_shard.iter().enumerate() {
        w.sample("acf_shard_ops_total", &[("shard", k.to_string())], sw.ops as f64);
    }
    w.family(
        "acf_shard_compute_seconds_total",
        "Seconds of epoch compute, per shard.",
        "counter",
    );
    for (k, sw) in snap.per_shard.iter().enumerate() {
        w.sample(
            "acf_shard_compute_seconds_total",
            &[("shard", k.to_string())],
            sw.compute_nanos as f64 * 1e-9,
        );
    }

    // Log₂ epoch-duration histogram. Internal bucket i counts
    // [2^(i−1), 2^i) ns, so its inclusive Prometheus upper bound is
    // 2^i ns; the last internal bucket is the +Inf overflow.
    w.family(
        "acf_epoch_duration_seconds",
        "Distribution of local-epoch compute times (log2 buckets).",
        "histogram",
    );
    let mut cumulative = 0u64;
    for (i, &c) in snap.epoch_nanos_hist.iter().take(HIST_BUCKETS - 1).enumerate() {
        cumulative += c;
        let le = (1u64 << i) as f64 * 1e-9;
        w.sample(
            "acf_epoch_duration_seconds_bucket",
            &[("le", fmt_value(le))],
            cumulative as f64,
        );
    }
    cumulative += snap.epoch_nanos_hist[HIST_BUCKETS - 1];
    w.sample("acf_epoch_duration_seconds_bucket", &[("le", "+Inf".to_string())], cumulative as f64);
    let compute_total: u64 = snap.per_shard.iter().map(|sw| sw.compute_nanos).sum();
    w.sample("acf_epoch_duration_seconds_sum", &[], compute_total as f64 * 1e-9);
    w.sample("acf_epoch_duration_seconds_count", &[], cumulative as f64);

    w.family(
        "acf_merge_submissions_total",
        "Merge decisions in submissions, by outcome tier.",
        "counter",
    );
    for (outcome, count) in [
        ("additive", snap.merge.additive),
        ("damped", snap.merge.damped),
        ("rejected", snap.merge.rejected),
        ("stale", snap.merge.stale),
    ] {
        w.sample("acf_merge_submissions_total", &[("outcome", outcome.to_string())], count as f64);
    }
    w.family(
        "acf_merge_acceptance_rate",
        "Accepted share of attempted submissions (1 when none).",
        "gauge",
    );
    w.sample("acf_merge_acceptance_rate", &[], snap.merge.acceptance_rate());
    w.family(
        "acf_merge_staleness_total",
        "Merge decisions by snapshot staleness (16+ is the overflow bucket).",
        "counter",
    );
    for (i, &c) in snap.staleness_hist.iter().enumerate() {
        let label =
            if i + 1 == snap.staleness_hist.len() { "16+".to_string() } else { i.to_string() };
        w.sample("acf_merge_staleness_total", &[("staleness", label)], c as f64);
    }
    w.family(
        "acf_merge_wait_seconds_total",
        "Seconds the merger spent idle on its queue.",
        "counter",
    );
    w.sample("acf_merge_wait_seconds_total", &[], snap.merge_wait_nanos as f64 * 1e-9);

    if let Some((_, tau)) = snap.tau.last() {
        w.family("acf_staleness_tau", "Current staleness bound (last adaptive move).", "gauge");
        w.sample("acf_staleness_tau", &[], *tau as f64);
    }
    if let Some(f) = snap.last_objective {
        w.family("acf_objective", "Exact objective at the last publish.", "gauge");
        w.sample("acf_objective", &[], f);
    }

    w.family("acf_pool_rounds_total", "Fork-join rounds dispatched by the sync engine.", "counter");
    w.sample("acf_pool_rounds_total", &[], snap.pool_rounds as f64);
    w.family(
        "acf_queue_pushes_total",
        "Submissions pushed through the async merge queue.",
        "counter",
    );
    w.sample("acf_queue_pushes_total", &[], snap.queue_pushes as f64);
    w.family("acf_queue_max_depth", "Largest merge-queue depth observed.", "gauge");
    w.sample("acf_queue_max_depth", &[], snap.queue_max_depth as f64);

    w.family(
        "acf_objective_evals_total",
        "Exact shared-objective evaluations by the merger.",
        "counter",
    );
    w.sample("acf_objective_evals_total", &[], ms.objective_evals as f64);
    w.family(
        "acf_accepted_submissions_total",
        "Submissions folded into accepted publishes.",
        "counter",
    );
    w.sample("acf_accepted_submissions_total", &[], ms.accepted_submissions as f64);
    w.family(
        "acf_rejected_submissions_total",
        "Submissions rejected by the exact objective check.",
        "counter",
    );
    w.sample("acf_rejected_submissions_total", &[], ms.rejected_submissions as f64);
    w.family(
        "acf_batched_merges_total",
        "Accepted publishes that folded a whole batch.",
        "counter",
    );
    w.sample("acf_batched_merges_total", &[], ms.batched_merges as f64);

    w.out
}

#[cfg(test)]
mod tests {
    use super::super::live::{LiveMetrics, LiveRecorder};
    use super::super::MergeTier;
    use super::*;
    use std::sync::Arc;

    /// Minimal exposition-format checker: every non-comment line is
    /// `name{labels} value` with a parseable value; returns the samples.
    fn parse(text: &str) -> Vec<(String, Vec<(String, String)>, f64)> {
        let mut out = Vec::new();
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP ") || line.starts_with("# TYPE "),
                    "bad comment: {line}"
                );
                continue;
            }
            let (series, value) =
                line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line}"));
            let v = match value {
                "+Inf" => f64::INFINITY,
                "-Inf" => f64::NEG_INFINITY,
                other => other.parse::<f64>().unwrap_or_else(|_| panic!("bad value: {line}")),
            };
            let (name, labels) = match series.split_once('{') {
                None => (series.to_string(), Vec::new()),
                Some((n, rest)) => {
                    let body = rest.strip_suffix('}').unwrap_or_else(|| panic!("no close: {line}"));
                    let mut labels = Vec::new();
                    // split on `",` boundaries — label values in these
                    // tests never embed that sequence
                    for pair in body.split("\",") {
                        let pair = pair.strip_suffix('"').unwrap_or(pair);
                        let (k, v) = pair
                            .split_once("=\"")
                            .unwrap_or_else(|| panic!("bad label: {line}"));
                        labels.push((k.to_string(), v.to_string()));
                    }
                    (n.to_string(), labels)
                }
            };
            out.push((name, labels, v));
        }
        out
    }

    fn get<'a>(
        samples: &'a [(String, Vec<(String, String)>, f64)],
        name: &str,
    ) -> Vec<&'a (String, Vec<(String, String)>, f64)> {
        samples.iter().filter(|(n, _, _)| n == name).collect()
    }

    #[test]
    fn empty_registry_renders_parseable_exposition() {
        let live = LiveMetrics::new(Vec::new());
        let text = render_prometheus(&live);
        let samples = parse(&text);
        assert!(!samples.is_empty());
        // no publish yet: counters at zero, acceptance defaults to 1
        assert_eq!(get(&samples, "acf_scrapes_total")[0].2, 0.0);
        assert_eq!(get(&samples, "acf_merge_acceptance_rate")[0].2, 1.0);
        assert_eq!(get(&samples, "acf_epoch_duration_seconds_count")[0].2, 0.0);
        // optional gauges absent without data
        assert!(get(&samples, "acf_objective").is_empty());
        assert!(get(&samples, "acf_staleness_tau").is_empty());
        // no per-shard series for a zero-shard snapshot
        assert!(get(&samples, "acf_shard_epochs_total").is_empty());
    }

    #[test]
    fn label_values_and_help_are_escaped() {
        let live = LiveMetrics::new(vec![
            ("dataset".to_string(), "a\\b\"c\nd".to_string()),
            ("job".to_string(), "plain".to_string()),
        ]);
        let text = render_prometheus(&live);
        assert!(
            text.contains(r#"dataset="a\\b\"c\nd""#),
            "label not escaped:\n{text}"
        );
        // escaped text stays on one physical line
        for line in text.lines() {
            assert!(!line.is_empty());
        }
        assert_eq!(escape_help("multi\nline \\ text"), "multi\\nline \\\\ text");
        assert_eq!(escape_label(r#"q"q"#), r#"q\"q"#);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_monotone() {
        let live = Arc::new(LiveMetrics::new(Vec::new()));
        let mut rec = LiveRecorder::new(Arc::clone(&live), 2);
        // 900 ns → bucket 10; 2 000 ns → bucket 11; 1 ns → bucket 1
        rec.epoch(0, 10, 100, 900);
        rec.epoch(1, 10, 100, 2_000);
        rec.epoch(0, 10, 100, 1);
        rec.flush();
        let samples = parse(&render_prometheus(&live));
        let buckets = get(&samples, "acf_epoch_duration_seconds_bucket");
        assert_eq!(buckets.len(), super::super::HIST_BUCKETS);
        let mut prev = 0.0;
        for (_, labels, v) in &buckets {
            assert_eq!(labels[0].0, "le");
            assert!(*v >= prev, "bucket counts must be cumulative: {v} < {prev}");
            prev = *v;
        }
        assert_eq!(buckets.last().unwrap().1[0].1, "+Inf");
        assert_eq!(buckets.last().unwrap().2, 3.0);
        assert_eq!(get(&samples, "acf_epoch_duration_seconds_count")[0].2, 3.0);
        let sum = get(&samples, "acf_epoch_duration_seconds_sum")[0].2;
        assert!((sum - 2_901e-9).abs() < 1e-15, "sum {sum}");
        // `le` values strictly increase up to the overflow bucket
        let les: Vec<f64> = buckets[..buckets.len() - 1]
            .iter()
            .map(|(_, l, _)| l[0].1.parse::<f64>().unwrap())
            .collect();
        assert!(les.windows(2).all(|w| w[0] < w[1]), "{les:?}");
    }

    #[test]
    fn series_reflect_recorder_state() {
        let live = Arc::new(LiveMetrics::new(vec![("row".to_string(), "3".to_string())]));
        let mut rec = LiveRecorder::new(Arc::clone(&live), 1);
        rec.epoch(0, 50, 700, 900);
        rec.merge_outcome(MergeTier::Additive, 0, 4);
        rec.merge_outcome(MergeTier::Rejected, 2, 1);
        rec.objective(-2.5);
        rec.tau(3);
        rec.engine(7, 21, 4);
        rec.flush();
        live.record_scrape();
        let samples = parse(&render_prometheus(&live));
        // every series carries the registry label
        for (name, labels, _) in &samples {
            assert_eq!(labels[0], ("row".to_string(), "3".to_string()), "{name}");
        }
        let find = |name: &str| get(&samples, name)[0].2;
        assert_eq!(find("acf_scrapes_total"), 1.0);
        assert_eq!(find("acf_shard_steps_total"), 50.0);
        assert_eq!(find("acf_objective"), -2.5);
        assert_eq!(find("acf_staleness_tau"), 3.0);
        assert_eq!(find("acf_pool_rounds_total"), 7.0);
        assert_eq!(find("acf_queue_pushes_total"), 21.0);
        assert_eq!(find("acf_queue_max_depth"), 4.0);
        let outcomes = get(&samples, "acf_merge_submissions_total");
        let additive = outcomes
            .iter()
            .find(|(_, l, _)| l.iter().any(|(k, v)| k == "outcome" && v == "additive"))
            .unwrap();
        assert_eq!(additive.2, 4.0);
        assert!((find("acf_merge_acceptance_rate") - 4.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn value_formatting_covers_edge_cases() {
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(12.0), "12");
        assert_eq!(fmt_value(-3.0), "-3");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(f64::NAN), "NaN");
        assert_eq!(fmt_value(0.5), "0.5");
        let parsed: f64 = fmt_value(1e-9).parse().unwrap();
        assert_eq!(parsed, 1e-9);
    }
}
