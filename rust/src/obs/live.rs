//! Live metrics registry — the in-process bridge between a running job
//! and the HTTP telemetry endpoints ([`crate::obs::server`]).
//!
//! The trace rings are drain-once: the JSONL sink consumes them after
//! the run, so a scraper cannot read them mid-flight without stealing
//! events from the trace. Instead the engine's driving thread (the sync
//! epoch loop, the async merger, or a serial solver's epoch boundary)
//! owns a [`LiveRecorder`] — a running [`MetricsSnapshot`] fed the same
//! observations the rings get — and publishes an immutable [`LivePoint`]
//! into the shared [`LiveMetrics`] registry. Scrapers only ever clone an
//! `Arc` out of the registry.
//!
//! Non-perturbation: the recorder lives entirely on the driving thread
//! and only *reads* solver state. A publish is one snapshot clone plus
//! one mutex-guarded pointer swap (`std` has no atomic `Arc` swap; the
//! mutex is held for the O(1) exchange only, mirroring the engine's
//! `PublishSlot`). Worker hot loops are untouched, and with no
//! `--metrics-addr` no registry or recorder is constructed at all, so
//! results stay bit-identical to an instrumented-but-idle build.

use super::{MergeTier, MetricsSnapshot};
use crate::shard::MergeStats;
use crate::util::sync;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cap on the τ-trajectory length a live snapshot retains (the JSONL
/// plane keeps the full trajectory; live scrapes only need the recent
/// tail and must stay O(1) per publish).
pub const TAU_POINT_CAP: usize = 256;

/// One published observation: the whole-run metrics fold plus the
/// merge-layer accounting at publish time.
#[derive(Clone, Debug)]
pub struct LivePoint {
    /// Whole-run aggregation (`t0 = 0`, `t1` = seconds since the
    /// recorder started).
    pub snapshot: MetricsSnapshot,
    /// Merge-layer accounting (authoritative driver/merger counters).
    pub merge_stats: MergeStats,
}

impl LivePoint {
    fn empty() -> LivePoint {
        LivePoint {
            snapshot: MetricsSnapshot::from_events(&[], 0, 0.0, 0.0),
            merge_stats: MergeStats::default(),
        }
    }
}

/// Shared registry the telemetry server reads and the run publishes
/// into. One instance per job (`--metrics-addr`); sweeps label each
/// row's registry so scrapes can tell the series apart.
#[derive(Debug)]
pub struct LiveMetrics {
    /// Constant `(name, value)` label pairs stamped on every exported
    /// series (job identity; `("row", i)` under `sweep`).
    labels: Vec<(String, String)>,
    scrapes: AtomicU64,
    /// Latest published point. The mutex guards an O(1) `Arc`
    /// clone/replace only — never the snapshot contents.
    latest: Mutex<Arc<LivePoint>>,
}

impl LiveMetrics {
    pub fn new(labels: Vec<(String, String)>) -> LiveMetrics {
        LiveMetrics {
            labels,
            scrapes: AtomicU64::new(0),
            latest: Mutex::new(Arc::new(LivePoint::empty())),
        }
    }

    /// The constant label set stamped on every exported series.
    pub fn labels(&self) -> &[(String, String)] {
        &self.labels
    }

    /// The most recently published point (the empty point before the
    /// first publish).
    pub fn latest(&self) -> Arc<LivePoint> {
        sync::lock(&self.latest).clone()
    }

    /// Replace the published point (called by [`LiveRecorder::flush`]).
    pub fn publish(&self, point: LivePoint) {
        *sync::lock(&self.latest) = Arc::new(point);
    }

    /// Count one `/metrics` scrape; returns the new total.
    pub fn record_scrape(&self) -> u64 {
        // ORDERING: Relaxed: pure monotone counter; the scrape *payload* is
        // published via the `latest` mutex, not this atomic.
        self.scrapes.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Scrapes served so far.
    pub fn scrapes(&self) -> u64 {
        // ORDERING: Relaxed: statistics read; no ordering with the payload.
        self.scrapes.load(Ordering::Relaxed)
    }
}

/// Driver-thread accumulator feeding a [`LiveMetrics`] registry. Mirrors
/// the fold rules of [`MetricsSnapshot::from_events`], but incrementally
/// and without touching the event rings.
pub struct LiveRecorder {
    target: Arc<LiveMetrics>,
    start: Instant,
    snap: MetricsSnapshot,
    merge_stats: MergeStats,
}

impl LiveRecorder {
    /// `n_shards` sizes the per-shard table (0 for serial runs).
    pub fn new(target: Arc<LiveMetrics>, n_shards: usize) -> LiveRecorder {
        LiveRecorder {
            target,
            start: Instant::now(),
            snap: MetricsSnapshot::from_events(&[], n_shards, 0.0, 0.0),
            merge_stats: MergeStats::default(),
        }
    }

    fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// One completed local epoch on `shard`.
    pub fn epoch(&mut self, shard: u32, steps: u64, ops: u64, nanos: u64) {
        if let Some(w) = self.snap.per_shard.get_mut(shard as usize) {
            w.epochs += 1;
            w.steps += steps;
            w.ops += ops;
            w.compute_nanos += nanos;
        }
        self.snap.epoch_nanos_hist[super::log2_bucket(nanos)] += 1;
    }

    /// One merge decision covering `batch` submissions.
    pub fn merge_outcome(&mut self, tier: MergeTier, staleness: u64, batch: u64) {
        let subs = batch.max(1);
        match tier {
            MergeTier::Additive => self.snap.merge.additive += subs,
            MergeTier::Damped => self.snap.merge.damped += subs,
            MergeTier::Rejected => self.snap.merge.rejected += subs,
            MergeTier::Stale => self.snap.merge.stale += subs,
        }
        self.snap.staleness_hist[(staleness as usize).min(super::STALENESS_BUCKETS - 1)] += 1;
    }

    /// Exact objective after a publish / epoch boundary.
    pub fn objective(&mut self, objective: f64) {
        self.snap.last_objective = Some(objective);
    }

    /// A staleness-bound move (adaptive τ).
    pub fn tau(&mut self, tau: u64) {
        if self.snap.tau.len() >= TAU_POINT_CAP {
            self.snap.tau.remove(0);
        }
        self.snap.tau.push((self.secs(), tau));
    }

    /// Nanoseconds the merger just spent waiting on the queue.
    pub fn merge_wait(&mut self, nanos: u64) {
        self.snap.merge_wait_nanos += nanos;
    }

    /// Cumulative engine-infrastructure counters (max-folded, matching
    /// [`crate::obs::Event::EngineStats`]).
    pub fn engine(&mut self, pool_rounds: u64, queue_pushes: u64, queue_max_depth: u64) {
        self.snap.pool_rounds = self.snap.pool_rounds.max(pool_rounds);
        self.snap.queue_pushes = self.snap.queue_pushes.max(queue_pushes);
        self.snap.queue_max_depth = self.snap.queue_max_depth.max(queue_max_depth);
    }

    /// Overwrite the merge-layer accounting with the authoritative
    /// driver/merger counters.
    pub fn set_merge_stats(&mut self, stats: MergeStats) {
        self.merge_stats = stats;
    }

    /// Publish the current fold into the registry.
    pub fn flush(&mut self) {
        self.snap.t1 = self.secs();
        self.target
            .publish(LivePoint { snapshot: self.snap.clone(), merge_stats: self.merge_stats });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_starts_empty_and_publishes_points() {
        let live = LiveMetrics::new(vec![("job".into(), "t".into())]);
        let p0 = live.latest();
        assert_eq!(p0.snapshot.per_shard.len(), 0);
        assert_eq!(p0.snapshot.last_objective, None);
        assert_eq!(live.labels(), &[("job".to_string(), "t".to_string())]);

        let live = Arc::new(live);
        let mut rec = LiveRecorder::new(Arc::clone(&live), 2);
        rec.epoch(0, 50, 700, 900);
        rec.epoch(1, 25, 300, 2_000);
        rec.epoch(7, 1, 1, 1); // out-of-range shard: histogram only
        rec.merge_outcome(MergeTier::Additive, 1, 2);
        rec.merge_outcome(MergeTier::Stale, 20, 1);
        rec.objective(-1.5);
        rec.merge_wait(400);
        rec.engine(3, 10, 2);
        rec.engine(5, 8, 1); // max-fold: pushes must not regress
        rec.set_merge_stats(MergeStats { objective_evals: 9, ..MergeStats::default() });
        rec.flush();

        let p = live.latest();
        let s = &p.snapshot;
        assert_eq!(s.per_shard[0].epochs, 1);
        assert_eq!(s.per_shard[0].steps, 50);
        assert_eq!(s.per_shard[1].ops, 300);
        assert_eq!(s.merge.additive, 2);
        assert_eq!(s.merge.stale, 1);
        assert_eq!(s.staleness_hist[1], 1);
        assert_eq!(s.staleness_hist[super::super::STALENESS_BUCKETS - 1], 1);
        assert_eq!(s.last_objective, Some(-1.5));
        assert_eq!(s.merge_wait_nanos, 400);
        assert_eq!((s.pool_rounds, s.queue_pushes, s.queue_max_depth), (5, 10, 2));
        assert_eq!(s.epoch_nanos_hist.iter().sum::<u64>(), 3);
        assert!(s.t1 >= 0.0);
        assert_eq!(p.merge_stats.objective_evals, 9);
    }

    #[test]
    fn tau_trajectory_is_capped() {
        let live = Arc::new(LiveMetrics::new(Vec::new()));
        let mut rec = LiveRecorder::new(Arc::clone(&live), 1);
        for tau in 0..(TAU_POINT_CAP as u64 + 50) {
            rec.tau(tau);
        }
        rec.flush();
        let s = &live.latest().snapshot;
        assert_eq!(s.tau.len(), TAU_POINT_CAP);
        // oldest entries dropped, newest kept
        assert_eq!(s.tau.last().unwrap().1, TAU_POINT_CAP as u64 + 49);
    }

    #[test]
    fn scrape_counter_increments() {
        let live = LiveMetrics::new(Vec::new());
        assert_eq!(live.scrapes(), 0);
        assert_eq!(live.record_scrape(), 1);
        assert_eq!(live.record_scrape(), 2);
        assert_eq!(live.scrapes(), 2);
    }

    #[test]
    fn flush_overwrites_previous_point() {
        let live = Arc::new(LiveMetrics::new(Vec::new()));
        let mut rec = LiveRecorder::new(Arc::clone(&live), 1);
        rec.objective(1.0);
        rec.flush();
        // a scraper holding the old point keeps a consistent view
        let held = live.latest();
        rec.objective(2.0);
        rec.flush();
        assert_eq!(held.snapshot.last_objective, Some(1.0));
        assert_eq!(live.latest().snapshot.last_objective, Some(2.0));
    }
}
