//! First-party runtime tracing and metrics for the solver runtime.
//!
//! Everything else in this crate reports *end-of-run aggregates*
//! ([`MergeStats`], [`crate::coordinator::JobOutcome`]); this module is
//! the window into the *running* system — how coordinate frequencies,
//! shard frequencies, the staleness bound τ and the merge acceptance
//! behave **over time**, and where wall-clock goes inside the sharded
//! engine. It is zero-dependency by construction: rings are plain
//! atomics ([`ring`]), records are fixed-width word tuples, and the
//! sink is the crate's own [`crate::util::json`] written as JSONL.
//!
//! # Event taxonomy
//!
//! | kind (JSONL)    | level  | emitted by        | payload |
//! |-----------------|--------|-------------------|---------|
//! | `snapshot_take` | events | async worker      | shard, published version snapshotted |
//! | `epoch`         | spans  | worker            | shard, steps, ops, compute nanos |
//! | `submit`        | events | async worker      | shard, base version, queue depth after push |
//! | `merge`         | spans  | merger / driver   | shard (−1 = whole-model sync merge), tier, staleness, batch size |
//! | `publish`       | spans  | merger / driver   | new version, exact objective |
//! | `tau`           | spans  | merger            | new τ, previous τ (adaptive window boundary) |
//! | `park`          | spans  | merger / driver   | shard sent to the parked state |
//! | `merge_wait`    | spans  | merger            | nanos the merger spent idle waiting for submissions |
//! | `selector`      | events | worker / serial   | shard (−1 = serial run), entropy, p_min, p_max of the selector distribution |
//! | `data_extent`   | spans  | driver            | shard, bytes of matrix data its rows span, distinct 4 KiB pages they touch |
//! | `objective`     | spans  | solver / driver   | shard (−1 = serial / whole model), epoch index, exact objective — the convergence curve |
//! | `engine_stats`  | spans  | driver / merger   | cumulative pool rounds dispatched, queue pushes, max observed queue depth |
//!
//! # Levels
//!
//! * `off` — recording is a single branch on a plain field; no ring is
//!   touched, no clock is read. Results are bit-identical to a build
//!   without tracing (instrumentation never reads or perturbs solver
//!   state, RNG streams or iteration counts at *any* level — higher
//!   levels only spend extra wall-clock).
//! * `summary` — no per-event recording; the sink still writes the
//!   end-of-run summary line (merge stats, totals). Use for dashboards
//!   that only need final aggregates.
//! * `spans` — coarse phase records: epochs, merges, publishes, τ
//!   moves, parks, merger idle. O(1) per *epoch*, not per step; the
//!   overhead budget is ≤ 5% on the `scaling_shards` S=4 rows. The
//!   default choice for "where does the time go?".
//! * `events` — adds per-submission records (queue depth, base
//!   versions, snapshot takes) and periodic selector-distribution
//!   probes. Highest fidelity; use on short runs or accept drop-oldest
//!   truncation on long ones.
//!
//! # Overhead model
//!
//! A recorded event is one `Instant` read plus [`ring::EVENT_WORDS`]
//! relaxed atomic stores into a preallocated ring — roughly the cost of
//! a few cache-line writes, no allocation, no lock, no syscall. Spans
//! fire O(1) per epoch/merge; events add O(1) per submission. Rings are
//! fixed-capacity and **drop-oldest**: a long run at `events` level
//! keeps the newest window and reports exactly how many records were
//! overwritten ([`TraceData::dropped`]). Aggregation and file I/O
//! happen strictly after the run (or between synchronized rounds),
//! never on the solver hot path.
//!
//! One measurement substrate: the pre-existing counters are re-exported
//! here — [`OpCounter`], [`Trace`]/[`TracePoint`] (objective-vs-ops
//! curves) and [`MergeStats`] — and the JSONL summary line folds them
//! together with the event-derived [`MetricsSnapshot`]s.
//!
//! # Live telemetry
//!
//! The post-hoc JSONL plane is complemented by an in-flight one:
//! [`live`] holds the latest [`MetricsSnapshot`] behind an `Arc` swap
//! ([`live::LiveMetrics`]), [`export`] renders it in the Prometheus
//! text exposition format, and [`server`] serves both over HTTP
//! (`train --metrics-addr`). The solver side only ever *publishes*
//! finished snapshots into the registry, so the non-perturbation
//! contract of the tracing plane extends to the live plane unchanged.

pub mod export;
pub mod live;
pub mod report;
pub mod ring;
pub mod server;
pub mod sink;

pub use crate::metrics::{OpCounter, Trace, TracePoint};
pub use crate::shard::MergeStats;
pub use ring::{EventRing, DEFAULT_RING_CAP, EVENT_WORDS};

use crate::select::{Selector, SelectorSnapshot};
use crate::util::json::{self, Json};
use std::sync::Arc;
use std::time::Instant;

/// How much the runtime records. Levels are ordered: each one includes
/// everything below it (see module docs for the per-level taxonomy).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// Record nothing; one branch of overhead.
    #[default]
    Off,
    /// End-of-run summary line only.
    Summary,
    /// Coarse phase spans (epochs, merges, publishes, τ, parks).
    Spans,
    /// Spans plus per-submission and selector-distribution events.
    Events,
}

impl TraceLevel {
    /// Accepted `--trace-level` spellings.
    pub const NAMES: [&'static str; 4] = ["off", "summary", "spans", "events"];

    /// Parse a CLI spelling.
    pub fn parse(text: &str) -> Option<TraceLevel> {
        match text {
            "off" => Some(TraceLevel::Off),
            "summary" => Some(TraceLevel::Summary),
            "spans" => Some(TraceLevel::Spans),
            "events" => Some(TraceLevel::Events),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn name(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Summary => "summary",
            TraceLevel::Spans => "spans",
            TraceLevel::Events => "events",
        }
    }
}

/// Outcome tier of one merge attempt (mirrors the engine's
/// additive → damped → rejected ladder plus the staleness gate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeTier {
    /// Exact additive candidate accepted.
    Additive,
    /// θ-damped fallback accepted.
    Damped,
    /// Both candidates would increase the objective; delta returned.
    Rejected,
    /// Base version older than the staleness bound; work discarded.
    Stale,
}

impl MergeTier {
    pub(crate) fn code(self) -> u64 {
        match self {
            MergeTier::Additive => 0,
            MergeTier::Damped => 1,
            MergeTier::Rejected => 2,
            MergeTier::Stale => 3,
        }
    }

    pub(crate) fn from_code(code: u64) -> Option<MergeTier> {
        match code {
            0 => Some(MergeTier::Additive),
            1 => Some(MergeTier::Damped),
            2 => Some(MergeTier::Rejected),
            3 => Some(MergeTier::Stale),
            _ => None,
        }
    }

    /// JSONL spelling.
    pub fn name(self) -> &'static str {
        match self {
            MergeTier::Additive => "additive",
            MergeTier::Damped => "damped",
            MergeTier::Rejected => "rejected",
            MergeTier::Stale => "stale",
        }
    }

    /// Parse the JSONL spelling.
    pub fn parse(text: &str) -> Option<MergeTier> {
        match text {
            "additive" => Some(MergeTier::Additive),
            "damped" => Some(MergeTier::Damped),
            "rejected" => Some(MergeTier::Rejected),
            "stale" => Some(MergeTier::Stale),
            _ => None,
        }
    }
}

/// Ring-index / JSONL marker for "not a specific shard" (the sync
/// whole-model merge, or a serial run). Serialized as `-1`.
pub const NO_SHARD: u32 = u32::MAX;

/// One typed trace record. `t` is nanoseconds since the collector was
/// created; `shard` is [`NO_SHARD`] where no single shard applies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// An async worker cloned the published buffer at `version`.
    SnapshotTake { t: u64, shard: u32, version: u64 },
    /// One local epoch: `steps` coordinate steps costing `ops`
    /// arithmetic operations over `nanos` of compute.
    Epoch { t: u64, shard: u32, steps: u64, ops: u64, nanos: u64 },
    /// An async worker queued a delta; `queue_depth` is the submission
    /// queue length right after the push.
    Submit { t: u64, shard: u32, base_version: u64, queue_depth: u64 },
    /// One merge attempt resolved at `tier`; `staleness` is published
    /// minus base version, `batch` the submissions folded together.
    Merge { t: u64, shard: u32, tier: MergeTier, staleness: u64, batch: u64 },
    /// A new shared buffer became visible with an exact objective.
    Publish { t: u64, version: u64, objective: f64 },
    /// The adaptive controller moved the staleness bound.
    Tau { t: u64, tau: u64, prev: u64 },
    /// A shard was sent to the parked state (no useful work left).
    Park { t: u64, shard: u32 },
    /// The merger sat idle for `nanos` waiting for submissions.
    MergeWait { t: u64, nanos: u64 },
    /// Periodic probe of a selector distribution (natural-log entropy).
    SelectorState { t: u64, shard: u32, entropy: f64, p_min: f64, p_max: f64 },
    /// Data-locality probe emitted once per run by the sharded driver:
    /// the matrix bytes a shard's coordinate rows span and the distinct
    /// 4 KiB pages they touch (working-set size under `--data-backend
    /// mmap`, where pages fault in on first touch).
    DataExtent { t: u64, shard: u32, bytes: u64, pages: u64 },
    /// Exact objective at an epoch boundary — one point of the
    /// convergence curve. Serial solvers emit with [`NO_SHARD`]; the
    /// sharded drivers emit after each publish with the merge epoch.
    Objective { t: u64, shard: u32, epoch: u64, objective: f64 },
    /// Cumulative engine-infrastructure counters: fork-join rounds the
    /// [`crate::util::threadpool::RoundPool`] dispatched, submissions
    /// pushed through the async merge queue, and the largest queue
    /// depth ever observed. Values are monotone; aggregation folds
    /// them with `max`.
    EngineStats { t: u64, pool_rounds: u64, queue_pushes: u64, queue_max_depth: u64 },
}

const TAG_SNAPSHOT_TAKE: u64 = 1;
const TAG_EPOCH: u64 = 2;
const TAG_SUBMIT: u64 = 3;
const TAG_MERGE: u64 = 4;
const TAG_PUBLISH: u64 = 5;
const TAG_TAU: u64 = 6;
const TAG_PARK: u64 = 7;
const TAG_MERGE_WAIT: u64 = 8;
const TAG_SELECTOR: u64 = 9;
const TAG_DATA_EXTENT: u64 = 10;
const TAG_OBJECTIVE: u64 = 11;
const TAG_ENGINE_STATS: u64 = 12;

impl Event {
    /// Nanoseconds since the collector started.
    pub fn t(&self) -> u64 {
        match *self {
            Event::SnapshotTake { t, .. }
            | Event::Epoch { t, .. }
            | Event::Submit { t, .. }
            | Event::Merge { t, .. }
            | Event::Publish { t, .. }
            | Event::Tau { t, .. }
            | Event::Park { t, .. }
            | Event::MergeWait { t, .. }
            | Event::SelectorState { t, .. }
            | Event::DataExtent { t, .. }
            | Event::Objective { t, .. }
            | Event::EngineStats { t, .. } => t,
        }
    }

    /// JSONL `kind` spelling.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::SnapshotTake { .. } => "snapshot_take",
            Event::Epoch { .. } => "epoch",
            Event::Submit { .. } => "submit",
            Event::Merge { .. } => "merge",
            Event::Publish { .. } => "publish",
            Event::Tau { .. } => "tau",
            Event::Park { .. } => "park",
            Event::MergeWait { .. } => "merge_wait",
            Event::SelectorState { .. } => "selector",
            Event::DataExtent { .. } => "data_extent",
            Event::Objective { .. } => "objective",
            Event::EngineStats { .. } => "engine_stats",
        }
    }

    /// Lowest [`TraceLevel`] at which this record is captured.
    pub fn min_level(&self) -> TraceLevel {
        match self {
            Event::SnapshotTake { .. } | Event::Submit { .. } | Event::SelectorState { .. } => TraceLevel::Events,
            _ => TraceLevel::Spans,
        }
    }

    /// Pack into the fixed ring-record width: word 0 holds the kind tag
    /// (low half) and shard id (high half), word 1 the timestamp, words
    /// 2–4 the payload (f64 fields via `to_bits`), word 5 is reserved.
    pub(crate) fn encode(&self) -> [u64; EVENT_WORDS] {
        let (tag, shard, a, b, c) = match *self {
            Event::SnapshotTake { shard, version, .. } => (TAG_SNAPSHOT_TAKE, shard, version, 0, 0),
            Event::Epoch { shard, steps, ops, nanos, .. } => (TAG_EPOCH, shard, steps, ops, nanos),
            Event::Submit { shard, base_version, queue_depth, .. } => (TAG_SUBMIT, shard, base_version, queue_depth, 0),
            Event::Merge { shard, tier, staleness, batch, .. } => (TAG_MERGE, shard, tier.code(), staleness, batch),
            Event::Publish { version, objective, .. } => (TAG_PUBLISH, NO_SHARD, version, objective.to_bits(), 0),
            Event::Tau { tau, prev, .. } => (TAG_TAU, NO_SHARD, tau, prev, 0),
            Event::Park { shard, .. } => (TAG_PARK, shard, 0, 0, 0),
            Event::MergeWait { nanos, .. } => (TAG_MERGE_WAIT, NO_SHARD, nanos, 0, 0),
            Event::SelectorState { shard, entropy, p_min, p_max, .. } => {
                (TAG_SELECTOR, shard, entropy.to_bits(), p_min.to_bits(), p_max.to_bits())
            }
            Event::DataExtent { shard, bytes, pages, .. } => (TAG_DATA_EXTENT, shard, bytes, pages, 0),
            Event::Objective { shard, epoch, objective, .. } => {
                (TAG_OBJECTIVE, shard, epoch, objective.to_bits(), 0)
            }
            Event::EngineStats { pool_rounds, queue_pushes, queue_max_depth, .. } => {
                (TAG_ENGINE_STATS, NO_SHARD, pool_rounds, queue_pushes, queue_max_depth)
            }
        };
        [tag | (u64::from(shard) << 32), self.t(), a, b, c, 0]
    }

    /// Decode a ring record; `None` for an unwritten or unknown slot.
    pub(crate) fn decode(raw: [u64; EVENT_WORDS]) -> Option<Event> {
        let tag = raw[0] & 0xffff_ffff;
        let shard = (raw[0] >> 32) as u32;
        let t = raw[1];
        let (a, b, c) = (raw[2], raw[3], raw[4]);
        match tag {
            TAG_SNAPSHOT_TAKE => Some(Event::SnapshotTake { t, shard, version: a }),
            TAG_EPOCH => Some(Event::Epoch { t, shard, steps: a, ops: b, nanos: c }),
            TAG_SUBMIT => Some(Event::Submit { t, shard, base_version: a, queue_depth: b }),
            TAG_MERGE => Some(Event::Merge {
                t,
                shard,
                tier: MergeTier::from_code(a)?,
                staleness: b,
                batch: c,
            }),
            TAG_PUBLISH => Some(Event::Publish { t, version: a, objective: f64::from_bits(b) }),
            TAG_TAU => Some(Event::Tau { t, tau: a, prev: b }),
            TAG_PARK => Some(Event::Park { t, shard }),
            TAG_MERGE_WAIT => Some(Event::MergeWait { t, nanos: a }),
            TAG_SELECTOR => Some(Event::SelectorState {
                t,
                shard,
                entropy: f64::from_bits(a),
                p_min: f64::from_bits(b),
                p_max: f64::from_bits(c),
            }),
            TAG_DATA_EXTENT => Some(Event::DataExtent { t, shard, bytes: a, pages: b }),
            TAG_OBJECTIVE => {
                Some(Event::Objective { t, shard, epoch: a, objective: f64::from_bits(b) })
            }
            TAG_ENGINE_STATS => Some(Event::EngineStats {
                t,
                pool_rounds: a,
                queue_pushes: b,
                queue_max_depth: c,
            }),
            _ => None,
        }
    }
}

/// The per-run collector: one [`EventRing`] per producer thread plus
/// the shared clock and level. Engine threads receive it behind an
/// `Arc` via `ShardSpec::obs`; serial runs hold a single ring.
#[derive(Debug)]
pub struct Obs {
    level: TraceLevel,
    rings: Vec<EventRing>,
    start: Instant,
}

impl Obs {
    /// A collector with `rings` producer slots of `cap` records each.
    /// The sharded engine expects `shards + 1` rings (ring *k* for
    /// shard *k*, the last ring for the merge driver).
    pub fn new(level: TraceLevel, rings: usize, cap: usize) -> Obs {
        assert!(rings > 0, "need at least one ring");
        Obs {
            level,
            rings: (0..rings).map(|_| EventRing::new(cap)).collect(),
            start: Instant::now(),
        }
    }

    /// Recording level for this run.
    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// Number of producer rings.
    pub fn n_rings(&self) -> usize {
        self.rings.len()
    }

    /// Nanoseconds since the collector was created.
    #[inline]
    pub fn now(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    /// Record an event on ring `ring`. Callers gate on the level first
    /// (see [`Emitter`]); this does not re-check it.
    #[inline]
    pub fn emit(&self, ring: usize, event: Event) {
        self.rings[ring].push(event.encode());
    }

    /// A cheap per-thread handle bound to one ring.
    pub fn emitter(&self, ring: usize) -> Emitter<'_> {
        assert!(ring < self.rings.len(), "ring {ring} out of range");
        Emitter { obs: Some(self), ring }
    }

    /// Fold every ring into one time-sorted event stream with exact
    /// drop accounting. Call at a quiescent point only.
    pub fn drain(&self) -> TraceData {
        let mut events: Vec<Event> = Vec::new();
        let mut dropped = 0u64;
        let mut total = 0u64;
        for ring in &self.rings {
            dropped += ring.dropped();
            total += ring.total();
            events.extend(ring.drain().into_iter().filter_map(Event::decode));
        }
        events.sort_by_key(Event::t);
        TraceData { events, dropped, total }
    }
}

/// Drained, decoded, time-sorted contents of a collector.
#[derive(Clone, Debug)]
pub struct TraceData {
    /// Retained events, oldest first.
    pub events: Vec<Event>,
    /// Exact count of records lost to drop-oldest overwrites.
    pub dropped: u64,
    /// Total records ever emitted (retained + dropped).
    pub total: u64,
}

/// A copyable emission handle: an optional collector reference bound to
/// one ring index. `Emitter::off()` is the zero-cost disabled handle —
/// every check is one branch on an immutable field.
#[derive(Clone, Copy, Debug)]
pub struct Emitter<'a> {
    obs: Option<&'a Obs>,
    ring: usize,
}

impl Emitter<'_> {
    /// The disabled handle (`--trace-level off` and untraced callers).
    pub fn off() -> Emitter<'static> {
        Emitter { obs: None, ring: 0 }
    }

    /// True when records at `level` are being captured.
    #[inline]
    pub fn enabled(&self, level: TraceLevel) -> bool {
        match self.obs {
            Some(o) => o.level >= level,
            None => false,
        }
    }

    /// True at `spans` and above.
    #[inline]
    pub fn spans(&self) -> bool {
        self.enabled(TraceLevel::Spans)
    }

    /// True at `events` level.
    #[inline]
    pub fn events(&self) -> bool {
        self.enabled(TraceLevel::Events)
    }

    /// Collector clock, or 0 when disabled.
    #[inline]
    pub fn now(&self) -> u64 {
        match self.obs {
            Some(o) => o.now(),
            None => 0,
        }
    }

    /// Record an event (no-op when disabled). Gate field computation on
    /// [`Emitter::spans`]/[`Emitter::events`] to keep the disabled path
    /// at one branch.
    #[inline]
    pub fn emit(&self, event: Event) {
        if let Some(o) = self.obs {
            o.emit(self.ring, event);
        }
    }
}

/// Build an `Emitter` for ring `ring` from an optional collector.
pub fn emitter(obs: Option<&Obs>, ring: usize) -> Emitter<'_> {
    match obs {
        Some(o) => o.emitter(ring),
        None => Emitter::off(),
    }
}

/// Decorator around any [`Selector`] that emits periodic
/// [`Event::SelectorState`] probes while forwarding every call
/// unchanged — how *serial* solver runs join the tracing plane without
/// touching a solver signature (the sharded engine probes its inner
/// selectors directly at epoch boundaries). Selection behavior is
/// bit-identical to the wrapped policy: the probe only reads state.
pub struct ObservedSelector {
    inner: Box<dyn Selector>,
    obs: Arc<Obs>,
    ring: usize,
    shard: u32,
    /// probe cadence in `next()` calls (≈ one coordinate sweep)
    every: u64,
    calls: u64,
    probs: Vec<f64>,
}

impl ObservedSelector {
    /// Wrap `inner`, probing onto `ring` roughly once per coordinate
    /// sweep (at least every 1024 selections, so tiny problems do not
    /// flood the ring); `shard` tags the probes ([`NO_SHARD`] for
    /// serial runs).
    pub fn new(
        inner: Box<dyn Selector>,
        obs: Arc<Obs>,
        ring: usize,
        shard: u32,
    ) -> ObservedSelector {
        let every = (inner.n() as u64).max(1024);
        ObservedSelector { inner, obs, ring, shard, every, calls: 0, probs: Vec::new() }
    }
}

impl Selector for ObservedSelector {
    fn next(&mut self) -> usize {
        self.calls += 1;
        if self.calls % self.every == 0 && self.obs.level() >= TraceLevel::Events {
            self.inner.probabilities_into(&mut self.probs);
            let (entropy, p_min, p_max) = entropy_stats(&self.probs);
            self.obs.emit(
                self.ring,
                Event::SelectorState {
                    t: self.obs.now(),
                    shard: self.shard,
                    entropy,
                    p_min,
                    p_max,
                },
            );
        }
        self.inner.next()
    }

    fn report(&mut self, i: usize, delta_f: f64) {
        self.inner.report(i, delta_f);
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn probabilities_into(&self, out: &mut Vec<f64>) {
        self.inner.probabilities_into(out);
    }

    fn snapshot(&self) -> SelectorSnapshot {
        self.inner.snapshot()
    }
}

/// Natural-log entropy plus min/max of a probability vector — the
/// selector-distribution probe recorded by [`Event::SelectorState`].
pub fn entropy_stats(p: &[f64]) -> (f64, f64, f64) {
    if p.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mut h = 0.0;
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in p {
        if x > 0.0 {
            h -= x * x.ln();
        }
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (h, lo, hi)
}

/// Buckets in the log-scale duration histograms: bucket *i* counts
/// durations in `[2^(i−1), 2^i)` nanoseconds (bucket 0 is `< 1 ns`,
/// the last bucket absorbs everything ≥ `2^(HIST_BUCKETS−2)`).
pub const HIST_BUCKETS: usize = 40;

/// Staleness histogram width: exact counts for staleness 0–15, one
/// overflow bucket for ≥ 16.
pub const STALENESS_BUCKETS: usize = 17;

fn log2_bucket(nanos: u64) -> usize {
    ((64 - nanos.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// Per-shard activity inside one aggregation window.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardWindow {
    /// Local epochs completed.
    pub epochs: u64,
    /// Coordinate steps taken.
    pub steps: u64,
    /// Arithmetic operations spent.
    pub ops: u64,
    /// Nanoseconds of epoch compute.
    pub compute_nanos: u64,
}

impl ShardWindow {
    /// Throughput over the shard's own compute time.
    pub fn ops_per_sec(&self) -> f64 {
        if self.compute_nanos == 0 {
            0.0
        } else {
            self.ops as f64 / (self.compute_nanos as f64 * 1e-9)
        }
    }
}

/// One selector-distribution probe.
#[derive(Clone, Copy, Debug)]
pub struct SelectorPoint {
    /// Collector time, seconds.
    pub t: f64,
    /// Shard, or [`NO_SHARD`] for a serial run.
    pub shard: u32,
    /// Natural-log entropy of the distribution.
    pub entropy: f64,
    /// Smallest probability.
    pub p_min: f64,
    /// Largest probability.
    pub p_max: f64,
}

/// Merge-attempt counts (in submissions) inside one window.
#[derive(Clone, Copy, Debug, Default)]
pub struct MergeWindow {
    /// Submissions accepted via the exact additive candidate.
    pub additive: u64,
    /// Submissions accepted via the damped fallback.
    pub damped: u64,
    /// Submissions rejected after both exact checks.
    pub rejected: u64,
    /// Submissions dropped by the staleness gate.
    pub stale: u64,
}

impl MergeWindow {
    /// Accepted share of all attempted submissions (1.0 when none).
    pub fn acceptance_rate(&self) -> f64 {
        let total = self.additive + self.damped + self.rejected + self.stale;
        if total == 0 {
            1.0
        } else {
            (self.additive + self.damped) as f64 / total as f64
        }
    }
}

/// Aggregated view of one time window of the event stream — the unit
/// the JSONL sink writes as `"kind": "metrics_snapshot"` lines.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Window start, seconds since collector start.
    pub t0: f64,
    /// Window end, seconds.
    pub t1: f64,
    /// Per-shard activity, indexed by shard id.
    pub per_shard: Vec<ShardWindow>,
    /// Log-scale histogram of epoch compute times (see [`HIST_BUCKETS`]).
    pub epoch_nanos_hist: [u64; HIST_BUCKETS],
    /// Merge outcomes in submissions.
    pub merge: MergeWindow,
    /// Histogram of merge-attempt staleness (see [`STALENESS_BUCKETS`]).
    pub staleness_hist: [u64; STALENESS_BUCKETS],
    /// τ trajectory: (seconds, new τ) at each adaptive move.
    pub tau: Vec<(f64, u64)>,
    /// Selector-distribution probes.
    pub selector: Vec<SelectorPoint>,
    /// Nanoseconds the merger spent idle.
    pub merge_wait_nanos: u64,
    /// Park transitions.
    pub parks: u64,
    /// Objective at the last publish in the window, if any.
    pub last_objective: Option<f64>,
    /// Fork-join rounds the engine's `RoundPool` has dispatched
    /// (cumulative; folded with `max` from [`Event::EngineStats`]).
    pub pool_rounds: u64,
    /// Submissions pushed through the async merge queue (cumulative).
    pub queue_pushes: u64,
    /// Largest merge-queue depth ever observed (cumulative max).
    pub queue_max_depth: u64,
}

impl MetricsSnapshot {
    /// Fold the events with `t0 ≤ t < t1` (seconds) into one snapshot.
    /// `n_shards` fixes the length of [`MetricsSnapshot::per_shard`].
    pub fn from_events(events: &[Event], n_shards: usize, t0: f64, t1: f64) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot {
            t0,
            t1,
            per_shard: vec![ShardWindow::default(); n_shards],
            epoch_nanos_hist: [0; HIST_BUCKETS],
            merge: MergeWindow::default(),
            staleness_hist: [0; STALENESS_BUCKETS],
            tau: Vec::new(),
            selector: Vec::new(),
            merge_wait_nanos: 0,
            parks: 0,
            last_objective: None,
            pool_rounds: 0,
            queue_pushes: 0,
            queue_max_depth: 0,
        };
        for ev in events {
            let secs = ev.t() as f64 * 1e-9;
            if secs < t0 || secs >= t1 {
                continue;
            }
            match *ev {
                Event::Epoch { shard, steps, ops, nanos, .. } => {
                    if let Some(w) = snap.per_shard.get_mut(shard as usize) {
                        w.epochs += 1;
                        w.steps += steps;
                        w.ops += ops;
                        w.compute_nanos += nanos;
                    }
                    snap.epoch_nanos_hist[log2_bucket(nanos)] += 1;
                }
                Event::Merge { tier, staleness, batch, .. } => {
                    let subs = batch.max(1);
                    match tier {
                        MergeTier::Additive => snap.merge.additive += subs,
                        MergeTier::Damped => snap.merge.damped += subs,
                        MergeTier::Rejected => snap.merge.rejected += subs,
                        MergeTier::Stale => snap.merge.stale += subs,
                    }
                    snap.staleness_hist[(staleness as usize).min(STALENESS_BUCKETS - 1)] += 1;
                }
                Event::Publish { objective, .. } => snap.last_objective = Some(objective),
                Event::Tau { tau, .. } => snap.tau.push((secs, tau)),
                Event::Park { .. } => snap.parks += 1,
                Event::MergeWait { nanos, .. } => snap.merge_wait_nanos += nanos,
                Event::SelectorState { shard, entropy, p_min, p_max, .. } => {
                    snap.selector.push(SelectorPoint { t: secs, shard, entropy, p_min, p_max });
                }
                Event::Objective { objective, .. } => snap.last_objective = Some(objective),
                Event::EngineStats { pool_rounds, queue_pushes, queue_max_depth, .. } => {
                    snap.pool_rounds = snap.pool_rounds.max(pool_rounds);
                    snap.queue_pushes = snap.queue_pushes.max(queue_pushes);
                    snap.queue_max_depth = snap.queue_max_depth.max(queue_max_depth);
                }
                Event::SnapshotTake { .. } | Event::Submit { .. } | Event::DataExtent { .. } => {}
            }
        }
        snap
    }

    /// Serialize for the JSONL sink.
    pub fn to_json(&self) -> Json {
        let mut shards = Vec::new();
        for (k, w) in self.per_shard.iter().enumerate() {
            let mut o = Json::obj();
            o.set("shard", json::num(k as f64))
                .set("epochs", json::num(w.epochs as f64))
                .set("steps", json::num(w.steps as f64))
                .set("ops", json::num(w.ops as f64))
                .set("compute_s", json::num(w.compute_nanos as f64 * 1e-9))
                .set("ops_per_sec", json::num(w.ops_per_sec()));
            shards.push(o);
        }
        let mut merge = Json::obj();
        merge
            .set("additive", json::num(self.merge.additive as f64))
            .set("damped", json::num(self.merge.damped as f64))
            .set("rejected", json::num(self.merge.rejected as f64))
            .set("stale", json::num(self.merge.stale as f64))
            .set("acceptance_rate", json::num(self.merge.acceptance_rate()));
        let mut j = Json::obj();
        j.set("kind", json::s("metrics_snapshot"))
            .set("t0", json::num(self.t0))
            .set("t1", json::num(self.t1))
            .set("per_shard", Json::Arr(shards))
            .set(
                "epoch_nanos_log2_hist",
                Json::Arr(self.epoch_nanos_hist.iter().map(|&c| json::num(c as f64)).collect()),
            )
            .set("merge", merge)
            .set(
                "staleness_hist",
                Json::Arr(self.staleness_hist.iter().map(|&c| json::num(c as f64)).collect()),
            )
            .set(
                "tau",
                Json::Arr(
                    self.tau
                        .iter()
                        .map(|&(t, tau)| Json::Arr(vec![json::num(t), json::num(tau as f64)]))
                        .collect(),
                ),
            )
            .set(
                "selector",
                Json::Arr(
                    self.selector
                        .iter()
                        .map(|p| {
                            Json::Arr(vec![
                                json::num(p.t),
                                json::num(if p.shard == NO_SHARD { -1.0 } else { p.shard as f64 }),
                                json::num(p.entropy),
                                json::num(p.p_min),
                                json::num(p.p_max),
                            ])
                        })
                        .collect(),
                ),
            )
            .set("merge_wait_s", json::num(self.merge_wait_nanos as f64 * 1e-9))
            .set("parks", json::num(self.parks as f64))
            .set("pool_rounds", json::num(self.pool_rounds as f64))
            .set("queue_pushes", json::num(self.queue_pushes as f64))
            .set("queue_max_depth", json::num(self.queue_max_depth as f64));
        if let Some(f) = self.last_objective {
            j.set("last_objective", json::num(f));
        }
        j
    }
}

/// Split a time-sorted event stream into fixed-width windows and fold
/// each into a [`MetricsSnapshot`]. `window_secs ≤ 0` yields a single
/// whole-run snapshot.
pub fn window_snapshots(
    events: &[Event],
    n_shards: usize,
    window_secs: f64,
) -> Vec<MetricsSnapshot> {
    if events.is_empty() {
        return Vec::new();
    }
    let t_last = events.last().map(|e| e.t() as f64 * 1e-9).unwrap_or(0.0);
    if window_secs <= 0.0 {
        return vec![MetricsSnapshot::from_events(events, n_shards, 0.0, t_last + 1e-9)];
    }
    let mut out = Vec::new();
    let mut t0 = 0.0;
    while t0 <= t_last {
        let t1 = t0 + window_secs;
        out.push(MetricsSnapshot::from_events(events, n_shards, t0, t1));
        t0 = t1;
    }
    out
}

/// Where the wall-clock went: the stage-time split recorded into
/// `BENCH_scaling_shards.json` and printed by the `trace` subcommand.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageBreakdown {
    /// Total epoch compute across shards, nanoseconds.
    pub compute_nanos: u64,
    /// Merger idle time, nanoseconds.
    pub merge_wait_nanos: u64,
    /// Park transitions observed.
    pub parks: u64,
    /// Epochs observed.
    pub epochs: u64,
    /// Merge attempts observed.
    pub merges: u64,
    /// Span of the event stream (first to last timestamp), nanoseconds.
    pub span_nanos: u64,
    /// Distinct shards that ran epochs.
    pub n_shards: usize,
}

impl StageBreakdown {
    /// Fold an event stream (any order) into the stage split.
    pub fn from_events(events: &[Event]) -> StageBreakdown {
        let mut b = StageBreakdown::default();
        let mut t_min = u64::MAX;
        let mut t_max = 0u64;
        let mut shards: Vec<u32> = Vec::new();
        for ev in events {
            t_min = t_min.min(ev.t());
            t_max = t_max.max(ev.t());
            match *ev {
                Event::Epoch { shard, nanos, .. } => {
                    b.compute_nanos += nanos;
                    b.epochs += 1;
                    if !shards.contains(&shard) {
                        shards.push(shard);
                    }
                }
                Event::MergeWait { nanos, .. } => b.merge_wait_nanos += nanos,
                Event::Park { .. } => b.parks += 1,
                Event::Merge { .. } => b.merges += 1,
                _ => {}
            }
        }
        if t_max >= t_min {
            b.span_nanos = t_max - t_min;
        }
        b.n_shards = shards.len();
        b
    }

    /// Upper-bound estimate of time shard slots spent *not* computing
    /// (parked or waiting on directives): `n_shards · span − compute`.
    pub fn idle_nanos_estimate(&self) -> u64 {
        (self.n_shards as u64 * self.span_nanos).saturating_sub(self.compute_nanos)
    }

    /// Serialize for bench summaries and the JSONL sink.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("compute_s", json::num(self.compute_nanos as f64 * 1e-9))
            .set("merge_wait_s", json::num(self.merge_wait_nanos as f64 * 1e-9))
            .set("idle_s_estimate", json::num(self.idle_nanos_estimate() as f64 * 1e-9))
            .set("parks", json::num(self.parks as f64))
            .set("epochs", json::num(self.epochs as f64))
            .set("merges", json::num(self.merges as f64))
            .set("span_s", json::num(self.span_nanos as f64 * 1e-9))
            .set("n_shards", json::num(self.n_shards as f64));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observed_selector_forwards_and_probes_periodically() {
        use crate::acf::AcfParams;
        use crate::select::SelectorKind;
        use crate::util::rng::Rng;
        let obs = Arc::new(Obs::new(TraceLevel::Events, 1, 256));
        let inner = SelectorKind::Uniform.build(4, AcfParams::default(), Rng::new(7));
        let mut plain = SelectorKind::Uniform.build(4, AcfParams::default(), Rng::new(7));
        let mut sel = ObservedSelector::new(inner, Arc::clone(&obs), 0, NO_SHARD);
        assert_eq!(sel.n(), 4);
        assert_eq!(sel.name(), "uniform");
        // forwarding is bit-identical to the unwrapped policy
        for _ in 0..2048 {
            assert_eq!(sel.next(), plain.next());
        }
        let data = obs.drain();
        assert_eq!(data.events.len(), 2, "one probe per 1024 selections");
        for ev in &data.events {
            match *ev {
                Event::SelectorState { shard, entropy, p_min, p_max, .. } => {
                    assert_eq!(shard, NO_SHARD);
                    assert!((entropy - 4.0f64.ln()).abs() < 1e-12);
                    assert!((p_min - 0.25).abs() < 1e-12 && (p_max - 0.25).abs() < 1e-12);
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        // below events level the wrapper records nothing
        let quiet = Arc::new(Obs::new(TraceLevel::Spans, 1, 256));
        let inner = SelectorKind::Uniform.build(4, AcfParams::default(), Rng::new(7));
        let mut sel = ObservedSelector::new(inner, Arc::clone(&quiet), 0, NO_SHARD);
        for _ in 0..2048 {
            sel.next();
        }
        assert_eq!(quiet.drain().total, 0);
    }

    fn sample_events() -> Vec<Event> {
        vec![
            Event::SnapshotTake { t: 10, shard: 0, version: 3 },
            Event::Epoch { t: 1_000, shard: 0, steps: 50, ops: 700, nanos: 900 },
            Event::Submit { t: 1_100, shard: 0, base_version: 3, queue_depth: 2 },
            Event::Merge { t: 1_200, shard: 0, tier: MergeTier::Additive, staleness: 1, batch: 2 },
            Event::Merge { t: 1_250, shard: 1, tier: MergeTier::Stale, staleness: 20, batch: 1 },
            Event::Publish { t: 1_300, version: 4, objective: -1.5 },
            Event::Tau { t: 1_400, tau: 3, prev: 2 },
            Event::Park { t: 1_500, shard: 1 },
            Event::MergeWait { t: 1_600, nanos: 400 },
            Event::SelectorState { t: 1_700, shard: 0, entropy: 0.69, p_min: 0.4, p_max: 0.6 },
            Event::DataExtent { t: 1_800, shard: 1, bytes: 12_288, pages: 4 },
            Event::Objective { t: 1_850, shard: NO_SHARD, epoch: 7, objective: -1.25 },
            Event::EngineStats { t: 1_900, pool_rounds: 12, queue_pushes: 34, queue_max_depth: 5 },
        ]
    }

    #[test]
    fn encode_decode_round_trips_every_kind() {
        for ev in sample_events() {
            assert_eq!(Event::decode(ev.encode()), Some(ev), "{}", ev.kind());
        }
        // Unwritten slots decode to None, not garbage events.
        assert_eq!(Event::decode([0; EVENT_WORDS]), None);
    }

    #[test]
    fn levels_are_ordered_and_parse() {
        assert!(TraceLevel::Off < TraceLevel::Summary);
        assert!(TraceLevel::Summary < TraceLevel::Spans);
        assert!(TraceLevel::Spans < TraceLevel::Events);
        for name in TraceLevel::NAMES {
            assert_eq!(TraceLevel::parse(name).unwrap().name(), name);
        }
        assert!(TraceLevel::parse("verbose").is_none());
    }

    #[test]
    fn emitter_gates_by_level() {
        let obs = Obs::new(TraceLevel::Spans, 2, 16);
        let em = obs.emitter(1);
        assert!(em.spans());
        assert!(!em.events());
        em.emit(Event::Park { t: em.now(), shard: 0 });
        let data = obs.drain();
        assert_eq!(data.events.len(), 1);
        assert_eq!(data.dropped, 0);
        assert_eq!(data.total, 1);
        // The disabled handle records nothing and reads no clock.
        let off = Emitter::off();
        assert!(!off.spans() && !off.events());
        assert_eq!(off.now(), 0);
        off.emit(Event::Park { t: 0, shard: 0 });
    }

    #[test]
    fn drain_merges_rings_sorted_by_time() {
        let obs = Obs::new(TraceLevel::Events, 3, 8);
        obs.emit(2, Event::Park { t: 30, shard: 2 });
        obs.emit(0, Event::Park { t: 10, shard: 0 });
        obs.emit(1, Event::Park { t: 20, shard: 1 });
        let data = obs.drain();
        let ts: Vec<u64> = data.events.iter().map(Event::t).collect();
        assert_eq!(ts, vec![10, 20, 30]);
    }

    #[test]
    fn snapshot_folds_counts_and_histograms() {
        let snap = MetricsSnapshot::from_events(&sample_events(), 2, 0.0, 1.0);
        assert_eq!(snap.per_shard[0].epochs, 1);
        assert_eq!(snap.per_shard[0].steps, 50);
        assert_eq!(snap.per_shard[0].ops, 700);
        assert_eq!(snap.per_shard[1].epochs, 0);
        assert_eq!(snap.merge.additive, 2);
        assert_eq!(snap.merge.stale, 1);
        assert!((snap.merge.acceptance_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(snap.staleness_hist[1], 1);
        assert_eq!(snap.staleness_hist[STALENESS_BUCKETS - 1], 1);
        assert_eq!(snap.tau.len(), 1);
        assert!((snap.tau[0].0 - 1.4e-6).abs() < 1e-12);
        assert_eq!(snap.tau[0].1, 3);
        assert_eq!(snap.parks, 1);
        assert_eq!(snap.merge_wait_nanos, 400);
        // The objective event at t=1_850 lands after the publish at
        // t=1_300, so it wins the "last" slot.
        assert_eq!(snap.last_objective, Some(-1.25));
        assert_eq!(snap.pool_rounds, 12);
        assert_eq!(snap.queue_pushes, 34);
        assert_eq!(snap.queue_max_depth, 5);
        // 900 ns lands in the [512, 1024) bucket.
        assert_eq!(snap.epoch_nanos_hist[log2_bucket(900)], 1);
        assert_eq!(log2_bucket(900), 10);
        let j = snap.to_json();
        assert_eq!(j.get("kind").and_then(|k| k.as_str()), Some("metrics_snapshot"));
    }

    #[test]
    fn stage_breakdown_sums_stages() {
        let b = StageBreakdown::from_events(&sample_events());
        assert_eq!(b.compute_nanos, 900);
        assert_eq!(b.merge_wait_nanos, 400);
        assert_eq!(b.parks, 1);
        assert_eq!(b.epochs, 1);
        assert_eq!(b.merges, 2);
        assert_eq!(b.n_shards, 1);
        assert_eq!(b.span_nanos, 1_900 - 10);
        assert!(b.idle_nanos_estimate() > 0);
    }

    #[test]
    fn entropy_probe_matches_closed_form() {
        let (h, lo, hi) = entropy_stats(&[0.5, 0.5]);
        assert!((h - std::f64::consts::LN_2).abs() < 1e-12);
        assert_eq!((lo, hi), (0.5, 0.5));
        let (h, lo, hi) = entropy_stats(&[1.0, 0.0]);
        assert_eq!((h, lo, hi), (0.0, 0.0, 1.0));
    }

    #[test]
    fn window_snapshots_cover_the_stream() {
        let evs = vec![
            Event::Park { t: 0, shard: 0 },
            Event::Park { t: 1_500_000_000, shard: 0 },
            Event::Park { t: 2_500_000_000, shard: 0 },
        ];
        let wins = window_snapshots(&evs, 1, 1.0);
        assert_eq!(wins.len(), 3);
        assert_eq!(wins.iter().map(|w| w.parks).sum::<u64>(), 3);
        let whole = window_snapshots(&evs, 1, 0.0);
        assert_eq!(whole.len(), 1);
        assert_eq!(whole[0].parks, 3);
    }
}
