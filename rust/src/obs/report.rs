//! Human-readable rendering of a JSONL trace file — the engine behind
//! the `acf-cd trace <file>` subcommand.
//!
//! The report answers the two questions the raw event stream encodes:
//! *where did the wall-clock go* (stage-time breakdown: per-shard
//! compute, merger idle, parks, plus the epoch-time histogram) and
//! *how did adaptation behave over time* (τ moves, published objective
//! trajectory, merge-tier outcomes and staleness distribution,
//! selector-entropy probes).

use super::sink::event_from_json;
use super::{Event, MetricsSnapshot, StageBreakdown, TraceData, NO_SHARD, STALENESS_BUCKETS};
use crate::util::json::{self, Json};
use crate::util::timer::{fmt_count, fmt_secs};
use crate::{Error, Result};

/// Parse a whole JSONL trace file and render the stage-time breakdown
/// and adaptation timeline as display-ready text. Malformed lines are
/// an error naming the line number.
pub fn summarize(text: &str) -> Result<String> {
    let mut events: Vec<Event> = Vec::new();
    let mut meta: Option<Json> = None;
    let mut summary: Option<Json> = None;
    let mut snapshot_lines = 0usize;
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let j = json::parse(line)
            .map_err(|e| Error::msg(format!("trace line {}: {e}", idx + 1)))?;
        match j.get("kind").and_then(Json::as_str) {
            Some("meta") => meta = Some(j),
            Some("summary") => summary = Some(j),
            Some("metrics_snapshot") => snapshot_lines += 1,
            _ => match event_from_json(&j) {
                Ok(Some(ev)) => events.push(ev),
                Ok(None) => {}
                Err(e) => return Err(e.context(format!("trace line {}", idx + 1))),
            },
        }
    }
    events.sort_by_key(Event::t);

    let mut out = String::new();
    if let Some(m) = &meta {
        out.push_str(&format!("meta     {}\n", scalar_fields(m)));
    }
    out.push_str(&format!(
        "stream   {} events retained, {} metrics snapshot(s)\n",
        events.len(),
        snapshot_lines
    ));
    if events.is_empty() {
        out.push_str("         (no event lines — summary-level trace)\n");
    } else {
        render_stage_time(&mut out, &events);
        render_adaptation(&mut out, &events);
    }
    if let Some(s) = &summary {
        out.push_str(&format!("\nsummary  {}\n", scalar_fields(s)));
    }
    Ok(out)
}

/// `key=value` rendering of an object's scalar fields (skips `kind`).
fn scalar_fields(j: &Json) -> String {
    let mut parts = Vec::new();
    if let Json::Obj(map) = j {
        for (k, v) in map {
            if k == "kind" {
                continue;
            }
            match v {
                Json::Num(_) | Json::Str(_) | Json::Bool(_) => {
                    parts.push(format!("{k}={}", v.to_string_compact().trim_matches('"')))
                }
                _ => {}
            }
        }
    }
    parts.join(" ")
}

fn render_stage_time(out: &mut String, events: &[Event]) {
    let n_shards = events
        .iter()
        .filter_map(|e| match e {
            Event::Epoch { shard, .. } if *shard != NO_SHARD => Some(*shard as usize + 1),
            _ => None,
        })
        .max()
        .unwrap_or(0);
    let b = StageBreakdown::from_events(events);
    out.push_str("\n-- stage time --\n");
    out.push_str(&format!("span        {}\n", fmt_secs(b.span_nanos as f64 * 1e-9)));
    out.push_str(&format!(
        "compute     {}  ({} epochs across {} shard(s))\n",
        fmt_secs(b.compute_nanos as f64 * 1e-9),
        b.epochs,
        b.n_shards
    ));
    out.push_str(&format!(
        "merge-wait  {}  (merger idle), {} merge attempt(s)\n",
        fmt_secs(b.merge_wait_nanos as f64 * 1e-9),
        b.merges
    ));
    out.push_str(&format!(
        "idle (est.) {}  ({} park transition(s))\n",
        fmt_secs(b.idle_nanos_estimate() as f64 * 1e-9),
        b.parks
    ));

    let snap = MetricsSnapshot::from_events(events, n_shards, 0.0, f64::INFINITY);
    if n_shards > 0 {
        out.push_str("\n-- per shard --\n");
        out.push_str("shard   epochs      steps        ops    compute      ops/s\n");
        for (k, w) in snap.per_shard.iter().enumerate() {
            out.push_str(&format!(
                "{k:<5} {:>8} {:>10} {:>10} {:>10} {:>10}\n",
                w.epochs,
                fmt_count(w.steps as f64),
                fmt_count(w.ops as f64),
                fmt_secs(w.compute_nanos as f64 * 1e-9),
                fmt_count(w.ops_per_sec())
            ));
        }
    }
    render_epoch_hist(out, &snap);
}

fn render_epoch_hist(out: &mut String, snap: &MetricsSnapshot) {
    let max = snap.epoch_nanos_hist.iter().copied().max().unwrap_or(0);
    if max == 0 {
        return;
    }
    out.push_str("\n-- epoch time histogram (log2 ns buckets) --\n");
    for (i, &count) in snap.epoch_nanos_hist.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let lo = if i == 0 { 0u64 } else { 1u64 << (i - 1) };
        let bar_len = (count as f64 / max as f64 * 40.0).ceil() as usize;
        out.push_str(&format!(
            "≥ {:>8}  {:<40} {}\n",
            fmt_secs(lo as f64 * 1e-9),
            "#".repeat(bar_len),
            count
        ));
    }
}

fn render_adaptation(out: &mut String, events: &[Event]) {
    let snap = MetricsSnapshot::from_events(events, 0, 0.0, f64::INFINITY);
    out.push_str("\n-- merge outcomes (submissions) --\n");
    let m = &snap.merge;
    out.push_str(&format!(
        "additive {}  damped {}  rejected {}  stale-dropped {}  (acceptance {:.1}%)\n",
        m.additive,
        m.damped,
        m.rejected,
        m.stale,
        m.acceptance_rate() * 100.0
    ));
    let staleness: Vec<String> = snap
        .staleness_hist
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(s, &c)| {
            if s == STALENESS_BUCKETS - 1 {
                format!("{}+:{c}", STALENESS_BUCKETS - 1)
            } else {
                format!("{s}:{c}")
            }
        })
        .collect();
    if !staleness.is_empty() {
        out.push_str(&format!("staleness   {}\n", staleness.join("  ")));
    }

    out.push_str("\n-- adaptation timeline --\n");
    let taus: Vec<&Event> = events.iter().filter(|e| matches!(e, Event::Tau { .. })).collect();
    if taus.is_empty() {
        out.push_str("tau         (no adaptive moves recorded)\n");
    } else {
        let mut line = String::from("tau        ");
        for ev in taus.iter().rev().take(8).rev() {
            if let Event::Tau { t, tau, prev } = ev {
                line.push_str(&format!("  {}: {prev}→{tau}", fmt_secs(*t as f64 * 1e-9)));
            }
        }
        if taus.len() > 8 {
            line.push_str(&format!("  (+{} earlier)", taus.len() - 8));
        }
        out.push_str(&line);
        out.push('\n');
    }
    let publishes: Vec<(u64, u64, f64)> = events
        .iter()
        .filter_map(|e| match *e {
            Event::Publish { t, version, objective } => Some((t, version, objective)),
            _ => None,
        })
        .collect();
    if let (Some(first), Some(last)) = (publishes.first(), publishes.last()) {
        out.push_str(&format!(
            "objective   v{} f={:.6e}  →  v{} f={:.6e}  over {} publish(es)\n",
            first.1,
            first.2,
            last.1,
            last.2,
            publishes.len()
        ));
    }
    render_selector_probes(out, events);
}

fn render_selector_probes(out: &mut String, events: &[Event]) {
    let mut shards: Vec<u32> = events
        .iter()
        .filter_map(|e| match e {
            Event::SelectorState { shard, .. } => Some(*shard),
            _ => None,
        })
        .collect();
    shards.sort_unstable();
    shards.dedup();
    for shard in shards {
        let probes: Vec<(f64, f64, f64)> = events
            .iter()
            .filter_map(|e| match *e {
                Event::SelectorState { shard: s, entropy, p_min, p_max, .. } if s == shard => {
                    Some((entropy, p_min, p_max))
                }
                _ => None,
            })
            .collect();
        let (first, last) = (probes[0], probes[probes.len() - 1]);
        let label = if shard == NO_SHARD { "serial".to_string() } else { format!("shard {shard}") };
        out.push_str(&format!(
            "selector    {label}: entropy {:.3}→{:.3}, p∈[{:.4}, {:.4}] at last probe ({} probe(s))\n",
            first.0,
            last.0,
            last.1,
            last.2,
            probes.len()
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sink::render_trace;
    use crate::obs::{window_snapshots, MergeTier, TraceLevel};

    fn sample_trace() -> String {
        let events = vec![
            Event::Epoch { t: 1_000, shard: 0, steps: 40, ops: 500, nanos: 800 },
            Event::Epoch { t: 2_000, shard: 1, steps: 40, ops: 480, nanos: 700 },
            Event::Submit { t: 2_100, shard: 1, base_version: 1, queue_depth: 1 },
            Event::Merge { t: 2_200, shard: 1, tier: MergeTier::Additive, staleness: 1, batch: 2 },
            Event::Publish { t: 2_300, version: 2, objective: -0.75 },
            Event::Tau { t: 2_400, tau: 3, prev: 2 },
            Event::Park { t: 2_500, shard: 0 },
            Event::MergeWait { t: 2_600, nanos: 300 },
            Event::SelectorState { t: 2_700, shard: 0, entropy: 1.2, p_min: 0.1, p_max: 0.5 },
            Event::SelectorState { t: 2_800, shard: 0, entropy: 1.1, p_min: 0.1, p_max: 0.6 },
        ];
        let data = TraceData { total: events.len() as u64, dropped: 0, events };
        let snaps = window_snapshots(&data.events, 2, 0.0);
        let mut meta = Json::obj();
        meta.set("problem", json::s("lasso")).set("shards", json::num(2.0));
        let mut summary = Json::obj();
        summary.set("objective", json::num(-0.75)).set("iterations", json::num(80.0));
        render_trace(TraceLevel::Events, &meta, &data, &snaps, &summary)
    }

    #[test]
    fn summarize_round_trips_a_rendered_trace() {
        let report = summarize(&sample_trace()).unwrap();
        assert!(report.contains("problem=lasso"), "{report}");
        assert!(report.contains("-- stage time --"), "{report}");
        assert!(report.contains("-- per shard --"), "{report}");
        assert!(report.contains("-- merge outcomes"), "{report}");
        assert!(report.contains("-- adaptation timeline --"), "{report}");
        assert!(report.contains("2→3"), "{report}");
        assert!(report.contains("shard 0: entropy 1.200→1.100"), "{report}");
        assert!(report.contains("iterations=80"), "{report}");
    }

    #[test]
    fn summary_only_trace_is_reported_without_events() {
        let data = TraceData { total: 0, dropped: 0, events: Vec::new() };
        let text = render_trace(TraceLevel::Summary, &Json::obj(), &data, &[], &Json::obj());
        let report = summarize(&text).unwrap();
        assert!(report.contains("summary-level trace"), "{report}");
    }

    #[test]
    fn malformed_line_names_the_line_number() {
        let text = "{\"kind\":\"meta\"}\nnot json\n";
        let err = summarize(text).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn unknown_event_kind_is_an_error() {
        let text = "{\"kind\":\"wobble\",\"t_ns\":1}\n";
        assert!(summarize(text).is_err());
    }
}
