//! Human-readable rendering of a JSONL trace file — the engine behind
//! the `acf-cd trace <file>` subcommand.
//!
//! The report answers the two questions the raw event stream encodes:
//! *where did the wall-clock go* (stage-time breakdown: per-shard
//! compute, merger idle, parks, plus the epoch-time histogram) and
//! *how did adaptation behave over time* (τ moves, published objective
//! trajectory, merge-tier outcomes and staleness distribution,
//! selector-entropy probes).

use super::sink::event_from_json;
use super::{Event, MetricsSnapshot, StageBreakdown, TraceData, NO_SHARD, STALENESS_BUCKETS};
use crate::util::json::{self, Json};
use crate::util::timer::{fmt_count, fmt_secs};
use crate::{Error, Result};

/// A fully-parsed JSONL trace: the event stream (time-sorted) plus the
/// meta/summary envelope lines. Shared by [`summarize`] and [`diff`].
struct ParsedTrace {
    meta: Option<Json>,
    summary: Option<Json>,
    snapshot_lines: usize,
    events: Vec<Event>,
}

/// Parse a whole JSONL trace file. Malformed lines are an error naming
/// the line number.
fn parse_trace(text: &str) -> Result<ParsedTrace> {
    let mut events: Vec<Event> = Vec::new();
    let mut meta: Option<Json> = None;
    let mut summary: Option<Json> = None;
    let mut snapshot_lines = 0usize;
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let j = json::parse(line)
            .map_err(|e| Error::msg(format!("trace line {}: {e}", idx + 1)))?;
        match j.get("kind").and_then(Json::as_str) {
            Some("meta") => meta = Some(j),
            Some("summary") => summary = Some(j),
            Some("metrics_snapshot") => snapshot_lines += 1,
            _ => match event_from_json(&j) {
                Ok(Some(ev)) => events.push(ev),
                Ok(None) => {}
                Err(e) => return Err(e.context(format!("trace line {}", idx + 1))),
            },
        }
    }
    events.sort_by_key(Event::t);
    Ok(ParsedTrace { meta, summary, snapshot_lines, events })
}

/// Render the stage-time breakdown and adaptation timeline of a JSONL
/// trace as display-ready text.
pub fn summarize(text: &str) -> Result<String> {
    let trace = parse_trace(text)?;
    let events = &trace.events;

    let mut out = String::new();
    if let Some(m) = &trace.meta {
        out.push_str(&format!("meta     {}\n", scalar_fields(m)));
    }
    out.push_str(&format!(
        "stream   {} events retained, {} metrics snapshot(s)\n",
        events.len(),
        trace.snapshot_lines
    ));
    if events.is_empty() {
        out.push_str("         (no event lines — summary-level trace)\n");
    } else {
        render_stage_time(&mut out, events);
        render_adaptation(&mut out, events);
    }
    if let Some(s) = &trace.summary {
        out.push_str(&format!("\nsummary  {}\n", scalar_fields(s)));
    }
    Ok(out)
}

/// `key=value` rendering of an object's scalar fields (skips `kind`).
fn scalar_fields(j: &Json) -> String {
    let mut parts = Vec::new();
    if let Json::Obj(map) = j {
        for (k, v) in map {
            if k == "kind" {
                continue;
            }
            match v {
                Json::Num(_) | Json::Str(_) | Json::Bool(_) => {
                    parts.push(format!("{k}={}", v.to_string_compact().trim_matches('"')))
                }
                _ => {}
            }
        }
    }
    parts.join(" ")
}

/// Shard count implied by the event stream (highest epoch shard id +1).
fn shard_count(events: &[Event]) -> usize {
    events
        .iter()
        .filter_map(|e| match e {
            Event::Epoch { shard, .. } if *shard != NO_SHARD => Some(*shard as usize + 1),
            _ => None,
        })
        .max()
        .unwrap_or(0)
}

fn render_stage_time(out: &mut String, events: &[Event]) {
    let n_shards = shard_count(events);
    let b = StageBreakdown::from_events(events);
    out.push_str("\n-- stage time --\n");
    out.push_str(&format!("span        {}\n", fmt_secs(b.span_nanos as f64 * 1e-9)));
    out.push_str(&format!(
        "compute     {}  ({} epochs across {} shard(s))\n",
        fmt_secs(b.compute_nanos as f64 * 1e-9),
        b.epochs,
        b.n_shards
    ));
    out.push_str(&format!(
        "merge-wait  {}  (merger idle), {} merge attempt(s)\n",
        fmt_secs(b.merge_wait_nanos as f64 * 1e-9),
        b.merges
    ));
    out.push_str(&format!(
        "idle (est.) {}  ({} park transition(s))\n",
        fmt_secs(b.idle_nanos_estimate() as f64 * 1e-9),
        b.parks
    ));

    let snap = MetricsSnapshot::from_events(events, n_shards, 0.0, f64::INFINITY);
    if n_shards > 0 {
        out.push_str("\n-- per shard --\n");
        out.push_str("shard   epochs      steps        ops    compute      ops/s\n");
        for (k, w) in snap.per_shard.iter().enumerate() {
            out.push_str(&format!(
                "{k:<5} {:>8} {:>10} {:>10} {:>10} {:>10}\n",
                w.epochs,
                fmt_count(w.steps as f64),
                fmt_count(w.ops as f64),
                fmt_secs(w.compute_nanos as f64 * 1e-9),
                fmt_count(w.ops_per_sec())
            ));
        }
    }
    render_epoch_hist(out, &snap);
}

fn render_epoch_hist(out: &mut String, snap: &MetricsSnapshot) {
    let max = snap.epoch_nanos_hist.iter().copied().max().unwrap_or(0);
    if max == 0 {
        return;
    }
    out.push_str("\n-- epoch time histogram (log2 ns buckets) --\n");
    for (i, &count) in snap.epoch_nanos_hist.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let lo = if i == 0 { 0u64 } else { 1u64 << (i - 1) };
        let bar_len = (count as f64 / max as f64 * 40.0).ceil() as usize;
        out.push_str(&format!(
            "≥ {:>8}  {:<40} {}\n",
            fmt_secs(lo as f64 * 1e-9),
            "#".repeat(bar_len),
            count
        ));
    }
}

fn render_adaptation(out: &mut String, events: &[Event]) {
    let snap = MetricsSnapshot::from_events(events, 0, 0.0, f64::INFINITY);
    out.push_str("\n-- merge outcomes (submissions) --\n");
    let m = &snap.merge;
    out.push_str(&format!(
        "additive {}  damped {}  rejected {}  stale-dropped {}  (acceptance {:.1}%)\n",
        m.additive,
        m.damped,
        m.rejected,
        m.stale,
        m.acceptance_rate() * 100.0
    ));
    let staleness: Vec<String> = snap
        .staleness_hist
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(s, &c)| {
            if s == STALENESS_BUCKETS - 1 {
                format!("{}+:{c}", STALENESS_BUCKETS - 1)
            } else {
                format!("{s}:{c}")
            }
        })
        .collect();
    if !staleness.is_empty() {
        out.push_str(&format!("staleness   {}\n", staleness.join("  ")));
    }

    out.push_str("\n-- adaptation timeline --\n");
    let taus: Vec<&Event> = events.iter().filter(|e| matches!(e, Event::Tau { .. })).collect();
    if taus.is_empty() {
        out.push_str("tau         (no adaptive moves recorded)\n");
    } else {
        let mut line = String::from("tau        ");
        for ev in taus.iter().rev().take(8).rev() {
            if let Event::Tau { t, tau, prev } = ev {
                line.push_str(&format!("  {}: {prev}→{tau}", fmt_secs(*t as f64 * 1e-9)));
            }
        }
        if taus.len() > 8 {
            line.push_str(&format!("  (+{} earlier)", taus.len() - 8));
        }
        out.push_str(&line);
        out.push('\n');
    }
    let publishes: Vec<(u64, u64, f64)> = events
        .iter()
        .filter_map(|e| match *e {
            Event::Publish { t, version, objective } => Some((t, version, objective)),
            _ => None,
        })
        .collect();
    if let (Some(first), Some(last)) = (publishes.first(), publishes.last()) {
        out.push_str(&format!(
            "objective   v{} f={:.6e}  →  v{} f={:.6e}  over {} publish(es)\n",
            first.1,
            first.2,
            last.1,
            last.2,
            publishes.len()
        ));
    }
    // epoch-boundary objective trajectory (serial solvers and the sync
    // engine record these; publishes above cover the async merger)
    let objectives: Vec<(u64, f64)> = events
        .iter()
        .filter_map(|e| match *e {
            Event::Objective { epoch, objective, .. } => Some((epoch, objective)),
            _ => None,
        })
        .collect();
    if let (Some(&(e0, f0)), Some(&(e1, f1))) = (objectives.first(), objectives.last()) {
        out.push_str(&format!(
            "epoch-obj   epoch {e0} f={f0:.6e}  →  epoch {e1} f={f1:.6e}  ({} record(s))\n",
            objectives.len()
        ));
    }
    render_selector_probes(out, events);
}

fn render_selector_probes(out: &mut String, events: &[Event]) {
    let mut shards: Vec<u32> = events
        .iter()
        .filter_map(|e| match e {
            Event::SelectorState { shard, .. } => Some(*shard),
            _ => None,
        })
        .collect();
    shards.sort_unstable();
    shards.dedup();
    for shard in shards {
        let probes: Vec<(f64, f64, f64)> = events
            .iter()
            .filter_map(|e| match *e {
                Event::SelectorState { shard: s, entropy, p_min, p_max, .. } if s == shard => {
                    Some((entropy, p_min, p_max))
                }
                _ => None,
            })
            .collect();
        let (first, last) = (probes[0], probes[probes.len() - 1]);
        let label = if shard == NO_SHARD { "serial".to_string() } else { format!("shard {shard}") };
        out.push_str(&format!(
            "selector    {label}: entropy {:.3}→{:.3}, p∈[{:.4}, {:.4}] at last probe ({} probe(s))\n",
            first.0,
            last.0,
            last.1,
            last.2,
            probes.len()
        ));
    }
}

// ---------------------------------------------------------------------------
// trace diff — regression gate between two JSONL traces
// ---------------------------------------------------------------------------

/// One compared quantity in a [`DiffReport`]. `ratio` is the badness
/// factor of `b` relative to `a`: exactly `1.0` when the raw values are
/// equal (including `0/0`), `+∞` when `a` is zero and `b` is not, and
/// `b/a` otherwise — except the objective row, which uses
/// `1 + (b − a) / max(|a|, 1)` so the gate stays meaningful for
/// negative and near-zero objective values.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub name: String,
    pub a: f64,
    pub b: f64,
    /// display-formatted `a` / `b` (units depend on the metric)
    pub a_disp: String,
    pub b_disp: String,
    pub ratio: f64,
    /// `true`: growth of `b` is a regression (times, work counts);
    /// `false`: shrinkage is (throughput, acceptance rate)
    pub higher_is_worse: bool,
    /// unwatched rows are informational and never trip the gate
    /// (e.g. the final τ — a different bound is a change, not a bug)
    pub watched: bool,
    pub regressed: bool,
}

/// Outcome of comparing two traces; `regressions() > 0` is the CLI's
/// non-zero-exit signal.
#[derive(Clone, Debug)]
pub struct DiffReport {
    pub rows: Vec<DiffRow>,
    pub tolerance: f64,
}

impl DiffReport {
    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.regressed).count()
    }

    /// Display-ready regression table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "-- trace diff (tolerance ±{:.0}%) --\n",
            self.tolerance * 100.0
        ));
        out.push_str(&format!(
            "{:<22} {:>12} {:>12} {:>8}  {}\n",
            "metric", "a", "b", "ratio", "status"
        ));
        for r in &self.rows {
            let ratio = if r.ratio.is_infinite() {
                "∞".to_string()
            } else {
                format!("{:.2}x", r.ratio)
            };
            let status = if r.regressed {
                "REGRESSED"
            } else if !r.watched {
                "info"
            } else {
                "ok"
            };
            out.push_str(&format!(
                "{:<22} {:>12} {:>12} {:>8}  {}\n",
                r.name, r.a_disp, r.b_disp, ratio, status
            ));
        }
        let n = self.regressions();
        if n == 0 {
            out.push_str("no regressions\n");
        } else {
            out.push_str(&format!(
                "{n} regression(s) beyond ±{:.0}%\n",
                self.tolerance * 100.0
            ));
        }
        out
    }
}

/// Aggregate scalars extracted from one parsed trace for comparison.
struct TraceMetrics {
    span_s: f64,
    compute_s: f64,
    merge_wait_s: f64,
    idle_s: f64,
    epochs: f64,
    steps: f64,
    ops: f64,
    ops_per_sec: f64,
    acceptance: f64,
    per_shard_ops_per_sec: Vec<f64>,
    final_objective: Option<f64>,
    final_tau: Option<f64>,
}

fn trace_metrics(trace: &ParsedTrace) -> TraceMetrics {
    let events = &trace.events;
    let b = StageBreakdown::from_events(events);
    let n_shards = shard_count(events);
    let snap = MetricsSnapshot::from_events(events, n_shards, 0.0, f64::INFINITY);
    let steps: u64 = snap.per_shard.iter().map(|w| w.steps).sum();
    let ops: u64 = snap.per_shard.iter().map(|w| w.ops).sum();
    let compute_s = b.compute_nanos as f64 * 1e-9;
    // objective: prefer the epoch-boundary records, then publishes,
    // then the summary line (summary-level traces have no events)
    let final_objective = events
        .iter()
        .rev()
        .find_map(|e| match *e {
            Event::Objective { objective, .. } => Some(objective),
            Event::Publish { objective, .. } => Some(objective),
            _ => None,
        })
        .or_else(|| {
            trace.summary.as_ref().and_then(|s| s.get("objective").and_then(Json::as_f64))
        });
    let final_tau = events
        .iter()
        .rev()
        .find_map(|e| match *e {
            Event::Tau { tau, .. } => Some(tau as f64),
            _ => None,
        });
    TraceMetrics {
        span_s: b.span_nanos as f64 * 1e-9,
        compute_s,
        merge_wait_s: b.merge_wait_nanos as f64 * 1e-9,
        idle_s: b.idle_nanos_estimate() as f64 * 1e-9,
        epochs: b.epochs as f64,
        steps: steps as f64,
        ops: ops as f64,
        ops_per_sec: if compute_s > 0.0 { ops as f64 / compute_s } else { 0.0 },
        acceptance: snap.merge.acceptance_rate(),
        per_shard_ops_per_sec: snap.per_shard.iter().map(|w| w.ops_per_sec()).collect(),
        final_objective,
        final_tau,
    }
}

/// `b` relative to `a` with the [`DiffRow`] conventions.
fn badness_ratio(a: f64, b: f64) -> f64 {
    if a == b {
        1.0
    } else if a == 0.0 {
        f64::INFINITY
    } else {
        b / a
    }
}

fn diff_row(
    name: &str,
    a: f64,
    b: f64,
    fmt: impl Fn(f64) -> String,
    higher_is_worse: bool,
    watched: bool,
    tolerance: f64,
) -> DiffRow {
    let ratio = badness_ratio(a, b);
    let regressed = watched
        && if higher_is_worse { ratio > 1.0 + tolerance } else { ratio < 1.0 - tolerance };
    DiffRow {
        name: name.to_string(),
        a,
        b,
        a_disp: fmt(a),
        b_disp: fmt(b),
        ratio,
        higher_is_worse,
        watched,
        regressed,
    }
}

/// Compare two JSONL traces (`a` = baseline, `b` = candidate) and gate
/// every watched ratio at `tolerance` (0.2 = ±20%). Wall-clock and work
/// metrics regress when `b` grows; throughput and acceptance regress
/// when `b` shrinks; the objective regresses when `b` ends higher than
/// `a` by more than `tolerance` relative to `max(|a|, 1)` (all four
/// paper families minimize). Identical inputs always report zero
/// regressions.
pub fn diff(a_text: &str, b_text: &str, tolerance: f64) -> Result<DiffReport> {
    let (ta, tb) = (parse_trace(a_text)?, parse_trace(b_text)?);
    let (ma, mb) = (trace_metrics(&ta), trace_metrics(&tb));
    let pct = |v: f64| format!("{:.1}%", v * 100.0);
    let mut rows = vec![
        diff_row("wall-clock span", ma.span_s, mb.span_s, fmt_secs, true, true, tolerance),
        diff_row("compute time", ma.compute_s, mb.compute_s, fmt_secs, true, true, tolerance),
        diff_row("merge-wait", ma.merge_wait_s, mb.merge_wait_s, fmt_secs, true, true, tolerance),
        diff_row("idle (est.)", ma.idle_s, mb.idle_s, fmt_secs, true, true, tolerance),
        diff_row("epochs", ma.epochs, mb.epochs, fmt_count, true, true, tolerance),
        diff_row("steps", ma.steps, mb.steps, fmt_count, true, true, tolerance),
        diff_row("ops", ma.ops, mb.ops, fmt_count, true, true, tolerance),
        diff_row(
            "throughput ops/s",
            ma.ops_per_sec,
            mb.ops_per_sec,
            fmt_count,
            false,
            true,
            tolerance,
        ),
        diff_row("acceptance rate", ma.acceptance, mb.acceptance, pct, false, true, tolerance),
    ];
    for (k, (&a, &b)) in
        ma.per_shard_ops_per_sec.iter().zip(&mb.per_shard_ops_per_sec).enumerate()
    {
        rows.push(diff_row(&format!("shard {k} ops/s"), a, b, fmt_count, false, true, tolerance));
    }
    if ma.per_shard_ops_per_sec.len() != mb.per_shard_ops_per_sec.len() {
        rows.push(diff_row(
            "shard count",
            ma.per_shard_ops_per_sec.len() as f64,
            mb.per_shard_ops_per_sec.len() as f64,
            fmt_count,
            true,
            false,
            tolerance,
        ));
    }
    if let (Some(a), Some(b)) = (ma.final_objective, mb.final_objective) {
        // directional, scale-robust: only a *worse* (higher) final
        // objective regresses, measured against max(|a|, 1)
        let rel = (b - a) / a.abs().max(1.0);
        let mut row =
            diff_row("final objective", a, b, |v| format!("{v:.6e}"), true, true, tolerance);
        row.ratio = 1.0 + rel;
        row.regressed = rel > tolerance;
        rows.push(row);
    }
    if let (Some(a), Some(b)) = (ma.final_tau, mb.final_tau) {
        rows.push(diff_row("final tau", a, b, fmt_count, true, false, tolerance));
    }
    Ok(DiffReport { rows, tolerance })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sink::render_trace;
    use crate::obs::{window_snapshots, MergeTier, TraceLevel};

    fn sample_trace() -> String {
        let events = vec![
            Event::Epoch { t: 1_000, shard: 0, steps: 40, ops: 500, nanos: 800 },
            Event::Epoch { t: 2_000, shard: 1, steps: 40, ops: 480, nanos: 700 },
            Event::Submit { t: 2_100, shard: 1, base_version: 1, queue_depth: 1 },
            Event::Merge { t: 2_200, shard: 1, tier: MergeTier::Additive, staleness: 1, batch: 2 },
            Event::Publish { t: 2_300, version: 2, objective: -0.75 },
            Event::Tau { t: 2_400, tau: 3, prev: 2 },
            Event::Park { t: 2_500, shard: 0 },
            Event::MergeWait { t: 2_600, nanos: 300 },
            Event::SelectorState { t: 2_700, shard: 0, entropy: 1.2, p_min: 0.1, p_max: 0.5 },
            Event::SelectorState { t: 2_800, shard: 0, entropy: 1.1, p_min: 0.1, p_max: 0.6 },
            Event::Objective { t: 2_900, shard: NO_SHARD, epoch: 1, objective: -0.75 },
        ];
        let data = TraceData { total: events.len() as u64, dropped: 0, events };
        let snaps = window_snapshots(&data.events, 2, 0.0);
        let mut meta = Json::obj();
        meta.set("problem", json::s("lasso")).set("shards", json::num(2.0));
        let mut summary = Json::obj();
        summary.set("objective", json::num(-0.75)).set("iterations", json::num(80.0));
        render_trace(TraceLevel::Events, &meta, &data, &snaps, &summary)
    }

    #[test]
    fn summarize_round_trips_a_rendered_trace() {
        let report = summarize(&sample_trace()).unwrap();
        assert!(report.contains("problem=lasso"), "{report}");
        assert!(report.contains("-- stage time --"), "{report}");
        assert!(report.contains("-- per shard --"), "{report}");
        assert!(report.contains("-- merge outcomes"), "{report}");
        assert!(report.contains("-- adaptation timeline --"), "{report}");
        assert!(report.contains("2→3"), "{report}");
        assert!(report.contains("shard 0: entropy 1.200→1.100"), "{report}");
        assert!(report.contains("iterations=80"), "{report}");
        assert!(report.contains("epoch-obj"), "{report}");
    }

    #[test]
    fn summary_only_trace_is_reported_without_events() {
        let data = TraceData { total: 0, dropped: 0, events: Vec::new() };
        let text = render_trace(TraceLevel::Summary, &Json::obj(), &data, &[], &Json::obj());
        let report = summarize(&text).unwrap();
        assert!(report.contains("summary-level trace"), "{report}");
    }

    #[test]
    fn malformed_line_names_the_line_number() {
        let text = "{\"kind\":\"meta\"}\nnot json\n";
        let err = summarize(text).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn unknown_event_kind_is_an_error() {
        let text = "{\"kind\":\"wobble\",\"t_ns\":1}\n";
        assert!(summarize(text).is_err());
    }

    /// Minimal two-shard trace with tunable epoch cost and objective.
    fn trace_with(epoch_nanos: u64, objective: f64) -> String {
        let events = vec![
            Event::Epoch { t: 1_000, shard: 0, steps: 40, ops: 500, nanos: epoch_nanos },
            Event::Epoch { t: 2_000, shard: 1, steps: 40, ops: 480, nanos: epoch_nanos },
            Event::Publish { t: 2_300, version: 2, objective },
            Event::Objective { t: 2_900, shard: NO_SHARD, epoch: 1, objective },
        ];
        let data = TraceData { total: events.len() as u64, dropped: 0, events };
        let snaps = window_snapshots(&data.events, 2, 0.0);
        render_trace(TraceLevel::Events, &Json::obj(), &data, &snaps, &Json::obj())
    }

    #[test]
    fn diff_of_identical_traces_reports_zero_regressions() {
        let a = sample_trace();
        let report = diff(&a, &a, 0.2).unwrap();
        assert_eq!(report.regressions(), 0, "{}", report.render());
        // every row compares equal values — the badness ratio is exactly 1
        for row in &report.rows {
            assert_eq!(row.ratio, 1.0, "{}: {} vs {}", row.name, row.a, row.b);
        }
        let text = report.render();
        assert!(text.contains("no regressions"), "{text}");
        // τ is reported but informational — never gated
        let tau = report.rows.iter().find(|r| r.name == "final tau").expect("tau row");
        assert!(!tau.watched && !tau.regressed);
    }

    #[test]
    fn diff_flags_a_slower_candidate_trace() {
        let a = trace_with(800, -0.75);
        let b = trace_with(2_000, -0.75);
        let report = diff(&a, &b, 0.2).unwrap();
        let compute = report.rows.iter().find(|r| r.name == "compute time").unwrap();
        assert!(compute.regressed, "{}", report.render());
        assert!((compute.ratio - 2.5).abs() < 1e-9, "ratio {}", compute.ratio);
        // the slower epochs also sink throughput below the gate
        let thr = report.rows.iter().find(|r| r.name == "throughput ops/s").unwrap();
        assert!(thr.regressed && !thr.higher_is_worse, "{}", report.render());
        assert!(report.regressions() >= 2);
        assert!(report.render().contains("REGRESSED"));
    }

    #[test]
    fn objective_gate_is_directional() {
        let a = trace_with(800, -0.75);
        // an improved (lower) objective is not a regression
        let better = diff(&a, &trace_with(800, -0.95), 0.05).unwrap();
        let row = better.rows.iter().find(|r| r.name == "final objective").unwrap();
        assert!(!row.regressed, "{}", better.render());
        // a worse (higher) one beyond tolerance trips the gate
        let worse = diff(&a, &trace_with(800, 0.75), 0.05).unwrap();
        let row = worse.rows.iter().find(|r| r.name == "final objective").unwrap();
        assert!(row.regressed, "{}", worse.render());
        assert!((row.ratio - 2.5).abs() < 1e-9, "1 + (0.75+0.75)/1, got {}", row.ratio);
    }

    #[test]
    fn diff_handles_summary_only_traces() {
        let mut summary = Json::obj();
        summary.set("objective", json::num(-0.5));
        let data = TraceData { total: 0, dropped: 0, events: Vec::new() };
        let text = render_trace(TraceLevel::Summary, &Json::obj(), &data, &[], &summary);
        let report = diff(&text, &text, 0.2).unwrap();
        assert_eq!(report.regressions(), 0, "{}", report.render());
        let obj = report.rows.iter().find(|r| r.name == "final objective").unwrap();
        assert_eq!(obj.a, -0.5);
    }
}
