//! Lock-free single-producer event ring buffers.
//!
//! Each engine thread (one per shard slot, plus one for the merge
//! driver) owns an [`EventRing`]: a fixed-capacity circular buffer of
//! fixed-width event records stored as plain atomic words. A push is a
//! handful of relaxed stores plus one release store of the sequence
//! counter — no locks, no allocation, no syscalls — so recording never
//! blocks the solver hot path. When the ring wraps, the **oldest**
//! records are overwritten (drop-oldest) and the exact number of lost
//! events stays recoverable from the monotone sequence counter:
//! `dropped = total_pushed − capacity` once the ring is full.
//!
//! # Producer/consumer contract
//!
//! Rings are *single-producer*: exactly one thread pushes to a given
//! ring at a time (the engine guarantees this — a shard's ring is only
//! touched by whichever worker currently holds that shard's state, and
//! the driver ring only by the merge thread). Draining is done at
//! quiescent points (between synchronized rounds, or after the worker
//! scope has joined), so readers never observe a half-written record.
//! Even under a misuse of that contract the buffer stays memory-safe:
//! every word is an [`AtomicU64`], so the worst outcome is a torn
//! *record*, never undefined behavior.

use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed number of 64-bit words per event record (see
/// [`super::Event::encode`]).
pub const EVENT_WORDS: usize = 6;

/// Default per-ring capacity in events (≈3 MiB of atomics per ring).
pub const DEFAULT_RING_CAP: usize = 1 << 16;

/// A fixed-capacity, drop-oldest, lock-free event ring (see module
/// docs for the producer/consumer contract).
#[derive(Debug)]
pub struct EventRing {
    words: Box<[AtomicU64]>,
    cap: usize,
    /// Total records ever pushed; `head % cap` is the next write slot.
    head: AtomicU64,
}

impl EventRing {
    /// Create a ring holding `cap` event records.
    pub fn new(cap: usize) -> EventRing {
        assert!(cap > 0, "ring capacity must be positive");
        let words: Vec<AtomicU64> = (0..cap * EVENT_WORDS).map(|_| AtomicU64::new(0)).collect();
        EventRing { words: words.into_boxed_slice(), cap, head: AtomicU64::new(0) }
    }

    /// Record capacity in events.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Push one encoded record, overwriting the oldest when full.
    #[inline]
    pub fn push(&self, raw: [u64; EVENT_WORDS]) {
        // ORDERING: Relaxed: single-producer ring — only the owning shard
        // thread writes `head`, so its own prior store is always visible.
        let seq = self.head.load(Ordering::Relaxed);
        let base = (seq as usize % self.cap) * EVENT_WORDS;
        for (i, w) in raw.iter().enumerate() {
            // ORDERING: Relaxed: the record words are published by the
            // Release store of `head` below; readers Acquire `head` first.
            self.words[base + i].store(*w, Ordering::Relaxed);
        }
        self.head.store(seq + 1, Ordering::Release);
    }

    /// Total records ever pushed (monotone; not capped at capacity).
    pub fn total(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Exact number of records lost to drop-oldest overwrites.
    pub fn dropped(&self) -> u64 {
        self.total().saturating_sub(self.cap as u64)
    }

    /// Records currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.total().min(self.cap as u64) as usize
    }

    /// True when nothing has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Copy out the retained records, oldest first. Call only at a
    /// quiescent point (see module docs).
    pub fn drain(&self) -> Vec<[u64; EVENT_WORDS]> {
        let head = self.total();
        let retained = head.min(self.cap as u64);
        let mut out = Vec::with_capacity(retained as usize);
        for seq in (head - retained)..head {
            let base = (seq as usize % self.cap) * EVENT_WORDS;
            let mut raw = [0u64; EVENT_WORDS];
            for (i, r) in raw.iter_mut().enumerate() {
                // ORDERING: Relaxed: `total()` Acquire-loaded `head` above,
                // which synchronizes with the producer's Release store and
                // makes all records below `head` visible.
                *r = self.words[base + i].load(Ordering::Relaxed);
            }
            out.push(raw);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(x: u64) -> [u64; EVENT_WORDS] {
        [x, x + 1, x + 2, x + 3, x + 4, x + 5]
    }

    #[test]
    fn push_and_drain_in_order() {
        let ring = EventRing::new(8);
        assert!(ring.is_empty());
        for x in 0..5 {
            ring.push(rec(x));
        }
        assert_eq!(ring.len(), 5);
        assert_eq!(ring.dropped(), 0);
        let got = ring.drain();
        assert_eq!(got, (0..5).map(rec).collect::<Vec<_>>());
    }

    #[test]
    fn overflow_keeps_dropped_counter_exact() {
        let ring = EventRing::new(4);
        for x in 0..10 {
            ring.push(rec(x));
        }
        assert_eq!(ring.total(), 10);
        assert_eq!(ring.len(), 4);
        // 10 pushed into 4 slots: exactly 6 overwritten, newest 4 kept.
        assert_eq!(ring.dropped(), 6);
        assert_eq!(ring.drain(), (6..10).map(rec).collect::<Vec<_>>());
        // Further pushes keep the accounting exact.
        ring.push(rec(10));
        assert_eq!(ring.dropped(), 7);
        assert_eq!(ring.drain(), (7..11).map(rec).collect::<Vec<_>>());
    }

    #[test]
    fn exact_capacity_boundary_drops_nothing() {
        let ring = EventRing::new(4);
        for x in 0..4 {
            ring.push(rec(x));
        }
        assert_eq!(ring.dropped(), 0);
        assert_eq!(ring.len(), 4);
    }

    #[test]
    fn concurrent_producers_on_disjoint_rings_are_race_free() {
        // One ring per thread (the engine's actual layout): every push
        // must land and every counter must stay exact under real
        // parallelism.
        // Miri explores this interleaving at interpreter speed: keep the
        // shape but shrink the per-thread push count.
        let pushes: u64 = if cfg!(miri) { 100 } else { 1000 };
        let rings: Vec<EventRing> = (0..4).map(|_| EventRing::new(64)).collect();
        std::thread::scope(|scope| {
            for (i, ring) in rings.iter().enumerate() {
                scope.spawn(move || {
                    for x in 0..pushes {
                        ring.push(rec(x * 4 + i as u64));
                    }
                });
            }
        });
        for ring in &rings {
            assert_eq!(ring.total(), pushes);
            assert_eq!(ring.dropped(), pushes - 64);
            assert_eq!(ring.len(), 64);
        }
    }
}
