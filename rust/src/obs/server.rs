//! Minimal blocking HTTP/1.1 telemetry server on `std::net`.
//!
//! Serves three read-only endpoints off a shared [`LiveMetrics`]
//! registry:
//!
//! - `GET /metrics` — Prometheus text exposition ([`crate::obs::export`])
//! - `GET /snapshot` — JSON of the latest [`LivePoint`] (snapshot +
//!   merge stats), same shape as the run's JSONL windows
//! - `GET /healthz` — liveness probe (`ok`)
//!
//! The design reuses the [`crate::util::threadpool`] idioms rather than
//! pulling in an HTTP stack: an acceptor thread polls a non-blocking
//! `TcpListener` and pushes accepted connections onto a bounded
//! [`WorkQueue`], and a small fixed set of worker threads drain it with
//! `pop_timeout`, so a stalled client can never wedge shutdown. Every
//! response closes the connection (`Connection: close`) — scrapers
//! reconnect per scrape, which keeps the server stateless.
//!
//! The server holds only an `Arc<LiveMetrics>`; it cannot reach solver
//! state, so the non-perturbation contract is structural.

use super::export::render_prometheus;
use super::live::{LiveMetrics, LivePoint};
use crate::util::error::Result;
use crate::util::json::{self, Json};
use crate::util::threadpool::{Pop, WorkQueue};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Worker threads draining accepted connections. Telemetry traffic is
/// one scraper every few seconds; two workers cover a slow client
/// overlapping a health probe.
const WORKERS: usize = 2;
/// Per-connection socket timeout — a scraper that stalls longer is
/// dropped.
const IO_TIMEOUT: Duration = Duration::from_secs(2);
/// Upper bound on request-head bytes read before giving up.
const MAX_HEAD: usize = 8 * 1024;

/// Handle to a running telemetry server. Dropping it (or calling
/// [`MetricsServer::stop`]) shuts the listener and workers down.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<WorkQueue<TcpStream>>,
    threads: Vec<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`; port `0` picks an ephemeral
    /// port — read it back via [`MetricsServer::local_addr`]) and start
    /// serving `live`.
    pub fn start(addr: &str, live: Arc<LiveMetrics>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| crate::anyhow!("metrics: cannot bind {}: {}", addr, e))?;
        let local = listener
            .local_addr()
            .map_err(|e| crate::anyhow!("metrics: no local addr: {}", e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| crate::anyhow!("metrics: set_nonblocking: {}", e))?;

        let stop = Arc::new(AtomicBool::new(false));
        let queue: Arc<WorkQueue<TcpStream>> = Arc::new(WorkQueue::new());
        let mut threads = Vec::with_capacity(WORKERS + 1);

        let accept_queue = Arc::clone(&queue);
        let accept_stop = Arc::clone(&stop);
        threads.push(
            std::thread::Builder::new()
                .name("metrics-accept".to_string())
                .spawn(move || {
                    let stop = accept_stop;
                    // ORDERING: Acquire: pairs with the Release store in
                    // `stop()` so everything sequenced before the shutdown
                    // request is visible when the acceptor winds down.
                    while !stop.load(Ordering::Acquire) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                accept_queue.push_counted(stream);
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(25));
                            }
                            // transient accept errors (e.g. ECONNABORTED):
                            // keep listening
                            Err(_) => std::thread::sleep(Duration::from_millis(25)),
                        }
                    }
                })
                .map_err(|e| crate::anyhow!("metrics: spawn acceptor: {}", e))?,
        );

        for w in 0..WORKERS {
            let q = Arc::clone(&queue);
            let lv = Arc::clone(&live);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("metrics-worker-{w}"))
                    .spawn(move || loop {
                        match q.pop_timeout(Duration::from_millis(100)) {
                            Pop::Item(stream) => handle_connection(stream, &lv),
                            Pop::TimedOut => continue,
                            Pop::Shutdown => break,
                        }
                    })
                    .map_err(|e| crate::anyhow!("metrics: spawn worker: {}", e))?,
            );
        }

        Ok(MetricsServer { addr: local, stop, queue, threads })
    }

    /// The bound address (resolves port `0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain the workers, and join all threads.
    /// Idempotent.
    pub fn stop(&mut self) {
        // ORDERING: Release: pairs with the acceptor's Acquire load; a
        // Relaxed store here could in principle let the shutdown flag
        // trail the queue teardown on a weakly-ordered machine.
        self.stop.store(true, Ordering::Release);
        self.queue.shutdown();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Read the request head, route it, and write one response.
fn handle_connection(mut stream: TcpStream, live: &LiveMetrics) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let head = match read_head(&mut stream) {
        Some(h) => h,
        None => return,
    };
    let (status, content_type, body) = route(&head, live);
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

/// Read until the blank line terminating the request head (bounded by
/// [`MAX_HEAD`]); returns `None` on timeout, disconnect, or oversize.
fn read_head(stream: &mut TcpStream) -> Option<String> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => return None,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") {
                    return Some(String::from_utf8_lossy(&buf).into_owned());
                }
                if buf.len() > MAX_HEAD {
                    return None;
                }
            }
            Err(_) => return None,
        }
    }
}

/// Dispatch on the request line; returns `(status, content-type, body)`.
fn route(head: &str, live: &LiveMetrics) -> (&'static str, &'static str, String) {
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    // ignore any query string — endpoints take no parameters
    let path = path.split('?').next().unwrap_or(path);
    if method != "GET" {
        let body = "method not allowed\n".to_string();
        return ("405 Method Not Allowed", "text/plain; charset=utf-8", body);
    }
    match path {
        "/metrics" => {
            live.record_scrape();
            ("200 OK", "text/plain; version=0.0.4; charset=utf-8", render_prometheus(live))
        }
        "/snapshot" => ("200 OK", "application/json", snapshot_json(live)),
        "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    }
}

/// JSON view of the latest [`LivePoint`]: labels, scrape count, the
/// metrics snapshot (same shape as JSONL `metrics_snapshot` records),
/// and the merge-layer accounting.
fn snapshot_json(live: &LiveMetrics) -> String {
    let point: Arc<LivePoint> = live.latest();
    let ms = &point.merge_stats;
    let mut labels = Json::obj();
    for (k, v) in live.labels() {
        labels.set(k, json::s(v));
    }
    let mut merge_stats = Json::obj();
    merge_stats
        .set("objective_evals", json::num(ms.objective_evals as f64))
        .set("accepted_submissions", json::num(ms.accepted_submissions as f64))
        .set("rejected_submissions", json::num(ms.rejected_submissions as f64))
        .set("batched_merges", json::num(ms.batched_merges as f64))
        .set("staleness_bound_final", json::num(ms.staleness_bound_final as f64));
    let mut j = Json::obj();
    j.set("labels", labels)
        .set("scrapes", json::num(live.scrapes() as f64))
        .set("snapshot", point.snapshot.to_json())
        .set("merge_stats", merge_stats);
    let mut out = j.to_string_compact();
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Blocking one-shot HTTP GET; returns `(status_line, body)`.
    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(stream, "GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).expect("read");
        let split = raw.find("\r\n\r\n").expect("header terminator");
        let status = raw.lines().next().unwrap_or("").to_string();
        (status, raw[split + 4..].to_string())
    }

    fn serve() -> (MetricsServer, Arc<LiveMetrics>) {
        let live = Arc::new(LiveMetrics::new(vec![("job".to_string(), "test".to_string())]));
        let srv = MetricsServer::start("127.0.0.1:0", Arc::clone(&live)).expect("start");
        (srv, live)
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri does not model sockets")]
    fn healthz_roundtrip() {
        let (srv, _live) = serve();
        let (status, body) = http_get(srv.local_addr(), "/healthz");
        assert!(status.contains("200"), "{status}");
        assert_eq!(body, "ok\n");
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri does not model sockets")]
    fn metrics_endpoint_serves_exposition_and_counts_scrapes() {
        let (srv, live) = serve();
        let (status, body) = http_get(srv.local_addr(), "/metrics");
        assert!(status.contains("200"), "{status}");
        assert!(body.contains("# TYPE acf_scrapes_total counter"), "{body}");
        assert!(body.contains("acf_uptime_seconds"), "{body}");
        assert_eq!(live.scrapes(), 1);
        let (_, body2) = http_get(srv.local_addr(), "/metrics");
        assert!(body2.contains("acf_scrapes_total{job=\"test\"} 2"), "{body2}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri does not model sockets")]
    fn snapshot_endpoint_returns_parseable_json() {
        let (srv, live) = serve();
        {
            let mut rec =
                super::super::live::LiveRecorder::new(Arc::clone(&live), 1);
            rec.objective(-3.25);
            rec.flush();
        }
        let (status, body) = http_get(srv.local_addr(), "/snapshot");
        assert!(status.contains("200"), "{status}");
        let j = json::parse(&body).expect("parse snapshot json");
        assert_eq!(
            j.get("labels").and_then(|l| l.get("job")).and_then(Json::as_str),
            Some("test")
        );
        let snap = j.get("snapshot").expect("snapshot key");
        assert_eq!(snap.get("last_objective").and_then(Json::as_f64), Some(-3.25));
        assert!(j.get("merge_stats").is_some());
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri does not model sockets")]
    fn unknown_path_is_404_and_post_is_405() {
        let (srv, _live) = serve();
        let (status, _) = http_get(srv.local_addr(), "/nope");
        assert!(status.contains("404"), "{status}");

        let mut stream = TcpStream::connect(srv.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(stream, "POST /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405"), "{raw}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri does not model sockets")]
    fn stop_joins_all_threads() {
        let (mut srv, _live) = serve();
        let addr = srv.local_addr();
        srv.stop();
        srv.stop(); // idempotent
        // the listener is gone: a fresh bind on the same port succeeds
        let rebind = TcpListener::bind(addr);
        assert!(rebind.is_ok(), "port not released: {rebind:?}");
    }
}
