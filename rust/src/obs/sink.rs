//! JSONL serialization of the trace stream (and the parser the `trace`
//! subcommand reads it back with).
//!
//! A trace file is line-delimited JSON built entirely on
//! [`crate::util::json`]. Line layout:
//!
//! 1. one `"kind": "meta"` header (level, event totals, exact dropped
//!    count, plus caller-supplied run metadata),
//! 2. one line per retained event at `spans`/`events` level — every
//!    event line carries `kind` and `t_ns` (nanoseconds since collector
//!    start) plus the kind-specific payload listed in the
//!    [module taxonomy](crate::obs),
//! 3. one `"kind": "metrics_snapshot"` line per aggregation window
//!    ([`MetricsSnapshot::to_json`]),
//! 4. one closing `"kind": "summary"` line (end-of-run aggregates,
//!    repeated drop accounting).
//!
//! Numbers round-trip exactly: integers print without a decimal point
//! and floats use the shortest representation that re-parses to the
//! same bits.

use super::{Event, MergeTier, MetricsSnapshot, TraceData, TraceLevel, NO_SHARD};
use crate::util::json::{self, Json};
use crate::{Error, Result};

/// Serialize one event as a JSONL object.
pub fn event_to_json(ev: &Event) -> Json {
    let mut j = Json::obj();
    j.set("kind", json::s(ev.kind())).set("t_ns", json::num(ev.t() as f64));
    match *ev {
        Event::SnapshotTake { shard, version, .. } => {
            j.set("shard", shard_num(shard)).set("version", json::num(version as f64));
        }
        Event::Epoch { shard, steps, ops, nanos, .. } => {
            j.set("shard", shard_num(shard))
                .set("steps", json::num(steps as f64))
                .set("ops", json::num(ops as f64))
                .set("nanos", json::num(nanos as f64));
        }
        Event::Submit { shard, base_version, queue_depth, .. } => {
            j.set("shard", shard_num(shard))
                .set("base_version", json::num(base_version as f64))
                .set("queue_depth", json::num(queue_depth as f64));
        }
        Event::Merge { shard, tier, staleness, batch, .. } => {
            j.set("shard", shard_num(shard))
                .set("tier", json::s(tier.name()))
                .set("staleness", json::num(staleness as f64))
                .set("batch", json::num(batch as f64));
        }
        Event::Publish { version, objective, .. } => {
            j.set("version", json::num(version as f64)).set("objective", json::num(objective));
        }
        Event::Tau { tau, prev, .. } => {
            j.set("tau", json::num(tau as f64)).set("prev", json::num(prev as f64));
        }
        Event::Park { shard, .. } => {
            j.set("shard", shard_num(shard));
        }
        Event::MergeWait { nanos, .. } => {
            j.set("nanos", json::num(nanos as f64));
        }
        Event::SelectorState { shard, entropy, p_min, p_max, .. } => {
            j.set("shard", shard_num(shard))
                .set("entropy", json::num(entropy))
                .set("p_min", json::num(p_min))
                .set("p_max", json::num(p_max));
        }
        Event::DataExtent { shard, bytes, pages, .. } => {
            j.set("shard", shard_num(shard))
                .set("bytes", json::num(bytes as f64))
                .set("pages", json::num(pages as f64));
        }
        Event::Objective { shard, epoch, objective, .. } => {
            j.set("shard", shard_num(shard))
                .set("epoch", json::num(epoch as f64))
                .set("objective", json::num(objective));
        }
        Event::EngineStats { pool_rounds, queue_pushes, queue_max_depth, .. } => {
            j.set("pool_rounds", json::num(pool_rounds as f64))
                .set("queue_pushes", json::num(queue_pushes as f64))
                .set("queue_max_depth", json::num(queue_max_depth as f64));
        }
    }
    j
}

fn shard_num(shard: u32) -> Json {
    json::num(if shard == NO_SHARD { -1.0 } else { shard as f64 })
}

fn field_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::msg(format!("trace line missing numeric field '{key}'")))
}

fn field_u64(j: &Json, key: &str) -> Result<u64> {
    Ok(field_f64(j, key)? as u64)
}

fn field_shard(j: &Json) -> Result<u32> {
    let x = field_f64(j, "shard")?;
    Ok(if x < 0.0 { NO_SHARD } else { x as u32 })
}

/// Parse one event line back (inverse of [`event_to_json`]). Returns
/// `Ok(None)` for valid non-event lines (`meta`, `metrics_snapshot`,
/// `summary`) and `Err` for anything malformed.
pub fn event_from_json(j: &Json) -> Result<Option<Event>> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::msg("trace line has no 'kind' field"))?;
    if matches!(kind, "meta" | "metrics_snapshot" | "summary") {
        return Ok(None);
    }
    let t = field_u64(j, "t_ns")?;
    let ev = match kind {
        "snapshot_take" => Event::SnapshotTake { t, shard: field_shard(j)?, version: field_u64(j, "version")? },
        "epoch" => Event::Epoch {
            t,
            shard: field_shard(j)?,
            steps: field_u64(j, "steps")?,
            ops: field_u64(j, "ops")?,
            nanos: field_u64(j, "nanos")?,
        },
        "submit" => Event::Submit {
            t,
            shard: field_shard(j)?,
            base_version: field_u64(j, "base_version")?,
            queue_depth: field_u64(j, "queue_depth")?,
        },
        "merge" => {
            let tier_name = j
                .get("tier")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::msg("merge line has no 'tier' field"))?;
            Event::Merge {
                t,
                shard: field_shard(j)?,
                tier: MergeTier::parse(tier_name)
                    .ok_or_else(|| Error::msg(format!("unknown merge tier '{tier_name}'")))?,
                staleness: field_u64(j, "staleness")?,
                batch: field_u64(j, "batch")?,
            }
        }
        "publish" => Event::Publish {
            t,
            version: field_u64(j, "version")?,
            objective: field_f64(j, "objective")?,
        },
        "tau" => Event::Tau { t, tau: field_u64(j, "tau")?, prev: field_u64(j, "prev")? },
        "park" => Event::Park { t, shard: field_shard(j)? },
        "merge_wait" => Event::MergeWait { t, nanos: field_u64(j, "nanos")? },
        "selector" => Event::SelectorState {
            t,
            shard: field_shard(j)?,
            entropy: field_f64(j, "entropy")?,
            p_min: field_f64(j, "p_min")?,
            p_max: field_f64(j, "p_max")?,
        },
        "data_extent" => Event::DataExtent {
            t,
            shard: field_shard(j)?,
            bytes: field_u64(j, "bytes")?,
            pages: field_u64(j, "pages")?,
        },
        "objective" => Event::Objective {
            t,
            shard: field_shard(j)?,
            epoch: field_u64(j, "epoch")?,
            objective: field_f64(j, "objective")?,
        },
        "engine_stats" => Event::EngineStats {
            t,
            pool_rounds: field_u64(j, "pool_rounds")?,
            queue_pushes: field_u64(j, "queue_pushes")?,
            queue_max_depth: field_u64(j, "queue_max_depth")?,
        },
        other => return Err(Error::msg(format!("unknown trace event kind '{other}'"))),
    };
    Ok(Some(ev))
}

/// Render a complete trace file (see module docs for the line layout).
/// `meta` and `summary` are caller-supplied objects (run identity and
/// end-of-run aggregates); non-object values are replaced by `{}`.
pub fn render_trace(
    level: TraceLevel,
    meta: &Json,
    data: &TraceData,
    snapshots: &[MetricsSnapshot],
    summary: &Json,
) -> String {
    let mut out = String::new();
    let mut head = as_object(meta);
    head.set("kind", json::s("meta"))
        .set("level", json::s(level.name()))
        .set("events_total", json::num(data.total as f64))
        .set("events_retained", json::num(data.events.len() as f64))
        .set("dropped_events", json::num(data.dropped as f64));
    out.push_str(&head.to_string_compact());
    out.push('\n');
    if level >= TraceLevel::Spans {
        for ev in &data.events {
            out.push_str(&event_to_json(ev).to_string_compact());
            out.push('\n');
        }
    }
    if level >= TraceLevel::Summary {
        for snap in snapshots {
            out.push_str(&snap.to_json().to_string_compact());
            out.push('\n');
        }
        let mut tail = as_object(summary);
        tail.set("kind", json::s("summary")).set("dropped_events", json::num(data.dropped as f64));
        out.push_str(&tail.to_string_compact());
        out.push('\n');
    }
    out
}

fn as_object(j: &Json) -> Json {
    match j {
        Json::Obj(_) => j.clone(),
        _ => Json::obj(),
    }
}

/// Write a rendered trace to `path`.
pub fn write_trace(path: &str, content: &str) -> Result<()> {
    std::fs::write(path, content)
        .map_err(|e| Error::msg(format!("cannot write trace file '{path}': {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{window_snapshots, MergeTier};

    fn sample_events() -> Vec<Event> {
        vec![
            Event::SnapshotTake { t: 10, shard: 2, version: 7 },
            Event::Epoch { t: 900, shard: 0, steps: 41, ops: 1234, nanos: 777 },
            Event::Submit { t: 1_000, shard: 1, base_version: 7, queue_depth: 3 },
            Event::Merge { t: 1_050, shard: 1, tier: MergeTier::Damped, staleness: 2, batch: 4 },
            Event::Merge { t: 1_060, shard: NO_SHARD, tier: MergeTier::Additive, staleness: 0, batch: 4 },
            Event::Publish { t: 1_100, version: 8, objective: 0.125 + 1e-13 },
            Event::Tau { t: 1_200, tau: 4, prev: 2 },
            Event::Park { t: 1_300, shard: 3 },
            Event::MergeWait { t: 1_400, nanos: 50_123 },
            Event::SelectorState { t: 1_500, shard: 0, entropy: 1.386_294, p_min: 0.05, p_max: 0.4 },
            Event::SelectorState { t: 1_600, shard: NO_SHARD, entropy: 0.5, p_min: 0.1, p_max: 0.9 },
            Event::DataExtent { t: 1_700, shard: 2, bytes: 36_864, pages: 10 },
            Event::Objective { t: 1_800, shard: NO_SHARD, epoch: 3, objective: -2.5 + 1e-12 },
            Event::EngineStats { t: 1_900, pool_rounds: 9, queue_pushes: 21, queue_max_depth: 4 },
        ]
    }

    #[test]
    fn every_kind_round_trips_through_jsonl() {
        for ev in sample_events() {
            let line = event_to_json(&ev).to_string_compact();
            let parsed = json::parse(&line).expect(&line);
            let back = event_from_json(&parsed).unwrap().expect("event line");
            assert_eq!(back, ev, "{line}");
        }
    }

    #[test]
    fn full_trace_renders_and_parses_line_by_line() {
        let events = sample_events();
        let data = TraceData { total: events.len() as u64 + 5, dropped: 5, events };
        let snaps = window_snapshots(&data.events, 4, 0.0);
        let mut meta = Json::obj();
        meta.set("problem", json::s("svm")).set("shards", json::num(4.0));
        let mut summary = Json::obj();
        summary.set("objective", json::num(-3.5));
        let text = render_trace(TraceLevel::Events, &meta, &data, &snaps, &summary);
        let lines: Vec<&str> = text.lines().collect();
        // meta + events + 1 snapshot + summary
        assert_eq!(lines.len(), 1 + data.events.len() + snaps.len() + 1);
        let mut events_seen = 0;
        for line in &lines {
            let j = json::parse(line).expect(line);
            if event_from_json(&j).expect(line).is_some() {
                events_seen += 1;
            }
        }
        assert_eq!(events_seen, data.events.len());
        let head = json::parse(lines[0]).unwrap();
        assert_eq!(head.get("kind").and_then(Json::as_str), Some("meta"));
        assert_eq!(head.get("dropped_events").and_then(Json::as_f64), Some(5.0));
        assert_eq!(head.get("problem").and_then(Json::as_str), Some("svm"));
        let tail = json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(tail.get("kind").and_then(Json::as_str), Some("summary"));
        assert_eq!(tail.get("objective").and_then(Json::as_f64), Some(-3.5));
    }

    #[test]
    fn summary_level_omits_event_lines() {
        let events = sample_events();
        let data = TraceData { total: events.len() as u64, dropped: 0, events };
        let text = render_trace(TraceLevel::Summary, &Json::obj(), &data, &[], &Json::obj());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2); // meta + summary only
        for line in lines {
            let j = json::parse(line).unwrap();
            assert!(event_from_json(&j).unwrap().is_none());
        }
    }

    #[test]
    fn malformed_lines_are_rejected_with_context() {
        let j = json::parse(r#"{"kind":"merge","t_ns":1,"shard":0,"tier":"sideways","staleness":0,"batch":1}"#).unwrap();
        let err = event_from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("sideways"));
        let j = json::parse(r#"{"kind":"epoch","t_ns":1,"shard":0}"#).unwrap();
        assert!(event_from_json(&j).is_err());
        let j = json::parse(r#"{"t_ns":1}"#).unwrap();
        assert!(event_from_json(&j).is_err());
    }
}
