//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them from Rust via
//! the `xla` crate's PJRT CPU client. Python never runs here.
//!
//! Graph contract (kept in sync with `python/compile/model.py`):
//!
//! | graph         | inputs                                   | outputs |
//! |---------------|------------------------------------------|---------|
//! | `margins`     | X (BL,BD) f32, w (BD,) f32               | (m (BL,) f32,) |
//! | `binary_eval` | m (BL,) f32, y (BL,) f32, mask (BL,) f32 | ((4,) f32,) |
//! | `cd_sweep`    | Q (N,N) f32, w (N,) f32, seq (M,) i32    | (w' (N,) f32, total (1,) f32) |
//!
//! The validator streams dense tiles of the (sparse) design matrix
//! through `margins`, accumulates partial margins per row block, then
//! reduces losses/accuracy with `binary_eval`. It lives on the
//! *evaluation* path (objective audits, accuracy) — the CD iteration
//! hot loop is pure Rust (see DESIGN.md §2).
//!
//! # Offline builds
//!
//! The PJRT path requires the `xla` crate and built artifacts, neither of
//! which exists in the dependency-free offline build. It is therefore
//! gated behind the `pjrt` cargo feature: without it, [`Runtime`] keeps
//! the same API but every entry point returns an explicit "unavailable"
//! error, so the CLI (`acf-cd info`, `--validate`) and the coordinator
//! degrade gracefully instead of failing to link.

pub mod validator;

use crate::anyhow;
use crate::util::error::Result;
use crate::util::json::Json;
use std::path::PathBuf;

/// Tile contract — must match python/compile/model.py.
pub const BL: usize = 256;
pub const BD: usize = 256;
pub const MARKOV_N: usize = 8;
pub const MARKOV_M: usize = 256;

impl Runtime {
    /// Default artifacts directory: `$ACF_CD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("ACF_CD_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load(&Self::default_dir())
    }
}

/// Loaded and compiled AOT artifacts (stub: the crate was built without
/// the `pjrt` feature, so nothing can be loaded or executed).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Json,
}

#[cfg(not(feature = "pjrt"))]
fn unavailable() -> crate::Error {
    anyhow!(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (add the `xla` dependency, build the AOT artifacts with `make artifacts`, \
         then rebuild with `--features pjrt`)"
    )
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub loader — always fails with an actionable message.
    pub fn load(_dir: &std::path::Path) -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Execute the margins graph on one dense tile (stub).
    pub fn margins_tile(&self, _x_tile: &[f32], _w_tile: &[f32]) -> Result<Vec<f32>> {
        Err(unavailable())
    }

    /// Execute the fused loss/accuracy reduction on one margins block
    /// (stub).
    pub fn binary_eval_block(&self, _m: &[f32], _y: &[f32], _mask: &[f32]) -> Result<[f32; 4]> {
        Err(unavailable())
    }

    /// Execute one CD sweep block on the dense quadratic (stub).
    pub fn cd_sweep_block(&self, _q: &[f32], _w: &[f32], _seq: &[i32]) -> Result<(Vec<f32>, f32)> {
        Err(unavailable())
    }
}

/// Loaded and compiled AOT artifacts.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    margins: xla::PjRtLoadedExecutable,
    binary_eval: xla::PjRtLoadedExecutable,
    cd_sweep: xla::PjRtLoadedExecutable,
    pub manifest: Json,
}

#[cfg(feature = "pjrt")]
fn compile(client: &xla::PjRtClient, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path.to_str().ok_or_else(|| anyhow!("non-UTF-8 HLO path: {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str).map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e:?}"))
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load from an artifacts directory (default: `artifacts/` next to
    /// the current dir, or `$ACF_CD_ARTIFACTS`).
    pub fn load(dir: &std::path::Path) -> Result<Runtime> {
        use crate::util::error::Context;
        use crate::util::json;
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {:?}/manifest.json — run `make artifacts`", dir))?;
        let manifest = json::parse(&manifest_text).context("parsing manifest.json")?;
        // verify the tile contract
        let bl = manifest
            .get("tile")
            .and_then(|t| t.get("bl"))
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest missing tile.bl"))?;
        if bl != BL {
            return Err(anyhow!("artifact tile BL {bl} != runtime BL {BL}; rebuild artifacts"));
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        let margins = compile(&client, &dir.join("margins.hlo.txt"))?;
        let binary_eval = compile(&client, &dir.join("binary_eval.hlo.txt"))?;
        let cd_sweep = compile(&client, &dir.join("cd_sweep.hlo.txt"))?;
        Ok(Runtime { client, margins, binary_eval, cd_sweep, manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the margins graph on one dense tile.
    /// `x_tile`: BL·BD row-major f32; `w_tile`: BD f32. Returns BL partial
    /// margins.
    pub fn margins_tile(&self, x_tile: &[f32], w_tile: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(x_tile.len(), BL * BD);
        assert_eq!(w_tile.len(), BD);
        let x = xla::Literal::vec1(x_tile).reshape(&[BL as i64, BD as i64])?;
        let w = xla::Literal::vec1(w_tile);
        let result = self.margins.execute::<xla::Literal>(&[x, w])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute the fused loss/accuracy reduction on one margins block.
    /// Returns `[hinge_sum, logistic_sum, correct, sq_err_sum]`.
    pub fn binary_eval_block(&self, m: &[f32], y: &[f32], mask: &[f32]) -> Result<[f32; 4]> {
        assert_eq!(m.len(), BL);
        assert_eq!(y.len(), BL);
        assert_eq!(mask.len(), BL);
        let lm = xla::Literal::vec1(m);
        let ly = xla::Literal::vec1(y);
        let lmask = xla::Literal::vec1(mask);
        let result = self.binary_eval.execute::<xla::Literal>(&[lm, ly, lmask])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let v = out.to_vec::<f32>()?;
        Ok([v[0], v[1], v[2], v[3]])
    }

    /// Execute one CD sweep block on the dense quadratic. `q` is
    /// MARKOV_N² row-major f32 (pad unused coordinates with identity
    /// diagonal), `w` MARKOV_N f32, `seq` MARKOV_M i32 indices into the
    /// *real* coordinates. Returns (w_out, total_log_progress).
    pub fn cd_sweep_block(&self, q: &[f32], w: &[f32], seq: &[i32]) -> Result<(Vec<f32>, f32)> {
        assert_eq!(q.len(), MARKOV_N * MARKOV_N);
        assert_eq!(w.len(), MARKOV_N);
        assert_eq!(seq.len(), MARKOV_M);
        let lq = xla::Literal::vec1(q).reshape(&[MARKOV_N as i64, MARKOV_N as i64])?;
        let lw = xla::Literal::vec1(w);
        let lseq = xla::Literal::vec1(seq);
        let result = self.cd_sweep.execute::<xla::Literal>(&[lq, lw, lseq])?[0][0].to_literal_sync()?;
        let (w_out, total) = result.to_tuple2()?;
        Ok((w_out.to_vec::<f32>()?, total.to_vec::<f32>()?[0]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        // Tests are skipped gracefully when artifacts are not built; the
        // Makefile/integration path always builds them first.
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: artifacts not built");
            return None;
        }
        Some(Runtime::load(&dir).expect("artifacts present but failed to load"))
    }

    #[test]
    #[ignore = "requires PJRT/JAX AOT artifacts: run `make artifacts` and build with --features pjrt"]
    fn loads_and_reports_platform() {
        let Some(rt) = runtime() else { return };
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    #[ignore = "requires PJRT/JAX AOT artifacts: run `make artifacts` and build with --features pjrt"]
    fn margins_tile_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = crate::util::rng::Rng::new(1);
        let x: Vec<f32> = (0..BL * BD).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let w: Vec<f32> = (0..BD).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let got = rt.margins_tile(&x, &w).unwrap();
        for r in 0..BL {
            let want: f32 = (0..BD).map(|c| x[r * BD + c] * w[c]).sum();
            assert!((got[r] - want).abs() <= 1e-3 * want.abs().max(1.0), "row {r}: {} vs {}", got[r], want);
        }
    }

    #[test]
    #[ignore = "requires PJRT/JAX AOT artifacts: run `make artifacts` and build with --features pjrt"]
    fn binary_eval_block_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = crate::util::rng::Rng::new(2);
        let m: Vec<f32> = (0..BL).map(|_| rng.normal(0.0, 2.0) as f32).collect();
        let y: Vec<f32> = (0..BL).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let mask: Vec<f32> = (0..BL).map(|i| if i < 200 { 1.0 } else { 0.0 }).collect();
        let [hinge, logistic, correct, sq] = rt.binary_eval_block(&m, &y, &mask).unwrap();
        let mut e_h = 0.0f64;
        let mut e_l = 0.0f64;
        let mut e_c = 0.0f64;
        let mut e_s = 0.0f64;
        for i in 0..200 {
            let ym = (y[i] * m[i]) as f64;
            e_h += (1.0 - ym).max(0.0);
            e_l += (-ym).max(0.0) + (-(ym.abs())).exp().ln_1p();
            if ym > 0.0 {
                e_c += 1.0;
            }
            e_s += ((m[i] - y[i]) as f64).powi(2);
        }
        assert!((hinge as f64 - e_h).abs() < 1e-2 * e_h.max(1.0));
        assert!((logistic as f64 - e_l).abs() < 1e-2 * e_l.max(1.0));
        assert_eq!(correct as f64, e_c);
        assert!((sq as f64 - e_s).abs() < 1e-2 * e_s.max(1.0));
    }

    #[test]
    #[ignore = "requires PJRT/JAX AOT artifacts: run `make artifacts` and build with --features pjrt"]
    fn cd_sweep_block_matches_rust_chain() {
        let Some(rt) = runtime() else { return };
        // real n = 5 padded into MARKOV_N = 8 with identity diagonal
        let n = 5usize;
        let mut rng = crate::util::rng::Rng::new(3);
        let quad = crate::markov::Quadratic::rbf_gram(n, 1.0, &mut rng);
        let mut q = vec![0.0f32; MARKOV_N * MARKOV_N];
        for i in 0..MARKOV_N {
            for j in 0..MARKOV_N {
                q[i * MARKOV_N + j] = if i < n && j < n {
                    quad.entry(i, j) as f32
                } else if i == j {
                    1.0
                } else {
                    0.0
                };
            }
        }
        let w0: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mut w_pad = vec![0.0f32; MARKOV_N];
        for i in 0..n {
            w_pad[i] = w0[i] as f32;
        }
        let seq: Vec<i32> = (0..MARKOV_M).map(|k| (k % n) as i32).collect();
        let (w_out, total) = rt.cd_sweep_block(&q, &w_pad, &seq).unwrap();
        // rust chain replay
        let mut chain = crate::markov::Chain { q: &quad, w: w0 };
        let seq_u: Vec<u32> = seq.iter().map(|&i| i as u32).collect();
        let total_rust = chain.apply_sequence(&seq_u);
        assert!(
            (total as f64 - total_rust).abs() < 0.05 * total_rust.abs().max(1.0),
            "pallas {total} vs rust {total_rust}"
        );
        // padded coordinates untouched
        for i in n..MARKOV_N {
            assert_eq!(w_out[i], 0.0);
        }
    }
}
