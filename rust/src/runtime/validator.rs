//! Tiled validator: streams a sparse dataset through the AOT `margins`
//! and `binary_eval` graphs to produce implementation-independent audits
//! of the Rust-native solvers — primal losses, accuracy, squared error —
//! computed by a *different* stack (JAX/Pallas → XLA) than the solver
//! itself. Used on the evaluation path only.

use super::Runtime;
use crate::sparse::Dataset;
use crate::util::error::Result;

/// Aggregated validation metrics over a dataset.
#[derive(Clone, Copy, Debug, Default)]
pub struct ValidationReport {
    /// Σ max(0, 1 − y⟨w,x⟩)
    pub hinge_sum: f64,
    /// Σ log(1 + exp(−y⟨w,x⟩))
    pub logistic_sum: f64,
    /// fraction of correctly classified instances
    pub accuracy: f64,
    /// Σ (⟨w,x⟩ − y)²
    pub sq_err_sum: f64,
    pub instances: usize,
}

impl ValidationReport {
    /// SVM primal objective ½‖w‖² + C·hinge_sum.
    pub fn svm_primal(&self, w: &[f64], c: f64) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(w) + c * self.hinge_sum
    }

    /// Logistic primal objective ½‖w‖² + C·logistic_sum.
    pub fn logreg_primal(&self, w: &[f64], c: f64) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(w) + c * self.logistic_sum
    }
}

/// Run the tiled validation of a linear model over a dataset.
///
/// Tiling: rows in blocks of BL; for each row block, margins are
/// accumulated over ⌈d/BD⌉ column tiles through the `margins` graph,
/// then reduced by `binary_eval` with a padding mask.
pub fn validate(rt: &Runtime, ds: &Dataset, w: &[f64]) -> Result<ValidationReport> {
    use super::{BD, BL};
    assert_eq!(w.len(), ds.n_features());
    let l = ds.n_instances();
    let d = ds.n_features();
    let row_blocks = l.div_ceil(BL);
    let col_blocks = d.div_ceil(BD).max(1);

    let mut totals = [0.0f64; 4];
    let mut x_tile = vec![0.0f32; BL * BD];
    let mut w_tile = vec![0.0f32; BD];
    let mut margins = vec![0.0f32; BL];
    let mut y_block = vec![0.0f32; BL];
    let mut mask = vec![0.0f32; BL];

    for rb in 0..row_blocks {
        let r0 = rb * BL;
        let r1 = ((rb + 1) * BL).min(l);
        margins.iter_mut().for_each(|m| *m = 0.0);
        for cb in 0..col_blocks {
            let c0 = cb * BD;
            let c1 = ((cb + 1) * BD).min(d);
            // dense tile extraction (padded)
            let tile = ds.x.dense_block(r0, r0 + BL, c0, c0 + BD);
            x_tile.copy_from_slice(&tile);
            w_tile.iter_mut().for_each(|v| *v = 0.0);
            for (k, c) in (c0..c1).enumerate() {
                w_tile[k] = w[c] as f32;
            }
            let partial = rt.margins_tile(&x_tile, &w_tile)?;
            for (m, p) in margins.iter_mut().zip(partial.iter()) {
                *m += p;
            }
        }
        for (k, slot) in y_block.iter_mut().enumerate() {
            let r = r0 + k;
            if r < r1 {
                *slot = ds.y[r] as f32;
                mask[k] = 1.0;
            } else {
                *slot = 0.0;
                mask[k] = 0.0;
            }
        }
        let part = rt.binary_eval_block(&margins, &y_block, &mask)?;
        for (t, p) in totals.iter_mut().zip(part.iter()) {
            *t += *p as f64;
        }
    }

    Ok(ValidationReport {
        hinge_sum: totals[0],
        logistic_sum: totals[1],
        accuracy: totals[2] / l.max(1) as f64,
        sq_err_sum: totals[3],
        instances: l,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping validator test: artifacts not built");
            return None;
        }
        Some(Runtime::load(&dir).unwrap())
    }

    #[test]
    #[ignore = "requires PJRT/JAX AOT artifacts: run `make artifacts` and build with --features pjrt"]
    fn validator_matches_native_metrics() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(5);
        let ds = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "v",
                n: 300,
                d: 290, // forces ragged row and column tiles
                nnz_per_row: 12,
                zipf_s: 1.0,
                concept_k: 20,
                noise: 0.05,
            },
            &mut rng,
        );
        let w: Vec<f64> = (0..ds.n_features()).map(|_| rng.normal(0.0, 0.3)).collect();
        let rep = validate(&rt, &ds, &w).unwrap();
        // native recomputation
        let mut hinge = 0.0;
        let mut logi = 0.0;
        let mut correct = 0usize;
        let mut sq = 0.0;
        for i in 0..ds.n_instances() {
            let m = ds.x.row(i).dot_dense(&w);
            let ym = ds.y[i] * m;
            hinge += (1.0 - ym).max(0.0);
            logi += if ym > 0.0 { (-ym).exp().ln_1p() } else { -ym + ym.exp().ln_1p() };
            if ym > 0.0 {
                correct += 1;
            }
            sq += (m - ds.y[i]) * (m - ds.y[i]);
        }
        let acc = correct as f64 / ds.n_instances() as f64;
        assert!((rep.hinge_sum - hinge).abs() < 1e-2 * hinge.max(1.0), "{} vs {hinge}", rep.hinge_sum);
        assert!((rep.logistic_sum - logi).abs() < 1e-2 * logi.max(1.0));
        assert!((rep.accuracy - acc).abs() < 1e-9, "{} vs {acc}", rep.accuracy);
        assert!((rep.sq_err_sum - sq).abs() < 1e-2 * sq.max(1.0));
        assert_eq!(rep.instances, 300);
    }

    #[test]
    #[ignore = "requires PJRT/JAX AOT artifacts: run `make artifacts` and build with --features pjrt"]
    fn validator_agrees_with_solver_primal() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(6);
        let ds = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "v2",
                n: 200,
                d: 150,
                nnz_per_row: 10,
                zipf_s: 1.0,
                concept_k: 15,
                noise: 0.02,
            },
            &mut rng,
        );
        let c = 1.0;
        let mut sched =
            crate::sched::PermutationScheduler::new(ds.n_instances(), Rng::new(7));
        let (model, res) = crate::solvers::svm::solve(
            &ds,
            c,
            &mut sched,
            crate::solvers::SolverConfig::with_eps(1e-4),
        );
        assert!(res.status.converged());
        let rep = validate(&rt, &ds, &model.w).unwrap();
        let primal_xla = rep.svm_primal(&model.w, c);
        let primal_native = crate::solvers::svm::primal_objective(&ds, &model.w, c);
        assert!(
            (primal_xla - primal_native).abs() < 1e-2 * primal_native.max(1.0),
            "xla {primal_xla} vs native {primal_native}"
        );
    }
}
