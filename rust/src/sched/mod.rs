//! Coordinate-selection policies behind one interface.
//!
//! The trait itself now lives in the [`crate::select`] subsystem as
//! [`crate::select::Selector`]; this module re-exports it under its
//! original name `Scheduler` (the two names are the same trait) and
//! keeps the epoch-sweep baseline policies plus the [`Policy`] name
//! registry. The `select/` subsystem adds the adaptive alternatives
//! (EXP3 bandit, adaptive importance sampling) and the `--selector`
//! face-off machinery.
//!
//! The CD solvers are generic over [`Scheduler`]; the paper's comparison
//! is exactly a comparison of these policies:
//!
//! * [`CyclicScheduler`] — deterministic `i ← t mod n` sweeps (the
//!   classic LASSO solver of Friedman et al.).
//! * [`PermutationScheduler`] — epoch sweeps over a fresh random
//!   permutation (liblinear's default).
//! * [`UniformScheduler`] — i.i.d. uniform selection.
//! * [`AcfSchedulerPolicy`] — the paper's contribution (wraps
//!   [`crate::acf::AcfScheduler`]).
//! * [`Policy::Hierarchical`] — two-level ACF over a shard partition
//!   (implemented by [`crate::shard::HierarchicalScheduler`]); the serial
//!   twin of the parallel engine in [`crate::shard`].
//!
//! Shrinking (liblinear's heuristic) is implemented *inside* the SVM
//! solver — it is an active-set transformation of the problem rather than
//! a pure selection policy — but from the CD perspective it is the
//! baseline's form of online frequency adaptation (§3.2).

use crate::acf::{AcfParams, AcfScheduler};
use crate::util::rng::Rng;

/// The coordinate-selection trait, re-exported from [`crate::select`]
/// under its historical name (`Scheduler` and
/// [`crate::select::Selector`] are the same trait — every implementor
/// of one satisfies the other).
pub use crate::select::Selector as Scheduler;

/// Deterministic cyclic sweeps: 0, 1, …, n−1, 0, 1, …
#[derive(Clone, Debug)]
pub struct CyclicScheduler {
    n: usize,
    t: usize,
}

impl CyclicScheduler {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self { n, t: 0 }
    }
}

impl Scheduler for CyclicScheduler {
    #[inline]
    fn next(&mut self) -> usize {
        let i = self.t;
        self.t += 1;
        if self.t == self.n {
            self.t = 0;
        }
        i
    }

    fn n(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "cyclic"
    }
}

/// Epoch sweeps over a fresh uniform random permutation (liblinear).
#[derive(Clone, Debug)]
pub struct PermutationScheduler {
    perm: Vec<u32>,
    cursor: usize,
    rng: Rng,
}

impl PermutationScheduler {
    pub fn new(n: usize, rng: Rng) -> Self {
        assert!(n > 0);
        Self { perm: (0..n as u32).collect(), cursor: n, rng }
    }
}

impl Scheduler for PermutationScheduler {
    #[inline]
    fn next(&mut self) -> usize {
        if self.cursor >= self.perm.len() {
            self.rng.shuffle(&mut self.perm);
            self.cursor = 0;
        }
        let i = self.perm[self.cursor];
        self.cursor += 1;
        i as usize
    }

    fn n(&self) -> usize {
        self.perm.len()
    }

    fn name(&self) -> &'static str {
        "random-permutation"
    }
}

/// I.i.d. uniform selection.
#[derive(Clone, Debug)]
pub struct UniformScheduler {
    n: usize,
    rng: Rng,
}

impl UniformScheduler {
    pub fn new(n: usize, rng: Rng) -> Self {
        assert!(n > 0);
        Self { n, rng }
    }
}

impl Scheduler for UniformScheduler {
    #[inline]
    fn next(&mut self) -> usize {
        self.rng.below(self.n)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "uniform-iid"
    }
}

/// The ACF policy (paper Algorithms 2+3).
#[derive(Clone, Debug)]
pub struct AcfSchedulerPolicy {
    inner: AcfScheduler,
}

impl AcfSchedulerPolicy {
    pub fn new(n: usize, params: AcfParams, rng: Rng) -> Self {
        Self { inner: AcfScheduler::new(n, params, rng) }
    }

    pub fn inner(&self) -> &AcfScheduler {
        &self.inner
    }
}

impl Scheduler for AcfSchedulerPolicy {
    #[inline]
    fn next(&mut self) -> usize {
        self.inner.next()
    }

    #[inline]
    fn report(&mut self, i: usize, delta_f: f64) {
        self.inner.report(i, delta_f);
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn name(&self) -> &'static str {
        "acf"
    }

    fn probabilities_into(&self, out: &mut Vec<f64>) {
        self.inner.preferences().probabilities_into(out);
    }
}

/// Named policy selector used by the CLI / coordinator / benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Cyclic,
    Permutation,
    Uniform,
    Acf,
    /// Two-level ACF over a shard partition (see
    /// [`crate::shard::HierarchicalScheduler`]). `shards = 0` selects
    /// √n automatically.
    Hierarchical { shards: usize, partitioner: crate::shard::Partitioner },
}

/// Valid policy names, kept in sync with [`Policy::parse`] (shown in CLI
/// error messages and help).
pub const POLICY_NAMES: &str = "cyclic, permutation|perm, uniform, acf, hierarchical|hier";

impl Policy {
    /// Case-insensitive name lookup. On failure the error lists every
    /// valid policy name, so a typo like `ACF→AFC` is self-explaining.
    pub fn parse(s: &str) -> Result<Policy, String> {
        match s.to_ascii_lowercase().as_str() {
            "cyclic" => Ok(Policy::Cyclic),
            "permutation" | "perm" | "random-permutation" => Ok(Policy::Permutation),
            "uniform" | "uniform-iid" => Ok(Policy::Uniform),
            "acf" => Ok(Policy::Acf),
            "hierarchical" | "hier" | "hierarchical-acf" => Ok(Policy::Hierarchical {
                shards: 0,
                partitioner: crate::shard::Partitioner::Contiguous,
            }),
            other => Err(format!("unknown policy '{other}' (valid: {POLICY_NAMES})")),
        }
    }

    pub fn build(self, n: usize, params: AcfParams, rng: Rng) -> Box<dyn Scheduler> {
        match self {
            Policy::Cyclic => Box::new(CyclicScheduler::new(n)),
            Policy::Permutation => Box::new(PermutationScheduler::new(n, rng)),
            Policy::Uniform => Box::new(UniformScheduler::new(n, rng)),
            Policy::Acf => Box::new(AcfSchedulerPolicy::new(n, params, rng)),
            Policy::Hierarchical { shards, partitioner } => {
                Box::new(crate::shard::HierarchicalScheduler::new(n, shards, partitioner, params, rng))
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Cyclic => "cyclic",
            Policy::Permutation => "random-permutation",
            Policy::Uniform => "uniform-iid",
            Policy::Acf => "acf",
            Policy::Hierarchical { .. } => "hierarchical-acf",
        }
    }

    /// Pin the shard count of the hierarchical policy (no-op for flat
    /// policies).
    pub fn with_shards(self, shards: usize) -> Policy {
        match self {
            Policy::Hierarchical { partitioner, .. } => Policy::Hierarchical { shards, partitioner },
            other => other,
        }
    }

    /// Pin the partitioner of the hierarchical policy (no-op for flat
    /// policies).
    pub fn with_partitioner(self, partitioner: crate::shard::Partitioner) -> Policy {
        match self {
            Policy::Hierarchical { shards, .. } => Policy::Hierarchical { shards, partitioner },
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn cyclic_order() {
        let mut s = CyclicScheduler::new(3);
        let seq: Vec<usize> = (0..7).map(|_| s.next()).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn permutation_each_epoch_is_permutation() {
        prop::check(20, |g| {
            let n = g.usize_in(1, 50);
            let mut s = PermutationScheduler::new(n, Rng::new(g.seed));
            for _ in 0..3 {
                let mut epoch: Vec<usize> = (0..n).map(|_| s.next()).collect();
                epoch.sort_unstable();
                prop::assert_holds(epoch == (0..n).collect::<Vec<_>>(), "epoch is a permutation")?;
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_covers_everything_eventually() {
        let n = 20;
        let mut s = UniformScheduler::new(n, Rng::new(5));
        let mut seen = vec![false; n];
        for _ in 0..2000 {
            seen[s.next()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn policy_parse_and_build() {
        for (name, expect) in [
            ("cyclic", Policy::Cyclic),
            ("perm", Policy::Permutation),
            ("uniform", Policy::Uniform),
            ("acf", Policy::Acf),
            ("hier", Policy::Hierarchical { shards: 0, partitioner: crate::shard::Partitioner::Contiguous }),
        ] {
            assert_eq!(Policy::parse(name), Ok(expect));
            let s = expect.build(4, AcfParams::default(), Rng::new(1));
            assert_eq!(s.n(), 4);
        }
    }

    #[test]
    fn policy_parse_is_case_insensitive() {
        assert_eq!(Policy::parse("ACF"), Ok(Policy::Acf));
        assert_eq!(Policy::parse("Cyclic"), Ok(Policy::Cyclic));
        assert_eq!(
            Policy::parse("HIERARCHICAL"),
            Ok(Policy::Hierarchical { shards: 0, partitioner: crate::shard::Partitioner::Contiguous })
        );
    }

    #[test]
    fn policy_parse_error_lists_valid_names() {
        let e = Policy::parse("bogus").unwrap_err();
        for name in ["cyclic", "perm", "uniform", "acf", "hier"] {
            assert!(e.contains(name), "error message misses '{name}': {e}");
        }
    }

    #[test]
    fn hierarchical_policy_shards_pinnable() {
        let p = Policy::parse("hier").unwrap().with_shards(3);
        assert_eq!(p, Policy::Hierarchical { shards: 3, partitioner: crate::shard::Partitioner::Contiguous });
        assert_eq!(p.name(), "hierarchical-acf");
        let s = p.build(12, AcfParams::default(), Rng::new(2));
        assert_eq!(s.n(), 12);
        // flat policies ignore the shard hint
        assert_eq!(Policy::Acf.with_shards(5), Policy::Acf);
    }

    #[test]
    fn probabilities_default_uniform() {
        let s = CyclicScheduler::new(4);
        assert_eq!(s.probabilities(), vec![0.25; 4]);
    }

    #[test]
    fn acf_policy_adapts_probabilities() {
        let mut s = AcfSchedulerPolicy::new(4, AcfParams::default(), Rng::new(6));
        for _ in 0..2000 {
            let i = s.next();
            s.report(i, if i == 2 { 5.0 } else { 0.1 });
        }
        let p = s.probabilities();
        assert!(p[2] > 0.3, "{p:?}");
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }
}
