//! The ACF selector — a thin adapter over [`crate::acf::AcfScheduler`].
//!
//! The adapter adds nothing: `next`/`report` delegate 1:1 and the RNG is
//! handed to the scheduler untouched, so a solver driven through
//! [`AcfSelector`] is **bit-identical** to the pre-subsystem path that
//! hard-wired `AcfScheduler` (asserted by
//! `acf_selector_bit_identical_to_raw_scheduler_on_recorded_trace` in
//! the module tests).

use super::Selector;
use crate::acf::{AcfParams, AcfScheduler};
use crate::util::rng::Rng;

/// The paper's Adaptive Coordinate Frequencies policy (Algorithms 2+3)
/// behind the [`Selector`] interface.
#[derive(Clone, Debug)]
pub struct AcfSelector {
    inner: AcfScheduler,
}

impl AcfSelector {
    pub fn new(n: usize, params: AcfParams, rng: Rng) -> AcfSelector {
        AcfSelector { inner: AcfScheduler::new(n, params, rng) }
    }

    /// Wrap an existing scheduler (lets callers pre-warm preferences).
    pub fn from_scheduler(inner: AcfScheduler) -> AcfSelector {
        AcfSelector { inner }
    }

    pub fn inner(&self) -> &AcfScheduler {
        &self.inner
    }
}

impl Selector for AcfSelector {
    #[inline]
    fn next(&mut self) -> usize {
        self.inner.next()
    }

    #[inline]
    fn report(&mut self, i: usize, delta_f: f64) {
        if !delta_f.is_finite() {
            // protect the preference vector from NaN/inf progress; a
            // finite trace is forwarded untouched, preserving the
            // bit-identity contract
            return;
        }
        self.inner.report(i, delta_f);
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn name(&self) -> &'static str {
        "acf"
    }

    fn probabilities_into(&self, out: &mut Vec<f64>) {
        self.inner.preferences().probabilities_into(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adapts_towards_rewarding_coordinate() {
        let mut s = AcfSelector::new(6, AcfParams::default(), Rng::new(11));
        for _ in 0..3_000 {
            let i = s.next();
            s.report(i, if i == 4 { 5.0 } else { 0.05 });
        }
        let p = s.probabilities();
        assert!(p[4] > 2.0 / 6.0, "{p:?}");
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn non_finite_reports_are_ignored() {
        let mut s = AcfSelector::new(5, AcfParams::default(), Rng::new(3));
        let mut clean = AcfSelector::new(5, AcfParams::default(), Rng::new(3));
        for t in 0..2_000 {
            let i = s.next();
            let j = clean.next();
            assert_eq!(i, j, "streams diverged at step {t}");
            let df = if i == 2 { 3.0 } else { 0.1 };
            s.report(i, df);
            s.report(i, f64::NAN);
            s.report(i, f64::INFINITY);
            clean.report(j, df);
        }
        assert_eq!(s.probabilities(), clean.probabilities());
        assert!(s.probabilities().iter().all(|p| p.is_finite()));
    }

    #[test]
    fn from_scheduler_preserves_state() {
        let mut raw = AcfScheduler::new(4, AcfParams::default(), Rng::new(1));
        for _ in 0..200 {
            let i = raw.next();
            raw.report(i, i as f64);
        }
        let expect = raw.preferences().probabilities();
        let s = AcfSelector::from_scheduler(raw);
        assert_eq!(s.probabilities(), expect);
    }
}
