//! EXP3-style bandit selection, after Salehi et al., *Coordinate
//! Descent with Bandit Sampling* (arXiv:1712.03010).
//!
//! Each coordinate is an arm; the reward of pulling arm `i` is the
//! observed step progress Δf_i, normalized into `[0, 1]` by a fading
//! running maximum (Δf is unbounded and non-stationary, EXP3 assumes
//! bounded rewards). The classic EXP3 mixture
//!
//! ```text
//! p_i = (1 − γ)·softmax(L)_i + γ/n,      L_i += γ · r̂_i / n,
//! r̂_i = r_i / p_i                         (importance weighting)
//! ```
//!
//! keeps a γ/n exploration floor on every coordinate, which preserves
//! the essentially-cyclic waiting-time bound (and with it CD
//! convergence) no matter how skewed the learned weights get. Weights
//! are stored in log space and re-centered when the maximum grows past
//! a threshold, so the softmax never overflows.
//!
//! Selection itself goes through [`BlockSampler`] — the distribution is
//! frozen for one block (~n draws) and refreshed at block boundaries,
//! the same amortized-O(1) regime ACF uses (an exact i.i.d. draw per
//! step would cost O(n) each).

use super::{BlockSampler, Selector};
use crate::util::rng::Rng;

/// Exploration rate γ (also the uniform floor mass). Salehi et al. tune
/// γ per horizon; a fixed small constant is robust across our tasks and
/// keeps the floor — the convergence-critical part — independent of
/// run length.
const GAMMA: f64 = 0.1;

/// Log-weight re-centering threshold (softmax-invariant shift).
const LOG_W_RECENTER: f64 = 64.0;

/// EXP3 bandit coordinate selection.
#[derive(Clone, Debug)]
pub struct Exp3BanditSelector {
    /// log-space arm weights L_i
    log_w: Vec<f64>,
    /// fading maximum of observed Δf (reward normalizer)
    scale: f64,
    /// per-report decay of `scale` (fades over ~2 sweeps)
    scale_decay: f64,
    sampler: BlockSampler,
    rng: Rng,
}

impl Exp3BanditSelector {
    pub fn new(n: usize, rng: Rng) -> Exp3BanditSelector {
        assert!(n > 0);
        Exp3BanditSelector {
            log_w: vec![0.0; n],
            scale: 0.0,
            scale_decay: 1.0 - 1.0 / (2.0 * n as f64),
            sampler: BlockSampler::new(n),
            rng,
        }
    }
}

/// EXP3 mixture probabilities from log-weights (numerically stable
/// softmax + γ-floor), written into `out` without allocating.
fn fill_probs(log_w: &[f64], out: &mut Vec<f64>) {
    let n = log_w.len() as f64;
    let m = log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    out.clear();
    out.extend(log_w.iter().map(|&lw| (lw - m).exp()));
    let sum: f64 = out.iter().sum();
    for p in out.iter_mut() {
        *p = (1.0 - GAMMA) * *p / sum + GAMMA / n;
    }
}

impl Selector for Exp3BanditSelector {
    #[inline]
    fn next(&mut self) -> usize {
        let log_w = &self.log_w;
        self.sampler.next(&mut self.rng, |out| fill_probs(log_w, out))
    }

    fn report(&mut self, i: usize, delta_f: f64) {
        if !delta_f.is_finite() {
            // an inf reward would pin `scale` at inf (NaN ratios from
            // then on) and a NaN would corrupt the log-weights — drop it
            return;
        }
        let delta_f = delta_f.max(0.0);
        self.scale = (self.scale * self.scale_decay).max(delta_f);
        if delta_f <= 0.0 || self.scale <= 0.0 {
            return; // zero reward: importance-weighted update is a no-op
        }
        let n = self.log_w.len() as f64;
        let r = (delta_f / self.scale).min(1.0);
        // p_i of the block the draw came from; the floor keeps r̂ bounded
        let p = self.sampler.probability(i).max(GAMMA / n);
        self.log_w[i] += GAMMA * r / (p * n);
        if self.log_w[i] > LOG_W_RECENTER {
            let m = self.log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            for lw in self.log_w.iter_mut() {
                *lw -= m;
            }
        }
    }

    fn n(&self) -> usize {
        self.log_w.len()
    }

    fn name(&self) -> &'static str {
        "bandit"
    }

    fn probabilities_into(&self, out: &mut Vec<f64>) {
        fill_probs(&self.log_w, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concentrates_on_the_rewarding_arm() {
        let n = 10;
        let mut s = Exp3BanditSelector::new(n, Rng::new(1));
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            let i = s.next();
            counts[i] += 1;
            s.report(i, if i == 3 { 1.0 } else { 0.01 });
        }
        let others_max = counts
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != 3)
            .map(|(_, &c)| c)
            .max()
            .unwrap();
        assert!(counts[3] > 2 * others_max, "{counts:?}");
        // the γ/n floor keeps every arm alive
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn floor_bounds_the_probabilities() {
        let n = 5;
        let mut s = Exp3BanditSelector::new(n, Rng::new(2));
        for _ in 0..10_000 {
            let i = s.next();
            s.report(i, if i == 0 { 100.0 } else { 0.0 });
        }
        let p = s.probabilities();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{p:?}");
        for &pi in &p {
            assert!(pi >= GAMMA / n as f64 - 1e-12, "{p:?}");
            assert!(pi <= 1.0 - GAMMA + GAMMA / n as f64 + 1e-12, "{p:?}");
        }
    }

    #[test]
    fn non_finite_reports_are_ignored() {
        let n = 6;
        let mut s = Exp3BanditSelector::new(n, Rng::new(4));
        let mut clean = Exp3BanditSelector::new(n, Rng::new(4));
        for t in 0..3_000 {
            let i = s.next();
            let j = clean.next();
            assert_eq!(i, j, "streams diverged at step {t}");
            let df = if i == 1 { 2.0 } else { 0.05 };
            s.report(i, df);
            s.report(i, f64::INFINITY);
            s.report(i, f64::NAN);
            clean.report(j, df);
        }
        assert_eq!(s.probabilities(), clean.probabilities());
        assert!(s.log_w.iter().all(|lw| lw.is_finite()), "{:?}", s.log_w);
        assert!(s.scale.is_finite());
    }

    #[test]
    fn log_weights_never_overflow_under_constant_max_rewards() {
        let mut s = Exp3BanditSelector::new(3, Rng::new(3));
        for _ in 0..200_000 {
            let i = s.next();
            s.report(i, 1.0);
        }
        assert!(s.log_w.iter().all(|lw| lw.is_finite()), "{:?}", s.log_w);
        let p = s.probabilities();
        assert!(p.iter().all(|x| x.is_finite() && *x > 0.0), "{p:?}");
    }
}
