//! Permuted-cyclic sweeps — every epoch visits every coordinate exactly
//! once, in a fresh random order (liblinear's default epoch structure;
//! the strongest non-adaptive baseline in the paper's comparisons).
//!
//! Distinct from [`crate::sched::CyclicScheduler`], which sweeps in
//! fixed index order: the per-epoch permutation removes the pathological
//! orderings fixed sweeps are vulnerable to while keeping the
//! once-per-epoch coverage guarantee.

use super::Selector;
use crate::util::rng::Rng;

/// Permuted-cyclic coordinate selection.
#[derive(Clone, Debug)]
pub struct CyclicSelector {
    perm: Vec<u32>,
    cursor: usize,
    rng: Rng,
}

impl CyclicSelector {
    pub fn new(n: usize, rng: Rng) -> CyclicSelector {
        assert!(n > 0);
        // cursor starts exhausted so the first `next` shuffles
        CyclicSelector { perm: (0..n as u32).collect(), cursor: n, rng }
    }
}

impl Selector for CyclicSelector {
    #[inline]
    fn next(&mut self) -> usize {
        if self.cursor >= self.perm.len() {
            self.rng.shuffle(&mut self.perm);
            self.cursor = 0;
        }
        let i = self.perm[self.cursor];
        self.cursor += 1;
        i as usize
    }

    fn n(&self) -> usize {
        self.perm.len()
    }

    fn name(&self) -> &'static str {
        "cyclic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn each_epoch_is_a_permutation() {
        prop::check(20, |g| {
            let n = g.usize_in(1, 50);
            let mut s = CyclicSelector::new(n, Rng::new(g.seed));
            for _ in 0..3 {
                let mut epoch: Vec<usize> = (0..n).map(|_| s.next()).collect();
                epoch.sort_unstable();
                prop::assert_holds(epoch == (0..n).collect::<Vec<_>>(), "epoch is a permutation")?;
            }
            Ok(())
        });
    }

    #[test]
    fn consecutive_epochs_differ() {
        let n = 32;
        let mut s = CyclicSelector::new(n, Rng::new(7));
        let a: Vec<usize> = (0..n).map(|_| s.next()).collect();
        let b: Vec<usize> = (0..n).map(|_| s.next()).collect();
        assert_ne!(a, b, "permutations should be re-drawn per epoch");
    }
}
