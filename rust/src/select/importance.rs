//! Adaptive importance sampling, after Perekrestenko et al., *Faster
//! Coordinate Descent via Adaptive Importance Sampling*
//! (arXiv:1703.02518).
//!
//! The reference scheme samples coordinates proportionally to
//! per-coordinate gradient (duality-gap) bounds that are cheap to keep
//! current. Our solvers expose exactly one cheap per-step signal — the
//! realized progress Δf — so the selector maintains a fading average
//! progress estimate `s_i` per coordinate and samples
//!
//! ```text
//! p_i = (1 − ε)·ŝ_i / Σ ŝ  +  ε/n
//! ```
//!
//! where `ŝ_i` is `s_i` for visited coordinates and the running mean of
//! the visited estimates for unvisited ones (optimistic initialization:
//! a coordinate is never starved merely because it has not been tried).
//! The ε/n floor preserves the essentially-cyclic waiting-time bound,
//! exactly as the clip range `p_min` does for ACF.
//!
//! Compared to [`super::Exp3BanditSelector`] this is the greedier
//! scheme: probabilities follow the raw estimates instead of an
//! exponential-weights posterior, which reacts faster but can
//! over-commit when progress estimates go stale together (the fading
//! average and the floor are the two stabilizers).

use super::{BlockSampler, Selector};
use crate::util::rng::Rng;

/// Uniform mixing floor ε.
const EPSILON: f64 = 0.2;

/// Fading rate β of the per-coordinate progress average.
const BETA: f64 = 0.3;

/// Adaptive importance sampling from running progress estimates.
#[derive(Clone, Debug)]
pub struct ImportanceSelector {
    /// fading average progress per coordinate (valid where `seen`)
    est: Vec<f64>,
    seen: Vec<bool>,
    /// Σ est over seen coordinates (kept incrementally)
    seen_sum: f64,
    seen_count: usize,
    sampler: BlockSampler,
    rng: Rng,
}

impl ImportanceSelector {
    pub fn new(n: usize, rng: Rng) -> ImportanceSelector {
        assert!(n > 0);
        ImportanceSelector {
            est: vec![0.0; n],
            seen: vec![false; n],
            seen_sum: 0.0,
            seen_count: 0,
            sampler: BlockSampler::new(n),
            rng,
        }
    }
}

/// Importance probabilities from the estimates (floored mixture),
/// written into `out` without allocating.
fn fill_probs(est: &[f64], seen: &[bool], seen_sum: f64, seen_count: usize, out: &mut Vec<f64>) {
    let n = est.len();
    out.clear();
    if seen_count == 0 || seen_sum <= 0.0 {
        // no signal yet (or a fully converged stretch): stay uniform
        out.resize(n, 1.0 / n as f64);
        return;
    }
    let mean = seen_sum / seen_count as f64;
    out.extend(est.iter().zip(seen.iter()).map(|(&s, &v)| if v { s } else { mean }));
    let total: f64 = out.iter().sum();
    if total <= 0.0 {
        out.clear();
        out.resize(n, 1.0 / n as f64);
        return;
    }
    for p in out.iter_mut() {
        *p = (1.0 - EPSILON) * *p / total + EPSILON / n as f64;
    }
}

impl Selector for ImportanceSelector {
    #[inline]
    fn next(&mut self) -> usize {
        let (est, seen) = (&self.est, &self.seen);
        let (sum, count) = (self.seen_sum, self.seen_count);
        self.sampler.next(&mut self.rng, |out| fill_probs(est, seen, sum, count, out))
    }

    fn report(&mut self, i: usize, delta_f: f64) {
        if !delta_f.is_finite() {
            // a single NaN/inf would flow into est/seen_sum and corrupt
            // every subsequent probability vector — drop it
            return;
        }
        let delta_f = delta_f.max(0.0);
        if self.seen[i] {
            let new = (1.0 - BETA) * self.est[i] + BETA * delta_f;
            self.seen_sum += new - self.est[i];
            self.est[i] = new;
        } else {
            // first sample initializes the fading average directly
            self.seen[i] = true;
            self.seen_count += 1;
            self.est[i] = delta_f;
            self.seen_sum += delta_f;
        }
    }

    fn n(&self) -> usize {
        self.est.len()
    }

    fn name(&self) -> &'static str {
        "importance"
    }

    fn probabilities_into(&self, out: &mut Vec<f64>) {
        fill_probs(&self.est, &self.seen, self.seen_sum, self.seen_count, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_uniform_then_concentrates() {
        let n = 8;
        let mut s = ImportanceSelector::new(n, Rng::new(1));
        assert_eq!(s.probabilities(), vec![1.0 / n as f64; n]);
        let mut counts = vec![0usize; n];
        for _ in 0..16_000 {
            let i = s.next();
            counts[i] += 1;
            s.report(i, if i == 5 { 4.0 } else { 0.05 });
        }
        let others_max = counts
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != 5)
            .map(|(_, &c)| c)
            .max()
            .unwrap();
        assert!(counts[5] > 2 * others_max, "{counts:?}");
    }

    #[test]
    fn floor_keeps_every_coordinate_alive() {
        let n = 6;
        let mut s = ImportanceSelector::new(n, Rng::new(2));
        for _ in 0..12_000 {
            let i = s.next();
            s.report(i, if i == 0 { 10.0 } else { 0.0 });
        }
        let p = s.probabilities();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{p:?}");
        for &pi in &p {
            assert!(pi >= EPSILON / n as f64 - 1e-12, "{p:?}");
        }
    }

    #[test]
    fn all_zero_progress_recovers_uniform() {
        // a converged stretch must not divide by a zero estimate sum
        let n = 4;
        let mut s = ImportanceSelector::new(n, Rng::new(3));
        for _ in 0..4_000 {
            let i = s.next();
            s.report(i, 0.0);
        }
        let p = s.probabilities();
        assert!(p.iter().all(|x| (x - 0.25).abs() < 1e-9), "{p:?}");
    }

    #[test]
    fn non_finite_reports_are_ignored() {
        // a solver pushing a NaN/inf Δf (e.g. a diverged step) must not
        // poison the estimates permanently
        let n = 5;
        let mut s = ImportanceSelector::new(n, Rng::new(6));
        let mut clean = ImportanceSelector::new(n, Rng::new(6));
        for t in 0..2_000 {
            let i = s.next();
            let j = clean.next();
            assert_eq!(i, j, "streams diverged at step {t}");
            let df = 0.1 + i as f64;
            s.report(i, df);
            s.report(i, f64::NAN);
            s.report(i, f64::INFINITY);
            s.report(i, f64::NEG_INFINITY);
            clean.report(j, df);
        }
        assert_eq!(s.probabilities(), clean.probabilities());
        assert!(s.est.iter().all(|e| e.is_finite()));
        assert!(s.seen_sum.is_finite());
    }

    #[test]
    fn unseen_coordinates_inherit_the_running_mean() {
        let mut s = ImportanceSelector::new(4, Rng::new(4));
        // only coordinate 0 reported so far
        s.report(0, 2.0);
        let p = s.probabilities();
        // all raw estimates equal (2.0 seen, mean 2.0 unseen) ⇒ uniform
        assert!(p.iter().all(|x| (x - 0.25).abs() < 1e-9), "{p:?}");
    }
}
