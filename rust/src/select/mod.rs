//! Pluggable **coordinate-selection subsystem**: one trait, five
//! policies, one benchmark contract.
//!
//! The paper's contribution (ACF) is one member of a *family* of online
//! coordinate-selection rules; this module makes the family a
//! first-class subsystem so every solver, the sharded engine's inner
//! loops, the CLI and the benches compare rules through one interface:
//!
//! | selector | module | rule | after |
//! |----------|--------|------|-------|
//! | [`AcfSelector`] | [`acf`] | preference adaptation from Δf/r̄ (Algorithms 2+3) | the source paper |
//! | [`UniformSelector`] | [`uniform`] | i.i.d. uniform | classic randomized CD |
//! | [`CyclicSelector`] | [`cyclic`] | permuted-cyclic sweeps | Friedman et al. / liblinear epochs |
//! | [`Exp3BanditSelector`] | [`bandit`] | EXP3 adversarial bandit, reward = normalized Δf | Salehi et al., *Coordinate Descent with Bandit Sampling* (arXiv:1712.03010) |
//! | [`ImportanceSelector`] | [`importance`] | probabilities ∝ fading per-coordinate progress estimates with a uniform floor | Perekrestenko et al., *Faster Coordinate Descent via Adaptive Importance Sampling* (arXiv:1703.02518) |
//!
//! # When to pick which selector
//!
//! * **`acf`** — the default. Cheap O(1) updates, clipped preference
//!   range (stable under non-stationary progress), the paper's speedups
//!   on all four problem families. Start here.
//! * **`cyclic`** — the strongest *non-adaptive* baseline: permuted
//!   sweeps guarantee every coordinate is visited once per epoch.
//!   Right when coordinate importance is near-uniform or unknown and
//!   reproducible epoch semantics matter.
//! * **`uniform`** — the analysis-friendly baseline (i.i.d. selection
//!   matches most randomized-CD theory); expect a log-factor more
//!   epochs than `cyclic` to touch every coordinate.
//! * **`bandit`** — adversarial-regret machinery; heavier-tailed
//!   exploration than ACF (its γ-floor never fades). Useful when
//!   progress per coordinate shifts abruptly between regimes and ACF's
//!   fading average adapts too slowly.
//! * **`importance`** — greedy-leaning: concentrates on coordinates
//!   with the largest *recent* progress estimates. Strong early on
//!   problems with few dominant coordinates (small-λ LASSO), weaker
//!   near the optimum where its estimates go stale together.
//!
//! All five are deterministic given their construction seed, so solver
//! runs stay reproducible (`BENCH_policy_faceoff.json` — the
//! `policy_faceoff` bench — records epochs- and wall-time-to-target per
//! selector per task).
//!
//! The previous trait home, [`crate::sched`], re-exports [`Selector`]
//! under its old name `Scheduler` and keeps the epoch-sweep baseline
//! types; new code should depend on this module.

pub mod acf;
pub mod bandit;
pub mod cyclic;
pub mod importance;
pub mod uniform;

pub use acf::AcfSelector;
pub use bandit::Exp3BanditSelector;
pub use cyclic::CyclicSelector;
pub use importance::ImportanceSelector;
pub use uniform::UniformSelector;

use crate::acf::AcfParams;
use crate::util::rng::Rng;

/// A coordinate-selection policy. `n` is fixed at construction; `next`
/// yields the coordinate for iteration t; `report` feeds back the
/// observed single-step progress Δf (ignored by non-adaptive policies).
///
/// `Send` is a supertrait so boxed selectors can live inside the
/// sharded engine's per-shard state and the sweep worker pool.
pub trait Selector: Send {
    /// Select the next active coordinate.
    fn next(&mut self) -> usize;

    /// Report observed progress of the last step on coordinate `i`.
    /// Solvers may pass tiny negative fp noise; adaptive selectors must
    /// clamp it themselves. Non-finite Δf (NaN/±inf from a diverged
    /// step) must be **ignored** — a single poisoned report must never
    /// corrupt future selection probabilities (regression-tested per
    /// policy).
    fn report(&mut self, _i: usize, _delta_f: f64) {}

    /// Number of coordinates.
    fn n(&self) -> usize;

    /// Human-readable policy name for reports.
    fn name(&self) -> &'static str;

    /// Metrics hook: write the current selection probabilities into
    /// `out` without allocating (uniform for non-adaptive policies).
    /// `out` is cleared first; its capacity is reused across calls.
    fn probabilities_into(&self, out: &mut Vec<f64>) {
        let n = self.n();
        out.clear();
        out.resize(n, 1.0 / n as f64);
    }

    /// Allocating convenience wrapper around
    /// [`probabilities_into`](Selector::probabilities_into).
    fn probabilities(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n());
        self.probabilities_into(&mut out);
        out
    }

    /// Snapshot hook for diagnostics/reporting: name, size and the
    /// current selection distribution in one value.
    fn snapshot(&self) -> SelectorSnapshot {
        SelectorSnapshot { name: self.name(), n: self.n(), probabilities: self.probabilities() }
    }
}

/// Point-in-time view of a selector's adaptive state (see
/// [`Selector::snapshot`]).
#[derive(Clone, Debug)]
pub struct SelectorSnapshot {
    pub name: &'static str,
    pub n: usize,
    pub probabilities: Vec<f64>,
}

/// Valid selector names, kept in sync with [`SelectorKind::parse`]
/// (shown in CLI error messages and help).
pub const SELECTOR_NAMES: &str =
    "acf, uniform|uniform-iid, cyclic|permuted-cyclic, bandit|exp3, importance|ais";

/// Named selector used by the CLI / coordinator / benches — the
/// `select/` analog of [`crate::sched::Policy`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectorKind {
    Acf,
    Uniform,
    Cyclic,
    Bandit,
    Importance,
}

impl SelectorKind {
    /// Every kind, in the order the face-off bench reports them.
    pub fn all() -> [SelectorKind; 5] {
        [
            SelectorKind::Acf,
            SelectorKind::Uniform,
            SelectorKind::Cyclic,
            SelectorKind::Bandit,
            SelectorKind::Importance,
        ]
    }

    /// Case-insensitive name lookup. On failure the error lists every
    /// valid selector name, so a typo like `bandit→bandti` is
    /// self-explaining (same contract as [`crate::sched::Policy::parse`]).
    pub fn parse(s: &str) -> Result<SelectorKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "acf" => Ok(SelectorKind::Acf),
            "uniform" | "uniform-iid" => Ok(SelectorKind::Uniform),
            "cyclic" | "permuted-cyclic" => Ok(SelectorKind::Cyclic),
            "bandit" | "exp3" => Ok(SelectorKind::Bandit),
            "importance" | "ais" => Ok(SelectorKind::Importance),
            other => Err(format!("unknown selector '{other}' (valid: {SELECTOR_NAMES})")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SelectorKind::Acf => "acf",
            SelectorKind::Uniform => "uniform",
            SelectorKind::Cyclic => "cyclic",
            SelectorKind::Bandit => "bandit",
            SelectorKind::Importance => "importance",
        }
    }

    /// Construct the selector. `params` only affects [`AcfSelector`];
    /// the ACF build hands `rng` to [`crate::acf::AcfScheduler`]
    /// untouched, which keeps it bit-identical to the pre-subsystem
    /// hard-wired path.
    pub fn build(self, n: usize, params: AcfParams, rng: Rng) -> Box<dyn Selector> {
        match self {
            SelectorKind::Acf => Box::new(AcfSelector::new(n, params, rng)),
            SelectorKind::Uniform => Box::new(UniformSelector::new(n, rng)),
            SelectorKind::Cyclic => Box::new(CyclicSelector::new(n, rng)),
            SelectorKind::Bandit => Box::new(Exp3BanditSelector::new(n, rng)),
            SelectorKind::Importance => Box::new(ImportanceSelector::new(n, rng)),
        }
    }
}

/// Algorithm 3 generalized beyond ACF preferences: an amortized-O(1)
/// index stream that respects *any* (slowly varying) probability vector
/// exactly over time. The accumulator/emit/shuffle core is
/// [`crate::acf::SequenceGenerator::next_block_weighted`] — the same
/// code path the ACF scheduler runs — driven here from a plain
/// normalized probability slice. The adaptive selectors
/// ([`Exp3BanditSelector`], [`ImportanceSelector`]) share this
/// machinery instead of paying an O(n) categorical sample per step.
///
/// The same waiting-time bound as the ACF generator applies: any
/// coordinate with probability ≥ p appears at least once every
/// `⌈1/(n·p)⌉` blocks — selectors keep a probability floor precisely so
/// this "essentially cyclic" property (and with it the CD convergence
/// guarantees) holds.
#[derive(Clone, Debug)]
pub struct BlockSampler {
    gen: crate::acf::SequenceGenerator,
    probs: Vec<f64>,
    block: Vec<u32>,
    cursor: usize,
}

impl BlockSampler {
    pub fn new(n: usize) -> BlockSampler {
        assert!(n > 0);
        BlockSampler {
            gen: crate::acf::SequenceGenerator::new(n),
            probs: vec![1.0 / n as f64; n],
            block: Vec::with_capacity(2 * n),
            cursor: 0,
        }
    }

    /// Probability of index `i` in the block currently being consumed
    /// (the distribution the last [`next`](BlockSampler::next) draw was
    /// made from — what importance-weighted updates need).
    #[inline]
    pub fn probability(&self, i: usize) -> f64 {
        self.probs[i]
    }

    /// The distribution of the block currently being consumed.
    pub fn probabilities(&self) -> &[f64] {
        &self.probs
    }

    /// Next index. `refresh` refills the internal normalized probability
    /// buffer whenever a new block must be generated — amortized once
    /// per ~n draws, so per-step selection stays O(1).
    pub fn next(&mut self, rng: &mut Rng, mut refresh: impl FnMut(&mut Vec<f64>)) -> usize {
        while self.cursor >= self.block.len() {
            refresh(&mut self.probs);
            debug_assert_eq!(self.probs.len(), self.gen.len());
            debug_assert!(
                (self.probs.iter().sum::<f64>() - 1.0).abs() < 1e-6,
                "refresh must produce a normalized distribution"
            );
            self.cursor = 0;
            let n = self.probs.len() as f64;
            let probs = &self.probs;
            self.gen.next_block_weighted(|i| probs[i] * n, rng, &mut self.block);
            // A normalized vector adds exactly n accumulator mass per
            // block while each accumulator retains < 1, so every block
            // emits ≥ 1 index; the loop (not recursion) tolerates fp
            // shortfall on the first block.
        }
        let i = self.block[self.cursor];
        self.cursor += 1;
        i as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acf::AcfScheduler;
    use crate::util::prop;

    /// Drive any selector for `steps`, feeding back a deterministic
    /// synthetic Δf trace, and record the index stream.
    fn record(sel: &mut dyn Selector, steps: usize) -> Vec<usize> {
        (0..steps)
            .map(|t| {
                let i = sel.next();
                // synthetic "recorded trace": coordinate 0 makes 10×
                // the progress of the rest, fading over time
                let base = if i == 0 { 10.0 } else { 1.0 };
                sel.report(i, base / (1.0 + t as f64 / 50.0));
                i
            })
            .collect()
    }

    #[test]
    fn every_selector_is_deterministic_given_seed() {
        for kind in SelectorKind::all() {
            let run = |seed: u64| {
                let mut s = kind.build(16, AcfParams::default(), Rng::new(seed));
                record(s.as_mut(), 400)
            };
            assert_eq!(run(7), run(7), "{}: same seed must replay", kind.name());
            assert_ne!(run(7), run(8), "{}: different seeds must diverge", kind.name());
        }
    }

    #[test]
    fn every_selector_covers_all_coordinates() {
        // The probability floors (γ/n for EXP3, ε/n for importance)
        // guarantee the essentially-cyclic property; check it
        // empirically under a heavily skewed reward stream.
        for kind in SelectorKind::all() {
            let n = 12;
            let mut s = kind.build(n, AcfParams::default(), Rng::new(3));
            let mut seen = vec![false; n];
            for t in 0..n * 400 {
                let i = s.next();
                seen[i] = true;
                s.report(i, if i == 0 { 5.0 } else { 0.01 * (t % 3) as f64 });
            }
            assert!(seen.iter().all(|&b| b), "{}: {seen:?}", kind.name());
        }
    }

    #[test]
    fn every_selector_ignores_non_finite_progress() {
        // The trait contract: NaN/inf Δf reports must not alter any
        // policy's state, so a poisoned run replays the clean run's
        // index stream and distribution exactly.
        for kind in SelectorKind::all() {
            let mut poisoned = kind.build(10, AcfParams::default(), Rng::new(13));
            let mut clean = kind.build(10, AcfParams::default(), Rng::new(13));
            for t in 0..1_500 {
                let a = poisoned.next();
                let b = clean.next();
                assert_eq!(a, b, "{}: streams diverged at step {t}", kind.name());
                let df = if a == 0 { 4.0 } else { 0.2 };
                poisoned.report(a, df);
                poisoned.report(a, f64::NAN);
                poisoned.report(a, f64::INFINITY);
                poisoned.report(a, f64::NEG_INFINITY);
                clean.report(b, df);
            }
            assert_eq!(
                poisoned.probabilities(),
                clean.probabilities(),
                "{}: distribution corrupted by non-finite reports",
                kind.name()
            );
            assert!(
                poisoned.probabilities().iter().all(|p| p.is_finite() && *p > 0.0),
                "{}",
                kind.name()
            );
        }
    }

    #[test]
    fn acf_selector_bit_identical_to_raw_scheduler_on_recorded_trace() {
        // The adapter contract: AcfSelector must replay the pre-refactor
        // AcfScheduler path exactly — same indices, same probabilities —
        // when driven with the same seed and Δf trace.
        let n = 24;
        let params = AcfParams::default();
        let mut raw = AcfScheduler::new(n, params, Rng::new(41));
        let mut sel = AcfSelector::new(n, params, Rng::new(41));
        for t in 0..5_000 {
            let a = raw.next();
            let b = sel.next();
            assert_eq!(a, b, "index stream diverged at step {t}");
            let df = ((t * t) % 17) as f64 / 4.0;
            raw.report(a, df);
            sel.report(b, df);
        }
        let mut probs = Vec::new();
        sel.probabilities_into(&mut probs);
        assert_eq!(raw.preferences().probabilities(), probs);
    }

    #[test]
    fn selector_kind_parse_and_build() {
        for kind in SelectorKind::all() {
            assert_eq!(SelectorKind::parse(kind.name()), Ok(kind));
            let s = kind.build(6, AcfParams::default(), Rng::new(1));
            assert_eq!(s.n(), 6);
            assert_eq!(s.name(), kind.name());
        }
        assert_eq!(SelectorKind::parse("EXP3"), Ok(SelectorKind::Bandit));
        assert_eq!(SelectorKind::parse("AIS"), Ok(SelectorKind::Importance));
        assert_eq!(SelectorKind::parse("Uniform-IID"), Ok(SelectorKind::Uniform));
    }

    #[test]
    fn selector_kind_parse_error_lists_valid_names() {
        let e = SelectorKind::parse("bogus").unwrap_err();
        for name in ["acf", "uniform", "cyclic", "bandit", "importance"] {
            assert!(e.contains(name), "error message misses '{name}': {e}");
        }
    }

    #[test]
    fn snapshot_reports_name_and_distribution() {
        let s = SelectorKind::Uniform.build(4, AcfParams::default(), Rng::new(2));
        let snap = s.snapshot();
        assert_eq!(snap.name, "uniform");
        assert_eq!(snap.n, 4);
        assert_eq!(snap.probabilities, vec![0.25; 4]);
    }

    #[test]
    fn probabilities_into_reuses_buffer_and_matches_allocating_path() {
        let mut s = SelectorKind::Acf.build(8, AcfParams::default(), Rng::new(5));
        for _ in 0..2_000 {
            let i = s.next();
            s.report(i, if i < 2 { 3.0 } else { 0.1 });
        }
        let mut buf = vec![0.0; 64]; // stale, oversized: must be cleared
        s.probabilities_into(&mut buf);
        assert_eq!(buf, s.probabilities());
        assert_eq!(buf.len(), 8);
        assert!((buf.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn block_sampler_respects_distribution_exactly() {
        let probs = vec![0.5, 0.25, 0.125, 0.125];
        let mut bs = BlockSampler::new(4);
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 4];
        let draws = 4_000;
        for _ in 0..draws {
            counts[bs.next(&mut rng, |out| {
                out.clear();
                out.extend_from_slice(&probs);
            })] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let got = c as f64 / draws as f64;
            // deterministic accumulators: error ≤ 1 index per block
            assert!((got - probs[i]).abs() < 0.01, "coord {i}: {got} vs {}", probs[i]);
        }
    }

    #[test]
    fn block_sampler_waiting_time_bound_under_skew() {
        prop::check(25, |g| {
            let n = g.usize_in(2, 24);
            let floor = 0.02;
            let hot = g.usize_in(0, n - 1);
            // skewed-but-floored distribution, as the adaptive
            // selectors produce
            let mut probs = vec![floor; n];
            probs[hot] = 1.0 - floor * (n - 1) as f64;
            let tau = (1.0 / (n as f64 * floor)).ceil() as usize;
            let mut bs = BlockSampler::new(n);
            let mut rng = Rng::new(g.seed);
            let mut last = vec![0usize; n];
            for step in 1..=(3 * tau + 2) * n {
                let i = bs.next(&mut rng, |out| {
                    out.clear();
                    out.extend_from_slice(&probs);
                });
                last[i] = step;
            }
            // waiting time ≤ tau+1 blocks; in steps that is at most
            // tau+2 block *spans* (≤ 2n each): occurrence positions
            // inside a block and the partially-consumed block at the
            // horizon each add up to one block of slack
            let horizon = (3 * tau + 2) * n;
            for (i, &s) in last.iter().enumerate() {
                prop::assert_holds(
                    horizon - s <= (tau + 2) * 2 * n,
                    &format!("coord {i} starved ({} of {horizon} steps)", horizon - s),
                )?;
            }
            Ok(())
        });
    }
}
