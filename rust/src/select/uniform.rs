//! I.i.d. uniform selection — the baseline most randomized-CD analysis
//! assumes (each step picks any coordinate with probability 1/n,
//! independently). Non-adaptive: `report` is a no-op.

use super::Selector;
use crate::util::rng::Rng;

/// Uniform i.i.d. coordinate selection.
#[derive(Clone, Debug)]
pub struct UniformSelector {
    n: usize,
    rng: Rng,
}

impl UniformSelector {
    pub fn new(n: usize, rng: Rng) -> UniformSelector {
        assert!(n > 0);
        UniformSelector { n, rng }
    }
}

impl Selector for UniformSelector {
    #[inline]
    fn next(&mut self) -> usize {
        self.rng.below(self.n)
    }

    fn n(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_everything_eventually() {
        let n = 20;
        let mut s = UniformSelector::new(n, Rng::new(5));
        let mut seen = vec![false; n];
        for _ in 0..2_000 {
            seen[s.next()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn roughly_uniform_counts() {
        let n = 8;
        let mut s = UniformSelector::new(n, Rng::new(6));
        let mut counts = vec![0usize; n];
        for _ in 0..40_000 {
            counts[s.next()] += 1;
        }
        let expect = 40_000.0 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 6.0 * expect.sqrt(), "{counts:?}");
        }
    }
}
