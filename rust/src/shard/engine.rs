//! The sharded coordinate-descent engine.
//!
//! [`ShardedDriver`] partitions the coordinate set into S shards, runs an
//! independent inner [`crate::select::Selector`] inside each shard
//! (ACF by default — [`ShardSpec::inner_selector`] swaps in any policy
//! from the `select/` subsystem without touching the merge machinery),
//! and layers an *outer* ACF instance (paper Algorithms 2+3, applied one
//! level up) over the shards themselves. The outer level stays ACF
//! regardless of the inner selector: shard visit frequencies are the
//! engine's own control loop, not a benchmarked policy. Two merge
//! protocols are available, selected by [`ShardSpec::merge`]:
//!
//! # Synchronized mode ([`MergeMode::Sync`], the default)
//!
//! 1. **Quota** — the outer sequence generator (Algorithm 3 over shard
//!    preferences) emits a block of shard visits; each visit grants the
//!    shard one local sweep (`n_s` CD steps). Hot shards therefore get
//!    proportionally more steps per epoch, exactly as hot coordinates get
//!    more visits in the flat algorithm.
//! 2. **Local epochs** — every shard copies the shared solver state
//!    (LASSO residual / SVM primal vector), then runs its quota of exact
//!    CD steps on its own coordinates against that private copy, driven
//!    by its inner ACF scheduler. Shards run on the persistent
//!    [`RoundPool`] workers (spawned once per run, parked between
//!    epochs); nothing is shared mutably, so the epoch is embarrassingly
//!    parallel.
//! 3. **Merge** — shared-state deltas are summed in fixed shard order.
//!    The additive merge (θ = 1) is tried first and kept whenever the
//!    objective does not increase; otherwise the engine falls back to the
//!    averaged merge θ = 1/S, which is *guaranteed* not to increase the
//!    objective: each shard's endpoint is an exact-CD iterate from the
//!    epoch-start point, the shared state is linear in the coordinate
//!    values, and f is convex, so f(mean of endpoints) ≤ mean of
//!    f(endpoints) ≤ f(start). The per-epoch objective sequence is thus
//!    monotone by construction.
//! 4. **Adapt** — each shard's aggregate progress Δf per step is reported
//!    to the outer preference vector (Algorithm 2 over shards), closing
//!    the hierarchical-ACF loop.
//!
//! Determinism: shard partitions are stateless, every RNG stream is
//! derived from `(seed, shard index)`, quotas come from the deterministic
//! outer accumulators, and merges run in fixed shard order — so results
//! are bit-identical given `(seed, shard count)` regardless of thread
//! scheduling or worker count.
//!
//! # Asynchronous mode ([`MergeMode::Async`])
//!
//! The per-epoch barrier is removed: fast shards never wait for slow
//! ones (Wright's asynchronous-CD regime, arXiv:1502.04759). The shared
//! state lives in *versioned published buffers*: workers snapshot the
//! currently published buffer (an O(1) `Arc` clone), run their local
//! epoch against the snapshot, and submit the resulting shared-state
//! delta to the merger (the driving thread). The merger evaluates the
//! candidate objective *exactly* against its authoritative copy and
//! publishes a fresh buffer via a version bump — an atomic pointer flip
//! under a mutex held only for the O(1) swap. Retired buffers are
//! recycled once the last reader drops its snapshot, so steady state
//! ping-pongs between a small fixed set of buffers (the classic double
//! buffer, generalized because a snapshot may be held across a whole
//! local epoch).
//!
//! Merge acceptance is three-tiered, and the *published objective is
//! monotone non-increasing by construction* because every candidate is
//! evaluated exactly before the flip:
//!
//! 1. additive (θ = 1) if the objective does not increase;
//! 2. otherwise averaged (θ = 1/S) — the convexity guarantee of the
//!    synchronized merge degrades under staleness, so this tier is also
//!    checked rather than trusted;
//! 3. otherwise the submission is **rejected**: nothing is published and
//!    the worker rolls back to its pre-epoch values before re-reading a
//!    fresh snapshot.
//!
//! **Batched merging** — before evaluating anything, the merger drains
//! every submission already sitting in its queue and folds the fresh
//! ones into a *single* additive candidate, paying **one**
//! `shared_objective` evaluation for the whole batch (sound for the same
//! linearity reason as staleness-tolerance, below). Only if the folded
//! candidate would increase the objective does it fall back to the
//! per-submission three-tier protocol. On many-shard runs, where the
//! merger is the contended resource, this cuts objective evaluations
//! per accepted submission below 1.
//!
//! A submission whose base version lags the published version by more
//! than the **staleness bound τ** (the `staleness_bound` field of
//! [`MergeMode::Async`]) is discarded outright, and — per the
//! bounded-staleness contract for
//! the outer ACF — its Δf report is *not* fed to the outer preference
//! update (Algorithm 2 stays driven by sufficiently fresh progress
//! only). With `adaptive: true` (CLI `--staleness-bound auto`) τ is
//! tuned online from the observed stale-drop/reject rates: objective
//! rejections shrink it (tolerated staleness is letting conflicting
//! work through), stale-drop waves and fully clean windows grow it
//! (capped at 2·S) — the opposing pulls keep the controller from
//! pinning τ at the floor and starving slow shards. State consistency
//! survives staleness exactly: the shared state
//! is linear in the coordinate values and each coordinate is owned by
//! exactly one shard, so applying shard k's delta `L(trial_k − values_k)`
//! to a *newer* published state still yields the shared state of the
//! merged coordinate values (up to fp rounding).
//!
//! Asynchronous runs are **not bit-deterministic** — merge order depends
//! on thread scheduling. Use the synchronized mode (the default) when
//! reproducibility matters; use async for wall-clock speed.
//!
//! # Failure containment
//!
//! A panic inside a worker (e.g. a `ShardProblem::step` bug) no longer
//! surfaces as an opaque poisoned-mutex panic: workers catch the unwind
//! and the engine returns [`crate::util::error::ErrorKind::ShardWorker`]
//! naming the failing shard.

use crate::acf::{AcfParams, Preferences, SequenceGenerator};
use crate::metrics::{OpCounter, Trace, TracePoint};
use crate::obs::live::{LiveMetrics, LiveRecorder};
use crate::obs::{self, Emitter, Event, MergeTier, Obs};
use crate::select::{Selector, SelectorKind};
use crate::shard::partition::{Partition, Partitioner};
use crate::solvers::{SolveResult, SolveStatus, SolverConfig};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use crate::util::sync;
use crate::util::threadpool::{panic_message, Pop, RoundPool, WorkQueue};
use crate::util::timer::Timer;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::time::Duration;

/// Default staleness bound τ for the asynchronous merge: a Δf report (and
/// its delta) may lag the published version by at most this many flips.
pub const DEFAULT_STALENESS_BOUND: u64 = 2;

/// Merge protocol of the sharded engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergeMode {
    /// Epoch-synchronized barrier merge — bit-deterministic given
    /// `(seed, shards)`, independent of the worker count.
    Sync,
    /// Asynchronous bounded-staleness merge — fast shards never wait;
    /// not bit-deterministic (see the module docs).
    Async {
        /// staleness bound τ: submissions (and their Δf reports to the
        /// outer ACF) older than τ published versions are discarded
        staleness_bound: u64,
        /// tune τ online (`--staleness-bound auto`): objective
        /// rejections shrink τ (stale work conflicting), stale-drop
        /// waves and clean windows grow it (bound choking throughput /
        /// room to relax). The `staleness_bound` field is then the
        /// *initial* τ.
        adaptive: bool,
    },
}

/// Merge-layer accounting of one sharded run. The async merger fills all
/// fields; the sync path reports its exact-objective evaluations only.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MergeStats {
    /// exact `shared_objective` evaluations performed by the merger —
    /// the denominator of the batching win (per-submission merging pays
    /// ≥ 1 per accepted submission; batched merging amortizes one
    /// evaluation over every submission folded into the candidate)
    pub objective_evals: u64,
    /// submissions folded into accepted publishes (additive or damped)
    pub accepted_submissions: u64,
    /// submissions rejected by the exact objective check
    pub rejected_submissions: u64,
    /// accepted publishes that folded ≥ 2 submissions into one additive
    /// candidate (one objective evaluation for the whole batch)
    pub batched_merges: u64,
    /// staleness bound τ when the run finished (moves under
    /// `--staleness-bound auto`, equals the configured τ otherwise;
    /// 0 in sync mode, which has no staleness)
    pub staleness_bound_final: u64,
}

/// Submissions observed between τ adaptation decisions.
const TAU_ADAPT_WINDOW: u64 = 16;

/// Fraction threshold for τ moves (numerator/denominator of the
/// comparison `count * TAU_FRAC_DEN > seen * TAU_FRAC_NUM`, i.e. 25 %).
const TAU_FRAC_NUM: u64 = 1;
const TAU_FRAC_DEN: u64 = 4;

/// How one merged submission ended, as seen by the τ controller.
#[derive(Clone, Copy, Debug)]
enum TauSignal {
    Accepted,
    /// rejected by the exact objective check: tolerated staleness let
    /// conflicting work through — τ is too loose
    Rejected,
    /// discarded for exceeding τ: the bound is discarding throughput —
    /// τ is too tight
    Stale,
}

/// Online staleness-bound tuning (ROADMAP "adaptive staleness bound"),
/// from the observed stale-drop/reject rates over fixed-size windows.
/// The two failure signals pull in *opposite* directions, which keeps
/// the controller self-stabilizing: a window with > 25 % objective
/// rejections shrinks τ (merging stale work degrades quality); otherwise
/// a window with > 25 % stale drops grows τ (the bound is wasting worker
/// epochs — shrinking on drops would feed back into more drops and pin
/// τ at the floor, starving slow shards); a perfectly clean window also
/// grows τ; anything else holds. Fixed bounds ignore observations.
struct TauController {
    tau: u64,
    adaptive: bool,
    min: u64,
    max: u64,
    seen: u64,
    rejected: u64,
    stale: u64,
}

impl TauController {
    fn new(initial: u64, adaptive: bool, s_count: usize) -> TauController {
        TauController {
            tau: initial,
            adaptive,
            min: initial.min(1),
            // more staleness than two full rounds of shards can never
            // help; also never clamp an explicitly larger initial τ
            max: (2 * s_count as u64).max(4).max(initial),
            seen: 0,
            rejected: 0,
            stale: 0,
        }
    }

    #[inline]
    fn current(&self) -> u64 {
        self.tau
    }

    /// Record one merge outcome. Returns `Some((previous, new))` when a
    /// window boundary moved τ — the merger turns that into an
    /// observability event ([`Event::Tau`]).
    fn observe(&mut self, signal: TauSignal) -> Option<(u64, u64)> {
        if !self.adaptive {
            return None;
        }
        self.seen += 1;
        match signal {
            TauSignal::Accepted => {}
            TauSignal::Rejected => self.rejected += 1,
            TauSignal::Stale => self.stale += 1,
        }
        let mut moved = None;
        if self.seen >= TAU_ADAPT_WINDOW {
            let prev = self.tau;
            let frac = |count: u64| count * TAU_FRAC_DEN > self.seen * TAU_FRAC_NUM;
            if frac(self.rejected) {
                self.tau = self.tau.saturating_sub(1).max(self.min);
            } else if (frac(self.stale) || self.rejected + self.stale == 0) && self.tau < self.max
            {
                self.tau += 1;
            }
            if self.tau != prev {
                moved = Some((prev, self.tau));
            }
            self.seen = 0;
            self.rejected = 0;
            self.stale = 0;
        }
        moved
    }
}

/// Configuration of a sharded run.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    /// number of shards S (clamped to the coordinate count)
    pub shards: usize,
    /// how coordinates are assigned to shards
    pub partitioner: Partitioner,
    /// master seed; all shard/outer streams derive from it
    pub seed: u64,
    /// ACF parameters of the per-shard inner schedulers (only consulted
    /// when `inner_selector` is [`SelectorKind::Acf`])
    pub inner_params: AcfParams,
    /// ACF parameters of the outer (shard-level) adaptation
    pub outer_params: AcfParams,
    /// coordinate-selection policy of the per-shard inner loops
    /// (default ACF — bit-identical to the pre-subsystem engine; the
    /// outer shard-level ACF is unaffected by this choice)
    pub inner_selector: SelectorKind,
    /// worker threads (0 = one per shard, bounded by hardware
    /// parallelism)
    pub workers: usize,
    /// merge protocol (synchronized by default, for determinism)
    pub merge: MergeMode,
    /// stopping criteria; `trace_every > 0` records one trace point per
    /// epoch (sync) or per published version (async)
    pub config: SolverConfig,
    /// observability collector ([`crate::obs`]); `None` (the default)
    /// keeps the engine bit-identical to an uninstrumented build. When
    /// set, the collector must have at least `shards + 1` rings (ring
    /// *k* for shard *k*, the last ring for the merge driver).
    /// Recording never mutates solver state, so results are identical
    /// at every trace level — only wall-clock differs.
    pub obs: Option<Arc<Obs>>,
    /// live telemetry registry ([`crate::obs::live`]); `None` (the
    /// default) constructs no recorder at all. When set, the driving
    /// thread (sync epoch loop or async merger) publishes a running
    /// [`crate::obs::MetricsSnapshot`] after every epoch/publish for the
    /// HTTP telemetry server to scrape. Publishing only reads solver
    /// state, so the non-perturbation contract of `obs` extends to the
    /// live plane.
    pub live: Option<Arc<LiveMetrics>>,
}

impl ShardSpec {
    pub fn new(shards: usize) -> ShardSpec {
        ShardSpec {
            shards,
            partitioner: Partitioner::Contiguous,
            seed: 20140103,
            inner_params: AcfParams::default(),
            outer_params: AcfParams::default(),
            inner_selector: SelectorKind::Acf,
            workers: 0,
            merge: MergeMode::Sync,
            config: SolverConfig::default(),
            obs: None,
            live: None,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> ShardSpec {
        self.seed = seed;
        self
    }

    pub fn with_config(mut self, config: SolverConfig) -> ShardSpec {
        self.config = config;
        self
    }

    /// Select the asynchronous merge with the given fixed staleness
    /// bound τ.
    pub fn with_async(mut self, staleness_bound: u64) -> ShardSpec {
        self.merge = MergeMode::Async { staleness_bound, adaptive: false };
        self
    }

    /// Select the asynchronous merge with τ tuned online from the
    /// observed stale-drop/reject rate (`--staleness-bound auto`),
    /// starting from [`DEFAULT_STALENESS_BOUND`].
    pub fn with_async_auto(mut self) -> ShardSpec {
        self.merge = MergeMode::Async { staleness_bound: DEFAULT_STALENESS_BOUND, adaptive: true };
        self
    }

    /// Pin the per-shard inner coordinate-selection policy.
    pub fn with_inner_selector(mut self, kind: SelectorKind) -> ShardSpec {
        self.inner_selector = kind;
        self
    }

    /// Attach an observability collector (see [`ShardSpec::obs`]).
    pub fn with_obs(mut self, obs: Arc<Obs>) -> ShardSpec {
        self.obs = Some(obs);
        self
    }

    /// Attach a live telemetry registry (see [`ShardSpec::live`]).
    pub fn with_live(mut self, live: Arc<LiveMetrics>) -> ShardSpec {
        self.live = Some(live);
        self
    }
}

/// Outcome of one CD step performed through [`ShardProblem::step`].
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// exact objective decrease of the step (≥ 0 up to fp noise)
    pub delta_f: f64,
    /// KKT violation of the coordinate *before* the step
    pub violation: f64,
    /// multiply-add operations spent
    pub ops: usize,
}

/// A problem family pluggable into the sharded engine.
///
/// The contract mirrors the serial solvers: one *coordinate block* of
/// [`coord_width`](ShardProblem::coord_width) values per coordinate
/// (width 1 — a plain scalar — for w_j in LASSO and α_i in the binary
/// duals; width K for the per-class dual block α_{i,·} of the
/// multi-class SVM) plus one dense *shared state* vector that is linear
/// in the values (residual r = Xw−y, primal w = Σ α_i y_i x_i, or the K
/// per-class primal vectors flattened into one K·d buffer that the
/// engine snapshots/publishes as a single versioned unit). `step` must
/// perform the exact block-CD update and keep `shared` consistent; the
/// engine owns snapshotting, merging and scheduling.
pub trait ShardProblem: Sync {
    /// Number of coordinates n.
    fn n_coords(&self) -> usize;

    /// Values per coordinate (1 for scalar problems; K for the
    /// multi-class per-class dual block). Must be ≥ 1 and constant for
    /// the lifetime of the problem.
    fn coord_width(&self) -> usize {
        1
    }

    /// Dimension of the shared state vector. Multi-buffer shared state
    /// (e.g. K per-class weight vectors) is flattened here so all
    /// buffers merge and publish atomically as one versioned unit.
    fn shared_dim(&self) -> usize;

    /// Shared state at the all-values-initial point.
    fn initial_shared(&self) -> Vec<f64>;

    /// Initial values of coordinate `i` (`values.len() == coord_width`;
    /// all-zero by default — LASSO / SVM dual; dual logreg starts
    /// interior).
    fn init_coord(&self, _i: usize, values: &mut [f64]) {
        values.fill(0.0);
    }

    /// Exact CD step on coordinate `i`: update its value block and
    /// `shared` in place, report progress / violation / cost.
    fn step(&self, i: usize, values: &mut [f64], shared: &mut [f64]) -> StepOutcome;

    /// KKT violation of coordinate `i` at the given state, with its
    /// operation cost (used by the synchronized verification pass).
    fn violation(&self, i: usize, values: &[f64], shared: &[f64]) -> (f64, usize);

    /// Best-effort prefetch of coordinate `i`'s backing data — typically
    /// the matrix row the next `step`/`violation` call will gather
    /// ([`crate::sparse::kernels::prefetch_row`]). The verification
    /// scans visit coordinates in a known order, so the engine overlaps
    /// coordinate `i`'s memory latency with the previous coordinate's
    /// reduction (software pipelining). Must be a pure hint: no
    /// observable state may change. Default: no-op.
    fn prefetch_coord(&self, _i: usize) {}

    /// Non-separable objective part, a function of the shared state only
    /// (½‖r‖²/ℓ for LASSO, ½‖w‖² / ½Σ_k‖w_k‖² for the duals).
    fn shared_objective(&self, shared: &[f64]) -> f64;

    /// Separable objective contribution of one coordinate block
    /// (λ|w_j|, −α_i, entropy terms, −Σ_k α_{ik}).
    fn coord_objective(&self, i: usize, values: &[f64]) -> f64;

    /// Byte / page footprint of the matrix rows this shard's coordinate
    /// ids touch — the `data_extent` locality probe emitted once per run
    /// at `spans` level. `None` (the default) for problems without a
    /// natural coordinate-to-row mapping.
    fn shard_extent(&self, _ids: &[u32]) -> Option<(u64, u64)> {
        None
    }
}

/// Result of a sharded run: final coordinate values (global indexing;
/// flattened `n_coords × coord_width` for block problems), final shared
/// state, solver metrics, and the outer ACF's final shard-selection
/// probabilities (diagnostics).
#[derive(Clone, Debug)]
pub struct ShardedOutcome {
    pub values: Vec<f64>,
    pub shared: Vec<f64>,
    pub result: SolveResult,
    pub outer_probabilities: Vec<f64>,
    /// async mode: submissions discarded for exceeding the staleness
    /// bound τ (always 0 in sync mode). The observed drop rate is the
    /// input the adaptive τ controller consumes.
    pub stale_drops: u64,
    /// merge-layer accounting (objective evaluations, batched folds,
    /// final τ) — see [`MergeStats`]
    pub merge_stats: MergeStats,
}

/// Per-shard mutable state. Behind a `Mutex` so pool workers can claim
/// disjoint shards through a shared slice; there is never lock contention
/// (each shard is touched by exactly one worker at a time — per epoch in
/// sync mode, per ready-queue pop in async mode).
struct ShardState {
    ids: Vec<u32>,
    /// accepted coordinate values, flattened `ids.len() × coord_width`
    /// (coordinate `ids[kk]` owns `values[kk·w..(kk+1)·w]`)
    values: Vec<f64>,
    /// scratch: values after the local epoch, before merge acceptance
    trial: Vec<f64>,
    /// scratch: private copy of the shared state
    local_shared: Vec<f64>,
    /// inner coordinate selector over this shard's local indices
    /// ([`ShardSpec::inner_selector`]; ACF by default)
    sched: Box<dyn Selector>,
}

/// What a shard reports back from one synchronized local epoch.
struct EpochReport {
    delta_f: f64,
    window_viol: f64,
    steps: u64,
    counter: OpCounter,
    /// wall-clock nanoseconds of the local epoch (0 unless the run is
    /// traced at spans level or live telemetry is attached)
    nanos: u64,
}

/// Task selector for the synchronized round workers (one fixed closure
/// serves both the epoch and the verification rounds).
enum SyncTask {
    Epoch,
    Verify,
}

/// Epoch-varying inputs of the synchronized round workers. Workers take
/// read locks during a round; the driving thread rewrites the contents
/// between rounds (never concurrently).
struct SyncCtx {
    shared: Vec<f64>,
    quotas: Vec<u64>,
    task: SyncTask,
}

/// Round output slot content (sync mode).
enum SyncReport {
    Epoch(EpochReport),
    Verify { viol: f64, ops: usize },
}

/// How a worker must fold its last submission into its accepted values.
#[derive(Clone, Copy, Debug)]
enum Apply {
    /// nothing pending (fresh shard, or after a verify)
    None,
    /// additive merge accepted: `values ← trial`
    Accept,
    /// averaged merge accepted: `values ← values + θ (trial − values)`
    Damp,
    /// merge rejected (objective increase or staleness): keep `values`
    Reject,
}

/// What a shard should do after applying its pending merge decision.
#[derive(Clone, Copy, Debug)]
enum Work {
    /// run one local epoch of `quota` CD steps against a fresh snapshot
    Epoch { quota: u64 },
    /// run a full KKT pass against the (final) published state
    Verify,
    /// report quiescence and stop until re-dispatched
    Park,
}

/// Merge decision + next assignment for one shard (async mode); written
/// by the merger, consumed by the next worker that picks the shard up
/// from the ready queue.
struct Directive {
    apply: Apply,
    work: Work,
    /// recycled delta buffer, handed back to the worker
    delta_back: Option<Vec<f64>>,
}

/// One shard's asynchronous local-epoch submission.
struct Submission {
    shard: usize,
    /// published version the epoch's snapshot was taken from
    base_version: u64,
    /// shared-state delta: `local_shared − snapshot`
    delta: Vec<f64>,
    /// separable objective of this shard at θ = 1 (trial values)
    sep_trial: f64,
    /// separable objective of this shard at θ = 1/S (damped values)
    sep_damped: f64,
    /// the shard's own summed per-step Δf claims over the local epoch
    /// (possibly stale-based); used to apportion a batched fold's
    /// achieved decrease across its members for the outer ACF
    claimed: f64,
    window_viol: f64,
    counter: OpCounter,
    /// wall-clock nanoseconds of the local epoch (0 unless traced at
    /// spans level or live telemetry is attached)
    nanos: u64,
}

/// Worker → merger messages (async mode).
enum AsyncMsg {
    Epoch(Submission),
    Verified { shard: usize, viol: f64, ops: usize },
    Parked(usize),
    Failed { shard: usize, message: String },
}

/// Why the async engine is draining towards a verification pass.
#[derive(Clone, Copy, Debug)]
enum Drain {
    Converge,
    Budget,
    Time,
}

/// The versioned publish slot of the async engine: `(version, buffer)`.
/// The mutex is held only for the O(1) pointer clone / swap.
struct PublishSlot {
    slot: Mutex<(u64, Arc<Vec<f64>>)>,
}

impl PublishSlot {
    fn new(initial: Vec<f64>) -> PublishSlot {
        PublishSlot { slot: Mutex::new((0, Arc::new(initial))) }
    }

    fn snapshot(&self) -> (u64, Arc<Vec<f64>>) {
        let g = sync::lock(&self.slot);
        (g.0, g.1.clone())
    }

    /// Publish `buf` as `version`; returns the retired buffer.
    fn publish(&self, version: u64, buf: Arc<Vec<f64>>) -> Arc<Vec<f64>> {
        let mut g = sync::lock(&self.slot);
        g.0 = version;
        std::mem::replace(&mut g.1, buf)
    }
}

/// Quota allocator of the async engine: converts outer-ACF shard visits
/// into per-shard step quotas on demand, respecting the global iteration
/// budget (issued, not merely completed, steps are counted so in-flight
/// epochs can never overshoot).
struct QuotaSource {
    gen: SequenceGenerator,
    rng: Rng,
    block: Vec<u32>,
    pending: Vec<u64>,
    issued: u64,
    max_iterations: u64,
}

impl QuotaSource {
    /// Next quota for shard `k`; 0 means the iteration budget is spent.
    fn next(&mut self, prefs: &Preferences, partition: &Partition, k: usize) -> u64 {
        let remaining = self.max_iterations.saturating_sub(self.issued);
        if remaining == 0 {
            return 0;
        }
        while self.pending[k] == 0 {
            // The outer generator is essentially cyclic (every shard's
            // accumulator grows each block), so this terminates.
            self.gen.next_block(prefs, &mut self.rng, &mut self.block);
            for &s in &self.block {
                self.pending[s as usize] += 1;
            }
        }
        let quota = (self.pending[k] * partition.shard(k).len() as u64).min(remaining);
        self.pending[k] = 0;
        self.issued += quota;
        quota
    }
}

/// Epochs (sync) or merge batches (async, scaled by S) to wait after a
/// failed full verification before re-verifying (the stale-window
/// heuristic can stay optimistic for a few epochs).
const VERIFY_COOLDOWN: u64 = 3;

/// Issue shard `k` its merge decision plus next assignment and put it
/// back on the ready queue: an epoch quota from the outer ACF, or Park
/// once the iteration budget is spent / a drain is in progress (the
/// budget case enters the budget drain). The single dispatch point of
/// the async engine — kick-off, steady state and verify-resume all go
/// through here so their drain behavior cannot diverge.
#[allow(clippy::too_many_arguments)]
fn dispatch_shard(
    k: usize,
    apply: Apply,
    delta_back: Option<Vec<f64>>,
    partition: &Partition,
    outer_prefs: &Preferences,
    quotas: &mut QuotaSource,
    draining: &mut Option<Drain>,
    directives: &[Mutex<Directive>],
    ready: &WorkQueue<usize>,
    em: &Emitter<'_>,
) {
    let quota = if draining.is_some() { 0 } else { quotas.next(outer_prefs, partition, k) };
    let work = if quota == 0 {
        draining.get_or_insert(Drain::Budget);
        if em.spans() {
            em.emit(Event::Park { t: em.now(), shard: k as u32 });
        }
        Work::Park
    } else {
        Work::Epoch { quota }
    };
    {
        let mut d = sync::lock(&directives[k]);
        d.apply = apply;
        d.work = work;
        // None callers (kick-off, resume) must not evict a buffer left
        // resident by a Verify/Park round trip
        if delta_back.is_some() {
            d.delta_back = delta_back;
        }
    }
    ready.push(k);
}

/// One trace sample from the driving thread's authoritative metrics
/// (shared by the sync epoch loop and both async accept paths).
fn trace_point(trace: &mut Trace, counter: &OpCounter, timer: &Timer, objective: f64, violation: f64) {
    trace.push(TracePoint {
        iteration: counter.iterations(),
        ops: counter.ops(),
        seconds: timer.secs(),
        objective,
        violation,
    });
}

/// One selector-entropy probe on the caller's ring: the inner policy's
/// current selection distribution reduced to (entropy, p_min, p_max).
/// Callers gate on [`Emitter::events`] before paying for the
/// probability read-out.
fn emit_selector_probe(em: &Emitter<'_>, shard: u32, sched: &dyn Selector) {
    let mut probs = Vec::new();
    sched.probabilities_into(&mut probs);
    let (entropy, p_min, p_max) = obs::entropy_stats(&probs);
    em.emit(Event::SelectorState { t: em.now(), shard, entropy, p_min, p_max });
}

/// Outcome of merging one submission.
enum MergeOutcome {
    /// discarded for exceeding the staleness bound: no publish, and no
    /// Δf report to the outer ACF
    Stale,
    /// rejected by the exact objective check: no publish; the outer ACF
    /// is told the shard burned its steps (Δf report 0)
    Rejected,
    /// accepted (additively or damped) and published; report `rate`
    /// (achieved decrease per step) to the outer ACF
    Accepted { apply: Apply, rate: f64 },
}

/// The async merger's authoritative state plus the merge tiers. Pulled
/// out of `async_loop` so the per-submission path and the batched fold
/// share one implementation of candidate evaluation, publishing and
/// bookkeeping.
struct Merger<'e, P: ShardProblem> {
    problem: &'e P,
    published: &'e PublishSlot,
    theta: f64,
    dim: usize,
    /// retired-buffer pool cap (shards + slack)
    max_retired: usize,
    /// authoritative shared state (exactly-evaluated objective)
    cur: Vec<f64>,
    scratch: Vec<f64>,
    version: u64,
    /// published versions (reported as epochs)
    merges: u64,
    retired: Vec<Arc<Vec<f64>>>,
    /// per-shard separable objective at the accepted values
    sep: Vec<f64>,
    sep_total: f64,
    f_cur: f64,
    stats: MergeStats,
    tau: TauController,
    stale_drops: u64,
    /// merger-thread emitter on the collector's driver ring
    em: Emitter<'e>,
    /// live telemetry recorder (merger thread only; `None` without
    /// `--metrics-addr`)
    live: Option<LiveRecorder>,
}

impl<'e, P: ShardProblem> Merger<'e, P> {
    #[inline]
    fn tol(&self) -> f64 {
        1e-12 * self.f_cur.abs().max(1.0)
    }

    /// Feed the τ controller and surface any resulting bound move as a
    /// `tau` span on the driver ring.
    fn tau_observe(&mut self, signal: TauSignal) {
        if let Some((prev, tau)) = self.tau.observe(signal) {
            if self.em.spans() {
                self.em.emit(Event::Tau { t: self.em.now(), tau, prev });
            }
            if let Some(lr) = self.live.as_mut() {
                lr.tau(tau);
            }
        }
    }

    /// One `merge` span for a (batch of) submission(s) that shared a fate.
    fn emit_merge(&mut self, shard: u32, tier: MergeTier, staleness: u64, batch: u64) {
        if self.em.spans() {
            self.em.emit(Event::Merge { t: self.em.now(), shard, tier, staleness, batch });
        }
        if let Some(lr) = self.live.as_mut() {
            lr.merge_outcome(tier, staleness, batch);
        }
    }

    /// Version flip: publish `self.cur` under the next version number.
    fn publish_current(&mut self) {
        self.version += 1;
        self.merges += 1;
        let mut buf = take_spare(&mut self.retired).unwrap_or_else(|| Vec::with_capacity(self.dim));
        buf.clear();
        buf.extend_from_slice(&self.cur);
        let old = self.published.publish(self.version, Arc::new(buf));
        self.retired.push(old);
        if self.retired.len() > self.max_retired {
            self.retired.remove(0);
        }
        if self.em.spans() {
            self.em.emit(Event::Publish {
                t: self.em.now(),
                version: self.version,
                objective: self.f_cur,
            });
            self.em.emit(Event::Objective {
                t: self.em.now(),
                shard: obs::NO_SHARD,
                epoch: self.merges,
                objective: self.f_cur,
            });
        }
        if let Some(lr) = self.live.as_mut() {
            lr.objective(self.f_cur);
            lr.set_merge_stats(self.stats);
            lr.flush();
        }
    }

    /// Bounded-staleness gate; a positive answer counts the drop and
    /// feeds the adaptive τ controller.
    fn is_stale(&mut self, sub: &Submission) -> bool {
        let staleness = self.version.saturating_sub(sub.base_version);
        if staleness > self.tau.current() {
            self.stale_drops += 1;
            self.tau_observe(TauSignal::Stale);
            self.emit_merge(sub.shard as u32, MergeTier::Stale, staleness, 1);
            true
        } else {
            false
        }
    }

    /// Per-submission three-tier merge: additive → averaged → rejected,
    /// each candidate evaluated exactly. Re-checks staleness because
    /// earlier accepts from the same drained batch advance the version.
    fn merge_one(&mut self, sub: &Submission) -> MergeOutcome {
        if self.is_stale(sub) {
            return MergeOutcome::Stale;
        }
        let p = self.problem;
        let k = sub.shard;
        let steps = sub.counter.iterations().max(1);
        let staleness = self.version.saturating_sub(sub.base_version);
        let tol = self.tol();
        // tier 1: additive candidate, evaluated exactly (one fused pass
        // — the merger is the serial bottleneck)
        crate::sparse::kernels::scaled_sum_into(&mut self.scratch, &self.cur, 1.0, &sub.delta);
        self.stats.objective_evals += 1;
        let f_add = p.shared_objective(&self.scratch) + (self.sep_total - self.sep[k] + sub.sep_trial);
        if f_add <= self.f_cur + tol {
            std::mem::swap(&mut self.cur, &mut self.scratch);
            self.sep_total += sub.sep_trial - self.sep[k];
            self.sep[k] = sub.sep_trial;
            let achieved = self.f_cur - f_add;
            self.f_cur = f_add;
            self.stats.accepted_submissions += 1;
            self.tau_observe(TauSignal::Accepted);
            self.emit_merge(k as u32, MergeTier::Additive, staleness, 1);
            self.publish_current();
            return MergeOutcome::Accepted { apply: Apply::Accept, rate: (achieved / steps as f64).max(0.0) };
        }
        // tier 2: averaged candidate θ = 1/S — convexity no longer binds
        // under staleness, so this tier is checked rather than trusted
        crate::sparse::kernels::scaled_sum_into(&mut self.scratch, &self.cur, self.theta, &sub.delta);
        self.stats.objective_evals += 1;
        let f_damp = p.shared_objective(&self.scratch) + (self.sep_total - self.sep[k] + sub.sep_damped);
        if f_damp <= self.f_cur + tol {
            std::mem::swap(&mut self.cur, &mut self.scratch);
            self.sep_total += sub.sep_damped - self.sep[k];
            self.sep[k] = sub.sep_damped;
            let achieved = self.f_cur - f_damp;
            self.f_cur = f_damp;
            self.stats.accepted_submissions += 1;
            self.tau_observe(TauSignal::Accepted);
            self.emit_merge(k as u32, MergeTier::Damped, staleness, 1);
            self.publish_current();
            return MergeOutcome::Accepted { apply: Apply::Damp, rate: (achieved / steps as f64).max(0.0) };
        }
        // tier 3: reject — the shard burned its steps
        self.stats.rejected_submissions += 1;
        self.tau_observe(TauSignal::Rejected);
        self.emit_merge(k as u32, MergeTier::Rejected, staleness, 1);
        MergeOutcome::Rejected
    }

    /// Batched additive fold (ROADMAP "batched async merging"): sum every
    /// fresh delta into **one** candidate and evaluate `shared_objective`
    /// **once** for the whole batch. Sound because each coordinate is
    /// owned by exactly one shard and the shared state is linear in the
    /// coordinate values, so summed deltas equal the sequential
    /// application of every shard's update (up to fp rounding). On
    /// acceptance returns one outer-ACF progress rate per batch member
    /// (the achieved decrease apportioned by each shard's claimed Δf, so
    /// per-shard attribution survives batching); `None` sends the caller
    /// to per-submission fallback.
    fn merge_batch(&mut self, batch: &[Submission]) -> Option<Vec<f64>> {
        debug_assert!(batch.len() >= 2);
        let p = self.problem;
        // scratch = cur + Σ deltas: the first delta rides the fused
        // copy pass, the rest accumulate with the unrolled axpy
        crate::sparse::kernels::scaled_sum_into(&mut self.scratch, &self.cur, 1.0, &batch[0].delta);
        for sub in &batch[1..] {
            crate::sparse::ops::axpy(1.0, &sub.delta, &mut self.scratch);
        }
        let mut sep_delta = 0.0f64;
        let mut claimed_total = 0.0f64;
        for sub in batch {
            // each shard has at most one outstanding submission, so the
            // sep replacement below never sees the same shard twice
            sep_delta += sub.sep_trial - self.sep[sub.shard];
            claimed_total += sub.claimed;
        }
        self.stats.objective_evals += 1;
        let f_add = p.shared_objective(&self.scratch) + self.sep_total + sep_delta;
        if f_add > self.f_cur + self.tol() {
            return None;
        }
        std::mem::swap(&mut self.cur, &mut self.scratch);
        self.sep_total += sep_delta;
        let achieved = self.f_cur - f_add;
        self.f_cur = f_add;
        let rates = batch
            .iter()
            .map(|sub| {
                let steps = sub.counter.iterations().max(1);
                let share = if claimed_total > 0.0 {
                    sub.claimed / claimed_total
                } else {
                    1.0 / batch.len() as f64
                };
                (achieved * share / steps as f64).max(0.0)
            })
            .collect();
        let mut max_staleness = 0u64;
        for sub in batch {
            self.sep[sub.shard] = sub.sep_trial;
            self.stats.accepted_submissions += 1;
            self.tau_observe(TauSignal::Accepted);
            max_staleness = max_staleness.max(self.version.saturating_sub(sub.base_version));
        }
        self.stats.batched_merges += 1;
        self.emit_merge(obs::NO_SHARD, MergeTier::Additive, max_staleness, batch.len() as u64);
        self.publish_current();
        Some(rates)
    }
}

/// Shutdown-on-drop guards so no exit path can leave pool workers parked
/// forever (which would deadlock the enclosing `thread::scope`).
struct PoolGuard<'a>(&'a RoundPool);

impl Drop for PoolGuard<'_> {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

struct QueueGuard<'a, T>(&'a WorkQueue<T>);

impl<T> Drop for QueueGuard<'_, T> {
    fn drop(&mut self) {
        self.0.shutdown();
    }
}

/// Lock a shard's state, mapping mutex poisoning (a worker panicked
/// while holding it) to the first-party shard-worker error.
fn lock_state<'m>(states: &'m [Mutex<ShardState>], k: usize) -> Result<MutexGuard<'m, ShardState>> {
    states[k]
        .lock()
        .map_err(|_| Error::shard_worker(k, "state mutex poisoned by an earlier worker panic"))
}

/// The sharded parallel CD driver.
pub struct ShardedDriver<'a, P: ShardProblem> {
    problem: &'a P,
    partition: Partition,
    spec: ShardSpec,
}

impl<'a, P: ShardProblem> ShardedDriver<'a, P> {
    pub fn new(problem: &'a P, spec: ShardSpec) -> Self {
        let partition = Partition::new(problem.n_coords(), spec.shards.max(1), spec.partitioner);
        Self { problem, partition, spec }
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Run to convergence (or budget); see the module docs for the two
    /// merge protocols. Returns
    /// [`crate::util::error::ErrorKind::ShardWorker`] if a shard's
    /// worker panics.
    pub fn run(&self) -> Result<ShardedOutcome> {
        self.emit_data_extents();
        match self.spec.merge {
            MergeMode::Sync => self.run_sync(),
            MergeMode::Async { staleness_bound, adaptive } => {
                self.run_async(staleness_bound, adaptive)
            }
        }
    }

    /// One `data_extent` record per shard (driver ring, `spans` level):
    /// the matrix bytes and distinct pages the shard's rows span. A
    /// locality profile of the partition, and under the mapped backend
    /// an upper bound on the pages each shard faults in.
    fn emit_data_extents(&self) {
        let em = obs::emitter(self.spec.obs.as_deref(), self.partition.n_shards());
        if !em.spans() {
            return;
        }
        for k in 0..self.partition.n_shards() {
            if let Some((bytes, pages)) = self.problem.shard_extent(self.partition.shard(k)) {
                em.emit(Event::DataExtent { t: em.now(), shard: k as u32, bytes, pages });
            }
        }
    }

    fn worker_count(&self, s_count: usize) -> usize {
        if self.spec.workers == 0 {
            // one thread per shard, but never oversubscribe the machine
            s_count.min(crate::util::threadpool::default_workers())
        } else {
            self.spec.workers.max(1).min(s_count)
        }
    }

    /// Values per coordinate block (1 for scalar problems).
    #[inline]
    fn width(&self) -> usize {
        self.problem.coord_width().max(1)
    }

    fn init_states(&self, dim: usize) -> Vec<Mutex<ShardState>> {
        let p = self.problem;
        let w = self.width();
        (0..self.partition.n_shards())
            .map(|k| {
                let ids = self.partition.shard(k).to_vec();
                let mut values = vec![0.0f64; ids.len() * w];
                for (kk, &i) in ids.iter().enumerate() {
                    p.init_coord(i as usize, &mut values[kk * w..(kk + 1) * w]);
                }
                // the RNG derivation is unchanged from the hard-wired
                // AcfScheduler era, so the default (ACF) inner selector
                // keeps sync runs bit-identical across the refactor
                let sched = self.spec.inner_selector.build(
                    ids.len(),
                    self.spec.inner_params,
                    Rng::new(self.spec.seed ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                );
                Mutex::new(ShardState {
                    trial: values.clone(),
                    values,
                    local_shared: vec![0.0; dim],
                    ids,
                    sched,
                })
            })
            .collect()
    }

    /// Separable objective of every shard at its current accepted values.
    fn initial_sep(&self, states: &[Mutex<ShardState>]) -> Result<Vec<f64>> {
        let p = self.problem;
        let w = self.width();
        (0..states.len())
            .map(|k| {
                let st = lock_state(states, k)?;
                Ok(st
                    .ids
                    .iter()
                    .zip(st.values.chunks_exact(w))
                    .map(|(&i, vs)| p.coord_objective(i as usize, vs))
                    .sum())
            })
            .collect()
    }

    /// Gather per-coordinate value blocks into global indexing
    /// (flattened `n_coords × coord_width`).
    fn collect_values(&self, states: &[Mutex<ShardState>]) -> Result<Vec<f64>> {
        let w = self.width();
        let mut values = vec![0.0f64; self.problem.n_coords() * w];
        for k in 0..states.len() {
            let st = lock_state(states, k)?;
            for (kk, &i) in st.ids.iter().enumerate() {
                let i = i as usize;
                values[i * w..(i + 1) * w].copy_from_slice(&st.values[kk * w..(kk + 1) * w]);
            }
        }
        Ok(values)
    }

    // ------------------------------------------------------------------
    // synchronized path
    // ------------------------------------------------------------------

    fn run_sync(&self) -> Result<ShardedOutcome> {
        let p = self.problem;
        let s_count = self.partition.n_shards();
        let dim = p.shared_dim();
        let w = self.width();
        let workers = self.worker_count(s_count);

        let states = self.init_states(dim);
        let ctx = RwLock::new(SyncCtx {
            shared: p.initial_shared(),
            quotas: vec![0; s_count],
            task: SyncTask::Epoch,
        });
        let reports: Vec<Mutex<Option<SyncReport>>> =
            (0..s_count).map(|_| Mutex::new(None)).collect();
        let pool = RoundPool::new();

        // The one fixed task closure served to the persistent workers;
        // `ctx.task` selects between epoch and verification rounds.
        let obs_ref = self.spec.obs.as_deref();
        let live_on = self.spec.live.is_some();
        let task = |k: usize| {
            // A read-guard panic does not poison an RwLock, so a crashed
            // sibling worker cannot wedge this lock.
            let ctx = sync::read(&ctx);
            let Ok(mut guard) = states[k].lock() else {
                return; // already-poisoned shard: its panic is the root error
            };
            let st = &mut *guard;
            // Holding the shard mutex makes this worker the ring's sole
            // producer for the round (the EventRing contract).
            let em = obs::emitter(obs_ref, k);
            let report = match ctx.task {
                SyncTask::Epoch => {
                    st.local_shared.copy_from_slice(&ctx.shared);
                    st.trial.copy_from_slice(&st.values);
                    let mut local = OpCounter::new();
                    let mut df_sum = 0.0f64;
                    let mut viol_max = 0.0f64;
                    // Timing reads a clock only — solver state is
                    // untouched, so results stay bit-identical. The
                    // collector clock is 0 when tracing is off, so a
                    // live-only run falls back to a local Instant.
                    let t_start = if em.spans() { em.now() } else { 0 };
                    let t_wall =
                        if live_on && !em.spans() { Some(std::time::Instant::now()) } else { None };
                    for _ in 0..ctx.quotas[k] {
                        let kk = st.sched.next();
                        let i = st.ids[kk] as usize;
                        let out =
                            p.step(i, &mut st.trial[kk * w..(kk + 1) * w], &mut st.local_shared);
                        st.sched.report(kk, out.delta_f.max(0.0));
                        df_sum += out.delta_f;
                        viol_max = viol_max.max(out.violation);
                        local.step(out.ops);
                    }
                    let nanos = if em.spans() {
                        em.now().saturating_sub(t_start)
                    } else {
                        t_wall.map_or(0, |t| t.elapsed().as_nanos() as u64)
                    };
                    if em.spans() {
                        em.emit(Event::Epoch {
                            t: em.now(),
                            shard: k as u32,
                            steps: ctx.quotas[k],
                            ops: local.ops(),
                            nanos,
                        });
                    }
                    if em.events() {
                        emit_selector_probe(&em, k as u32, st.sched.as_ref());
                    }
                    SyncReport::Epoch(EpochReport {
                        delta_f: df_sum,
                        window_viol: viol_max,
                        steps: ctx.quotas[k],
                        counter: local,
                        nanos,
                    })
                }
                SyncTask::Verify => {
                    let mut vmax = 0.0f64;
                    let mut ops = 0usize;
                    for (kk, &i) in st.ids.iter().enumerate() {
                        // software pipelining: issue the next coordinate's
                        // row loads while this violation reduces
                        if let Some(&nx) = st.ids.get(kk + 1) {
                            p.prefetch_coord(nx as usize);
                        }
                        let (v, o) =
                            p.violation(i as usize, &st.values[kk * w..(kk + 1) * w], &ctx.shared);
                        vmax = vmax.max(v);
                        ops += o;
                    }
                    SyncReport::Verify { viol: vmax, ops }
                }
            };
            *sync::lock(&reports[k]) = Some(report);
        };

        std::thread::scope(|scope| {
            let _shutdown = PoolGuard(&pool);
            for _ in 0..workers {
                scope.spawn(|| pool.worker_loop(&task));
            }
            self.sync_loop(&states, &ctx, &reports, &pool)
        })
    }

    /// Dispatch one round and collect every shard's report.
    fn sync_round(
        &self,
        pool: &RoundPool,
        reports: &[Mutex<Option<SyncReport>>],
    ) -> Result<Vec<SyncReport>> {
        pool.run_round(reports.len())
            .map_err(|p| Error::shard_worker(p.task, format!("panicked: {}", p.message)))?;
        reports
            .iter()
            .enumerate()
            .map(|(k, slot)| {
                slot.lock()
                    .map_err(|_| Error::shard_worker(k, "report slot poisoned"))?
                    .take()
                    .ok_or_else(|| Error::shard_worker(k, "produced no epoch report"))
            })
            .collect()
    }

    /// Full KKT pass over the merged state, parallel over shards on the
    /// persistent pool. Returns (max violation, ops spent).
    fn sync_verify(
        &self,
        ctx: &RwLock<SyncCtx>,
        pool: &RoundPool,
        reports: &[Mutex<Option<SyncReport>>],
    ) -> Result<(f64, usize)> {
        sync::write(&ctx).task = SyncTask::Verify;
        let outcome = self.sync_round(pool, reports);
        sync::write(&ctx).task = SyncTask::Epoch;
        outcome?.into_iter().try_fold((0.0f64, 0usize), |(vm, os), r| match r {
            SyncReport::Verify { viol, ops } => Ok((vm.max(viol), os + ops)),
            SyncReport::Epoch(_) => Err(Error::msg("verify round produced an epoch report")),
        })
    }

    fn sync_loop(
        &self,
        states: &[Mutex<ShardState>],
        ctx: &RwLock<SyncCtx>,
        reports: &[Mutex<Option<SyncReport>>],
        pool: &RoundPool,
    ) -> Result<ShardedOutcome> {
        let p = self.problem;
        let s_count = self.partition.n_shards();
        let dim = p.shared_dim();
        let w = self.width();
        let cfg = &self.spec.config;

        // ---- outer (shard-level) ACF ---------------------------------
        let mut outer_prefs = Preferences::new(s_count, self.spec.outer_params);
        let mut outer_gen = SequenceGenerator::new(s_count);
        let mut outer_rng = Rng::new(self.spec.seed ^ 0x07E2_ACF0);
        let mut outer_block: Vec<u32> = Vec::with_capacity(2 * s_count);

        // ---- bookkeeping ---------------------------------------------
        let mut sep = self.initial_sep(states)?;
        let mut f_curr = {
            let ctx = sync::read(&ctx);
            p.shared_objective(&ctx.shared) + sep.iter().sum::<f64>()
        };

        let mut counter = OpCounter::new();
        let timer = Timer::start();
        let mut trace = Trace::new();
        let mut epochs = 0u64;
        let mut status = SolveStatus::IterLimit;
        let mut final_viol = f64::INFINITY;
        let mut last_failed_verify: Option<u64> = None;
        let mut stats = MergeStats::default();
        // Driver ring: the last ring of the collector (index S).
        let em = obs::emitter(self.spec.obs.as_deref(), s_count);
        // Live telemetry: the driving thread owns the recorder and
        // publishes one point per epoch (reads only — no solver state
        // is touched, and no recorder exists without `--metrics-addr`).
        let mut live = self.spec.live.as_ref().map(|l| LiveRecorder::new(Arc::clone(l), s_count));

        let mut sum_diff = vec![0.0f64; dim];
        let mut trial_shared = vec![0.0f64; dim];

        'outer: loop {
            // ---- quotas from the outer ACF level ---------------------
            outer_gen.next_block(&outer_prefs, &mut outer_rng, &mut outer_block);
            let mut quotas = vec![0u64; s_count];
            for &s in &outer_block {
                quotas[s as usize] += self.partition.shard(s as usize).len() as u64;
            }
            let total: u64 = quotas.iter().sum();
            let remaining = cfg.max_iterations.saturating_sub(counter.iterations());
            if remaining == 0 {
                let (v, vops) = self.sync_verify(ctx, pool, reports)?;
                counter.extra(vops);
                final_viol = v;
                status = if v < cfg.eps { SolveStatus::Converged } else { SolveStatus::IterLimit };
                break 'outer;
            }
            if total > remaining {
                for q in quotas.iter_mut() {
                    *q = *q * remaining / total;
                }
                if quotas.iter().sum::<u64>() == 0 {
                    // Give the whole tail budget to the largest shard so
                    // the loop always makes progress.
                    let big =
                        (0..s_count).max_by_key(|&k| self.partition.shard(k).len()).unwrap_or(0);
                    quotas[big] = remaining;
                }
            }
            epochs += 1;

            // ---- parallel local epochs on the persistent pool --------
            sync::write(&ctx).quotas.copy_from_slice(&quotas);
            let round = self.sync_round(pool, reports)?;
            let epoch_reports: Vec<EpochReport> = round
                .into_iter()
                .map(|r| match r {
                    SyncReport::Epoch(e) => Ok(e),
                    SyncReport::Verify { .. } => {
                        Err(Error::msg("epoch round produced a verify report"))
                    }
                })
                .collect::<Result<_>>()?;
            for r in &epoch_reports {
                counter.merge(&r.counter);
            }

            // ---- merge (fixed shard order ⇒ deterministic) -----------
            let mut ctx_g = sync::write(&ctx);
            let shared = &mut ctx_g.shared;
            sum_diff.fill(0.0);
            for k in 0..s_count {
                let st = lock_state(states, k)?;
                for (d, (&l, &g)) in
                    sum_diff.iter_mut().zip(st.local_shared.iter().zip(shared.iter()))
                {
                    *d += l - g;
                }
            }
            for t in 0..dim {
                trial_shared[t] = shared[t] + sum_diff[t];
            }
            let sep_trial: Vec<f64> = (0..s_count)
                .map(|k| {
                    let st = lock_state(states, k)?;
                    Ok(st
                        .ids
                        .iter()
                        .zip(st.trial.chunks_exact(w))
                        .map(|(&i, vs)| p.coord_objective(i as usize, vs))
                        .sum())
                })
                .collect::<Result<_>>()?;
            let f_full = p.shared_objective(&trial_shared) + sep_trial.iter().sum::<f64>();
            stats.objective_evals += 1;
            let tol = 1e-12 * f_curr.abs().max(1.0);
            let merge_tier;
            if f_full <= f_curr + tol {
                // additive merge accepted
                std::mem::swap(shared, &mut trial_shared);
                for k in 0..s_count {
                    let mut st = lock_state(states, k)?;
                    let st = &mut *st;
                    st.values.copy_from_slice(&st.trial);
                    sep[k] = sep_trial[k];
                }
                f_curr = f_full;
                stats.accepted_submissions += s_count as u64;
                stats.batched_merges += 1;
                merge_tier = MergeTier::Additive;
                if em.spans() {
                    em.emit(Event::Merge {
                        t: em.now(),
                        shard: obs::NO_SHARD,
                        tier: MergeTier::Additive,
                        staleness: 0,
                        batch: s_count as u64,
                    });
                }
            } else {
                // averaged merge θ = 1/S: never increases f (convexity)
                let theta = 1.0 / s_count as f64;
                for t in 0..dim {
                    shared[t] += theta * sum_diff[t];
                }
                for k in 0..s_count {
                    let mut st = lock_state(states, k)?;
                    let st = &mut *st;
                    for (v, &t) in st.values.iter_mut().zip(st.trial.iter()) {
                        *v += theta * (t - *v);
                    }
                    sep[k] = st
                        .ids
                        .iter()
                        .zip(st.values.chunks_exact(w))
                        .map(|(&i, vs)| p.coord_objective(i as usize, vs))
                        .sum();
                }
                f_curr = p.shared_objective(shared) + sep.iter().sum::<f64>();
                stats.objective_evals += 1;
                stats.accepted_submissions += s_count as u64;
                merge_tier = MergeTier::Damped;
                if em.spans() {
                    em.emit(Event::Merge {
                        t: em.now(),
                        shard: obs::NO_SHARD,
                        tier: MergeTier::Damped,
                        staleness: 0,
                        batch: s_count as u64,
                    });
                }
            }
            if em.spans() {
                em.emit(Event::Publish { t: em.now(), version: epochs, objective: f_curr });
                em.emit(Event::Objective {
                    t: em.now(),
                    shard: obs::NO_SHARD,
                    epoch: epochs,
                    objective: f_curr,
                });
            }
            drop(ctx_g);

            // ---- live telemetry publish ------------------------------
            if let Some(lr) = live.as_mut() {
                for (k, r) in epoch_reports.iter().enumerate() {
                    lr.epoch(k as u32, r.steps, r.counter.ops(), r.nanos);
                }
                lr.merge_outcome(merge_tier, 0, s_count as u64);
                lr.objective(f_curr);
                lr.engine(pool.round_stats().rounds, 0, 0);
                lr.set_merge_stats(stats);
                lr.flush();
            }

            // ---- hierarchical adaptation: outer Δf report ------------
            for (k, r) in epoch_reports.iter().enumerate() {
                if r.steps > 0 {
                    outer_prefs.update(k, (r.delta_f / r.steps as f64).max(0.0));
                }
            }
            if epochs % 64 == 0 {
                outer_prefs.refresh_sum();
            }

            let window_viol = epoch_reports
                .iter()
                .filter(|r| r.steps > 0)
                .map(|r| r.window_viol)
                .fold(0.0f64, f64::max);
            if cfg.trace_every > 0 {
                trace_point(&mut trace, &counter, &timer, f_curr, window_viol);
            }

            // ---- stopping --------------------------------------------
            let budget_hit = counter.iterations() >= cfg.max_iterations;
            let time_hit = match cfg.max_seconds {
                Some(cap) => timer.secs() > cap,
                None => false,
            };
            let verify_cooled = match last_failed_verify {
                Some(at) => epochs >= at + VERIFY_COOLDOWN,
                None => true,
            };
            let window_converged = window_viol < cfg.eps && verify_cooled;
            if window_converged || budget_hit || time_hit {
                let (v, vops) = self.sync_verify(ctx, pool, reports)?;
                counter.extra(vops);
                final_viol = v;
                if v < cfg.eps {
                    status = SolveStatus::Converged;
                    break 'outer;
                }
                if budget_hit {
                    status = SolveStatus::IterLimit;
                    break 'outer;
                }
                if time_hit {
                    status = SolveStatus::TimeLimit;
                    break 'outer;
                }
                last_failed_verify = Some(epochs);
            }
        }

        let pool_rounds = pool.round_stats().rounds;
        if em.spans() {
            em.emit(Event::EngineStats {
                t: em.now(),
                pool_rounds,
                queue_pushes: 0,
                queue_max_depth: 0,
            });
        }
        if let Some(lr) = live.as_mut() {
            lr.engine(pool_rounds, 0, 0);
            lr.set_merge_stats(stats);
            lr.flush();
        }

        // ---- assemble global views -----------------------------------
        let values = self.collect_values(states)?;
        let shared = std::mem::take(&mut sync::write(&ctx).shared);
        let result = SolveResult {
            status,
            iterations: counter.iterations(),
            ops: counter.ops(),
            seconds: timer.secs(),
            objective: f_curr,
            final_violation: final_viol,
            epochs,
            trace,
        };
        Ok(ShardedOutcome {
            values,
            shared,
            result,
            outer_probabilities: outer_prefs.probabilities(),
            stale_drops: 0,
            merge_stats: stats,
        })
    }

    // ------------------------------------------------------------------
    // asynchronous path
    // ------------------------------------------------------------------

    /// One unit of async worker work for shard `k`: apply the pending
    /// merge decision, then run the assigned work item.
    fn async_shard_task(
        &self,
        k: usize,
        states: &[Mutex<ShardState>],
        directives: &[Mutex<Directive>],
        published: &PublishSlot,
        theta: f64,
    ) -> AsyncMsg {
        let p = self.problem;
        let w = self.width();
        let Ok(mut guard) = states[k].lock() else {
            return AsyncMsg::Failed {
                shard: k,
                message: "state mutex poisoned by an earlier worker panic".to_string(),
            };
        };
        let st = &mut *guard;
        // Holding the shard mutex makes this worker ring `k`'s sole
        // producer until the merger re-dispatches the shard — which it
        // cannot do before this task's message is pushed.
        let em = obs::emitter(self.spec.obs.as_deref(), k);
        let (apply, work, mut delta) = {
            let mut d = sync::lock(&directives[k]);
            // only an epoch consumes the recycled delta buffer; leave it
            // resident across Verify/Park so it survives verify cycles
            let delta = match d.work {
                Work::Epoch { .. } => d.delta_back.take().unwrap_or_default(),
                Work::Verify | Work::Park => Vec::new(),
            };
            (std::mem::replace(&mut d.apply, Apply::None), d.work, delta)
        };
        match apply {
            Apply::Accept => st.values.copy_from_slice(&st.trial),
            Apply::Damp => {
                for kk in 0..st.values.len() {
                    st.values[kk] += theta * (st.trial[kk] - st.values[kk]);
                }
            }
            Apply::None | Apply::Reject => {}
        }
        match work {
            Work::Park => AsyncMsg::Parked(k),
            Work::Verify => {
                let (_, snap) = published.snapshot();
                let mut vmax = 0.0f64;
                let mut ops = 0usize;
                for (kk, &i) in st.ids.iter().enumerate() {
                    // software pipelining: issue the next coordinate's
                    // row loads while this violation reduces
                    if let Some(&nx) = st.ids.get(kk + 1) {
                        p.prefetch_coord(nx as usize);
                    }
                    let (v, o) =
                        p.violation(i as usize, &st.values[kk * w..(kk + 1) * w], &snap);
                    vmax = vmax.max(v);
                    ops += o;
                }
                AsyncMsg::Verified { shard: k, viol: vmax, ops }
            }
            Work::Epoch { quota } => {
                let (base_version, snap) = published.snapshot();
                if em.events() {
                    em.emit(Event::SnapshotTake {
                        t: em.now(),
                        shard: k as u32,
                        version: base_version,
                    });
                }
                st.local_shared.copy_from_slice(&snap);
                st.trial.copy_from_slice(&st.values);
                let mut counter = OpCounter::new();
                let mut viol = 0.0f64;
                let mut claimed = 0.0f64;
                // Local Instant fallback for live-only runs (the
                // collector clock reads 0 when tracing is off).
                let live_on = self.spec.live.is_some();
                let t_start = if em.spans() { em.now() } else { 0 };
                let t_wall =
                    if live_on && !em.spans() { Some(std::time::Instant::now()) } else { None };
                for _ in 0..quota {
                    let kk = st.sched.next();
                    let i = st.ids[kk] as usize;
                    let out =
                        p.step(i, &mut st.trial[kk * w..(kk + 1) * w], &mut st.local_shared);
                    // inner scheduler still adapts on the worker's own
                    // (possibly stale-based) per-step Δf; the *outer*
                    // level is fed the merger's achieved decrease instead
                    st.sched.report(kk, out.delta_f.max(0.0));
                    claimed += out.delta_f.max(0.0);
                    viol = viol.max(out.violation);
                    counter.step(out.ops);
                }
                let nanos = if em.spans() {
                    em.now().saturating_sub(t_start)
                } else {
                    t_wall.map_or(0, |t| t.elapsed().as_nanos() as u64)
                };
                if em.spans() {
                    em.emit(Event::Epoch {
                        t: em.now(),
                        shard: k as u32,
                        steps: quota,
                        ops: counter.ops(),
                        nanos,
                    });
                }
                if em.events() {
                    emit_selector_probe(&em, k as u32, st.sched.as_ref());
                }
                delta.clear();
                delta.extend(st.local_shared.iter().zip(snap.iter()).map(|(l, s)| l - s));
                let mut sep_trial = 0.0f64;
                let mut sep_damped = 0.0f64;
                let mut damped = vec![0.0f64; w];
                for (kk, &i) in st.ids.iter().enumerate() {
                    let vs = &st.values[kk * w..(kk + 1) * w];
                    let ts = &st.trial[kk * w..(kk + 1) * w];
                    sep_trial += p.coord_objective(i as usize, ts);
                    // must match Apply::Damp bit-for-bit (same formula on
                    // the same values), so the merger's f bookkeeping is
                    // exact
                    for ((d, &v), &t) in damped.iter_mut().zip(vs).zip(ts) {
                        *d = v + theta * (t - v);
                    }
                    sep_damped += p.coord_objective(i as usize, &damped);
                }
                AsyncMsg::Epoch(Submission {
                    shard: k,
                    base_version,
                    delta,
                    sep_trial,
                    sep_damped,
                    claimed,
                    window_viol: viol,
                    counter,
                    nanos,
                })
            }
        }
    }

    fn run_async(&self, tau: u64, adaptive: bool) -> Result<ShardedOutcome> {
        let p = self.problem;
        let s_count = self.partition.n_shards();
        let dim = p.shared_dim();
        let workers = self.worker_count(s_count);
        let cfg = &self.spec.config;
        let theta = 1.0 / s_count as f64;

        let states = self.init_states(dim);
        let published = PublishSlot::new(p.initial_shared());
        let ready: WorkQueue<usize> = WorkQueue::new();
        let msgs: WorkQueue<AsyncMsg> = WorkQueue::new();
        let directives: Vec<Mutex<Directive>> = (0..s_count)
            .map(|_| {
                Mutex::new(Directive { apply: Apply::None, work: Work::Park, delta_back: None })
            })
            .collect();

        std::thread::scope(|scope| {
            let _rg = QueueGuard(&ready);
            let _mg = QueueGuard(&msgs);
            let obs_ref = self.spec.obs.as_deref();
            for _ in 0..workers {
                scope.spawn(|| {
                    while let Some(k) = ready.pop() {
                        let msg = match catch_unwind(AssertUnwindSafe(|| {
                            self.async_shard_task(k, &states, &directives, &published, theta)
                        })) {
                            Ok(m) => m,
                            Err(payload) => AsyncMsg::Failed {
                                shard: k,
                                message: format!("panicked: {}", panic_message(payload.as_ref())),
                            },
                        };
                        // Submit is recorded *before* the push: until the
                        // merger sees the message it cannot re-dispatch
                        // shard k, so ring k still has a single producer.
                        if let AsyncMsg::Epoch(ref sub) = msg {
                            let em = obs::emitter(obs_ref, k);
                            if em.events() {
                                em.emit(Event::Submit {
                                    t: em.now(),
                                    shard: sub.shard as u32,
                                    base_version: sub.base_version,
                                    queue_depth: msgs.depth() as u64 + 1,
                                });
                            }
                        }
                        msgs.push(msg);
                    }
                });
            }
            self.async_loop(
                tau, adaptive, theta, cfg, &states, &published, &ready, &msgs, &directives,
            )
        })
    }

    /// The merger: consumes worker submissions, evaluates candidates
    /// exactly, publishes versions, adapts the outer ACF, and drives the
    /// drain → verify → (resume | finish) protocol.
    #[allow(clippy::too_many_arguments)]
    fn async_loop(
        &self,
        tau: u64,
        adaptive: bool,
        theta: f64,
        cfg: &SolverConfig,
        states: &[Mutex<ShardState>],
        published: &PublishSlot,
        ready: &WorkQueue<usize>,
        msgs: &WorkQueue<AsyncMsg>,
        directives: &[Mutex<Directive>],
    ) -> Result<ShardedOutcome> {
        let p = self.problem;
        let s_count = self.partition.n_shards();
        let dim = p.shared_dim();

        // ---- outer ACF + quota allocation ----------------------------
        let mut outer_prefs = Preferences::new(s_count, self.spec.outer_params);
        let mut quotas = QuotaSource {
            gen: SequenceGenerator::new(s_count),
            rng: Rng::new(self.spec.seed ^ 0x07E2_ACF0),
            block: Vec::with_capacity(2 * s_count),
            pending: vec![0; s_count],
            issued: 0,
            max_iterations: cfg.max_iterations,
        };

        // ---- merger state --------------------------------------------
        let sep = self.initial_sep(states)?;
        let sep_total: f64 = sep.iter().sum();
        let cur = p.initial_shared();
        let f_cur = p.shared_objective(&cur) + sep_total;
        // Driver ring: the last ring of the collector (index S); this
        // thread (the merger) is its sole producer.
        let em = obs::emitter(self.spec.obs.as_deref(), s_count);
        let mut mg = Merger {
            problem: p,
            published,
            theta,
            dim,
            max_retired: s_count + 4,
            scratch: vec![0.0f64; dim],
            cur,
            version: 0,
            merges: 0,
            retired: Vec::new(),
            sep,
            sep_total,
            f_cur,
            stats: MergeStats::default(),
            tau: TauController::new(tau, adaptive, s_count),
            stale_drops: 0,
            em,
            live: self.spec.live.as_ref().map(|l| LiveRecorder::new(Arc::clone(l), s_count)),
        };

        let mut counter = OpCounter::new();
        let timer = Timer::start();
        let mut trace = Trace::new();
        let mut last_viol = vec![f64::INFINITY; s_count];
        let mut last_failed_verify: Option<u64> = None;
        let mut next_refresh = 64u64;

        let mut draining: Option<Drain> = None;
        let mut parked = 0usize;
        let mut verified = 0usize;
        let mut verify_viol = 0.0f64;
        // non-epoch messages deferred while draining a merge batch from
        // the queue; processed before the queue is polled again
        let mut pending: std::collections::VecDeque<AsyncMsg> = std::collections::VecDeque::new();

        // ---- kick-off: every shard gets a first epoch ----------------
        for k in 0..s_count {
            dispatch_shard(
                k,
                Apply::None,
                None,
                &self.partition,
                &outer_prefs,
                &mut quotas,
                &mut draining,
                directives,
                ready,
                &em,
            );
        }

        let (status, final_viol) = loop {
            let msg = if let Some(m) = pending.pop_front() {
                m
            } else {
                let live_on = mg.live.is_some();
                let wait_t0 = if em.spans() { em.now() } else { 0 };
                let wait_wall =
                    if live_on && !em.spans() { Some(std::time::Instant::now()) } else { None };
                let popped = msgs.pop_timeout(Duration::from_millis(50));
                let wait_nanos = if em.spans() {
                    em.now().saturating_sub(wait_t0)
                } else {
                    wait_wall.map_or(0, |t| t.elapsed().as_nanos() as u64)
                };
                if em.spans() {
                    em.emit(Event::MergeWait { t: em.now(), nanos: wait_nanos });
                }
                if let Some(lr) = mg.live.as_mut() {
                    lr.merge_wait(wait_nanos);
                }
                match popped {
                    Pop::Item(m) => m,
                    Pop::TimedOut => {
                        let over_time = match cfg.max_seconds {
                            Some(cap) => timer.secs() > cap,
                            None => false,
                        };
                        if over_time && draining.is_none() {
                            draining = Some(Drain::Time);
                        }
                        continue;
                    }
                    Pop::Shutdown => {
                        return Err(Error::msg("async merge queue shut down unexpectedly"))
                    }
                }
            };
            match msg {
                AsyncMsg::Failed { shard, message } => {
                    return Err(Error::shard_worker(shard, message));
                }
                AsyncMsg::Parked(_) => {
                    parked += 1;
                    if parked == s_count {
                        // all shards quiescent and every merge applied:
                        // the published state is final for this round —
                        // dispatch the parallel verification pass
                        parked = 0;
                        verified = 0;
                        verify_viol = 0.0;
                        for k in 0..s_count {
                            let mut d = sync::lock(&directives[k]);
                            d.apply = Apply::None;
                            d.work = Work::Verify;
                            drop(d);
                            ready.push(k);
                        }
                    }
                }
                AsyncMsg::Verified { shard, viol, ops } => {
                    counter.extra(ops);
                    last_viol[shard] = viol;
                    verify_viol = verify_viol.max(viol);
                    verified += 1;
                    if verified == s_count {
                        let reason = draining.take().unwrap_or(Drain::Converge);
                        if verify_viol < cfg.eps {
                            break (SolveStatus::Converged, verify_viol);
                        }
                        match reason {
                            Drain::Budget => break (SolveStatus::IterLimit, verify_viol),
                            Drain::Time => break (SolveStatus::TimeLimit, verify_viol),
                            Drain::Converge => {
                                // stale-window false positive: resume
                                last_failed_verify = Some(mg.merges);
                                for k in 0..s_count {
                                    dispatch_shard(
                                        k,
                                        Apply::None,
                                        None,
                                        &self.partition,
                                        &outer_prefs,
                                        &mut quotas,
                                        &mut draining,
                                        directives,
                                        ready,
                                        &em,
                                    );
                                }
                            }
                        }
                    }
                }
                AsyncMsg::Epoch(first) => {
                    // ---- batched merging: drain every already-queued
                    // submission into one candidate (non-epoch messages
                    // are deferred; per-shard ordering is preserved since
                    // each shard has at most one outstanding message) ---
                    let mut batch = vec![first];
                    while batch.len() < s_count {
                        match msgs.try_pop() {
                            Some(AsyncMsg::Epoch(sub)) => batch.push(sub),
                            Some(other) => pending.push_back(other),
                            None => break,
                        }
                    }
                    for sub in &batch {
                        counter.merge(&sub.counter);
                        last_viol[sub.shard] = sub.window_viol;
                    }
                    if let Some(lr) = mg.live.as_mut() {
                        for sub in &batch {
                            lr.epoch(
                                sub.shard as u32,
                                sub.counter.iterations(),
                                sub.counter.ops(),
                                sub.nanos,
                            );
                        }
                        let qs = msgs.stats();
                        lr.engine(0, qs.pushes, qs.max_depth as u64);
                    }

                    // bounded staleness first: discard the delta AND the
                    // Δf report — the outer ACF only consumes
                    // sufficiently fresh progress
                    let mut decisions: Vec<(usize, Apply, Vec<f64>)> = Vec::with_capacity(batch.len());
                    let mut fresh: Vec<Submission> = Vec::with_capacity(batch.len());
                    for sub in batch {
                        if mg.is_stale(&sub) {
                            decisions.push((sub.shard, Apply::Reject, sub.delta));
                        } else {
                            fresh.push(sub);
                        }
                    }

                    // one additive fold for the whole batch (one exact
                    // objective evaluation); per-submission three-tier
                    // fallback when the fold is rejected
                    let batched_rate = if fresh.len() >= 2 { mg.merge_batch(&fresh) } else { None };
                    if let Some(rates) = batched_rate {
                        if cfg.trace_every > 0 {
                            let viol = fresh.iter().map(|s| s.window_viol).fold(0.0f64, f64::max);
                            trace_point(&mut trace, &counter, &timer, mg.f_cur, viol);
                        }
                        for (sub, rate) in fresh.drain(..).zip(rates) {
                            outer_prefs.update(sub.shard, rate);
                            decisions.push((sub.shard, Apply::Accept, sub.delta));
                        }
                    } else {
                        for sub in fresh.drain(..) {
                            let apply = match mg.merge_one(&sub) {
                                MergeOutcome::Accepted { apply, rate } => {
                                    outer_prefs.update(sub.shard, rate);
                                    if cfg.trace_every > 0 {
                                        trace_point(
                                            &mut trace,
                                            &counter,
                                            &timer,
                                            mg.f_cur,
                                            sub.window_viol,
                                        );
                                    }
                                    apply
                                }
                                MergeOutcome::Rejected => {
                                    // tell the outer ACF the shard burned
                                    // its steps
                                    outer_prefs.update(sub.shard, 0.0);
                                    Apply::Reject
                                }
                                MergeOutcome::Stale => Apply::Reject,
                            };
                            decisions.push((sub.shard, apply, sub.delta));
                        }
                    }
                    while mg.merges >= next_refresh {
                        outer_prefs.refresh_sum();
                        next_refresh += 64;
                    }

                    // ---- convergence / budget / time checks ----------
                    if draining.is_none() {
                        let over_time = match cfg.max_seconds {
                            Some(cap) => timer.secs() > cap,
                            None => false,
                        };
                        if over_time {
                            draining = Some(Drain::Time);
                        } else {
                            let cooled = match last_failed_verify {
                                Some(at) => mg.merges >= at + VERIFY_COOLDOWN * s_count as u64,
                                None => true,
                            };
                            if cooled && last_viol.iter().all(|&v| v < cfg.eps) {
                                draining = Some(Drain::Converge);
                            }
                        }
                    }

                    // ---- respond: merge decisions + next assignments --
                    for (k, apply, delta) in decisions {
                        dispatch_shard(
                            k,
                            apply,
                            Some(delta),
                            &self.partition,
                            &outer_prefs,
                            &mut quotas,
                            &mut draining,
                            directives,
                            ready,
                            &em,
                        );
                    }
                }
            }
        };

        // ---- assemble global views -----------------------------------
        let values = self.collect_values(states)?;
        let result = SolveResult {
            status,
            iterations: counter.iterations(),
            ops: counter.ops(),
            seconds: timer.secs(),
            objective: mg.f_cur,
            final_violation: final_viol,
            epochs: mg.merges,
            trace,
        };
        mg.stats.staleness_bound_final = mg.tau.current();
        let qs = msgs.stats();
        if em.spans() {
            em.emit(Event::EngineStats {
                t: em.now(),
                pool_rounds: 0,
                queue_pushes: qs.pushes,
                queue_max_depth: qs.max_depth as u64,
            });
        }
        if let Some(lr) = mg.live.as_mut() {
            lr.engine(0, qs.pushes, qs.max_depth as u64);
            lr.objective(mg.f_cur);
            lr.set_merge_stats(mg.stats);
            lr.flush();
        }
        Ok(ShardedOutcome {
            values,
            shared: mg.cur,
            result,
            outer_probabilities: outer_prefs.probabilities(),
            stale_drops: mg.stale_drops,
            merge_stats: mg.stats,
        })
    }
}

/// Reclaim a retired publish buffer whose last snapshot holder is gone.
/// Retired arcs are no longer in the publish slot, so their strong count
/// can only decrease — `try_unwrap` after the count check cannot race.
fn take_spare(retired: &mut Vec<Arc<Vec<f64>>>) -> Option<Vec<f64>> {
    for i in 0..retired.len() {
        if Arc::strong_count(&retired[i]) == 1 {
            let arc = retired.swap_remove(i);
            return Arc::try_unwrap(arc).ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::ErrorKind;

    /// Minimal separable quadratic for engine-level tests:
    /// f(x) = Σ ½ (x_i − 1)², with the shared state being x itself (a
    /// linear — identity — function of the coordinate values).
    struct Quad {
        n: usize,
        /// coordinate whose step panics (usize::MAX = never)
        boom: usize,
    }

    impl Quad {
        fn new(n: usize) -> Quad {
            Quad { n, boom: usize::MAX }
        }
    }

    impl ShardProblem for Quad {
        fn n_coords(&self) -> usize {
            self.n
        }

        fn shared_dim(&self) -> usize {
            self.n
        }

        fn initial_shared(&self) -> Vec<f64> {
            vec![0.0; self.n]
        }

        fn step(&self, i: usize, values: &mut [f64], shared: &mut [f64]) -> StepOutcome {
            if i == self.boom {
                panic!("boom on coordinate {i}");
            }
            let old = values[0];
            let delta_f = 0.5 * (old - 1.0) * (old - 1.0);
            values[0] = 1.0;
            shared[i] += 1.0 - old;
            StepOutcome { delta_f, violation: (old - 1.0).abs(), ops: 1 }
        }

        fn violation(&self, i: usize, _values: &[f64], shared: &[f64]) -> (f64, usize) {
            ((shared[i] - 1.0).abs(), 1)
        }

        fn shared_objective(&self, shared: &[f64]) -> f64 {
            shared.iter().map(|&s| 0.5 * (s - 1.0) * (s - 1.0)).sum()
        }

        fn coord_objective(&self, _i: usize, _values: &[f64]) -> f64 {
            0.0
        }
    }

    /// Width-2 block problem: coordinate `i` owns a 2-value block with
    /// targets (1, −2); the shared state is the flattened identity of
    /// the blocks (dim 2n). Exercises the `coord_width` plumbing — the
    /// per-class generalization the multi-class SVM needs — end to end.
    struct BlockQuad {
        n: usize,
    }

    const BLOCK_TARGET: [f64; 2] = [1.0, -2.0];

    impl ShardProblem for BlockQuad {
        fn n_coords(&self) -> usize {
            self.n
        }

        fn coord_width(&self) -> usize {
            2
        }

        fn shared_dim(&self) -> usize {
            2 * self.n
        }

        fn initial_shared(&self) -> Vec<f64> {
            vec![0.0; 2 * self.n]
        }

        fn step(&self, i: usize, values: &mut [f64], shared: &mut [f64]) -> StepOutcome {
            let mut delta_f = 0.0;
            let mut viol = 0.0f64;
            for (k, v) in values.iter_mut().enumerate() {
                let r = BLOCK_TARGET[k] - *v;
                delta_f += 0.5 * r * r;
                viol = viol.max(r.abs());
                shared[2 * i + k] += r;
                *v = BLOCK_TARGET[k];
            }
            StepOutcome { delta_f, violation: viol, ops: 2 }
        }

        fn violation(&self, i: usize, _values: &[f64], shared: &[f64]) -> (f64, usize) {
            let v = (0..2)
                .map(|k| (shared[2 * i + k] - BLOCK_TARGET[k]).abs())
                .fold(0.0f64, f64::max);
            (v, 2)
        }

        fn shared_objective(&self, shared: &[f64]) -> f64 {
            shared
                .chunks_exact(2)
                .map(|c| {
                    0.5 * ((c[0] - BLOCK_TARGET[0]).powi(2) + (c[1] - BLOCK_TARGET[1]).powi(2))
                })
                .sum()
        }

        fn coord_objective(&self, _i: usize, _values: &[f64]) -> f64 {
            0.0
        }
    }

    fn spec(shards: usize) -> ShardSpec {
        ShardSpec::new(shards).with_config(SolverConfig::with_eps(1e-10))
    }

    #[test]
    fn quad_sync_converges_exactly() {
        let p = Quad::new(16);
        let out = ShardedDriver::new(&p, spec(4)).run().unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        assert!(out.values.iter().all(|&v| (v - 1.0).abs() < 1e-12));
        assert_eq!(out.stale_drops, 0, "sync mode never discards for staleness");
    }

    #[test]
    fn quad_sync_converges_with_every_inner_selector() {
        // The merge machinery must be selector-agnostic: any policy
        // from the select/ subsystem drives the inner loops to the same
        // fixed point (the outer shard-level ACF is untouched).
        let p = Quad::new(16);
        for kind in SelectorKind::all() {
            let out = ShardedDriver::new(&p, spec(4).with_inner_selector(kind)).run().unwrap();
            assert!(
                out.result.status.converged(),
                "inner selector {}: {}",
                kind.name(),
                out.result.summary()
            );
            assert!(
                out.values.iter().all(|&v| (v - 1.0).abs() < 1e-12),
                "inner selector {}",
                kind.name()
            );
        }
    }

    #[test]
    fn default_inner_selector_is_acf_and_matches_explicit_acf() {
        // Bit-identical contract of the adapter inside the engine: the
        // default spec and an explicit ACF selection are the same run.
        let p = Quad::new(24);
        let a = ShardedDriver::new(&p, spec(3)).run().unwrap();
        let b = ShardedDriver::new(&p, spec(3).with_inner_selector(SelectorKind::Acf))
            .run()
            .unwrap();
        assert_eq!(a.values, b.values);
        assert_eq!(a.result.iterations, b.result.iterations);
        assert_eq!(a.result.objective, b.result.objective);
    }

    #[test]
    fn block_problem_converges_in_both_merge_modes() {
        // values are laid out flattened n × coord_width in global
        // indexing, and every block reaches its target under both the
        // barrier and the versioned-buffer merge
        let p = BlockQuad { n: 12 };
        let sync = ShardedDriver::new(&p, spec(3)).run().unwrap();
        assert!(sync.result.status.converged(), "{}", sync.result.summary());
        assert_eq!(sync.values.len(), 24);
        for c in sync.values.chunks_exact(2) {
            assert!((c[0] - 1.0).abs() < 1e-12 && (c[1] + 2.0).abs() < 1e-12, "{c:?}");
        }
        let asy = ShardedDriver::new(&p, spec(3).with_async(2)).run().unwrap();
        assert!(asy.result.status.converged(), "{}", asy.result.summary());
        assert_eq!(asy.values, sync.values);
    }

    #[test]
    fn block_problem_sync_is_worker_count_independent() {
        let p = BlockQuad { n: 16 };
        let run = |workers: usize| {
            let mut sp = spec(4);
            sp.workers = workers;
            let out = ShardedDriver::new(&p, sp).run().unwrap();
            (out.values, out.result.iterations, out.result.objective.to_bits())
        };
        assert_eq!(run(1), run(2));
        assert_eq!(run(2), run(4));
    }

    #[test]
    fn quad_async_converges_exactly() {
        let p = Quad::new(16);
        let out = ShardedDriver::new(&p, spec(4).with_async(2)).run().unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        assert!(out.values.iter().all(|&v| (v - 1.0).abs() < 1e-12));
    }

    #[test]
    fn sync_worker_panic_names_the_failing_shard() {
        // coordinate 1 lives in shard 0 under the contiguous split of
        // 16 coordinates into 4 shards of 4
        let p = Quad { n: 16, boom: 1 };
        let err = ShardedDriver::new(&p, spec(4)).run().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::ShardWorker { shard: 0 }, "{err:#}");
        assert!(format!("{err:#}").contains("boom"), "{err:#}");
    }

    #[test]
    fn async_worker_panic_names_the_failing_shard() {
        // coordinate 9 lives in shard 2 (shards of 4: 8..12)
        let p = Quad { n: 16, boom: 9 };
        let err = ShardedDriver::new(&p, spec(4).with_async(2)).run().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::ShardWorker { shard: 2 }, "{err:#}");
    }

    #[test]
    fn async_single_shard_matches_sync() {
        let p = Quad::new(9);
        let sync = ShardedDriver::new(&p, spec(1)).run().unwrap();
        let asy = ShardedDriver::new(&p, spec(1).with_async(0)).run().unwrap();
        assert!(sync.result.status.converged() && asy.result.status.converged());
        assert_eq!(sync.values, asy.values);
    }

    #[test]
    fn async_merge_stats_are_consistent() {
        let p = Quad::new(64);
        let out = ShardedDriver::new(&p, spec(8).with_async(2)).run().unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let s = out.merge_stats;
        // every published version accepted at least one submission, and a
        // batched fold accepts several per version
        assert!(s.accepted_submissions >= out.result.epochs, "{s:?}");
        assert!(s.objective_evals >= 1, "{s:?}");
        // loose accounting bound: every decided submission costs at most
        // 2 evaluations (tier 1 + tier 2) plus at most half a batch
        // attempt (a batch has ≥ 2 members)
        assert!(
            s.objective_evals <= 3 * (s.accepted_submissions + s.rejected_submissions).max(1),
            "{s:?}"
        );
        assert_eq!(s.staleness_bound_final, 2, "fixed τ must not move: {s:?}");
    }

    #[test]
    fn sync_merge_stats_count_objective_evals() {
        let p = Quad::new(16);
        let out = ShardedDriver::new(&p, spec(4)).run().unwrap();
        assert!(out.result.status.converged());
        let s = out.merge_stats;
        assert!(s.objective_evals >= out.result.epochs, "one exact eval per epoch: {s:?}");
        assert_eq!(s.staleness_bound_final, 0, "sync mode has no staleness bound");
    }

    fn observed(shards: usize, level: crate::obs::TraceLevel) -> Arc<Obs> {
        Arc::new(Obs::new(level, shards + 1, crate::obs::DEFAULT_RING_CAP))
    }

    #[test]
    fn tracing_does_not_perturb_sync_results() {
        use crate::obs::TraceLevel;
        let p = Quad::new(32);
        let plain = ShardedDriver::new(&p, spec(4)).run().unwrap();
        for level in [TraceLevel::Summary, TraceLevel::Spans, TraceLevel::Events] {
            let collector = observed(4, level);
            let out = ShardedDriver::new(&p, spec(4).with_obs(Arc::clone(&collector)))
                .run()
                .unwrap();
            // bit-identical contract: recording reads state, never
            // mutates it
            assert_eq!(out.values, plain.values, "{level:?}");
            assert_eq!(out.result.iterations, plain.result.iterations, "{level:?}");
            assert_eq!(
                out.result.objective.to_bits(),
                plain.result.objective.to_bits(),
                "{level:?}"
            );
            let data = collector.drain();
            if level >= TraceLevel::Spans {
                assert!(!data.events.is_empty(), "{level:?} must retain events");
                assert_eq!(data.dropped, 0, "{level:?}");
            } else {
                assert!(data.events.is_empty(), "summary level records nothing");
            }
        }
        // the live telemetry path shares the contract: attaching a
        // registry (with or without a collector) changes no result bit
        let live = Arc::new(crate::obs::live::LiveMetrics::new(Vec::new()));
        let out = ShardedDriver::new(&p, spec(4).with_live(Arc::clone(&live))).run().unwrap();
        assert_eq!(out.values, plain.values, "live leg");
        assert_eq!(out.result.iterations, plain.result.iterations, "live leg");
        assert_eq!(out.result.objective.to_bits(), plain.result.objective.to_bits(), "live leg");
        // and the registry saw the run: final point matches the outcome
        let point = live.latest();
        assert_eq!(point.snapshot.last_objective, Some(out.result.objective));
        assert!(point.snapshot.pool_rounds >= out.result.epochs, "one pool round per epoch");
        let steps: u64 = point.snapshot.per_shard.iter().map(|w| w.steps).sum();
        assert_eq!(steps, out.result.iterations);
        assert_eq!(point.merge_stats, out.merge_stats);
    }

    #[test]
    fn live_registry_tracks_async_runs() {
        let p = Quad::new(64);
        let live = Arc::new(crate::obs::live::LiveMetrics::new(Vec::new()));
        let out = ShardedDriver::new(&p, spec(8).with_async(2).with_live(Arc::clone(&live)))
            .run()
            .unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let point = live.latest();
        let s = &point.snapshot;
        assert_eq!(s.last_objective, Some(out.result.objective));
        assert_eq!(point.merge_stats, out.merge_stats);
        // every accepted/rejected submission passed through the recorder
        let decided = s.merge.additive + s.merge.damped + s.merge.rejected;
        assert_eq!(
            decided,
            out.merge_stats.accepted_submissions + out.merge_stats.rejected_submissions,
            "{s:?}"
        );
        assert!(s.queue_pushes > 0, "queue stats must flow into the snapshot");
        assert!(s.queue_max_depth >= 1, "{s:?}");
    }

    #[test]
    fn off_level_collector_records_nothing() {
        let p = Quad::new(16);
        let collector = observed(4, crate::obs::TraceLevel::Off);
        let out =
            ShardedDriver::new(&p, spec(4).with_obs(Arc::clone(&collector))).run().unwrap();
        assert!(out.result.status.converged());
        let data = collector.drain();
        assert_eq!(data.total, 0);
        assert!(data.events.is_empty());
    }

    #[test]
    fn sync_trace_covers_epochs_merges_and_publishes() {
        let p = Quad::new(32);
        let collector = observed(4, crate::obs::TraceLevel::Events);
        let out =
            ShardedDriver::new(&p, spec(4).with_obs(Arc::clone(&collector))).run().unwrap();
        assert!(out.result.status.converged());
        let data = collector.drain();
        let epochs = data.events.iter().filter(|e| matches!(e, Event::Epoch { .. })).count();
        let merges = data.events.iter().filter(|e| matches!(e, Event::Merge { .. })).count();
        let publishes =
            data.events.iter().filter(|e| matches!(e, Event::Publish { .. })).count();
        let probes =
            data.events.iter().filter(|e| matches!(e, Event::SelectorState { .. })).count();
        let objectives =
            data.events.iter().filter(|e| matches!(e, Event::Objective { .. })).count();
        let engine_stats =
            data.events.iter().filter(|e| matches!(e, Event::EngineStats { .. })).count();
        // 4 shards × ≥1 epoch each, one merge + publish + objective per
        // barrier, one selector probe per shard epoch (events level)
        assert!(epochs >= 4, "{epochs}");
        assert!(merges as u64 >= out.result.epochs, "{merges} vs {}", out.result.epochs);
        assert!(publishes as u64 >= out.result.epochs, "{publishes}");
        assert_eq!(objectives as u64, out.result.epochs, "one objective event per epoch");
        assert_eq!(engine_stats, 1, "one engine_stats summary at the end");
        assert_eq!(probes, epochs, "one probe per epoch at events level");
        assert!(data.events.windows(2).all(|w| w[0].t() <= w[1].t()), "drain must sort");
    }

    #[test]
    fn async_trace_covers_snapshots_submits_and_merge_tiers() {
        let p = Quad::new(64);
        let collector = observed(8, crate::obs::TraceLevel::Events);
        let out = ShardedDriver::new(&p, spec(8).with_async(2).with_obs(Arc::clone(&collector)))
            .run()
            .unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let data = collector.drain();
        let kinds: std::collections::BTreeSet<&str> =
            data.events.iter().map(Event::kind).collect();
        for k in ["snapshot_take", "epoch", "submit", "merge", "publish", "merge_wait"] {
            assert!(kinds.contains(k), "missing '{k}' in {kinds:?}");
        }
        // merged submissions in the trace account for every accepted or
        // rejected submission the engine counted (rings did not overflow)
        assert_eq!(data.dropped, 0);
        let merged: u64 = data
            .events
            .iter()
            .filter_map(|e| match e {
                Event::Merge { batch, .. } => Some((*batch).max(1)),
                _ => None,
            })
            .sum();
        let s = out.merge_stats;
        assert_eq!(
            merged,
            s.accepted_submissions + s.rejected_submissions + out.stale_drops,
            "{s:?}"
        );
    }

    #[test]
    fn async_adaptive_tau_converges_within_bounds() {
        let p = Quad::new(64);
        let out = ShardedDriver::new(&p, spec(8).with_async_auto()).run().unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        let tau = out.merge_stats.staleness_bound_final;
        assert!((1..=16).contains(&tau), "τ drifted out of bounds: {tau}");
    }

    #[test]
    fn tau_controller_shrinks_on_rejections() {
        let mut t = TauController::new(4, true, 8);
        for _ in 0..TAU_ADAPT_WINDOW {
            t.observe(TauSignal::Rejected);
        }
        assert_eq!(t.current(), 3, "a reject-heavy window must shrink τ");
        // keep the pressure on: τ floors at min (1) and stays there
        for _ in 0..10 * TAU_ADAPT_WINDOW {
            t.observe(TauSignal::Rejected);
        }
        assert_eq!(t.current(), 1);
    }

    #[test]
    fn tau_controller_grows_when_always_accepting() {
        let mut t = TauController::new(2, true, 4);
        for _ in 0..TAU_ADAPT_WINDOW {
            t.observe(TauSignal::Accepted);
        }
        assert_eq!(t.current(), 3, "a clean window must grow τ");
        // cap: 2 · S = 8 for S = 4
        for _ in 0..20 * TAU_ADAPT_WINDOW {
            t.observe(TauSignal::Accepted);
        }
        assert_eq!(t.current(), 8, "τ must cap at 2·S");
    }

    #[test]
    fn tau_controller_grows_on_stale_drops() {
        // stale drops mean the bound is discarding throughput: τ must
        // grow, NOT shrink (shrinking would feed back into more drops
        // and starve slow shards at the floor)
        let mut t = TauController::new(1, true, 8);
        for _ in 0..TAU_ADAPT_WINDOW {
            t.observe(TauSignal::Stale);
        }
        assert_eq!(t.current(), 2, "a drop-heavy window must grow τ");
    }

    #[test]
    fn tau_controller_rejections_dominate_stale_drops() {
        // both signals above threshold: quality wins, τ shrinks
        let mut t = TauController::new(4, true, 8);
        for i in 0..TAU_ADAPT_WINDOW {
            t.observe(if i % 2 == 0 { TauSignal::Rejected } else { TauSignal::Stale });
        }
        assert_eq!(t.current(), 3);
    }

    #[test]
    fn tau_controller_holds_on_mixed_windows_and_fixed_mode() {
        let mut t = TauController::new(3, true, 8);
        // 1 reject in 16 (≤ 25 %, not clean): hold
        for i in 0..TAU_ADAPT_WINDOW {
            t.observe(if i == 0 { TauSignal::Rejected } else { TauSignal::Accepted });
        }
        assert_eq!(t.current(), 3);
        let mut fixed = TauController::new(2, false, 8);
        for _ in 0..10 * TAU_ADAPT_WINDOW {
            fixed.observe(TauSignal::Rejected);
        }
        assert_eq!(fixed.current(), 2, "fixed τ ignores observations");
    }
}
