//! The sharded coordinate-descent engine.
//!
//! [`ShardedDriver`] partitions the coordinate set into S shards, runs an
//! independent inner [`AcfScheduler`] inside each shard, and layers an
//! *outer* ACF instance (paper Algorithms 2+3, applied one level up) over
//! the shards themselves. Execution is epoch-synchronized:
//!
//! 1. **Quota** — the outer sequence generator (Algorithm 3 over shard
//!    preferences) emits a block of shard visits; each visit grants the
//!    shard one local sweep (`n_s` CD steps). Hot shards therefore get
//!    proportionally more steps per epoch, exactly as hot coordinates get
//!    more visits in the flat algorithm.
//! 2. **Local epochs** — every shard copies the shared solver state
//!    (LASSO residual / SVM primal vector), then runs its quota of exact
//!    CD steps on its own coordinates against that private copy, driven
//!    by its inner ACF scheduler. Shards run on worker threads; nothing
//!    is shared mutably, so the epoch is embarrassingly parallel.
//! 3. **Merge** — shared-state deltas are summed in fixed shard order.
//!    The additive merge (θ = 1) is tried first and kept whenever the
//!    objective does not increase; otherwise the engine falls back to the
//!    averaged merge θ = 1/S, which is *guaranteed* not to increase the
//!    objective: each shard's endpoint is an exact-CD iterate from the
//!    epoch-start point, the shared state is linear in the coordinate
//!    values, and f is convex, so f(mean of endpoints) ≤ mean of
//!    f(endpoints) ≤ f(start). The per-epoch objective sequence is thus
//!    monotone by construction.
//! 4. **Adapt** — each shard's aggregate progress Δf per step is reported
//!    to the outer preference vector (Algorithm 2 over shards), closing
//!    the hierarchical-ACF loop.
//!
//! Determinism: shard partitions are stateless, every RNG stream is
//! derived from `(seed, shard index)`, quotas come from the deterministic
//! outer accumulators, and merges run in fixed shard order — so results
//! are bit-identical given `(seed, shard count)` regardless of thread
//! scheduling or worker count.

use crate::acf::{AcfParams, AcfScheduler, Preferences, SequenceGenerator};
use crate::metrics::{OpCounter, Trace, TracePoint};
use crate::shard::partition::{Partition, Partitioner};
use crate::solvers::{SolveResult, SolveStatus, SolverConfig};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;
use crate::util::timer::Timer;
use std::sync::Mutex;

/// Configuration of a sharded run.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    /// number of shards S (clamped to the coordinate count)
    pub shards: usize,
    /// how coordinates are assigned to shards
    pub partitioner: Partitioner,
    /// master seed; all shard/outer streams derive from it
    pub seed: u64,
    /// ACF parameters of the per-shard inner schedulers
    pub inner_params: AcfParams,
    /// ACF parameters of the outer (shard-level) adaptation
    pub outer_params: AcfParams,
    /// worker threads (0 = one per shard, bounded by hardware
    /// parallelism)
    pub workers: usize,
    /// stopping criteria; `trace_every > 0` records one trace point per
    /// epoch (the engine's natural sampling unit)
    pub config: SolverConfig,
}

impl ShardSpec {
    pub fn new(shards: usize) -> ShardSpec {
        ShardSpec {
            shards,
            partitioner: Partitioner::Contiguous,
            seed: 20140103,
            inner_params: AcfParams::default(),
            outer_params: AcfParams::default(),
            workers: 0,
            config: SolverConfig::default(),
        }
    }

    pub fn with_seed(mut self, seed: u64) -> ShardSpec {
        self.seed = seed;
        self
    }

    pub fn with_config(mut self, config: SolverConfig) -> ShardSpec {
        self.config = config;
        self
    }
}

/// Outcome of one CD step performed through [`ShardProblem::step`].
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// exact objective decrease of the step (≥ 0 up to fp noise)
    pub delta_f: f64,
    /// KKT violation of the coordinate *before* the step
    pub violation: f64,
    /// multiply-add operations spent
    pub ops: usize,
}

/// A problem family pluggable into the sharded engine.
///
/// The contract mirrors the serial solvers: one *coordinate value* per
/// coordinate (w_j for LASSO, α_i for the SVM dual) plus one dense
/// *shared state* vector that is linear in the values (residual r = Xw−y,
/// primal w = Σ α_i y_i x_i). `step` must perform the exact
/// one-dimensional CD update and keep `shared` consistent; the engine
/// owns snapshotting, merging and scheduling.
pub trait ShardProblem: Sync {
    /// Number of coordinates n.
    fn n_coords(&self) -> usize;

    /// Dimension of the shared state vector.
    fn shared_dim(&self) -> usize;

    /// Shared state at the all-values-initial point.
    fn initial_shared(&self) -> Vec<f64>;

    /// Initial value of coordinate `i` (0 for both LASSO and SVM dual).
    fn initial_value(&self, _i: usize) -> f64 {
        0.0
    }

    /// Exact CD step on coordinate `i`: update `value` and `shared` in
    /// place, report progress / violation / cost.
    fn step(&self, i: usize, value: &mut f64, shared: &mut [f64]) -> StepOutcome;

    /// KKT violation of coordinate `i` at the given state, with its
    /// operation cost (used by the synchronized verification pass).
    fn violation(&self, i: usize, value: f64, shared: &[f64]) -> (f64, usize);

    /// Non-separable objective part, a function of the shared state only
    /// (½‖r‖²/ℓ for LASSO, ½‖w‖² for the SVM dual).
    fn shared_objective(&self, shared: &[f64]) -> f64;

    /// Separable objective contribution of one coordinate (λ|w_j|, −α_i).
    fn coord_objective(&self, i: usize, value: f64) -> f64;
}

/// Result of a sharded run: final coordinate values (global indexing),
/// final shared state, solver metrics, and the outer ACF's final
/// shard-selection probabilities (diagnostics).
pub struct ShardedOutcome {
    pub values: Vec<f64>,
    pub shared: Vec<f64>,
    pub result: SolveResult,
    pub outer_probabilities: Vec<f64>,
}

/// Per-shard mutable state. Lives behind a `Mutex` purely so the scoped
/// worker threads can claim disjoint shards through a shared slice; there
/// is never lock contention (each shard is touched by exactly one worker
/// per epoch).
struct ShardState {
    ids: Vec<u32>,
    /// accepted coordinate values (aligned with `ids`)
    values: Vec<f64>,
    /// scratch: values after the local epoch, before merge acceptance
    trial: Vec<f64>,
    /// scratch: private copy of the shared state
    local_shared: Vec<f64>,
    sched: AcfScheduler,
}

/// What a shard reports back from one local epoch.
struct EpochReport {
    delta_f: f64,
    window_viol: f64,
    steps: u64,
    counter: OpCounter,
}

/// Epochs to wait after a failed full verification before re-verifying
/// (the stale-window heuristic can stay optimistic for a few epochs).
const VERIFY_COOLDOWN: u64 = 3;

/// The sharded parallel CD driver.
pub struct ShardedDriver<'a, P: ShardProblem> {
    problem: &'a P,
    partition: Partition,
    spec: ShardSpec,
}

impl<'a, P: ShardProblem> ShardedDriver<'a, P> {
    pub fn new(problem: &'a P, spec: ShardSpec) -> Self {
        let partition = Partition::new(problem.n_coords(), spec.shards.max(1), spec.partitioner);
        Self { problem, partition, spec }
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Run to convergence (or budget); see the module docs for the epoch
    /// protocol.
    pub fn run(&self) -> ShardedOutcome {
        let p = self.problem;
        let s_count = self.partition.n_shards();
        let dim = p.shared_dim();
        let workers = if self.spec.workers == 0 {
            // one thread per shard, but never oversubscribe the machine
            s_count.min(crate::util::threadpool::default_workers())
        } else {
            self.spec.workers.max(1)
        };
        let cfg = &self.spec.config;

        // ---- per-shard state -----------------------------------------
        let states: Vec<Mutex<ShardState>> = (0..s_count)
            .map(|k| {
                let ids = self.partition.shard(k).to_vec();
                let values: Vec<f64> = ids.iter().map(|&i| p.initial_value(i as usize)).collect();
                let sched = AcfScheduler::new(
                    ids.len(),
                    self.spec.inner_params,
                    Rng::new(self.spec.seed ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                );
                Mutex::new(ShardState {
                    trial: values.clone(),
                    values,
                    local_shared: vec![0.0; dim],
                    ids,
                    sched,
                })
            })
            .collect();

        // ---- outer (shard-level) ACF ---------------------------------
        let mut outer_prefs = Preferences::new(s_count, self.spec.outer_params);
        let mut outer_gen = SequenceGenerator::new(s_count);
        let mut outer_rng = Rng::new(self.spec.seed ^ 0x07E2_ACF0);
        let mut outer_block: Vec<u32> = Vec::with_capacity(2 * s_count);

        // ---- bookkeeping ---------------------------------------------
        let mut shared = p.initial_shared();
        let mut sep: Vec<f64> = (0..s_count)
            .map(|k| {
                let st = states[k].lock().unwrap();
                st.ids.iter().zip(&st.values).map(|(&i, &v)| p.coord_objective(i as usize, v)).sum()
            })
            .collect();
        let mut f_curr = p.shared_objective(&shared) + sep.iter().sum::<f64>();

        let mut counter = OpCounter::new();
        let timer = Timer::start();
        let mut trace = Trace::new();
        let mut epochs = 0u64;
        let mut status = SolveStatus::IterLimit;
        let mut final_viol = f64::INFINITY;
        let mut last_failed_verify: Option<u64> = None;

        let mut sum_diff = vec![0.0f64; dim];
        let mut trial_shared = vec![0.0f64; dim];

        'outer: loop {
            // ---- quotas from the outer ACF level ---------------------
            outer_gen.next_block(&outer_prefs, &mut outer_rng, &mut outer_block);
            let mut quotas = vec![0u64; s_count];
            for &s in &outer_block {
                quotas[s as usize] += self.partition.shard(s as usize).len() as u64;
            }
            let total: u64 = quotas.iter().sum();
            let remaining = cfg.max_iterations.saturating_sub(counter.iterations());
            if remaining == 0 {
                let (v, vops) = self.verify(&states, &shared, workers);
                counter.extra(vops);
                final_viol = v;
                status = if v < cfg.eps { SolveStatus::Converged } else { SolveStatus::IterLimit };
                break 'outer;
            }
            if total > remaining {
                for q in quotas.iter_mut() {
                    *q = *q * remaining / total;
                }
                if quotas.iter().sum::<u64>() == 0 {
                    // Give the whole tail budget to the largest shard so
                    // the loop always makes progress.
                    let big = (0..s_count).max_by_key(|&k| self.partition.shard(k).len()).unwrap_or(0);
                    quotas[big] = remaining;
                }
            }
            epochs += 1;

            // ---- parallel local epochs -------------------------------
            let reports: Vec<EpochReport> = parallel_map(s_count, workers, |k| {
                let mut guard = states[k].lock().unwrap();
                let st = &mut *guard;
                st.local_shared.copy_from_slice(&shared);
                st.trial.copy_from_slice(&st.values);
                let mut local = OpCounter::new();
                let mut df_sum = 0.0f64;
                let mut viol_max = 0.0f64;
                for _ in 0..quotas[k] {
                    let kk = st.sched.next();
                    let i = st.ids[kk] as usize;
                    let out = p.step(i, &mut st.trial[kk], &mut st.local_shared);
                    st.sched.report(kk, out.delta_f.max(0.0));
                    df_sum += out.delta_f;
                    viol_max = viol_max.max(out.violation);
                    local.step(out.ops);
                }
                EpochReport { delta_f: df_sum, window_viol: viol_max, steps: quotas[k], counter: local }
            });
            for r in &reports {
                counter.merge(&r.counter);
            }

            // ---- merge (fixed shard order ⇒ deterministic) -----------
            sum_diff.fill(0.0);
            for state in states.iter() {
                let st = state.lock().unwrap();
                for (d, (&l, &g)) in sum_diff.iter_mut().zip(st.local_shared.iter().zip(shared.iter())) {
                    *d += l - g;
                }
            }
            for t in 0..dim {
                trial_shared[t] = shared[t] + sum_diff[t];
            }
            let sep_trial: Vec<f64> = (0..s_count)
                .map(|k| {
                    let st = states[k].lock().unwrap();
                    st.ids.iter().zip(&st.trial).map(|(&i, &v)| p.coord_objective(i as usize, v)).sum()
                })
                .collect();
            let f_full = p.shared_objective(&trial_shared) + sep_trial.iter().sum::<f64>();
            let tol = 1e-12 * f_curr.abs().max(1.0);
            if f_full <= f_curr + tol {
                // additive merge accepted
                std::mem::swap(&mut shared, &mut trial_shared);
                for (k, state) in states.iter().enumerate() {
                    let mut st = state.lock().unwrap();
                    let st = &mut *st;
                    st.values.copy_from_slice(&st.trial);
                    sep[k] = sep_trial[k];
                }
                f_curr = f_full;
            } else {
                // averaged merge θ = 1/S: never increases f (convexity)
                let theta = 1.0 / s_count as f64;
                for t in 0..dim {
                    shared[t] += theta * sum_diff[t];
                }
                for (k, state) in states.iter().enumerate() {
                    let mut st = state.lock().unwrap();
                    let st = &mut *st;
                    let mut sk = 0.0;
                    for (kk, &i) in st.ids.iter().enumerate() {
                        st.values[kk] += theta * (st.trial[kk] - st.values[kk]);
                        sk += p.coord_objective(i as usize, st.values[kk]);
                    }
                    sep[k] = sk;
                }
                f_curr = p.shared_objective(&shared) + sep.iter().sum::<f64>();
            }

            // ---- hierarchical adaptation: outer Δf report ------------
            for (k, r) in reports.iter().enumerate() {
                if r.steps > 0 {
                    outer_prefs.update(k, (r.delta_f / r.steps as f64).max(0.0));
                }
            }
            if epochs % 64 == 0 {
                outer_prefs.refresh_sum();
            }

            let window_viol =
                reports.iter().filter(|r| r.steps > 0).map(|r| r.window_viol).fold(0.0f64, f64::max);
            if cfg.trace_every > 0 {
                trace.push(TracePoint {
                    iteration: counter.iterations(),
                    ops: counter.ops(),
                    seconds: timer.secs(),
                    objective: f_curr,
                    violation: window_viol,
                });
            }

            // ---- stopping --------------------------------------------
            let budget_hit = counter.iterations() >= cfg.max_iterations;
            let time_hit = match cfg.max_seconds {
                Some(cap) => timer.secs() > cap,
                None => false,
            };
            let verify_cooled = match last_failed_verify {
                Some(at) => epochs >= at + VERIFY_COOLDOWN,
                None => true,
            };
            let window_converged = window_viol < cfg.eps && verify_cooled;
            if window_converged || budget_hit || time_hit {
                let (v, vops) = self.verify(&states, &shared, workers);
                counter.extra(vops);
                final_viol = v;
                if v < cfg.eps {
                    status = SolveStatus::Converged;
                    break 'outer;
                }
                if budget_hit {
                    status = SolveStatus::IterLimit;
                    break 'outer;
                }
                if time_hit {
                    status = SolveStatus::TimeLimit;
                    break 'outer;
                }
                last_failed_verify = Some(epochs);
            }
        }

        // ---- assemble global views -----------------------------------
        let mut values = vec![0.0f64; p.n_coords()];
        for state in states.iter() {
            let st = state.lock().unwrap();
            for (kk, &i) in st.ids.iter().enumerate() {
                values[i as usize] = st.values[kk];
            }
        }
        let result = SolveResult {
            status,
            iterations: counter.iterations(),
            ops: counter.ops(),
            seconds: timer.secs(),
            objective: f_curr,
            final_violation: final_viol,
            epochs,
            trace,
        };
        ShardedOutcome { values, shared, result, outer_probabilities: outer_prefs.probabilities() }
    }

    /// Synchronized full KKT pass over the merged state, parallel over
    /// shards. Returns (max violation, ops spent).
    fn verify(&self, states: &[Mutex<ShardState>], shared: &[f64], workers: usize) -> (f64, usize) {
        let p = self.problem;
        let per_shard: Vec<(f64, usize)> = parallel_map(states.len(), workers, |k| {
            let st = states[k].lock().unwrap();
            let mut vmax = 0.0f64;
            let mut ops = 0usize;
            for (kk, &i) in st.ids.iter().enumerate() {
                let (v, o) = p.violation(i as usize, st.values[kk], shared);
                vmax = vmax.max(v);
                ops += o;
            }
            (vmax, ops)
        });
        per_shard.into_iter().fold((0.0, 0), |(vm, os), (v, o)| (vm.max(v), os + o))
    }
}
