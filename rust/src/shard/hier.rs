//! Serial two-level ACF — the hierarchical policy behind
//! [`crate::sched::Policy::Hierarchical`].
//!
//! An outer [`AcfScheduler`] adapts frequencies over *shards*; each shard
//! owns an inner [`AcfScheduler`] over its coordinates. `next()` first
//! asks the outer level for a shard, then the shard's inner level for a
//! coordinate; `report()` feeds the observed Δf to both levels. The
//! stationary selection distribution is the product
//! `π_outer(shard) · π_inner(coord | shard)`, so the effective preference
//! range widens to `(p_max/p_min)²` — useful when coordinate importance
//! is clustered (feature blocks, class groups) and the flat clip range
//! saturates.
//!
//! This is the single-threaded twin of the parallel engine in
//! [`crate::shard::engine`]: same two-level adaptation, no threads, fully
//! deterministic given the seed, pluggable wherever a
//! [`Scheduler`](crate::sched::Scheduler) is accepted. It is unaffected
//! by the engine's merge protocol ([`crate::shard::MergeMode`]): there is
//! no shared-state merging here at all — one thread owns the full state,
//! so `--async-merge` / `--staleness-bound` apply only to the parallel
//! engine, and this policy remains the right baseline when comparing
//! hierarchical adaptation in isolation from merge effects.

use crate::acf::{AcfParams, AcfScheduler};
use crate::sched::Scheduler;
use crate::shard::partition::{Partition, Partitioner};
use crate::util::rng::Rng;

/// Two-level (shards × coordinates) ACF scheduler.
#[derive(Clone, Debug)]
pub struct HierarchicalScheduler {
    partition: Partition,
    outer: AcfScheduler,
    inners: Vec<AcfScheduler>,
}

/// Default shard count when the caller does not pin one: √n balances the
/// two levels (each adapts over a set of comparable size).
pub fn auto_shards(n: usize) -> usize {
    (n as f64).sqrt().round().max(1.0) as usize
}

impl HierarchicalScheduler {
    /// `shards = 0` selects [`auto_shards`]; the count is clamped to `n`.
    pub fn new(
        n: usize,
        shards: usize,
        partitioner: Partitioner,
        params: AcfParams,
        mut rng: Rng,
    ) -> HierarchicalScheduler {
        assert!(n > 0);
        let s = if shards == 0 { auto_shards(n) } else { shards }.min(n);
        let partition = Partition::new(n, s, partitioner);
        let outer = AcfScheduler::new(partition.n_shards(), params, rng.split());
        let inners = (0..partition.n_shards())
            .map(|k| AcfScheduler::new(partition.shard(k).len(), params, rng.split()))
            .collect();
        HierarchicalScheduler { partition, outer, inners }
    }

    pub fn n_shards(&self) -> usize {
        self.partition.n_shards()
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }
}

impl Scheduler for HierarchicalScheduler {
    #[inline]
    fn next(&mut self) -> usize {
        let s = self.outer.next();
        let kk = self.inners[s].next();
        self.partition.shard(s)[kk] as usize
    }

    #[inline]
    fn report(&mut self, i: usize, delta_f: f64) {
        let s = self.partition.shard_of(i);
        self.inners[s].report(self.partition.local_of(i), delta_f);
        self.outer.report(s, delta_f);
    }

    fn n(&self) -> usize {
        self.partition.n()
    }

    fn name(&self) -> &'static str {
        "hierarchical-acf"
    }

    fn probabilities_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.partition.n(), 0.0);
        let mut outer = Vec::with_capacity(self.inners.len());
        self.outer.preferences().probabilities_into(&mut outer);
        let mut pi = Vec::new();
        for (s, inner) in self.inners.iter().enumerate() {
            inner.preferences().probabilities_into(&mut pi);
            for (kk, &i) in self.partition.shard(s).iter().enumerate() {
                out[i as usize] = outer[s] * pi[kk];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_coordinates() {
        let mut s =
            HierarchicalScheduler::new(40, 5, Partitioner::Contiguous, AcfParams::default(), Rng::new(1));
        let mut seen = vec![false; 40];
        for _ in 0..4000 {
            seen[s.next()] = true;
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn auto_shard_count_is_sqrt() {
        assert_eq!(auto_shards(1), 1);
        assert_eq!(auto_shards(100), 10);
        let s = HierarchicalScheduler::new(100, 0, Partitioner::Hash, AcfParams::default(), Rng::new(2));
        assert_eq!(s.n_shards(), 10);
        assert_eq!(s.n(), 100);
    }

    #[test]
    fn probabilities_form_a_distribution_and_adapt() {
        let mut s =
            HierarchicalScheduler::new(30, 3, Partitioner::Contiguous, AcfParams::default(), Rng::new(3));
        for _ in 0..6000 {
            let i = s.next();
            // coordinate 7 (shard 0) is the only productive one
            s.report(i, if i == 7 { 5.0 } else { 0.01 });
        }
        let p = s.probabilities();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let max = p.iter().cloned().fold(0.0f64, f64::max);
        assert_eq!(p[7], max, "{p:?}");
        // hierarchical range: coordinate 7 beats same-shard peers *and*
        // its shard beats the other shards
        assert!(p[7] > 4.0 * p[20], "{p:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let mut s =
                HierarchicalScheduler::new(25, 4, Partitioner::Hash, AcfParams::default(), Rng::new(seed));
            (0..300)
                .map(|k| {
                    let i = s.next();
                    s.report(i, (k % 5) as f64);
                    i
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
