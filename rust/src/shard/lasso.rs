//! Sharded LASSO: features are the coordinates, the residual `r = Xw − y`
//! is the shared state. The per-step math is identical to
//! [`crate::solvers::lasso`]; this module only adapts it to the
//! [`ShardProblem`] contract.
//!
//! The per-shard inner loops run any
//! [`crate::select::Selector`] policy — set
//! [`ShardSpec::inner_selector`] (CLI `--selector`) to face off ACF
//! against bandit / importance sampling inside the parallel engine; the
//! outer shard-level ACF is unaffected.

use crate::shard::engine::{ShardProblem, ShardSpec, ShardedDriver, ShardedOutcome, StepOutcome};
use crate::solvers::lasso::{subgrad_violation, LassoModel, LassoProblem};
use crate::solvers::SolveResult;
use crate::sparse::ops::soft_threshold;
use crate::sparse::Dataset;
use crate::util::error::Result;

/// LASSO adapted to the sharded engine. Owns the transposed problem view
/// so one instance can be reused across shard counts (benches amortize
/// the transpose).
pub struct ShardedLasso {
    prob: LassoProblem,
    lambda: f64,
}

impl ShardedLasso {
    pub fn new(ds: &Dataset, lambda: f64) -> ShardedLasso {
        ShardedLasso { prob: LassoProblem::new(ds), lambda }
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }
}

impl ShardProblem for ShardedLasso {
    fn n_coords(&self) -> usize {
        self.prob.n_features
    }

    fn shared_dim(&self) -> usize {
        self.prob.n_instances
    }

    fn initial_shared(&self) -> Vec<f64> {
        // r = Xw − y = −y at w = 0
        self.prob.y.iter().map(|&v| -v).collect()
    }

    #[inline]
    fn step(&self, j: usize, values: &mut [f64], shared: &mut [f64]) -> StepOutcome {
        let l = self.prob.n_instances as f64;
        let col = self.prob.xt.row(j);
        let h = self.prob.h[j];
        let old = values[0];
        // fused kernel, same update as the serial solver
        let mut g = 0.0;
        let mut new = old;
        let (_, d) = col.step(shared, |dot| {
            g = dot / l;
            if h > 0.0 {
                new = soft_threshold(old - g / h, self.lambda / h);
            }
            new - old
        });
        let violation = subgrad_violation(old, g, self.lambda);
        let mut ops = col.nnz();
        let mut delta_f = 0.0;
        if d != 0.0 {
            values[0] = new;
            ops += col.nnz();
            // exact decrease: smooth part g·d + ½h·d², plus the ℓ1
            // term change
            delta_f = -(g * d + 0.5 * h * d * d) - self.lambda * (new.abs() - old.abs());
        }
        StepOutcome { delta_f, violation, ops }
    }

    fn violation(&self, j: usize, values: &[f64], shared: &[f64]) -> (f64, usize) {
        let l = self.prob.n_instances as f64;
        let col = self.prob.xt.row(j);
        let g = col.dot_dense(shared) / l;
        (subgrad_violation(values[0], g, self.lambda), col.nnz())
    }

    #[inline]
    fn prefetch_coord(&self, j: usize) {
        // feature-sharded: coordinate j's data is a column of X, i.e. a
        // row of the transposed view
        let col = self.prob.xt.row(j);
        crate::sparse::kernels::prefetch_row(col.indices(), col.values());
    }

    fn shared_objective(&self, shared: &[f64]) -> f64 {
        crate::sparse::ops::norm_sq(shared) / (2.0 * self.prob.n_instances as f64)
    }

    #[inline]
    fn coord_objective(&self, _j: usize, values: &[f64]) -> f64 {
        self.lambda * values[0].abs()
    }

    fn shard_extent(&self, ids: &[u32]) -> Option<(u64, u64)> {
        // feature-sharded: a shard touches the columns of X it owns,
        // i.e. rows of the transposed view
        Some(self.prob.xt.rows_extent(ids))
    }
}

/// Solve the LASSO on the sharded engine; drop-in analog of
/// [`crate::solvers::lasso::solve`]. Errs with
/// [`crate::util::error::ErrorKind::ShardWorker`] if a shard worker dies.
pub fn solve_sharded(ds: &Dataset, lambda: f64, spec: ShardSpec) -> Result<(LassoModel, SolveResult)> {
    let problem = ShardedLasso::new(ds, lambda);
    let out = run_prepared(&problem, spec)?;
    Ok((LassoModel { w: out.values, lambda }, out.result))
}

/// Run on an already-prepared problem (amortizes the transpose across
/// shard counts / λ values).
pub fn run_prepared(problem: &ShardedLasso, spec: ShardSpec) -> Result<ShardedOutcome> {
    ShardedDriver::new(problem, spec).run()
}
