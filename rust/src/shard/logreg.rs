//! Sharded dual logistic regression: instances are the coordinates, the
//! primal vector `w = Σ α_i y_i x_i` is the shared state (linear in the
//! duals, exactly as the engine's merge protocol requires). The per-step
//! math is identical to [`crate::solvers::logreg`] — the same
//! bisection-safeguarded Newton 1-D solve and exact Δf — so serial and
//! sharded runs price every point identically; this module only adapts
//! it to the [`ShardProblem`] contract.
//!
//! The dual solution is strictly interior (the entropy terms push α off
//! the bounds), so the averaged-merge fallback θ = 1/S keeps every α_i
//! inside (0, C) automatically: a convex combination of interior points
//! is interior, and the separable entropy objective is convex, which is
//! what makes the damped tier objective-safe.
//!
//! The per-shard inner loops run any [`crate::select::Selector`] policy —
//! set [`ShardSpec::inner_selector`] (CLI `--selector`); the outer
//! shard-level ACF is unaffected.

use crate::shard::engine::{ShardProblem, ShardSpec, ShardedDriver, ShardedOutcome, StepOutcome};
use crate::solvers::logreg::{ent, grad_violation, initial_alpha, solve_1d, LogRegModel};
use crate::solvers::SolveResult;
use crate::sparse::Dataset;
use crate::util::error::Result;

/// Dual logistic regression adapted to the sharded engine.
pub struct ShardedLogReg<'a> {
    ds: &'a Dataset,
    /// borrowed from the matrix-level norm cache (computed once per Csr)
    q_diag: &'a [f64],
    c: f64,
    /// interior starting point (same constant as the serial solver)
    a_init: f64,
}

impl<'a> ShardedLogReg<'a> {
    pub fn new(ds: &'a Dataset, c: f64) -> ShardedLogReg<'a> {
        ShardedLogReg { ds, q_diag: ds.x.row_norms_sq(), c, a_init: initial_alpha(c) }
    }

    pub fn c(&self) -> f64 {
        self.c
    }
}

impl ShardProblem for ShardedLogReg<'_> {
    fn n_coords(&self) -> usize {
        self.ds.n_instances()
    }

    fn shared_dim(&self) -> usize {
        self.ds.n_features()
    }

    fn initial_shared(&self) -> Vec<f64> {
        // w = Σ α_init y_i x_i — the same accumulation order as the
        // serial solver, so initial objectives agree to the last bit
        let mut w = vec![0.0f64; self.ds.n_features()];
        for i in 0..self.ds.n_instances() {
            self.ds.x.row(i).axpy_into(self.a_init * self.ds.y[i], &mut w);
        }
        w
    }

    fn init_coord(&self, _i: usize, values: &mut [f64]) {
        values[0] = self.a_init;
    }

    #[inline]
    fn step(&self, i: usize, values: &mut [f64], shared: &mut [f64]) -> StepOutcome {
        let row = self.ds.x.row(i);
        let yi = self.ds.y[i];
        let a_old = values[0];
        // fused kernel, same guarded-Newton update as the serial solver
        let mut m = 0.0;
        let mut g = 0.0;
        let mut a_new = a_old;
        row.step(shared, |dot| {
            m = yi * dot;
            g = m + (a_old / (self.c - a_old)).ln();
            a_new = solve_1d(self.q_diag[i], m, a_old, self.c, 1e-10, 25);
            let d = a_new - a_old;
            if d.abs() > 1e-15 {
                d * yi
            } else {
                0.0
            }
        });
        let violation = grad_violation(g);
        let mut ops = row.nnz();
        let mut delta_f = 0.0;
        let d = a_new - a_old;
        if d.abs() > 1e-15 {
            values[0] = a_new;
            ops += row.nnz();
            // exact decrease: quadratic part m·d + ½q·d² plus entropy
            delta_f = -(m * d + 0.5 * self.q_diag[i] * d * d) - (ent(a_new, self.c) - ent(a_old, self.c));
        }
        StepOutcome { delta_f, violation, ops }
    }

    fn violation(&self, i: usize, values: &[f64], shared: &[f64]) -> (f64, usize) {
        let row = self.ds.x.row(i);
        let m = self.ds.y[i] * row.dot_dense(shared);
        let g = m + (values[0] / (self.c - values[0])).ln();
        (grad_violation(g), row.nnz())
    }

    #[inline]
    fn prefetch_coord(&self, i: usize) {
        let row = self.ds.x.row(i);
        crate::sparse::kernels::prefetch_row(row.indices(), row.values());
    }

    fn shared_objective(&self, shared: &[f64]) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(shared)
    }

    #[inline]
    fn coord_objective(&self, _i: usize, values: &[f64]) -> f64 {
        ent(values[0], self.c)
    }

    fn shard_extent(&self, ids: &[u32]) -> Option<(u64, u64)> {
        Some(self.ds.x.rows_extent(ids))
    }
}

/// Solve dual logistic regression on the sharded engine; drop-in analog
/// of [`crate::solvers::logreg::solve`]. Errs with
/// [`crate::util::error::ErrorKind::ShardWorker`] if a shard worker dies.
pub fn solve_sharded(ds: &Dataset, c: f64, spec: ShardSpec) -> Result<(LogRegModel, SolveResult)> {
    let problem = ShardedLogReg::new(ds, c);
    let out = run_prepared(&problem, spec)?;
    Ok((LogRegModel { alpha: out.values, w: out.shared, c }, out.result))
}

/// Run on an already-prepared problem (amortizes the norm cache across
/// shard counts / C values).
pub fn run_prepared(problem: &ShardedLogReg<'_>, spec: ShardSpec) -> Result<ShardedOutcome> {
    ShardedDriver::new(problem, spec).run()
}
