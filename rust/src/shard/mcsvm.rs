//! Sharded Weston–Watkins multi-class SVM: instances are the
//! coordinates, and each coordinate owns a **block** of K dual values
//! α_{i,·} ([`ShardProblem::coord_width`] = K). The shared state is the
//! K per-class primal vectors w_1..w_K flattened into one K·d buffer
//! (`w_k` occupies `shared[k·d..(k+1)·d]`), so the engine snapshots,
//! merges and publishes all K buffers **atomically as one versioned
//! unit** — a merge can never observe some classes at one version and
//! the rest at another, which is what keeps the exact-objective
//! acceptance checks (and with them the async bounded-staleness merge
//! and the sync θ = 1/S fallback) objective-exact.
//!
//! Each w_k is linear in the dual block values
//! (`w_k = Σ_i x_i·([y_i = k]·Σ_m α_{im} − [y_i ≠ k]·α_{ik})`), so the
//! engine's linearity contract holds per class and the flattened buffer
//! inherits it. The per-step math is the serial solver's
//! `solve_subspace` — the identical SMO-style inner CD loop — against
//! margins gathered from the flattened snapshot. The averaged-merge
//! fallback keeps every
//! α_{ik} inside the box `[0, C]` automatically (a convex combination of
//! feasible blocks is feasible).
//!
//! Labels are validated at construction
//! ([`crate::solvers::mcsvm::class_labels`]): ±1-labeled binary data is
//! rejected with an error naming the offending value instead of
//! saturating into class 0.
//!
//! **Iteration convention caveat:** the engine counts one *iteration*
//! per coordinate visit (one whole subspace solve), while the serial
//! solver follows the paper's convention of counting inner SMO steps —
//! up to 10·K per visit. `max_iterations` therefore budgets subspace
//! solves here, and the serial vs sharded `iterations`/`steps` columns
//! are not directly comparable for this family (ops columns are: both
//! paths bill the same multiply-adds per visit). Exact inner-step
//! accounting needs engine support for variable-cost steps — the quota
//! allocator issues budget in visit units before a visit's inner-step
//! count is knowable (see the ROADMAP follow-up).
//!
//! The per-shard inner loops run any [`crate::select::Selector`] policy —
//! set [`ShardSpec::inner_selector`] (CLI `--selector`); the outer
//! shard-level ACF is unaffected.

use crate::shard::engine::{ShardProblem, ShardSpec, ShardedDriver, ShardedOutcome, StepOutcome};
use crate::solvers::mcsvm::{class_labels, solve_subspace, McSvmModel};
use crate::solvers::SolveResult;
use crate::sparse::Dataset;
use crate::util::error::Result;

/// Multi-class SVM adapted to the sharded engine (per-class shared
/// state). Build with [`ShardedMcSvm::new`], which validates labels.
pub struct ShardedMcSvm<'a> {
    ds: &'a Dataset,
    /// borrowed from the matrix-level norm cache (computed once per Csr)
    norms: &'a [f64],
    /// validated labels in 0..K−1
    y: Vec<usize>,
    k_classes: usize,
    d: usize,
    c: f64,
    /// inner SMO stopping threshold (serial convention: 0.1 · outer ε)
    eps_inner: f64,
    max_inner: usize,
}

impl<'a> ShardedMcSvm<'a> {
    /// `eps` is the run's outer stopping threshold
    /// ([`crate::solvers::SolverConfig::eps`]); the inner SMO loop stops
    /// at `0.1 · eps`, matching the serial solver. Errs when the labels
    /// are not integers in `0..K−1`.
    pub fn new(ds: &'a Dataset, c: f64, eps: f64) -> Result<ShardedMcSvm<'a>> {
        let k_classes = ds.classes().len();
        // one shared validator with the serial path — the k >= 2 check
        // and the per-label range check both live in class_labels
        let y = class_labels(ds, k_classes)?;
        Ok(ShardedMcSvm {
            ds,
            norms: ds.x.row_norms_sq(),
            y,
            k_classes,
            d: ds.n_features(),
            c,
            eps_inner: eps * 0.1,
            max_inner: 10 * k_classes,
        })
    }

    pub fn k_classes(&self) -> usize {
        self.k_classes
    }

    /// Split a flattened K·d shared buffer back into per-class weights.
    pub fn unflatten_weights(&self, shared: &[f64]) -> Vec<Vec<f64>> {
        shared.chunks_exact(self.d).map(|wk| wk.to_vec()).collect()
    }
}

impl ShardProblem for ShardedMcSvm<'_> {
    fn n_coords(&self) -> usize {
        self.ds.n_instances()
    }

    fn coord_width(&self) -> usize {
        self.k_classes
    }

    fn shared_dim(&self) -> usize {
        self.k_classes * self.d
    }

    fn initial_shared(&self) -> Vec<f64> {
        vec![0.0; self.k_classes * self.d]
    }

    fn step(&self, i: usize, values: &mut [f64], shared: &mut [f64]) -> StepOutcome {
        // margins + per-class scatter deltas live in a thread-local
        // arena: `step` runs millions of times on the engine hot path,
        // and a per-step `vec![0.0; 2K]` allocation showed up as real
        // allocator traffic once the sparse kernels got fast. Each
        // worker thread reuses its own buffer, so shard parallelism
        // needs no locking.
        thread_local! {
            static SCRATCH: std::cell::RefCell<Vec<f64>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        let row = self.ds.x.row(i);
        let yi = self.y[i];
        let k = self.k_classes;
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.clear();
            scratch.resize(2 * k, 0.0);
            let (margins, delta_beta) = scratch.split_at_mut(k);
            for (kk, m) in margins.iter_mut().enumerate() {
                *m = row.dot_dense(&shared[kk * self.d..(kk + 1) * self.d]);
            }
            let mut ops = k * row.nnz();
            let out = solve_subspace(
                yi,
                k,
                self.norms[i],
                self.c,
                margins,
                values,
                delta_beta,
                self.max_inner,
                self.eps_inner,
            );
            // apply weight updates: O(nnz) per class actually moved
            for (kk, &b) in delta_beta.iter().enumerate() {
                if b != 0.0 {
                    row.axpy_into(b, &mut shared[kk * self.d..(kk + 1) * self.d]);
                    ops += row.nnz();
                }
            }
            ops += out.ops;
            StepOutcome { delta_f: out.delta_f, violation: out.max_viol_entry, ops }
        })
    }

    fn violation(&self, i: usize, values: &[f64], shared: &[f64]) -> (f64, usize) {
        let row = self.ds.x.row(i);
        let yi = self.y[i];
        let myi = row.dot_dense(&shared[yi * self.d..(yi + 1) * self.d]);
        let mut max_viol = 0.0f64;
        for k in 0..self.k_classes {
            if k == yi {
                continue;
            }
            let g = myi - row.dot_dense(&shared[k * self.d..(k + 1) * self.d]) - 1.0;
            let a = values[k];
            let v = if a <= 0.0 {
                (-g).max(0.0)
            } else if a >= self.c {
                g.max(0.0)
            } else {
                g.abs()
            };
            max_viol = max_viol.max(v);
        }
        (max_viol, self.k_classes * row.nnz())
    }

    #[inline]
    fn prefetch_coord(&self, i: usize) {
        // K dots reuse the same row slices, so one row prefetch covers
        // the whole per-class violation scan
        let row = self.ds.x.row(i);
        crate::sparse::kernels::prefetch_row(row.indices(), row.values());
    }

    fn shared_objective(&self, shared: &[f64]) -> f64 {
        // ½ Σ_k ‖w_k‖² is ½‖·‖² of the flattened buffer
        0.5 * crate::sparse::ops::norm_sq(shared)
    }

    #[inline]
    fn coord_objective(&self, _i: usize, values: &[f64]) -> f64 {
        // −Σ_{k≠y_i} α_{ik}; the k = y_i entry is identically 0 (exact
        // CD never writes it and damped merges average two zeros)
        -values.iter().sum::<f64>()
    }

    fn shard_extent(&self, ids: &[u32]) -> Option<(u64, u64)> {
        Some(self.ds.x.rows_extent(ids))
    }
}

/// Solve the WW multi-class SVM on the sharded engine; drop-in analog of
/// [`crate::solvers::mcsvm::solve`]. Errs on invalid labels, or with
/// [`crate::util::error::ErrorKind::ShardWorker`] if a shard worker
/// dies.
pub fn solve_sharded(ds: &Dataset, c: f64, spec: ShardSpec) -> Result<(McSvmModel, SolveResult)> {
    let problem = ShardedMcSvm::new(ds, c, spec.config.eps)?;
    let out = run_prepared(&problem, spec)?;
    let w = problem.unflatten_weights(&out.shared);
    Ok((McSvmModel { w, alpha: out.values, c, k_classes: problem.k_classes }, out.result))
}

/// Run on an already-prepared problem (amortizes label validation and
/// the norm cache across shard counts / C values).
pub fn run_prepared(problem: &ShardedMcSvm<'_>, spec: ShardSpec) -> Result<ShardedOutcome> {
    ShardedDriver::new(problem, spec).run()
}
