//! Sharded parallel coordinate descent with **hierarchical (two-level)
//! ACF** — the scaling subsystem layered over the paper's algorithms.
//!
//! The flat ACF scheduler adapts per-coordinate frequencies online
//! (Algorithms 2+3); this subsystem applies the same machinery *twice*:
//!
//! * [`partition`] — splits the coordinate set into S shards
//!   (contiguous ranges or a deterministic hash);
//! * [`engine`] — runs an independent inner ACF scheduler inside every
//!   shard on worker threads with epoch-synchronized merges of the
//!   shared solver state, while an **outer** ACF instance adapts how
//!   often each shard is visited from its aggregate progress Δf;
//! * [`lasso`] / [`svm`] — shard-aware solver front-ends (features are
//!   sharded for LASSO, instances for the SVM dual);
//! * [`hier`] — the single-threaded two-level scheduler exposed as
//!   [`crate::sched::Policy::Hierarchical`] for any serial solver.
//!
//! Guarantees:
//!
//! * **Determinism** — results are bit-identical given `(seed, shard
//!   count)`, independent of worker threads or scheduling (see
//!   [`engine`]).
//! * **Monotone descent** — the merge accepts the additive combination
//!   only when the objective does not increase and otherwise falls back
//!   to the averaged combination, which convexity guarantees is
//!   non-increasing; every epoch makes progress.
//!
//! Related work: Wright's *Coordinate Descent Algorithms* survey
//! describes the parallel/asynchronous block-CD design space this
//! subsystem instantiates; *Coordinate Descent with Bandit Sampling*
//! shows adaptive selection composing with block structure — the outer
//! ACF level is exactly that idea built from the paper's own update rule.

pub mod engine;
pub mod hier;
pub mod lasso;
pub mod partition;
pub mod svm;

pub use engine::{ShardProblem, ShardSpec, ShardedDriver, ShardedOutcome, StepOutcome};
pub use hier::{auto_shards, HierarchicalScheduler};
pub use partition::{Partition, Partitioner, PARTITIONER_NAMES};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::sched::CyclicScheduler;
    use crate::solvers::{lasso as serial_lasso, svm as serial_svm, SolverConfig};
    use crate::sparse::Dataset;
    use crate::util::rng::Rng;

    fn reg_ds(seed: u64) -> Dataset {
        synth::regression_sparse("reg", 200, 120, 12, 10, 0.05, &mut Rng::new(seed)).0
    }

    fn svm_ds(seed: u64) -> Dataset {
        synth::sparse_text(
            &synth::SparseTextSpec {
                name: "t",
                n: 300,
                d: 500,
                nnz_per_row: 15,
                zipf_s: 1.0,
                concept_k: 30,
                noise: 0.05,
            },
            &mut Rng::new(seed),
        )
    }

    fn spec(shards: usize, eps: f64) -> ShardSpec {
        ShardSpec::new(shards).with_config(SolverConfig::with_eps(eps))
    }

    #[test]
    fn sharded_lasso_matches_serial_objective() {
        let ds = reg_ds(1);
        let lambda = 0.02;
        let mut cyc = CyclicScheduler::new(ds.n_features());
        let (_, serial) = serial_lasso::solve(&ds, lambda, &mut cyc, SolverConfig::with_eps(1e-6));
        assert!(serial.status.converged());
        for shards in [1, 3, 4] {
            let (model, res) = lasso::solve_sharded(&ds, lambda, spec(shards, 1e-6));
            assert!(res.status.converged(), "S={shards}: {}", res.summary());
            let rel = (serial.objective - res.objective).abs() / serial.objective.abs().max(1e-12);
            assert!(rel < 1e-4, "S={shards}: {} vs {}", serial.objective, res.objective);
            assert_eq!(model.w.len(), ds.n_features());
        }
    }

    #[test]
    fn sharded_svm_matches_serial_objective() {
        let ds = svm_ds(2);
        let c = 1.0;
        let mut perm = crate::sched::PermutationScheduler::new(ds.n_instances(), Rng::new(3));
        let (_, serial) = serial_svm::solve(&ds, c, &mut perm, SolverConfig::with_eps(1e-5));
        assert!(serial.status.converged());
        for shards in [2, 4] {
            let (model, res) = svm::solve_sharded(&ds, c, spec(shards, 1e-5));
            assert!(res.status.converged(), "S={shards}: {}", res.summary());
            let rel = (serial.objective - res.objective).abs() / serial.objective.abs().max(1.0);
            assert!(rel < 1e-4, "S={shards}: {} vs {}", serial.objective, res.objective);
            // box feasibility survives damped merges
            assert!(model.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
        }
    }

    #[test]
    fn sharded_runs_are_deterministic_and_worker_independent() {
        let ds = svm_ds(4);
        let run = |workers: usize| {
            let mut sp = spec(4, 1e-4).with_seed(99);
            sp.workers = workers;
            let (model, res) = svm::solve_sharded(&ds, 1.0, sp);
            (model.alpha, res.iterations, res.ops, res.objective)
        };
        let a = run(1);
        let b = run(4);
        let c = run(4);
        assert_eq!(a, b, "worker count must not change the result");
        assert_eq!(b, c, "same (seed, shards) must be bit-identical");
    }

    #[test]
    fn epoch_objective_is_monotone() {
        let ds = reg_ds(5);
        let mut sp = spec(4, 1e-6);
        sp.config.trace_every = 1; // one point per epoch
        let problem = lasso::ShardedLasso::new(&ds, 0.01);
        let out = lasso::run_prepared(&problem, sp);
        assert!(out.result.status.converged());
        assert!(out.result.trace.points.len() > 1);
        out.result.trace.check_monotone(1e-9).expect("merge must never increase the objective");
    }

    #[test]
    fn hash_partition_parity_with_contiguous() {
        let ds = reg_ds(6);
        let lambda = 0.02;
        let mut sp = spec(4, 1e-6);
        sp.partitioner = Partitioner::Hash;
        let (_, hash) = lasso::solve_sharded(&ds, lambda, sp);
        let (_, cont) = lasso::solve_sharded(&ds, lambda, spec(4, 1e-6));
        assert!(hash.status.converged() && cont.status.converged());
        let rel = (hash.objective - cont.objective).abs() / cont.objective.abs().max(1e-12);
        assert!(rel < 1e-4, "{} vs {}", hash.objective, cont.objective);
    }

    #[test]
    fn outer_probabilities_are_a_distribution() {
        let ds = reg_ds(7);
        let problem = lasso::ShardedLasso::new(&ds, 0.001);
        let mut sp = spec(4, 1e-7);
        sp.config.max_iterations = 200_000;
        let out = lasso::run_prepared(&problem, sp);
        let p = &out.outer_probabilities;
        assert_eq!(p.len(), 4);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn iteration_budget_respected() {
        let ds = svm_ds(8);
        let mut sp = spec(4, 1e-9);
        sp.config.max_iterations = 700;
        let (_, res) = svm::solve_sharded(&ds, 1000.0, sp);
        assert!(res.iterations <= 700, "{} steps", res.iterations);
        assert_eq!(res.status, crate::solvers::SolveStatus::IterLimit);
    }
}
