//! Sharded parallel coordinate descent with **hierarchical (two-level)
//! ACF** — the scaling subsystem layered over the paper's algorithms.
//!
//! The flat ACF scheduler adapts per-coordinate frequencies online
//! (Algorithms 2+3); this subsystem applies the same machinery *twice*:
//!
//! * [`partition`] — splits the coordinate set into S shards
//!   (contiguous ranges or a deterministic hash);
//! * [`engine`] — runs an independent inner coordinate selector inside
//!   every shard on a persistent worker pool (ACF by default;
//!   [`ShardSpec::inner_selector`] plugs in any
//!   [`crate::select::Selector`] policy), merging the shared solver
//!   state either at an epoch barrier or asynchronously (below), while
//!   an **outer** ACF instance adapts how often each shard is visited
//!   from its aggregate progress Δf;
//! * [`lasso`] / [`svm`] / [`logreg`] / [`mcsvm`] — shard-aware solver
//!   front-ends covering all four of the paper's testbeds;
//! * [`hier`] — the single-threaded two-level scheduler exposed as
//!   [`crate::sched::Policy::Hierarchical`] for any serial solver.
//!
//! # What is sharded, per workload
//!
//! | workload | coordinates (sharded over) | block width | shared state |
//! |----------|---------------------------|-------------|--------------|
//! | [`lasso`] | **features** w_j | 1 | residual `r = Xw − y` (dim ℓ) |
//! | [`svm`] | **instances** α_i | 1 | primal `w = Σ α_i y_i x_i` (dim d) |
//! | [`logreg`] | **instances** α_i | 1 | primal `w = Σ α_i y_i x_i` (dim d) |
//! | [`mcsvm`] | **instances** α_{i,·} | K | K per-class primals, flattened K·d |
//!
//! # Per-class shared state (the multi-class merge protocol)
//!
//! The engine's contract generalizes from one value per coordinate to a
//! *block* of [`ShardProblem::coord_width`] values, and from one shared
//! vector to any fixed-size family of them **flattened into a single
//! buffer**: the multi-class SVM owns a K-value dual block α_{i,·} per
//! instance and flattens its K per-class primal vectors w_1..w_K into
//! one K·d buffer. Because that buffer is what the engine snapshots,
//! merges and version-publishes, the K classes move **atomically as one
//! versioned unit** — no reader can see class 0 at version v and class 1
//! at version v+1, and every merge candidate is priced by one exact
//! objective evaluation over all classes at once. Each w_k is linear in
//! the block values, so the flattened buffer satisfies the same
//! linearity contract the scalar problems do, and both merge protocols
//! keep their guarantees unchanged: the asynchronous bounded-staleness
//! delta application stays state-consistent, and the synchronous
//! θ = 1/S fallback stays objective-safe by convexity (a convex
//! combination of feasible per-class blocks is feasible, so the box
//! `[0, C]` survives damped merges).
//!
//! # Merge protocols
//!
//! [`MergeMode::Sync`] (default) is the epoch-synchronized barrier merge:
//! all shards finish their local epoch, deltas are combined in fixed
//! shard order, and the additive merge is kept unless the objective would
//! increase (then the convexity-safe θ = 1/S average is taken).
//!
//! [`MergeMode::Async`] removes the barrier (Wright's asynchronous CD
//! regime): the shared state lives in **versioned published buffers**.
//! A worker snapshots the published buffer with an O(1) `Arc` clone, runs
//! its shard's local epoch against the snapshot, and submits the delta;
//! the merger drains every queued submission, folds the fresh ones into
//! **one batched additive candidate**, evaluates it *exactly* against
//! its authoritative copy (one `shared_objective` call for the whole
//! batch) and publishes the successor buffer with an atomic version
//! flip (retired buffers are recycled once their last reader drops — a
//! generalized double buffer, since a snapshot may be held for a whole
//! local epoch). A submission, **and its Δf report to the outer ACF**,
//! is discarded when its base version lags the published version by
//! more than the staleness bound τ (the `staleness_bound` field of
//! [`MergeMode::Async`], tuned online under `--staleness-bound auto`);
//! within the bound, a rejected batch falls back to per-submission
//! additive → averaged → rejected tiers, each checked exactly.
//!
//! # Guarantees
//!
//! * **Determinism (sync only)** — synchronized results are bit-identical
//!   given `(seed, shard count)`, independent of worker threads or OS
//!   scheduling (see [`engine`]). Asynchronous results are *not*
//!   reproducible across runs: merge order follows thread timing. Use
//!   the default synchronized mode when bit-determinism matters.
//! * **Monotone descent (both modes)** — every published objective value
//!   is exactly evaluated before acceptance, and candidates that would
//!   increase it are damped or rejected; the per-epoch (sync) and
//!   per-version (async) objective sequences are monotone
//!   non-increasing by construction. Under staleness the convexity
//!   argument for θ = 1/S no longer binds, which is why the async merger
//!   re-checks the damped tier instead of trusting it.
//! * **Failure containment** — a panicking shard worker surfaces as
//!   [`crate::util::error::ErrorKind::ShardWorker`] naming the shard,
//!   not as an opaque poisoned-mutex panic.
//!
//! Related work: Wright's *Coordinate Descent Algorithms* survey
//! (arXiv:1502.04759) describes the parallel/asynchronous block-CD
//! design space this subsystem instantiates — the bounded-staleness
//! contract mirrors its consistent-reading assumption; *Coordinate
//! Descent with Bandit Sampling* shows adaptive selection composing with
//! block structure — the outer ACF level is exactly that idea built from
//! the paper's own update rule.

pub mod engine;
pub mod hier;
pub mod lasso;
pub mod logreg;
pub mod mcsvm;
pub mod partition;
pub mod svm;

pub use engine::{
    MergeMode, MergeStats, ShardProblem, ShardSpec, ShardedDriver, ShardedOutcome, StepOutcome,
    DEFAULT_STALENESS_BOUND,
};
pub use hier::{auto_shards, HierarchicalScheduler};
pub use partition::{Partition, Partitioner, PARTITIONER_NAMES};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::sched::CyclicScheduler;
    use crate::solvers::{lasso as serial_lasso, svm as serial_svm, SolverConfig};
    use crate::sparse::Dataset;
    use crate::util::rng::Rng;

    fn reg_ds(seed: u64) -> Dataset {
        synth::regression_sparse("reg", 200, 120, 12, 10, 0.05, &mut Rng::new(seed)).0
    }

    fn svm_ds(seed: u64) -> Dataset {
        synth::sparse_text(
            &synth::SparseTextSpec {
                name: "t",
                n: 300,
                d: 500,
                nnz_per_row: 15,
                zipf_s: 1.0,
                concept_k: 30,
                noise: 0.05,
            },
            &mut Rng::new(seed),
        )
    }

    fn spec(shards: usize, eps: f64) -> ShardSpec {
        ShardSpec::new(shards).with_config(SolverConfig::with_eps(eps))
    }

    #[test]
    fn sharded_lasso_matches_serial_objective() {
        let ds = reg_ds(1);
        let lambda = 0.02;
        let mut cyc = CyclicScheduler::new(ds.n_features());
        let (_, serial) = serial_lasso::solve(&ds, lambda, &mut cyc, SolverConfig::with_eps(1e-6));
        assert!(serial.status.converged());
        for shards in [1, 3, 4] {
            let (model, res) = lasso::solve_sharded(&ds, lambda, spec(shards, 1e-6)).unwrap();
            assert!(res.status.converged(), "S={shards}: {}", res.summary());
            let rel = (serial.objective - res.objective).abs() / serial.objective.abs().max(1e-12);
            assert!(rel < 1e-4, "S={shards}: {} vs {}", serial.objective, res.objective);
            assert_eq!(model.w.len(), ds.n_features());
        }
    }

    #[test]
    fn sharded_svm_matches_serial_objective() {
        let ds = svm_ds(2);
        let c = 1.0;
        let mut perm = crate::sched::PermutationScheduler::new(ds.n_instances(), Rng::new(3));
        let (_, serial) = serial_svm::solve(&ds, c, &mut perm, SolverConfig::with_eps(1e-5));
        assert!(serial.status.converged());
        for shards in [2, 4] {
            let (model, res) = svm::solve_sharded(&ds, c, spec(shards, 1e-5)).unwrap();
            assert!(res.status.converged(), "S={shards}: {}", res.summary());
            let rel = (serial.objective - res.objective).abs() / serial.objective.abs().max(1.0);
            assert!(rel < 1e-4, "S={shards}: {} vs {}", serial.objective, res.objective);
            // box feasibility survives damped merges
            assert!(model.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
        }
    }

    #[test]
    fn sharded_runs_are_deterministic_and_worker_independent() {
        let ds = svm_ds(4);
        let run = |workers: usize| {
            let mut sp = spec(4, 1e-4).with_seed(99);
            sp.workers = workers;
            let (model, res) = svm::solve_sharded(&ds, 1.0, sp).unwrap();
            (model.alpha, res.iterations, res.ops, res.objective)
        };
        let a = run(1);
        let b = run(4);
        let c = run(4);
        assert_eq!(a, b, "worker count must not change the result");
        assert_eq!(b, c, "same (seed, shards) must be bit-identical");
    }

    #[test]
    fn sync_lasso_bit_identical_across_worker_counts() {
        // the determinism contract of the synchronized path across
        // --shard-workers 1/2/4 at fixed (seed, shards)
        let ds = reg_ds(11);
        let run = |workers: usize| {
            let mut sp = spec(4, 1e-6).with_seed(7);
            sp.workers = workers;
            let (model, res) = lasso::solve_sharded(&ds, 0.01, sp).unwrap();
            (model.w, res.objective.to_bits(), res.iterations, res.ops)
        };
        let a = run(1);
        let b = run(2);
        let c = run(4);
        assert_eq!(a, b, "1 vs 2 workers must be bit-identical");
        assert_eq!(b, c, "2 vs 4 workers must be bit-identical");
    }

    #[test]
    fn epoch_objective_is_monotone() {
        let ds = reg_ds(5);
        let mut sp = spec(4, 1e-6);
        sp.config.trace_every = 1; // one point per epoch
        let problem = lasso::ShardedLasso::new(&ds, 0.01);
        let out = lasso::run_prepared(&problem, sp).unwrap();
        assert!(out.result.status.converged());
        assert!(out.result.trace.points.len() > 1);
        out.result.trace.check_monotone(1e-9).expect("merge must never increase the objective");
    }

    #[test]
    fn async_objective_is_monotone_across_published_versions() {
        let ds = reg_ds(5);
        let mut sp = spec(4, 1e-6).with_async(2);
        sp.config.trace_every = 1; // one point per published version
        let problem = lasso::ShardedLasso::new(&ds, 0.01);
        let out = lasso::run_prepared(&problem, sp).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        assert!(out.result.trace.points.len() > 1);
        out.result
            .trace
            .check_monotone(1e-9)
            .expect("async merge must never publish an objective increase");
        // solution quality parity with the synchronized path
        let sync = lasso::run_prepared(&problem, spec(4, 1e-6)).unwrap();
        let rel = (sync.result.objective - out.result.objective).abs()
            / sync.result.objective.abs().max(1e-12);
        assert!(rel < 1e-3, "async {} vs sync {}", out.result.objective, sync.result.objective);
    }

    #[test]
    fn async_svm_feasible_and_matches_sync_objective() {
        let ds = svm_ds(2);
        let c = 1.0;
        let (sync_model, sync_res) = svm::solve_sharded(&ds, c, spec(4, 1e-5)).unwrap();
        let (model, res) = svm::solve_sharded(&ds, c, spec(4, 1e-5).with_async(2)).unwrap();
        assert!(sync_res.status.converged() && res.status.converged(), "{}", res.summary());
        assert!(model.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
        let rel = (sync_res.objective - res.objective).abs() / sync_res.objective.abs().max(1.0);
        assert!(rel < 1e-3, "async {} vs sync {}", res.objective, sync_res.objective);
        assert_eq!(sync_model.alpha.len(), model.alpha.len());
    }

    #[test]
    fn async_tight_staleness_bound_still_converges() {
        // τ = 1 discards most overlapping work under contention but must
        // stay correct
        let ds = reg_ds(6);
        let (_, res) = lasso::solve_sharded(&ds, 0.02, spec(3, 1e-6).with_async(1)).unwrap();
        assert!(res.status.converged(), "{}", res.summary());
    }

    #[test]
    fn async_iteration_budget_respected() {
        let ds = svm_ds(8);
        let mut sp = spec(4, 1e-9).with_async(2);
        sp.config.max_iterations = 700;
        let (_, res) = svm::solve_sharded(&ds, 1000.0, sp).unwrap();
        assert!(res.iterations <= 700, "{} steps", res.iterations);
        assert_eq!(res.status, crate::solvers::SolveStatus::IterLimit);
    }

    fn logreg_ds(seed: u64) -> Dataset {
        synth::sparse_text(
            &synth::SparseTextSpec {
                name: "lr",
                n: 250,
                d: 400,
                nnz_per_row: 12,
                zipf_s: 1.0,
                concept_k: 25,
                noise: 0.05,
            },
            &mut Rng::new(seed),
        )
    }

    fn mcsvm_ds(seed: u64) -> Dataset {
        synth::multiclass_text("mc", 180, 300, 4, 10, 0.02, &mut Rng::new(seed))
    }

    #[test]
    fn sharded_logreg_matches_serial_objective() {
        let ds = logreg_ds(21);
        let c = 1.0;
        let mut perm = crate::sched::PermutationScheduler::new(ds.n_instances(), Rng::new(21));
        let (_, serial) =
            crate::solvers::logreg::solve(&ds, c, &mut perm, SolverConfig::with_eps(1e-5));
        assert!(serial.status.converged());
        for shards in [1, 3, 4] {
            let (model, res) = logreg::solve_sharded(&ds, c, spec(shards, 1e-5)).unwrap();
            assert!(res.status.converged(), "S={shards}: {}", res.summary());
            let rel = (serial.objective - res.objective).abs() / serial.objective.abs().max(1.0);
            assert!(rel < 1e-4, "S={shards}: {} vs {}", serial.objective, res.objective);
            // the dual solution stays strictly interior through merges
            assert!(model.alpha.iter().all(|&a| a > 0.0 && a < c));
            assert_eq!(model.w.len(), ds.n_features());
        }
    }

    #[test]
    fn sharded_mcsvm_matches_serial_objective() {
        let ds = mcsvm_ds(22);
        let c = 1.0;
        let eps = 1e-5;
        let mut perm = crate::sched::PermutationScheduler::new(ds.n_instances(), Rng::new(22));
        let (_, serial) =
            crate::solvers::mcsvm::solve(&ds, c, &mut perm, SolverConfig::with_eps(eps)).unwrap();
        assert!(serial.status.converged());
        for shards in [2, 4] {
            let (model, res) = mcsvm::solve_sharded(&ds, c, spec(shards, eps)).unwrap();
            assert!(res.status.converged(), "S={shards}: {}", res.summary());
            let rel = (serial.objective - res.objective).abs() / serial.objective.abs().max(1.0);
            assert!(rel < 1e-4, "S={shards}: {} vs {}", serial.objective, res.objective);
            // per-class box feasibility survives damped merges
            assert!(model.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
            assert_eq!(model.w.len(), model.k_classes);
        }
    }

    #[test]
    fn sync_logreg_bit_identical_across_worker_counts() {
        let ds = logreg_ds(23);
        let run = |workers: usize| {
            let mut sp = spec(4, 1e-5).with_seed(17);
            sp.workers = workers;
            let (model, res) = logreg::solve_sharded(&ds, 1.0, sp).unwrap();
            (model.alpha, res.objective.to_bits(), res.iterations, res.ops)
        };
        let a = run(1);
        let b = run(2);
        let c = run(4);
        assert_eq!(a, b, "1 vs 2 workers must be bit-identical");
        assert_eq!(b, c, "2 vs 4 workers must be bit-identical");
    }

    #[test]
    fn sync_mcsvm_bit_identical_across_worker_counts() {
        let ds = mcsvm_ds(24);
        let run = |workers: usize| {
            let mut sp = spec(4, 1e-3).with_seed(18);
            sp.workers = workers;
            let (model, res) = mcsvm::solve_sharded(&ds, 1.0, sp).unwrap();
            (model.alpha, res.objective.to_bits(), res.iterations, res.ops)
        };
        let a = run(1);
        let b = run(2);
        let c = run(4);
        assert_eq!(a, b, "1 vs 2 workers must be bit-identical");
        assert_eq!(b, c, "2 vs 4 workers must be bit-identical");
    }

    #[test]
    fn async_logreg_objective_monotone_and_matches_sync() {
        let ds = logreg_ds(25);
        let problem = logreg::ShardedLogReg::new(&ds, 1.0);
        let mut sp = spec(4, 1e-5).with_async(2);
        sp.config.trace_every = 1; // one point per published version
        let out = logreg::run_prepared(&problem, sp).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        out.result
            .trace
            .check_monotone(1e-9)
            .expect("async merge must never publish an objective increase");
        let sync = logreg::run_prepared(&problem, spec(4, 1e-5)).unwrap();
        let rel = (sync.result.objective - out.result.objective).abs()
            / sync.result.objective.abs().max(1.0);
        assert!(rel < 1e-3, "async {} vs sync {}", out.result.objective, sync.result.objective);
    }

    #[test]
    fn async_mcsvm_monotone_feasible_and_matches_sync() {
        let ds = mcsvm_ds(26);
        let c = 1.0;
        let eps = 1e-3;
        let problem = mcsvm::ShardedMcSvm::new(&ds, c, eps).unwrap();
        let mut sp = spec(4, eps).with_async(2);
        sp.config.trace_every = 1;
        let out = mcsvm::run_prepared(&problem, sp).unwrap();
        assert!(out.result.status.converged(), "{}", out.result.summary());
        out.result
            .trace
            .check_monotone(1e-9)
            .expect("per-class merges must publish one monotone versioned unit");
        // per-class box feasibility after damped merges
        assert!(out.values.iter().all(|&a| (0.0..=c).contains(&a)));
        let sync = mcsvm::run_prepared(&problem, spec(4, eps)).unwrap();
        let rel = (sync.result.objective - out.result.objective).abs()
            / sync.result.objective.abs().max(1.0);
        assert!(rel < 1e-3, "async {} vs sync {}", out.result.objective, sync.result.objective);
    }

    #[test]
    fn new_shard_problems_accept_swapped_inner_selectors() {
        // ShardSpec::inner_selector pluggability extends to the new
        // front-ends: a non-ACF inner policy still reaches the serial
        // fixed point (the outer shard-level ACF is untouched)
        use crate::select::SelectorKind;
        let ds = logreg_ds(27);
        let (_, acf) = logreg::solve_sharded(&ds, 1.0, spec(3, 1e-5)).unwrap();
        let (_, cyc) = logreg::solve_sharded(
            &ds,
            1.0,
            spec(3, 1e-5).with_inner_selector(SelectorKind::Cyclic),
        )
        .unwrap();
        assert!(acf.status.converged() && cyc.status.converged());
        let rel = (acf.objective - cyc.objective).abs() / acf.objective.abs().max(1.0);
        assert!(rel < 1e-4, "{} vs {}", acf.objective, cyc.objective);

        let ds = mcsvm_ds(28);
        let (_, ban) = mcsvm::solve_sharded(
            &ds,
            1.0,
            spec(2, 1e-3).with_inner_selector(SelectorKind::Bandit),
        )
        .unwrap();
        assert!(ban.status.converged(), "{}", ban.summary());
    }

    #[test]
    fn sharded_mcsvm_rejects_pm1_labels() {
        // the shard front-end validates at construction — the same
        // first-party error as the serial path, before any thread spawns
        let ds = svm_ds(2); // ±1-labeled binary fixture
        let err = mcsvm::solve_sharded(&ds, 1.0, spec(2, 1e-3)).unwrap_err();
        assert!(format!("{err:#}").contains("-1"), "{err:#}");
    }

    #[test]
    fn hash_partition_parity_with_contiguous() {
        let ds = reg_ds(6);
        let lambda = 0.02;
        let mut sp = spec(4, 1e-6);
        sp.partitioner = Partitioner::Hash;
        let (_, hash) = lasso::solve_sharded(&ds, lambda, sp).unwrap();
        let (_, cont) = lasso::solve_sharded(&ds, lambda, spec(4, 1e-6)).unwrap();
        assert!(hash.status.converged() && cont.status.converged());
        let rel = (hash.objective - cont.objective).abs() / cont.objective.abs().max(1e-12);
        assert!(rel < 1e-4, "{} vs {}", hash.objective, cont.objective);
    }

    #[test]
    fn outer_probabilities_are_a_distribution() {
        let ds = reg_ds(7);
        let problem = lasso::ShardedLasso::new(&ds, 0.001);
        let mut sp = spec(4, 1e-7);
        sp.config.max_iterations = 200_000;
        let out = lasso::run_prepared(&problem, sp).unwrap();
        let p = &out.outer_probabilities;
        assert_eq!(p.len(), 4);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn iteration_budget_respected() {
        let ds = svm_ds(8);
        let mut sp = spec(4, 1e-9);
        sp.config.max_iterations = 700;
        let (_, res) = svm::solve_sharded(&ds, 1000.0, sp).unwrap();
        assert!(res.iterations <= 700, "{} steps", res.iterations);
        assert_eq!(res.status, crate::solvers::SolveStatus::IterLimit);
    }
}
