//! Coordinate partitioning — how the coordinate set `0..n` is split into
//! S disjoint shards.
//!
//! Two strategies:
//!
//! * [`Partitioner::Contiguous`] — balanced index ranges. Preserves any
//!   locality in the coordinate ordering (feature blocks, class-grouped
//!   instances) and gives perfectly even shard sizes.
//! * [`Partitioner::Hash`] — deterministic SplitMix64 hash of the
//!   coordinate id. Breaks up correlated neighborhoods so each shard sees
//!   a statistically similar slice of the problem (useful when contiguous
//!   blocks would concentrate all the hard coordinates in one shard).
//!
//! Both are pure functions of `(n, shards)` — no RNG state — so sharded
//! runs stay deterministic given `(seed, shard count)`.

use crate::util::rng::SplitMix64;

/// Partitioning strategy selector (CLI: `--partitioner contiguous|hash`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioner {
    /// Balanced contiguous index ranges.
    Contiguous,
    /// Deterministic hash of the coordinate id.
    Hash,
}

/// Valid partitioner names, kept in sync with [`Partitioner::parse`].
pub const PARTITIONER_NAMES: &str = "contiguous, hash";

impl Partitioner {
    /// Case-insensitive name lookup with an actionable error message.
    pub fn parse(s: &str) -> Result<Partitioner, String> {
        match s.to_ascii_lowercase().as_str() {
            "contiguous" | "range" => Ok(Partitioner::Contiguous),
            "hash" | "hashed" => Ok(Partitioner::Hash),
            other => Err(format!("unknown partitioner '{other}' (valid: {PARTITIONER_NAMES})")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Partitioner::Contiguous => "contiguous",
            Partitioner::Hash => "hash",
        }
    }
}

/// A disjoint, exhaustive split of `0..n` into shards, with O(1) lookup
/// of both the owning shard and the position within it.
#[derive(Clone, Debug)]
pub struct Partition {
    shards: Vec<Vec<u32>>,
    shard_of: Vec<u32>,
    local_of: Vec<u32>,
}

impl Partition {
    /// Split `0..n` into (at most) `shards` non-empty shards. `shards` is
    /// clamped to `n` so every shard owns at least one coordinate.
    pub fn new(n: usize, shards: usize, strategy: Partitioner) -> Partition {
        assert!(n > 0, "cannot partition an empty coordinate set");
        assert!(shards > 0, "need at least one shard");
        let s = shards.min(n);
        let mut buckets: Vec<Vec<u32>> = (0..s).map(|_| Vec::with_capacity(n / s + 1)).collect();
        match strategy {
            Partitioner::Contiguous => {
                let base = n / s;
                let rem = n % s;
                let mut next = 0u32;
                for (k, bucket) in buckets.iter_mut().enumerate() {
                    let size = base + usize::from(k < rem);
                    bucket.extend(next..next + size as u32);
                    next += size as u32;
                }
            }
            Partitioner::Hash => {
                for i in 0..n {
                    // One SplitMix64 step per id: a high-quality, stateless
                    // mix that spreads consecutive ids across shards.
                    let h = SplitMix64::new(i as u64).next_u64();
                    buckets[(h % s as u64) as usize].push(i as u32);
                }
                // Hashing can leave a shard empty when n is barely above
                // s; repair deterministically by stealing from the
                // largest shard.
                loop {
                    let Some(empty) = buckets.iter().position(|b| b.is_empty()) else { break };
                    // INFALLIBLE: s >= 1 so the range is non-empty, and
                    // because s = min(shards, n) <= n the largest of the s
                    // buckets holds >= ceil(n/s) >= 1 items whenever some
                    // other bucket is empty.
                    let donor = (0..s).max_by_key(|&k| buckets[k].len()).unwrap();
                    let moved = buckets[donor].pop().unwrap(); // INFALLIBLE: donor is the largest bucket
                    buckets[empty].push(moved);
                }
            }
        }
        let mut shard_of = vec![0u32; n];
        let mut local_of = vec![0u32; n];
        for (k, bucket) in buckets.iter().enumerate() {
            for (pos, &i) in bucket.iter().enumerate() {
                shard_of[i as usize] = k as u32;
                local_of[i as usize] = pos as u32;
            }
        }
        Partition { shards: buckets, shard_of, local_of }
    }

    /// Number of shards (≥ 1, ≤ n).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total number of coordinates.
    pub fn n(&self) -> usize {
        self.shard_of.len()
    }

    /// Global coordinate ids owned by shard `s`.
    pub fn shard(&self, s: usize) -> &[u32] {
        &self.shards[s]
    }

    /// Owning shard of global coordinate `i`.
    #[inline]
    pub fn shard_of(&self, i: usize) -> usize {
        self.shard_of[i] as usize
    }

    /// Position of global coordinate `i` within its owning shard.
    #[inline]
    pub fn local_of(&self, i: usize) -> usize {
        self.local_of[i] as usize
    }

    /// Structural invariants (property tests): disjoint, exhaustive,
    /// non-empty shards with consistent reverse maps.
    pub fn check_invariants(&self) -> Result<(), String> {
        let n = self.n();
        let mut seen = vec![false; n];
        for (k, bucket) in self.shards.iter().enumerate() {
            if bucket.is_empty() {
                return Err(format!("shard {k} is empty"));
            }
            for (pos, &i) in bucket.iter().enumerate() {
                let i = i as usize;
                if i >= n {
                    return Err(format!("shard {k} holds out-of-range id {i}"));
                }
                if seen[i] {
                    return Err(format!("coordinate {i} assigned twice"));
                }
                seen[i] = true;
                if self.shard_of(i) != k || self.local_of(i) != pos {
                    return Err(format!("reverse map inconsistent for coordinate {i}"));
                }
            }
        }
        if !seen.iter().all(|&b| b) {
            return Err("partition is not exhaustive".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn parse_is_case_insensitive_with_good_errors() {
        assert_eq!(Partitioner::parse("Contiguous").unwrap(), Partitioner::Contiguous);
        assert_eq!(Partitioner::parse("HASH").unwrap(), Partitioner::Hash);
        let e = Partitioner::parse("modulo").unwrap_err();
        assert!(e.contains("contiguous") && e.contains("hash"), "{e}");
    }

    #[test]
    fn contiguous_is_balanced_and_ordered() {
        let p = Partition::new(10, 3, Partitioner::Contiguous);
        assert_eq!(p.shard(0), &[0, 1, 2, 3]);
        assert_eq!(p.shard(1), &[4, 5, 6]);
        assert_eq!(p.shard(2), &[7, 8, 9]);
        p.check_invariants().unwrap();
    }

    #[test]
    fn shards_clamped_to_n() {
        let p = Partition::new(3, 8, Partitioner::Contiguous);
        assert_eq!(p.n_shards(), 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn hash_partition_is_deterministic() {
        let a = Partition::new(1000, 7, Partitioner::Hash);
        let b = Partition::new(1000, 7, Partitioner::Hash);
        for s in 0..7 {
            assert_eq!(a.shard(s), b.shard(s));
        }
    }

    #[test]
    fn hash_partition_spreads_reasonably() {
        let p = Partition::new(10_000, 8, Partitioner::Hash);
        for s in 0..8 {
            let size = p.shard(s).len();
            assert!((1000..1600).contains(&size), "shard {s} has {size} coordinates");
        }
        p.check_invariants().unwrap();
    }

    #[test]
    fn property_invariants_hold() {
        prop::check(60, |g| {
            let n = g.usize_in(1, 300);
            let s = g.usize_in(1, 16);
            let strategy = *g.choose(&[Partitioner::Contiguous, Partitioner::Hash]);
            let p = Partition::new(n, s, strategy);
            prop::assert_holds(p.n_shards() == s.min(n), "shard count clamped")?;
            p.check_invariants()
        });
    }
}
