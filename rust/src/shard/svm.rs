//! Sharded linear SVM dual: instances are the coordinates, the primal
//! vector `w = Σ α_i y_i x_i` is the shared state. The per-step math is
//! identical to [`crate::solvers::svm`]; this module only adapts it to
//! the [`ShardProblem`] contract. The averaged-merge fallback keeps α
//! inside the box `[0, C]` automatically (a convex combination of
//! feasible points).
//!
//! The per-shard inner loops run any
//! [`crate::select::Selector`] policy — set
//! [`ShardSpec::inner_selector`] (CLI `--selector`) to face off ACF
//! against bandit / importance sampling inside the parallel engine; the
//! outer shard-level ACF is unaffected.

use crate::shard::engine::{ShardProblem, ShardSpec, ShardedDriver, ShardedOutcome, StepOutcome};
use crate::solvers::svm::{pg_violation, SvmModel};
use crate::solvers::SolveResult;
use crate::sparse::Dataset;
use crate::util::error::Result;

/// SVM dual adapted to the sharded engine.
pub struct ShardedSvm<'a> {
    ds: &'a Dataset,
    /// borrowed from the matrix-level norm cache (computed once per Csr)
    q_diag: &'a [f64],
    c: f64,
}

impl<'a> ShardedSvm<'a> {
    pub fn new(ds: &'a Dataset, c: f64) -> ShardedSvm<'a> {
        ShardedSvm { ds, q_diag: ds.x.row_norms_sq(), c }
    }

    pub fn c(&self) -> f64 {
        self.c
    }
}

impl ShardProblem for ShardedSvm<'_> {
    fn n_coords(&self) -> usize {
        self.ds.n_instances()
    }

    fn shared_dim(&self) -> usize {
        self.ds.n_features()
    }

    fn initial_shared(&self) -> Vec<f64> {
        vec![0.0; self.ds.n_features()]
    }

    #[inline]
    fn step(&self, i: usize, values: &mut [f64], shared: &mut [f64]) -> StepOutcome {
        let row = self.ds.x.row(i);
        let yi = self.ds.y[i];
        let qii = self.q_diag[i];
        let old = values[0];
        // fused kernel, same update as the serial solver
        let mut g = 0.0;
        let mut new = old;
        row.step(shared, |dot| {
            g = yi * dot - 1.0;
            new = if qii > 0.0 {
                (old - g / qii).clamp(0.0, self.c)
            } else if g < 0.0 {
                // empty row: the linear term −α_i drives α_i to the bound
                self.c
            } else {
                0.0
            };
            (new - old) * yi
        });
        let violation = pg_violation(old, g, self.c);
        let d = new - old;
        let mut ops = row.nnz();
        let mut delta_f = 0.0;
        if d != 0.0 {
            values[0] = new;
            ops += row.nnz();
            // exact decrease of the dual objective along this coordinate
            delta_f = -(g * d + 0.5 * qii * d * d);
        }
        StepOutcome { delta_f, violation, ops }
    }

    fn violation(&self, i: usize, values: &[f64], shared: &[f64]) -> (f64, usize) {
        let row = self.ds.x.row(i);
        let g = self.ds.y[i] * row.dot_dense(shared) - 1.0;
        (pg_violation(values[0], g, self.c), row.nnz())
    }

    #[inline]
    fn prefetch_coord(&self, i: usize) {
        let row = self.ds.x.row(i);
        crate::sparse::kernels::prefetch_row(row.indices(), row.values());
    }

    fn shared_objective(&self, shared: &[f64]) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(shared)
    }

    #[inline]
    fn coord_objective(&self, _i: usize, values: &[f64]) -> f64 {
        -values[0]
    }

    fn shard_extent(&self, ids: &[u32]) -> Option<(u64, u64)> {
        Some(self.ds.x.rows_extent(ids))
    }
}

/// Solve the SVM dual on the sharded engine; drop-in analog of
/// [`crate::solvers::svm::solve`]. Errs with
/// [`crate::util::error::ErrorKind::ShardWorker`] if a shard worker dies.
pub fn solve_sharded(ds: &Dataset, c: f64, spec: ShardSpec) -> Result<(SvmModel, SolveResult)> {
    let problem = ShardedSvm::new(ds, c);
    let out = run_prepared(&problem, spec)?;
    Ok((SvmModel { alpha: out.values, w: out.shared, c }, out.result))
}

/// Run on an already-prepared problem.
pub fn run_prepared(problem: &ShardedSvm<'_>, spec: ShardSpec) -> Result<ShardedOutcome> {
    ShardedDriver::new(problem, spec).run()
}
