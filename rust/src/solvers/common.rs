//! Shared solver configuration, result types and stopping criteria.
//!
//! All four solvers follow the paper's experimental protocol (§7):
//!
//! * stop when the maximum KKT violation (or gradient-infinity norm for
//!   unconstrained problems) drops below ε,
//! * count *iterations* (CD steps) and *operations* (multiply-adds in
//!   derivative computations — the implementation-independent metric),
//! * report wall-clock seconds,
//! * expose the single-step progress `Δf` to the scheduler as a cheap
//!   by-product of each step.

use crate::metrics::{OpCounter, Trace, TracePoint};
use crate::obs::live::{LiveMetrics, LiveRecorder};
use crate::obs::{self, Event, Obs};
use crate::util::timer::Timer;
use std::sync::Arc;

/// Why a solver run terminated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveStatus {
    /// KKT / gradient criterion met (max violation < ε).
    Converged,
    /// Iteration budget exhausted — reported as "—" (DNF) in the paper's
    /// style for runs that did not finish.
    IterLimit,
    /// Wall-clock budget exhausted.
    TimeLimit,
}

impl SolveStatus {
    pub fn converged(&self) -> bool {
        matches!(self, SolveStatus::Converged)
    }
}

/// Common solver knobs.
#[derive(Clone, Debug)]
pub struct SolverConfig {
    /// stopping threshold on the max KKT violation (paper: 0.01 / 0.001)
    pub eps: f64,
    /// hard cap on CD iterations (DNF guard; the paper's huge runs are
    /// capped the same way at our reduced scale)
    pub max_iterations: u64,
    /// optional wall-clock cap in seconds
    pub max_seconds: Option<f64>,
    /// record a convergence trace point every `trace_every` iterations
    /// (0 = no tracing)
    pub trace_every: u64,
    /// observability collector for serial solvers (`None` — the default
    /// — records nothing; serial runs use ring 0). Only the epoch-level
    /// [`Event::Objective`] records flow through this; per-step state is
    /// far too hot to trace.
    pub obs: Option<Arc<Obs>>,
    /// live telemetry registry ([`crate::obs::live`]); `None` constructs
    /// no recorder. Publishing happens at epoch boundaries only and
    /// reads solver state, never mutates it.
    pub live: Option<Arc<LiveMetrics>>,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            eps: 0.01,
            max_iterations: 200_000_000,
            max_seconds: None,
            trace_every: 0,
            obs: None,
            live: None,
        }
    }
}

impl SolverConfig {
    pub fn with_eps(eps: f64) -> Self {
        Self { eps, ..Default::default() }
    }
}

/// Outcome of a solver run.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub status: SolveStatus,
    /// CD iterations performed (inner steps for subspace descent count
    /// as the paper counts them: one iteration = one dual variable
    /// update).
    pub iterations: u64,
    /// multiply-add operations in derivative computations
    pub ops: u64,
    pub seconds: f64,
    /// final objective value
    pub objective: f64,
    /// final max KKT violation seen in the verification pass
    pub final_violation: f64,
    /// number of full passes (epochs / blocks) executed
    pub epochs: u64,
    pub trace: Trace,
}

impl SolveResult {
    pub fn summary(&self) -> String {
        format!(
            "{:?}: iters {}, ops {}, {:.3}s, obj {:.6e}, viol {:.3e}",
            self.status,
            self.iterations,
            self.ops,
            self.seconds,
            self.objective,
            self.final_violation
        )
    }
}

/// Epoch-boundary observability hook for the serial solvers: emits
/// [`Event::Objective`] records (spans level) and feeds the live
/// telemetry registry. Constructed from the [`SolverConfig`] *before*
/// [`RunState::new`] consumes it; does nothing (and computes nothing)
/// when neither plane is attached.
pub struct EpochObs {
    obs: Option<Arc<Obs>>,
    live: Option<LiveRecorder>,
}

impl EpochObs {
    pub fn new(config: &SolverConfig) -> EpochObs {
        EpochObs {
            obs: config.obs.clone(),
            live: config.live.as_ref().map(|l| LiveRecorder::new(Arc::clone(l), 0)),
        }
    }

    /// Record the end of epoch `epoch`. `objective` is evaluated at most
    /// once, and only when a plane that consumes it is attached — the
    /// untraced path pays two `None` checks.
    pub fn epoch(&mut self, epoch: u64, objective: impl FnOnce() -> f64) {
        let em = obs::emitter(self.obs.as_deref(), 0);
        let spans = em.spans();
        if !spans && self.live.is_none() {
            return;
        }
        let f = objective();
        if spans {
            em.emit(Event::Objective {
                t: em.now(),
                shard: obs::NO_SHARD,
                epoch,
                objective: f,
            });
        }
        if let Some(lr) = self.live.as_mut() {
            lr.objective(f);
            lr.flush();
        }
    }
}

/// Book-keeping helper shared by the solver loops: iteration/ops
/// counting, wall-clock budget, trace sampling.
pub struct RunState {
    pub counter: OpCounter,
    pub timer: Timer,
    pub trace: Trace,
    config: SolverConfig,
}

impl RunState {
    pub fn new(config: SolverConfig) -> Self {
        Self { counter: OpCounter::new(), timer: Timer::start(), trace: Trace::new(), config }
    }

    #[inline]
    pub fn eps(&self) -> f64 {
        self.config.eps
    }

    /// Record one CD step of `ops` multiply-adds; returns false when a
    /// budget is exhausted.
    #[inline]
    pub fn step(&mut self, ops: usize) -> bool {
        self.counter.step(ops);
        self.counter.iterations() < self.config.max_iterations
    }

    #[inline]
    pub fn over_time(&self) -> bool {
        match self.config.max_seconds {
            Some(cap) => self.timer.secs() > cap,
            None => false,
        }
    }

    /// Sample a trace point if due.
    #[inline]
    pub fn maybe_trace(&mut self, objective: impl FnOnce() -> f64, violation: f64) {
        let every = self.config.trace_every;
        if every > 0 && self.counter.iterations() % every == 0 {
            self.trace.push(TracePoint {
                iteration: self.counter.iterations(),
                ops: self.counter.ops(),
                seconds: self.timer.secs(),
                objective: objective(),
                violation,
            });
        }
    }

    pub fn finish(
        self,
        status: SolveStatus,
        objective: f64,
        final_violation: f64,
        epochs: u64,
    ) -> SolveResult {
        SolveResult {
            status,
            iterations: self.counter.iterations(),
            ops: self.counter.ops(),
            seconds: self.timer.secs(),
            objective,
            final_violation,
            epochs,
            trace: self.trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_state_budgets() {
        let cfg = SolverConfig { max_iterations: 3, ..Default::default() };
        let mut rs = RunState::new(cfg);
        assert!(rs.step(10));
        assert!(rs.step(10));
        assert!(!rs.step(10)); // 3rd iteration hits the cap
        let r = rs.finish(SolveStatus::IterLimit, 1.0, 0.5, 1);
        assert_eq!(r.iterations, 3);
        assert_eq!(r.ops, 30);
        assert!(!r.status.converged());
    }

    #[test]
    fn tracing_samples_at_interval() {
        let cfg = SolverConfig { trace_every: 2, ..Default::default() };
        let mut rs = RunState::new(cfg);
        for _ in 0..6 {
            rs.step(1);
            rs.maybe_trace(|| 1.0, 0.1);
        }
        assert_eq!(rs.trace.points.len(), 3);
    }

    #[test]
    fn time_budget() {
        let cfg = SolverConfig { max_seconds: Some(0.0), ..Default::default() };
        let rs = RunState::new(cfg);
        std::thread::sleep(std::time::Duration::from_millis(1));
        assert!(rs.over_time());
    }
}
