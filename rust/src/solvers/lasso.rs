//! Coordinate descent for the LASSO (Friedman et al., 2007) — the
//! paper's §3.1 testbed (Table 3).
//!
//! Problem (1) with p = 1 and squared loss:
//!
//! ```text
//! min_w  f(w) = λ‖w‖₁ + (1/2ℓ) Σ_i (⟨w,x_i⟩ − y_i)²
//! ```
//!
//! Coordinates are *features*. With the residual `r = Xw − y` maintained
//! incrementally, the partial derivative of the smooth part is
//! `g_j = (1/ℓ)⟨x_{·j}, r⟩` (cost O(nnz of column j)) and the exact
//! one-dimensional minimizer is the soft-thresholded Newton step
//!
//! ```text
//! w_j ← S( w_j − g_j/h_j , λ/h_j ),   h_j = (1/ℓ)‖x_{·j}‖²
//! ```
//!
//! The exact progress `Δf` is again an O(1) by-product. The baseline of
//! Table 3 is plain cyclic CD ("iterating over all coordinates in
//! order"); ACF replaces the cyclic rule.

use super::common::{EpochObs, RunState, SolveResult, SolveStatus, SolverConfig};
use crate::select::Selector;
use crate::sparse::ops::soft_threshold;
use crate::sparse::{Csr, Dataset};

/// Trained LASSO model.
#[derive(Clone, Debug)]
pub struct LassoModel {
    pub w: Vec<f64>,
    pub lambda: f64,
}

/// Precomputed column-major problem view (the design matrix transposed so
/// a coordinate step touches one contiguous sparse row).
pub struct LassoProblem {
    /// ℓ (instances)
    pub n_instances: usize,
    /// d (features = coordinates)
    pub n_features: usize,
    /// Xᵀ in CSR layout: row j = column j of X
    pub xt: Csr,
    /// targets
    pub y: Vec<f64>,
    /// h_j = (1/ℓ)‖x_{·j}‖²
    pub h: Vec<f64>,
}

impl LassoProblem {
    pub fn new(ds: &Dataset) -> Self {
        let xt = ds.x.transpose();
        let l = ds.n_instances();
        // borrows the matrix-level norm cache (also warms it for anyone
        // else holding this xt)
        let h = xt.row_norms_sq().iter().map(|&n| n / l as f64).collect();
        Self { n_instances: l, n_features: xt.rows(), xt, y: ds.y.clone(), h }
    }

    /// Full objective value λ‖w‖₁ + (1/2ℓ)‖r‖² given w and the residual
    /// r = Xw − y.
    pub fn objective(&self, lambda: f64, w: &[f64], r: &[f64]) -> f64 {
        lambda * w.iter().map(|v| v.abs()).sum::<f64>()
            + r.iter().map(|v| v * v).sum::<f64>() / (2.0 * self.n_instances as f64)
    }
}

/// Subgradient violation of coordinate j: distance of 0 from the
/// subdifferential of f restricted to w_j (shared with the sharded
/// engine in [`crate::shard`]).
#[inline]
pub(crate) fn subgrad_violation(w_j: f64, g: f64, lambda: f64) -> f64 {
    if w_j > 0.0 {
        (g + lambda).abs()
    } else if w_j < 0.0 {
        (g - lambda).abs()
    } else {
        (g.abs() - lambda).max(0.0)
    }
}

/// Solve the LASSO with a generic coordinate selector.
pub fn solve(
    ds: &Dataset,
    lambda: f64,
    sched: &mut dyn Selector,
    config: SolverConfig,
) -> (LassoModel, SolveResult) {
    let prob = LassoProblem::new(ds);
    solve_prepared(&prob, lambda, sched, config)
}

/// Solve with a pre-transposed problem (lets benches amortize the
/// transpose across the λ grid).
pub fn solve_prepared(
    prob: &LassoProblem,
    lambda: f64,
    sched: &mut dyn Selector,
    config: SolverConfig,
) -> (LassoModel, SolveResult) {
    let d = prob.n_features;
    let l = prob.n_instances as f64;
    assert_eq!(sched.n(), d, "selector size must match feature count");
    let mut w = vec![0.0f64; d];
    // residual r = Xw − y = −y at w = 0
    let mut r: Vec<f64> = prob.y.iter().map(|&v| -v).collect();
    let mut eo = EpochObs::new(&config);
    let mut rs = RunState::new(config);
    let mut status = SolveStatus::IterLimit;
    let mut window_max = 0.0f64;
    let mut window_count = 0usize;
    let mut epochs = 0u64;
    let mut final_viol = f64::INFINITY;

    let objective = |w: &[f64], r: &[f64]| -> f64 {
        lambda * w.iter().map(|v| v.abs()).sum::<f64>()
            + r.iter().map(|v| v * v).sum::<f64>() / (2.0 * l)
    };

    'outer: loop {
        let j = sched.next();
        let col = prob.xt.row(j);
        let h = prob.h[j];
        let old = w[j];
        // fused kernel: gradient dot + soft-threshold step + residual
        // scatter on the same hot column slices
        // NOTE: keep in sync with `crate::shard::lasso::ShardedLasso::step`,
        // which carries the same update for the sharded engine
        let mut g = 0.0;
        let mut new = old;
        let (_, step_d) = col.step(&mut r, |dot| {
            g = dot / l;
            if h > 0.0 {
                new = soft_threshold(old - g / h, lambda / h);
            }
            new - old
        });
        let viol = subgrad_violation(old, g, lambda);
        window_max = window_max.max(viol);
        window_count += 1;

        let mut ops = col.nnz();
        let mut delta_f = 0.0;
        if step_d != 0.0 {
            w[j] = new;
            ops += col.nnz();
            // exact decrease: smooth part g·d + ½h·d², plus the ℓ1
            // term change
            delta_f = -(g * step_d + 0.5 * h * step_d * step_d) - lambda * (new.abs() - old.abs());
        }
        sched.report(j, delta_f.max(0.0));

        let budget_ok = rs.step(ops);
        rs.maybe_trace(|| objective(&w, &r), viol);
        if !budget_ok || rs.over_time() {
            if rs.over_time() {
                status = SolveStatus::TimeLimit;
            }
            let (v, extra) = verify(prob, lambda, &w, &r);
            rs.counter.extra(extra);
            final_viol = v;
            break 'outer;
        }

        if window_count >= d {
            epochs += 1;
            eo.epoch(epochs, || objective(&w, &r));
            if window_max < rs.eps() {
                let (v, extra) = verify(prob, lambda, &w, &r);
                rs.counter.extra(extra);
                if v < rs.eps() {
                    status = SolveStatus::Converged;
                    final_viol = v;
                    break 'outer;
                }
            }
            window_max = 0.0;
            window_count = 0;
        }
    }

    let obj = objective(&w, &r);
    (LassoModel { w, lambda }, rs.finish(status, obj, final_viol, epochs))
}

/// Full subgradient-violation pass. Software-pipelined: column `j + 1`'s
/// slices are prefetched while column `j`'s gather-dot reduces.
fn verify(prob: &LassoProblem, lambda: f64, w: &[f64], r: &[f64]) -> (f64, usize) {
    let l = prob.n_instances as f64;
    let mut max_viol = 0.0f64;
    let mut ops = 0usize;
    for j in 0..prob.n_features {
        let col = prob.xt.row(j);
        if j + 1 < prob.n_features {
            let next = prob.xt.row(j + 1);
            crate::sparse::kernels::prefetch_row(next.indices(), next.values());
        }
        let g = col.dot_dense(r) / l;
        ops += col.nnz();
        max_viol = max_viol.max(subgrad_violation(w[j], g, lambda));
    }
    (max_viol, ops)
}

/// Count of non-zero coefficients (the paper's sparsity report).
pub fn nnz_coefficients(model: &LassoModel) -> usize {
    model.w.iter().filter(|&&v| v != 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acf::AcfParams;
    use crate::data::synth;
    use crate::sched::{AcfSchedulerPolicy, CyclicScheduler};
    use crate::util::rng::Rng;

    fn reg_ds(seed: u64) -> (Dataset, Vec<f64>) {
        synth::regression_sparse("reg", 200, 120, 12, 10, 0.05, &mut Rng::new(seed))
    }

    #[test]
    fn high_lambda_gives_zero_solution() {
        let (ds, _) = reg_ds(1);
        // λ above max |(1/ℓ)Xᵀy| forces w = 0
        let prob = LassoProblem::new(&ds);
        let l = ds.n_instances() as f64;
        let max_corr = (0..prob.n_features)
            .map(|j| (prob.xt.row(j).dot_dense(&ds.y) / l).abs())
            .fold(0.0f64, f64::max);
        let mut sched = CyclicScheduler::new(ds.n_features());
        let (model, res) = solve(&ds, max_corr * 1.01, &mut sched, SolverConfig::with_eps(1e-8));
        assert!(res.status.converged());
        assert_eq!(nnz_coefficients(&model), 0);
    }

    #[test]
    fn recovers_planted_signal_at_low_lambda() {
        let (ds, w_true) = reg_ds(2);
        let mut sched = CyclicScheduler::new(ds.n_features());
        let (model, res) = solve(&ds, 0.001, &mut sched, SolverConfig::with_eps(1e-6));
        assert!(res.status.converged(), "{}", res.summary());
        // top true coefficients should be recovered with the right sign
        let mut idx: Vec<usize> = (0..w_true.len()).filter(|&j| w_true[j].abs() > 1.0).collect();
        idx.sort_by(|&a, &b| w_true[b].abs().partial_cmp(&w_true[a].abs()).unwrap());
        for &j in idx.iter().take(3) {
            assert!(
                model.w[j] * w_true[j] > 0.0,
                "coefficient {j}: {} vs true {}",
                model.w[j],
                w_true[j]
            );
        }
    }

    #[test]
    fn solution_satisfies_kkt() {
        let (ds, _) = reg_ds(3);
        let lambda = 0.05;
        let mut sched = CyclicScheduler::new(ds.n_features());
        let (model, res) = solve(&ds, lambda, &mut sched, SolverConfig::with_eps(1e-8));
        assert!(res.status.converged());
        let prob = LassoProblem::new(&ds);
        let mut r: Vec<f64> = ds.y.iter().map(|&v| -v).collect();
        for j in 0..ds.n_features() {
            prob.xt.row(j).axpy_into(model.w[j], &mut r);
        }
        let l = ds.n_instances() as f64;
        for j in 0..ds.n_features() {
            let g = prob.xt.row(j).dot_dense(&r) / l;
            let v = subgrad_violation(model.w[j], g, lambda);
            assert!(v < 1e-7, "feature {j}: violation {v}");
        }
    }

    #[test]
    fn acf_matches_cyclic_objective() {
        let (ds, _) = reg_ds(4);
        let lambda = 0.02;
        let cfg = SolverConfig::with_eps(1e-6);
        let mut cyc = CyclicScheduler::new(ds.n_features());
        let (_, r1) = solve(&ds, lambda, &mut cyc, cfg.clone());
        let mut acf = AcfSchedulerPolicy::new(ds.n_features(), AcfParams::default(), Rng::new(5));
        let (_, r2) = solve(&ds, lambda, &mut acf, cfg);
        assert!(r1.status.converged() && r2.status.converged());
        let rel = (r1.objective - r2.objective).abs() / r1.objective.abs().max(1e-12);
        assert!(rel < 1e-4, "{} vs {}", r1.objective, r2.objective);
    }

    #[test]
    fn sparsity_decreases_with_lambda() {
        let (ds, _) = reg_ds(6);
        let mut nnz_prev = usize::MAX;
        for lambda in [0.001, 0.01, 0.1] {
            let mut sched = CyclicScheduler::new(ds.n_features());
            let (model, res) = solve(&ds, lambda, &mut sched, SolverConfig::with_eps(1e-6));
            assert!(res.status.converged());
            let k = nnz_coefficients(&model);
            assert!(k <= nnz_prev, "λ={lambda}: {k} > {nnz_prev}");
            nnz_prev = k;
        }
    }

    #[test]
    fn objective_monotone() {
        let (ds, _) = reg_ds(7);
        let cfg = SolverConfig { eps: 1e-5, trace_every: 40, ..Default::default() };
        let mut sched = CyclicScheduler::new(ds.n_features());
        let (_, res) = solve(&ds, 0.01, &mut sched, cfg);
        res.trace.check_monotone(1e-9).expect("descent method must not increase f");
    }

    #[test]
    fn empty_columns_are_inert() {
        // feature 3 never occurs: w[3] must stay 0 and not break anything
        let ds = Dataset {
            name: "gap".into(),
            x: Csr::from_rows(
                5,
                vec![vec![(0, 1.0), (4, 0.5)], vec![(1, 1.0)], vec![(0, -1.0), (1, 0.3)]],
            ),
            y: vec![1.0, -0.5, 0.2],
        };
        let mut sched = CyclicScheduler::new(5);
        let (model, res) = solve(&ds, 0.01, &mut sched, SolverConfig::with_eps(1e-8));
        assert!(res.status.converged());
        assert_eq!(model.w[3], 0.0);
        assert_eq!(model.w[2], 0.0);
    }
}
