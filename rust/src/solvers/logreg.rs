//! Dual coordinate descent for L2-regularized logistic regression
//! (Yu, Huang & Lin, 2011) — the paper's §3.4 testbed (Table 9).
//!
//! Problem (3):
//!
//! ```text
//! min_α  f(α) = ½ Σ_ij α_i α_j y_i y_j ⟨x_i,x_j⟩
//!               + Σ_i [ α_i log α_i + (C−α_i) log(C−α_i) ]
//! s.t.   0 ≤ α_i ≤ C
//! ```
//!
//! The one-dimensional sub-problem has no closed form (the logarithmic
//! terms); following liblinear we run a few guarded Newton iterations on
//! the scalar function
//!
//! ```text
//! g(z) = Q_ii·(z − α_i) + m_i + log(z / (C − z)),   m_i = y_i⟨w,x_i⟩
//! ```
//!
//! which is strictly increasing on (0, C) with g(0⁺) = −∞, g(C⁻) = +∞, so
//! a bisection-safeguarded Newton always converges. The solution is
//! interior (never exactly 0 or C) — hence no shrinking, and liblinear's
//! baseline policy is uniform sweeps in random order (§3.4).
//!
//! `Δf` is computed exactly in O(1) from the quadratic change plus the
//! entropy terms before/after.

use super::common::{EpochObs, RunState, SolveResult, SolveStatus, SolverConfig};
use crate::select::Selector;
use crate::sparse::Dataset;

/// Trained dual logistic-regression model.
#[derive(Clone, Debug)]
pub struct LogRegModel {
    pub alpha: Vec<f64>,
    pub w: Vec<f64>,
    pub c: f64,
}

/// Entropy-like term `a log a + (C−a) log(C−a)` with the 0·log0 = 0
/// convention. Shared with the sharded front-end
/// ([`crate::shard::logreg`]) so both paths price the separable
/// objective identically.
#[inline]
pub(crate) fn ent(a: f64, c: f64) -> f64 {
    let mut s = 0.0;
    if a > 0.0 {
        s += a * a.ln();
    }
    let b = c - a;
    if b > 0.0 {
        s += b * b.ln();
    }
    s
}

/// Inner solver: minimize `½q(z−a₀)² + m·(z−a₀) + ent(z)` over z ∈ (0,C).
/// Returns the new α_i. Newton with bisection safeguards; ~O(10) scalar
/// iterations, independent of data size.
#[inline]
pub(crate) fn solve_1d(q: f64, m: f64, a0: f64, c: f64, tol: f64, max_newton: usize) -> f64 {
    // derivative: g(z) = q(z − a0) + m + ln(z/(C−z))
    let g = |z: f64| q * (z - a0) + m + (z / (c - z)).ln();
    // bracket: derivative is −∞ at 0⁺, +∞ at C⁻
    let mut lo = 0.0f64;
    let mut hi = c;
    let mut z = a0.clamp(c * 1e-12, c * (1.0 - 1e-12));
    for _ in 0..max_newton {
        let gz = g(z);
        if gz.abs() < tol {
            return z;
        }
        if gz > 0.0 {
            hi = z;
        } else {
            lo = z;
        }
        let h = q + c / (z * (c - z)); // g'(z) > 0
        let mut z_new = z - gz / h;
        if !(z_new > lo && z_new < hi) {
            z_new = 0.5 * (lo + hi); // bisection fallback
        }
        z = z_new;
    }
    z
}

/// Violation measure: |∂f/∂α_i| (solution is interior, so the stopping
/// criterion is a plain gradient-infinity norm, paper §7).
#[inline]
pub(crate) fn grad_violation(g: f64) -> f64 {
    g.abs()
}

/// Interior starting point α_i (liblinear-style: a small fraction of C).
/// One definition serves the serial and sharded paths so their initial
/// objectives agree exactly.
#[inline]
pub(crate) fn initial_alpha(c: f64) -> f64 {
    (0.001 * c).min(1e-3).max(1e-10)
}

/// Selector-driven dual CD for logistic regression.
pub fn solve(
    ds: &Dataset,
    c: f64,
    sched: &mut dyn Selector,
    config: SolverConfig,
) -> (LogRegModel, SolveResult) {
    let n = ds.n_instances();
    assert_eq!(sched.n(), n);
    let d = ds.n_features();
    let q_diag = ds.x.row_norms_sq();
    // Interior initialization (liblinear-style): α_i a small fraction of
    // C, with w built consistently.
    let a_init = initial_alpha(c);
    let mut alpha = vec![a_init; n];
    let mut w = vec![0.0f64; d];
    for i in 0..n {
        ds.x.row(i).axpy_into(alpha[i] * ds.y[i], &mut w);
    }
    let mut eo = EpochObs::new(&config);
    let mut rs = RunState::new(config);
    let mut status = SolveStatus::IterLimit;
    let mut window_max = 0.0f64;
    let mut window_count = 0usize;
    let mut epochs = 0u64;
    let mut final_viol = f64::INFINITY;

    let objective = |alpha: &[f64], w: &[f64]| -> f64 {
        0.5 * crate::sparse::ops::norm_sq(w)
            + alpha.iter().map(|&a| ent(a, c)).sum::<f64>()
    };

    'outer: loop {
        let i = sched.next();
        let row = ds.x.row(i);
        let yi = ds.y[i];
        let a_old = alpha[i];
        // fused kernel: margin dot + guarded-Newton 1D solve + scatter
        // on the same hot row slices
        let mut m = 0.0;
        let mut g = 0.0;
        let mut a_new = a_old;
        row.step(&mut w, |dot| {
            m = yi * dot;
            // gradient at the current point: the Qα term is y_i⟨w,x_i⟩ = m
            g = m + (a_old / (c - a_old)).ln();
            a_new = solve_1d(q_diag[i], m, a_old, c, 1e-10, 25);
            let step_d = a_new - a_old;
            if step_d.abs() > 1e-15 {
                step_d * yi
            } else {
                0.0
            }
        });
        let viol = grad_violation(g);
        window_max = window_max.max(viol);
        window_count += 1;

        let mut ops = row.nnz();
        let mut delta_f = 0.0;
        let step_d = a_new - a_old;
        if step_d.abs() > 1e-15 {
            alpha[i] = a_new;
            ops += row.nnz();
            // exact decrease: quadratic part m·d + ½q·d² plus entropy
            delta_f = -(m * step_d + 0.5 * q_diag[i] * step_d * step_d)
                - (ent(a_new, c) - ent(a_old, c));
        }
        sched.report(i, delta_f.max(0.0));

        let budget_ok = rs.step(ops);
        rs.maybe_trace(|| objective(&alpha, &w), viol);
        if !budget_ok || rs.over_time() {
            if rs.over_time() {
                status = SolveStatus::TimeLimit;
            }
            let (v, extra) = verify(ds, &alpha, &w, c);
            rs.counter.extra(extra);
            final_viol = v;
            break 'outer;
        }

        if window_count >= n {
            epochs += 1;
            eo.epoch(epochs, || objective(&alpha, &w));
            if window_max < rs.eps() {
                let (v, extra) = verify(ds, &alpha, &w, c);
                rs.counter.extra(extra);
                if v < rs.eps() {
                    status = SolveStatus::Converged;
                    final_viol = v;
                    break 'outer;
                }
            }
            window_max = 0.0;
            window_count = 0;
        }
    }

    let obj = objective(&alpha, &w);
    let model = LogRegModel { alpha, w, c };
    (model, rs.finish(status, obj, final_viol, epochs))
}

fn verify(ds: &Dataset, alpha: &[f64], w: &[f64], c: f64) -> (f64, usize) {
    let n = ds.n_instances();
    let mut max_viol = 0.0f64;
    let mut ops = 0usize;
    for i in 0..n {
        let row = ds.x.row(i);
        // software pipelining: next row's loads overlap this reduction
        if i + 1 < n {
            let next = ds.x.row(i + 1);
            crate::sparse::kernels::prefetch_row(next.indices(), next.values());
        }
        let m = ds.y[i] * row.dot_dense(w);
        ops += row.nnz();
        let g = m + (alpha[i] / (c - alpha[i])).ln();
        max_viol = max_viol.max(grad_violation(g));
    }
    (max_viol, ops)
}

/// Primal objective `½‖w‖² + C Σ log(1+exp(−y⟨w,x⟩))` for duality-gap
/// audits.
pub fn primal_objective(ds: &Dataset, w: &[f64], c: f64) -> f64 {
    let mut loss = 0.0;
    for i in 0..ds.n_instances() {
        let m = ds.y[i] * ds.x.row(i).dot_dense(w);
        // numerically stable log1p(exp(−m))
        loss += if m > 0.0 { (-m).exp().ln_1p() } else { -m + m.exp().ln_1p() };
    }
    0.5 * crate::sparse::ops::norm_sq(w) + c * loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acf::AcfParams;
    use crate::data::synth;
    use crate::sched::{AcfSchedulerPolicy, PermutationScheduler};
    use crate::sparse::Csr;
    use crate::util::rng::Rng;

    fn text_ds(seed: u64) -> Dataset {
        synth::sparse_text(
            &synth::SparseTextSpec {
                name: "t",
                n: 250,
                d: 400,
                nnz_per_row: 12,
                zipf_s: 1.0,
                concept_k: 25,
                noise: 0.05,
            },
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn solve_1d_finds_root() {
        // check that the returned point zeroes the derivative
        for (q, m, a0, c) in [(1.0, 0.5, 0.3, 1.0), (10.0, -2.0, 0.9, 2.0), (0.0, 1.0, 0.1, 0.5)]
        {
            let z = solve_1d(q, m, a0, c, 1e-12, 50);
            let g = q * (z - a0) + m + (z / (c - z)).ln();
            assert!(g.abs() < 1e-8, "g({z}) = {g}");
            assert!(z > 0.0 && z < c);
        }
    }

    #[test]
    fn converges_and_interior() {
        let ds = text_ds(1);
        let c = 1.0;
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(1));
        let (model, res) = solve(&ds, c, &mut sched, SolverConfig::with_eps(1e-4));
        assert!(res.status.converged(), "{}", res.summary());
        // dual solution strictly interior
        assert!(model.alpha.iter().all(|&a| a > 0.0 && a < c));
    }

    #[test]
    fn duality_gap_closes() {
        let ds = text_ds(2);
        let c = 2.0;
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(2));
        let (model, res) = solve(&ds, c, &mut sched, SolverConfig::with_eps(1e-6));
        assert!(res.status.converged());
        // dual value = −f(α) + constant C·log C·ℓ? For our f the duality
        // relation is P(w*) = −f(α*) + ℓ·C·ln C; check the gap with that
        // constant folded in.
        let l = ds.n_instances() as f64;
        let dual_value = -(res.objective) + l * c * c.ln();
        let primal = primal_objective(&ds, &model.w, c);
        let gap = (primal - dual_value).abs() / primal.abs().max(1.0);
        assert!(gap < 1e-3, "gap {gap}: primal {primal} dual {dual_value}");
    }

    #[test]
    fn gradient_norm_small_at_solution() {
        let ds = text_ds(3);
        let c = 1.0;
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(3));
        let (model, res) = solve(&ds, c, &mut sched, SolverConfig::with_eps(1e-5));
        assert!(res.status.converged());
        let (v, _) = verify(&ds, &model.alpha, &model.w, c);
        assert!(v < 1e-5, "violation {v}");
    }

    #[test]
    fn acf_matches_uniform_objective() {
        let ds = text_ds(4);
        let c = 10.0;
        let cfg = SolverConfig::with_eps(1e-4);
        let mut perm = PermutationScheduler::new(ds.n_instances(), Rng::new(4));
        let (_, r1) = solve(&ds, c, &mut perm, cfg.clone());
        let mut acf =
            AcfSchedulerPolicy::new(ds.n_instances(), AcfParams::default(), Rng::new(5));
        let (_, r2) = solve(&ds, c, &mut acf, cfg);
        assert!(r1.status.converged() && r2.status.converged());
        let rel = (r1.objective - r2.objective).abs() / r1.objective.abs().max(1.0);
        assert!(rel < 1e-3, "{} vs {}", r1.objective, r2.objective);
    }

    #[test]
    fn model_predicts_toy() {
        let ds = Dataset {
            name: "toy".into(),
            x: Csr::from_rows(
                2,
                vec![
                    vec![(0, 1.0)],
                    vec![(0, 2.0), (1, 0.5)],
                    vec![(0, -1.5)],
                    vec![(0, -1.0), (1, -1.0)],
                ],
            ),
            y: vec![1.0, 1.0, -1.0, -1.0],
        };
        let mut sched = PermutationScheduler::new(4, Rng::new(6));
        let (model, res) = solve(&ds, 5.0, &mut sched, SolverConfig::with_eps(1e-6));
        assert!(res.status.converged());
        assert_eq!(crate::data::split::binary_accuracy(&ds, &model.w), 1.0);
    }

    #[test]
    fn objective_monotone() {
        let ds = text_ds(7);
        let cfg = SolverConfig { eps: 1e-4, trace_every: 50, ..Default::default() };
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(7));
        let (_, res) = solve(&ds, 1.0, &mut sched, cfg);
        res.trace.check_monotone(1e-9).expect("monotone descent");
    }
}
