//! Weston–Watkins multi-class SVM via **subspace descent** — the paper's
//! §3.3 testbed (Table 8).
//!
//! Primal:
//!
//! ```text
//! min  ½ Σ_k ‖w_k‖² + C Σ_i Σ_{k≠y_i} max(0, 1 − (⟨w_{y_i},x_i⟩ − ⟨w_k,x_i⟩))
//! ```
//!
//! Dual variables `α_{ik} ∈ [0, C]` for `k ≠ y_i`, with
//!
//! ```text
//! w_k = Σ_i x_i · ( [y_i = k]·Σ_m α_{im}  −  [y_i ≠ k]·α_{ik} )
//! f(α) = ½ Σ_k ‖w_k‖² − Σ_{i,k≠y_i} α_{ik}        (minimize)
//! ∂f/∂α_{ik} = ⟨w_{y_i} − w_k, x_i⟩ − 1
//! ```
//!
//! A *subspace* step picks example `i`, computes the K−1 partial
//! derivatives at the cost of K sparse dots (O(K·nnz(x_i))), then solves
//! the (K−1)-dimensional box-constrained QP with an SMO-style inner CD
//! loop: repeatedly pick the inner coordinate with the largest projected
//! gradient and make a clipped Newton step, updating cached margins in
//! O(K) per inner step — up to `10·K` inner iterations (paper §7.3). The
//! aggregated exact decrease `Δf` over the sub-problem solve is the
//! progress signal reported to ACF.
//!
//! The subspace Hessian for example `i` is `‖x_i‖²·(I + 1·1ᵀ)` restricted
//! to `k ≠ y_i`: diagonal `2‖x_i‖²`, off-diagonal `‖x_i‖²`.

use super::common::{EpochObs, RunState, SolveResult, SolveStatus, SolverConfig};
use crate::select::Selector;
use crate::sparse::Dataset;
use crate::util::error::Result;

/// Trained multi-class model.
#[derive(Clone, Debug)]
pub struct McSvmModel {
    /// per-class primal weights, K × d
    pub w: Vec<Vec<f64>>,
    /// dual variables, flattened ℓ × K (entry (i,k) unused when k = y_i)
    pub alpha: Vec<f64>,
    pub c: f64,
    pub k_classes: usize,
}

impl McSvmModel {
    /// Dual objective ½Σ‖w_k‖² − Σα.
    pub fn objective(&self) -> f64 {
        let quad: f64 = self.w.iter().map(|wk| crate::sparse::ops::norm_sq(wk)).sum();
        let lin: f64 = self.alpha.iter().sum();
        0.5 * quad - lin
    }
}

/// Validate and map labels to classes `0..K−1` (the one validator both
/// the serial and sharded front-ends share; also rejects K < 2).
///
/// `v as usize` saturates negative floats to 0, so a binary ±1-labeled
/// dataset would silently pass a `v < k_classes` assert and train on
/// garbage classes; reject anything that is not a non-negative integer
/// below K with a first-party error naming the offending value.
pub fn class_labels(ds: &Dataset, k_classes: usize) -> Result<Vec<usize>> {
    if k_classes < 2 {
        return Err(crate::anyhow!("mcsvm needs >= 2 distinct labels, got {k_classes}"));
    }
    ds.y.iter()
        .enumerate()
        .map(|(i, &v)| {
            if v.is_finite() && v >= 0.0 && v.fract() == 0.0 && (v as usize) < k_classes {
                Ok(v as usize)
            } else {
                Err(crate::anyhow!(
                    "mcsvm labels must be integers in 0..{k_classes}, got {v} at instance {i} \
                     (relabel ±1 binary data to {{0, 1}} before training)"
                ))
            }
        })
        .collect()
}

/// Result of one subspace solve.
pub(crate) struct SubspaceOutcome {
    pub(crate) delta_f: f64,
    pub(crate) max_viol_entry: f64,
    pub(crate) inner_steps: u64,
    pub(crate) ops: usize,
}

/// Solve the K−1 dimensional sub-problem for example `i` in place.
///
/// `margins[k] = ⟨w_k, x_i⟩` are computed by the caller; `alpha_i` is the
/// slice of the K dual variables of example i. Updates `alpha_i`,
/// returns the deltas to apply to the weight vectors via
/// `delta_beta[k]`. Shared with the sharded front-end
/// ([`crate::shard::mcsvm`]), which runs the same exact block update
/// against per-class snapshots of the weight vectors.
#[allow(clippy::too_many_arguments)]
pub(crate) fn solve_subspace(
    yi: usize,
    k_classes: usize,
    xi_norm_sq: f64,
    c: f64,
    margins: &mut [f64],
    alpha_i: &mut [f64],
    delta_beta: &mut [f64],
    max_inner: usize,
    eps_inner: f64,
) -> SubspaceOutcome {
    // g_k = ⟨w_{y_i} − w_k, x_i⟩ − 1 changes when any inner variable
    // moves: raising α_{ik'} adds x_i to w_{y_i} (affects all g) and
    // subtracts x_i from w_{k'} (affects g_{k'} only).
    // Track s = Σ_m α_{im} implicitly through margin updates.
    for b in delta_beta.iter_mut() {
        *b = 0.0;
    }
    let q = xi_norm_sq;
    let mut delta_f = 0.0f64;
    let mut inner_steps = 0u64;
    let mut max_viol_first = 0.0f64;
    // Every inner SMO step costs O(K): the projected-gradient scan over
    // the K classes (the margin/delta updates are O(1) on top). Counted
    // in BOTH branches so `BENCH_*`/sweep op columns stay comparable
    // across solvers — the empty-row branch is one K-wide pass.
    let mut ops = 0usize;
    if q <= 0.0 {
        // empty row: gradient is −1 for every k ⇒ all α go to C
        let mut moved = 0.0;
        for k in 0..k_classes {
            if k == yi {
                continue;
            }
            let d = c - alpha_i[k];
            if d > 0.0 {
                alpha_i[k] = c;
                delta_beta[k] -= d;
                delta_beta[yi] += d;
                moved += d;
                max_viol_first = 1.0;
            }
        }
        return SubspaceOutcome {
            delta_f: moved,
            max_viol_entry: max_viol_first,
            inner_steps: 1,
            ops: k_classes,
        };
    }

    for step in 0..max_inner {
        // pick the inner coordinate with the largest projected gradient
        ops += k_classes;
        let myi = margins[yi];
        let mut best_k = usize::MAX;
        let mut best_v = 0.0f64;
        for k in 0..k_classes {
            if k == yi {
                continue;
            }
            let g = myi - margins[k] - 1.0;
            let a = alpha_i[k];
            let v = if a <= 0.0 {
                (-g).max(0.0)
            } else if a >= c {
                g.max(0.0)
            } else {
                g.abs()
            };
            if v > best_v {
                best_v = v;
                best_k = k;
            }
        }
        if step == 0 {
            max_viol_first = best_v;
        }
        if best_k == usize::MAX || best_v < eps_inner {
            break;
        }
        let k = best_k;
        let g = myi - margins[k] - 1.0;
        // diagonal curvature: 2‖x_i‖²
        let h = 2.0 * q;
        let old = alpha_i[k];
        let new = (old - g / h).clamp(0.0, c);
        let d = new - old;
        if d == 0.0 {
            break;
        }
        alpha_i[k] = new;
        // margins: w_{y_i} += d·x_i ⇒ m_{y_i} += d·q ; w_k −= d·x_i ⇒ m_k −= d·q
        margins[yi] += d * q;
        margins[k] -= d * q;
        delta_beta[yi] += d;
        delta_beta[k] -= d;
        // exact decrease along this inner coordinate
        delta_f += -(g * d + 0.5 * h * d * d);
        inner_steps += 1;
    }
    SubspaceOutcome {
        delta_f,
        max_viol_entry: max_viol_first,
        inner_steps: inner_steps.max(1),
        ops,
    }
}

/// Selector-driven subspace descent. The selector picks *examples*
/// (subspaces); iteration counts follow the paper's convention of
/// counting inner CD steps. Errs (before touching any state) when the
/// labels are not integers in `0..K−1` — see [`class_labels`].
pub fn solve(
    ds: &Dataset,
    c: f64,
    sched: &mut dyn Selector,
    config: SolverConfig,
) -> Result<(McSvmModel, SolveResult)> {
    let n = ds.n_instances();
    assert_eq!(sched.n(), n);
    let d = ds.n_features();
    let k_classes = ds.classes().len();
    let y = class_labels(ds, k_classes)?;

    // borrowed from the matrix-level cache (computed once per Csr)
    let norms = ds.x.row_norms_sq();
    let mut w: Vec<Vec<f64>> = vec![vec![0.0; d]; k_classes];
    let mut alpha = vec![0.0f64; n * k_classes];
    let max_inner = 10 * k_classes;

    let mut eo = EpochObs::new(&config);
    let mut rs = RunState::new(config);
    let mut status = SolveStatus::IterLimit;
    let mut window_max = 0.0f64;
    let mut window_count = 0usize;
    let mut epochs = 0u64;
    let mut final_viol = f64::INFINITY;
    let mut margins = vec![0.0f64; k_classes];
    let mut delta_beta = vec![0.0f64; k_classes];

    'outer: loop {
        let i = sched.next();
        let yi = y[i];
        let row = ds.x.row(i);
        // K margins: O(K · nnz)
        for (k, m) in margins.iter_mut().enumerate() {
            *m = row.dot_dense(&w[k]);
        }
        let mut ops = k_classes * row.nnz();

        let out = solve_subspace(
            yi,
            k_classes,
            norms[i],
            c,
            &mut margins,
            &mut alpha[i * k_classes..(i + 1) * k_classes],
            &mut delta_beta,
            max_inner,
            rs.eps() * 0.1,
        );
        // apply weight updates: O(nnz) per class actually moved
        for (k, &b) in delta_beta.iter().enumerate() {
            if b != 0.0 {
                row.axpy_into(b, &mut w[k]);
                ops += row.nnz();
            }
        }
        ops += out.ops;
        sched.report(i, out.delta_f.max(0.0));
        window_max = window_max.max(out.max_viol_entry);
        window_count += 1;

        // count inner steps as iterations (paper's convention)
        let mut budget_ok = true;
        for _ in 0..out.inner_steps {
            budget_ok = rs.step(0);
            if !budget_ok {
                break;
            }
        }
        // attribute the ops to the subspace solve
        rs.counter.extra(ops);
        rs.maybe_trace(
            || {
                let quad: f64 = w.iter().map(|wk| crate::sparse::ops::norm_sq(wk)).sum();
                0.5 * quad - alpha.iter().sum::<f64>()
            },
            out.max_viol_entry,
        );
        if !budget_ok || rs.over_time() {
            if rs.over_time() {
                status = SolveStatus::TimeLimit;
            }
            let (v, extra) = verify(ds, &y, &alpha, &w, c, k_classes);
            rs.counter.extra(extra);
            final_viol = v;
            break 'outer;
        }

        if window_count >= n {
            epochs += 1;
            eo.epoch(epochs, || {
                let quad: f64 = w.iter().map(|wk| crate::sparse::ops::norm_sq(wk)).sum();
                0.5 * quad - alpha.iter().sum::<f64>()
            });
            if window_max < rs.eps() {
                let (v, extra) = verify(ds, &y, &alpha, &w, c, k_classes);
                rs.counter.extra(extra);
                if v < rs.eps() {
                    status = SolveStatus::Converged;
                    final_viol = v;
                    break 'outer;
                }
            }
            window_max = 0.0;
            window_count = 0;
        }
    }

    let model = McSvmModel { w, alpha, c, k_classes };
    let obj = model.objective();
    Ok((model, rs.finish(status, obj, final_viol, epochs)))
}

/// Full KKT verification over all (i, k≠y_i) pairs.
fn verify(
    ds: &Dataset,
    y: &[usize],
    alpha: &[f64],
    w: &[Vec<f64>],
    c: f64,
    k_classes: usize,
) -> (f64, usize) {
    let mut max_viol = 0.0f64;
    let mut ops = 0usize;
    for i in 0..ds.n_instances() {
        let row = ds.x.row(i);
        let myi = row.dot_dense(&w[y[i]]);
        ops += k_classes * row.nnz();
        for k in 0..k_classes {
            if k == y[i] {
                continue;
            }
            let g = myi - row.dot_dense(&w[k]) - 1.0;
            let a = alpha[i * k_classes + k];
            let v = if a <= 0.0 {
                (-g).max(0.0)
            } else if a >= c {
                g.max(0.0)
            } else {
                g.abs()
            };
            max_viol = max_viol.max(v);
        }
    }
    (max_viol, ops)
}

/// Primal objective for duality-gap audits. Errs on invalid labels with
/// the same first-party error as [`solve`] (callers need not have gone
/// through training first).
pub fn primal_objective(ds: &Dataset, w: &[Vec<f64>], c: f64) -> Result<f64> {
    let y = class_labels(ds, w.len())?;
    let mut loss = 0.0;
    for i in 0..ds.n_instances() {
        let row = ds.x.row(i);
        let myi = row.dot_dense(&w[y[i]]);
        for (k, wk) in w.iter().enumerate() {
            if k == y[i] {
                continue;
            }
            loss += (1.0 - (myi - row.dot_dense(wk))).max(0.0);
        }
    }
    let quad: f64 = w.iter().map(|wk| crate::sparse::ops::norm_sq(wk)).sum();
    Ok(0.5 * quad + c * loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acf::AcfParams;
    use crate::data::synth;
    use crate::sched::{AcfSchedulerPolicy, PermutationScheduler, UniformScheduler};
    use crate::util::rng::Rng;

    fn blobs(seed: u64) -> Dataset {
        synth::multiclass_blobs("b", 90, 5, 3, 0.4, &mut Rng::new(seed))
    }

    #[test]
    fn converges_and_classifies_blobs() {
        let ds = blobs(1);
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(1));
        let (model, res) = solve(&ds, 1.0, &mut sched, SolverConfig::with_eps(1e-4)).unwrap();
        assert!(res.status.converged(), "{}", res.summary());
        let acc = crate::data::split::multiclass_accuracy(&ds, &model.w);
        assert!(acc > 0.95, "train accuracy {acc}");
    }

    #[test]
    fn kkt_holds_at_solution() {
        let ds = blobs(2);
        let c = 0.5;
        let mut sched = UniformScheduler::new(ds.n_instances(), Rng::new(2));
        let (model, res) = solve(&ds, c, &mut sched, SolverConfig::with_eps(1e-5)).unwrap();
        assert!(res.status.converged());
        let y: Vec<usize> = ds.y.iter().map(|&v| v as usize).collect();
        let (v, _) = verify(&ds, &y, &model.alpha, &model.w, c, model.k_classes);
        assert!(v < 1e-5, "violation {v}");
        // box feasibility
        assert!(model.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
    }

    #[test]
    fn duality_gap_closes() {
        let ds = blobs(3);
        let c = 1.0;
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(3));
        let (model, res) = solve(&ds, c, &mut sched, SolverConfig::with_eps(1e-6)).unwrap();
        assert!(res.status.converged());
        let dual = -res.objective;
        let primal = primal_objective(&ds, &model.w, c).unwrap();
        let gap = (primal - dual) / primal.abs().max(1.0);
        assert!(gap >= -1e-9, "weak duality violated: {gap}");
        assert!(gap < 1e-3, "gap {gap}");
    }

    #[test]
    fn acf_matches_uniform_objective() {
        let ds = synth::multiclass_text("mc", 150, 300, 4, 10, 0.02, &mut Rng::new(4));
        let c = 1.0;
        let cfg = SolverConfig::with_eps(1e-3);
        let mut perm = PermutationScheduler::new(ds.n_instances(), Rng::new(4));
        let (_, r1) = solve(&ds, c, &mut perm, cfg.clone()).unwrap();
        let mut acf =
            AcfSchedulerPolicy::new(ds.n_instances(), AcfParams::default(), Rng::new(5));
        let (_, r2) = solve(&ds, c, &mut acf, cfg).unwrap();
        assert!(r1.status.converged() && r2.status.converged());
        let rel = (r1.objective - r2.objective).abs() / r1.objective.abs().max(1.0);
        assert!(rel < 5e-3, "{} vs {}", r1.objective, r2.objective);
    }

    #[test]
    fn two_class_ww_reduces_to_binary_like_solution() {
        // With K=2 the WW dual is equivalent to binary SVM up to scaling;
        // check both models classify identically.
        let mut rng = Rng::new(6);
        let bin = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "b2",
                n: 120,
                d: 200,
                nnz_per_row: 10,
                zipf_s: 1.0,
                concept_k: 12,
                noise: 0.0,
            },
            &mut rng,
        );
        // convert ±1 labels to {0,1}
        let mc = Dataset {
            name: "b2mc".into(),
            x: bin.x.clone(),
            y: bin.y.iter().map(|&l| if l > 0.0 { 1.0 } else { 0.0 }).collect(),
        };
        let mut s1 = PermutationScheduler::new(mc.n_instances(), Rng::new(7));
        let (m_mc, r_mc) = solve(&mc, 1.0, &mut s1, SolverConfig::with_eps(1e-5)).unwrap();
        assert!(r_mc.status.converged());
        // WW with K = 2 and parameter C is equivalent to the binary SVM
        // with parameter 2C (the WW regularizer splits ½‖v‖² in half
        // across w₀ = −w₁).
        let mut s2 = PermutationScheduler::new(bin.n_instances(), Rng::new(8));
        let (m_bin, r_bin) =
            crate::solvers::svm::solve(&bin, 2.0, &mut s2, SolverConfig::with_eps(1e-6));
        assert!(r_bin.status.converged());
        let mut agree = 0usize;
        for i in 0..bin.n_instances() {
            let row = bin.x.row(i);
            let mc_pred = row.dot_dense(&m_mc.w[1]) - row.dot_dense(&m_mc.w[0]);
            let bin_pred = row.dot_dense(&m_bin.w);
            if mc_pred * bin_pred > 0.0 {
                agree += 1;
            }
        }
        let frac = agree as f64 / bin.n_instances() as f64;
        assert!(frac > 0.97, "agreement {frac}");
    }

    #[test]
    fn iteration_cap_respected() {
        let ds = blobs(9);
        let cfg = SolverConfig { eps: 1e-12, max_iterations: 100, ..Default::default() };
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(9));
        let (_, res) = solve(&ds, 100.0, &mut sched, cfg).unwrap();
        assert_eq!(res.status, SolveStatus::IterLimit);
    }

    #[test]
    fn pm1_labels_are_rejected_with_a_named_error() {
        // ±1 labels used to saturate (−1.0 as usize == 0), pass the
        // range check and train on garbage classes; now they fail fast
        // with an error naming the offending value
        let mut rng = Rng::new(10);
        let ds = synth::sparse_text(
            &synth::SparseTextSpec {
                name: "pm1",
                n: 40,
                d: 60,
                nnz_per_row: 8,
                zipf_s: 1.0,
                concept_k: 6,
                noise: 0.0,
            },
            &mut rng,
        );
        assert!(ds.y.contains(&-1.0), "fixture must carry a −1 label");
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(10));
        let err = solve(&ds, 1.0, &mut sched, SolverConfig::with_eps(1e-3)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("-1"), "error must name the offending label: {msg}");
        // fractional labels are rejected too
        let mut frac = ds.clone();
        frac.y = frac.y.iter().map(|&v| if v < 0.0 { 0.5 } else { 1.0 }).collect();
        let mut sched = PermutationScheduler::new(frac.n_instances(), Rng::new(10));
        let err = solve(&frac, 1.0, &mut sched, SolverConfig::with_eps(1e-3)).unwrap_err();
        assert!(format!("{err:#}").contains("0.5"), "{err:#}");
    }

    #[test]
    fn subspace_ops_are_counted_on_both_branches() {
        let k = 4;
        let c = 1.0;
        let mut margins = vec![0.0f64; k];
        let mut alpha = vec![0.0f64; k];
        let mut beta = vec![0.0f64; k];
        // main path: a unit-norm row with fresh alphas makes progress,
        // so the K-wide scans must be billed (was `ops: 0`)
        let out = solve_subspace(0, k, 1.0, c, &mut margins, &mut alpha, &mut beta, 10 * k, 1e-6);
        assert!(out.inner_steps >= 1);
        assert!(
            out.ops >= k * out.inner_steps as usize,
            "main path must count >= K ops per inner step, got {} for {} steps",
            out.ops,
            out.inner_steps
        );
        // empty-row branch: one K-wide pass
        let mut margins = vec![0.0f64; k];
        let mut alpha = vec![0.0f64; k];
        let out = solve_subspace(0, k, 0.0, c, &mut margins, &mut alpha, &mut beta, 10 * k, 1e-6);
        assert_eq!(out.ops, k);
    }
}
