//! CD solvers for the paper's four problem families (§3), all generic
//! over [`crate::select::Selector`] (the coordinate-selection
//! subsystem; `--selector acf|uniform|cyclic|bandit|importance`) and
//! instrumented with the paper's iteration / operation / wall-clock
//! metrics.
//!
//! | module | problem | paper | experiments |
//! |--------|---------|-------|-------------|
//! | [`lasso`] | L1-regularized least squares | §3.1 | Table 3 |
//! | [`svm`] | linear SVM dual (+ liblinear shrinking baseline) | §3.2 | Tables 5–6, Fig. 2 |
//! | [`mcsvm`] | Weston–Watkins multi-class, subspace descent | §3.3 | Table 8 |
//! | [`logreg`] | dual logistic regression (inner Newton) | §3.4 | Table 9 |

pub mod common;
pub mod lasso;
pub mod logreg;
pub mod mcsvm;
pub mod svm;

pub use common::{SolveResult, SolveStatus, SolverConfig};
