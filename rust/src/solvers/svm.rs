//! Dual coordinate descent for linear SVMs (Hsieh et al., 2008) — the
//! paper's §3.2 testbed (Tables 5–6, Figure 2).
//!
//! Problem (2):
//!
//! ```text
//! min_α  f(α) = ½ Σ_ij α_i α_j y_i y_j ⟨x_i,x_j⟩ − Σ_i α_i
//! s.t.   0 ≤ α_i ≤ C
//! ```
//!
//! One CD step on coordinate `i` is an interval-constrained Newton step
//!
//! ```text
//! α_i ← [ α_i − (y_i⟨w,x_i⟩ − 1) / ⟨x_i,x_i⟩ ]₀^C
//! ```
//!
//! with the model vector `w = Σ α_i y_i x_i` maintained incrementally, so
//! a step costs O(nnz(x_i)). The exact single-step progress
//! `Δf = −(G·d + ½ Q_ii d²)` is a constant-time by-product — exactly what
//! ACF consumes.
//!
//! Two solver entry points:
//! * [`solve`] — generic over a [`Selector`] (any policy from the
//!   [`crate::select`] subsystem: uniform / cyclic / ACF / bandit /
//!   importance), stopping on max-KKT-violation < ε verified by a
//!   full pass;
//! * [`solve_liblinear_shrinking`] — the liblinear baseline: random
//!   permutation epochs plus the shrinking heuristic with warm-restart on
//!   shrink failure (the paper's strongest competitor).

use super::common::{EpochObs, RunState, SolveResult, SolveStatus, SolverConfig};
use crate::select::Selector;
use crate::sparse::Dataset;

/// Trained binary SVM model (dual and primal views).
#[derive(Clone, Debug)]
pub struct SvmModel {
    pub alpha: Vec<f64>,
    pub w: Vec<f64>,
    pub c: f64,
}

impl SvmModel {
    /// Dual objective ½‖w‖² − Σα.
    pub fn objective(&self) -> f64 {
        0.5 * crate::sparse::ops::norm_sq(&self.w) - self.alpha.iter().sum::<f64>()
    }
}

/// Projected-gradient KKT violation of coordinate `i` (the quantity whose
/// maximum defines the stopping criterion; shared with the sharded engine
/// in [`crate::shard`]).
#[inline]
pub(crate) fn pg_violation(alpha_i: f64, g: f64, c: f64) -> f64 {
    if alpha_i <= 0.0 {
        (-g).max(0.0)
    } else if alpha_i >= c {
        g.max(0.0)
    } else {
        g.abs()
    }
}

/// Full KKT verification pass; returns (max violation, ops spent).
/// Software-pipelined: row `i + 1`'s slices are prefetched while row
/// `i`'s gather-dot reduces (a pure hint — results are unchanged).
fn verify_pass(ds: &Dataset, alpha: &[f64], w: &[f64], c: f64) -> (f64, usize) {
    let n = ds.n_instances();
    let mut max_viol = 0.0f64;
    let mut ops = 0usize;
    for i in 0..n {
        let row = ds.x.row(i);
        if i + 1 < n {
            let next = ds.x.row(i + 1);
            crate::sparse::kernels::prefetch_row(next.indices(), next.values());
        }
        let g = ds.y[i] * row.dot_dense(w) - 1.0;
        ops += row.nnz();
        max_viol = max_viol.max(pg_violation(alpha[i], g, c));
    }
    (max_viol, ops)
}

/// Scheduler-driven dual CD. The stopping protocol mirrors liblinear's:
/// once the running max violation over a sweep-sized window falls below
/// ε, a full verification pass over all coordinates confirms (or refutes)
/// convergence.
pub fn solve(
    ds: &Dataset,
    c: f64,
    sched: &mut dyn Selector,
    config: SolverConfig,
) -> (SvmModel, SolveResult) {
    let n = ds.n_instances();
    assert_eq!(sched.n(), n, "selector size must match instance count");
    let d = ds.n_features();
    let q_diag = ds.x.row_norms_sq();
    let mut alpha = vec![0.0f64; n];
    let mut w = vec![0.0f64; d];
    let mut eo = EpochObs::new(&config);
    let mut rs = RunState::new(config);
    let mut status = SolveStatus::IterLimit;
    let mut window_max = 0.0f64;
    let mut window_count = 0usize;
    let mut epochs = 0u64;
    let mut final_viol = f64::INFINITY;

    'outer: loop {
        let i = sched.next();
        let row = ds.x.row(i);
        let yi = ds.y[i];
        let qii = q_diag[i];
        let old = alpha[i];
        // fused kernel: gradient dot + interval-Newton update + scatter
        // on the same hot row slices (sparse::kernels::step_unchecked)
        // NOTE: keep in sync with `crate::shard::svm::ShardedSvm::step`,
        // which carries the same update for the sharded engine
        let mut g = 0.0;
        let mut new = old;
        let (_, _scale) = row.step(&mut w, |dot| {
            g = yi * dot - 1.0;
            new = if qii > 0.0 {
                (old - g / qii).clamp(0.0, c)
            } else if g < 0.0 {
                c
            } else {
                0.0
            };
            (new - old) * yi
        });
        let viol = pg_violation(old, g, c);
        window_max = window_max.max(viol);
        window_count += 1;

        let step_d = new - old;
        let mut ops = row.nnz();
        let mut delta_f = 0.0;
        if step_d != 0.0 {
            alpha[i] = new;
            ops += row.nnz();
            delta_f = -(g * step_d + 0.5 * qii * step_d * step_d);
        }
        sched.report(i, delta_f);

        let budget_ok = rs.step(ops);
        rs.maybe_trace(
            || 0.5 * crate::sparse::ops::norm_sq(&w) - alpha.iter().sum::<f64>(),
            viol,
        );
        if !budget_ok || rs.over_time() {
            if rs.over_time() {
                status = SolveStatus::TimeLimit;
            }
            let (v, extra) = verify_pass(ds, &alpha, &w, c);
            rs.counter.extra(extra);
            final_viol = v;
            break 'outer;
        }

        if window_count >= n {
            epochs += 1;
            eo.epoch(epochs, || {
                0.5 * crate::sparse::ops::norm_sq(&w) - alpha.iter().sum::<f64>()
            });
            if window_max < rs.eps() {
                // candidate convergence: verify over all coordinates
                let (v, extra) = verify_pass(ds, &alpha, &w, c);
                rs.counter.extra(extra);
                if v < rs.eps() {
                    status = SolveStatus::Converged;
                    final_viol = v;
                    break 'outer;
                }
            }
            window_max = 0.0;
            window_count = 0;
        }
    }

    let model = SvmModel { alpha, w, c };
    let obj = model.objective();
    (model, rs.finish(status, obj, final_viol, epochs))
}

/// The liblinear baseline: random-permutation epochs + shrinking.
///
/// Shrinking removes variables at active bounds whose gradients indicate
/// they will stay there (thresholds from the previous epoch's projected
/// gradient range). When the criterion is met on the shrunk problem the
/// solver un-shrinks and re-checks — a failed heuristic costs a warm
/// restart, exactly the failure mode the paper describes (§3.2).
pub fn solve_liblinear_shrinking(
    ds: &Dataset,
    c: f64,
    rng: &mut crate::util::rng::Rng,
    config: SolverConfig,
) -> (SvmModel, SolveResult) {
    let n = ds.n_instances();
    let d = ds.n_features();
    let q_diag = ds.x.row_norms_sq();
    let mut alpha = vec![0.0f64; n];
    let mut w = vec![0.0f64; d];
    let mut eo = EpochObs::new(&config);
    let mut rs = RunState::new(config);
    let mut status = SolveStatus::IterLimit;

    let mut active: Vec<u32> = (0..n as u32).collect();
    let mut pgmax_old = f64::INFINITY;
    let mut pgmin_old = f64::NEG_INFINITY;
    let mut epochs = 0u64;
    let mut final_viol = f64::INFINITY;

    'outer: loop {
        epochs += 1;
        rng.shuffle(&mut active);
        let mut pgmax_new = f64::NEG_INFINITY;
        let mut pgmin_new = f64::INFINITY;
        let mut k = 0usize;
        while k < active.len() {
            let i = active[k] as usize;
            let row = ds.x.row(i);
            let yi = ds.y[i];
            let qii = q_diag[i];
            let old = alpha[i];
            // fused gather-dot / shrink test / Newton scatter: the
            // closure decides the scatter scale (0 = shrink or no move)
            let mut g = 0.0;
            let mut pg = 0.0;
            let mut shrink = false;
            let mut new = old;
            row.step(&mut w, |dot| {
                g = yi * dot - 1.0;
                // shrinking test (liblinear)
                if old <= 0.0 {
                    if g > pgmax_old {
                        shrink = true;
                    } else if g < 0.0 {
                        pg = g;
                    }
                } else if old >= c {
                    if g < pgmin_old {
                        shrink = true;
                    } else if g > 0.0 {
                        pg = g;
                    }
                } else {
                    pg = g;
                }
                if shrink || pg.abs() <= 1e-12 {
                    return 0.0;
                }
                new = if qii > 0.0 {
                    (old - g / qii).clamp(0.0, c)
                } else if g < 0.0 {
                    c
                } else {
                    0.0
                };
                (new - old) * yi
            });
            let mut ops = row.nnz();
            if shrink {
                active.swap_remove(k);
                rs.counter.extra(ops);
                continue; // do not advance k: swapped-in element next
            }
            pgmax_new = pgmax_new.max(pg);
            pgmin_new = pgmin_new.min(pg);

            let step_d = new - old;
            if step_d != 0.0 {
                alpha[i] = new;
                ops += row.nnz();
            }
            let budget_ok = rs.step(ops);
            rs.maybe_trace(
                || 0.5 * crate::sparse::ops::norm_sq(&w) - alpha.iter().sum::<f64>(),
                pg.abs(),
            );
            if !budget_ok || rs.over_time() {
                if rs.over_time() {
                    status = SolveStatus::TimeLimit;
                }
                let (v, extra) = verify_pass(ds, &alpha, &w, c);
                rs.counter.extra(extra);
                final_viol = v;
                break 'outer;
            }
            k += 1;
        }
        eo.epoch(epochs, || 0.5 * crate::sparse::ops::norm_sq(&w) - alpha.iter().sum::<f64>());

        if pgmax_new - pgmin_new <= rs.eps() {
            if active.len() == n {
                status = SolveStatus::Converged;
                let (v, extra) = verify_pass(ds, &alpha, &w, c);
                rs.counter.extra(extra);
                final_viol = v;
                break 'outer;
            }
            // shrinking may have been wrong: restore all variables and
            // loosen the thresholds (warm restart)
            active = (0..n as u32).collect();
            pgmax_old = f64::INFINITY;
            pgmin_old = f64::NEG_INFINITY;
            continue;
        }
        pgmax_old = if pgmax_new > 0.0 { pgmax_new } else { f64::INFINITY };
        pgmin_old = if pgmin_new < 0.0 { pgmin_new } else { f64::NEG_INFINITY };
        if active.is_empty() {
            active = (0..n as u32).collect();
            pgmax_old = f64::INFINITY;
            pgmin_old = f64::NEG_INFINITY;
        }
    }

    let model = SvmModel { alpha, w, c };
    let obj = model.objective();
    (model, rs.finish(status, obj, final_viol, epochs))
}

/// Primal objective (for duality-gap audits in tests):
/// `½λ‖w‖² + (1/ℓ)Σ hinge` with `λ = 1/C` scaled to match the dual's
/// normalization: `P(w) = ½‖w‖² + C Σ hinge(y_i⟨w,x_i⟩)`.
pub fn primal_objective(ds: &Dataset, w: &[f64], c: f64) -> f64 {
    let mut hinge_sum = 0.0;
    for i in 0..ds.n_instances() {
        let m = ds.y[i] * ds.x.row(i).dot_dense(w);
        hinge_sum += (1.0 - m).max(0.0);
    }
    0.5 * crate::sparse::ops::norm_sq(w) + c * hinge_sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acf::AcfParams;
    use crate::data::synth;
    use crate::sched::{AcfSchedulerPolicy, PermutationScheduler, UniformScheduler};
    use crate::sparse::Csr;
    use crate::util::rng::Rng;

    fn toy() -> Dataset {
        // 4 separable points in 2D
        Dataset {
            name: "toy".into(),
            x: Csr::from_rows(
                2,
                vec![
                    vec![(0, 1.0), (1, 1.0)],
                    vec![(0, 2.0), (1, 0.5)],
                    vec![(0, -1.0), (1, -1.0)],
                    vec![(0, -1.5), (1, -0.5)],
                ],
            ),
            y: vec![1.0, 1.0, -1.0, -1.0],
        }
    }

    fn text_ds(seed: u64) -> Dataset {
        synth::sparse_text(
            &synth::SparseTextSpec {
                name: "t",
                n: 300,
                d: 500,
                nnz_per_row: 15,
                zipf_s: 1.0,
                concept_k: 30,
                noise: 0.05,
            },
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn converges_on_toy_and_separates() {
        let ds = toy();
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(1));
        let (model, res) = solve(&ds, 1.0, &mut sched, SolverConfig::with_eps(1e-4));
        assert!(res.status.converged(), "{}", res.summary());
        for i in 0..ds.n_instances() {
            let m = ds.y[i] * ds.x.row(i).dot_dense(&model.w);
            assert!(m > 0.0, "point {i} misclassified");
        }
    }

    #[test]
    fn kkt_conditions_hold_at_solution() {
        let ds = toy();
        let c = 2.0;
        let mut sched = UniformScheduler::new(ds.n_instances(), Rng::new(2));
        let (model, res) = solve(&ds, c, &mut sched, SolverConfig::with_eps(1e-6));
        assert!(res.status.converged());
        for i in 0..ds.n_instances() {
            let g = ds.y[i] * ds.x.row(i).dot_dense(&model.w) - 1.0;
            let v = pg_violation(model.alpha[i], g, c);
            assert!(v < 1e-5, "coord {i}: violation {v}");
        }
        // box feasibility
        assert!(model.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
    }

    #[test]
    fn duality_gap_closes() {
        let ds = text_ds(3);
        let c = 1.0;
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(3));
        let (model, res) = solve(&ds, c, &mut sched, SolverConfig::with_eps(1e-5));
        assert!(res.status.converged());
        let dual = -res.objective; // our f is the min form: dual value = −f
        let primal = primal_objective(&ds, &model.w, c);
        let gap = (primal - dual) / primal.abs().max(1.0);
        assert!(gap >= -1e-9, "weak duality violated: {gap}");
        assert!(gap < 1e-3, "gap {gap}");
    }

    #[test]
    fn acf_and_baseline_reach_same_objective() {
        let ds = text_ds(4);
        let c = 10.0;
        let cfg = SolverConfig::with_eps(1e-3);
        let mut perm = PermutationScheduler::new(ds.n_instances(), Rng::new(4));
        let (_, r1) = solve(&ds, c, &mut perm, cfg.clone());
        let mut acf =
            AcfSchedulerPolicy::new(ds.n_instances(), AcfParams::default(), Rng::new(5));
        let (_, r2) = solve(&ds, c, &mut acf, cfg);
        assert!(r1.status.converged() && r2.status.converged());
        let rel = (r1.objective - r2.objective).abs() / r1.objective.abs().max(1.0);
        assert!(rel < 1e-3, "objectives differ: {} vs {}", r1.objective, r2.objective);
    }

    #[test]
    fn shrinking_matches_plain_solution() {
        let ds = text_ds(6);
        let c = 1.0;
        let cfg = SolverConfig::with_eps(1e-4);
        let mut rng = Rng::new(7);
        let (m1, r1) = solve_liblinear_shrinking(&ds, c, &mut rng, cfg.clone());
        let mut perm = PermutationScheduler::new(ds.n_instances(), Rng::new(8));
        let (m2, r2) = solve(&ds, c, &mut perm, cfg);
        assert!(r1.status.converged() && r2.status.converged());
        let rel = (r1.objective - r2.objective).abs() / r1.objective.abs().max(1.0);
        assert!(rel < 1e-3, "{} vs {}", r1.objective, r2.objective);
        // both models classify the training set the same way
        let acc1 = crate::data::split::binary_accuracy(&ds, &m1.w);
        let acc2 = crate::data::split::binary_accuracy(&ds, &m2.w);
        assert!((acc1 - acc2).abs() < 0.02, "{acc1} vs {acc2}");
    }

    #[test]
    fn objective_monotone_under_trace() {
        let ds = text_ds(9);
        let cfg = SolverConfig { eps: 1e-3, trace_every: 50, ..Default::default() };
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(9));
        let (_, res) = solve(&ds, 1.0, &mut sched, cfg);
        assert!(res.trace.points.len() > 2);
        res.trace.check_monotone(1e-9).expect("objective must not increase");
    }

    #[test]
    fn iteration_cap_reports_dnf() {
        let ds = text_ds(10);
        let cfg = SolverConfig { eps: 1e-9, max_iterations: 500, ..Default::default() };
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(10));
        let (_, res) = solve(&ds, 1000.0, &mut sched, cfg);
        assert_eq!(res.status, SolveStatus::IterLimit);
        assert_eq!(res.iterations, 500);
    }

    #[test]
    fn empty_rows_handled() {
        let ds = Dataset {
            name: "empty-row".into(),
            x: Csr::from_rows(2, vec![vec![(0, 1.0)], vec![], vec![(0, -1.0)]]),
            y: vec![1.0, 1.0, -1.0],
        };
        let mut sched = PermutationScheduler::new(3, Rng::new(11));
        let (model, res) = solve(&ds, 1.5, &mut sched, SolverConfig::with_eps(1e-5));
        assert!(res.status.converged());
        // empty row's alpha must sit at C (gradient −1 throughout)
        assert!((model.alpha[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ops_counted_reasonably() {
        let ds = toy();
        let mut sched = PermutationScheduler::new(ds.n_instances(), Rng::new(12));
        let (_, res) = solve(&ds, 1.0, &mut sched, SolverConfig::with_eps(1e-4));
        // every iteration costs at least one op on this dense-ish toy
        assert!(res.ops >= res.iterations);
    }
}
