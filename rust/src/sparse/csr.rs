//! Compressed sparse row (CSR) matrix — the instance-major layout used by
//! the dual solvers (SVM, logistic regression, multi-class SVM), where a
//! CD step on dual variable `α_i` touches exactly row `i`.

use super::kernels;
use std::sync::OnceLock;

/// CSR sparse matrix with f64 values and usize column indices.
///
/// Invariants: `indptr.len() == rows + 1`, `indptr` non-decreasing,
/// `indices[indptr[r]..indptr[r+1]]` strictly increasing per row, all
/// `indices[k] < cols`.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
    /// Lazily-computed per-row squared norms (`Q_ii` for the dual
    /// solvers, column norms for the transposed LASSO view). `Csr` has
    /// no mutating methods, so the cache can never go stale.
    norms_sq: OnceLock<Vec<f64>>,
}

// Structural equality only — the norm cache is derived state.
impl PartialEq for Csr {
    fn eq(&self, other: &Csr) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.indptr == other.indptr
            && self.indices == other.indices
            && self.values == other.values
    }
}

/// Borrowed view of one sparse row.
///
/// Invariant: `indices` is strictly increasing (inherited from the
/// [`Csr`] row it was sliced from, or validated by [`RowView::new`]).
/// The hot-path methods rely on it for their O(1) bounds proof — see
/// [`crate::sparse::kernels`] — so the fields are private: every
/// `RowView` reachable from safe code upholds the invariant.
#[derive(Clone, Copy, Debug)]
pub struct RowView<'a> {
    indices: &'a [u32],
    values: &'a [f64],
}

impl<'a> RowView<'a> {
    /// Build a view from raw slices, validating the strictly-increasing
    /// invariant (release-grade — this constructor is what keeps the
    /// unchecked kernels sound for hand-built views; `Csr::row` skips it
    /// because construction already established the invariant).
    pub fn new(indices: &'a [u32], values: &'a [f64]) -> RowView<'a> {
        assert_eq!(indices.len(), values.len(), "RowView slice length mismatch");
        assert!(
            indices.windows(2).all(|p| p[0] < p[1]),
            "RowView indices must be strictly increasing"
        );
        RowView { indices, values }
    }

    #[inline]
    pub fn indices(&self) -> &'a [u32] {
        self.indices
    }

    #[inline]
    pub fn values(&self) -> &'a [f64] {
        self.values
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// O(1) soundness gate for the unchecked kernels: row indices are
    /// strictly increasing, so the last one bounds them all.
    #[inline(always)]
    fn check_bounds(&self, dim: usize) {
        debug_assert_eq!(self.indices.len(), self.values.len());
        debug_assert!(
            self.indices.windows(2).all(|p| p[0] < p[1]),
            "RowView indices must be strictly increasing"
        );
        if let Some(&last) = self.indices.last() {
            assert!((last as usize) < dim, "row index {last} out of bounds for dimension {dim}");
        }
    }

    /// Dot product against a dense vector (unrolled unchecked kernel;
    /// the bounds of every gather are established in O(1) by
    /// [`Self::check_bounds`]).
    #[inline]
    pub fn dot_dense(&self, w: &[f64]) -> f64 {
        self.check_bounds(w.len());
        // SAFETY: check_bounds proved indices.last() < w.len(), and the
        // strictly-increasing row invariant bounds every other index.
        unsafe { kernels::dot_dense_unchecked(self.indices, self.values, w) }
    }

    /// w += scale * row (unrolled unchecked scatter-add).
    #[inline]
    pub fn axpy_into(&self, scale: f64, w: &mut [f64]) {
        self.check_bounds(w.len());
        // SAFETY: as in dot_dense.
        unsafe { kernels::axpy_unchecked(scale, self.indices, self.values, w) }
    }

    /// Fused CD step: gather-dot, O(1) coordinate update (the closure
    /// maps the dot to the scatter scale, `0.0` = no update), scatter —
    /// all on the same cache-hot row slices. Returns `(dot, scale)`.
    #[inline]
    pub fn step<F: FnOnce(f64) -> f64>(&self, w: &mut [f64], update: F) -> (f64, f64) {
        self.check_bounds(w.len());
        // SAFETY: as in dot_dense; w is only written at the same indices
        // that were gathered.
        unsafe { kernels::step_unchecked(self.indices, self.values, w, update) }
    }

    /// Squared Euclidean norm of the row.
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        kernels::dot(self.values, self.values)
    }
}

impl Csr {
    /// Build from triplet rows: `rows_data[r]` is a list of (col, value)
    /// pairs (will be sorted and deduplicated by summation).
    pub fn from_rows(cols: usize, rows_data: Vec<Vec<(usize, f64)>>) -> Csr {
        let rows = rows_data.len();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for mut row in rows_data {
            row.sort_unstable_by_key(|&(c, _)| c);
            let mut last: Option<usize> = None;
            for (c, v) in row {
                assert!(c < cols, "column index {c} out of bounds ({cols})");
                if last == Some(c) {
                    // duplicate column: accumulate
                    *values.last_mut().unwrap() += v;
                } else if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                    last = Some(c);
                } else {
                    last = Some(c);
                    // skip explicit zeros, but remember the column so a
                    // duplicate still merges correctly
                    indices.push(c as u32);
                    values.push(0.0);
                }
            }
            indptr.push(indices.len());
        }
        Csr { rows, cols, indptr, indices, values, norms_sq: OnceLock::new() }
    }

    /// Build from raw parts. Validated with release-grade asserts
    /// (O(nnz), construction-time only): the hot-path kernels rely on
    /// the strictly-increasing row invariant for their unchecked
    /// indexing, so an invalid `Csr` must be impossible to construct
    /// from safe code.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Csr {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length mismatch");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr endpoint");
        let m = Csr { rows, cols, indptr, indices, values, norms_sq: OnceLock::new() };
        if let Err(e) = m.check_invariants() {
            panic!("Csr::from_parts: invalid structure: {e}");
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn row(&self, r: usize) -> RowView<'_> {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        RowView { indices: &self.indices[lo..hi], values: &self.values[lo..hi] }
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Per-row squared norms, computed once and cached on the matrix.
    /// Every solver that needs `Q_ii` (svm / logreg / mcsvm / the shard
    /// fronts) borrows this slice instead of recomputing its own copy.
    pub fn row_norms_sq(&self) -> &[f64] {
        self.norms_sq.get_or_init(|| (0..self.rows).map(|r| self.row(r).norm_sq()).collect())
    }

    /// Dense matvec `y = A x` (reference / validation path).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|r| self.row(r).dot_dense(x)).collect()
    }

    /// Transposed matvec `y = Aᵀ x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            self.row(r).axpy_into(x[r], &mut y);
        }
        y
    }

    /// Transpose to CSC-equivalent CSR (i.e. a CSR matrix of the
    /// transpose). Counting sort over columns — O(nnz + cols).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for &j in &self.indices {
            counts[j as usize + 1] += 1;
        }
        for c in 0..self.cols {
            counts[c + 1] += counts[c];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        for r in 0..self.rows {
            let row = self.row(r);
            for (&j, &v) in row.indices.iter().zip(row.values.iter()) {
                let dst = cursor[j as usize];
                indices[dst] = r as u32;
                values[dst] = v;
                cursor[j as usize] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values, norms_sq: OnceLock::new() }
    }

    /// Extract a dense row-major block [r0..r1) × [c0..c1), padded with
    /// zeros; used by the PJRT validator which runs on fixed-shape tiles.
    pub fn dense_block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Vec<f32> {
        let h = r1 - r0;
        let w = c1 - c0;
        let mut out = vec![0.0f32; h * w];
        for r in r0..r1.min(self.rows) {
            let row = self.row(r);
            for (&j, &v) in row.indices.iter().zip(row.values.iter()) {
                let j = j as usize;
                if j >= c0 && j < c1 {
                    out[(r - r0) * w + (j - c0)] = v as f32;
                }
            }
        }
        out
    }

    /// Convert the full matrix to a dense row-major f64 buffer (tests /
    /// tiny problems only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for (&j, &v) in row.indices.iter().zip(row.values.iter()) {
                out[r * self.cols + j as usize] = v;
            }
        }
        out
    }

    /// Select a subset of rows (dataset splits).
    pub fn select_rows(&self, idx: &[usize]) -> Csr {
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &r in idx {
            let row = self.row(r);
            indices.extend_from_slice(row.indices);
            values.extend_from_slice(row.values);
            indptr.push(indices.len());
        }
        Csr { rows: idx.len(), cols: self.cols, indptr, indices, values, norms_sq: OnceLock::new() }
    }

    /// Validate structural invariants (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err("indptr length".into());
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.indices.len() {
            return Err("indptr endpoints".into());
        }
        for r in 0..self.rows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr decreasing at {r}"));
            }
            let row = self.row(r);
            for w in row.indices.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} indices not strictly increasing"));
                }
            }
            if let Some(&j) = row.indices.last() {
                if j as usize >= self.cols {
                    return Err(format!("row {r} column out of bounds"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        Csr::from_rows(3, vec![vec![(0, 1.0), (2, 2.0)], vec![], vec![(1, 4.0), (0, 3.0)]])
    }

    #[test]
    fn construction_sorts_and_counts() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(2).indices, &[0, 1]);
        assert_eq!(m.row(2).values, &[3.0, 4.0]);
        m.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_columns_accumulate() {
        let m = Csr::from_rows(4, vec![vec![(1, 2.0), (1, 3.0), (0, 1.0)]]);
        assert_eq!(m.row(0).indices, &[0, 1]);
        assert_eq!(m.row(0).values, &[1.0, 5.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(m.matvec(&x), vec![7.0, 0.0, 11.0]);
        let y = vec![1.0, 1.0, 1.0];
        assert_eq!(m.matvec_t(&y), vec![4.0, 4.0, 2.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        t.check_invariants().unwrap();
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_matches_dense_property() {
        prop::check(50, |g| {
            let rows = g.usize_in(1, 20);
            let cols = g.usize_in(1, 20);
            let mut data = Vec::new();
            for _ in 0..rows {
                let k = g.usize_in(0, cols.min(8));
                let pat = g.sparse_pattern(cols, k);
                data.push(pat.into_iter().map(|c| (c, g.f64_in(-2.0, 2.0))).collect());
            }
            let m = Csr::from_rows(cols, data);
            m.check_invariants()?;
            let t = m.transpose();
            t.check_invariants()?;
            let d = m.to_dense();
            let td = t.to_dense();
            for r in 0..rows {
                for c in 0..cols {
                    prop::assert_close(d[r * cols + c], td[c * rows + r], 1e-12, "transpose")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matvec_t_matches_transpose_matvec_property() {
        prop::check(30, |g| {
            let rows = g.usize_in(1, 15);
            let cols = g.usize_in(1, 15);
            let mut data = Vec::new();
            for _ in 0..rows {
                let k = g.usize_in(0, cols.min(6));
                let pat = g.sparse_pattern(cols, k);
                data.push(pat.into_iter().map(|c| (c, g.f64_in(-1.0, 1.0))).collect());
            }
            let m = Csr::from_rows(cols, data);
            let x = g.vec_f64(rows, -3.0, 3.0);
            let a = m.matvec_t(&x);
            let b = m.transpose().matvec(&x);
            for (u, v) in a.iter().zip(b.iter()) {
                prop::assert_close(*u, *v, 1e-12, "matvec_t == transpose.matvec")?;
            }
            Ok(())
        });
    }

    #[test]
    fn dense_block_extraction() {
        let m = sample();
        let b = m.dense_block(0, 2, 1, 3); // rows 0..2, cols 1..3
        assert_eq!(b, vec![0.0, 2.0, 0.0, 0.0]);
        // padding beyond matrix bounds
        let b2 = m.dense_block(2, 4, 0, 2);
        assert_eq!(b2, vec![3.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn select_rows_subsets() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row(0).values, &[3.0, 4.0]);
        assert_eq!(s.row(1).values, &[1.0, 2.0]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn norms_cached_and_correct() {
        let m = sample();
        let n = m.row_norms_sq();
        assert_eq!(n, &[5.0, 0.0, 25.0]);
        // second call must hand back the same cached allocation
        assert!(std::ptr::eq(n.as_ptr(), m.row_norms_sq().as_ptr()));
        // clones answer identically (whether they copy or recompute)
        assert_eq!(m.clone().row_norms_sq(), &[5.0, 0.0, 25.0]);
    }

    #[test]
    fn equality_ignores_norm_cache() {
        let a = sample();
        let b = sample();
        let _ = a.row_norms_sq(); // warm only one side's cache
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn dot_dense_rejects_short_vector() {
        let m = sample();
        let w = vec![0.0; 2]; // cols = 3: the O(1) gate must fire
        m.row(0).dot_dense(&w);
    }
}
