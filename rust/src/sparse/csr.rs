//! Compressed sparse row (CSR) matrix — the instance-major layout used by
//! the dual solvers (SVM, logistic regression, multi-class SVM), where a
//! CD step on dual variable `α_i` touches exactly row `i`.
//!
//! # Storage backends
//!
//! Since the out-of-core data plane landed, a [`Csr`] is a thin facade
//! over one of three [`CsrStorage`] backends:
//!
//! * **Owned** — the classic three-array layout (`indptr`/`indices`/
//!   `values` in `Vec`s). Produced by [`Csr::from_rows`] /
//!   [`Csr::from_parts`] and the in-memory libsvm parser.
//! * **Mapped** — zero-copy views over the sections of a memory-mapped
//!   `.acfbin` file ([`crate::sparse::storage`]). Row access costs two
//!   `u64` loads from the mapped row-pointer section plus two slice
//!   constructions; the kernel pages the value/index sections in on
//!   demand, so datasets much larger than RAM stay trainable and cold
//!   starts skip parsing entirely.
//! * **Chunked** — rows grouped into fixed-size chunks, each chunk its
//!   own small three-array block. This is the bounded-memory shape the
//!   streaming libsvm parser ([`crate::sparse::ingest`]) builds, and a
//!   backend in its own right for callers that want owned data without
//!   one giant allocation per array.
//!
//! Every backend serves rows through the same [`RowView`] type, so the
//! solvers, kernels, and the sharded engine are backend-oblivious; the
//! round-trip property tests in `storage`/`ingest` pin mapped and
//! chunked rows bit-identical to owned rows.
//!
//! ```
//! use acf_cd::sparse::Csr;
//! let m = Csr::from_rows(3, vec![vec![(0, 1.0), (2, 2.0)], vec![], vec![(1, 4.0)]]);
//! assert_eq!(m.storage_kind(), "owned");
//! let chunked = m.to_chunked(2);
//! assert_eq!(chunked.storage_kind(), "chunked");
//! assert_eq!(chunked, m); // equality is structural, backend-oblivious
//! assert_eq!(chunked.row(0).dot_dense(&[1.0, 1.0, 1.0]), 3.0);
//! ```

use super::kernels;
use crate::util::mmap::{Mmap, PAGE_SIZE};
use std::sync::{Arc, OnceLock};

/// CSR sparse matrix with f64 values and u32 column indices.
///
/// Invariants (upheld by every backend, validated at construction):
/// row pointers non-decreasing with `indptr[0] == 0` and
/// `indptr[rows] == nnz`, `indices` strictly increasing per row, all
/// `indices[k] < cols`.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    storage: CsrStorage,
    /// Lazily-computed per-row squared norms (`Q_ii` for the dual
    /// solvers, column norms for the transposed LASSO view). `Csr` has
    /// no mutating methods, so the cache can never go stale. For mapped
    /// matrices the cache is pre-seeded from the `.acfbin` norms
    /// section, which was written with the same kernel at ingest time —
    /// bit-identical to recomputation, without touching the value pages.
    norms_sq: OnceLock<Vec<f64>>,
}

/// The physical layout behind a [`Csr`] — see the module docs for when
/// each backend is produced.
#[derive(Clone, Debug)]
pub enum CsrStorage {
    /// Heap-owned three-array CSR.
    Owned { indptr: Vec<usize>, indices: Vec<u32>, values: Vec<f64> },
    /// Zero-copy sections of a memory-mapped `.acfbin` file.
    Mapped(MappedCsr),
    /// Fixed-size row chunks, each an independent owned block.
    Chunked(ChunkedCsr),
}

// Structural equality only — backends and the norm cache are physical
// details; two matrices are equal when every row serves the same
// indices and (bit-identical) values.
impl PartialEq for Csr {
    fn eq(&self, other: &Csr) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.nnz() == other.nnz()
            && (0..self.rows).all(|r| {
                let a = self.row(r);
                let b = other.row(r);
                a.indices == b.indices && a.values == b.values
            })
    }
}

/// Borrowed view of one sparse row.
///
/// # Safety contract
///
/// Invariant: `indices` is strictly increasing (inherited from the
/// [`Csr`] row it was sliced from, or validated by [`RowView::new`]).
/// The hot-path methods rely on it for their O(1) bounds proof — the
/// last index bounds all of them — before calling the unchecked
/// gather/scatter kernels in [`crate::sparse::kernels`]. The fields are
/// private so every `RowView` reachable from safe code upholds the
/// invariant: `Csr` construction validates it for all three storage
/// backends (including untrusted mapped files), and hand-built views
/// must pass [`RowView::new`].
///
/// ```
/// use acf_cd::sparse::RowView;
/// let row = RowView::new(&[0, 3, 7], &[1.0, -2.0, 0.5]);
/// let mut w = vec![0.0; 8];
/// row.axpy_into(2.0, &mut w);
/// assert_eq!(w[3], -4.0);
/// assert_eq!(row.dot_dense(&w), 2.0 * row.norm_sq());
/// ```
#[derive(Clone, Copy, Debug)]
pub struct RowView<'a> {
    indices: &'a [u32],
    values: &'a [f64],
}

impl<'a> RowView<'a> {
    /// Build a view from raw slices, validating the strictly-increasing
    /// invariant (release-grade — this constructor is what keeps the
    /// unchecked kernels sound for hand-built views; `Csr::row` skips it
    /// because construction already established the invariant).
    pub fn new(indices: &'a [u32], values: &'a [f64]) -> RowView<'a> {
        assert_eq!(indices.len(), values.len(), "RowView slice length mismatch");
        assert!(
            indices.windows(2).all(|p| p[0] < p[1]),
            "RowView indices must be strictly increasing"
        );
        RowView { indices, values }
    }

    #[inline]
    pub fn indices(&self) -> &'a [u32] {
        self.indices
    }

    #[inline]
    pub fn values(&self) -> &'a [f64] {
        self.values
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// O(1) soundness gate for the unchecked kernels: row indices are
    /// strictly increasing, so the last one bounds them all.
    #[inline(always)]
    fn check_bounds(&self, dim: usize) {
        debug_assert_eq!(self.indices.len(), self.values.len());
        debug_assert!(
            self.indices.windows(2).all(|p| p[0] < p[1]),
            "RowView indices must be strictly increasing"
        );
        if let Some(&last) = self.indices.last() {
            assert!((last as usize) < dim, "row index {last} out of bounds for dimension {dim}");
        }
    }

    /// Dot product against a dense vector (unchecked kernel on the
    /// runtime-dispatched SIMD tier — see [`crate::sparse::kernels`];
    /// bit-identical across tiers. The bounds of every gather are
    /// established in O(1) by [`Self::check_bounds`]).
    #[inline]
    pub fn dot_dense(&self, w: &[f64]) -> f64 {
        self.check_bounds(w.len());
        // SAFETY: check_bounds proved indices.last() < w.len(), and the
        // strictly-increasing row invariant bounds every other index.
        unsafe { kernels::dot_dense_unchecked(self.indices, self.values, w) }
    }

    /// w += scale * row (unrolled unchecked scatter-add).
    #[inline]
    pub fn axpy_into(&self, scale: f64, w: &mut [f64]) {
        self.check_bounds(w.len());
        // SAFETY: as in dot_dense.
        unsafe { kernels::axpy_unchecked(scale, self.indices, self.values, w) }
    }

    /// Fused CD step: gather-dot, O(1) coordinate update (the closure
    /// maps the dot to the scatter scale, `0.0` = no update), scatter —
    /// all on the same cache-hot row slices. Returns `(dot, scale)`.
    #[inline]
    pub fn step<F: FnOnce(f64) -> f64>(&self, w: &mut [f64], update: F) -> (f64, f64) {
        self.check_bounds(w.len());
        // SAFETY: as in dot_dense; w is only written at the same indices
        // that were gathered.
        unsafe { kernels::step_unchecked(self.indices, self.values, w, update) }
    }

    /// Squared Euclidean norm of the row.
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        kernels::dot(self.values, self.values)
    }
}

/// Sort a triplet row by column and merge duplicate columns by
/// summation, preserving explicit zeros. This is the **single**
/// normalization every row-producing path applies — [`Csr::from_rows`],
/// the in-memory libsvm parser, and the streaming `.acfbin` ingest — so
/// the same input text yields bit-identical rows no matter which path
/// parsed it.
pub(crate) fn normalize_row(mut row: Vec<(usize, f64)>) -> (Vec<u32>, Vec<f64>) {
    row.sort_unstable_by_key(|&(c, _)| c);
    let mut indices = Vec::with_capacity(row.len());
    let mut values: Vec<f64> = Vec::with_capacity(row.len());
    let mut last: Option<usize> = None;
    for (c, v) in row {
        if last == Some(c) {
            // duplicate column: accumulate
            // INFALLIBLE: `last == Some(c)` implies a prior push.
            *values.last_mut().unwrap() += v;
        } else {
            debug_assert!(c <= u32::MAX as usize, "column index {c} exceeds u32");
            indices.push(c as u32);
            values.push(v);
            last = Some(c);
        }
    }
    (indices, values)
}

/// Zero-copy CSR sections of a memory-mapped `.acfbin` file.
///
/// Holds raw pointers into the mapping alongside the [`Arc<Mmap>`] that
/// keeps the bytes alive — the mapping's buffer address is stable for
/// its lifetime (a kernel mapping never moves; the heap fallback's
/// buffer is owned by the `Mmap` and never reallocated), so the
/// pointers remain valid for as long as the `Arc` does. Cloning is
/// cheap: an `Arc` bump plus pointer copies.
///
/// Construction ([`MappedCsr::new`]) performs the same release-grade
/// O(nnz) invariant validation as [`Csr::from_parts`]; a mapped file is
/// untrusted input, and the unchecked kernels are only sound over rows
/// whose indices are strictly increasing and bounded by `cols`.
#[derive(Clone, Debug)]
pub struct MappedCsr {
    /// keeps the mapped bytes alive; pointers below point into it
    map: Arc<Mmap>,
    indptr: *const u64,
    indices: *const u32,
    values: *const f64,
    rows: usize,
    nnz: usize,
    /// byte offsets of the sections within the map (page-locality probes)
    values_off: usize,
    indices_off: usize,
}

// SAFETY: the pointers target the immutable buffer owned by `map`
// (read-only for the lifetime of the Arc — see `Mmap`'s contract), so
// shared references across threads are sound.
unsafe impl Send for MappedCsr {}
// SAFETY: shared access is read-only (same argument as for `Send`).
unsafe impl Sync for MappedCsr {}

impl MappedCsr {
    /// Build zero-copy sections over `map`, validating layout (bounds,
    /// 8-/4-byte alignment of each section) and the full CSR structural
    /// invariants. Errors name the failing byte offset — the file is
    /// untrusted input.
    pub(crate) fn new(
        map: Arc<Mmap>,
        rows: usize,
        cols: usize,
        nnz: usize,
        indptr_off: usize,
        values_off: usize,
        indices_off: usize,
    ) -> Result<MappedCsr, String> {
        let total = map.len();
        // checked arithmetic throughout: the header fields are untrusted,
        // and a wrapped size here would defeat the bounds proof below
        let need = |off: usize, bytes: Option<usize>, what: &str| -> Result<(), String> {
            match bytes.and_then(|b| off.checked_add(b)) {
                Some(end) if end <= total => Ok(()),
                _ => Err(format!("{what} section at byte offset {off} overruns the {total}-byte mapping")),
            }
        };
        need(indptr_off, rows.checked_add(1).and_then(|r| r.checked_mul(8)), "row-pointer")?;
        need(values_off, nnz.checked_mul(8), "values")?;
        need(indices_off, nnz.checked_mul(4), "indices")?;
        let base = map.as_bytes().as_ptr();
        debug_assert_eq!(base as usize % 8, 0, "Mmap guarantees 8-aligned base");
        for (off, align, what) in
            [(indptr_off, 8, "row-pointer"), (values_off, 8, "values"), (indices_off, 4, "indices")]
        {
            if off % align != 0 {
                return Err(format!("{what} section offset {off} is not {align}-byte aligned"));
            }
        }
        // SAFETY: bounds and alignment of every section were just
        // proven against the live mapping.
        let m = unsafe {
            MappedCsr {
                indptr: base.add(indptr_off) as *const u64,
                values: base.add(values_off) as *const f64,
                indices: base.add(indices_off) as *const u32,
                map,
                rows,
                nnz,
                values_off,
                indices_off,
            }
        };
        m.validate(cols, indptr_off, indices_off)?;
        Ok(m)
    }

    /// Release-grade O(nnz) structural validation (the mapped analog of
    /// `Csr::from_parts`' asserts), with byte offsets in every error.
    fn validate(&self, cols: usize, indptr_off: usize, indices_off: usize) -> Result<(), String> {
        let ip = |r: usize| -> u64 {
            // SAFETY: r <= rows, and the section holds rows+1 u64s.
            unsafe { *self.indptr.add(r) }
        };
        if ip(0) != 0 {
            return Err(format!("indptr[0] = {} (expected 0) at byte offset {indptr_off}", ip(0)));
        }
        if ip(self.rows) != self.nnz as u64 {
            return Err(format!(
                "indptr[{}] = {} does not match nnz {} (byte offset {})",
                self.rows,
                ip(self.rows),
                self.nnz,
                indptr_off + self.rows * 8
            ));
        }
        for r in 0..self.rows {
            let (lo, hi) = (ip(r), ip(r + 1));
            if lo > hi {
                return Err(format!(
                    "indptr decreasing at row {r} (byte offset {})",
                    indptr_off + (r + 1) * 8
                ));
            }
            if hi > self.nnz as u64 {
                return Err(format!(
                    "indptr[{}] = {hi} exceeds nnz {} (byte offset {})",
                    r + 1,
                    self.nnz,
                    indptr_off + (r + 1) * 8
                ));
            }
            let mut prev: Option<u32> = None;
            for k in lo..hi {
                // SAFETY: k < nnz, proven by the indptr checks above.
                let j = unsafe { *self.indices.add(k as usize) };
                if prev.is_some_and(|p| p >= j) {
                    return Err(format!(
                        "row {r}: indices not strictly increasing (byte offset {})",
                        indices_off + k as usize * 4
                    ));
                }
                if j as usize >= cols {
                    return Err(format!(
                        "row {r}: column {j} out of bounds for {cols} columns (byte offset {})",
                        indices_off + k as usize * 4
                    ));
                }
                prev = Some(j);
            }
        }
        Ok(())
    }

    #[inline]
    fn bounds(&self, r: usize) -> (usize, usize) {
        assert!(r < self.rows, "row {r} out of bounds ({} rows)", self.rows);
        // SAFETY: r + 1 <= rows; the section holds rows + 1 entries, and
        // construction proved every entry <= nnz.
        unsafe { (*self.indptr.add(r) as usize, *self.indptr.add(r + 1) as usize) }
    }

    #[inline]
    fn row(&self, r: usize) -> RowView<'_> {
        let (lo, hi) = self.bounds(r);
        // SAFETY: lo <= hi <= nnz (validated at construction), and the
        // sections hold nnz elements inside the live mapping.
        unsafe {
            RowView {
                indices: std::slice::from_raw_parts(self.indices.add(lo), hi - lo),
                values: std::slice::from_raw_parts(self.values.add(lo), hi - lo),
            }
        }
    }

    /// The mapping this matrix reads from (backing kind, page counts).
    pub fn map(&self) -> &Mmap {
        &self.map
    }
}

/// Owned CSR rows grouped into fixed-size chunks — the bounded-memory
/// layout the streaming libsvm parser builds (each chunk becomes one
/// allocation instead of three matrix-sized ones).
#[derive(Clone, Debug)]
pub struct ChunkedCsr {
    /// rows per chunk (every chunk but the last holds exactly this many)
    chunk_rows: usize,
    rows: usize,
    nnz: usize,
    chunks: Vec<CsrChunk>,
}

#[derive(Clone, Debug)]
struct CsrChunk {
    /// global nnz offset of this chunk's first entry (extent accounting)
    base_nnz: usize,
    /// chunk-local row pointers, `indptr[0] == 0`
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl ChunkedCsr {
    pub(crate) fn new(chunk_rows: usize) -> ChunkedCsr {
        assert!(chunk_rows > 0, "chunk_rows must be positive");
        ChunkedCsr { chunk_rows, rows: 0, nnz: 0, chunks: Vec::new() }
    }

    /// Append one row. Release-grade validation, as in
    /// [`Csr::from_parts`]: chunked rows feed the unchecked kernels too.
    pub(crate) fn push_row(&mut self, indices: &[u32], values: &[f64]) {
        assert_eq!(indices.len(), values.len(), "indices/values length mismatch");
        assert!(
            indices.windows(2).all(|p| p[0] < p[1]),
            "row indices must be strictly increasing"
        );
        if self.rows % self.chunk_rows == 0 {
            self.chunks.push(CsrChunk {
                base_nnz: self.nnz,
                indptr: vec![0],
                indices: Vec::new(),
                values: Vec::new(),
            });
        }
        // INFALLIBLE: the first row of every chunk pushes one above.
        let chunk = self.chunks.last_mut().expect("chunk pushed above");
        chunk.indices.extend_from_slice(indices);
        chunk.values.extend_from_slice(values);
        chunk.indptr.push(chunk.indices.len());
        self.rows += 1;
        self.nnz += indices.len();
    }

    #[inline]
    fn locate(&self, r: usize) -> (&CsrChunk, usize) {
        assert!(r < self.rows, "row {r} out of bounds ({} rows)", self.rows);
        (&self.chunks[r / self.chunk_rows], r % self.chunk_rows)
    }

    #[inline]
    fn bounds(&self, r: usize) -> (usize, usize) {
        let (chunk, local) = self.locate(r);
        (chunk.base_nnz + chunk.indptr[local], chunk.base_nnz + chunk.indptr[local + 1])
    }

    #[inline]
    fn row(&self, r: usize) -> RowView<'_> {
        let (chunk, local) = self.locate(r);
        let lo = chunk.indptr[local];
        let hi = chunk.indptr[local + 1];
        RowView { indices: &chunk.indices[lo..hi], values: &chunk.values[lo..hi] }
    }

    /// Number of chunks (diagnostics).
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }
}

impl Csr {
    /// Build from triplet rows: `rows_data[r]` is a list of (col, value)
    /// pairs (will be sorted and deduplicated by summation).
    ///
    /// ```
    /// use acf_cd::sparse::Csr;
    /// let m = Csr::from_rows(4, vec![vec![(2, 1.0), (0, 3.0)], vec![(1, -1.0)]]);
    /// assert_eq!(m.row(0).indices(), &[0, 2]); // sorted per row
    /// assert_eq!(m.nnz(), 3);
    /// ```
    pub fn from_rows(cols: usize, rows_data: Vec<Vec<(usize, f64)>>) -> Csr {
        let rows = rows_data.len();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for row in rows_data {
            for &(c, _) in &row {
                assert!(c < cols, "column index {c} out of bounds ({cols})");
            }
            let (ri, rv) = normalize_row(row);
            indices.extend_from_slice(&ri);
            values.extend_from_slice(&rv);
            indptr.push(indices.len());
        }
        Csr {
            rows,
            cols,
            storage: CsrStorage::Owned { indptr, indices, values },
            norms_sq: OnceLock::new(),
        }
    }

    /// Build from raw parts. Validated with release-grade asserts
    /// (O(nnz), construction-time only): the hot-path kernels rely on
    /// the strictly-increasing row invariant for their unchecked
    /// indexing, so an invalid `Csr` must be impossible to construct
    /// from safe code.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Csr {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length mismatch");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr endpoint");
        let m = Csr {
            rows,
            cols,
            storage: CsrStorage::Owned { indptr, indices, values },
            norms_sq: OnceLock::new(),
        };
        if let Err(e) = m.check_invariants() {
            // acf-lint: allow(AL005) -- documented contract panic: an
            // invalid Csr must be impossible to construct from safe code
            // (the unchecked kernels rely on the row invariant).
            panic!("Csr::from_parts: invalid structure: {e}");
        }
        m
    }

    /// Wrap a validated storage backend. `norms` pre-seeds the
    /// squared-norm cache (the `.acfbin` open path, which loads the
    /// norms written at ingest instead of touching every value page).
    ///
    /// Callers must have validated the backend's structural invariants
    /// ([`MappedCsr::new`] and [`ChunkedCsr::push_row`] both do).
    pub(crate) fn from_storage(
        rows: usize,
        cols: usize,
        storage: CsrStorage,
        norms: Option<Vec<f64>>,
    ) -> Csr {
        let norms_sq = OnceLock::new();
        if let Some(n) = norms {
            debug_assert_eq!(n.len(), rows, "norms length");
            let _ = norms_sq.set(n);
        }
        Csr { rows, cols, storage, norms_sq }
    }

    /// Re-layout into the chunked backend with `chunk_rows` rows per
    /// chunk. Content (and therefore equality, norms, kernel results)
    /// is unchanged — only the physical grouping differs.
    pub fn to_chunked(&self, chunk_rows: usize) -> Csr {
        let mut chunked = ChunkedCsr::new(chunk_rows);
        for r in 0..self.rows {
            let row = self.row(r);
            chunked.push_row(row.indices, row.values);
        }
        Csr::from_storage(self.rows, self.cols, CsrStorage::Chunked(chunked), None)
    }

    /// The backing storage (backend-specific inspection; row access
    /// goes through [`Csr::row`]).
    pub fn storage(&self) -> &CsrStorage {
        &self.storage
    }

    /// `"owned"`, `"mapped"`, or `"chunked"` — for reports and logs.
    pub fn storage_kind(&self) -> &'static str {
        match &self.storage {
            CsrStorage::Owned { .. } => "owned",
            CsrStorage::Mapped(_) => "mapped",
            CsrStorage::Chunked(_) => "chunked",
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        match &self.storage {
            CsrStorage::Owned { indices, .. } => indices.len(),
            CsrStorage::Mapped(m) => m.nnz,
            CsrStorage::Chunked(c) => c.nnz,
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> RowView<'_> {
        match &self.storage {
            CsrStorage::Owned { indptr, indices, values } => {
                let lo = indptr[r];
                let hi = indptr[r + 1];
                RowView { indices: &indices[lo..hi], values: &values[lo..hi] }
            }
            CsrStorage::Mapped(m) => m.row(r),
            CsrStorage::Chunked(c) => c.row(r),
        }
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        let (lo, hi) = self.row_bounds(r);
        hi - lo
    }

    /// Global nnz range of row `r` (identical across backends).
    #[inline]
    fn row_bounds(&self, r: usize) -> (usize, usize) {
        match &self.storage {
            CsrStorage::Owned { indptr, .. } => (indptr[r], indptr[r + 1]),
            CsrStorage::Mapped(m) => m.bounds(r),
            CsrStorage::Chunked(c) => c.bounds(r),
        }
    }

    /// Byte / nominal-page footprint of the given rows' value + index
    /// data, for the data-locality probes the sharded engine emits at
    /// `spans` trace level (see [`crate::obs`]). `ids` must be sorted
    /// ascending (shard partitions are). Pages are counted per section
    /// (values, then indices) at the nominal
    /// [`PAGE_SIZE`](crate::util::mmap::PAGE_SIZE); for mapped storage
    /// the offsets are the real file offsets, so the count reflects the
    /// pages the worker actually touches.
    pub fn rows_extent(&self, ids: &[u32]) -> (u64, u64) {
        debug_assert!(ids.windows(2).all(|p| p[0] < p[1]), "ids must be sorted ascending");
        let (vbase, ibase) = match &self.storage {
            CsrStorage::Mapped(m) => (m.values_off, m.indices_off),
            _ => (0, 0),
        };
        let mut bytes = 0u64;
        let mut pages = 0u64;
        let mut last_vpage: Option<usize> = None;
        let mut last_ipage: Option<usize> = None;
        let mut fresh = |lo_byte: usize, hi_byte: usize, last: &mut Option<usize>| -> u64 {
            // [lo_byte, hi_byte) is non-empty and non-decreasing in
            // start across calls (ids are sorted)
            let p0 = lo_byte / PAGE_SIZE;
            let p1 = (hi_byte - 1) / PAGE_SIZE;
            let start = match *last {
                Some(seen) => p0.max(seen + 1),
                None => p0,
            };
            *last = Some(match *last {
                Some(seen) => seen.max(p1),
                None => p1,
            });
            (p1 + 1).saturating_sub(start) as u64
        };
        for &i in ids {
            let (lo, hi) = self.row_bounds(i as usize);
            if hi == lo {
                continue;
            }
            bytes += ((hi - lo) * (8 + 4)) as u64;
            pages += fresh(vbase + lo * 8, vbase + hi * 8, &mut last_vpage);
            pages += fresh(ibase + lo * 4, ibase + hi * 4, &mut last_ipage);
        }
        (bytes, pages)
    }

    /// Run `f` over every row in order, prefetching row `r + 1`'s
    /// index/value slices while `f` consumes row `r` — software
    /// pipelining for full-matrix sweeps: the next row's cache-line
    /// loads are in flight during the current row's reduction. Prefetch
    /// is a pure hint, so results are identical to a plain loop.
    fn for_each_row_pipelined<F: FnMut(usize, RowView<'_>)>(&self, mut f: F) {
        if self.rows == 0 {
            return;
        }
        let mut cur = self.row(0);
        for r in 0..self.rows {
            if r + 1 < self.rows {
                let next = self.row(r + 1);
                kernels::prefetch_row(next.indices, next.values);
                f(r, cur);
                cur = next;
            } else {
                f(r, cur);
            }
        }
    }

    /// Per-row squared norms, computed once and cached on the matrix.
    /// Every solver that needs `Q_ii` (svm / logreg / mcsvm / the shard
    /// fronts) borrows this slice instead of recomputing its own copy.
    /// The one-time fill is a pipelined full sweep (prefetch row `r + 1`
    /// while row `r` reduces); the values are bit-identical to a naive
    /// per-row loop.
    pub fn row_norms_sq(&self) -> &[f64] {
        self.norms_sq.get_or_init(|| {
            let mut norms = Vec::with_capacity(self.rows);
            self.for_each_row_pipelined(|_, row| norms.push(row.norm_sq()));
            norms
        })
    }

    /// Dense matvec `y = A x` (reference / validation path; pipelined
    /// full sweep, bit-identical to per-row [`RowView::dot_dense`]).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = Vec::with_capacity(self.rows);
        self.for_each_row_pipelined(|_, row| y.push(row.dot_dense(x)));
        y
    }

    /// Transposed matvec `y = Aᵀ x` (pipelined full sweep).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        self.for_each_row_pipelined(|r, row| row.axpy_into(x[r], &mut y));
        y
    }

    /// Batched gather-dot `out[k] = row(ids[k]) · w` through the
    /// software-pipelined [`kernels::dot_many_unchecked`]: row `k + 1`'s
    /// slices are prefetched while row `k` reduces, so a verification
    /// scan's cache misses overlap its arithmetic. Bit-identical to
    /// calling [`RowView::dot_dense`] per id — pipelining changes memory
    /// timing, never the reduction tree.
    pub fn dot_rows_into(&self, ids: &[u32], w: &[f64], out: &mut [f64]) {
        assert_eq!(ids.len(), out.len(), "dot_rows_into length mismatch");
        // fixed-size batches keep the slice-pair scratch on the stack
        const BATCH: usize = 32;
        let empty: (&[u32], &[f64]) = (&[], &[]);
        let mut batch = [empty; BATCH];
        for (ids_chunk, out_chunk) in ids.chunks(BATCH).zip(out.chunks_mut(BATCH)) {
            for (slot, &r) in batch.iter_mut().zip(ids_chunk.iter()) {
                let row = self.row(r as usize);
                // the O(1) soundness gate of the unchecked kernels
                row.check_bounds(w.len());
                *slot = (row.indices, row.values);
            }
            // SAFETY: every batched row passed the O(1) last-index gate
            // (row indices strictly increasing — Csr invariant), so all
            // gathers are in bounds for w.
            unsafe { kernels::dot_many_unchecked(&batch[..ids_chunk.len()], w, out_chunk) };
        }
    }

    /// Transpose to CSC-equivalent CSR (i.e. a CSR matrix of the
    /// transpose). Counting sort over columns — O(nnz + cols). Always
    /// produces owned storage.
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols + 1];
        for r in 0..self.rows {
            for &j in self.row(r).indices {
                counts[j as usize + 1] += 1;
            }
        }
        for c in 0..self.cols {
            counts[c + 1] += counts[c];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        for r in 0..self.rows {
            let row = self.row(r);
            for (&j, &v) in row.indices.iter().zip(row.values.iter()) {
                let dst = cursor[j as usize];
                indices[dst] = r as u32;
                values[dst] = v;
                cursor[j as usize] += 1;
            }
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            storage: CsrStorage::Owned { indptr, indices, values },
            norms_sq: OnceLock::new(),
        }
    }

    /// Extract a dense row-major block [r0..r1) × [c0..c1), padded with
    /// zeros; used by the PJRT validator which runs on fixed-shape tiles.
    pub fn dense_block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Vec<f32> {
        let h = r1 - r0;
        let w = c1 - c0;
        let mut out = vec![0.0f32; h * w];
        for r in r0..r1.min(self.rows) {
            let row = self.row(r);
            for (&j, &v) in row.indices.iter().zip(row.values.iter()) {
                let j = j as usize;
                if j >= c0 && j < c1 {
                    out[(r - r0) * w + (j - c0)] = v as f32;
                }
            }
        }
        out
    }

    /// Convert the full matrix to a dense row-major f64 buffer (tests /
    /// tiny problems only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for (&j, &v) in row.indices.iter().zip(row.values.iter()) {
                out[r * self.cols + j as usize] = v;
            }
        }
        out
    }

    /// Select a subset of rows (dataset splits). Always produces owned
    /// storage.
    pub fn select_rows(&self, idx: &[usize]) -> Csr {
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &r in idx {
            let row = self.row(r);
            indices.extend_from_slice(row.indices);
            values.extend_from_slice(row.values);
            indptr.push(indices.len());
        }
        Csr {
            rows: idx.len(),
            cols: self.cols,
            storage: CsrStorage::Owned { indptr, indices, values },
            norms_sq: OnceLock::new(),
        }
    }

    /// Validate structural invariants (used by property tests; mapped
    /// and chunked backends were already validated at construction, but
    /// re-checking is cheap insurance for tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        match &self.storage {
            CsrStorage::Owned { indptr, indices, .. } => {
                if indptr.len() != self.rows + 1 {
                    return Err("indptr length".into());
                }
                // INFALLIBLE: `indptr.len() == rows + 1 >= 1` was just checked.
                if indptr[0] != 0 || *indptr.last().unwrap() != indices.len() {
                    return Err("indptr endpoints".into());
                }
                for r in 0..self.rows {
                    if indptr[r] > indptr[r + 1] {
                        return Err(format!("indptr decreasing at {r}"));
                    }
                }
            }
            CsrStorage::Mapped(m) => {
                if m.rows != self.rows {
                    return Err("mapped row count mismatch".into());
                }
            }
            CsrStorage::Chunked(c) => {
                if c.rows != self.rows {
                    return Err("chunked row count mismatch".into());
                }
                let mut running = 0usize;
                for (k, chunk) in c.chunks.iter().enumerate() {
                    if chunk.base_nnz != running {
                        return Err(format!("chunk {k} base_nnz mismatch"));
                    }
                    if chunk.indptr.first() != Some(&0) {
                        return Err(format!("chunk {k} indptr start"));
                    }
                    running += chunk.indices.len();
                }
                if running != c.nnz {
                    return Err("chunked nnz mismatch".into());
                }
            }
        }
        for r in 0..self.rows {
            let row = self.row(r);
            for w in row.indices.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} indices not strictly increasing"));
                }
            }
            if let Some(&j) = row.indices.last() {
                if j as usize >= self.cols {
                    return Err(format!("row {r} column out of bounds"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sample() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        Csr::from_rows(3, vec![vec![(0, 1.0), (2, 2.0)], vec![], vec![(1, 4.0), (0, 3.0)]])
    }

    #[test]
    fn construction_sorts_and_counts() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(2).indices, &[0, 1]);
        assert_eq!(m.row(2).values, &[3.0, 4.0]);
        m.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_columns_accumulate() {
        let m = Csr::from_rows(4, vec![vec![(1, 2.0), (1, 3.0), (0, 1.0)]]);
        assert_eq!(m.row(0).indices, &[0, 1]);
        assert_eq!(m.row(0).values, &[1.0, 5.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(m.matvec(&x), vec![7.0, 0.0, 11.0]);
        let y = vec![1.0, 1.0, 1.0];
        assert_eq!(m.matvec_t(&y), vec![4.0, 4.0, 2.0]);
    }

    #[test]
    fn dot_rows_into_bit_matches_per_row() {
        prop::check(40, |g| {
            let cols = g.usize_in(1, 24);
            // up to 80 rows so the scan crosses the 32-row batch boundary
            let nrows = g.usize_in(0, 80);
            let rows: Vec<Vec<(usize, f64)>> = (0..nrows)
                .map(|_| {
                    let nnz = g.usize_in(0, cols);
                    let pat = g.sparse_pattern(cols, nnz);
                    pat.iter().map(|&c| (c, g.f64_in(-2.0, 2.0))).collect()
                })
                .collect();
            let m = Csr::from_rows(cols, rows);
            let w = g.vec_f64(cols, -2.0, 2.0);
            // reversed ids: the batch API promises per-id results in any
            // visit order, not just ascending scans
            let ids: Vec<u32> = (0..nrows as u32).rev().collect();
            let mut out = vec![0.0; nrows];
            m.dot_rows_into(&ids, &w, &mut out);
            for (k, &i) in ids.iter().enumerate() {
                let reference = m.row(i as usize).dot_dense(&w);
                prop::assert_holds(out[k].to_bits() == reference.to_bits(), "dot_rows_into bits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_round_trip() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        t.check_invariants().unwrap();
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_matches_dense_property() {
        prop::check(50, |g| {
            let rows = g.usize_in(1, 20);
            let cols = g.usize_in(1, 20);
            let mut data = Vec::new();
            for _ in 0..rows {
                let k = g.usize_in(0, cols.min(8));
                let pat = g.sparse_pattern(cols, k);
                data.push(pat.into_iter().map(|c| (c, g.f64_in(-2.0, 2.0))).collect());
            }
            let m = Csr::from_rows(cols, data);
            m.check_invariants()?;
            let t = m.transpose();
            t.check_invariants()?;
            let d = m.to_dense();
            let td = t.to_dense();
            for r in 0..rows {
                for c in 0..cols {
                    prop::assert_close(d[r * cols + c], td[c * rows + r], 1e-12, "transpose")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matvec_t_matches_transpose_matvec_property() {
        prop::check(30, |g| {
            let rows = g.usize_in(1, 15);
            let cols = g.usize_in(1, 15);
            let mut data = Vec::new();
            for _ in 0..rows {
                let k = g.usize_in(0, cols.min(6));
                let pat = g.sparse_pattern(cols, k);
                data.push(pat.into_iter().map(|c| (c, g.f64_in(-1.0, 1.0))).collect());
            }
            let m = Csr::from_rows(cols, data);
            let x = g.vec_f64(rows, -3.0, 3.0);
            let a = m.matvec_t(&x);
            let b = m.transpose().matvec(&x);
            for (u, v) in a.iter().zip(b.iter()) {
                prop::assert_close(*u, *v, 1e-12, "matvec_t == transpose.matvec")?;
            }
            Ok(())
        });
    }

    #[test]
    fn dense_block_extraction() {
        let m = sample();
        let b = m.dense_block(0, 2, 1, 3); // rows 0..2, cols 1..3
        assert_eq!(b, vec![0.0, 2.0, 0.0, 0.0]);
        // padding beyond matrix bounds
        let b2 = m.dense_block(2, 4, 0, 2);
        assert_eq!(b2, vec![3.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn select_rows_subsets() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row(0).values, &[3.0, 4.0]);
        assert_eq!(s.row(1).values, &[1.0, 2.0]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn norms_cached_and_correct() {
        let m = sample();
        let n = m.row_norms_sq();
        assert_eq!(n, &[5.0, 0.0, 25.0]);
        // second call must hand back the same cached allocation
        assert!(std::ptr::eq(n.as_ptr(), m.row_norms_sq().as_ptr()));
        // clones answer identically (whether they copy or recompute)
        assert_eq!(m.clone().row_norms_sq(), &[5.0, 0.0, 25.0]);
    }

    #[test]
    fn equality_ignores_norm_cache() {
        let a = sample();
        let b = sample();
        let _ = a.row_norms_sq(); // warm only one side's cache
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn dot_dense_rejects_short_vector() {
        let m = sample();
        let w = vec![0.0; 2]; // cols = 3: the O(1) gate must fire
        m.row(0).dot_dense(&w);
    }

    // ---- storage-backend behavior ------------------------------------

    #[test]
    fn chunked_backend_serves_identical_rows() {
        let m = sample();
        for chunk_rows in [1, 2, 3, 7] {
            let c = m.to_chunked(chunk_rows);
            assert_eq!(c.storage_kind(), "chunked");
            assert_eq!(c, m, "chunk_rows={chunk_rows}");
            c.check_invariants().unwrap();
            assert_eq!(c.nnz(), m.nnz());
            for r in 0..m.rows() {
                assert_eq!(c.row(r).indices(), m.row(r).indices());
                assert_eq!(c.row(r).values(), m.row(r).values());
                assert_eq!(c.row_nnz(r), m.row_nnz(r));
            }
            assert_eq!(c.row_norms_sq(), m.row_norms_sq());
            assert_eq!(c.transpose(), m.transpose());
        }
    }

    #[test]
    fn chunked_backend_property_matches_owned() {
        prop::check(30, |g| {
            let rows = g.usize_in(1, 25);
            let cols = g.usize_in(1, 20);
            let mut data = Vec::new();
            for _ in 0..rows {
                let k = g.usize_in(0, cols.min(6));
                let pat = g.sparse_pattern(cols, k);
                data.push(pat.into_iter().map(|c| (c, g.f64_in(-2.0, 2.0))).collect());
            }
            let m = Csr::from_rows(cols, data);
            let chunk_rows = g.usize_in(1, rows + 2);
            let c = m.to_chunked(chunk_rows);
            c.check_invariants()?;
            prop::assert_holds(c == m, "chunked == owned")?;
            let x = g.vec_f64(cols, -1.0, 1.0);
            let (a, b) = (m.matvec(&x), c.matvec(&x));
            prop::assert_holds(
                a.iter().zip(&b).all(|(u, v)| u.to_bits() == v.to_bits()),
                "matvec bit-identical across backends",
            )
        });
    }

    #[test]
    fn chunk_count_is_ceil_rows_over_chunk_rows() {
        let m = sample();
        for (chunk_rows, expect) in [(1, 3), (2, 2), (3, 1), (10, 1)] {
            match m.to_chunked(chunk_rows).storage() {
                CsrStorage::Chunked(c) => assert_eq!(c.n_chunks(), expect),
                other => panic!("expected chunked storage, got {other:?}"),
            }
        }
    }

    #[test]
    fn rows_extent_counts_bytes_and_pages() {
        let m = sample();
        // rows 0 and 2 hold 2 nnz each: 2 * 2 * (8 + 4) bytes
        let (bytes, pages) = m.rows_extent(&[0, 2]);
        assert_eq!(bytes, 48);
        // tiny matrix: everything on one values page + one indices page
        assert_eq!(pages, 2);
        // the empty row contributes nothing
        assert_eq!(m.rows_extent(&[1]), (0, 0));
        // extents agree across backends for owned-style offsets
        assert_eq!(m.to_chunked(2).rows_extent(&[0, 2]).0, bytes);
    }

    #[test]
    fn storage_kind_reports_backend() {
        let m = sample();
        assert_eq!(m.storage_kind(), "owned");
        assert_eq!(m.to_chunked(2).storage_kind(), "chunked");
    }
}
