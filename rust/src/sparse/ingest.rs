//! Streaming libsvm → `.acfbin` ingest: parse rows in bounded chunks
//! and spill them straight into the on-disk layout
//! ([`crate::sparse::storage`]) without ever materializing the matrix.
//!
//! Peak memory is O(chunk) for row data plus O(rows) for the
//! row-pointer/label/norm columns — independent of nnz — so datasets
//! much larger than RAM can be converted once and then trained
//! memory-mapped (`acf-cd ingest`, then `--data-backend mmap`).
//!
//! Each parsed row goes through the **same** per-line tokenizer and the
//! same column normalization (sort, merge duplicates, keep explicit
//! zeros) as the in-memory parser, so the streamed file opens to a
//! matrix bit-identical to [`parse_libsvm`](crate::sparse::parse_libsvm)
//! on the same text — the round-trip property the tests pin down.
//!
//! ```
//! use acf_cd::sparse::{ingest, parse_libsvm, storage};
//! let text = "+1 1:0.5 3:1.25\n-1 2:2\n+1 4:1 # comment\n";
//! let dir = std::env::temp_dir().join("acf_ingest_doc");
//! std::fs::create_dir_all(&dir).unwrap();
//! let path = dir.join(format!("doc_{}.acfbin", std::process::id()));
//! let report = ingest::ingest_reader(text.as_bytes(), &path, 0, 2).unwrap();
//! assert_eq!(report.rows, 3);
//! let mapped = storage::open_dataset(&path).unwrap();
//! assert_eq!(mapped.x, parse_libsvm(text, "doc", 0).unwrap().x);
//! std::fs::remove_file(&path).ok();
//! ```

use super::csr::{normalize_row, ChunkedCsr, Csr, CsrStorage};
use super::libsvm::{parse_line, Dataset, LibsvmError};
use super::storage::AcfbinWriter;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader};
use std::path::Path;
use std::time::Instant;

/// Rows buffered per chunk when the caller does not choose
/// (`acf-cd ingest --chunk-rows`). Small enough that a chunk of even
/// very wide rows stays cache-friendly, large enough to amortize flush
/// overhead.
pub const DEFAULT_CHUNK_ROWS: usize = 4096;

/// What an ingest run did — row/nnz counts, sizes, and throughput (the
/// `ingest_throughput` row in `BENCH_scaling_shards.json` and the
/// `acf-cd ingest` report come straight from this).
#[derive(Clone, Copy, Debug)]
pub struct IngestReport {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// bytes of libsvm text consumed
    pub input_bytes: u64,
    /// bytes of the finished `.acfbin` file
    pub output_bytes: u64,
    pub seconds: f64,
    /// input megabytes (1e6 bytes) parsed per second
    pub mb_per_s: f64,
}

/// Stream a libsvm file into `dst` as `.acfbin`. `chunk_rows = 0`
/// selects [`DEFAULT_CHUNK_ROWS`].
pub fn ingest_libsvm(src: &Path, dst: &Path, min_features: usize, chunk_rows: usize) -> Result<IngestReport> {
    let f = std::fs::File::open(src).with_context(|| format!("opening {}", src.display()))?;
    ingest_reader(BufReader::new(f), dst, min_features, chunk_rows)
        .with_context(|| format!("ingesting {}", src.display()))
}

/// Stream libsvm text from any reader into `dst` as `.acfbin`.
pub fn ingest_reader<R: BufRead>(
    reader: R,
    dst: &Path,
    min_features: usize,
    chunk_rows: usize,
) -> Result<IngestReport> {
    let chunk_rows = if chunk_rows == 0 { DEFAULT_CHUNK_ROWS } else { chunk_rows };
    let start = Instant::now();
    let mut writer = AcfbinWriter::create(dst)?;
    let mut input_bytes = 0u64;
    let mut chunk: Vec<(f64, Vec<u32>, Vec<f64>)> = Vec::with_capacity(chunk_rows);
    let mut flush = |chunk: &mut Vec<(f64, Vec<u32>, Vec<f64>)>, w: &mut AcfbinWriter| -> Result<()> {
        for (label, indices, values) in chunk.drain(..) {
            w.push_row(label, &indices, &values)?;
        }
        Ok(())
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(LibsvmError::Io)?;
        input_bytes += line.len() as u64 + 1; // + newline
        let Some((label, row)) = parse_line(&line, lineno)? else { continue };
        let (indices, values) = normalize_row(row);
        chunk.push((label, indices, values));
        if chunk.len() >= chunk_rows {
            flush(&mut chunk, &mut writer)?;
        }
    }
    flush(&mut chunk, &mut writer)?;
    let summary = writer.finish(min_features)?;
    let seconds = start.elapsed().as_secs_f64();
    Ok(IngestReport {
        rows: summary.rows,
        cols: summary.cols,
        nnz: summary.nnz,
        input_bytes,
        output_bytes: summary.bytes,
        seconds,
        mb_per_s: if seconds > 0.0 { input_bytes as f64 / 1e6 / seconds } else { 0.0 },
    })
}

/// Parse libsvm text into an **in-memory chunked** matrix
/// ([`CsrStorage::Chunked`]): same dialect and normalization as
/// [`parse_libsvm`](crate::sparse::parse_libsvm), but rows land in
/// fixed-size chunk blocks instead of three matrix-sized allocations.
pub fn parse_libsvm_chunked(
    text: &str,
    name: &str,
    min_features: usize,
    chunk_rows: usize,
) -> Result<Dataset, LibsvmError> {
    let chunk_rows = if chunk_rows == 0 { DEFAULT_CHUNK_ROWS } else { chunk_rows };
    let mut chunked = ChunkedCsr::new(chunk_rows);
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let Some((label, row)) = parse_line(line, lineno)? else { continue };
        let (indices, values) = normalize_row(row);
        if let Some(&last) = indices.last() {
            max_col = max_col.max(last as usize + 1);
        }
        chunked.push_row(&indices, &values);
        y.push(label);
    }
    let rows = y.len();
    let cols = max_col.max(min_features);
    Ok(Dataset {
        name: name.to_string(),
        x: Csr::from_storage(rows, cols, CsrStorage::Chunked(chunked), None),
        y,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::libsvm::{parse_libsvm, to_libsvm_string};
    use crate::sparse::storage::open_dataset;
    use crate::util::prop;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("acf_cd_ingest_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{}", std::process::id(), name))
    }

    /// Deliberately awkward text: comments, blank lines, an empty row
    /// (label only), trailing whitespace, rows with nnz % 4 ∈ {1,2,3}
    /// tails, and a duplicate column to exercise merge-by-summation.
    const AWKWARD: &str = "\
# header comment

+1 1:0.5 3:1.25 9:2 7:-1 2:0.125
-1\t
+1 4:1
-1 2:2 2:3 5:-0.5  # dup column accumulates
+1 1:1 2:2 3:3 4:4 5:5 6:6 7:7
";

    #[test]
    fn streamed_file_matches_in_memory_parser_bit_exactly() {
        let path = tmp("awkward.acfbin");
        let report = ingest_reader(AWKWARD.as_bytes(), &path, 0, 2).unwrap();
        let mem = parse_libsvm(AWKWARD, "awkward", 0).unwrap();
        let mapped = open_dataset(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(report.rows, 5);
        assert_eq!(mapped.x.storage_kind(), "mapped");
        assert_eq!(mapped.x, mem.x);
        assert_eq!(mapped.y, mem.y);
        // dup column 2 merged: 2 + 3
        let r3 = mapped.x.row(3);
        assert_eq!(r3.indices(), &[1, 4]);
        assert_eq!(r3.values(), &[5.0, -0.5]);
        // the empty row survives as an empty row
        assert_eq!(mapped.x.row_nnz(1), 0);
        // norms from the file match recomputation bit-for-bit
        for (a, b) in mapped.x.row_norms_sq().iter().zip(mem.x.row_norms_sq()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn chunked_parser_matches_in_memory_parser() {
        for chunk_rows in [1, 2, 3, 100] {
            let mem = parse_libsvm(AWKWARD, "t", 0).unwrap();
            let chunked = parse_libsvm_chunked(AWKWARD, "t", 0, chunk_rows).unwrap();
            assert_eq!(chunked.x.storage_kind(), "chunked");
            assert_eq!(chunked.x, mem.x, "chunk_rows={chunk_rows}");
            assert_eq!(chunked.y, mem.y);
            chunked.x.check_invariants().unwrap();
        }
    }

    #[test]
    fn ingest_round_trip_property() {
        prop::check(20, |g| {
            let n = g.usize_in(1, 40);
            let d = g.usize_in(1, 50);
            let mut rows = Vec::new();
            let mut y = Vec::new();
            for _ in 0..n {
                let k = g.usize_in(0, d.min(9)); // includes empty rows and odd tails
                let pat = g.sparse_pattern(d, k);
                rows.push(pat.into_iter().map(|c| (c, g.f64_in(-3.0, 3.0))).collect::<Vec<_>>());
                y.push(if g.bool() { 1.0 } else { -1.0 });
            }
            let ds = Dataset { name: "prop".into(), x: Csr::from_rows(d, rows), y };
            let text = to_libsvm_string(&ds);
            let chunk_rows = g.usize_in(1, n + 3);
            let path = tmp(&format!("prop_{}.acfbin", g.usize_in(0, usize::MAX / 2)));
            ingest_reader(text.as_bytes(), &path, d, chunk_rows).map_err(|e| format!("{e:#}"))?;
            let mapped = open_dataset(&path).map_err(|e| format!("{e:#}"))?;
            std::fs::remove_file(&path).ok();
            let mem = parse_libsvm(&text, "prop", d).map_err(|e| format!("{e}"))?;
            prop::assert_holds(mapped.x == mem.x, "streamed == in-memory matrix")?;
            prop::assert_holds(mapped.y == mem.y, "streamed == in-memory labels")?;
            // and the chunked in-memory backend agrees too
            let chk = parse_libsvm_chunked(&text, "prop", d, chunk_rows).map_err(|e| format!("{e}"))?;
            prop::assert_holds(chk.x == mem.x, "chunked == in-memory matrix")
        });
    }

    #[test]
    fn report_accounts_for_sizes_and_throughput() {
        let path = tmp("report.acfbin");
        let report = ingest_reader(AWKWARD.as_bytes(), &path, 0, 0).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(report.rows, 5);
        assert_eq!(report.cols, 9);
        assert!(report.nnz >= 13, "nnz {}", report.nnz);
        assert!(report.input_bytes as usize >= AWKWARD.len());
        assert!(report.output_bytes > 104);
        assert!(report.seconds >= 0.0 && report.mb_per_s >= 0.0);
    }

    #[test]
    fn malformed_lines_fail_with_line_numbers() {
        let path = tmp("malformed.acfbin");
        let err = ingest_reader("+1 1:1\n+1 0:1\n".as_bytes(), &path, 0, 0).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
        assert!(!path.exists(), "failed ingest must not leave a file behind");
        let err = parse_libsvm_chunked("+1 1:abc\n", "t", 0, 0).unwrap_err();
        assert!(format!("{err}").contains("line 1"), "{err}");
    }

    #[test]
    fn min_features_pads_streamed_files() {
        let path = tmp("pad.acfbin");
        ingest_reader("+1 1:1\n".as_bytes(), &path, 12, 0).unwrap();
        let ds = open_dataset(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(ds.n_features(), 12);
    }
}
