//! Hot-path sparse/dense kernels: 4-way unrolled gather/scatter with
//! independent accumulator lanes, in checked and unchecked flavors.
//!
//! The CD inner loop is one sparse gather-dot followed by (usually) one
//! sparse scatter-add over the same row slices. The paper's wall-clock
//! claim lives or dies on the cost of those two primitives, so this
//! module rewrites them with
//!
//! * **4 independent accumulator lanes** — breaks the sequential
//!   floating-point dependency chain so the CPU can keep several
//!   multiply-adds in flight (and the autovectorizer can use them),
//! * **`get_unchecked` indexing** on the unchecked variants — the gather
//!   `w[indices[k]]` otherwise pays one bounds check per non-zero,
//! * a **fused [`step_unchecked`]** entry point that runs the gradient
//!   dot and the scatter-update back-to-back on the same row slices
//!   while they are hot in cache.
//!
//! # Safety contract of the unchecked paths
//!
//! Every `*_unchecked` function requires, and `debug_assert!`s:
//!
//! 1. `indices.len() == values.len()`;
//! 2. every `indices[k] as usize` is in bounds for `w`.
//!
//! Violating either in a release build is undefined behavior. The safe
//! entry points ([`crate::sparse::RowView::dot_dense`] and friends)
//! restore soundness with an O(1) check: CSR row indices are *strictly
//! increasing* (a [`crate::sparse::Csr`] structural invariant verified
//! by `check_invariants` and the construction paths), so checking
//! `indices.last() < w.len()` bounds every index in the row.
//!
//! # Parity oracle
//!
//! Each unchecked kernel has a `*_checked` twin generated from the same
//! monomorphized implementation (`const CHECKED: bool` toggles the
//! indexing only), so checked and unchecked results are **bit-identical
//! by construction** — the property tests below assert it anyway, across
//! empty rows, `nnz % 4 != 0` tails and random sparse patterns. The
//! pre-existing sequential implementations remain as [`dot_dense_scalar`]
//! / [`axpy_scalar`]: the *semantic* oracle (and the perf baseline of
//! `benches/kernel_microbench.rs`). Note that lane accumulation
//! re-associates the dot-product sum, so the unrolled dot agrees with the
//! scalar reference only up to floating-point rounding; the scatter-add
//! touches each (distinct) index exactly once and is bit-identical to the
//! scalar version.

/// Sequential bounds-checked sparse dot — the original implementation,
/// kept as the semantic oracle and microbench baseline.
#[inline]
pub fn dot_dense_scalar(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (&j, &v) in indices.iter().zip(values.iter()) {
        acc += v * w[j as usize];
    }
    acc
}

/// Sequential bounds-checked scatter-add `w[indices[k]] += scale *
/// values[k]` — the original implementation, kept as the semantic oracle
/// and microbench baseline.
#[inline]
pub fn axpy_scalar(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    for (&j, &v) in indices.iter().zip(values.iter()) {
        w[j as usize] += scale * v;
    }
}

/// Shared 4-lane gather-dot body; `CHECKED` selects the indexing and is
/// resolved at monomorphization time, so both flavors run the identical
/// floating-point schedule (bit-identical results).
///
/// Safety: with `CHECKED = false` the caller must uphold the module-level
/// contract (index bounds); with `CHECKED = true` the function is safe.
#[inline(always)]
unsafe fn dot_lanes<const CHECKED: bool>(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    let n = indices.len();
    let chunks = n / 4;
    let mut a0 = 0.0f64;
    let mut a1 = 0.0f64;
    let mut a2 = 0.0f64;
    let mut a3 = 0.0f64;
    macro_rules! at {
        ($k:expr) => {{
            let j = if CHECKED {
                indices[$k] as usize
            } else {
                *indices.get_unchecked($k) as usize
            };
            let v = if CHECKED { values[$k] } else { *values.get_unchecked($k) };
            debug_assert!(j < w.len(), "sparse index {j} out of bounds ({})", w.len());
            let x = if CHECKED { w[j] } else { *w.get_unchecked(j) };
            v * x
        }};
    }
    for c in 0..chunks {
        let base = c * 4;
        a0 += at!(base);
        a1 += at!(base + 1);
        a2 += at!(base + 2);
        a3 += at!(base + 3);
    }
    for k in chunks * 4..n {
        a0 += at!(k);
    }
    (a0 + a1) + (a2 + a3)
}

/// Shared 4-way unrolled scatter-add body; see [`dot_lanes`] for the
/// `CHECKED` mechanics. Correct even with repeated indices (the four
/// per-chunk updates execute in order); CSR rows never repeat indices,
/// which is what lets the compiler schedule them independently.
#[inline(always)]
unsafe fn axpy_unrolled<const CHECKED: bool>(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    debug_assert_eq!(indices.len(), values.len());
    let n = indices.len();
    let chunks = n / 4;
    macro_rules! upd {
        ($k:expr) => {{
            let j = if CHECKED {
                indices[$k] as usize
            } else {
                *indices.get_unchecked($k) as usize
            };
            let v = if CHECKED { values[$k] } else { *values.get_unchecked($k) };
            debug_assert!(j < w.len(), "sparse index {j} out of bounds ({})", w.len());
            if CHECKED {
                w[j] += scale * v;
            } else {
                *w.get_unchecked_mut(j) += scale * v;
            }
        }};
    }
    for c in 0..chunks {
        let base = c * 4;
        upd!(base);
        upd!(base + 1);
        upd!(base + 2);
        upd!(base + 3);
    }
    for k in chunks * 4..n {
        upd!(k);
    }
}

/// 4-lane gather-dot, bounds-checked — the parity oracle for
/// [`dot_dense_unchecked`] (bit-identical by construction).
#[inline]
pub fn dot_dense_checked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    // SAFETY: CHECKED = true performs ordinary indexing; no contract.
    unsafe { dot_lanes::<true>(indices, values, w) }
}

/// 4-lane gather-dot with unchecked indexing.
///
/// # Safety
/// `indices.len() == values.len()` and every `indices[k] as usize` must
/// be `< w.len()` (see the module docs).
#[inline]
pub unsafe fn dot_dense_unchecked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    dot_lanes::<false>(indices, values, w)
}

/// 4-way unrolled scatter-add, bounds-checked — the parity oracle for
/// [`axpy_unchecked`].
#[inline]
pub fn axpy_checked(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    // SAFETY: CHECKED = true performs ordinary indexing; no contract.
    unsafe { axpy_unrolled::<true>(scale, indices, values, w) }
}

/// 4-way unrolled scatter-add with unchecked indexing.
///
/// # Safety
/// Same contract as [`dot_dense_unchecked`], with `w` writable.
#[inline]
pub unsafe fn axpy_unchecked(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    axpy_unrolled::<false>(scale, indices, values, w)
}

/// Fused CD step on one sparse row: gather-dot against `w`, hand the
/// result to `update` (which performs the O(1) coordinate math and
/// returns the scatter scale; `0.0` means "no update"), then scatter-add
/// on the *same, still-cache-hot* row slices. Returns `(dot, scale)`.
///
/// # Safety
/// Same contract as [`dot_dense_unchecked`], with `w` writable.
#[inline]
pub unsafe fn step_unchecked<F: FnOnce(f64) -> f64>(
    indices: &[u32],
    values: &[f64],
    w: &mut [f64],
    update: F,
) -> (f64, f64) {
    let dot = dot_lanes::<false>(indices, values, w);
    let scale = update(dot);
    if scale != 0.0 {
        axpy_unrolled::<false>(scale, indices, values, w);
    }
    (dot, scale)
}

/// Bounds-checked twin of [`step_unchecked`] (parity oracle).
#[inline]
pub fn step_checked<F: FnOnce(f64) -> f64>(indices: &[u32], values: &[f64], w: &mut [f64], update: F) -> (f64, f64) {
    // SAFETY: CHECKED = true performs ordinary indexing; no contract.
    let dot = unsafe { dot_lanes::<true>(indices, values, w) };
    let scale = update(dot);
    if scale != 0.0 {
        unsafe { axpy_unrolled::<true>(scale, indices, values, w) };
    }
    (dot, scale)
}

/// Dense 4-lane dot product. Safe: `chunks_exact` gives the compiler
/// bounds-check-free access without any unsafe code. Lengths must match
/// (release-grade assert: a silent partial dot would let a
/// wrong-dimension vector corrupt a solve without a diagnostic).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dense dot length mismatch");
    let n = a.len();
    let mut a0 = 0.0f64;
    let mut a1 = 0.0f64;
    let mut a2 = 0.0f64;
    let mut a3 = 0.0f64;
    let mut ca = a[..n].chunks_exact(4);
    let mut cb = b[..n].chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        a0 += x[0] * y[0];
        a1 += x[1] * y[1];
        a2 += x[2] * y[2];
        a3 += x[3] * y[3];
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        a0 += x * y;
    }
    (a0 + a1) + (a2 + a3)
}

/// Dense fused `out = a + alpha * b` in one pass — the async merger's
/// candidate constructor. One read of each input and one write of the
/// output, versus the memcpy-then-axpy double traffic of
/// `copy_from_slice` + [`axpy`]. Lengths must match (release-grade
/// assert, as in [`dot`]).
#[inline]
pub fn scaled_sum_into(out: &mut [f64], a: &[f64], alpha: f64, b: &[f64]) {
    assert_eq!(a.len(), b.len(), "dense scaled_sum length mismatch");
    assert_eq!(out.len(), a.len(), "dense scaled_sum output length mismatch");
    let mut co = out.chunks_exact_mut(4);
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for ((o, x), y) in (&mut co).zip(&mut ca).zip(&mut cb) {
        o[0] = x[0] + alpha * y[0];
        o[1] = x[1] + alpha * y[1];
        o[2] = x[2] + alpha * y[2];
        o[3] = x[3] + alpha * y[3];
    }
    for ((o, x), y) in co.into_remainder().iter_mut().zip(ca.remainder()).zip(cb.remainder()) {
        *o = x + alpha * y;
    }
}

/// Dense 4-way unrolled `y += alpha * x`. Safe (`chunks_exact`);
/// lengths must match (release-grade assert, as in [`dot`]).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "dense axpy length mismatch");
    let n = x.len();
    let mut cx = x[..n].chunks_exact(4);
    let mut cy = y[..n].chunks_exact_mut(4);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        ys[0] += alpha * xs[0];
        ys[1] += alpha * xs[1];
        ys[2] += alpha * xs[2];
        ys[3] += alpha * xs[3];
    }
    for (xv, yv) in cx.remainder().iter().zip(cy.into_remainder().iter_mut()) {
        *yv += alpha * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Random sorted duplicate-free sparse row over a dense vector of
    /// dimension `d`; `nnz` is chosen to exercise empty rows and every
    /// `nnz % 4` tail class.
    fn random_row(g: &mut prop::Gen, d: usize) -> (Vec<u32>, Vec<f64>) {
        let nnz = g.usize_in(0, d.min(23));
        let pat = g.sparse_pattern(d, nnz);
        let idx: Vec<u32> = pat.iter().map(|&c| c as u32).collect();
        let vals = g.vec_f64(idx.len(), -3.0, 3.0);
        (idx, vals)
    }

    #[test]
    fn unchecked_dot_bit_identical_to_checked() {
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w = g.vec_f64(d, -2.0, 2.0);
            let a = dot_dense_checked(&idx, &vals, &w);
            // SAFETY: idx comes from sparse_pattern over [0, d), so every
            // index is in bounds for w.
            let b = unsafe { dot_dense_unchecked(&idx, &vals, &w) };
            prop::assert_holds(a.to_bits() == b.to_bits(), "dot checked == unchecked (bits)")
        });
    }

    #[test]
    fn unchecked_axpy_bit_identical_to_checked_and_scalar() {
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w0 = g.vec_f64(d, -2.0, 2.0);
            let s = g.f64_in(-2.0, 2.0);
            let mut wa = w0.clone();
            let mut wb = w0.clone();
            let mut wc = w0.clone();
            axpy_checked(s, &idx, &vals, &mut wa);
            // SAFETY: indices in bounds by construction (sparse_pattern).
            unsafe { axpy_unchecked(s, &idx, &vals, &mut wb) };
            axpy_scalar(s, &idx, &vals, &mut wc);
            for t in 0..d {
                // scatter touches each distinct index once: all three
                // variants perform the identical per-slot arithmetic
                prop::assert_holds(
                    wa[t].to_bits() == wb[t].to_bits() && wa[t].to_bits() == wc[t].to_bits(),
                    "axpy checked == unchecked == scalar (bits)",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn fused_step_bit_identical_to_checked_and_split() {
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w0 = g.vec_f64(d, -2.0, 2.0);
            let coeff = g.f64_in(-1.0, 1.0);
            let upd = |dot: f64| coeff * dot;
            let mut wa = w0.clone();
            let mut wb = w0.clone();
            let mut wc = w0.clone();
            let (da, sa) = step_checked(&idx, &vals, &mut wa, upd);
            // SAFETY: indices in bounds by construction (sparse_pattern).
            let (db, sb) = unsafe { step_unchecked(&idx, &vals, &mut wb, upd) };
            // split reference: same kernels called separately
            let dc = dot_dense_checked(&idx, &vals, &wc);
            let sc = upd(dc);
            if sc != 0.0 {
                axpy_checked(sc, &idx, &vals, &mut wc);
            }
            prop::assert_holds(da.to_bits() == db.to_bits() && da.to_bits() == dc.to_bits(), "step dot parity")?;
            prop::assert_holds(sa.to_bits() == sb.to_bits() && sa.to_bits() == sc.to_bits(), "step scale parity")?;
            for t in 0..d {
                prop::assert_holds(
                    wa[t].to_bits() == wb[t].to_bits() && wa[t].to_bits() == wc[t].to_bits(),
                    "step w parity (bits)",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn lane_dot_close_to_scalar_reference() {
        // lanes re-associate the sum: agreement is up to fp rounding, not
        // bit-exact — that is the documented contract
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w = g.vec_f64(d, -2.0, 2.0);
            let a = dot_dense_checked(&idx, &vals, &w);
            let b = dot_dense_scalar(&idx, &vals, &w);
            prop::assert_close(a, b, 1e-13, "lanes vs scalar dot")
        });
    }

    #[test]
    fn empty_row_is_identity() {
        let w0 = vec![1.0, 2.0, 3.0];
        let mut w = w0.clone();
        assert_eq!(dot_dense_checked(&[], &[], &w), 0.0);
        assert_eq!(unsafe { dot_dense_unchecked(&[], &[], &w) }, 0.0);
        axpy_checked(2.0, &[], &[], &mut w);
        unsafe { axpy_unchecked(2.0, &[], &[], &mut w) };
        let (dot, scale) = step_checked(&[], &[], &mut w, |d| d + 1.0);
        assert_eq!((dot, scale), (0.0, 1.0));
        assert_eq!(w, w0);
    }

    #[test]
    fn tail_classes_nnz_mod_4() {
        // exercise every tail length explicitly at small fixed sizes
        for nnz in 0..=9usize {
            let idx: Vec<u32> = (0..nnz as u32).map(|k| 2 * k).collect();
            let vals: Vec<f64> = (0..nnz).map(|k| k as f64 + 0.5).collect();
            let d = 2 * nnz + 1;
            let w: Vec<f64> = (0..d).map(|t| 0.1 * t as f64).collect();
            let a = dot_dense_checked(&idx, &vals, &w);
            let b = unsafe { dot_dense_unchecked(&idx, &vals, &w) };
            assert_eq!(a.to_bits(), b.to_bits(), "nnz = {nnz}");
            let mut wa = w.clone();
            let mut wb = w.clone();
            axpy_checked(0.25, &idx, &vals, &mut wa);
            unsafe { axpy_unchecked(0.25, &idx, &vals, &mut wb) };
            assert_eq!(wa, wb, "nnz = {nnz}");
        }
    }

    #[test]
    fn dense_kernels_match_scalar() {
        prop::check(100, |g| {
            let n = g.usize_in(0, 40);
            let a = g.vec_f64(n, -2.0, 2.0);
            let b = g.vec_f64(n, -2.0, 2.0);
            let scalar: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            prop::assert_close(dot(&a, &b), scalar, 1e-13, "dense dot")?;
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            axpy(0.7, &a, &mut y1);
            for (t, yv) in y2.iter_mut().enumerate() {
                *yv += 0.7 * a[t];
            }
            for t in 0..n {
                prop::assert_holds(y1[t].to_bits() == y2[t].to_bits(), "dense axpy bits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn scaled_sum_matches_copy_then_axpy() {
        prop::check(100, |g| {
            let n = g.usize_in(0, 40);
            let a = g.vec_f64(n, -2.0, 2.0);
            let b = g.vec_f64(n, -2.0, 2.0);
            let alpha = g.f64_in(-2.0, 2.0);
            let mut fused = vec![0.0; n];
            scaled_sum_into(&mut fused, &a, alpha, &b);
            let mut split = a.clone();
            axpy(alpha, &b, &mut split);
            for t in 0..n {
                prop::assert_holds(fused[t].to_bits() == split[t].to_bits(), "scaled_sum bits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn step_skips_scatter_on_zero_scale() {
        let idx = [0u32, 2];
        let vals = [1.0, 4.0];
        let mut w = vec![1.0, 1.0, 1.0];
        let (dot, scale) = step_checked(&idx, &vals, &mut w, |_| 0.0);
        assert_eq!(dot, 5.0);
        assert_eq!(scale, 0.0);
        assert_eq!(w, vec![1.0, 1.0, 1.0]);
    }
}
