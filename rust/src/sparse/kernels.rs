//! Hot-path sparse/dense kernels: 4-way unrolled gather/scatter with
//! independent accumulator lanes, in checked and unchecked flavors, plus
//! runtime-dispatched SIMD tiers and a software-pipelined multi-row
//! variant.
//!
//! The CD inner loop is one sparse gather-dot followed by (usually) one
//! sparse scatter-add over the same row slices. The paper's wall-clock
//! claim lives or dies on the cost of those two primitives, so this
//! module rewrites them with
//!
//! * **4 independent accumulator lanes** — breaks the sequential
//!   floating-point dependency chain so the CPU can keep several
//!   multiply-adds in flight,
//! * **`get_unchecked` indexing** on the unchecked variants — the gather
//!   `w[indices[k]]` otherwise pays one bounds check per non-zero,
//! * a **fused [`step_unchecked`]** entry point that runs the gradient
//!   dot and the scatter-update back-to-back on the same row slices
//!   while they are hot in cache,
//! * **explicit SIMD tiers** dispatched at runtime (below), and
//! * **software pipelining** ([`dot_many_unchecked`], [`prefetch_row`])
//!   that issues the next row's cache-line loads while the current row's
//!   reduction is still retiring.
//!
//! # Runtime dispatch
//!
//! The unchecked entry points ([`dot_dense_unchecked`],
//! [`axpy_unchecked`], [`step_unchecked`]) route through a process-wide
//! dispatch table resolved exactly once ([`active_tier`], a
//! `OnceLock<&'static KernelTier>`). Tier selection order:
//!
//! 1. the `ACF_FORCE_KERNEL` override (`scalar` | `simd` | `auto`,
//!    parsed once by [`crate::util::cpufeat::kernel_force`]), then
//! 2. the best tier the CPU supports: `avx2+fma` when `cpuid` reports
//!    both AVX2 and FMA, else `sse2` (baseline on x86_64); `neon` on
//!    aarch64 (baseline); `scalar` everywhere else.
//!
//! The 4-way **scalar unroll is always compiled** and remains both the
//! fallback tier and the parity oracle — SIMD tiers are an
//! implementation detail behind the same contract, never a semantic
//! fork. The `*_checked` twins below never dispatch: they are the fixed
//! scalar reference every tier is tested against.
//!
//! # Bit-identity / reduction-tree contract
//!
//! Every tier — scalar, SSE2, AVX2+FMA, NEON — produces **bit-identical
//! results** for `dot`, `axpy`, and the fused `step`. The sharded
//! engine's determinism guarantees (sync runs bit-identical across
//! `--shard-workers` counts, owned ↔ mmap data-plane parity, tracing
//! non-perturbation) silently assume the kernels are a pure function of
//! their inputs; dispatch must not make results a function of the host
//! CPU. Concretely, every implementation keeps the exact reduction tree
//! of the scalar unroll:
//!
//! * the dot keeps 4 independent accumulators where lane `l` sums the
//!   elements at positions `4c + l` in chunk order, the `nnz % 4` tail
//!   folds into lane 0, and the final reduction is `(a0 + a1) +
//!   (a2 + a3)` — SIMD lanes map 1:1 onto scalar lanes, so every
//!   intermediate rounding is the same;
//! * **no FMA contraction anywhere**: the scalar unroll rounds the
//!   product and the add separately, so the AVX2 tier uses
//!   `mul_pd` + `add_pd` rather than `vfmadd` (one rounding) — the
//!   `+fma` in the tier name records the *detection gate*, not the
//!   instruction mix;
//! * the axpy vectorizes only the products `scale * values[k]` (the
//!   same single IEEE multiply as the scalar path) and applies the
//!   scatter `w[j] += p` element-by-element in row order — which also
//!   keeps repeated indices exact, a stronger property than CSR needs;
//! * prefetching ([`prefetch_row`], [`dot_many_unchecked`]) changes
//!   memory timing only, never arithmetic.
//!
//! The per-tier property tests at the bottom assert bit-identity against
//! the checked oracle for every tier the host can run, across empty
//! rows, repeated axpy indices, and every `nnz % 4` tail class.
//!
//! # Safety contract of the unchecked paths
//!
//! Every `*_unchecked` function (and every [`KernelTier`] method)
//! requires, and `debug_assert!`s where practical:
//!
//! 1. `indices.len() == values.len()`;
//! 2. every `indices[k] as usize` is in bounds for `w`.
//!
//! Violating either in a release build is undefined behavior. The safe
//! entry points ([`crate::sparse::RowView::dot_dense`] and friends)
//! restore soundness with an O(1) check: CSR row indices are *strictly
//! increasing* (a [`crate::sparse::Csr`] structural invariant verified
//! by `check_invariants` and the construction paths), so checking
//! `indices.last() < w.len()` bounds every index in the row.
//!
//! # Parity oracle
//!
//! Each unchecked kernel has a `*_checked` twin generated from the same
//! monomorphized implementation (`const CHECKED: bool` toggles the
//! indexing only), so checked and scalar-unrolled results are
//! **bit-identical by construction**, and every SIMD tier is tested
//! bit-exact against that twin. The pre-existing sequential
//! implementations remain as [`dot_dense_scalar`] / [`axpy_scalar`]: the
//! *semantic* oracle (and the perf baseline of
//! `benches/kernel_microbench.rs`; `#[inline(never)]` keeps that
//! baseline honest). Note that lane accumulation re-associates the
//! dot-product sum, so the unrolled dot agrees with the sequential
//! reference only up to floating-point rounding; the scatter-add touches
//! each (distinct) index exactly once and is bit-identical to the
//! sequential version.

use crate::util::cpufeat;
use std::sync::OnceLock;

/// Sequential bounds-checked sparse dot — the original implementation,
/// kept as the semantic oracle and microbench baseline.
/// (`inline(never)`: the microbench measures it as a real call, so the
/// baseline cannot be inlined-and-vectorized into something it is not.)
#[inline(never)]
pub fn dot_dense_scalar(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (&j, &v) in indices.iter().zip(values.iter()) {
        acc += v * w[j as usize];
    }
    acc
}

/// Sequential bounds-checked scatter-add `w[indices[k]] += scale *
/// values[k]` — the original implementation, kept as the semantic oracle
/// and microbench baseline (`inline(never)`, as in [`dot_dense_scalar`]).
#[inline(never)]
pub fn axpy_scalar(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    for (&j, &v) in indices.iter().zip(values.iter()) {
        w[j as usize] += scale * v;
    }
}

/// Shared 4-lane gather-dot body; `CHECKED` selects the indexing and is
/// resolved at monomorphization time, so both flavors run the identical
/// floating-point schedule (bit-identical results).
///
/// Safety: with `CHECKED = false` the caller must uphold the module-level
/// contract (index bounds); with `CHECKED = true` the function is safe.
#[inline(always)]
unsafe fn dot_lanes<const CHECKED: bool>(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    let n = indices.len();
    let chunks = n / 4;
    let mut a0 = 0.0f64;
    let mut a1 = 0.0f64;
    let mut a2 = 0.0f64;
    let mut a3 = 0.0f64;
    macro_rules! at {
        ($k:expr) => {{
            let j = if CHECKED {
                indices[$k] as usize
            } else {
                *indices.get_unchecked($k) as usize
            };
            let v = if CHECKED { values[$k] } else { *values.get_unchecked($k) };
            debug_assert!(j < w.len(), "sparse index {j} out of bounds ({})", w.len());
            let x = if CHECKED { w[j] } else { *w.get_unchecked(j) };
            v * x
        }};
    }
    for c in 0..chunks {
        let base = c * 4;
        a0 += at!(base);
        a1 += at!(base + 1);
        a2 += at!(base + 2);
        a3 += at!(base + 3);
    }
    for k in chunks * 4..n {
        a0 += at!(k);
    }
    (a0 + a1) + (a2 + a3)
}

/// Shared 4-way unrolled scatter-add body; see [`dot_lanes`] for the
/// `CHECKED` mechanics. Correct even with repeated indices (the four
/// per-chunk updates execute in order); CSR rows never repeat indices,
/// which is what lets the compiler schedule them independently.
///
/// Safety: same contract as [`dot_lanes`]: with `CHECKED = false` every
/// index must be in bounds for `w`; with `CHECKED = true` the function
/// is effectively safe.
#[inline(always)]
unsafe fn axpy_unrolled<const CHECKED: bool>(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    debug_assert_eq!(indices.len(), values.len());
    let n = indices.len();
    let chunks = n / 4;
    macro_rules! upd {
        ($k:expr) => {{
            let j = if CHECKED {
                indices[$k] as usize
            } else {
                *indices.get_unchecked($k) as usize
            };
            let v = if CHECKED { values[$k] } else { *values.get_unchecked($k) };
            debug_assert!(j < w.len(), "sparse index {j} out of bounds ({})", w.len());
            if CHECKED {
                w[j] += scale * v;
            } else {
                *w.get_unchecked_mut(j) += scale * v;
            }
        }};
    }
    for c in 0..chunks {
        let base = c * 4;
        upd!(base);
        upd!(base + 1);
        upd!(base + 2);
        upd!(base + 3);
    }
    for k in chunks * 4..n {
        upd!(k);
    }
}

/// 4-lane gather-dot, bounds-checked — the parity oracle every dispatch
/// tier is tested bit-exact against. Never dispatched.
#[inline]
pub fn dot_dense_checked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    // SAFETY: CHECKED = true performs ordinary indexing; no contract.
    unsafe { dot_lanes::<true>(indices, values, w) }
}

/// 4-way unrolled scatter-add, bounds-checked — the parity oracle for
/// [`axpy_unchecked`] and the SIMD tiers. Never dispatched.
#[inline]
pub fn axpy_checked(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    // SAFETY: CHECKED = true performs ordinary indexing; no contract.
    unsafe { axpy_unrolled::<true>(scale, indices, values, w) }
}

/// The always-compiled scalar-unroll tier: the unchecked 4-lane kernels,
/// exposed directly so benches and the dispatch table can name the tier
/// regardless of what the CPU supports.
pub mod scalar {
    /// Scalar-tier unchecked gather-dot (4 accumulator lanes).
    ///
    /// # Safety
    /// Same contract as [`super::dot_dense_unchecked`].
    #[inline]
    pub unsafe fn dot(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        super::dot_lanes::<false>(indices, values, w)
    }

    /// Scalar-tier unchecked scatter-add (4-way unrolled).
    ///
    /// # Safety
    /// Same contract as [`super::axpy_unchecked`].
    #[inline]
    pub unsafe fn axpy(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
        super::axpy_unrolled::<false>(scale, indices, values, w)
    }
}

/// x86_64 SIMD tiers. The AVX2 bodies carry `#[target_feature]` and are
/// reached through plain `unsafe fn` wrappers (MSRV 1.73 cannot coerce
/// `#[target_feature]` functions to fn pointers); SSE2 is part of the
/// x86_64 baseline and needs no gate. Both keep the scalar reduction
/// tree exactly — see the module docs — and in particular use separate
/// multiply and add instructions (no FMA contraction).
#[cfg(all(target_arch = "x86_64", not(miri)))]
pub mod x86 {
    use core::arch::x86_64::*;

    /// AVX2 unchecked gather-dot: 4 f64 lanes per step via
    /// `vgatherdpd`, lane `l` accumulating scalar lane `l` exactly.
    ///
    /// # Safety
    /// Same contract as [`super::dot_dense_unchecked`]; additionally the
    /// CPU must support AVX2 (guaranteed by the dispatch table).
    #[inline]
    pub unsafe fn dot_avx2(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        // vgatherdpd offsets are signed 32-bit: on a >2^31-element dense
        // vector the gather could not address the tail, so fall back to
        // the (bit-identical) scalar tier for such degenerate shapes.
        if w.len() > i32::MAX as usize {
            return super::scalar::dot(indices, values, w);
        }
        dot_avx2_body(indices, values, w)
    }

    // SAFETY: same index contract as the public `dot_avx2` wrapper; the
    // target_feature additionally requires AVX2+FMA, which the wrapper's
    // caller established via the dispatch probe.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_avx2_body(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        debug_assert_eq!(indices.len(), values.len());
        let n = indices.len();
        let chunks = n / 4;
        let ip = indices.as_ptr();
        let vp = values.as_ptr();
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let base = c * 4;
            // SAFETY (caller contract): 4 u32 indices and 4 f64 values
            // are in bounds at `base`, and every gathered index < w.len().
            let idx = _mm_loadu_si128(ip.add(base) as *const __m128i);
            let x = _mm256_i32gather_pd::<8>(w.as_ptr(), idx);
            let v = _mm256_loadu_pd(vp.add(base));
            // mul then add, NOT vfmadd: the scalar oracle rounds the
            // product and the sum separately, and a fused single
            // rounding would break the bit-identity contract.
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, x));
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut a0 = lanes[0];
        for k in chunks * 4..n {
            a0 += *vp.add(k) * *w.get_unchecked(*ip.add(k) as usize);
        }
        (a0 + lanes[1]) + (lanes[2] + lanes[3])
    }

    /// AVX2 unchecked scatter-add: products `scale * values` vectorize;
    /// the scatter stays element-by-element in row order (repeated
    /// indices observe every prior update, exactly like the scalar
    /// unroll).
    ///
    /// # Safety
    /// Same contract as [`super::axpy_unchecked`]; additionally the CPU
    /// must support AVX2 (guaranteed by the dispatch table).
    #[inline]
    pub unsafe fn axpy_avx2(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
        axpy_avx2_body(scale, indices, values, w)
    }

    // SAFETY: same contract as `axpy_avx2` plus the AVX2+FMA feature
    // requirement established by the dispatch probe.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy_avx2_body(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
        debug_assert_eq!(indices.len(), values.len());
        let n = indices.len();
        let chunks = n / 4;
        let ip = indices.as_ptr();
        let vp = values.as_ptr();
        let s = _mm256_set1_pd(scale);
        let mut prod = [0.0f64; 4];
        for c in 0..chunks {
            let base = c * 4;
            // SAFETY (caller contract): 4 values in bounds at `base`.
            let v = _mm256_loadu_pd(vp.add(base));
            _mm256_storeu_pd(prod.as_mut_ptr(), _mm256_mul_pd(s, v));
            for (l, p) in prod.iter().enumerate() {
                let j = *ip.add(base + l) as usize;
                *w.get_unchecked_mut(j) += *p;
            }
        }
        for k in chunks * 4..n {
            let j = *ip.add(k) as usize;
            *w.get_unchecked_mut(j) += scale * *vp.add(k);
        }
    }

    /// SSE2 unchecked gather-dot: two 2-lane accumulators `[a0, a1]` /
    /// `[a2, a3]`, gathers packed from scalar loads (SSE2 has no gather
    /// instruction), reduction `(a0 + a1) + (a2 + a3)` in scalar.
    ///
    /// # Safety
    /// Same contract as [`super::dot_dense_unchecked`]. SSE2 is baseline
    /// on x86_64; no extra CPU requirement.
    #[inline]
    pub unsafe fn dot_sse2(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        debug_assert_eq!(indices.len(), values.len());
        let n = indices.len();
        let chunks = n / 4;
        let ip = indices.as_ptr();
        let vp = values.as_ptr();
        let mut acc01 = _mm_setzero_pd();
        let mut acc23 = _mm_setzero_pd();
        for c in 0..chunks {
            let base = c * 4;
            // SAFETY (caller contract): 4 indices/values in bounds at
            // `base`, every index < w.len().
            let j0 = *ip.add(base) as usize;
            let j1 = *ip.add(base + 1) as usize;
            let j2 = *ip.add(base + 2) as usize;
            let j3 = *ip.add(base + 3) as usize;
            // _mm_set_pd lists lanes high-to-low: lane 0 is w[j0]
            let x01 = _mm_set_pd(*w.get_unchecked(j1), *w.get_unchecked(j0));
            let x23 = _mm_set_pd(*w.get_unchecked(j3), *w.get_unchecked(j2));
            let v01 = _mm_loadu_pd(vp.add(base));
            let v23 = _mm_loadu_pd(vp.add(base + 2));
            acc01 = _mm_add_pd(acc01, _mm_mul_pd(v01, x01));
            acc23 = _mm_add_pd(acc23, _mm_mul_pd(v23, x23));
        }
        let mut l01 = [0.0f64; 2];
        let mut l23 = [0.0f64; 2];
        _mm_storeu_pd(l01.as_mut_ptr(), acc01);
        _mm_storeu_pd(l23.as_mut_ptr(), acc23);
        let mut a0 = l01[0];
        for k in chunks * 4..n {
            a0 += *vp.add(k) * *w.get_unchecked(*ip.add(k) as usize);
        }
        (a0 + l01[1]) + (l23[0] + l23[1])
    }

    /// SSE2 unchecked scatter-add: 2-lane product vectors, scatter
    /// element-by-element in row order (see [`axpy_avx2`]).
    ///
    /// # Safety
    /// Same contract as [`super::axpy_unchecked`]. SSE2 is baseline on
    /// x86_64; no extra CPU requirement.
    #[inline]
    pub unsafe fn axpy_sse2(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
        debug_assert_eq!(indices.len(), values.len());
        let n = indices.len();
        let chunks = n / 4;
        let ip = indices.as_ptr();
        let vp = values.as_ptr();
        let s = _mm_set1_pd(scale);
        let mut prod = [0.0f64; 4];
        for c in 0..chunks {
            let base = c * 4;
            // SAFETY (caller contract): 4 values in bounds at `base`.
            let v01 = _mm_loadu_pd(vp.add(base));
            let v23 = _mm_loadu_pd(vp.add(base + 2));
            _mm_storeu_pd(prod.as_mut_ptr(), _mm_mul_pd(s, v01));
            _mm_storeu_pd(prod.as_mut_ptr().add(2), _mm_mul_pd(s, v23));
            for (l, p) in prod.iter().enumerate() {
                let j = *ip.add(base + l) as usize;
                *w.get_unchecked_mut(j) += *p;
            }
        }
        for k in chunks * 4..n {
            let j = *ip.add(k) as usize;
            *w.get_unchecked_mut(j) += scale * *vp.add(k);
        }
    }
}

/// aarch64 NEON tier: two 2-lane accumulators mirroring the SSE2 shape.
/// NEON is baseline on aarch64; the `#[target_feature]` bodies are
/// reached through plain `unsafe fn` wrappers for fn-pointer coercion
/// (as in the `x86` module). No FMA contraction (`vmulq` + `vaddq`,
/// never `vfmaq`) — see the module docs for the bit-identity contract.
#[cfg(all(target_arch = "aarch64", not(miri)))]
pub mod neon {
    use core::arch::aarch64::*;

    /// NEON unchecked gather-dot.
    ///
    /// # Safety
    /// Same contract as [`super::dot_dense_unchecked`]. NEON is baseline
    /// on aarch64; no extra CPU requirement.
    #[inline]
    pub unsafe fn dot(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        dot_body(indices, values, w)
    }

    // SAFETY: same index contract as the public `dot` wrapper; NEON is
    // baseline on aarch64, so the feature requirement is always met.
    #[target_feature(enable = "neon")]
    unsafe fn dot_body(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        debug_assert_eq!(indices.len(), values.len());
        let n = indices.len();
        let chunks = n / 4;
        let ip = indices.as_ptr();
        let vp = values.as_ptr();
        let wp = w.as_ptr();
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        for c in 0..chunks {
            let base = c * 4;
            // SAFETY (caller contract): 4 indices/values in bounds at
            // `base`, every index < w.len().
            let j0 = *ip.add(base) as usize;
            let j1 = *ip.add(base + 1) as usize;
            let j2 = *ip.add(base + 2) as usize;
            let j3 = *ip.add(base + 3) as usize;
            let x01 = vcombine_f64(vld1_f64(wp.add(j0)), vld1_f64(wp.add(j1)));
            let x23 = vcombine_f64(vld1_f64(wp.add(j2)), vld1_f64(wp.add(j3)));
            let v01 = vld1q_f64(vp.add(base));
            let v23 = vld1q_f64(vp.add(base + 2));
            acc01 = vaddq_f64(acc01, vmulq_f64(v01, x01));
            acc23 = vaddq_f64(acc23, vmulq_f64(v23, x23));
        }
        let mut a0 = vgetq_lane_f64::<0>(acc01);
        for k in chunks * 4..n {
            a0 += *vp.add(k) * *wp.add(*ip.add(k) as usize);
        }
        (a0 + vgetq_lane_f64::<1>(acc01)) + (vgetq_lane_f64::<0>(acc23) + vgetq_lane_f64::<1>(acc23))
    }

    /// NEON unchecked scatter-add: 2-lane product vectors, scatter
    /// element-by-element in row order (see the module docs).
    ///
    /// # Safety
    /// Same contract as [`super::axpy_unchecked`]. NEON is baseline on
    /// aarch64; no extra CPU requirement.
    #[inline]
    pub unsafe fn axpy(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
        axpy_body(scale, indices, values, w)
    }

    // SAFETY: same contract as the public `axpy` wrapper; NEON is
    // baseline on aarch64.
    #[target_feature(enable = "neon")]
    unsafe fn axpy_body(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
        debug_assert_eq!(indices.len(), values.len());
        let n = indices.len();
        let chunks = n / 4;
        let ip = indices.as_ptr();
        let vp = values.as_ptr();
        let s = vdupq_n_f64(scale);
        let mut prod = [0.0f64; 4];
        for c in 0..chunks {
            let base = c * 4;
            // SAFETY (caller contract): 4 values in bounds at `base`.
            let v01 = vld1q_f64(vp.add(base));
            let v23 = vld1q_f64(vp.add(base + 2));
            vst1q_f64(prod.as_mut_ptr(), vmulq_f64(s, v01));
            vst1q_f64(prod.as_mut_ptr().add(2), vmulq_f64(s, v23));
            for (l, p) in prod.iter().enumerate() {
                let j = *ip.add(base + l) as usize;
                *w.get_unchecked_mut(j) += *p;
            }
        }
        for k in chunks * 4..n {
            let j = *ip.add(k) as usize;
            *w.get_unchecked_mut(j) += scale * *vp.add(k);
        }
    }
}

/// One resolved kernel implementation tier: a named pair of unchecked
/// `dot`/`axpy` entry points with identical (bit-exact) semantics.
/// `&'static KernelTier` values come from [`active_tier`] /
/// [`available_tiers`]; the struct is plain fn pointers, so a tier is
/// `Copy` and free to pass around.
#[derive(Clone, Copy, Debug)]
pub struct KernelTier {
    name: &'static str,
    dot: unsafe fn(&[u32], &[f64], &[f64]) -> f64,
    axpy: unsafe fn(f64, &[u32], &[f64], &mut [f64]),
}

impl KernelTier {
    /// Tier name: `"scalar"`, `"sse2"`, `"avx2+fma"`, or `"neon"`.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// This tier's unchecked gather-dot.
    ///
    /// # Safety
    /// Same contract as [`dot_dense_unchecked`].
    #[inline]
    pub unsafe fn dot(&self, indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        (self.dot)(indices, values, w)
    }

    /// This tier's unchecked scatter-add.
    ///
    /// # Safety
    /// Same contract as [`axpy_unchecked`].
    #[inline]
    pub unsafe fn axpy(&self, scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
        (self.axpy)(scale, indices, values, w)
    }

    /// This tier's fused CD step (dot → `update` → conditional scatter;
    /// see [`step_unchecked`] for the semantics).
    ///
    /// # Safety
    /// Same contract as [`step_unchecked`].
    #[inline]
    pub unsafe fn step<F: FnOnce(f64) -> f64>(
        &self,
        indices: &[u32],
        values: &[f64],
        w: &mut [f64],
        update: F,
    ) -> (f64, f64) {
        let dot = (self.dot)(indices, values, w);
        let scale = update(dot);
        if scale != 0.0 {
            (self.axpy)(scale, indices, values, w);
        }
        (dot, scale)
    }
}

static SCALAR_TIER: KernelTier = KernelTier { name: "scalar", dot: scalar::dot, axpy: scalar::axpy };
#[cfg(all(target_arch = "x86_64", not(miri)))]
static SSE2_TIER: KernelTier = KernelTier { name: "sse2", dot: x86::dot_sse2, axpy: x86::axpy_sse2 };
#[cfg(all(target_arch = "x86_64", not(miri)))]
static AVX2_TIER: KernelTier = KernelTier { name: "avx2+fma", dot: x86::dot_avx2, axpy: x86::axpy_avx2 };
#[cfg(all(target_arch = "aarch64", not(miri)))]
static NEON_TIER: KernelTier = KernelTier { name: "neon", dot: neon::dot, axpy: neon::axpy };

static ACTIVE_TIER: OnceLock<&'static KernelTier> = OnceLock::new();

/// The tier every dispatched entry point runs on, resolved once per
/// process: the `ACF_FORCE_KERNEL` override if set, else the best tier
/// the CPU supports. One atomic load after first use.
#[inline]
pub fn active_tier() -> &'static KernelTier {
    ACTIVE_TIER.get_or_init(select_tier)
}

/// Name of the active dispatch tier (`"avx2+fma"` / `"sse2"` / `"neon"`
/// / `"scalar"`) — recorded in bench metadata so runs from different
/// hosts stay comparable.
pub fn active_tier_name() -> &'static str {
    active_tier().name
}

fn select_tier() -> &'static KernelTier {
    match cpufeat::kernel_force() {
        cpufeat::KernelForce::Scalar => &SCALAR_TIER,
        cpufeat::KernelForce::Auto | cpufeat::KernelForce::Simd => simd_tier().unwrap_or(&SCALAR_TIER),
    }
}

/// Best SIMD tier the running CPU can execute, or `None` when only the
/// scalar tier exists for this architecture.
#[cfg(all(target_arch = "x86_64", not(miri)))]
pub fn simd_tier() -> Option<&'static KernelTier> {
    if cpufeat::has_avx2_fma() {
        Some(&AVX2_TIER)
    } else {
        // SSE2 is part of the x86_64 baseline: always runnable
        Some(&SSE2_TIER)
    }
}

/// Best SIMD tier the running CPU can execute, or `None` when only the
/// scalar tier exists for this architecture.
#[cfg(all(target_arch = "aarch64", not(miri)))]
pub fn simd_tier() -> Option<&'static KernelTier> {
    // NEON is part of the aarch64 baseline: always runnable
    Some(&NEON_TIER)
}

/// Best SIMD tier the running CPU can execute, or `None` when only the
/// scalar tier exists for this architecture.
#[cfg(any(miri, not(any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn simd_tier() -> Option<&'static KernelTier> {
    None
}

/// Every tier the running CPU can execute, scalar first. The per-tier
/// bit-identity property tests iterate this list, so one test binary
/// covers all locally runnable tiers regardless of which one dispatch
/// selected.
pub fn available_tiers() -> Vec<&'static KernelTier> {
    #[allow(unused_mut)]
    let mut tiers: Vec<&'static KernelTier> = vec![&SCALAR_TIER];
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        tiers.push(&SSE2_TIER);
        if cpufeat::has_avx2_fma() {
            tiers.push(&AVX2_TIER);
        }
    }
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    tiers.push(&NEON_TIER);
    tiers
}

/// 4-lane gather-dot with unchecked indexing, dispatched to the active
/// tier ([`active_tier`]); bit-identical to [`dot_dense_checked`] on
/// every tier.
///
/// # Safety
/// `indices.len() == values.len()` and every `indices[k] as usize` must
/// be `< w.len()` (see the module docs).
#[inline]
pub unsafe fn dot_dense_unchecked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    (active_tier().dot)(indices, values, w)
}

/// Unrolled scatter-add with unchecked indexing, dispatched to the
/// active tier; bit-identical to [`axpy_checked`] on every tier.
///
/// # Safety
/// Same contract as [`dot_dense_unchecked`], with `w` writable.
#[inline]
pub unsafe fn axpy_unchecked(scale: f64, indices: &[u32], values: &[f64], w: &mut [f64]) {
    (active_tier().axpy)(scale, indices, values, w)
}

/// Fused CD step on one sparse row: gather-dot against `w`, hand the
/// result to `update` (which performs the O(1) coordinate math and
/// returns the scatter scale; `0.0` means "no update"), then scatter-add
/// on the *same, still-cache-hot* row slices. Returns `(dot, scale)`.
/// Both halves run on the active dispatch tier.
///
/// # Safety
/// Same contract as [`dot_dense_unchecked`], with `w` writable.
#[inline]
pub unsafe fn step_unchecked<F: FnOnce(f64) -> f64>(
    indices: &[u32],
    values: &[f64],
    w: &mut [f64],
    update: F,
) -> (f64, f64) {
    active_tier().step(indices, values, w, update)
}

/// Bounds-checked twin of [`step_unchecked`] (parity oracle; always the
/// scalar unroll, never dispatched).
#[inline]
pub fn step_checked<F: FnOnce(f64) -> f64>(indices: &[u32], values: &[f64], w: &mut [f64], update: F) -> (f64, f64) {
    // SAFETY: CHECKED = true performs ordinary indexing; no contract.
    let dot = unsafe { dot_lanes::<true>(indices, values, w) };
    let scale = update(dot);
    if scale != 0.0 {
        // SAFETY: CHECKED = true performs ordinary indexing; no contract.
        unsafe { axpy_unrolled::<true>(scale, indices, values, w) };
    }
    (dot, scale)
}

/// Best-effort prefetch of the cache line at `p`. A pure scheduling
/// hint: `prefetcht0` / `prfm pldl1keep` cannot fault on any address,
/// and the function is a no-op on architectures without a stable
/// prefetch primitive.
#[inline(always)]
fn prefetch_read<T>(p: *const T) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    // SAFETY: prefetch is a hint, not a memory access; any address is
    // acceptable and SSE is part of the x86_64 baseline.
    unsafe {
        use core::arch::x86_64::{_MM_HINT_T0, _mm_prefetch};
        _mm_prefetch::<_MM_HINT_T0>(p as *const i8);
    }
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    // SAFETY: prfm is a hint, not a memory access; any address is
    // acceptable.
    unsafe {
        core::arch::asm!("prfm pldl1keep, [{0}]", in(reg) p, options(nostack, preserves_flags, readonly));
    }
    #[cfg(any(miri, not(any(target_arch = "x86_64", target_arch = "aarch64"))))]
    let _ = p;
}

/// Prefetch the leading cache lines of a sparse row's index/value
/// slices — the software-pipelining half of [`dot_many_unchecked`]:
/// issue these loads for row `k + 1` while row `k`'s reduction is still
/// retiring, so the next row's cache misses overlap the current row's
/// arithmetic. The slice starts plus one line deeper on each side
/// (16 `u32` indices / 8 `f64` values per 64-byte line) cover rows up to
/// two lines long completely; longer rows stream behind the hardware
/// prefetcher once the head is resident. Hint only: results are
/// identical with or without it.
#[inline]
pub fn prefetch_row(indices: &[u32], values: &[f64]) {
    prefetch_read(indices.as_ptr());
    prefetch_read(values.as_ptr());
    if indices.len() > 16 {
        prefetch_read(indices[16..].as_ptr());
    }
    if values.len() > 8 {
        prefetch_read(values[8..].as_ptr());
    }
}

/// Software-pipelined multi-row gather-dot: `out[k] = rows[k] · w`,
/// prefetching row `k + 1`'s slices while row `k` reduces. Bit-identical
/// to calling [`dot_dense_unchecked`] per row — pipelining changes
/// memory timing, never the reduction tree. Used by the batched
/// verification scans and `row_norms_sq()`-style full sweeps.
///
/// # Safety
/// The module contract must hold for **every** `(indices, values)` pair
/// in `rows` against `w`; `rows.len() == out.len()`.
pub unsafe fn dot_many_unchecked(rows: &[(&[u32], &[f64])], w: &[f64], out: &mut [f64]) {
    debug_assert_eq!(rows.len(), out.len());
    let t = active_tier();
    for (k, (&(indices, values), o)) in rows.iter().zip(out.iter_mut()).enumerate() {
        if let Some(&(ni, nv)) = rows.get(k + 1) {
            prefetch_row(ni, nv);
        }
        *o = (t.dot)(indices, values, w);
    }
}

/// Bounds-checked twin of [`dot_many_unchecked`] (parity oracle: scalar
/// checked kernel per row, no prefetch, no dispatch).
pub fn dot_many_checked(rows: &[(&[u32], &[f64])], w: &[f64], out: &mut [f64]) {
    assert_eq!(rows.len(), out.len(), "dot_many length mismatch");
    for (&(indices, values), o) in rows.iter().zip(out.iter_mut()) {
        *o = dot_dense_checked(indices, values, w);
    }
}

/// Dense 4-lane dot product. Safe: `chunks_exact` gives the compiler
/// bounds-check-free access without any unsafe code. Lengths must match
/// (release-grade assert: a silent partial dot would let a
/// wrong-dimension vector corrupt a solve without a diagnostic).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dense dot length mismatch");
    let n = a.len();
    let mut a0 = 0.0f64;
    let mut a1 = 0.0f64;
    let mut a2 = 0.0f64;
    let mut a3 = 0.0f64;
    let mut ca = a[..n].chunks_exact(4);
    let mut cb = b[..n].chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        a0 += x[0] * y[0];
        a1 += x[1] * y[1];
        a2 += x[2] * y[2];
        a3 += x[3] * y[3];
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder().iter()) {
        a0 += x * y;
    }
    (a0 + a1) + (a2 + a3)
}

/// Dense fused `out = a + alpha * b` in one pass — the async merger's
/// candidate constructor. One read of each input and one write of the
/// output, versus the memcpy-then-axpy double traffic of
/// `copy_from_slice` + [`axpy`]. Lengths must match (release-grade
/// assert, as in [`dot`]).
#[inline]
pub fn scaled_sum_into(out: &mut [f64], a: &[f64], alpha: f64, b: &[f64]) {
    assert_eq!(a.len(), b.len(), "dense scaled_sum length mismatch");
    assert_eq!(out.len(), a.len(), "dense scaled_sum output length mismatch");
    let mut co = out.chunks_exact_mut(4);
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for ((o, x), y) in (&mut co).zip(&mut ca).zip(&mut cb) {
        o[0] = x[0] + alpha * y[0];
        o[1] = x[1] + alpha * y[1];
        o[2] = x[2] + alpha * y[2];
        o[3] = x[3] + alpha * y[3];
    }
    for ((o, x), y) in co.into_remainder().iter_mut().zip(ca.remainder()).zip(cb.remainder()) {
        *o = x + alpha * y;
    }
}

/// Dense 4-way unrolled `y += alpha * x`. Safe (`chunks_exact`);
/// lengths must match (release-grade assert, as in [`dot`]).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "dense axpy length mismatch");
    let n = x.len();
    let mut cx = x[..n].chunks_exact(4);
    let mut cy = y[..n].chunks_exact_mut(4);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        ys[0] += alpha * xs[0];
        ys[1] += alpha * xs[1];
        ys[2] += alpha * xs[2];
        ys[3] += alpha * xs[3];
    }
    for (xv, yv) in cx.remainder().iter().zip(cy.into_remainder().iter_mut()) {
        *yv += alpha * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Random sorted duplicate-free sparse row over a dense vector of
    /// dimension `d`; `nnz` is chosen to exercise empty rows and every
    /// `nnz % 4` tail class.
    fn random_row(g: &mut prop::Gen, d: usize) -> (Vec<u32>, Vec<f64>) {
        let nnz = g.usize_in(0, d.min(23));
        let pat = g.sparse_pattern(d, nnz);
        let idx: Vec<u32> = pat.iter().map(|&c| c as u32).collect();
        let vals = g.vec_f64(idx.len(), -3.0, 3.0);
        (idx, vals)
    }

    #[test]
    fn unchecked_dot_bit_identical_to_checked() {
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w = g.vec_f64(d, -2.0, 2.0);
            let a = dot_dense_checked(&idx, &vals, &w);
            // SAFETY: idx comes from sparse_pattern over [0, d), so every
            // index is in bounds for w.
            let b = unsafe { dot_dense_unchecked(&idx, &vals, &w) };
            prop::assert_holds(a.to_bits() == b.to_bits(), "dot checked == unchecked (bits)")
        });
    }

    #[test]
    fn unchecked_axpy_bit_identical_to_checked_and_scalar() {
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w0 = g.vec_f64(d, -2.0, 2.0);
            let s = g.f64_in(-2.0, 2.0);
            let mut wa = w0.clone();
            let mut wb = w0.clone();
            let mut wc = w0.clone();
            axpy_checked(s, &idx, &vals, &mut wa);
            // SAFETY: indices in bounds by construction (sparse_pattern).
            unsafe { axpy_unchecked(s, &idx, &vals, &mut wb) };
            axpy_scalar(s, &idx, &vals, &mut wc);
            for t in 0..d {
                // scatter touches each distinct index once: all three
                // variants perform the identical per-slot arithmetic
                prop::assert_holds(
                    wa[t].to_bits() == wb[t].to_bits() && wa[t].to_bits() == wc[t].to_bits(),
                    "axpy checked == unchecked == scalar (bits)",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn fused_step_bit_identical_to_checked_and_split() {
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w0 = g.vec_f64(d, -2.0, 2.0);
            let coeff = g.f64_in(-1.0, 1.0);
            let upd = |dot: f64| coeff * dot;
            let mut wa = w0.clone();
            let mut wb = w0.clone();
            let mut wc = w0.clone();
            let (da, sa) = step_checked(&idx, &vals, &mut wa, upd);
            // SAFETY: indices in bounds by construction (sparse_pattern).
            let (db, sb) = unsafe { step_unchecked(&idx, &vals, &mut wb, upd) };
            // split reference: same kernels called separately
            let dc = dot_dense_checked(&idx, &vals, &wc);
            let sc = upd(dc);
            if sc != 0.0 {
                axpy_checked(sc, &idx, &vals, &mut wc);
            }
            prop::assert_holds(da.to_bits() == db.to_bits() && da.to_bits() == dc.to_bits(), "step dot parity")?;
            prop::assert_holds(sa.to_bits() == sb.to_bits() && sa.to_bits() == sc.to_bits(), "step scale parity")?;
            for t in 0..d {
                prop::assert_holds(
                    wa[t].to_bits() == wb[t].to_bits() && wa[t].to_bits() == wc[t].to_bits(),
                    "step w parity (bits)",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn all_tiers_dot_bit_identical_to_checked() {
        for tier in available_tiers() {
            prop::check(150, |g| {
                let d = g.usize_in(1, 96);
                let (idx, vals) = random_row(g, d);
                let w = g.vec_f64(d, -2.0, 2.0);
                let a = dot_dense_checked(&idx, &vals, &w);
                // SAFETY: indices in bounds by construction
                // (sparse_pattern over [0, d)).
                let b = unsafe { tier.dot(&idx, &vals, &w) };
                prop::assert_holds(a.to_bits() == b.to_bits(), tier.name())
            });
        }
    }

    #[test]
    fn all_tiers_axpy_bit_identical_to_checked() {
        for tier in available_tiers() {
            prop::check(150, |g| {
                let d = g.usize_in(1, 96);
                let (idx, vals) = random_row(g, d);
                let w0 = g.vec_f64(d, -2.0, 2.0);
                let s = g.f64_in(-2.0, 2.0);
                let mut wa = w0.clone();
                let mut wb = w0;
                axpy_checked(s, &idx, &vals, &mut wa);
                // SAFETY: indices in bounds by construction.
                unsafe { tier.axpy(s, &idx, &vals, &mut wb) };
                for t in 0..d {
                    prop::assert_holds(wa[t].to_bits() == wb[t].to_bits(), tier.name())?;
                }
                Ok(())
            });
        }
    }

    #[test]
    fn all_tiers_fused_step_bit_identical_to_checked() {
        for tier in available_tiers() {
            prop::check(100, |g| {
                let d = g.usize_in(1, 96);
                let (idx, vals) = random_row(g, d);
                let w0 = g.vec_f64(d, -2.0, 2.0);
                let coeff = g.f64_in(-1.0, 1.0);
                let upd = |dot: f64| coeff * dot;
                let mut wa = w0.clone();
                let mut wb = w0;
                let (da, sa) = step_checked(&idx, &vals, &mut wa, upd);
                // SAFETY: indices in bounds by construction.
                let (db, sb) = unsafe { tier.step(&idx, &vals, &mut wb, upd) };
                prop::assert_holds(da.to_bits() == db.to_bits() && sa.to_bits() == sb.to_bits(), tier.name())?;
                for t in 0..d {
                    prop::assert_holds(wa[t].to_bits() == wb[t].to_bits(), tier.name())?;
                }
                Ok(())
            });
        }
    }

    #[test]
    fn all_tiers_tail_classes_and_empty_rows() {
        // nnz values from the issue spec: every lane-width tail class
        // (1, 2, 3, 5 ≡ 1 mod 4, 33 ≡ 1 mod 4 past one full vector) plus
        // the empty row.
        for nnz in [0usize, 1, 2, 3, 5, 33] {
            let idx: Vec<u32> = (0..nnz as u32).map(|k| 3 * k).collect();
            let vals: Vec<f64> = (0..nnz).map(|k| (k as f64 - 2.0) * 0.37).collect();
            let d = 3 * nnz + 1;
            let w: Vec<f64> = (0..d).map(|t| 0.05 * t as f64 - 1.0).collect();
            let dot_ref = dot_dense_checked(&idx, &vals, &w);
            for tier in available_tiers() {
                // SAFETY: indices are 3k < d by construction.
                let dt = unsafe { tier.dot(&idx, &vals, &w) };
                assert_eq!(dot_ref.to_bits(), dt.to_bits(), "dot tier {} nnz {nnz}", tier.name());
                let mut wa = w.clone();
                let mut wb = w.clone();
                axpy_checked(-0.625, &idx, &vals, &mut wa);
                // SAFETY: as above.
                unsafe { tier.axpy(-0.625, &idx, &vals, &mut wb) };
                assert_eq!(wa, wb, "axpy tier {} nnz {nnz}", tier.name());
            }
        }
    }

    #[test]
    fn all_tiers_axpy_exact_with_repeated_indices() {
        // CSR rows never repeat indices, but the scatter contract is
        // stronger: in-order read-modify-write per element, so repeated
        // slots observe every prior update. Pin that down per tier.
        let idx = [0u32, 3, 3, 5, 1, 3, 3, 3, 2];
        let vals = [1.0, 2.0, -0.5, 4.0, 0.25, 8.0, -1.0, 0.125, 3.0];
        let w0: Vec<f64> = (0..7).map(|t| 0.3 * t as f64 - 1.0).collect();
        for tier in available_tiers() {
            let mut wa = w0.clone();
            let mut wb = w0.clone();
            axpy_checked(0.7, &idx, &vals, &mut wa);
            // SAFETY: all indices < 7 = w.len().
            unsafe { tier.axpy(0.7, &idx, &vals, &mut wb) };
            for t in 0..w0.len() {
                assert_eq!(wa[t].to_bits(), wb[t].to_bits(), "tier {} slot {t}", tier.name());
            }
        }
    }

    #[test]
    fn dot_many_bit_identical_to_per_row() {
        prop::check(80, |g| {
            let d = g.usize_in(1, 48);
            let w = g.vec_f64(d, -2.0, 2.0);
            let nrows = g.usize_in(0, 9);
            let rows_owned: Vec<(Vec<u32>, Vec<f64>)> = (0..nrows).map(|_| random_row(g, d)).collect();
            let rows: Vec<(&[u32], &[f64])> = rows_owned.iter().map(|(i, v)| (i.as_slice(), v.as_slice())).collect();
            let mut out = vec![0.0; nrows];
            // SAFETY: every row's indices are in bounds by construction.
            unsafe { dot_many_unchecked(&rows, &w, &mut out) };
            let mut reference = vec![0.0; nrows];
            dot_many_checked(&rows, &w, &mut reference);
            for k in 0..nrows {
                prop::assert_holds(out[k].to_bits() == reference[k].to_bits(), "dot_many bits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn active_tier_is_a_runnable_tier() {
        let name = active_tier_name();
        assert!(["scalar", "sse2", "avx2+fma", "neon"].contains(&name), "unknown tier {name}");
        assert!(available_tiers().iter().any(|t| t.name() == name));
        // and resolution is stable
        assert_eq!(active_tier_name(), active_tier_name());
    }

    #[test]
    fn prefetch_row_is_inert() {
        // covers all slice-length branches, including the deep-line ones
        for n in [0usize, 1, 9, 17, 40] {
            let idx: Vec<u32> = (0..n as u32).collect();
            let vals = vec![1.0f64; n];
            prefetch_row(&idx, &vals);
        }
    }

    #[test]
    fn lane_dot_close_to_scalar_reference() {
        // lanes re-associate the sum: agreement is up to fp rounding, not
        // bit-exact — that is the documented contract
        prop::check(200, |g| {
            let d = g.usize_in(1, 64);
            let (idx, vals) = random_row(g, d);
            let w = g.vec_f64(d, -2.0, 2.0);
            let a = dot_dense_checked(&idx, &vals, &w);
            let b = dot_dense_scalar(&idx, &vals, &w);
            prop::assert_close(a, b, 1e-13, "lanes vs scalar dot")
        });
    }

    #[test]
    fn empty_row_is_identity() {
        let w0 = vec![1.0, 2.0, 3.0];
        let mut w = w0.clone();
        assert_eq!(dot_dense_checked(&[], &[], &w), 0.0);
        // SAFETY: an empty row reads no indices at all.
        assert_eq!(unsafe { dot_dense_unchecked(&[], &[], &w) }, 0.0);
        axpy_checked(2.0, &[], &[], &mut w);
        // SAFETY: an empty row writes no indices at all.
        unsafe { axpy_unchecked(2.0, &[], &[], &mut w) };
        let (dot, scale) = step_checked(&[], &[], &mut w, |d| d + 1.0);
        assert_eq!((dot, scale), (0.0, 1.0));
        assert_eq!(w, w0);
    }

    #[test]
    fn tail_classes_nnz_mod_4() {
        // exercise every tail length explicitly at small fixed sizes
        for nnz in 0..=9usize {
            let idx: Vec<u32> = (0..nnz as u32).map(|k| 2 * k).collect();
            let vals: Vec<f64> = (0..nnz).map(|k| k as f64 + 0.5).collect();
            let d = 2 * nnz + 1;
            let w: Vec<f64> = (0..d).map(|t| 0.1 * t as f64).collect();
            let a = dot_dense_checked(&idx, &vals, &w);
            // SAFETY: indices are 2k < d = 2·nnz+1 by construction.
            let b = unsafe { dot_dense_unchecked(&idx, &vals, &w) };
            assert_eq!(a.to_bits(), b.to_bits(), "nnz = {nnz}");
            let mut wa = w.clone();
            let mut wb = w.clone();
            axpy_checked(0.25, &idx, &vals, &mut wa);
            // SAFETY: same in-bounds indices as the dot above.
            unsafe { axpy_unchecked(0.25, &idx, &vals, &mut wb) };
            assert_eq!(wa, wb, "nnz = {nnz}");
        }
    }

    #[test]
    fn dense_kernels_match_scalar() {
        prop::check(100, |g| {
            let n = g.usize_in(0, 40);
            let a = g.vec_f64(n, -2.0, 2.0);
            let b = g.vec_f64(n, -2.0, 2.0);
            let scalar: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            prop::assert_close(dot(&a, &b), scalar, 1e-13, "dense dot")?;
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            axpy(0.7, &a, &mut y1);
            for (t, yv) in y2.iter_mut().enumerate() {
                *yv += 0.7 * a[t];
            }
            for t in 0..n {
                prop::assert_holds(y1[t].to_bits() == y2[t].to_bits(), "dense axpy bits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn scaled_sum_matches_copy_then_axpy() {
        prop::check(100, |g| {
            let n = g.usize_in(0, 40);
            let a = g.vec_f64(n, -2.0, 2.0);
            let b = g.vec_f64(n, -2.0, 2.0);
            let alpha = g.f64_in(-2.0, 2.0);
            let mut fused = vec![0.0; n];
            scaled_sum_into(&mut fused, &a, alpha, &b);
            let mut split = a.clone();
            axpy(alpha, &b, &mut split);
            for t in 0..n {
                prop::assert_holds(fused[t].to_bits() == split[t].to_bits(), "scaled_sum bits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn step_skips_scatter_on_zero_scale() {
        let idx = [0u32, 2];
        let vals = [1.0, 4.0];
        let mut w = vec![1.0, 1.0, 1.0];
        let (dot, scale) = step_checked(&idx, &vals, &mut w, |_| 0.0);
        assert_eq!(dot, 5.0);
        assert_eq!(scale, 0.0);
        assert_eq!(w, vec![1.0, 1.0, 1.0]);
    }
}
