//! Reader/writer for the libsvm sparse data format used by all the
//! paper's datasets:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices in files are 1-based; we convert to 0-based internally. Labels
//! may be real-valued (regression), ±1 (binary), or small integers
//! (multi-class).

use super::csr::Csr;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

/// A labelled sparse dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// name for reporting
    pub name: String,
    /// ℓ × d design matrix, one row per instance
    pub x: Csr,
    /// labels, length ℓ
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn n_instances(&self) -> usize {
        self.x.rows()
    }

    pub fn n_features(&self) -> usize {
        self.x.cols()
    }

    pub fn nnz(&self) -> usize {
        self.x.nnz()
    }

    /// Distinct labels, sorted (for multi-class problems).
    pub fn classes(&self) -> Vec<i64> {
        let mut c: Vec<i64> = self.y.iter().map(|&v| v as i64).collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    /// Subset by instance indices.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        Dataset {
            name: self.name.clone(),
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }
}

#[derive(Debug)]
pub enum LibsvmError {
    Io(std::io::Error),
    Parse { line: usize, message: String },
}

impl std::fmt::Display for LibsvmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LibsvmError::Io(e) => write!(f, "I/O error: {e}"),
            LibsvmError::Parse { line, message } => write!(f, "line {line}: {message}"),
        }
    }
}

impl std::error::Error for LibsvmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LibsvmError::Io(e) => Some(e),
            LibsvmError::Parse { .. } => None,
        }
    }
}

impl From<std::io::Error> for LibsvmError {
    fn from(e: std::io::Error) -> Self {
        LibsvmError::Io(e)
    }
}

/// Parse libsvm text. `min_features` lets callers force a feature-space
/// dimension (e.g. to align train/test splits).
pub fn parse_libsvm(text: &str, name: &str, min_features: usize) -> Result<Dataset, LibsvmError> {
    parse_reader(text.as_bytes(), name, min_features)
}

/// Read a libsvm file from disk.
pub fn read_libsvm(path: &Path, min_features: usize) -> Result<Dataset, LibsvmError> {
    let f = std::fs::File::open(path)?;
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset").to_string();
    parse_reader(BufReader::new(f), &name, min_features)
}

/// Parse one libsvm line: strip `#` comments and surrounding
/// whitespace, convert 1-based indices to 0-based. `Ok(None)` for
/// blank / comment-only lines. `lineno` is 0-based (error messages are
/// 1-based). Shared by the in-memory parser below and the streaming
/// `.acfbin` ingest ([`crate::sparse::ingest`]), so both accept exactly
/// the same dialect.
pub(crate) fn parse_line(
    raw: &str,
    lineno: usize,
) -> Result<Option<(f64, Vec<(usize, f64)>)>, LibsvmError> {
    let line = raw.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let mut toks = line.split_ascii_whitespace();
    let label_tok = toks.next().ok_or_else(|| LibsvmError::Parse {
        line: lineno + 1,
        message: "missing label".into(),
    })?;
    let label: f64 = label_tok.parse().map_err(|_| LibsvmError::Parse {
        line: lineno + 1,
        message: format!("bad label '{label_tok}'"),
    })?;
    let mut row = Vec::new();
    for tok in toks {
        let (idx, val) = tok.split_once(':').ok_or_else(|| LibsvmError::Parse {
            line: lineno + 1,
            message: format!("bad feature token '{tok}'"),
        })?;
        let idx: usize = idx.parse().map_err(|_| LibsvmError::Parse {
            line: lineno + 1,
            message: format!("bad feature index '{idx}'"),
        })?;
        if idx == 0 {
            return Err(LibsvmError::Parse {
                line: lineno + 1,
                message: "libsvm feature indices are 1-based".into(),
            });
        }
        let val: f64 = val.parse().map_err(|_| LibsvmError::Parse {
            line: lineno + 1,
            message: format!("bad feature value '{val}'"),
        })?;
        row.push((idx - 1, val));
    }
    Ok(Some((label, row)))
}

fn parse_reader<R: Read>(r: R, name: &str, min_features: usize) -> Result<Dataset, LibsvmError> {
    let reader = BufReader::new(r);
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let Some((label, row)) = parse_line(&line, lineno)? else { continue };
        for &(c, _) in &row {
            max_col = max_col.max(c + 1);
        }
        rows.push(row);
        y.push(label);
    }
    let cols = max_col.max(min_features);
    Ok(Dataset { name: name.to_string(), x: Csr::from_rows(cols, rows), y })
}

/// Serialize a dataset to libsvm text.
pub fn write_libsvm<W: Write>(ds: &Dataset, mut out: W) -> std::io::Result<()> {
    for i in 0..ds.n_instances() {
        let label = ds.y[i];
        if label == label.trunc() {
            write!(out, "{}", label as i64)?;
        } else {
            write!(out, "{}", label)?;
        }
        let row = ds.x.row(i);
        for (&j, &v) in row.indices().iter().zip(row.values().iter()) {
            write!(out, " {}:{}", j + 1, v)?;
        }
        writeln!(out)?;
    }
    Ok(())
}

pub fn to_libsvm_string(ds: &Dataset) -> String {
    let mut buf = Vec::new();
    // INFALLIBLE: `Write` on a `Vec<u8>` cannot fail.
    write_libsvm(ds, &mut buf).expect("in-memory write");
    String::from_utf8(buf).expect("utf8") // INFALLIBLE: the writer emits ASCII only
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.25
-1 2:2 4:-0.5
+1 1:1
";

    #[test]
    fn parses_basic() {
        let ds = parse_libsvm(SAMPLE, "t", 0).unwrap();
        assert_eq!(ds.n_instances(), 3);
        assert_eq!(ds.n_features(), 4);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.row(0).indices(), &[0, 2]);
        assert_eq!(ds.x.row(1).values(), &[2.0, -0.5]);
    }

    #[test]
    fn handles_comments_blank_lines() {
        let text = "# header\n\n+1 1:1 # trailing\n";
        let ds = parse_libsvm(text, "t", 0).unwrap();
        assert_eq!(ds.n_instances(), 1);
    }

    #[test]
    fn min_features_pads() {
        let ds = parse_libsvm("+1 1:1\n", "t", 10).unwrap();
        assert_eq!(ds.n_features(), 10);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_libsvm("notalabel 1:1\n", "t", 0).is_err());
        assert!(parse_libsvm("+1 0:1\n", "t", 0).is_err()); // 0-based index
        assert!(parse_libsvm("+1 1:abc\n", "t", 0).is_err());
        assert!(parse_libsvm("+1 11\n", "t", 0).is_err());
    }

    #[test]
    fn multiclass_classes() {
        let ds = parse_libsvm("0 1:1\n2 1:1\n1 1:1\n2 2:1\n", "t", 0).unwrap();
        assert_eq!(ds.classes(), vec![0, 1, 2]);
    }

    #[test]
    fn roundtrip_property() {
        prop::check(30, |g| {
            let n = g.usize_in(1, 20);
            let d = g.usize_in(1, 30);
            let mut rows = Vec::new();
            let mut y = Vec::new();
            for _ in 0..n {
                let k = g.usize_in(0, d.min(6));
                let pat = g.sparse_pattern(d, k);
                // values with exact decimal representation survive the
                // text round-trip bit-exactly
                rows.push(
                    pat.into_iter()
                        .map(|c| (c, (g.usize_in(1, 100) as f64) / 8.0))
                        .collect::<Vec<_>>(),
                );
                y.push(if g.bool() { 1.0 } else { -1.0 });
            }
            let ds = Dataset {
                name: "prop".into(),
                x: super::super::csr::Csr::from_rows(d, rows),
                y,
            };
            let text = to_libsvm_string(&ds);
            let back = parse_libsvm(&text, "prop", d).unwrap();
            prop::assert_holds(back.y == ds.y, "labels")?;
            prop::assert_holds(back.x == ds.x, "matrix")
        });
    }

    #[test]
    fn select_subsets_dataset() {
        let ds = parse_libsvm(SAMPLE, "t", 0).unwrap();
        let s = ds.select(&[2, 0]);
        assert_eq!(s.y, vec![1.0, 1.0]);
        assert_eq!(s.x.row(0).indices(), &[0]);
    }

    #[test]
    fn file_round_trip() {
        let ds = parse_libsvm(SAMPLE, "t", 0).unwrap();
        let dir = std::env::temp_dir().join("acf_cd_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sample.libsvm");
        std::fs::write(&path, to_libsvm_string(&ds)).unwrap();
        let back = read_libsvm(&path, 4).unwrap();
        let mut rng = Rng::new(0);
        let _ = rng.next_u64(); // silence unused warnings in some cfgs
        assert_eq!(back.x, ds.x);
        std::fs::remove_file(&path).ok();
    }
}
