//! Sparse linear-algebra substrate: CSR matrices, the libsvm data
//! format, dense-vector helpers, and the hot-path [`kernels`] layer
//! (4-way unrolled unchecked gather/scatter + the fused CD `step`; see
//! that module's safety contract) the CD solvers run on.

pub mod csr;
pub mod kernels;
pub mod libsvm;
pub mod ops;

pub use csr::{Csr, RowView};
pub use libsvm::{parse_libsvm, read_libsvm, to_libsvm_string, Dataset};
