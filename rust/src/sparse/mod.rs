//! Sparse linear-algebra substrate: CSR matrices, the libsvm data format,
//! and dense-vector helpers used by the CD solvers.

pub mod csr;
pub mod libsvm;
pub mod ops;

pub use csr::{Csr, RowView};
pub use libsvm::{parse_libsvm, read_libsvm, to_libsvm_string, Dataset};
