//! Sparse linear-algebra substrate and the **data plane** under it.
//!
//! The solvers see one matrix type — [`Csr`] handing out per-row
//! [`RowView`]s — but the bytes behind it come from one of three
//! interchangeable backends ([`csr::CsrStorage`]):
//!
//! * **Owned** — three heap vectors; what [`parse_libsvm`] and the
//!   synthetic generators build.
//! * **Mapped** — a read-only file mapping of an `.acfbin` file
//!   ([`storage`]); rows are zero-copy views into the mapped pages, so
//!   training sets can exceed RAM (`--data-backend mmap`).
//! * **Chunked** — bounded row blocks filled by the streaming ingest
//!   ([`ingest`]), avoiding matrix-sized allocations while a file is
//!   being converted.
//!
//! All backends serve bit-identical rows for the same logical matrix;
//! the property tests in [`storage`] and [`ingest`] pin that down. The
//! hot paths ([`kernels`]: unchecked gather/scatter + the fused CD
//! `step`, dispatched at runtime across SIMD tiers — AVX2+FMA / SSE2 /
//! NEON / 4-way scalar unroll, all bit-identical; see that module's
//! safety and bit-identity contracts) only ever see `&[u32]`/`&[f64]`
//! slices, so they are backend-oblivious.
//!
//! Also here: the libsvm reader/writer ([`libsvm`]) and dense-vector
//! helpers ([`ops`]).

pub mod csr;
pub mod ingest;
pub mod kernels;
pub mod libsvm;
pub mod ops;
pub mod storage;

pub use csr::{Csr, CsrStorage, RowView};
pub use libsvm::{parse_libsvm, read_libsvm, to_libsvm_string, Dataset};
