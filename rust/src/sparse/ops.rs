//! Dense-vector helpers shared by the solvers. Kept tiny and `#[inline]`
//! — these appear in the CD inner loop.
//!
//! Unlike the sparse gather/scatter entry points, the dense kernels here
//! are *not* runtime-dispatched: they are safe `chunks_exact` loops the
//! autovectorizer already turns into packed code (no gathers involved),
//! so a SIMD tier would buy nothing while adding an indirect call. See
//! [`crate::sparse::kernels`] for the dispatch story on the sparse side.

/// Clip `x` to `[lo, hi]` — the paper's `[x]_a^b` truncation.
#[inline(always)]
pub fn clip(x: f64, lo: f64, hi: f64) -> f64 {
    // branch-light form; NaN-free inputs assumed in the hot loop
    x.max(lo).min(hi)
}

/// Dense dot product (4-lane unrolled, [`crate::sparse::kernels`]).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    super::kernels::dot(a, b)
}

/// y += alpha * x (4-way unrolled, [`crate::sparse::kernels`]).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    super::kernels::axpy(alpha, x, y)
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Infinity norm, NaN-propagating: any NaN element yields NaN (the
/// previous `f64::max` fold silently *discarded* NaNs, so a caller
/// auditing a residual could see a finite norm for poisoned data).
/// Empty slices give 0. Substrate utility — no solver hot path calls
/// it today; it exists for residual/diagnostic audits.
#[inline]
pub fn norm_inf(a: &[f64]) -> f64 {
    let mut m = 0.0f64;
    for &x in a {
        let ax = x.abs();
        // `ax > m` is false for NaN on either side, so once a NaN is
        // captured it sticks; the explicit is_nan check captures it.
        if ax > m || ax.is_nan() {
            m = ax;
        }
    }
    m
}

/// Soft-threshold operator `S(x, t) = sign(x)·max(|x|−t, 0)` — the LASSO
/// proximal step.
#[inline(always)]
pub fn soft_threshold(x: f64, t: f64) -> f64 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_works() {
        assert_eq!(clip(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clip(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clip(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn dot_axpy_norms() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert_eq!(norm_sq(&a), 14.0);
        assert_eq!(norm_inf(&[-5.0, 3.0]), 5.0);
    }

    #[test]
    fn norm_inf_empty_is_zero() {
        assert_eq!(norm_inf(&[]), 0.0);
    }

    #[test]
    fn norm_inf_negative_only() {
        assert_eq!(norm_inf(&[-2.0, -7.5, -0.25]), 7.5);
    }

    #[test]
    fn norm_inf_propagates_nan() {
        // documented behavior: any NaN poisons the result, wherever it
        // sits relative to the running maximum
        assert!(norm_inf(&[1.0, f64::NAN, 3.0]).is_nan());
        assert!(norm_inf(&[f64::NAN]).is_nan());
        assert!(norm_inf(&[9.0, f64::NAN]).is_nan());
        assert!(norm_inf(&[f64::NAN, 9.0]).is_nan());
        // infinities are not NaN and behave as ordinary magnitudes
        assert_eq!(norm_inf(&[f64::NEG_INFINITY, 1.0]), f64::INFINITY);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }
}
