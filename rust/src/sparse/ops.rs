//! Dense-vector helpers shared by the solvers. Kept tiny and `#[inline]`
//! — these appear in the CD inner loop.

/// Clip `x` to `[lo, hi]` — the paper's `[x]_a^b` truncation.
#[inline(always)]
pub fn clip(x: f64, lo: f64, hi: f64) -> f64 {
    // branch-light form; NaN-free inputs assumed in the hot loop
    x.max(lo).min(hi)
}

/// Dense dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Infinity norm.
#[inline]
pub fn norm_inf(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, &x| m.max(x.abs()))
}

/// Soft-threshold operator `S(x, t) = sign(x)·max(|x|−t, 0)` — the LASSO
/// proximal step.
#[inline(always)]
pub fn soft_threshold(x: f64, t: f64) -> f64 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_works() {
        assert_eq!(clip(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clip(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clip(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn dot_axpy_norms() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert_eq!(norm_sq(&a), 14.0);
        assert_eq!(norm_inf(&[-5.0, 3.0]), 5.0);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }
}
