//! The `.acfbin` on-disk dataset format and its mapped reader — the
//! persistence half of the out-of-core data plane.
//!
//! # Format (version 1)
//!
//! A column-stable binary layout: one header, then five contiguous
//! sections, each at an 8-byte-aligned offset recorded in the header so
//! readers never infer positions. All integers and floats are
//! **native-endian**; the endianness tag makes a foreign-endian file
//! fail loudly instead of decoding garbage.
//!
//! ```text
//! offset  size          field
//! ------  ------------  -----------------------------------------
//!      0  8             magic "ACFBIN01"
//!      8  8 (u64)       endianness tag 0x0102030405060708
//!     16  8 (u64)       format version (1)
//!     24  8 (u64)       rows
//!     32  8 (u64)       cols
//!     40  8 (u64)       nnz
//!     48  8 (u64)       flags (reserved, 0)
//!     56  8 (u64)       byte offset of the row-pointer section
//!     64  8 (u64)       byte offset of the labels section
//!     72  8 (u64)       byte offset of the norms section
//!     80  8 (u64)       byte offset of the values section
//!     88  8 (u64)       byte offset of the indices section
//!     96  8 (u64)       total file length in bytes
//!    104  (rows+1)*8    row pointers (u64, indptr[0] = 0)
//!         rows*8        labels (f64)
//!         rows*8        per-row squared norms (f64, written at ingest)
//!         nnz*8         values (f64)
//!         nnz*4         column indices (u32, strictly increasing per row)
//! ```
//!
//! The u32 indices section goes **last** so every other section sits at
//! a naturally 8-aligned offset with zero padding. Squared norms are
//! computed once at write time with the same kernel the solvers use
//! ([`crate::sparse::kernels::dot`]), so a mapped matrix serves
//! bit-identical `row_norms_sq()` without ever touching the value pages.
//!
//! # Reading
//!
//! [`open_dataset`] maps the file ([`crate::util::mmap::Mmap`]) and
//! builds a [`Csr`] whose rows are zero-copy views into the mapped
//! value/index sections ([`CsrStorage::Mapped`]). The header and the
//! full CSR structural invariants are validated up front — the file is
//! untrusted input, and the unchecked row kernels are only sound over
//! validated rows; every validation error names the byte offset at
//! fault.
//!
//! # Writing
//!
//! [`AcfbinWriter`] streams rows in bounded memory (O(rows) row-pointer
//! /label/norm state, O(1) value/index state via spill segments) and
//! assembles the final file with an atomic rename, so a crashed ingest
//! never leaves a half-written `.acfbin` behind; [`write_dataset`] is
//! the one-call version for in-memory datasets.
//!
//! ```
//! use acf_cd::sparse::{parse_libsvm, storage};
//! let ds = parse_libsvm("+1 1:0.5 3:1.25\n-1 2:2\n", "doc", 0).unwrap();
//! let dir = std::env::temp_dir().join("acf_storage_doc");
//! std::fs::create_dir_all(&dir).unwrap();
//! let path = dir.join(format!("doc_{}.acfbin", std::process::id()));
//! storage::write_dataset(&ds, &path).unwrap();
//! let mapped = storage::open_dataset(&path).unwrap();
//! assert_eq!(mapped.x.storage_kind(), "mapped");
//! assert_eq!(mapped.x, ds.x); // bit-identical rows, zero copies
//! assert_eq!(mapped.y, ds.y);
//! std::fs::remove_file(&path).ok();
//! ```

use super::csr::{Csr, CsrStorage, MappedCsr};
use super::kernels;
use super::libsvm::Dataset;
use crate::util::error::{Context, Result};
use crate::util::mmap::Mmap;
use crate::{anyhow, bail};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// First 8 bytes of every `.acfbin` file.
pub const MAGIC: [u8; 8] = *b"ACFBIN01";
/// Byte-order canary: reads back differently under the wrong endianness.
pub const ENDIAN_TAG: u64 = 0x0102_0304_0506_0708;
/// Current format version.
pub const VERSION: u64 = 1;
/// Fixed header length; the first section starts here.
pub const HEADER_LEN: usize = 104;

/// Summary of a written `.acfbin` file.
#[derive(Clone, Copy, Debug)]
pub struct AcfbinSummary {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// total bytes of the final file
    pub bytes: u64,
}

/// Streaming `.acfbin` writer with bounded memory: per-row state is
/// O(1) (values and indices spill to temporary segment files as they
/// arrive), plus O(rows) for the row-pointer, label, and norm columns
/// that land in the header-adjacent sections. [`AcfbinWriter::finish`]
/// assembles header + sections into `<path>.tmp` and renames it over
/// the destination, so readers never observe a partial file.
pub struct AcfbinWriter {
    final_path: PathBuf,
    values_path: PathBuf,
    indices_path: PathBuf,
    values_w: BufWriter<File>,
    indices_w: BufWriter<File>,
    indptr: Vec<u64>,
    labels: Vec<f64>,
    norms: Vec<f64>,
    nnz: u64,
    /// 1 + highest column index seen
    min_cols: usize,
}

impl AcfbinWriter {
    /// Start writing toward `path` (parent directory must exist). Two
    /// spill segments (`<path>.values.tmp`, `<path>.indices.tmp`) are
    /// created next to it and removed by [`AcfbinWriter::finish`].
    pub fn create(path: &Path) -> Result<AcfbinWriter> {
        let suffixed = |suffix: &str| -> PathBuf {
            let mut os = path.as_os_str().to_os_string();
            os.push(suffix);
            PathBuf::from(os)
        };
        let values_path = suffixed(".values.tmp");
        let indices_path = suffixed(".indices.tmp");
        let open = |p: &Path| -> Result<BufWriter<File>> {
            Ok(BufWriter::new(File::create(p).with_context(|| format!("creating spill segment {}", p.display()))?))
        };
        Ok(AcfbinWriter {
            final_path: path.to_path_buf(),
            values_w: open(&values_path)?,
            indices_w: open(&indices_path)?,
            values_path,
            indices_path,
            indptr: vec![0],
            labels: Vec::new(),
            norms: Vec::new(),
            nnz: 0,
            min_cols: 0,
        })
    }

    /// Append one row. `indices` must be strictly increasing (the same
    /// invariant every [`Csr`] backend enforces); the row's squared norm
    /// is computed here, with the solver dot kernel, and stored in the
    /// norms section.
    pub fn push_row(&mut self, label: f64, indices: &[u32], values: &[f64]) -> Result<()> {
        if indices.len() != values.len() {
            bail!("row {}: {} indices vs {} values", self.labels.len(), indices.len(), values.len());
        }
        if !indices.windows(2).all(|p| p[0] < p[1]) {
            bail!("row {}: indices must be strictly increasing", self.labels.len());
        }
        for &v in values {
            self.values_w.write_all(&v.to_ne_bytes())?;
        }
        for &j in indices {
            self.indices_w.write_all(&j.to_ne_bytes())?;
        }
        if let Some(&last) = indices.last() {
            self.min_cols = self.min_cols.max(last as usize + 1);
        }
        self.nnz += indices.len() as u64;
        self.indptr.push(self.nnz);
        self.labels.push(label);
        self.norms.push(kernels::dot(values, values));
        Ok(())
    }

    /// Rows written so far.
    pub fn rows(&self) -> usize {
        self.labels.len()
    }

    /// Assemble the final file and atomically rename it into place.
    /// `min_features` forces a feature-space dimension at least that
    /// large (the libsvm `min_features` convention).
    pub fn finish(mut self, min_features: usize) -> Result<AcfbinSummary> {
        self.values_w.flush()?;
        self.indices_w.flush()?;
        let rows = self.labels.len();
        let cols = self.min_cols.max(min_features);
        let nnz = self.nnz as usize;

        let off_indptr = HEADER_LEN as u64;
        let off_labels = off_indptr + (rows as u64 + 1) * 8;
        let off_norms = off_labels + rows as u64 * 8;
        let off_values = off_norms + rows as u64 * 8;
        let off_indices = off_values + nnz as u64 * 8;
        let file_len = off_indices + nnz as u64 * 4;

        let tmp_path = {
            let mut os = self.final_path.as_os_str().to_os_string();
            os.push(".tmp");
            PathBuf::from(os)
        };
        let mut out = BufWriter::new(
            File::create(&tmp_path).with_context(|| format!("creating {}", tmp_path.display()))?,
        );
        out.write_all(&MAGIC)?;
        for word in [
            ENDIAN_TAG,
            VERSION,
            rows as u64,
            cols as u64,
            nnz as u64,
            0, // flags
            off_indptr,
            off_labels,
            off_norms,
            off_values,
            off_indices,
            file_len,
        ] {
            out.write_all(&word.to_ne_bytes())?;
        }
        for &p in &self.indptr {
            out.write_all(&p.to_ne_bytes())?;
        }
        for &l in &self.labels {
            out.write_all(&l.to_ne_bytes())?;
        }
        for &n in &self.norms {
            out.write_all(&n.to_ne_bytes())?;
        }
        for spill in [&self.values_path, &self.indices_path] {
            let mut f = File::open(spill).with_context(|| format!("reopening spill segment {}", spill.display()))?;
            std::io::copy(&mut f, &mut out)?;
        }
        out.flush()?;
        drop(out);
        std::fs::rename(&tmp_path, &self.final_path)
            .with_context(|| format!("renaming into {}", self.final_path.display()))?;
        std::fs::remove_file(&self.values_path).ok();
        std::fs::remove_file(&self.indices_path).ok();
        Ok(AcfbinSummary { rows, cols, nnz, bytes: file_len })
    }
}

impl Drop for AcfbinWriter {
    fn drop(&mut self) {
        // abandoned writer (error path): don't leave spill segments
        std::fs::remove_file(&self.values_path).ok();
        std::fs::remove_file(&self.indices_path).ok();
    }
}

/// Write an in-memory dataset as `.acfbin` (the registry spill path and
/// the tests' round-trip oracle).
pub fn write_dataset(ds: &Dataset, path: &Path) -> Result<AcfbinSummary> {
    let mut w = AcfbinWriter::create(path)?;
    for r in 0..ds.n_instances() {
        let row = ds.x.row(r);
        w.push_row(ds.y[r], row.indices(), row.values())?;
    }
    w.finish(ds.n_features())
        .with_context(|| format!("writing {} as .acfbin to {}", ds.name, path.display()))
}

fn read_u64(bytes: &[u8], off: usize) -> u64 {
    // INFALLIBLE: the slice is exactly 8 bytes by construction.
    u64::from_ne_bytes(bytes[off..off + 8].try_into().expect("8-byte slice"))
}

fn read_f64_section(bytes: &[u8], off: usize, count: usize, what: &str, total: usize) -> Result<Vec<f64>> {
    let end = count.checked_mul(8).and_then(|b| off.checked_add(b)).filter(|&e| e <= total);
    let end = end.ok_or_else(|| anyhow!("{what} section at byte offset {off} overruns the {total}-byte file"))?;
    let words = bytes[off..end].chunks_exact(8);
    // INFALLIBLE: `chunks_exact(8)` yields exactly-8-byte slices only.
    Ok(words.map(|c| f64::from_ne_bytes(c.try_into().expect("8-byte chunk"))).collect())
}

/// Open an `.acfbin` file as a memory-mapped [`Dataset`]: zero-copy
/// [`CsrStorage::Mapped`] rows, labels and norms copied out of their
/// (small, O(rows)) sections, the norm cache pre-seeded so
/// `row_norms_sq()` never touches the value pages. The dataset name is
/// the file stem.
///
/// Every header or structure violation is rejected with an error naming
/// the byte offset at fault — mapped rows feed the unchecked kernels,
/// so an invalid file must be impossible to open.
pub fn open_dataset(path: &Path) -> Result<Dataset> {
    let map = Arc::new(Mmap::open(path)?);
    let total = map.len();
    let err = |msg: String| anyhow!("{}: invalid .acfbin: {msg}", path.display());
    if total < HEADER_LEN {
        return Err(err(format!(
            "truncated: {total} bytes, the {HEADER_LEN}-byte header starting at offset 0 is incomplete"
        )));
    }
    let bytes = map.as_bytes();
    if bytes[..8] != MAGIC {
        return Err(err(format!("bad magic {:02x?} at offset 0 (expected {MAGIC:02x?})", &bytes[..8])));
    }
    if read_u64(bytes, 8) != ENDIAN_TAG {
        return Err(err(format!(
            "endianness tag {:#018x} at offset 8 does not match this machine (expected {ENDIAN_TAG:#018x}); \
             the file was written on a foreign-endian host",
            read_u64(bytes, 8)
        )));
    }
    let version = read_u64(bytes, 16);
    if version != VERSION {
        return Err(err(format!("unsupported format version {version} at offset 16 (supported: {VERSION})")));
    }
    let as_size = |off: usize, what: &str| -> Result<usize> {
        let v = read_u64(bytes, off);
        usize::try_from(v).map_err(|_| err(format!("{what} {v} at offset {off} does not fit this target's usize")))
    };
    let rows = as_size(24, "row count")?;
    let cols = as_size(32, "column count")?;
    let nnz = as_size(40, "nnz")?;
    let declared_len = read_u64(bytes, 96);
    if declared_len != total as u64 {
        return Err(err(format!(
            "file is {total} bytes but the header at offset 96 declares {declared_len} (truncated or trailing garbage)"
        )));
    }
    let off_indptr = as_size(56, "row-pointer offset")?;
    let off_labels = as_size(64, "labels offset")?;
    let off_norms = as_size(72, "norms offset")?;
    let off_values = as_size(80, "values offset")?;
    let off_indices = as_size(88, "indices offset")?;
    let labels = read_f64_section(bytes, off_labels, rows, "labels", total).map_err(|e| err(format!("{e}")))?;
    let norms = read_f64_section(bytes, off_norms, rows, "norms", total).map_err(|e| err(format!("{e}")))?;
    let mapped = MappedCsr::new(Arc::clone(&map), rows, cols, nnz, off_indptr, off_values, off_indices)
        .map_err(err)?;
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset").to_string();
    Ok(Dataset {
        name,
        x: Csr::from_storage(rows, cols, CsrStorage::Mapped(mapped), Some(norms)),
        y: labels,
    })
}

/// Spill an in-memory dataset to a transient `.acfbin` and reopen it
/// memory-mapped. The on-disk file is unlinked immediately after
/// mapping (the mapping stays valid until dropped), so the caller gets
/// mapped-backend semantics with no cleanup obligations — this is how
/// `--data-backend mmap` serves registry-synthesized datasets, and how
/// the benches put the mapped backend under the existing speedup gates.
pub fn remap_dataset(ds: &Dataset) -> Result<Dataset> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join("acf_cd_remap");
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
    let path = dir.join(format!(
        "remap_{}_{}.acfbin",
        std::process::id(),
        // ORDERING: Relaxed: unique-filename counter; only uniqueness of
        // the fetched value matters, no data is published through it.
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    write_dataset(ds, &path)?;
    let mut mapped = open_dataset(&path)?;
    std::fs::remove_file(&path).ok(); // mapping outlives the directory entry
    mapped.name = ds.name.clone();
    Ok(mapped)
}

/// Full `{:#}` chain of an `open_dataset` failure — the corruption
/// tests assert these messages name the byte offset at fault.
#[cfg(test)]
fn open_err(path: &Path) -> String {
    format!("{:#}", open_dataset(path).expect_err("open should fail"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::libsvm::parse_libsvm;
    use crate::util::prop;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("acf_cd_storage_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{}", std::process::id(), name))
    }

    fn sample_ds() -> Dataset {
        parse_libsvm("+1 1:0.5 3:1.25\n-1 2:2 4:-0.5\n+1 1:1\n-1 5:3.5\n", "sample", 0).unwrap()
    }

    #[test]
    fn round_trip_is_bit_identical() {
        let ds = sample_ds();
        let path = tmp("round_trip.acfbin");
        let summary = write_dataset(&ds, &path).unwrap();
        assert_eq!(summary.rows, ds.n_instances());
        assert_eq!(summary.cols, ds.n_features());
        assert_eq!(summary.nnz, ds.nnz());
        let back = open_dataset(&path).unwrap();
        assert_eq!(back.x.storage_kind(), "mapped");
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
        // the pre-seeded norm cache is bit-identical to recomputation
        let owned_norms = ds.x.row_norms_sq();
        for (a, b) in back.x.row_norms_sq().iter().zip(owned_norms) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(summary.bytes, std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn round_trip_property_with_empty_rows_and_odd_tails() {
        prop::check(25, |g| {
            let n = g.usize_in(1, 30);
            let d = g.usize_in(1, 40);
            let mut rows = Vec::new();
            let mut y = Vec::new();
            for _ in 0..n {
                // explicitly include empty rows and nnz % 4 != 0 tails
                let k = g.usize_in(0, d.min(7));
                let pat = g.sparse_pattern(d, k);
                rows.push(pat.into_iter().map(|c| (c, g.f64_in(-4.0, 4.0))).collect::<Vec<_>>());
                y.push(g.f64_in(-2.0, 2.0));
            }
            let ds = Dataset { name: "prop".into(), x: Csr::from_rows(d, rows), y };
            let path = tmp(&format!("prop_{}.acfbin", g.usize_in(0, usize::MAX / 2)));
            write_dataset(&ds, &path).map_err(|e| format!("{e:#}"))?;
            let back = open_dataset(&path).map_err(|e| format!("{e:#}"))?;
            std::fs::remove_file(&path).ok();
            prop::assert_holds(back.x == ds.x, "matrix bit-identical")?;
            prop::assert_holds(
                back.y.iter().zip(&ds.y).all(|(a, b)| a.to_bits() == b.to_bits()),
                "labels bit-identical",
            )?;
            back.x.check_invariants()
        });
    }

    #[test]
    fn empty_dataset_round_trips() {
        let ds = Dataset { name: "empty".into(), x: Csr::from_rows(3, vec![]), y: vec![] };
        let path = tmp("empty.acfbin");
        write_dataset(&ds, &path).unwrap();
        let back = open_dataset(&path).unwrap();
        assert_eq!(back.n_instances(), 0);
        assert_eq!(back.n_features(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mapped_rows_share_no_heap_with_the_file_copy() {
        // zero-copy check: two opens of the same file produce equal rows
        let ds = sample_ds();
        let path = tmp("zero_copy.acfbin");
        write_dataset(&ds, &path).unwrap();
        let a = open_dataset(&path).unwrap();
        let b = open_dataset(&path).unwrap();
        assert_eq!(a.x, b.x);
        for r in 0..a.n_instances() {
            assert_eq!(a.x.row(r).values(), b.x.row(r).values());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic_naming_offset_zero() {
        let path = tmp("bad_magic.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("offset 0") && msg.contains("magic"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_unsupported_version_naming_offset() {
        let path = tmp("bad_version.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[16..24].copy_from_slice(&99u64.to_ne_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("offset 16") && msg.contains("version 99"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_foreign_endian_naming_offset() {
        let path = tmp("bad_endian.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..16].copy_from_slice(&ENDIAN_TAG.swap_bytes().to_ne_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("offset 8") && msg.contains("endian"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated_file_naming_length() {
        let path = tmp("truncated.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // cut mid-values-section
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("offset 96") && msg.contains("truncated"), "{msg}");
        // and a cut inside the header itself
        std::fs::write(&path, &bytes[..40]).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("truncated") && msg.contains("offset 0"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_indices_naming_byte_offset() {
        let path = tmp("bad_indices.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let off_indices = read_u64(&bytes, 88) as usize;
        // row 0 is [0, 2]: make it non-increasing by raising entry 0
        bytes[off_indices..off_indices + 4].copy_from_slice(&7u32.to_ne_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("byte offset") && msg.contains("row 0"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_out_of_bounds_column_naming_byte_offset() {
        let path = tmp("bad_col.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let off_indices = read_u64(&bytes, 88) as usize;
        bytes[off_indices..off_indices + 4].copy_from_slice(&u32::MAX.to_ne_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("out of bounds") && msg.contains("byte offset"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_indptr_naming_byte_offset() {
        let path = tmp("bad_indptr.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let off_indptr = read_u64(&bytes, 56) as usize;
        // indptr[1] beyond nnz
        bytes[off_indptr + 8..off_indptr + 16].copy_from_slice(&10_000u64.to_ne_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let msg = open_err(&path);
        assert!(msg.contains("byte offset") && msg.contains("exceeds nnz"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn finish_cleans_spill_segments_and_writes_atomically() {
        let path = tmp("atomic.acfbin");
        write_dataset(&sample_ds(), &path).unwrap();
        let dir = path.parent().unwrap();
        let strays: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(path.file_name().unwrap().to_str().unwrap()) && n.ends_with(".tmp"))
            .collect();
        assert!(strays.is_empty(), "leftover temp files: {strays:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn remap_preserves_name_and_content_without_files() {
        let ds = sample_ds();
        let mapped = remap_dataset(&ds).unwrap();
        assert_eq!(mapped.name, ds.name);
        assert_eq!(mapped.x.storage_kind(), "mapped");
        assert_eq!(mapped.x, ds.x);
        assert_eq!(mapped.y, ds.y);
        // norms served from the header section, bit-identical
        for (a, b) in mapped.x.row_norms_sq().iter().zip(ds.x.row_norms_sq()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
