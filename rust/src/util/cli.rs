//! Tiny command-line argument parser (no `clap` in the offline build).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style used by the `acf-cd` launcher, with typed accessors and
//! good error messages.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// First positional token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    BadValue(String, String, &'static str),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(flag) => write!(f, "missing required flag --{flag}"),
            CliError::BadValue(flag, value, ty) => {
                write!(f, "flag --{flag}: cannot parse '{value}' as {ty}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut command = None;
        let mut positional = Vec::new();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (key, val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => {
                        // Look ahead: next token is the value unless it is
                        // another flag.
                        let next_is_value =
                            iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                        if next_is_value {
                            (body.to_string(), iter.next())
                        } else {
                            (body.to_string(), None)
                        }
                    }
                };
                flags.entry(key).or_default().push(val.unwrap_or_else(|| "true".to_string()));
            } else if command.is_none() {
                command = Some(tok);
            } else {
                positional.push(tok);
            }
        }
        Args { command, positional, flags }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences of a repeatable flag.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn require(&self, key: &str) -> Result<&str, CliError> {
        self.get(key).ok_or_else(|| CliError::Missing(key.to_string()))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| CliError::BadValue(key.into(), v.into(), "float"))
            }
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| CliError::BadValue(key.into(), v.into(), "integer"))
            }
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| CliError::BadValue(key.into(), v.into(), "integer"))
            }
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(CliError::BadValue(key.into(), v.into(), "bool")),
        }
    }

    /// Comma-separated list of floats, e.g. `--grid 0.01,0.1,1`.
    pub fn f64_list(&self, key: &str) -> Result<Option<Vec<f64>>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<f64>()
                        .map_err(|_| CliError::BadValue(key.into(), t.into(), "float list"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Comma-separated list of strings.
    pub fn str_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| v.split(',').map(|t| t.trim().to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = args(&["train", "--dataset", "rcv1-like", "--c", "1.5", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("dataset"), Some("rcv1-like"));
        assert_eq!(a.f64_or("c", 0.0).unwrap(), 1.5);
        assert!(a.has("verbose"));
        assert!(a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn equals_syntax() {
        let a = args(&["bench", "--eps=0.01", "--n=100"]);
        assert_eq!(a.f64_or("eps", 0.0).unwrap(), 0.01);
        assert_eq!(a.usize_or("n", 0).unwrap(), 100);
    }

    #[test]
    fn lists() {
        let a = args(&["x", "--grid", "0.01,0.1,1", "--names", "a, b"]);
        assert_eq!(a.f64_list("grid").unwrap().unwrap(), vec![0.01, 0.1, 1.0]);
        assert_eq!(a.str_list("names").unwrap(), vec!["a", "b"]);
    }

    #[test]
    fn missing_and_bad() {
        let a = args(&["x", "--k", "abc"]);
        assert!(a.require("absent").is_err());
        assert!(a.usize_or("k", 1).is_err());
        assert_eq!(a.usize_or("absent", 7).unwrap(), 7);
    }

    #[test]
    fn repeated_flags_last_wins_and_all_available() {
        let a = args(&["x", "--p", "1", "--p", "2"]);
        assert_eq!(a.get("p"), Some("2"));
        assert_eq!(a.get_all("p"), vec!["1", "2"]);
    }

    #[test]
    fn positional_args() {
        let a = args(&["run", "file1", "file2", "--flag"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn negative_number_value() {
        // A value starting with '-' but not '--' is accepted as a value.
        let a = args(&["x", "--shift", "-3.5"]);
        assert_eq!(a.f64_or("shift", 0.0).unwrap(), -3.5);
    }
}
