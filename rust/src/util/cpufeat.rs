//! CPU capability detection and the kernel-dispatch override.
//!
//! The sparse hot-path kernels ([`crate::sparse::kernels`]) ship several
//! implementation tiers (scalar unroll, SSE2, AVX2+FMA, NEON) and pick
//! one at runtime. This module owns the two process-global inputs to
//! that decision, each resolved exactly once and cached:
//!
//! * [`has_avx2_fma`] — `cpuid`-backed feature detection
//!   (`std::is_x86_feature_detected!`), queried once per process;
//! * [`kernel_force`] — the `ACF_FORCE_KERNEL` environment override
//!   (`scalar` | `simd` | `auto`), read once per process. CI uses
//!   `ACF_FORCE_KERNEL=scalar` to keep the always-compiled scalar
//!   fallback tested, and the bench harness uses it to measure tiers
//!   against each other.
//!
//! Because both answers are cached in [`std::sync::OnceLock`]s, changing
//! the environment variable after the first kernel call has no effect —
//! dispatch is decided once and stays fixed for the life of the process
//! (which is what keeps runs internally consistent).

use std::sync::OnceLock;

/// Parsed `ACF_FORCE_KERNEL` override for the kernel dispatch tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelForce {
    /// No override: pick the best tier the CPU supports (the default).
    Auto,
    /// Pin the always-compiled scalar-unrolled tier.
    Scalar,
    /// Pin the best SIMD tier (falls back to scalar on architectures
    /// without one).
    Simd,
}

/// The `ACF_FORCE_KERNEL` override, read and parsed once per process.
/// Unset or empty means [`KernelForce::Auto`]; an unrecognized value
/// warns on stderr (once) and behaves as `Auto`.
pub fn kernel_force() -> KernelForce {
    static FORCE: OnceLock<KernelForce> = OnceLock::new();
    *FORCE.get_or_init(|| match std::env::var("ACF_FORCE_KERNEL") {
        Ok(raw) => match raw.to_ascii_lowercase().as_str() {
            "scalar" => KernelForce::Scalar,
            "simd" => KernelForce::Simd,
            "" | "auto" => KernelForce::Auto,
            other => {
                eprintln!("warning: ACF_FORCE_KERNEL={other:?} not recognized (expected scalar|simd|auto); using auto");
                KernelForce::Auto
            }
        },
        Err(_) => KernelForce::Auto,
    })
}

/// Whether the running CPU supports both AVX2 and FMA — the gate for the
/// `avx2+fma` kernel tier. Detection runs once (`cpuid`) and is cached;
/// always `false` off x86_64.
#[cfg(target_arch = "x86_64")]
pub fn has_avx2_fma() -> bool {
    static HAS: OnceLock<bool> = OnceLock::new();
    *HAS.get_or_init(|| std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma"))
}

/// Whether the running CPU supports both AVX2 and FMA — the gate for the
/// `avx2+fma` kernel tier. Always `false` off x86_64.
#[cfg(not(target_arch = "x86_64"))]
pub fn has_avx2_fma() -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_force_is_stable_across_calls() {
        // OnceLock semantics: two reads agree no matter what the
        // environment does in between (we do not mutate env in-process —
        // that is racy across test threads; CI exercises the override in
        // a dedicated forced-scalar leg).
        assert_eq!(kernel_force(), kernel_force());
    }

    #[test]
    fn avx2_detection_is_stable_and_arch_consistent() {
        assert_eq!(has_avx2_fma(), has_avx2_fma());
        if cfg!(not(target_arch = "x86_64")) {
            assert!(!has_avx2_fma());
        }
    }
}
